"""Layer-2 model graphs: shapes, gradients, cube-vs-fp32 training parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def mlp():
    key = jax.random.PRNGKey(42)
    sizes = (64, 128, 128, 32)
    params = model.mlp_init(sizes, key)
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (64, sizes[0]), jnp.float32)
    # Synthetic regression target from a random linear teacher.
    w_true = jax.random.normal(ky, (sizes[0], sizes[-1]), jnp.float32) * 0.3
    y = x @ w_true
    return params, x, y


class TestMlpForward:
    def test_output_shape(self, mlp):
        params, x, _ = mlp
        out = model.mlp_forward(params, x)
        assert out.shape == (64, 32)
        assert out.dtype == jnp.float32

    def test_cube_forward_close_to_fp32_forward(self, mlp):
        params, x, _ = mlp
        out_cube = model.mlp_forward(params, x, matmul=model.cube_mm)
        out_f32 = model.mlp_forward(params, x, matmul=lambda a, b: a @ b)
        np.testing.assert_allclose(np.asarray(out_cube), np.asarray(out_f32), rtol=1e-4, atol=1e-4)

    def test_flat_wrapper_consistent(self, mlp):
        params, x, _ = mlp
        flat_args = [x]
        for w, b in params:
            flat_args.extend([w, b])
        (out_flat,) = model.mlp_forward_flat(*flat_args)
        np.testing.assert_array_equal(np.asarray(out_flat), np.asarray(model.mlp_forward(params, x)))


class TestMlpTraining:
    def test_one_step_reduces_loss(self, mlp):
        params, x, y = mlp
        l0 = float(model.mlp_loss(params, x, y))
        p1, _ = model.mlp_train_step(params, x, y, lr=1e-2)
        l1 = float(model.mlp_loss(p1, x, y))
        assert l1 < l0, f"{l1} !< {l0}"

    def test_gradients_match_fp32_path(self, mlp):
        params, x, y = mlp
        g_cube = jax.grad(model.mlp_loss)(params, x, y, model.cube_mm)
        g_f32 = jax.grad(model.mlp_loss)(params, x, y, lambda a, b: a @ b)
        flat_c, _ = jax.tree_util.tree_flatten(g_cube)
        flat_f, _ = jax.tree_util.tree_flatten(g_f32)
        for gc, gf in zip(flat_c, flat_f):
            denom = np.maximum(np.abs(np.asarray(gf)), 1e-3)
            rel = np.max(np.abs(np.asarray(gc) - np.asarray(gf)) / denom)
            assert rel < 1e-2, f"grad rel diff {rel}"

    def test_short_training_tracks_fp32(self, mlp):
        params, x, y = mlp
        p_cube, p_f32 = params, params
        for _ in range(5):
            p_cube, l_cube = model.mlp_train_step(p_cube, x, y, lr=1e-2)
            p_f32, l_f32 = model.mlp_train_step(p_f32, x, y, lr=1e-2, matmul=lambda a, b: a @ b)
        assert abs(float(l_cube) - float(l_f32)) / float(l_f32) < 0.05

    def test_train_step_flat_returns_loss_and_params(self, mlp):
        params, x, y = mlp
        flat_args = [x, y]
        for w, b in params:
            flat_args.extend([w, b])
        out = model.mlp_train_step_flat(*flat_args)
        assert len(out) == 7  # loss + 3x(W, b)
        assert out[0].shape == ()
        assert out[1].shape == params[0][0].shape


class TestGemmGraphs:
    def test_gemm_graph_matches_kernel(self):
        a = jax.random.uniform(jax.random.PRNGKey(0), (64, 64), jnp.float32, -1, 1)
        b = jax.random.uniform(jax.random.PRNGKey(1), (64, 64), jnp.float32, -1, 1)
        (c,) = model.gemm_graph(a, b)
        err = float(ref.relative_error(ref.dgemm_ref(a, b), c))
        assert err < 5e-7

    def test_hgemm_graph(self):
        a = jax.random.uniform(jax.random.PRNGKey(2), (64, 64), jnp.float32, -1, 1)
        b = jax.random.uniform(jax.random.PRNGKey(3), (64, 64), jnp.float32, -1, 1)
        (c,) = model.hgemm_graph(a, b)
        err = float(ref.relative_error(ref.dgemm_ref(a, b), c))
        assert 1e-6 < err < 1e-3

    def test_split_graph(self):
        x = jax.random.uniform(jax.random.PRNGKey(4), (128, 128), jnp.float32, -1, 1)
        h, l = model.split_graph(x)
        rh, rl = ref.split_ref(x)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(rh))
        np.testing.assert_array_equal(np.asarray(l), np.asarray(rl))
