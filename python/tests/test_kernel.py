"""SGEMM-cube and HGEMM Pallas kernels vs oracles — the core correctness
signal of the L1 layer (kernel vs ref allclose, error-ordering, scaling)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hgemm import hgemm_pallas
from compile.kernels.sgemm_cube import cube_matmul, cube_matmul_split
from compile.kernels.split import split_pallas


def rand(seed, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, lo, hi)


def rel_err(c_true, c):
    return float(ref.relative_error(c_true, c))


class TestCubeKernel:
    @pytest.mark.parametrize("shape", [(64, 64, 64), (128, 96, 80), (32, 256, 48)])
    def test_close_to_ref_oracle(self, shape):
        m, k, n = shape
        a, b = rand(0, (m, k)), rand(1, (k, n))
        kc = cube_matmul(a, b)
        rc = ref.cube_matmul_ref(a, b)
        # Same three terms; the blocked k loop accumulates in a different
        # order than the monolithic dot, so allow accumulation noise at
        # the k*ulp scale.
        np.testing.assert_allclose(np.asarray(kc), np.asarray(rc), rtol=1e-5, atol=2e-5)

    @pytest.mark.parametrize("termwise", [True, False])
    def test_near_fp32_accuracy(self, termwise):
        a, b = rand(2, (96, 96)), rand(3, (96, 96))
        c_true = ref.dgemm_ref(a, b)
        err = rel_err(c_true, cube_matmul(a, b, termwise=termwise))
        assert err < 5e-7, f"termwise={termwise} err={err}"

    def test_beats_hgemm_by_orders_of_magnitude(self):
        # Paper Fig. 8: cube ~1e-7 vs hgemm ~1e-4 at e = 0.
        a, b = rand(4, (128, 128)), rand(5, (128, 128))
        c_true = ref.dgemm_ref(a, b)
        e_cube = rel_err(c_true, cube_matmul(a, b))
        e_h = rel_err(c_true, hgemm_pallas(a, b))
        assert e_cube < e_h / 100, f"cube={e_cube} hgemm={e_h}"

    def test_scaling_matters_at_small_exponents(self):
        # Paper Fig. 8: s_b=0 trails at low exponents, s_b=12 recovers.
        e = 2.0**-10
        a, b = rand(6, (64, 64), -e, e), rand(7, (64, 64), -e, e)
        c_true = ref.dgemm_ref(a, b)
        e0 = rel_err(c_true, cube_matmul(a, b, scale_exp=0))
        e12 = rel_err(c_true, cube_matmul(a, b, scale_exp=12))
        assert e12 < e0 / 5, f"e0={e0} e12={e12}"

    def test_presplit_entry_point(self):
        a, b = rand(8, (128, 128)), rand(9, (128, 128))
        ah, al = split_pallas(a)
        bh, bl = split_pallas(b)
        c = cube_matmul_split(ah, al, bh, bl)
        np.testing.assert_allclose(
            np.asarray(c), np.asarray(ref.cube_matmul_ref(a, b)), rtol=1e-5, atol=1e-6
        )

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 80),
        k=st.integers(1, 80),
        n=st.integers(1, 80),
        e=st.integers(-8, 8),
        seed=st.integers(0, 2**31 - 1),
        termwise=st.booleans(),
    )
    def test_hypothesis_shape_dtype_sweep(self, m, k, n, e, seed, termwise):
        s = 2.0**e
        a = rand(seed, (m, k), -s, s)
        b = rand(seed + 1, (k, n), -s, s)
        c = cube_matmul(a, b, termwise=termwise)
        assert c.shape == (m, n)
        assert c.dtype == jnp.float32
        c_true = np.asarray(ref.dgemm_ref(a, b), np.float64)
        denom = np.linalg.norm(c_true) or 1.0
        err = np.linalg.norm(c_true - np.asarray(c, np.float64)) / denom
        assert err < 1e-5, f"err={err} ({m},{k},{n}) e={e}"

    def test_nonsquare_blocks_pad_correctly(self):
        a, b = rand(10, (130, 70)), rand(11, (70, 190))
        c = cube_matmul(a, b, block=(64, 64, 64))
        np.testing.assert_allclose(
            np.asarray(c), np.asarray(ref.cube_matmul_ref(a, b)), rtol=1e-5, atol=1e-6
        )


class TestHgemmKernel:
    @pytest.mark.parametrize("shape", [(64, 64, 64), (100, 36, 52)])
    def test_matches_ref(self, shape):
        m, k, n = shape
        a, b = rand(12, (m, k)), rand(13, (k, n))
        kc = hgemm_pallas(a, b)
        rc = ref.hgemm_ref(a, b)
        np.testing.assert_allclose(np.asarray(kc), np.asarray(rc), rtol=1e-6, atol=1e-7)

    def test_error_magnitude_order(self):
        a, b = rand(14, (128, 128)), rand(15, (128, 128))
        err = rel_err(ref.dgemm_ref(a, b), hgemm_pallas(a, b))
        assert 1e-5 < err < 1e-3, f"err={err}"


class TestAccumulationOrder:
    def test_termwise_at_least_as_good_at_large_k(self):
        # Paper Fig. 9: termwise beats elementwise as k grows.
        k = 2048
        a, b = rand(16, (16, k), 0.0, 1.0), rand(17, (k, 16), 0.0, 1.0)
        c_true = ref.dgemm_ref(a, b)
        e_tw = rel_err(c_true, ref.cube_matmul_ref(a, b, termwise=True))
        e_el = rel_err(c_true, ref.cube_matmul_ref(a, b, termwise=False))
        assert e_tw <= e_el * 1.05, f"termwise={e_tw} elementwise={e_el}"
