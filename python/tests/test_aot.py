"""AOT pipeline: HLO-text lowering and manifest format."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


class TestHloLowering:
    def test_gemm_graph_lowers_to_hlo_text(self):
        spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        lowered = jax.jit(model.gemm_graph).lower(spec, spec)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # The three-dot structure must survive lowering.
        assert "dot(" in text or "dot." in text

    def test_spec_format(self):
        s = jax.ShapeDtypeStruct((4, 8), jnp.float32)
        assert aot._spec(s) == "float32:4x8"
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        assert aot._spec(scalar) == "float32:"

    def test_artifact_table_well_formed(self):
        table = aot.artifact_table()
        names = [t[0] for t in table]
        assert len(names) == len(set(names)), "duplicate artifact names"
        assert "cube_gemm_128" in names
        assert "mlp_train_step" in names
        for _, fn, args in table:
            assert callable(fn)
            assert all(isinstance(a, jax.ShapeDtypeStruct) for a in args)


class TestManifest:
    @pytest.fixture(scope="class")
    def artifact_dir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        record = aot.lower_artifact(
            "cube_gemm_64", model.gemm_graph,
            [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 2, str(out),
        )
        return out, record

    def test_artifact_written(self, artifact_dir):
        out, _ = artifact_dir
        path = os.path.join(str(out), "cube_gemm_64.hlo.txt")
        assert os.path.exists(path)
        with open(path) as f:
            assert f.read().startswith("HloModule")

    def test_record_fields(self, artifact_dir):
        _, record = artifact_dir
        parts = record.split()
        assert parts[0] == "cube_gemm_64"
        assert parts[1] == "cube_gemm_64.hlo.txt"
        assert parts[2] == "2"  # two inputs
        assert parts[3] == "float32:64x64"
        assert parts[4] == "float32:64x64"
        assert parts[5] == "1"  # one output
        assert parts[6] == "float32:64x64"
