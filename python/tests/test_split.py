"""Split kernel (L1) vs pure-jnp oracle: bit-exactness and precision."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.split import split_pallas


def rand(key, shape, e_lo=-1.0, e_hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, e_lo, e_hi)


class TestSplitKernelVsRef:
    @pytest.mark.parametrize("shape", [(16, 16), (128, 128), (96, 130), (1, 7), (257, 3)])
    def test_bit_exact_against_ref(self, shape):
        x = rand(0, shape)
        kh, kl = split_pallas(x)
        rh, rl = ref.split_ref(x)
        np.testing.assert_array_equal(np.asarray(kh).view(np.uint16), np.asarray(rh).view(np.uint16))
        np.testing.assert_array_equal(np.asarray(kl).view(np.uint16), np.asarray(rl).view(np.uint16))

    @pytest.mark.parametrize("scale_exp", [0, 6, 12])
    def test_scale_exponents(self, scale_exp):
        x = rand(1, (64, 64)) * 0.01
        kh, kl = split_pallas(x, scale_exp)
        rh, rl = ref.split_ref(x, scale_exp)
        np.testing.assert_array_equal(np.asarray(kh), np.asarray(rh))
        np.testing.assert_array_equal(np.asarray(kl), np.asarray(rl))

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 70),
        n=st.integers(1, 70),
        e=st.integers(-12, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes_and_magnitudes(self, m, n, e, seed):
        x = rand(seed, (m, n), -(2.0**e), 2.0**e)
        kh, kl = split_pallas(x)
        rh, rl = ref.split_ref(x)
        np.testing.assert_array_equal(np.asarray(kh), np.asarray(rh))
        np.testing.assert_array_equal(np.asarray(kl), np.asarray(rl))


class TestSplitPrecision:
    def test_reconstruction_recovers_22_bits(self):
        x = rand(2, (128, 128))
        h, l = split_pallas(x)
        r = ref.reconstruct_ref(h, l)
        rel = np.max(np.abs(np.asarray(r, np.float64) - np.asarray(x, np.float64))
                     / np.maximum(np.abs(np.asarray(x, np.float64)), 1e-30))
        assert rel < 2.0**-21, f"rel={rel}"

    def test_zero_maps_to_zero(self):
        x = jnp.zeros((32, 32), jnp.float32)
        h, l = split_pallas(x)
        assert not np.any(np.asarray(h))
        assert not np.any(np.asarray(l))

    def test_fp16_exact_values_have_zero_residual(self):
        x = jnp.asarray([[1.0, 0.5, -2.0, 1024.0]], jnp.float32)
        h, l = split_pallas(x)
        np.testing.assert_array_equal(np.asarray(h, np.float32), np.asarray(x))
        assert not np.any(np.asarray(l, np.float32))

    def test_unscaled_split_degrades_small_values(self):
        # Rule 1: below 2^-12, s_b = 0 loses significant precision.
        x = rand(3, (64, 64)) * 2.0**-13
        h0, l0 = split_pallas(x, scale_exp=0)
        h12, l12 = split_pallas(x, scale_exp=12)
        err0 = np.max(np.abs(np.asarray(ref.reconstruct_ref(h0, l0, 0), np.float64) - np.asarray(x, np.float64)))
        err12 = np.max(np.abs(np.asarray(ref.reconstruct_ref(h12, l12, 12), np.float64) - np.asarray(x, np.float64)))
        assert err12 < err0 / 10, f"err12={err12} err0={err0}"
