"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per artifact plus ``manifest.txt`` with
whitespace-separated records the rust loader parses without a JSON
dependency::

    name  file  n_inputs  in0_spec  in1_spec ...  n_outputs  out0_spec ...

where a spec is ``dtype:d0xd1x...``.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(s: jax.ShapeDtypeStruct) -> str:
    dims = "x".join(str(d) for d in s.shape)
    return f"{jnp.dtype(s.dtype).name}:{dims}"


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_table():
    """(name, fn, example_args) for every shipped artifact.

    Shapes are chosen to exercise the runtime at quickstart scale (64³),
    serving scale (128³/256³) and the MLP end-to-end path. All are
    multiples of 16 per the cube-alignment constraint (Eq. 12).
    """
    mlp_sizes = (64, 128, 128, 32)
    batch = 64
    mlp_args = [f32(batch, mlp_sizes[0])]
    train_args = [f32(batch, mlp_sizes[0]), f32(batch, mlp_sizes[-1])]
    for d_in, d_out in zip(mlp_sizes[:-1], mlp_sizes[1:]):
        mlp_args.append(f32(d_in, d_out))
        mlp_args.append(f32(d_out))
    train_args.extend(mlp_args[1:])

    return [
        ("cube_gemm_64", model.gemm_graph, [f32(64, 64), f32(64, 64)]),
        ("cube_gemm_128", model.gemm_graph, [f32(128, 128), f32(128, 128)]),
        ("cube_gemm_256", model.gemm_graph, [f32(256, 256), f32(256, 256)]),
        ("cube_gemm_128x256x128", model.gemm_graph, [f32(128, 256), f32(256, 128)]),
        ("hgemm_128", model.hgemm_graph, [f32(128, 128), f32(128, 128)]),
        ("split_128", model.split_graph, [f32(128, 128)]),
        ("mlp_forward", model.mlp_forward_flat, mlp_args),
        ("mlp_train_step", model.mlp_train_step_flat, train_args),
    ]


def lower_artifact(name, fn, args, out_dir):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    # Recover output specs from the lowered computation.
    out_avals = lowered.out_info
    flat, _ = jax.tree_util.tree_flatten(out_avals)
    in_specs = " ".join(_spec(a) for a in args)
    out_specs = " ".join(_spec(o) for o in flat)
    record = f"{name} {name}.hlo.txt {len(args)} {in_specs} {len(flat)} {out_specs}"
    print(f"  {name}: {len(text)} chars, {len(args)} in / {len(flat)} out")
    return record


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="lower a single artifact by name")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    records = []
    for name, fn, ex_args in artifact_table():
        if args.only and name != args.only:
            continue
        records.append(lower_artifact(name, fn, ex_args, args.out_dir))

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# name file n_inputs in_specs... n_outputs out_specs...\n")
        f.write("\n".join(records) + "\n")
    print(f"wrote {manifest} ({len(records)} artifacts)")


if __name__ == "__main__":
    main()
