"""Layer-1 Pallas kernel: direct FP16 GEMM with FP32 accumulation.

The baseline HGEMM the paper compares against (Fig. 8): operands are cast
to FP16 (RN) and multiplied on the Cube/MXU with an FP32 accumulator —
one pass, ~11 bits of precision.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hgemm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def hgemm_pallas(a, b, block=(128, 128, 128), interpret: bool = True):
    """``C = fp16(A) · fp16(B)`` with FP32 accumulation; C is FP32.

    Arbitrary shapes are zero-padded to block multiples.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm = min(block[0], _ceil16(m))
    bn = min(block[1], _ceil16(n))
    bk = min(block[2], _ceil16(k))
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ah = a.astype(jnp.float16)
    bh = b.astype(jnp.float16)
    if pm or pk:
        ah = jnp.pad(ah, ((0, pm), (0, pk)))
    if pk or pn:
        bh = jnp.pad(bh, ((0, pk), (0, pn)))
    grid = (ah.shape[0] // bm, bh.shape[1] // bn, ah.shape[1] // bk)
    c = pl.pallas_call(
        _hgemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ah.shape[0], bh.shape[1]), jnp.float32),
        interpret=interpret,
    )(ah, bh)
    return c[:m, :n] if (pm or pn) else c


def _ceil16(x: int) -> int:
    return ((x + 15) // 16) * 16
