"""Layer-1 Pallas kernel: the FP32 -> 2xFP16 operand split (Eq. 7).

A pure elementwise kernel, tiled so each grid step converts one block in
VMEM. On a real TPU this runs on the VPU with the block schedule keeping
the conversion off the matrix path; under ``interpret=True`` it lowers to
plain HLO the CPU PJRT client can run (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_SCALE_EXP


def _split_kernel(x_ref, high_ref, low_ref, *, sf: float):
    x = x_ref[...]
    high = x.astype(jnp.float16)
    resid = (x - high.astype(jnp.float32)) * jnp.float32(sf)
    high_ref[...] = high
    low_ref[...] = resid.astype(jnp.float16)


def split_pallas(x, scale_exp: int = DEFAULT_SCALE_EXP, block=(128, 128), interpret: bool = True):
    """Split a 2-D FP32 array into (high, low) FP16 components.

    Shapes need not be multiples of ``block``; inputs are zero-padded and
    the outputs sliced back (zeros split to zeros exactly).
    """
    assert x.ndim == 2, "split_pallas expects a matrix"
    m, n = x.shape
    bm, bn = (min(block[0], m), min(block[1], n))
    pm, pn = (-m) % bm, (-n) % bn
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    gm, gn = xp.shape[0] // bm, xp.shape[1] // bn

    kernel = functools.partial(_split_kernel, sf=2.0 ** scale_exp)
    high, low = pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xp.shape, jnp.float16),
            jax.ShapeDtypeStruct(xp.shape, jnp.float16),
        ],
        interpret=interpret,
    )(xp)
    if pm or pn:
        high, low = high[:m, :n], low[:m, :n]
    return high, low
