"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from . import hgemm, ref, sgemm_cube, split  # noqa: F401
