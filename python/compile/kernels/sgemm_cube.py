"""Layer-1 Pallas kernel: the three-term SGEMM-cube block GEMM.

The kernel consumes pre-split operands (high/low FP16 components from
``split.py``) and computes the three dominant terms of Eq. (7) with a
blocked (m, n, k) grid:

* Grid axes are ordered ``(m-block, n-block, k-block)`` with k innermost,
  so the A block stays resident across the n sweep — the Pallas/Mosaic
  analogue of the paper's "A resident in L1, B streamed" schedule
  (Sec. 5.1.1); the pipeline double-buffers the VMEM windows exactly like
  the paper's double-buffered L1 (Sec. 5.1.2, see DESIGN.md
  §Hardware-Adaptation).
* Each grid step issues three MXU/Cube matmuls (hh, hl, lh) on FP16
  inputs with FP32 accumulation (``preferred_element_type``).
* **Termwise** mode keeps two FP32 accumulators — the high-high term and
  the aggregated corrections — merging them only after the k sweep
  (Fig. 3b). **Elementwise** mode folds everything into one running
  accumulator per k step (Fig. 3a).

Block sizes default to multiples of 16 mirroring Eq. (12)'s cube
alignment; TPU tile alignment (8×128) is satisfied by the 128-multiples
used for the shipped artifacts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_SCALE_EXP
from .split import split_pallas


def _cube_kernel_termwise(ah_ref, al_ref, bh_ref, bl_ref, hh_ref, corr_ref, *, inv_sf):
    """One (m, n, k) grid step: accumulate hh and (hl + lh) separately."""
    del inv_sf  # applied at reconstruction time, outside the k loop

    @pl.when(pl.program_id(2) == 0)
    def _init():
        hh_ref[...] = jnp.zeros_like(hh_ref)
        corr_ref[...] = jnp.zeros_like(corr_ref)

    ah = ah_ref[...]
    al = al_ref[...]
    bh = bh_ref[...]
    bl = bl_ref[...]
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    hh_ref[...] += dot(ah, bh)
    corr_ref[...] += dot(ah, bl) + dot(al, bh)


def _cube_kernel_elementwise(ah_ref, al_ref, bh_ref, bl_ref, o_ref, *, inv_sf):
    """One grid step folding all three terms into a single accumulator."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ah = ah_ref[...]
    al = al_ref[...]
    bh = bh_ref[...]
    bl = bl_ref[...]
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    hh = dot(ah, bh)
    hl = dot(ah, bl)
    lh = dot(al, bh)
    o_ref[...] += hh + (hl + lh) * jnp.float32(inv_sf)


def cube_matmul_split(
    ah, al, bh, bl,
    scale_exp: int = DEFAULT_SCALE_EXP,
    termwise: bool = True,
    block=(128, 128, 128),
    interpret: bool = True,
):
    """SGEMM-cube over pre-split FP16 components. Returns FP32 ``C``.

    Shapes must tile exactly by ``block`` (the public entry point
    ``cube_matmul`` pads arbitrary shapes).
    """
    (m, k), (k2, n) = ah.shape, bh.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm, bn, bk = block
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k},{n}) not tiled by block ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    inv_sf = 2.0 ** (-scale_exp)

    if termwise:
        kernel = functools.partial(_cube_kernel_termwise, inv_sf=inv_sf)
        hh, corr = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[a_spec, a_spec, b_spec, b_spec],
            out_specs=[o_spec, o_spec],
            out_shape=[
                jax.ShapeDtypeStruct((m, n), jnp.float32),
                jax.ShapeDtypeStruct((m, n), jnp.float32),
            ],
            interpret=interpret,
        )(ah, al, bh, bl)
        # Termwise reconstruction: corrections aggregate fully before
        # meeting the high-order product (one vector op, VPU work).
        return hh + corr * jnp.float32(inv_sf)

    kernel = functools.partial(_cube_kernel_elementwise, inv_sf=inv_sf)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(ah, al, bh, bl)


def cube_matmul(
    a, b,
    scale_exp: int = DEFAULT_SCALE_EXP,
    termwise: bool = True,
    block=(128, 128, 128),
    interpret: bool = True,
):
    """Full SGEMM-cube: split FP32 operands, run the three-term kernel.

    Arbitrary shapes are zero-padded up to block multiples (zero rows and
    columns contribute exact zeros) and the result is sliced back.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm = min(block[0], _ceil_mult(m, 16))
    bn = min(block[1], _ceil_mult(n, 16))
    bk = min(block[2], _ceil_mult(k, 16))
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b

    ah, al = split_pallas(ap, scale_exp, block=(bm, bk), interpret=interpret)
    bh, bl = split_pallas(bp, scale_exp, block=(bk, bn), interpret=interpret)
    c = cube_matmul_split(
        ah, al, bh, bl,
        scale_exp=scale_exp,
        termwise=termwise,
        block=(bm, bn, bk),
        interpret=interpret,
    )
    return c[:m, :n] if (pm or pn) else c


def _ceil_mult(x: int, q: int) -> int:
    """Round ``x`` up to a multiple of ``q`` (cube alignment, Eq. 12)."""
    return ((x + q - 1) // q) * q
