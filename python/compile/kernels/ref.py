"""Pure-jnp correctness oracles for the SGEMM-cube kernels.

Everything here is the *reference semantics* the Pallas kernels (and the
rust numerics engine) are validated against:

* ``split_ref``       -- Eq. (7) two-component FP32 -> 2xFP16 split (RN).
* ``reconstruct_ref`` -- high + low / s_f.
* ``hgemm_ref``       -- FP16 GEMM with FP32 accumulation (Cube datapath).
* ``cube_matmul_ref`` -- three-term SGEMM-cube, termwise or elementwise.
* ``dgemm_ref``       -- FP64 ground truth (paper's Eq. 13 reference).
* ``relative_error``  -- Eq. (13).

FP64 requires the x64 flag; this module is build/test-time only (never on
the request path), so enabling it globally here is safe.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# The paper's default residual scaling exponent (Sec. 4.2, Rules 1+2).
DEFAULT_SCALE_EXP = 12


def scale_factor(scale_exp: int = DEFAULT_SCALE_EXP):
    """s_f = 2**s_b as an exact FP32 constant."""
    return jnp.float32(2.0 ** scale_exp)


def split_ref(x, scale_exp: int = DEFAULT_SCALE_EXP):
    """Eq. (7): split FP32 ``x`` into (high fp16, scaled residual fp16).

    ``astype(float16)`` rounds to nearest even -- the Ascend conversion.
    """
    x = x.astype(jnp.float32)
    sf = scale_factor(scale_exp)
    high = x.astype(jnp.float16)
    resid = (x - high.astype(jnp.float32)) * sf
    low = resid.astype(jnp.float16)
    return high, low


def reconstruct_ref(high, low, scale_exp: int = DEFAULT_SCALE_EXP):
    """Inverse of ``split_ref`` up to the residual quantization."""
    sf = scale_factor(scale_exp)
    return high.astype(jnp.float32) + low.astype(jnp.float32) / sf


def _dot_f32(x, y):
    return jnp.dot(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )


def hgemm_ref(a, b):
    """FP16 GEMM with FP32 accumulation (direct Cube execution).

    FP16xFP16 products are exact in FP32, so casting the fp16 operands up
    and multiplying in fp32 reproduces the hardware datapath.
    """
    ah = a.astype(jnp.float32).astype(jnp.float16)
    bh = b.astype(jnp.float32).astype(jnp.float16)
    return _dot_f32(ah, bh)


def cube_matmul_ref(a, b, scale_exp: int = DEFAULT_SCALE_EXP, termwise: bool = True):
    """SGEMM-cube reference: three dominant terms of Eq. (7).

    ``termwise=True`` accumulates each term matrix independently and sums
    the two corrections before adding them to the high-high product
    (Fig. 3b); ``termwise=False`` merges everything into one running sum
    (Fig. 3a, elementwise order at matrix granularity).
    """
    sf = scale_factor(scale_exp)
    ah, al = split_ref(a, scale_exp)
    bh, bl = split_ref(b, scale_exp)
    hh = _dot_f32(ah, bh)
    hl = _dot_f32(ah, bl)
    lh = _dot_f32(al, bh)
    if termwise:
        return hh + (hl + lh) / sf
    return (hh + hl / sf) + lh / sf


def dgemm_ref(a, b):
    """FP64 ground truth (``C_true`` of Eq. 13)."""
    return jnp.dot(
        a.astype(jnp.float64),
        b.astype(jnp.float64),
        preferred_element_type=jnp.float64,
        precision=jax.lax.Precision.HIGHEST,
    )


def relative_error(c_true, c_calc):
    """Eq. (13): ||C_true - C_calc||_2 / ||C_true||_2 (Frobenius)."""
    t = c_true.astype(jnp.float64)
    c = c_calc.astype(jnp.float64)
    return jnp.linalg.norm(t - c) / jnp.linalg.norm(t)
