"""Layer-2 JAX compute graphs, built on the Layer-1 Pallas kernels.

All graphs here are *build-time only*: they are lowered once by
``aot.py`` to HLO text and executed from the rust runtime; Python is
never on the request path.

Graphs:

* ``gemm_graph``       -- one SGEMM-cube matmul (the serving hot path).
* ``hgemm_graph``      -- baseline FP16 GEMM.
* ``split_graph``      -- standalone operand split (for pipelines that
                          cache split operands across requests).
* ``mlp_forward``      -- small MLP inference with every matmul routed
                          through SGEMM-cube.
* ``mlp_train_step``   -- one SGD step (fwd + bwd) of the same MLP; the
                          backward matmuls also run through the cube
                          kernel via a custom JVP, demonstrating the
                          paper's "deep-learning workloads" motivation.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.hgemm import hgemm_pallas
from .kernels.ref import DEFAULT_SCALE_EXP
from .kernels.sgemm_cube import cube_matmul
from .kernels.split import split_pallas


# ---------------------------------------------------------------------------
# GEMM graphs
# ---------------------------------------------------------------------------

def gemm_graph(a, b, scale_exp: int = DEFAULT_SCALE_EXP, termwise: bool = True):
    """One precision-recovery matmul: the artifact behind `runtime::gemm`."""
    return (cube_matmul(a, b, scale_exp=scale_exp, termwise=termwise),)


def hgemm_graph(a, b):
    """Baseline FP16 GEMM artifact."""
    return (hgemm_pallas(a, b),)


def split_graph(x, scale_exp: int = DEFAULT_SCALE_EXP):
    """Standalone split artifact: FP32 matrix -> (high, low) FP16 pair."""
    return split_pallas(x, scale_exp)


# ---------------------------------------------------------------------------
# Cube matmul with a differentiation rule
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def cube_mm(a, b, scale_exp: int = DEFAULT_SCALE_EXP):
    """Differentiable SGEMM-cube matmul (termwise, the paper default)."""
    return cube_matmul(a, b, scale_exp=scale_exp, termwise=True)


def _cube_mm_fwd(a, b, scale_exp):
    return cube_mm(a, b, scale_exp), (a, b)


def _cube_mm_bwd(scale_exp, res, g):
    # The backward matmuls also run through the precision-recovery path:
    # the paper's DL workloads execute fwd *and* bwd on the Cube.
    a, b = res
    da = cube_mm(g, b.T, scale_exp)  # dL/dA = g · Bᵀ
    db = cube_mm(a.T, g, scale_exp)  # dL/dB = Aᵀ · g
    return da, db


cube_mm.defvjp(_cube_mm_fwd, _cube_mm_bwd)


# ---------------------------------------------------------------------------
# MLP (the end-to-end DL workload)
# ---------------------------------------------------------------------------

def mlp_init(sizes, key):
    """Initialize MLP parameters: list of (W, b) with He-normal weights."""
    params = []
    for d_in, d_out in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (d_in, d_out), jnp.float32) * jnp.sqrt(2.0 / d_in)
        params.append((w, jnp.zeros((d_out,), jnp.float32)))
    return params


def mlp_forward(params, x, matmul=cube_mm):
    """MLP forward pass; every layer matmul goes through ``matmul``."""
    h = x
    for i, (w, b) in enumerate(params):
        h = matmul(h, w) + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def mlp_loss(params, x, y, matmul=cube_mm):
    """Mean-squared-error regression loss."""
    pred = mlp_forward(params, x, matmul)
    return jnp.mean((pred - y) ** 2)


def mlp_train_step(params, x, y, lr=1e-2, matmul=cube_mm):
    """One SGD step; returns (new_params, loss)."""
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, y, matmul)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss


# ---------------------------------------------------------------------------
# Flattened export wrappers (PJRT-friendly signatures: only arrays)
# ---------------------------------------------------------------------------

def mlp_forward_flat(x, w0, b0, w1, b1, w2, b2):
    """3-layer MLP forward with a flat arg list, for AOT export."""
    params = [(w0, b0), (w1, b1), (w2, b2)]
    return (mlp_forward(params, x),)


def mlp_train_step_flat(x, y, w0, b0, w1, b1, w2, b2):
    """One SGD step with flat args; returns (loss, w0', b0', ..., b2')."""
    params = [(w0, b0), (w1, b1), (w2, b2)]
    new_params, loss = mlp_train_step(params, x, y)
    flat = [loss]
    for w, b in new_params:
        flat.extend([w, b])
    return tuple(flat)
