#!/usr/bin/env python3
"""Check relative links and anchors in the repo's markdown docs.

Walks every tracked *.md file (skipping .git/, target/, and vendored
trees), extracts inline links, and verifies that

* relative file links resolve to an existing file or directory, and
* fragment links (``#anchor``) match a heading in the target file,
  using GitHub's slugification (lowercase, punctuation stripped,
  spaces -> hyphens, ``-1``/``-2`` suffixes for duplicates).

External links (http/https/mailto) are not fetched — the CI docs job
must stay hermetic. Exits non-zero listing every broken link.

Usage: python3 tools/check_md_links.py [repo_root]
"""

import os
import re
import sys

SKIP_DIRS = {".git", "target", "node_modules", "__pycache__", ".claude"}

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d not in SKIP_DIRS)
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def strip_fences(text):
    """Blank out fenced code blocks so example links are not checked."""
    out, in_fence = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
        else:
            out.append("" if in_fence else line)
    return "\n".join(out)


def slugify(heading):
    """GitHub-style anchor slug for one heading (pre-dedup)."""
    # Inline code and emphasis markers contribute their text only.
    heading = re.sub(r"[`*_]", "", heading)
    # Markdown links in headings anchor on the link text.
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    heading = heading.strip().lower()
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def anchors_of(path, cache={}):
    if path not in cache:
        with open(path, encoding="utf-8") as f:
            text = strip_fences(f.read())
        slugs, seen = set(), {}
        for line in text.splitlines():
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = slugify(m.group(1))
            n = seen.get(slug, 0)
            seen[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
        cache[path] = slugs
    return cache[path]


def check_file(path, root):
    errors = []
    with open(path, encoding="utf-8") as f:
        text = strip_fences(f.read())
    rel = os.path.relpath(path, root)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL) or target.startswith("<"):
            continue
        target, _, fragment = target.partition("#")
        if target:
            dest = os.path.normpath(os.path.join(os.path.dirname(path), target))
        else:
            dest = path  # same-file fragment
        if not os.path.exists(dest):
            errors.append(f"{rel}: broken link '{m.group(1)}' (no such file)")
            continue
        if fragment:
            if not dest.endswith(".md") or os.path.isdir(dest):
                continue  # anchors into non-markdown targets: not checked
            if fragment.lower() not in anchors_of(dest):
                errors.append(f"{rel}: broken anchor '{m.group(1)}'")
    return errors


def main():
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else ".")
    files = list(md_files(root))
    errors = []
    for path in files:
        errors.extend(check_file(path, root))
    print(f"checked {len(files)} markdown files under {root}")
    if errors:
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        print(f"{len(errors)} broken link(s)", file=sys.stderr)
        return 1
    print("all relative links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
