#!/usr/bin/env python3
"""Render the EXPERIMENTS.md measured tables from the bench JSONs.

The fig11 bench (`cargo bench --bench fig11_blocking_perf`) writes every
measurement to BENCH_gemm.json at the repo root, and the serving load
harness (`cargo bench --bench serving_load`) writes BENCH_serving.json
next to it; the CI bench-smoke and serving-smoke jobs upload both as
workflow artifacts on every PR. This script turns that JSON into the
markdown rows EXPERIMENTS.md keeps in §Perf-iteration-log (item 3),
§Serving-amortization, §Resilience, §Overlap, §Executor,
§Kernel-dispatch, §Precision-family and §Serving-SLO, so filling the
tables is mechanical:

    python3 tools/render_bench_tables.py [BENCH_gemm.json] [BENCH_serving.json]

Degrades gracefully: rows whose records are missing from the JSON (an
older bench run, a partial artifact) render as "_pending_", and a
missing or malformed JSON file renders every row pending — the exit
status is 0 in all cases, so the script is safe to call from docs
tooling regardless of which bench revision produced the file.
"""

import json
import sys

PENDING = "_pending_"


def fmt_s(v):
    if v is None:
        return PENDING
    if v >= 1.0:
        return f"{v:.3f} s"
    if v >= 1e-3:
        return f"{v * 1e3:.3f} ms"
    return f"{v * 1e6:.1f} µs"


def fmt_x(v):
    return PENDING if v is None else f"{v:.2f}×"


def fmt_f(v, digits=3):
    return PENDING if v is None else f"{v:.{digits}f}"


def fmt_ns(v):
    return PENDING if v is None else f"{v:,.0f} ns"


def load_rows(path):
    try:
        rows = json.load(open(path))
    except (OSError, ValueError) as e:
        print(f"warning: could not read {path} ({e}); rendering all rows as {PENDING}",
              file=sys.stderr)
        return []
    if not isinstance(rows, list):
        print(f"warning: {path} is not a JSON array; rendering all rows as {PENDING}",
              file=sys.stderr)
        return []
    return [r for r in rows if isinstance(r, dict) and "name" in r]


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_gemm.json"
    rows = load_rows(path)

    def find(prefix):
        for r in rows:
            if r["name"].startswith(prefix):
                return r
        return None

    def med(prefix):
        r = find(prefix)
        return None if r is None else r.get("median_s")

    def gflops(prefix):
        r = find(prefix)
        return PENDING if r is None or r.get("gflops") is None else str(r["gflops"])

    def ratio(num, den):
        return None if num is None or den is None or den == 0 else num / den

    three = med("host/cube_gemm_three_pass/")
    blocked = med("host/cube_gemm_blocked/")

    print("## §Perf-iteration-log item 3 (blocked engine vs three-pass)\n")
    print("| kernel | median s | GFLOP/s | speedup vs three-pass |")
    print("|--------|----------|---------|-----------------------|")
    entries = [
        ("host/cube_gemm_three_pass/", "1.0×"),
        ("host/cube_gemm_blocked/", fmt_x(ratio(three, blocked))),
        ("host/sgemm_blocked/", "—"),
        ("host/hgemm_blocked/", "—"),
    ]
    for prefix, speed in entries:
        r = find(prefix)
        name = r["name"] if r else prefix + "…"
        print(f"| `{name}` | {fmt_s(med(prefix))} | {gflops(prefix)} | {speed} |")

    print("\n## §Serving-amortization\n")
    print("| record | median | note |")
    print("|--------|--------|------|")
    print(f"| `serving/cube_repack` | {fmt_s(med('serving/cube_repack/'))} | split+pack per request |")
    print(f"| `serving/cube_prepacked` | {fmt_s(med('serving/cube_prepacked/'))} | panels from cache |")
    print(f"| `serving/prepacked_speedup` | {fmt_x(med('serving/prepacked_speedup/'))} | gate: ≥ 1.2× |")
    print(f"| `serving/cube_prepacked_ab` | {fmt_s(med('serving/cube_prepacked_ab/'))} | cached B + prefetched A (kernel-only) |")
    print(f"| `serving/prepacked_ab_speedup` | {fmt_x(med('serving/prepacked_ab_speedup/'))} | gate: ≥ 1.0× vs repack |")
    print(f"| `serving/prepacked_ab_inline_pack_s` | {fmt_s(med('serving/prepacked_ab_inline_pack_s'))} | consumer inline packs (≈ 0 when the ring keeps up) |")
    print(f"| `serving/prepacked_ab_consumer_wait_s` | {fmt_s(med('serving/prepacked_ab_consumer_wait_s'))} | consumer stalls behind the prefetcher (≈ 0 when the ring keeps up) |")

    print("\n## §Resilience\n")
    print("| record | value | note |")
    print("|--------|-------|------|")
    print(f"| `serving/cube_sharded4` | {fmt_s(med('serving/cube_sharded4/'))} | 4-shard fan-out, all healthy |")
    print(f"| `serving/shard_scaling` | {fmt_x(med('serving/shard_scaling'))} | vs single prepacked; runner-core dependent (CI floor 0.25×) |")
    print(f"| `serving/cube_sharded3of4` | {fmt_s(med('serving/cube_sharded3of4/'))} | one shard killed, slice on a survivor |")
    print(f"| `serving/failover_overhead` | {fmt_x(med('serving/failover_overhead'))} | degraded vs healthy sharded; CI band [0.25×, 4.0×] |")

    print("\n## §Overlap\n")
    print("| record | value | note |")
    print("|--------|-------|------|")
    print(f"| `host/cube_gemm_blocked` | {fmt_s(blocked)} | serial: pack on the critical path |")
    print(f"| `host/cube_gemm_overlapped` | {fmt_s(med('host/cube_gemm_overlapped/'))} | prefetched B panels |")
    print(f"| `blocked/overlap_speedup` | {fmt_x(med('blocked/overlap_speedup'))} | sanity floor 1.0× |")
    for stage in ("pack_a", "pack_b", "kernel", "c_update"):
        v = None
        for r in rows:
            if r["name"].startswith("blocked/stage/") and r["name"].endswith(f"/{stage}_s"):
                v = r.get("median_s")
                break
        print(f"| stage `{stage}` | {fmt_s(v)} | instrumented serial pass |")
    print(f"| `blocked/alpha_measured` | {fmt_f(med('blocked/alpha_measured'))} | replaces hard-coded α = 0.25 |")
    print(f"| `sim/double_util_alpha_measured` | {fmt_f(med('sim/double_util_alpha_measured'))} | paper anchor 0.766 |")

    print("\n## §Executor\n")
    print("| record | value | note |")
    print("|--------|-------|------|")
    print(f"| `host/cube_gemm_overlapped_ab` | {fmt_s(med('host/cube_gemm_overlapped_ab/'))} | A+B dual-panel pipeline |")
    print(f"| `blocked/overlap_speedup` | {fmt_x(med('blocked/overlap_speedup'))} | B-only prefetch baseline |")
    print(f"| `blocked/ab_overlap_speedup` | {fmt_x(med('blocked/ab_overlap_speedup'))} | gate: ≥ 0.90 × overlap_speedup |")
    print(f"| `exec/pool_spawn_overhead_ns` | {fmt_ns(med('exec/pool_spawn_overhead_ns'))} | run_chunks round-trip on the pool |")
    print(f"| `exec/steals` | {fmt_f(med('exec/steals'), 0)} | tasks taken from a peer worker's queue |")
    print(f"| `exec/steal_ratio` | {fmt_f(med('exec/steal_ratio'))} | steals / (steals + failed attempts); 0 when idle |")

    print("\n## §Kernel-dispatch\n")
    lane = med("kernel/lane")
    lane_cell = PENDING
    if lane is not None:
        lane_cell = {0: "scalar (0)", 1: "avx2 (1)", 2: "neon (2)", 3: "avx512 (3)"}.get(
            int(lane), f"? ({lane:.0f})"
        )
    mr, nr = med("kernel/mr"), med("kernel/nr")
    tile = PENDING if mr is None or nr is None else f"{mr:.0f} × {nr:.0f}"
    print("| record | value | note |")
    print("|--------|-------|------|")
    print(f"| `kernel/lane` | {lane_cell} | 0 scalar / 1 avx2 / 2 neon / 3 avx512 |")
    print(f"| `kernel/mr` × `kernel/nr` | {tile} | detected lane's micro-tile (8 × 16 on avx512, 4 × 8 elsewhere) |")
    print(f"| `host/sgemm_blocked_scalar` | {fmt_s(med('host/sgemm_blocked_scalar/'))} | blocked fp32, scalar lane forced |")
    print(f"| `blocked/simd_speedup` | {fmt_x(med('blocked/simd_speedup'))} | gate: ≥ 2× when avx2/avx512 detected |")
    print(f"| `host/sgemm_blocked_avx512` | {fmt_s(med('host/sgemm_blocked_avx512/'))} | blocked fp32, avx512 lane forced (AVX-512F hosts only) |")
    print(f"| `blocked/avx512_vs_avx2` | {fmt_x(med('blocked/avx512_vs_avx2/'))} | avx512 vs forced avx2; CI sanity floor 0.5× |")

    print("\n## §Precision-family\n")
    print("| record | value | note |")
    print("|--------|-------|------|")
    print(f"| `precision/fp16x2` | {fmt_s(med('precision/fp16x2/'))} | family engine, N = 2 FP16 (bit-identical to the cube path) |")
    print(f"| `precision/fp16x2_bits` | {fmt_f(med('precision/fp16x2_bits'), 1)} | derived bound ≈ 22 in-window; CI floor 18 |")
    print(f"| `precision/bf16x2` | {fmt_s(med('precision/bf16x2/'))} | full-exponent-range BF16 pair |")
    print(f"| `precision/bf16x2_bits` | {fmt_f(med('precision/bf16x2_bits'), 1)} | derived bound ≈ 16; CI floor 12 |")
    print(f"| `precision/bf16x3` | {fmt_s(med('precision/bf16x3/'))} | exact 3-way split, accumulation-limited |")
    print(f"| `precision/bf16x3_bits` | {fmt_f(med('precision/bf16x3_bits'), 1)} | derived bound ≥ 24; CI floor 18 |")
    print(f"| `precision/frontier` | {fmt_x(med('precision/frontier'))} | bf16x3 cost vs fp16x2 on the same engine |")

    serving_path = sys.argv[2] if len(sys.argv) > 2 else "BENCH_serving.json"
    srows = load_rows(serving_path)

    def smed(name):
        for r in srows:
            if r["name"] == name:
                return r.get("median_s")
        return None

    def fmt_qps(v):
        return PENDING if v is None else f"{v:,.0f} req/s"

    print("\n## §Serving-SLO\n")
    print("| record | value | note |")
    print("|--------|-------|------|")
    for conc in (1, 2, 4):
        qps = fmt_qps(smed(f"serving/wire_qps_c{conc}"))
        tail = fmt_s(smed(f"serving/wire_p99_s_c{conc}"))
        print(f"| closed-loop c={conc} | {qps} (p99 {tail}) | one in-flight request per connection |")
    print(f"| `serving/wire_qps_at_slo` | {fmt_qps(smed('serving/wire_qps_at_slo'))} | **headline**: best closed-loop QPS with p99 ≤ 50 ms |")
    print(f"| `serving/wire_slo_p99_s` | {fmt_s(smed('serving/wire_slo_p99_s'))} | p99 at that operating point |")
    print(f"| `serving/wire_open_qps` | {fmt_qps(smed('serving/wire_open_qps'))} | paced at ~60% of closed-loop peak |")
    print(f"| `serving/wire_open_p99_s` | {fmt_s(smed('serving/wire_open_p99_s'))} | open-loop tail (queueing included) |")
    print(f"| `serving/wire_errors` | {fmt_f(smed('serving/wire_errors'), 0)} | client-observed failures; CI gate: 0 |")
    print(f"| `serving/wire_shed` / `serving/wire_timeouts` | {fmt_f(smed('serving/wire_shed'), 0)} / {fmt_f(smed('serving/wire_timeouts'), 0)} | server admission/deadline counters; CI gate: 0 |")


if __name__ == "__main__":
    main()
