//! Bench target for the design-choice ablations (DESIGN.md §5 footer):
//! omitted low·low term, RN/RZ rounding modes, dynamic s_b selection.

use sgemm_cube::experiments::ablations;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let (n, seeds) = if quick { (48, 1) } else { (96, 3) };
    ablations::run_low_low(n, seeds).emit(None);
    ablations::run_rounding(n, seeds).emit(None);
    ablations::run_dynamic_scaling(n.min(48), seeds).emit(None);
    println!("anchors: low-low omission costs <~0.5 bit while a 4th GEMM would cost +33%;");
    println!("RZ splitting loses ~1-2 bits (Markidis-style, Table 2); RZ accumulation is");
    println!("measurably worse than RN on deep cancellation-free sums (Ootomo's finding);");
    println!("the range policy (Eq. 6 + low-side fp32 fallback) wins below the s_b=12 window.");
}
