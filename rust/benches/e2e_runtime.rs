//! End-to-end runtime hot-path bench: PJRT artifact execution latency /
//! throughput, the native numerics engine, and the coordinator's
//! batched-serving throughput. Uses the custom harness in
//! `sgemm_cube::util::bench` (the image has no criterion).

use std::time::Duration;

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::policy::PrecisionPolicy;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::gemm::backend::{Backend, GemmBackend};
use sgemm_cube::util::bench::Bencher;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() {
    let mut b = Bencher::quick();
    let mut rng = Rng::new(42);

    println!("== native numerics engine (host CPU) ==");
    for n in [64usize, 128, 256] {
        let a = Matrix::random_symmetric(n, n, 0, &mut rng);
        let bb = Matrix::random_symmetric(n, n, 0, &mut rng);
        let flops = 2.0 * (n * n * n) as f64;
        for backend in [Backend::Fp32, Backend::Fp16, Backend::CubeTermwise] {
            let exec = GemmBackend::new(backend);
            b.bench(&format!("native/{}/{}³", backend.name(), n), Some(flops), || {
                exec.gemm(&a, &bb)
            });
        }
    }

    println!("\n== PJRT artifact execution (AOT Pallas kernels) ==");
    pjrt_benches(&mut b, &mut rng);

    println!("\n== coordinator serving throughput ==");
    let svc = GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 0,
        ..Default::default()
    });
    let n = 96usize;
    let reqs = 32usize;
    let flops = 2.0 * (n * n * n) as f64 * reqs as f64;
    b.bench(&format!("serve/{reqs}x{n}³ batched"), Some(flops), || {
        let mut rng = Rng::new(7);
        let rxs: Vec<_> = (0..reqs)
            .map(|_| {
                let a = Matrix::random_symmetric(n, n, 0, &mut rng);
                let bb = Matrix::random_symmetric(n, n, 0, &mut rng);
                svc.submit(a, bb, None).expect("submit")
            })
            .collect();
        for (_, rx) in rxs {
            rx.recv().unwrap().result.unwrap();
        }
    });
    println!("\n{}", svc.metrics().report().line());
    svc.shutdown();
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &mut Bencher, rng: &mut Rng) {
    use sgemm_cube::runtime::Engine;
    match Engine::from_default_dir() {
        Ok(engine) => {
            for (name, n) in [("cube_gemm_64", 64usize), ("cube_gemm_128", 128), ("cube_gemm_256", 256)] {
                let a = Matrix::random_symmetric(n, n, 0, rng);
                let bb = Matrix::random_symmetric(n, n, 0, rng);
                let flops = 2.0 * (n * n * n) as f64;
                // warm the executable cache outside the timer
                let _ = engine.gemm(name, &a, &bb).unwrap();
                b.bench(&format!("pjrt/{name}"), Some(flops), || {
                    engine.gemm(name, &a, &bb).unwrap()
                });
            }
            let x = Matrix::random_normal(64, 64, 1.0, rng);
            let mut args: Vec<Matrix<f32>> = vec![x];
            for w in [64usize, 128, 128, 32].windows(2) {
                args.push(Matrix::random_normal(w[0], w[1], 0.1, rng));
                args.push(Matrix::zeros(1, w[1]));
            }
            let refs: Vec<&Matrix<f32>> = args.iter().collect();
            let _ = engine.run("mlp_forward", &refs).unwrap();
            b.bench("pjrt/mlp_forward(batch=64)", None, || {
                engine.run("mlp_forward", &refs).unwrap()
            });
        }
        Err(e) => println!("(skipping PJRT benches: {e}; run `make artifacts`)"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(_b: &mut Bencher, _rng: &mut Rng) {
    println!("(PJRT benches disabled at build time; rerun with --features pjrt)");
}
