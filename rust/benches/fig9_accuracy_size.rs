//! Bench target for Fig. 9: relative error vs matrix size at e = 0 —
//! (a) m = n sweep at fixed k, (b, c) k sweeps stressing accumulation.

use sgemm_cube::experiments::fig9_size_accuracy;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let seeds = if quick { 1 } else { 5 };
    fig9_size_accuracy::run_mn_sweep(&[32, 64, 128, 256], 2816.min(512), seeds).emit(None);
    fig9_size_accuracy::run_k_sweep(32, &[128, 512, 2048, 8192], seeds).emit(None);
    println!("paper anchors: error flat in m,n (depth fixed by k); under k growth the");
    println!("termwise variant consistently beats elementwise and FP32 OpenBLAS SGEMM.");
}
