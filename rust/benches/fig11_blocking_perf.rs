//! Bench target for Fig. 11: throughput vs blocking, single vs double
//! buffer, on the calibrated 910A model.

use sgemm_cube::experiments::fig11_blocking_perf;
use sgemm_cube::sim::blocking::GemmShape;

fn main() {
    let shape = GemmShape::new(5632, 4096, 5632);
    fig11_blocking_perf::run(shape).emit(None);
    let (s, d, frac) = fig11_blocking_perf::headline(shape);
    println!("headline (paper → measured):");
    println!("  single-buffer peak : 41.7 → {s:.1} TFLOP/s");
    println!("  double-buffer peak : 65.3 → {d:.1} TFLOP/s  (+{:.0}%, paper +57%)", (d / s - 1.0) * 100.0);
    println!("  fraction of 85.3   : 77% → {:.0}%", frac * 100.0);
    println!("  best block         : (176, 64, 176), N_fused = 44");
}
