//! Bench target for Fig. 11: throughput vs blocking, single vs double
//! buffer, on the calibrated 910A model — plus the *executed* host
//! counterpart: the cache-blocked packed engine vs the pre-blocking
//! three-pass kernel, the serving-amortization column (prepacked
//! weight panels vs per-request split + pack at a serving-realistic
//! shape, including the kernel-only prepacked-AB row: cached B panels
//! with the A stripe prefetched), and the overlapped-pipeline column
//! (prefetched B panels vs
//! the serial `b_k` loop, `blocked/overlap_speedup`) with the measured
//! stage breakdown and the recalibrated non-overlapped fraction α fed
//! into `sim::pipeline` (`blocked/alpha_measured`), and the
//! precision-family column: per-tier timing plus measured accuracy bits
//! for the fp16x2 / bf16x2 / bf16x3 specs on the one family engine,
//! with `precision/frontier` recording what the exact 3-way BF16 split
//! costs relative to the paper's 2-way FP16 split. Measurements are
//! written to `BENCH_gemm.json` at the repository root (overwritten
//! with the latest run; commit it per PR — the CI bench-smoke job also
//! uploads it as a workflow artifact — see EXPERIMENTS.md
//! §Perf-iteration-log, §Serving-amortization and §Overlap).
//!
//! `QUICK=1 cargo bench --bench fig11_blocking_perf` shrinks the host
//! GEMMs from 1024³ to 256³ for a fast smoke pass; the serving column
//! keeps its 8×1024×1024 shape in both modes (it is cheap — `m = 8` —
//! and the CI gate pins that exact shape).

use std::sync::Arc;

use sgemm_cube::coordinator::metrics::Metrics;
use sgemm_cube::coordinator::{ShardConfig, ShardRouter};
use sgemm_cube::exec::pipeline::DEFAULT_PIPELINE_DEPTH;
use sgemm_cube::exec::pool::{self, Pool};
use sgemm_cube::experiments::fig11_blocking_perf;
use sgemm_cube::gemm::backend::{Backend, Schedule};
use sgemm_cube::gemm::cache::PrepackCache;
use sgemm_cube::gemm::blocked::{
    cube_gemm_blocked, cube_gemm_blocked_overlapped, cube_gemm_blocked_overlapped_ab,
    cube_gemm_blocked_staged, cube_gemm_prepacked, family_gemm_blocked,
    gemm_prepacked_overlapped_ab, gemm_prepacked_overlapped_staged, hgemm_blocked, host_block,
    sgemm_blocked,
};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
use sgemm_cube::gemm::fast::cube_gemm_three_pass;
use sgemm_cube::gemm::kernels::{detect_lane, force_lane, Lane};
use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
use sgemm_cube::sim::blocking::{BlockConfig, GemmShape};
use sgemm_cube::sim::chip::Chip;
use sgemm_cube::sim::pipeline::{Buffering, IterTiming, ALPHA_NONOVERLAP};
use sgemm_cube::softfloat::family::SplitSpec;
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::bench::{black_box, fmt_duration, Bencher};
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() {
    let shape = GemmShape::new(5632, 4096, 5632);
    fig11_blocking_perf::run(shape).emit(None);
    let (s, d, frac) = fig11_blocking_perf::headline(shape);
    println!("headline (paper → measured):");
    println!("  single-buffer peak : 41.7 → {s:.1} TFLOP/s");
    println!("  double-buffer peak : 65.3 → {d:.1} TFLOP/s  (+{:.0}%, paper +57%)", (d / s - 1.0) * 100.0);
    println!("  fraction of 85.3   : 77% → {:.0}%", frac * 100.0);
    println!("  best block         : (176, 64, 176), N_fused = 44");

    // ---- executed host engine: blocked packed kernels vs the baseline ----
    let n = if std::env::var("QUICK").is_ok() { 256 } else { 1024 };
    let block = host_block();
    println!(
        "\nhost-executed blocked engine at {n}³ — block = ({}, {}, {}) from sim::blocking on Chip::host_cpu():",
        block.bm, block.bk, block.bn
    );
    let mut bench = Bencher::quick();
    let mut rng = Rng::new(42);
    let a = Matrix::random_symmetric(n, n, 0, &mut rng);
    let b = Matrix::random_symmetric(n, n, 0, &mut rng);
    let flops = 2.0 * (n as f64).powi(3);
    let cfg = SplitConfig::default();
    bench.bench(&format!("host/cube_gemm_three_pass/{n}^3"), Some(flops), || {
        cube_gemm_three_pass(&a, &b, cfg)
    });
    // Captured here for the overlapped-pipeline comparison below.
    let serial_median = bench
        .bench(&format!("host/cube_gemm_blocked/{n}^3"), Some(flops), || {
            cube_gemm_blocked(&a, &b, cfg)
        })
        .seconds
        .median;
    let sgemm_detected_median = bench
        .bench(&format!("host/sgemm_blocked/{n}^3"), Some(flops), || sgemm_blocked(&a, &b))
        .seconds
        .median;
    bench.bench(&format!("host/hgemm_blocked/{n}^3"), Some(flops), || hgemm_blocked(&a, &b));

    let results = bench.results();
    let speedup = results[0].seconds.median / results[1].seconds.median;
    println!("\ncube blocked-fused vs three-pass speedup: {speedup:.2}x (target ≥ 3x at 1024³)");

    // ---- kernel dispatch: detected SIMD lane vs forced scalar ----
    // The sweeps dispatch per-lane micro-kernels (gemm::kernels):
    // AVX-512F, AVX2+FMA or NEON when the host supports them, portable
    // scalar otherwise. Pinning the scalar lane on the same operands
    // isolates the SIMD contribution; the detected lane is restored
    // before every later measurement. kernel/lane records the detected
    // lane's stable code (0 scalar / 1 avx2 / 2 neon / 3 avx512) and
    // kernel/mr / kernel/nr its register-derived micro-tile, so the CI
    // gate and the EXPERIMENTS table can condition on what the runner
    // actually has.
    let lane = detect_lane();
    let (lane_mr, lane_nr) = lane.tile_dims();
    bench.record_scalar("kernel/lane", lane.code() as f64);
    bench.record_scalar("kernel/mr", lane_mr as f64);
    bench.record_scalar("kernel/nr", lane_nr as f64);
    assert!(force_lane(Lane::Scalar), "the scalar lane is always available");
    let scalar_median = bench
        .bench(&format!("host/sgemm_blocked_scalar/{n}^3"), Some(flops), || sgemm_blocked(&a, &b))
        .seconds
        .median;
    assert!(force_lane(lane), "the detected lane must be forceable");
    let simd_speedup = scalar_median / sgemm_detected_median;
    println!(
        "\nkernel dispatch: lane '{lane}' (micro-tile {lane_mr}x{lane_nr}); \
         detected vs forced-scalar fp32 speedup: {simd_speedup:.2}x \
         (CI gates ≥ 2x on avx2 and ≥ 1.8x on avx512 runners)"
    );
    bench.record_scalar(&format!("blocked/simd_speedup/{n}^3"), simd_speedup);

    // ---- wide lane: forced AVX-512 vs forced AVX2 on the same host ----
    // On AVX-512F hosts, pin both x86 lanes on identical operands: the
    // wide 8×16 micro-tile must not lose to the narrow 4×8 one (the CI
    // acceptance for the wide lane). Skipped silently elsewhere — the
    // records are simply absent and the renderer shows `_pending_`.
    if Lane::Avx512.is_available() {
        assert!(force_lane(Lane::Avx512));
        let avx512_median = bench
            .bench(&format!("host/sgemm_blocked_avx512/{n}^3"), Some(flops), || {
                sgemm_blocked(&a, &b)
            })
            .seconds
            .median;
        if Lane::Avx2.is_available() {
            assert!(force_lane(Lane::Avx2));
            let avx2_median = bench
                .bench(&format!("host/sgemm_blocked_avx2/{n}^3"), Some(flops), || {
                    sgemm_blocked(&a, &b)
                })
                .seconds
                .median;
            let wide_speedup = avx2_median / avx512_median;
            println!(
                "wide-lane dispatch: forced avx512 vs forced avx2 fp32: {wide_speedup:.2}x"
            );
            bench.record_scalar(&format!("blocked/avx512_vs_avx2/{n}^3"), wide_speedup);
        }
        assert!(force_lane(lane), "the detected lane must be restorable");
    }

    // ---- precision-emulation family: cost vs measured bits per tier ----
    // One engine (family_gemm_blocked) serves every tier; the fp16x2
    // spec is bit-identical to cube_gemm_blocked (pinned by the
    // dispatch/property suites), so its timing row doubles as the
    // family-dispatch overhead check. The BF16 tiers put numbers on the
    // frontier the coordinator's budget ladder walks: bf16x2 covers the
    // full f32 exponent range at ~16 bits, bf16x3 splits exactly
    // (3 × 8 ≥ 24 mantissa bits) so only f32 accumulation error
    // remains — FP32-class accuracy off the emulated cube datapath at
    // twice the fused-term count of the 2-way split.
    println!("\nprecision-emulation family at {n}³ (one engine, per-tier spec):");
    let c_ref = dgemm_of_f32(&a, &b);
    let tiers = [
        ("fp16x2", SplitSpec::fp16x2(cfg)),
        ("bf16x2", SplitSpec::bf16x2()),
        ("bf16x3", SplitSpec::bf16x3()),
    ];
    let mut tier_medians = [0.0f64; 3];
    for (i, (tier, spec)) in tiers.iter().enumerate() {
        tier_medians[i] = bench
            .bench(&format!("precision/{tier}/{n}^3"), Some(flops), || {
                family_gemm_blocked(&a, &b, *spec)
            })
            .seconds
            .median;
        let err = relative_error(&c_ref, &family_gemm_blocked(&a, &b, *spec).to_f64());
        // The 1e-15 floor keeps an exactly-zero error finite (~49.8 bits).
        let bits = -err.max(1e-15).log2();
        println!("  {tier}: {bits:.1} measured bits (derived bound {:.0})", spec.bound_bits());
        bench.record_scalar(&format!("precision/{tier}_bits"), bits);
    }
    // Accuracy/cost frontier: what the highest-accuracy tier costs
    // relative to the paper's 2-way split on the same engine.
    let frontier = tier_medians[2] / tier_medians[0];
    println!("  frontier: bf16x3 costs {frontier:.2}x fp16x2 for the exact split");
    bench.record_scalar("precision/frontier", frontier);

    // ---- serving amortization: prepacked weight vs per-request packing ----
    // Serving-realistic shape: small activation batch against a fixed
    // K×N weight. Per request the repack path pays the weight's
    // FP32→2×FP16 split (k·n softfloat conversion pairs) plus the dual
    // panel pack — all O(k·n) work independent of m — while the
    // prepacked path only splits the 8-row activation.
    let (sm, skn) = (8usize, 1024usize);
    println!("\nserving amortization at {sm}×{skn}×{skn} (fixed weight, small activations):");
    let a_act = Matrix::random_symmetric(sm, skn, 0, &mut rng);
    let w = Matrix::random_symmetric(skn, skn, 0, &mut rng);
    let sflops = 2.0 * sm as f64 * skn as f64 * skn as f64;
    let repack_median = bench
        .bench(&format!("serving/cube_repack/{sm}x{skn}x{skn}"), Some(sflops), || {
            cube_gemm_blocked(&a_act, &w, cfg)
        })
        .seconds
        .median;
    let packed = PrepackedMatrix::prepack(&w, PrepackPath::Cube(cfg));
    let prepacked_median = bench
        .bench(&format!("serving/cube_prepacked/{sm}x{skn}x{skn}"), Some(sflops), || {
            cube_gemm_prepacked(&a_act, &packed)
        })
        .seconds
        .median;
    let prepack_speedup = repack_median / prepacked_median;
    println!(
        "prepacked vs per-request packing: {prepack_speedup:.2}x (CI bench-smoke gate ≥ 1.2x)"
    );
    bench.record_scalar(&format!("serving/prepacked_speedup/{sm}x{skn}x{skn}"), prepack_speedup);

    // ---- kernel-only prepacked serving: cached B + prefetched A ----
    // gemm_prepacked_overlapped_ab routes the per-request A stripe
    // through the prefetch ring while B panels stream straight from the
    // prepacked operand, so the consuming sweeps are kernel-only
    // (exec::pipeline). Measured against the same per-request repack
    // baseline as the serial prepacked column; the CI gate is >= 1.0x —
    // the prefetched path must never fall below the baseline that still
    // pays the weight split + pack per request (on a 1-core runner the
    // ring degenerates to the serial prepacked nest, so ~prepack_speedup
    // is expected there too).
    let prepacked_ab_median = bench
        .bench(&format!("serving/cube_prepacked_ab/{sm}x{skn}x{skn}"), Some(sflops), || {
            gemm_prepacked_overlapped_ab(&a_act, &packed, DEFAULT_PIPELINE_DEPTH)
        })
        .seconds
        .median;
    let prepacked_ab_speedup = repack_median / prepacked_ab_median;
    println!(
        "prepacked-AB (prefetched A) vs per-request packing: {prepacked_ab_speedup:.2}x \
         (CI gate ≥ 1.0x)"
    );
    let ab_record = format!("serving/prepacked_ab_speedup/{sm}x{skn}x{skn}");
    bench.record_scalar(&ab_record, prepacked_ab_speedup);
    // Consumer-side critical path of the staged prepacked-AB pass: B is
    // never packed (structurally zero) and A staging reaches the
    // consumer only as inline fallback packs or stalls behind a
    // mid-pack prefetcher — the kernel-only serving evidence for
    // EXPERIMENTS.md §Serving-amortization. Median-of-5 probes by
    // critical-path staging time: a single cold run is hostage to one
    // descheduled prefetcher on a shared runner.
    let mut probes = Vec::new();
    for _ in 0..5 {
        let (c_pp, stages, stats) =
            gemm_prepacked_overlapped_staged(&a_act, &packed, DEFAULT_PIPELINE_DEPTH);
        black_box(c_pp);
        probes.push((stages, stats));
    }
    probes.sort_by(|x, y| x.0.pack_a.total_cmp(&y.0.pack_a));
    let (pp_stages, pp_stats) = probes[probes.len() / 2];
    println!(
        "prepacked-AB consumer critical-path A staging: {} of {} total \
         ({} of {} stripes inline, {} ring wait)",
        fmt_duration(pp_stages.pack_a),
        fmt_duration(pp_stages.total()),
        pp_stats.inline_packs,
        pp_stats.inline_packs + pp_stats.prefetched,
        fmt_duration(pp_stats.wait_s),
    );
    bench.record_scalar("serving/prepacked_ab_inline_pack_s", pp_stats.inline_pack_s);
    bench.record_scalar("serving/prepacked_ab_consumer_wait_s", pp_stats.wait_s);
    bench.record_scalar("serving/prepacked_ab_inline_packs", pp_stats.inline_packs as f64);

    // ---- resilient serving: column-shard fan-out and failover ----
    // The same serving weight column-partitioned across 4 logical
    // shards (coordinator::shard): slice panels are cached per shard,
    // requests fan out one slice-GEMM per live shard and recombine
    // bit-identically. shard_scaling is the healthy 4-shard router
    // against the single prepacked run (fan-out + recombine overhead on
    // a 1-core runner, parallel slices on multi-core); killing a shard
    // reassigns its slice to a survivor, and failover_overhead is the
    // degraded 3-of-4 median against the healthy sharded median —
    // bench-smoke asserts both records exist and stay within sane
    // bounds rather than pinning a ratio (the split is runner-core
    // dependent).
    println!("\nsharded serving at {sm}x{skn}x{skn} (4 column shards, shared prepack cache):");
    let shard_cache = Arc::new(PrepackCache::new(256 << 20));
    let router = Arc::new(ShardRouter::new(
        1,
        &w,
        ShardConfig { count: 4, ..Default::default() },
        shard_cache,
        Arc::new(Metrics::new()),
    ));
    let shard_gemm = |r: &Arc<ShardRouter>| {
        r.gemm(
            &a_act,
            Backend::CubeTermwise,
            cfg.scale_exp,
            PrepackPath::Cube(cfg),
            Schedule::Serial,
            DEFAULT_PIPELINE_DEPTH,
            None,
        )
        .expect("sharded gemm")
    };
    black_box(shard_gemm(&router)); // pack all slice panels once, off the clock
    let shard_median = bench
        .bench(&format!("serving/cube_sharded4/{sm}x{skn}x{skn}"), Some(sflops), || {
            shard_gemm(&router)
        })
        .seconds
        .median;
    let shard_scaling = prepacked_median / shard_median;
    println!("4-shard router vs single prepacked: {shard_scaling:.2}x");
    bench.record_scalar("serving/shard_scaling", shard_scaling);
    router.kill(1); // lose one shard; its slice moves to a survivor
    black_box(shard_gemm(&router));
    let degraded_median = bench
        .bench(&format!("serving/cube_sharded3of4/{sm}x{skn}x{skn}"), Some(sflops), || {
            shard_gemm(&router)
        })
        .seconds
        .median;
    let failover_overhead = degraded_median / shard_median;
    println!("degraded 3-of-4 vs healthy sharded: {failover_overhead:.2}x");
    bench.record_scalar("serving/failover_overhead", failover_overhead);

    // ---- overlapped b_k pipeline: prefetched B panels vs serial pack ----
    // The serial driver packs each B panel on the critical path; the
    // overlapped driver hides that span behind the row sweeps
    // (gemm::overlap). Bit-identical output, different schedule — on a
    // 1-core host the pipeline degenerates to the serial loop, so the
    // CI sanity floor for the speedup is 1.0x (modulo runner noise).
    println!("\noverlapped (double-buffered) b_k pipeline at {n}³:");
    let overlap_median = bench
        .bench(&format!("host/cube_gemm_overlapped/{n}^3"), Some(flops), || {
            cube_gemm_blocked_overlapped(&a, &b, cfg)
        })
        .seconds
        .median;
    let overlap_speedup = serial_median / overlap_median;
    println!("overlapped vs serial blocked: {overlap_speedup:.2}x");
    bench.record_scalar(&format!("blocked/overlap_speedup/{n}^3"), overlap_speedup);

    // ---- A+B dual-panel pipeline on the persistent pool ----
    // The executor subsystem's deeper schedule: a pool prefetch job
    // packs the next block's B panel *and* A row-block stripe through a
    // depth-configurable ring while kernel-only sweeps consume the
    // current one (exec::pipeline). Bit-identical output; CI gates
    // ab_overlap_speedup >= 0.90 * overlap_speedup (A prefetch must not
    // cost pipeline throughput; on multi-core hosts it should exceed
    // the B-only speedup because pack-A leaves the sweep threads).
    println!("\nA+B dual-panel pipeline at {n}³ (ring depth {DEFAULT_PIPELINE_DEPTH}):");
    let ab_median = bench
        .bench(&format!("host/cube_gemm_overlapped_ab/{n}^3"), Some(flops), || {
            cube_gemm_blocked_overlapped_ab(&a, &b, cfg, DEFAULT_PIPELINE_DEPTH)
        })
        .seconds
        .median;
    let ab_speedup = serial_median / ab_median;
    println!("A+B overlapped vs serial blocked: {ab_speedup:.2}x");
    bench.record_scalar(&format!("blocked/ab_overlap_speedup/{n}^3"), ab_speedup);

    // ---- persistent-pool dispatch overhead ----
    // One empty run_chunks round (queue push per chunk + caller
    // participation + completion wait) — the cost that replaced the
    // per-sweep scoped spawn/join. Recorded in nanoseconds. On a
    // 1-worker host run_chunks degenerates to a direct call and would
    // measure nothing, so the record always comes from a >= 2-worker
    // pool (a dedicated one if the global pool is that small) — the
    // number stays comparable across runners with different core
    // counts.
    let gpool = pool::global();
    let nw = gpool.n_workers();
    let owned;
    let (mpool, mworkers) = if nw >= 2 {
        (gpool, nw)
    } else {
        owned = Pool::new(2);
        (&owned, 2)
    };
    let spawn_overhead = bench
        .bench("exec/pool_run_chunks_noop", None, || mpool.run_chunks(mworkers, |_, _| {}))
        .seconds
        .median;
    bench.record_scalar("exec/pool_spawn_overhead_ns", spawn_overhead * 1e9);
    println!(
        "pool dispatch round-trip ({mworkers} workers): {:.0} ns per run_chunks",
        spawn_overhead * 1e9
    );

    // ---- work-stealing instrumentation on the global pool ----
    // Every sweep above enlisted the global pool's per-worker queues, so
    // its cumulative counters describe this whole run: steal_ratio is
    // steals / (steals + hungry parks) — how often an idle scan found a
    // backlog to take versus going to sleep. On a 1-worker pool both
    // counters stay ~0 and the ratio records 0.
    let (steals, steal_fails) = (gpool.steals(), gpool.steal_fails());
    let steal_ratio = if steals + steal_fails == 0 {
        0.0
    } else {
        steals as f64 / (steals + steal_fails) as f64
    };
    println!(
        "work stealing on the global pool: {steals} steals, {steal_fails} hungry parks \
         (ratio {steal_ratio:.3})"
    );
    bench.record_scalar("exec/steals", steals as f64);
    bench.record_scalar("exec/steal_ratio", steal_ratio);

    // ---- measured stage breakdown → recalibrated sim::pipeline α ----
    // The instrumented single-threaded pass times each stage. Deriving
    // T_mem: pack-B runs single-threaded in the *parallel* serial driver
    // too (it sits between the parallel sweeps), so the staged pass's
    // pack_b wall time transfers directly. T_comp is everything else on
    // the serial driver's critical path (parallel sweeps + per-call
    // split), i.e. serial_median − T_mem — deliberately *not* the staged
    // pass's compute share, which would be inflated by the missing
    // parallelism. The overlapped median then pins the non-overlapped
    // fraction α of the paper's T_comp + α·T_mem model.
    let (c_staged, stages) = cube_gemm_blocked_staged(&a, &b, cfg);
    black_box(c_staged);
    println!("\nserial stage breakdown (instrumented single-threaded pass):");
    println!("  {}", stages.line());
    bench.record_stages(&format!("blocked/stage/{n}^3"), &stages);
    let t_mem = stages.transfer().min(serial_median);
    let t_comp = (serial_median - t_mem).max(0.0);
    // Pre-clamp α recorded for diagnosis (noise can push it outside
    // [0, 1]); the clamped value is the one the calibration applies.
    let alpha_raw = IterTiming::alpha_from_measured_raw(t_comp, t_mem, overlap_median);
    bench.record_scalar("blocked/alpha_raw", alpha_raw);
    let alpha = IterTiming::alpha_from_measured(t_comp, t_mem, overlap_median);
    bench.record_scalar("blocked/alpha_measured", alpha);
    let chip = Chip::ascend_910a();
    let best = BlockConfig::paper_best();
    let hard = IterTiming::of(&chip, best, best.n_fused(&chip));
    let meas = IterTiming::from_measured(&chip, best, best.n_fused(&chip), alpha);
    let u_hard = hard.utilization(Buffering::Double, best, &chip);
    let u_meas = meas.utilization(Buffering::Double, best, &chip);
    println!(
        "sim::pipeline calibration: α = {alpha:.3} measured (hard-coded {ALPHA_NONOVERLAP}); \
         double-buffer cube utilization {u_hard:.3} → {u_meas:.3}"
    );
    bench.record_scalar("sim/double_util_alpha_measured", u_meas);

    // Repo root, independent of the bench's working directory.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_gemm.json");
    match bench.write_json(&path) {
        Ok(()) => println!("[json] {}", path.display()),
        Err(e) => eprintln!("warning: could not write {path:?}: {e}"),
    }
}
