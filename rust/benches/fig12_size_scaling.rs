//! Bench target for Fig. 12: size scaling and the 910A-cube vs
//! 910B3-CANN-FP32 cross-platform comparison.

use sgemm_cube::experiments::fig12_size_scaling as fig12;

fn main() {
    fig12::run_mn(2816, &[704, 1408, 2816, 5632, 11264]).emit(None);
    fig12::run_k(5632, &[704, 1408, 2816, 5632, 11264]).emit(None);
    fig12::run_mkn(&[1408, 2816, 5632, 11264]).emit(None);
    println!("paper anchors: m,n growth pushes cube@910A past 60 TF/s, slightly above");
    println!("CANN FP32@910B3 at large m=n; k sweep stable (~60 vs ~63); at very large");
    println!("joint sizes the cube kernel holds utilization (L1-aware blocking).");
}
