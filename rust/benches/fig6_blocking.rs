//! Bench target for Fig. 6: N_fused and fusion factor f across the
//! feasible block space (Eq. 8 / Eq. 12), plus the b_m,opt derivation.

use sgemm_cube::experiments::fig6_blocking;

fn main() {
    fig6_blocking::run().emit(None);
    println!("{}", fig6_blocking::optimal_bm_summary());
    println!("paper anchors: N_fused = 44 at (176, 64, 176); 0.92 ≤ f ≤ 1.");
}
