//! Extension bench (paper future work, Sec. 8: "extending the approach
//! to other low-precision matrix engines"): the two-component **BF16**
//! cube vs the FP16 scheme across the exponent range — accuracy inside
//! the FP16 window, and survival far outside it.

use sgemm_cube::experiments::report::{sci, Table};
use sgemm_cube::gemm::bfcube::{bf16_cube_gemm, bgemm};
use sgemm_cube::gemm::cube::{cube_gemm, Accumulation};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() {
    let n = 64;
    let seeds = 3u64;
    let mut t = Table::new(
        "Extension: BF16 two-component cube vs FP16 scheme vs single-pass BF16",
        &["e", "fp16-cube sb=12", "bf16-cube", "bf16 single"],
    );
    for e in [-55i32, -20, -12, 0, 12, 18, 40, 60] {
        let (mut e16, mut ebf, mut eb1) = (0.0, 0.0, 0.0);
        for s in 0..seeds {
            let mut rng = Rng::new(6000 + s);
            let a = Matrix::from_fn(n, n, |_, _| rng.f32_with_exponent(e));
            let b = Matrix::from_fn(n, n, |_, _| rng.f32_with_exponent(e));
            let c_ref = dgemm_of_f32(&a, &b);
            e16 += relative_error(
                &c_ref,
                &cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise).to_f64(),
            ) / seeds as f64;
            ebf += relative_error(&c_ref, &bf16_cube_gemm(&a, &b).to_f64()) / seeds as f64;
            eb1 += relative_error(&c_ref, &bgemm(&a, &b).to_f64()) / seeds as f64;
        }
        let fmt16 = if e16.is_finite() { sci(e16) } else { "overflow".into() };
        t.row(vec![e.to_string(), fmt16, sci(ebf), sci(eb1)]);
    }
    t.emit(None);
    println!("reading guide: inside the FP16 window ([-12, 15]) the paper's scheme is");
    println!("~6 bits better (22 vs 16 recovered bits); outside it the FP16 high part");
    println!("overflows/underflows while the BF16 pair holds ~1e-5 across the full");
    println!("f32 normal range — the same trade as Ootomo's TF32 full-range fallback.");
    println!("Cost on a dual-format engine is identical: three dominant GEMM terms.");
}
