//! Bench target for Table 2: method comparison with the SGEMM-cube row
//! *measured* on this reproduction (accuracy: numerics engine; perf:
//! calibrated 910A model).

use sgemm_cube::experiments::table2;

fn main() {
    table2::run().emit(None);
    println!("paper anchor row: SGEMM-cube, approx 1–2 bits loss, 65.3 TFLOPS = 77% of 85.3.");
}
