//! Bench target for Table 1: published accelerator peaks, with the 910A
//! row cross-checked against the simulator chip model (`make bench` /
//! `cargo bench --bench table1_peaks`).

use sgemm_cube::experiments::table1;

fn main() {
    table1::run().emit(None);
    println!("paper anchor: Ascend 910A = 256 FP16 TFLOP/s, no native FP32 GEMM.");
}
