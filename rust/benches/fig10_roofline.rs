//! Bench target for Fig. 10: roofline placement of single- vs
//! double-buffered SGEMM-cube on the 910A model.

use sgemm_cube::experiments::fig10_roofline;
use sgemm_cube::sim::blocking::GemmShape;

fn main() {
    fig10_roofline::run(GemmShape::new(5632, 4096, 5632)).emit(None);
    println!("paper anchors: every config's OI lies above the knee (~71 F/B) —");
    println!("compute-bound regime; double buffering lifts throughput but both stay");
    println!("below the 85.3 TF/s FP32-equivalent ceiling.");
}
