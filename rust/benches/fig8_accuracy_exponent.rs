//! Bench target for Fig. 8: relative error vs FP32 offset exponent for
//! both sampling regimes, all methods, s_b ∈ {0, 6, 12}.
//!
//! `QUICK=1 cargo bench --bench fig8_accuracy_exponent` for a fast pass.

use sgemm_cube::experiments::fig8_accuracy::{run, Sampling};

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let (n, seeds) = if quick { (48, 1) } else { (128, 5) };
    let exps: Vec<i32> = (-14..=12).step_by(2).collect();
    run(Sampling::Symmetric, n, &exps, seeds).emit(None);
    run(Sampling::NonNegative, n, &exps, seeds).emit(None);
    println!("paper anchors: hgemm ~1e-4; cube s_b=12 within ~1 order of fp32 SGEMM");
    println!("(termwise surpassing it at small exponents); s_b=6 insufficient below e≈-6;");
    println!("symmetric sampling inflates all errors via cancellation in ||C_true||.");
}
