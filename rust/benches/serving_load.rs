//! Serving-SLO load harness: drives the wire front door over real
//! sockets and records sustained QPS at a p99 latency SLO into
//! `BENCH_serving.json` (rendered into EXPERIMENTS.md §Serving-SLO by
//! `tools/render_bench_tables.py`, gated by the `serving-smoke` CI
//! job).
//!
//! Two arrival disciplines, per EXPERIMENTS.md:
//!
//! * **closed-loop** — each connection keeps exactly one request in
//!   flight; sweeping the connection count maps the throughput/latency
//!   frontier. The headline metric is the highest measured QPS whose
//!   client-observed p99 still meets the SLO (`wire_qps_at_slo`).
//! * **open-loop** — requests are paced at a fixed arrival rate
//!   regardless of completions, so queueing delay is visible in the
//!   tail instead of being absorbed by backpressure.
//!
//! Traffic is mixed: three registered weight panels of different
//! shapes, rotating activation heights and per-request precision
//! options (policy default, an explicit precision budget, a pinned
//! backend), all through register-then-serve `POST /gemm`.
//!
//! `QUICK=1 cargo bench --bench serving_load` shrinks the measurement
//! windows for CI smoke; latencies are exact sorted samples, not
//! histogram buckets, so the p99 needs no estimator caveats.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::net::{NetClient, NetConfig, NetServer, WireOpts};
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::util::bench::Bencher;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

/// The serving SLO: client-observed p99 latency must stay within 50ms.
const SLO_P99_S: f64 = 0.050;

/// One worker's traffic tally: (ok, errors, per-request latencies).
type Tally = (u64, u64, Vec<f64>);

/// Exact p99 from raw samples (no estimator): sort and index.
fn p99(lat: &mut [f64]) -> f64 {
    if lat.is_empty() {
        return f64::NAN;
    }
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let idx = ((lat.len() as f64) * 0.99).ceil() as usize;
    lat[idx.saturating_sub(1).min(lat.len() - 1)]
}

/// The mixed request the whole harness sends: weight panel, activation
/// height and precision option all rotate with the iteration index.
fn send_one(
    client: &mut NetClient,
    weights: &[(u64, usize)],
    rng: &mut Rng,
    i: usize,
) -> (bool, f64) {
    let (id, k) = weights[i % weights.len()];
    let m = [4usize, 8, 16][(i / weights.len()) % 3];
    let a = Matrix::random_symmetric(m, k, 0, rng);
    let opts = match i % 3 {
        0 => WireOpts::default(),
        1 => WireOpts { precision: Some(1e-6), ..WireOpts::default() },
        _ => WireOpts { backend: Some("cube-termwise"), ..WireOpts::default() },
    };
    let t = Instant::now();
    let ok = client.gemm_weight(&a, id, &opts).is_ok();
    (ok, t.elapsed().as_secs_f64())
}

/// Closed loop at `conc` connections for `measure`: returns
/// (sustained QPS, p99 seconds, client-observed errors).
fn run_closed(
    addr: &str,
    weights: &[(u64, usize)],
    conc: usize,
    measure: Duration,
) -> (f64, f64, u64) {
    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..conc)
        .map(|w| {
            let (addr, weights, stop) = (addr.to_string(), weights.to_vec(), Arc::clone(&stop));
            std::thread::spawn(move || -> Tally {
                let mut client = NetClient::connect(addr);
                let mut rng = Rng::new(0xc105_ed00 + w as u64);
                let (mut ok, mut err, mut lat) = (0u64, 0u64, Vec::new());
                let mut i = w; // offset so workers stagger the mix
                while !stop.load(Ordering::Relaxed) {
                    let (success, secs) = send_one(&mut client, &weights, &mut rng, i);
                    if success {
                        ok += 1;
                        lat.push(secs);
                    } else {
                        err += 1;
                    }
                    i += 1;
                }
                (ok, err, lat)
            })
        })
        .collect();
    let t0 = Instant::now();
    std::thread::sleep(measure);
    stop.store(true, Ordering::Relaxed);
    let mut lat = Vec::new();
    let (mut ok, mut err) = (0u64, 0u64);
    for h in workers {
        let (o, e, l) = h.join().expect("closed-loop worker");
        ok += o;
        err += e;
        lat.extend(l);
    }
    (ok as f64 / t0.elapsed().as_secs_f64(), p99(&mut lat), err)
}

/// Open loop: `conc` pacer threads jointly target `rate` requests/sec
/// for `measure`, sending on schedule whether or not earlier requests
/// have completed (queueing shows up in the tail).
fn run_open(
    addr: &str,
    weights: &[(u64, usize)],
    conc: usize,
    rate: f64,
    measure: Duration,
) -> (f64, f64, u64) {
    let interval = Duration::from_secs_f64(conc as f64 / rate);
    let workers: Vec<_> = (0..conc)
        .map(|w| {
            let (addr, weights) = (addr.to_string(), weights.to_vec());
            std::thread::spawn(move || -> Tally {
                let mut client = NetClient::connect(addr);
                let mut rng = Rng::new(0x09e7_1007 + w as u64);
                let (mut ok, mut err, mut lat) = (0u64, 0u64, Vec::new());
                let start = Instant::now();
                let mut tick = 0u32;
                while start.elapsed() < measure {
                    let due = start + interval * tick;
                    tick += 1;
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let (success, secs) =
                        send_one(&mut client, &weights, &mut rng, tick as usize * conc + w);
                    if success {
                        ok += 1;
                        lat.push(secs);
                    } else {
                        err += 1;
                    }
                }
                (ok, err, lat)
            })
        })
        .collect();
    let t0 = Instant::now();
    let mut lat = Vec::new();
    let (mut ok, mut err) = (0u64, 0u64);
    for h in workers {
        let (o, e, l) = h.join().expect("open-loop worker");
        ok += o;
        err += e;
        lat.extend(l);
    }
    (ok as f64 / t0.elapsed().as_secs_f64().max(measure.as_secs_f64()), p99(&mut lat), err)
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let measure = if quick { Duration::from_millis(400) } else { Duration::from_secs(2) };
    let mut bench = Bencher::quick();

    let svc = Arc::new(GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        ..Default::default()
    }));
    let net = NetServer::bind(Arc::clone(&svc), NetConfig::default()).expect("bind front door");
    let addr = net.local_addr().to_string();
    println!("front door on {addr} (SLO: p99 <= {:.0} ms)", SLO_P99_S * 1e3);

    // Mixed weight panels, registered over the wire like a real client.
    let mut rng = Rng::new(42);
    let mut reg = NetClient::connect(addr.clone());
    let weights: Vec<(u64, usize)> = [(48usize, 32usize), (64, 48), (96, 64)]
        .iter()
        .map(|&(k, n)| {
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            (reg.register(&b).expect("register weights over the wire"), k)
        })
        .collect();

    // Warm caches (prepack panels, backend dispatch) outside any timer.
    let _ = run_closed(&addr, &weights, 1, measure / 4);

    println!("== closed-loop: connection sweep ==");
    let mut errors = 0u64;
    let mut best_qps = 0.0f64;
    let mut qps_at_slo = 0.0f64;
    let mut slo_p99 = 0.0f64;
    for conc in [1usize, 2, 4] {
        let (qps, p99s, errs) = run_closed(&addr, &weights, conc, measure);
        println!("  c={conc}: {qps:7.0} req/s, p99 {:7.2} ms, {errs} errors", p99s * 1e3);
        bench.record_scalar(&format!("serving/wire_qps_c{conc}"), qps);
        bench.record_scalar(&format!("serving/wire_p99_s_c{conc}"), p99s);
        errors += errs;
        best_qps = best_qps.max(qps);
        if p99s <= SLO_P99_S && qps > qps_at_slo {
            qps_at_slo = qps;
            slo_p99 = p99s;
        }
    }
    bench.record_scalar("serving/wire_qps_at_slo", qps_at_slo);
    bench.record_scalar("serving/wire_slo_p99_s", slo_p99);
    println!("sustained at SLO: {qps_at_slo:.0} req/s (p99 {:.2} ms)", slo_p99 * 1e3);

    // Open loop at ~60% of the closed-loop peak: below saturation, so
    // the tail reflects service time plus transient queueing.
    let rate = (best_qps * 0.6).clamp(20.0, 2000.0);
    let (oqps, op99, oerrs) = run_open(&addr, &weights, 4, rate, measure);
    errors += oerrs;
    println!(
        "== open-loop @ {rate:.0} req/s target: {oqps:.0} req/s achieved, p99 {:.2} ms ==",
        op99 * 1e3
    );
    bench.record_scalar("serving/wire_open_target_qps", rate);
    bench.record_scalar("serving/wire_open_qps", oqps);
    bench.record_scalar("serving/wire_open_p99_s", op99);

    // Client-observed failures plus the server's own shed/timeout
    // counters — the smoke gate asserts these stay sane.
    let report = svc.metrics().report();
    bench.record_scalar("serving/wire_errors", errors as f64);
    bench.record_scalar("serving/wire_shed", report.shed as f64);
    bench.record_scalar("serving/wire_timeouts", report.timeouts as f64);
    println!("\n{}", report.line());

    // Repo root, independent of the bench's working directory.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
    match bench.write_json(&path) {
        Ok(()) => println!("[json] {}", path.display()),
        Err(e) => eprintln!("warning: could not write {path:?}: {e}"),
    }
    net.shutdown();
    svc.shutdown();
}
