//! Bench target for Fig. 2: RN underflow probabilities (a) and retained
//! precision bits (b), analytic (Eq. 3–6) vs Monte-Carlo on the
//! bit-exact FP16.

use sgemm_cube::experiments::fig2_analysis;

fn main() {
    fig2_analysis::run_underflow(50_000, 42).emit(None);
    fig2_analysis::run_precision_bits(5_000, 42).emit(None);
    println!("paper anchors: P(gradual underflow) > 10% at E_offset = 0 (no subnormals);");
    println!("P(underflow) → 100% below E_offset = -12; s_b = 12 shifts the bits curve left by 12.");
}
