//! Executor-subsystem integration tests: the persistent pool under
//! concurrent serving load (ISSUE 4 stress satellite).
//!
//! The scenario the refactor exists for: several client threads
//! submitting mixed-shape GEMMs against one `GemmService` whose batch
//! tasks, blocked sweeps and A+B prefetch jobs all draw from worker
//! pools — asserting every served result bit-matches the serial blocked
//! reference and the service's pool never runs more concurrent tasks
//! than its configured worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::policy::PrecisionPolicy;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::exec::pool::Pool;
use sgemm_cube::gemm::backend::{Backend, Schedule};
use sgemm_cube::gemm::blocked::{cube_gemm_blocked, hgemm_blocked, sgemm_blocked};
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

/// Serial blocked reference for whatever path the service reported it
/// executed (backend + residual scale from the response).
fn serial_reference(a: &Matrix<f32>, b: &Matrix<f32>, backend: Backend, s_b: i32) -> Matrix<f32> {
    match backend {
        Backend::Fp32 => sgemm_blocked(a, b),
        Backend::Fp16 => hgemm_blocked(a, b),
        Backend::CubeElementwise | Backend::CubeTermwise => {
            cube_gemm_blocked(a, b, SplitConfig::with_scale(s_b))
        }
    }
}

#[test]
fn concurrent_mixed_shape_serving_bit_matches_serial_and_bounds_the_pool() {
    // Dedicated two-worker pool so the bound being asserted is this
    // service's own, independent of whatever else the global pool runs
    // during the test session; the overlapped-AB schedule keeps the
    // prefetch pipeline engaged under load.
    let svc = Arc::new(GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 4,
        pool_threads: 2,
        schedule: Schedule::OverlapAB,
        pipeline_depth: 3,
        ..Default::default()
    }));
    assert_eq!(svc.pool().n_workers(), 2);

    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 5;
    let mut threads = Vec::new();
    for t in 0..CLIENTS {
        let svc = Arc::clone(&svc);
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..PER_CLIENT {
                let (m, k, n) = match (t as usize + i) % 3 {
                    0 => (9, 40, 17),
                    1 => (16, 96, 8),
                    _ => (3, 130, 25),
                };
                let a = Matrix::random_symmetric(m, k, 0, &mut rng);
                let b = Matrix::random_symmetric(k, n, 0, &mut rng);
                let backend = match i % 3 {
                    0 => None, // policy decides (cube for moderate inputs)
                    1 => Some(Backend::Fp32),
                    _ => Some(Backend::CubeTermwise),
                };
                let resp = svc.gemm_blocking(a.clone(), b.clone(), backend).expect("submit");
                let c = resp.result.expect("request failed");
                let want = serial_reference(&a, &b, resp.backend, resp.scale_exp);
                for (x, y) in c.as_slice().iter().zip(want.as_slice()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "({m},{k},{n}) backend {} differs from serial reference",
                        resp.backend
                    );
                }
            }
        }));
    }
    for th in threads {
        th.join().expect("client thread panicked");
    }

    let report = svc.metrics().report();
    assert_eq!(report.requests, (CLIENTS as usize * PER_CLIENT) as u64);
    assert_eq!(report.errors, 0);
    let (high, workers) = (svc.pool().high_water(), svc.pool().n_workers());
    assert!(high >= 1, "batches must actually run on the service pool");
    assert!(high <= workers, "pool ran {high} concurrent tasks with only {workers} workers");

    let svc = Arc::try_unwrap(svc).ok().expect("all clients dropped their handles");
    svc.shutdown();
}

#[test]
fn pool_survives_external_contention_from_many_threads() {
    // Four threads hammering one three-worker pool with fan-out rounds:
    // every round must cover its index range exactly once, and the
    // pool-worker concurrency stays bounded by construction.
    let pool = Arc::new(Pool::new(3));
    let mut threads = Vec::new();
    for t in 0..4usize {
        let pool = Arc::clone(&pool);
        threads.push(std::thread::spawn(move || {
            for round in 0..10 {
                let n = 97 + t * 13 + round;
                let counter = AtomicUsize::new(0);
                pool.run_chunks(n, |s, e| {
                    counter.fetch_add(e - s, Ordering::SeqCst);
                });
                assert_eq!(counter.load(Ordering::SeqCst), n, "round {round} thread {t}");
            }
        }));
    }
    for th in threads {
        th.join().expect("stress thread panicked");
    }
    assert!(pool.high_water() <= pool.n_workers());
}
