//! Executor-subsystem integration tests: the persistent pool under
//! concurrent serving load (ISSUE 4 stress satellite, extended by the
//! ISSUE 5 registered-weight and eviction-race satellites).
//!
//! The scenario the refactor exists for: several client threads
//! submitting mixed-shape GEMMs against one `GemmService` whose batch
//! tasks, blocked sweeps and A+B prefetch jobs all draw from worker
//! pools — asserting every served result bit-matches the serial blocked
//! reference, the prepack-cache counters balance, and the service's
//! pool never runs more concurrent tasks than its configured worker
//! count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::policy::PrecisionPolicy;
use sgemm_cube::coordinator::request::WeightId;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::exec::pool::Pool;
use sgemm_cube::gemm::backend::{Backend, Schedule};
use sgemm_cube::gemm::blocked::{
    cube_gemm_blocked, family_gemm_blocked, gemm_prepacked, gemm_prepacked_overlapped_ab,
    hgemm_blocked, sgemm_blocked,
};
use sgemm_cube::gemm::cache::{PrepackCache, PrepackKey};
use sgemm_cube::gemm::kernels::active_lane;
use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

/// Serial blocked reference for whatever path the service reported it
/// executed (backend + residual scale from the response).
fn serial_reference(a: &Matrix<f32>, b: &Matrix<f32>, backend: Backend, s_b: i32) -> Matrix<f32> {
    match backend {
        Backend::Fp32 => sgemm_blocked(a, b),
        Backend::Fp16 => hgemm_blocked(a, b),
        Backend::CubeElementwise | Backend::CubeTermwise => {
            cube_gemm_blocked(a, b, SplitConfig::with_scale(s_b))
        }
        Backend::Bf16x2 | Backend::Bf16x3 => {
            family_gemm_blocked(a, b, backend.family_spec().expect("bf16 tier"))
        }
    }
}

#[test]
fn concurrent_mixed_shape_serving_bit_matches_serial_and_bounds_the_pool() {
    // Dedicated two-worker pool so the bound being asserted is this
    // service's own, independent of whatever else the global pool runs
    // during the test session; the overlapped-AB schedule keeps the
    // prefetch pipeline engaged under load.
    let svc = Arc::new(GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 4,
        pool_threads: 2,
        schedule: Schedule::OverlapAB,
        pipeline_depth: 3,
        ..Default::default()
    }));
    assert_eq!(svc.pool().n_workers(), 2);

    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 5;
    let mut threads = Vec::new();
    for t in 0..CLIENTS {
        let svc = Arc::clone(&svc);
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..PER_CLIENT {
                let (m, k, n) = match (t as usize + i) % 3 {
                    0 => (9, 40, 17),
                    1 => (16, 96, 8),
                    _ => (3, 130, 25),
                };
                let a = Matrix::random_symmetric(m, k, 0, &mut rng);
                let b = Matrix::random_symmetric(k, n, 0, &mut rng);
                let backend = match i % 3 {
                    0 => None, // policy decides (cube for moderate inputs)
                    1 => Some(Backend::Fp32),
                    _ => Some(Backend::CubeTermwise),
                };
                let resp = svc.gemm_blocking(a.clone(), b.clone(), backend).expect("submit");
                let c = resp.result.expect("request failed");
                let want = serial_reference(&a, &b, resp.backend, resp.scale_exp);
                for (x, y) in c.as_slice().iter().zip(want.as_slice()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "({m},{k},{n}) backend {} differs from serial reference",
                        resp.backend
                    );
                }
            }
        }));
    }
    for th in threads {
        th.join().expect("client thread panicked");
    }

    let report = svc.metrics().report();
    assert_eq!(report.requests, (CLIENTS as usize * PER_CLIENT) as u64);
    assert_eq!(report.errors, 0);
    let (high, workers) = (svc.pool().high_water(), svc.pool().n_workers());
    assert!(high >= 1, "batches must actually run on the service pool");
    assert!(high <= workers, "pool ran {high} concurrent tasks with only {workers} workers");

    let svc = Arc::try_unwrap(svc).ok().expect("all clients dropped their handles");
    svc.shutdown();
}

#[test]
fn registered_weight_serving_bit_matches_serial_with_clean_cache_stats() {
    // ISSUE 5 satellite: N clients hammering one service with
    // registered weights under a dedicated 2-worker pool and the
    // prepacked A-stripe prefetch schedule. Every response must
    // bit-match the serial blocked reference (prepacked panels are
    // bit-identical to pack-on-the-fly by construction), the cache
    // counters must balance (hits + misses == prepacked requests, no
    // evictions at this capacity), and the pool must never run more
    // concurrent batch tasks than its worker count.
    let svc = Arc::new(GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 4,
        pool_threads: 2,
        schedule: Schedule::OverlapAB,
        schedule_prepacked: Schedule::OverlapAB,
        pipeline_depth: 3,
        ..Default::default()
    }));
    let mut rng = Rng::new(600);
    let shapes = [(40usize, 17usize), (96, 8), (130, 25)];
    let weights: Arc<Vec<(WeightId, Matrix<f32>)>> = Arc::new(
        shapes
            .iter()
            .map(|&(k, n)| {
                let w = Matrix::random_symmetric(k, n, 0, &mut rng);
                (svc.register_weights(w.clone()), w)
            })
            .collect(),
    );

    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 6;
    let mut threads = Vec::new();
    for t in 0..CLIENTS {
        let svc = Arc::clone(&svc);
        let weights = Arc::clone(&weights);
        threads.push(std::thread::spawn(move || {
            let mut rng = Rng::new(700 + t);
            for i in 0..PER_CLIENT {
                let (id, w) = &weights[(t as usize + i) % weights.len()];
                let m = [3usize, 9, 16][i % 3];
                let a = Matrix::random_symmetric(m, w.rows(), 0, &mut rng);
                let backend = match i % 3 {
                    0 => None, // policy decides (cube for moderate inputs)
                    1 => Some(Backend::Fp32),
                    _ => Some(Backend::CubeTermwise),
                };
                let resp = svc.gemm_blocking_prepacked(a.clone(), *id, backend).expect("submit");
                let c = resp.result.expect("request failed");
                let want = serial_reference(&a, w, resp.backend, resp.scale_exp);
                for (x, y) in c.as_slice().iter().zip(want.as_slice()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "m={m} weight {id:?} backend {} differs from serial reference",
                        resp.backend
                    );
                }
            }
        }));
    }
    for th in threads {
        th.join().expect("client thread panicked");
    }

    let total = CLIENTS * PER_CLIENT as u64;
    let report = svc.metrics().report();
    assert_eq!(report.requests, total);
    assert_eq!(report.errors, 0);
    let s = svc.prepack_stats();
    assert_eq!(s.hits + s.misses, total, "one cache lookup per prepacked request: {s:?}");
    // 3 weights × {fp32, cube} = 6 distinct keys: each packs at least
    // once (racing cold lookups may add a few extra misses) and stays
    // resident — the adopt-on-race insert never duplicates entries.
    assert!(s.misses >= 6, "each (weight, path) pair packs at least once: {s:?}");
    assert_eq!(s.entries, 6, "one resident entry per (weight, path): {s:?}");
    assert_eq!(s.evictions, 0, "capacity was never exceeded: {s:?}");
    let (high, workers) = (svc.pool().high_water(), svc.pool().n_workers());
    assert!(high >= 1, "batches must actually run on the service pool");
    assert!(high <= workers, "pool ran {high} concurrent tasks with only {workers} workers");

    let svc = Arc::try_unwrap(svc).ok().expect("all clients dropped their handles");
    svc.shutdown();
}

#[test]
fn cache_eviction_racing_an_in_flight_prefetched_batch_is_harmless() {
    // ISSUE 5 satellite: the cache hands out `Arc<PrepackedMatrix>` and
    // the batch holds that Arc for its lifetime, so eviction racing the
    // A-stripe prefetch ring must neither invalidate panels the ring
    // has already claimed nor perturb a single output bit. The tiny
    // capacity below makes every insert from the evictor thread evict.
    let cfg = SplitConfig::with_scale(12);
    let mut rng = Rng::new(800);
    let b = Matrix::random_symmetric(130, 25, 0, &mut rng);
    let probe = PrepackedMatrix::prepack(&b, PrepackPath::Cube(cfg));
    let cache = Arc::new(PrepackCache::new(probe.bytes() + probe.bytes() / 2));
    let key = |weight: u64| PrepackKey {
        weight,
        k: 130,
        n: 25,
        backend: Backend::CubeTermwise,
        scale_exp: 12,
        lane: active_lane(),
        col0: 0,
    };
    let held = cache.get_or_insert_with(key(1), || probe.clone());
    let a = Matrix::random_symmetric(16, 130, 0, &mut rng);
    let want = gemm_prepacked(&a, &held);

    let evictor = {
        let cache = Arc::clone(&cache);
        let b = b.clone();
        std::thread::spawn(move || {
            for w in 2..40u64 {
                cache.get_or_insert_with(key(w), || {
                    PrepackedMatrix::prepack(&b, PrepackPath::Cube(cfg))
                });
            }
        })
    };
    for round in 0..10 {
        let got = gemm_prepacked_overlapped_ab(&a, &held, 3);
        for (x, y) in got.as_slice().iter().zip(want.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "round {round}");
        }
    }
    evictor.join().expect("evictor thread panicked");
    let s = cache.stats();
    assert!(s.evictions >= 1, "the storm must actually evict: {s:?}");
    assert!(cache.get(&key(1)).is_none(), "held key evicted while its Arc stayed usable");
    // The held operand is still fully intact after the storm.
    let again = gemm_prepacked_overlapped_ab(&a, &held, 2);
    for (x, y) in again.as_slice().iter().zip(want.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn pool_survives_external_contention_from_many_threads() {
    // Four threads hammering one three-worker pool with fan-out rounds:
    // every round must cover its index range exactly once, and the
    // pool-worker concurrency stays bounded by construction.
    let pool = Arc::new(Pool::new(3));
    let mut threads = Vec::new();
    for t in 0..4usize {
        let pool = Arc::clone(&pool);
        threads.push(std::thread::spawn(move || {
            for round in 0..10 {
                let n = 97 + t * 13 + round;
                let counter = AtomicUsize::new(0);
                pool.run_chunks(n, |s, e| {
                    counter.fetch_add(e - s, Ordering::SeqCst);
                });
                assert_eq!(counter.load(Ordering::SeqCst), n, "round {round} thread {t}");
            }
        }));
    }
    for th in threads {
        th.join().expect("stress thread panicked");
    }
    assert!(pool.high_water() <= pool.n_workers());
}

#[test]
fn skewed_load_drives_work_stealing_and_counters_advance() {
    // Work-stealing satellite: pin one of three workers on a gated
    // detached job, then hammer the pool with fan-out rounds whose
    // chunk costs are skewed (every round enlists all three worker
    // queues, but the pinned worker never drains its own). The free
    // workers must steal the pinned worker's queued batch participants
    // — correctness (exact index coverage) must hold throughout, and
    // the steal counters must advance.
    use std::sync::mpsc::channel;

    let pool = Arc::new(Pool::new(3));
    let (gate_tx, gate_rx) = channel::<()>();
    let blocker = pool.submit(move || {
        gate_rx.recv().unwrap();
    });
    while blocker.state() != sgemm_cube::exec::pool::TaskState::Running {
        std::thread::yield_now();
    }
    let before = pool.steals();

    let mut threads = Vec::new();
    for t in 0..3usize {
        let pool = Arc::clone(&pool);
        threads.push(std::thread::spawn(move || {
            for round in 0..8 {
                let n = 64 + t * 7 + round;
                let counter = AtomicUsize::new(0);
                pool.run_chunks(n, |s, e| {
                    // Skew: the first chunk of each round is an order of
                    // magnitude heavier than the rest.
                    if s == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    counter.fetch_add(e - s, Ordering::SeqCst);
                });
                assert_eq!(counter.load(Ordering::SeqCst), n, "round {round} thread {t}");
            }
        }));
    }
    for th in threads {
        th.join().expect("stress thread panicked");
    }
    // The pinned worker's queued drains can only have been executed by
    // a thief; poll briefly because the last steal may still be mid
    // hand-off when the joins return.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while pool.steals() == before {
        assert!(std::time::Instant::now() < deadline, "no steal under skewed load");
        std::thread::yield_now();
    }
    gate_tx.send(()).unwrap();
    assert_eq!(blocker.join(), sgemm_cube::exec::pool::TaskState::Done);
    assert!(pool.steals() > before);
    assert!(pool.high_water() <= pool.n_workers());
}
