//! Forced-lane dispatch tests: the per-lane bit-identity contract.
//!
//! [`force_lane`] is process-global, so every test that pins a lane
//! takes [`lane_lock`] first and restores the detected lane before
//! releasing it. These tests live in their own integration binary —
//! cargo runs each binary's tests in one process, so nothing here can
//! race the lane-agnostic suites (`tests/properties.rs` et al.), which
//! execute in *their* processes under the detected lane.
//!
//! Contract under test (see `gemm::kernels` module docs):
//!
//! * **Per lane, across schedules**: with any single lane pinned, the
//!   serial, overlap-B, overlap-AB and prepacked paths are bit-identical
//!   — every sweep resolves its lane exactly once, and packing follows
//!   that lane's micro-tile geometry (prepacked operands record theirs
//!   at pack time, so a later lane switch cannot desynchronize panel
//!   interleave and kernel dispatch).
//! * **Scalar lane vs exact**: the scalar kernel performs the same
//!   rounded-multiply + rounded-add chain as the exact reference
//!   kernels, so for `k <= b_k` (one k block, one accumulation chain)
//!   the blocked fp32 engine is bit-identical to `sgemm`.
//! * **Across lanes**: results agree within an accumulation-order
//!   envelope (FMA lanes round once per chain step, scalar twice), but
//!   are *not* expected to be bit-identical.

use std::sync::{Mutex, MutexGuard};

use sgemm_cube::gemm::blocked::{
    cube_gemm_blocked, cube_gemm_blocked_overlapped, cube_gemm_blocked_overlapped_ab,
    family_gemm_blocked, family_gemm_blocked_overlapped, family_gemm_blocked_overlapped_ab,
    gemm_prepacked, gemm_prepacked_overlapped, gemm_prepacked_overlapped_ab, hgemm_blocked,
    hgemm_blocked_overlapped, hgemm_blocked_overlapped_ab, host_block, sgemm_blocked,
    sgemm_blocked_overlapped, sgemm_blocked_overlapped_ab,
};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::kernels::{active_lane, detect_lane, force_lane, Lane};
use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
use sgemm_cube::gemm::sgemm::sgemm;
use sgemm_cube::softfloat::family::SplitSpec;
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

/// Serializes every forced-lane test in this binary.
static LANE_LOCK: Mutex<()> = Mutex::new(());

fn lane_lock() -> MutexGuard<'static, ()> {
    LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pin `lane` for the duration of the returned guard; the detected lane
/// is restored on drop (also on panic, so one failing test does not
/// leak a stale lane into the next).
struct ForcedLane(MutexGuard<'static, ()>);

impl ForcedLane {
    fn pin(lane: Lane) -> Option<ForcedLane> {
        let guard = lane_lock();
        if !force_lane(lane) {
            return None; // unavailable on this host; caller skips
        }
        Some(ForcedLane(guard))
    }
}

impl Drop for ForcedLane {
    fn drop(&mut self) {
        assert!(force_lane(detect_lane()));
    }
}

fn operands(m: usize, k: usize, n: usize, seed: u64) -> (Matrix<f32>, Matrix<f32>) {
    let mut rng = Rng::new(seed);
    let a = Matrix::random_symmetric(m, k, 0, &mut rng);
    let b = Matrix::random_symmetric(k, n, 0, &mut rng);
    (a, b)
}

fn assert_bits(want: &Matrix<f32>, got: &Matrix<f32>, what: &str) {
    assert_eq!(want.shape(), got.shape(), "{what}: shape");
    for (u, v) in want.as_slice().iter().zip(got.as_slice()) {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: {u} vs {v}");
    }
}

#[test]
fn every_available_lane_is_bit_identical_across_schedules() {
    // Shapes straddle the b_k boundary and the MR/NR edges so multiple
    // panels, partial tiles and the prefetch ring all engage.
    let bk = host_block().bk;
    let shapes = [(17, bk - 1, 23), (9, 2 * bk + 5, 33), (4, 1, 1)];
    let cfg = SplitConfig::default();
    for lane in Lane::ALL {
        let Some(_pin) = ForcedLane::pin(lane) else { continue };
        assert_eq!(active_lane(), lane);
        for (sh, (m, k, n)) in shapes.into_iter().enumerate() {
            let (a, b) = operands(m, k, n, 100 + sh as u64);
            let ctx = |path: &str, sched: &str| format!("{lane} {path} {sched} ({m},{k},{n})");

            let want = sgemm_blocked(&a, &b);
            assert_bits(&want, &sgemm_blocked_overlapped(&a, &b), &ctx("fp32", "overlap-b"));
            for depth in [1usize, 3] {
                let got = sgemm_blocked_overlapped_ab(&a, &b, depth);
                assert_bits(&want, &got, &ctx("fp32", &format!("overlap-ab d{depth}")));
            }

            let want = hgemm_blocked(&a, &b);
            assert_bits(&want, &hgemm_blocked_overlapped(&a, &b), &ctx("fp16", "overlap-b"));
            let got = hgemm_blocked_overlapped_ab(&a, &b, 2);
            assert_bits(&want, &got, &ctx("fp16", "overlap-ab d2"));

            let want = cube_gemm_blocked(&a, &b, cfg);
            let got = cube_gemm_blocked_overlapped(&a, &b, cfg);
            assert_bits(&want, &got, &ctx("cube", "overlap-b"));
            let got = cube_gemm_blocked_overlapped_ab(&a, &b, cfg, 3);
            assert_bits(&want, &got, &ctx("cube", "overlap-ab d3"));
        }
    }
}

#[test]
fn every_available_lane_is_bit_identical_on_the_prepacked_paths() {
    let bk = host_block().bk;
    let (m, k, n) = (11, 2 * bk + 3, 29);
    let paths = [
        (PrepackPath::Fp32, "fp32"),
        (PrepackPath::Fp16, "fp16"),
        (PrepackPath::Cube(SplitConfig::default()), "cube"),
    ];
    for lane in Lane::ALL {
        let Some(_pin) = ForcedLane::pin(lane) else { continue };
        let (a, b) = operands(m, k, n, 200);
        for (path, what) in paths {
            // Prepack under the pinned lane: the operand records it and
            // every consuming schedule replays its geometry.
            let pp = PrepackedMatrix::prepack(&b, path);
            assert_eq!(pp.lane(), lane, "{what}: recorded packing lane");
            let want = gemm_prepacked(&a, &pp);
            let ctx = |s: &str| format!("{lane} prepacked {what} {s}");
            assert_bits(&want, &gemm_prepacked_overlapped(&a, &pp), &ctx("overlap"));
            for depth in [1usize, 2, 3] {
                let got = gemm_prepacked_overlapped_ab(&a, &pp, depth);
                assert_bits(&want, &got, &ctx(&format!("ab d{depth}")));
            }
        }
    }
}

#[test]
fn family_fp16x2_is_bit_identical_to_the_cube_engine_on_every_lane() {
    // The tentpole's anchor, pinned per lane: the N = 2 FP16 spec *is*
    // the pre-family cube engine — the family entry points delegate to
    // it structurally, and even the generic N-term machinery (the
    // `Family` prepack format → `pack_b_multi` panels → `kernel_family`
    // dispatch) reproduces its bits, because multi-packing at N = 2
    // lays out the same bytes as dual-packing and `kernel_family`
    // routes `ncomp == 2` onto `kernel_cube`.
    let bk = host_block().bk;
    let cfg = SplitConfig::default();
    let spec = SplitSpec::fp16x2(cfg);
    for lane in Lane::ALL {
        let Some(_pin) = ForcedLane::pin(lane) else { continue };
        for (sh, (m, k, n)) in [(17, bk - 1, 23), (9, 2 * bk + 5, 33)].into_iter().enumerate() {
            let (a, b) = operands(m, k, n, 600 + sh as u64);
            let want = cube_gemm_blocked(&a, &b, cfg);
            let ctx = |s: &str| format!("{lane} fp16x2-family {s} ({m},{k},{n})");
            assert_bits(&want, &family_gemm_blocked(&a, &b, spec), &ctx("serial"));
            assert_bits(&want, &family_gemm_blocked_overlapped(&a, &b, spec), &ctx("overlap-b"));
            let got = family_gemm_blocked_overlapped_ab(&a, &b, spec, 3);
            assert_bits(&want, &got, &ctx("overlap-ab d3"));
            // Generic family panels vs the dedicated cube panels.
            let pp = PrepackedMatrix::prepack(&b, PrepackPath::Family(spec));
            assert_bits(&want, &gemm_prepacked(&a, &pp), &ctx("prepacked"));
            let got = gemm_prepacked_overlapped_ab(&a, &pp, 2);
            assert_bits(&want, &got, &ctx("prepacked ab d2"));
        }
    }
}

#[test]
fn bf16_tiers_are_bit_identical_across_schedules_on_every_lane() {
    let bk = host_block().bk;
    let (m, k, n) = (11, 2 * bk + 3, 29);
    for lane in Lane::ALL {
        let Some(_pin) = ForcedLane::pin(lane) else { continue };
        let (a, b) = operands(m, k, n, 700);
        for spec in [SplitSpec::bf16x2(), SplitSpec::bf16x3()] {
            let want = family_gemm_blocked(&a, &b, spec);
            let ctx = |s: &str| format!("{lane} {} {s}", spec.name());
            assert_bits(&want, &family_gemm_blocked_overlapped(&a, &b, spec), &ctx("overlap-b"));
            for depth in [1usize, 3] {
                let got = family_gemm_blocked_overlapped_ab(&a, &b, spec, depth);
                assert_bits(&want, &got, &ctx(&format!("overlap-ab d{depth}")));
            }
            let pp = PrepackedMatrix::prepack(&b, PrepackPath::Family(spec));
            assert_bits(&want, &gemm_prepacked(&a, &pp), &ctx("prepacked"));
            let got = gemm_prepacked_overlapped_ab(&a, &pp, 2);
            assert_bits(&want, &got, &ctx("prepacked ab d2"));
        }
    }
}

#[test]
fn prepacked_operands_pin_their_packing_lane() {
    // Panels are interleaved with the packing lane's micro-tile dims
    // and the operand records that lane, so consumption is driven by
    // `pp.lane()` — NOT by whatever lane is active when the GEMM runs.
    // Pin lane X, prepack and compute the reference; then repin every
    // other available lane Y and rerun all prepacked schedules on the
    // same operand: bit-identical, because the recorded lane X still
    // governs both the panel geometry and the kernel dispatch.
    let (a, b) = operands(7, 150, 37, 300);
    for pack_lane in Lane::ALL {
        let (pp, want) = {
            let Some(_pin) = ForcedLane::pin(pack_lane) else { continue };
            let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(SplitConfig::default()));
            assert_eq!(pp.lane(), pack_lane);
            let want = gemm_prepacked(&a, &pp);
            (pp, want)
        };
        for exec_lane in Lane::ALL {
            let Some(_pin) = ForcedLane::pin(exec_lane) else { continue };
            let ctx = |s: &str| format!("packed {pack_lane}, executed {exec_lane}, {s}");
            assert_bits(&want, &gemm_prepacked(&a, &pp), &ctx("serial"));
            assert_bits(&want, &gemm_prepacked_overlapped(&a, &pp), &ctx("overlap"));
            let got = gemm_prepacked_overlapped_ab(&a, &pp, 2);
            assert_bits(&want, &got, &ctx("ab d2"));
        }
    }
}

#[test]
fn forced_wide_lane_bit_matches_serial_reference_on_every_serving_path() {
    // ISSUE 9 acceptance gate: with the AVX-512 lane pinned, the full
    // serving stack — inline requests under every host schedule, the
    // registered-weight (prepacked) path, and the column-shard router —
    // serves bits identical to the serial blocked reference on every
    // precision tier. Skips cleanly on hosts without AVX-512F.
    use std::time::Duration;
    use sgemm_cube::coordinator::batcher::BatcherConfig;
    use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
    use sgemm_cube::coordinator::shard::ShardConfig;
    use sgemm_cube::gemm::backend::{Backend, Schedule};

    let Some(_pin) = ForcedLane::pin(Lane::Avx512) else { return };

    let (a, w) = operands(9, 150, 37, 900);
    let reference = |backend: Backend, s_b: i32| match backend {
        Backend::Fp32 => sgemm_blocked(&a, &w),
        Backend::Fp16 => hgemm_blocked(&a, &w),
        Backend::CubeElementwise | Backend::CubeTermwise => {
            cube_gemm_blocked(&a, &w, SplitConfig::with_scale(s_b))
        }
        Backend::Bf16x2 | Backend::Bf16x3 => {
            family_gemm_blocked(&a, &w, backend.family_spec().expect("bf16 tier"))
        }
    };
    let cfg = |schedule: Schedule, shards: usize| ServiceConfig {
        batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(1) },
        n_workers: 2,
        schedule,
        schedule_prepacked: schedule,
        pipeline_depth: 2,
        shards: ShardConfig { count: shards, ..Default::default() },
        ..Default::default()
    };
    let backends =
        [Backend::Fp32, Backend::Fp16, Backend::CubeTermwise, Backend::Bf16x2, Backend::Bf16x3];
    for schedule in Schedule::ALL {
        for shards in [0usize, 2] {
            let svc = GemmService::start(cfg(schedule, shards));
            let id = svc.register_weights(w.clone());
            for backend in backends {
                let resp = svc.gemm_blocking(a.clone(), w.clone(), Some(backend)).expect("submit");
                let c = resp.result.expect("inline request failed");
                let what = format!("avx512 {} shards={shards} {backend} inline", schedule.name());
                assert_bits(&reference(resp.backend, resp.scale_exp), &c, &what);
                let resp =
                    svc.gemm_blocking_prepacked(a.clone(), id, Some(backend)).expect("submit");
                let c = resp.result.expect("prepacked request failed");
                let what =
                    format!("avx512 {} shards={shards} {backend} prepacked", schedule.name());
                assert_bits(&reference(resp.backend, resp.scale_exp), &c, &what);
            }
            svc.shutdown();
        }
    }
}

#[test]
fn forced_scalar_is_bit_identical_to_exact_within_one_k_block() {
    // The promise referenced from gemm::blocked and tests/properties.rs:
    // on the scalar lane the blocked fp32 engine runs the same rounded
    // mul + rounded add chain as the exact kernel, so one k block
    // (k <= b_k, a single accumulation chain per output) matches sgemm
    // bit for bit. FMA lanes break this on purpose (one rounding per
    // step), which is why the claim is pinned under a forced lane here
    // rather than under detection.
    let _pin = ForcedLane::pin(Lane::Scalar).expect("scalar is always available");
    let bk = host_block().bk;
    for (m, k, n, seed) in [(7, bk, 13, 400u64), (33, bk - 3, 5, 401), (2, 1, 2, 402)] {
        let (a, b) = operands(m, k, n, seed);
        let exact = sgemm(&a, &b);
        let blocked = sgemm_blocked(&a, &b);
        assert_bits(&exact, &blocked, &format!("scalar vs exact ({m},{k},{n})"));
    }
}

#[test]
fn lanes_agree_within_accumulation_order_noise_end_to_end() {
    // Full-GEMM version of the kernel-level envelope: pin each available
    // lane in turn on identical operands; results agree with the scalar
    // lane within a forward-error bound of k·eps·Σ|a||b| per entry.
    let (m, k, n) = (19, 150, 21);
    let (a, b) = operands(m, k, n, 500);
    let abs_p = dgemm_of_f32(&a.map(f32::abs), &b.map(f32::abs));
    let scalar = {
        let _pin = ForcedLane::pin(Lane::Scalar).expect("scalar is always available");
        sgemm_blocked(&a, &b)
    };
    for lane in [Lane::Avx512, Lane::Avx2, Lane::Neon] {
        let Some(_pin) = ForcedLane::pin(lane) else { continue };
        let got = sgemm_blocked(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let (x, y) = (scalar.get(i, j) as f64, got.get(i, j) as f64);
                let tol = 4.0 * k as f64 * f32::EPSILON as f64 * abs_p.get(i, j) + 1e-30;
                assert!(
                    (x - y).abs() <= tol,
                    "{lane} vs scalar at ({i},{j}): {x} vs {y} (tol {tol:.3e})"
                );
            }
        }
    }
}

#[test]
fn forcing_an_unavailable_lane_changes_nothing() {
    let _guard = lane_lock();
    let before = active_lane();
    for lane in Lane::ALL {
        if !lane.is_available() {
            assert!(!force_lane(lane), "{lane} force should be rejected");
            assert_eq!(active_lane(), before, "{lane}");
        }
    }
}
