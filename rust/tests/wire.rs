//! Wire front-door suite: the HTTP/1.1-over-TCP path end to end.
//!
//! The acceptance pin lives here: a `/gemm` served over a real socket
//! must be **bit-identical** to the same request through the in-process
//! blocking entry points, across inline and register-then-serve
//! operand paths, backend pins and precision tiers. The rest of the
//! suite covers the typed framing failures (truncated frame, oversized
//! body, slow client hitting the read deadline), routing, the metrics
//! and health endpoints, and keep-alive reuse.
//!
//! Failpoint-armed socket scenarios live in `tests/chaos.rs`, which
//! serializes on the process-global registry; nothing here arms faults.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sgemm_cube::coordinator::net::{NetClient, NetConfig, NetServer, WireError, WireOpts};
use sgemm_cube::coordinator::server::{GemmService, RequestOpts, ServiceConfig};
use sgemm_cube::gemm::backend::Backend;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

/// A service plus a bound front door on an ephemeral port.
fn front_door(cfg: NetConfig) -> (Arc<GemmService>, NetServer) {
    let svc = Arc::new(GemmService::start(ServiceConfig::default()));
    let net = NetServer::bind(Arc::clone(&svc), cfg).expect("bind ephemeral port");
    (svc, net)
}

fn assert_bits_eq(x: &Matrix<f32>, y: &Matrix<f32>, what: &str) {
    assert_eq!(x.shape(), y.shape(), "{what}");
    for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}");
    }
}

/// Read everything until the server closes, as a lossy string — enough
/// to assert on a status line when speaking raw bytes to the socket.
fn slurp(stream: &mut TcpStream) -> String {
    let mut buf = Vec::new();
    let _ = stream.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

/// The acceptance pin: wire replies are bit-identical to the in-process
/// blocking path — inline and registered-weight operands, pinned
/// backends, and precision-tier selection all included.
#[test]
fn wire_gemm_bit_matches_in_process() {
    let (svc, net) = front_door(NetConfig::default());
    let mut client = NetClient::connect(net.local_addr().to_string());
    let mut rng = Rng::new(91);
    let a = Matrix::random_symmetric(16, 48, 0, &mut rng);
    let b = Matrix::random_symmetric(48, 24, 0, &mut rng);

    // Inline path, policy-chosen backend, then pinned backends and a
    // precision tier.
    let cases = [
        WireOpts::default(),
        WireOpts { backend: Some("fp32"), ..WireOpts::default() },
        WireOpts { backend: Some("cube-termwise"), ..WireOpts::default() },
        WireOpts { precision: Some(1e-6), ..WireOpts::default() },
    ];
    for opts in cases {
        let wire = client.gemm(&a, &b, &opts).expect("wire /gemm");
        let want = svc
            .gemm_blocking_opts(
                a.clone(),
                b.clone(),
                RequestOpts {
                    backend: opts.backend.and_then(Backend::parse),
                    precision: opts.precision,
                    timeout: None,
                },
            )
            .expect("submit")
            .result
            .expect("in-process");
        assert_bits_eq(&want, &wire.c, &format!("inline, opts {opts:?}"));
        assert!(Backend::parse(&wire.backend).is_some(), "reply names a backend: {wire:?}");
    }

    // Register-then-serve: same weight via both doors, same bits.
    let id_wire = client.register(&b).expect("wire /register");
    let wire = client.gemm_weight(&a, id_wire, &WireOpts::default()).expect("wire weight gemm");
    let want = svc
        .gemm_blocking(a, b, None)
        .expect("submit")
        .result
        .expect("in-process");
    assert_bits_eq(&want, &wire.c, "registered-weight path");
    net.shutdown();
    svc.shutdown();
}

/// One keep-alive connection serves many exchanges; health, metrics and
/// the counter names the smoke gate scrapes are all visible over it.
#[test]
fn keep_alive_metrics_and_healthz_over_one_connection() {
    let (svc, net) = front_door(NetConfig::default());
    let mut client = NetClient::connect(net.local_addr().to_string());
    assert!(client.healthz().expect("healthz"));
    let mut rng = Rng::new(92);
    let a = Matrix::random_symmetric(4, 8, 0, &mut rng);
    let b = Matrix::random_symmetric(8, 4, 0, &mut rng);
    for _ in 0..3 {
        client.gemm(&a, &b, &WireOpts::default()).expect("gemm over keep-alive");
    }
    let metrics = client.metrics().expect("metrics");
    for name in [
        "requests_total",
        "errors_total",
        "shed_total",
        "timeouts_total",
        "retries_total",
        "failovers_total",
        "latency_samples_held",
    ] {
        assert!(metrics.contains(name), "metrics dump missing {name}:\n{metrics}");
    }
    let requests = metrics
        .lines()
        .find_map(|l| l.strip_prefix("requests_total "))
        .and_then(|v| v.parse::<u64>().ok())
        .expect("requests_total parses");
    assert!(requests >= 3, "served requests show up in the scrape: {requests}");
    net.shutdown();
    svc.shutdown();
}

/// Service-level errors come back as typed statuses with stable kinds:
/// unknown weight → 404, shape mismatch → 400.
#[test]
fn service_errors_map_to_typed_statuses() {
    let (svc, net) = front_door(NetConfig::default());
    let mut client = NetClient::connect(net.local_addr().to_string());
    let mut rng = Rng::new(93);
    let a = Matrix::random_symmetric(4, 8, 0, &mut rng);
    match client.gemm_weight(&a, 999_999, &WireOpts::default()) {
        Err(WireError::Status { code: 404, kind, .. }) => assert_eq!(kind, "unknown-weight"),
        other => panic!("expected 404 unknown-weight, got {other:?}"),
    }
    let b_bad = Matrix::random_symmetric(7, 4, 0, &mut rng); // inner dims disagree
    match client.gemm(&a, &b_bad, &WireOpts::default()) {
        Err(WireError::Status { code: 400, kind, .. }) => assert_eq!(kind, "shape-mismatch"),
        other => panic!("expected 400 shape-mismatch, got {other:?}"),
    }
    match client.gemm(&a, &a, &WireOpts { backend: Some("no-such"), ..WireOpts::default() }) {
        Err(WireError::Status { code: 400, kind, .. }) => assert_eq!(kind, "bad-request"),
        other => panic!("expected 400 for an unknown backend, got {other:?}"),
    }
    net.shutdown();
    svc.shutdown();
}

/// Unknown paths and wrong methods get 404/405, and the connection
/// survives them (they are not framing errors).
#[test]
fn routing_unknown_path_and_wrong_method() {
    let (svc, net) = front_door(NetConfig::default());
    let mut s = TcpStream::connect(net.local_addr()).expect("connect");
    s.write_all(b"GET /nope HTTP/1.1\r\ncontent-length: 0\r\n\r\n")
        .and_then(|()| s.write_all(b"GET /gemm HTTP/1.1\r\nconnection: close\r\n\r\n"))
        .expect("send");
    let reply = slurp(&mut s);
    assert!(reply.starts_with("HTTP/1.1 404 "), "{reply}");
    assert!(reply.contains("HTTP/1.1 405 "), "{reply}");
    net.shutdown();
    svc.shutdown();
}

/// A truncated frame — Content-Length promises more than the client
/// sends before closing — is a typed 400, not a hang or a panic.
#[test]
fn truncated_frame_is_a_typed_400() {
    let (svc, net) = front_door(NetConfig::default());
    let mut s = TcpStream::connect(net.local_addr()).expect("connect");
    s.write_all(b"POST /gemm HTTP/1.1\r\nx-a-rows: 4\r\nx-a-cols: 4\r\nx-b-rows: 4\r\nx-b-cols: 4\r\ncontent-length: 128\r\n\r\nshort")
        .expect("send");
    s.shutdown(std::net::Shutdown::Write).expect("half-close");
    let reply = slurp(&mut s);
    assert!(reply.starts_with("HTTP/1.1 400 "), "{reply}");
    assert!(reply.contains("x-error-kind: bad-request"), "{reply}");
    assert!(reply.contains("truncated"), "{reply}");
    net.shutdown();
    svc.shutdown();
}

/// A body larger than the configured cap is refused with 413 before the
/// server reads (or allocates) any of it.
#[test]
fn oversized_body_is_a_typed_413() {
    let (svc, net) = front_door(NetConfig { max_body: 1024, ..NetConfig::default() });
    let mut s = TcpStream::connect(net.local_addr()).expect("connect");
    s.write_all(b"POST /gemm HTTP/1.1\r\ncontent-length: 1048576\r\n\r\n").expect("send");
    let reply = slurp(&mut s);
    assert!(reply.starts_with("HTTP/1.1 413 "), "{reply}");
    assert!(reply.contains("x-error-kind: payload-too-large"), "{reply}");
    net.shutdown();
    svc.shutdown();
}

/// A client that stalls mid-request trips the socket read deadline and
/// gets a typed 408 — bounded, well before the claimed body could have
/// been "slow".
#[test]
fn slow_client_hits_read_deadline_with_typed_408() {
    let (svc, net) =
        front_door(NetConfig { read_timeout: Duration::from_millis(80), ..NetConfig::default() });
    let mut s = TcpStream::connect(net.local_addr()).expect("connect");
    // Half a request, then silence: the server must give up at its read
    // deadline rather than hold the handler thread.
    s.write_all(b"POST /gemm HTTP/1.1\r\ncontent-le").expect("send");
    let t0 = Instant::now();
    let reply = slurp(&mut s);
    assert!(t0.elapsed() < Duration::from_secs(10), "bounded wait");
    assert!(reply.starts_with("HTTP/1.1 408 "), "{reply}");
    assert!(reply.contains("x-error-kind: read-deadline"), "{reply}");
    net.shutdown();
    svc.shutdown();
}

/// Chunked transfer encoding is declared unsupported with a 501, not
/// misparsed.
#[test]
fn chunked_framing_is_a_typed_501() {
    let (svc, net) = front_door(NetConfig::default());
    let mut s = TcpStream::connect(net.local_addr()).expect("connect");
    s.write_all(b"POST /gemm HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n").expect("send");
    let reply = slurp(&mut s);
    assert!(reply.starts_with("HTTP/1.1 501 "), "{reply}");
    assert!(reply.contains("x-error-kind: not-implemented"), "{reply}");
    net.shutdown();
    svc.shutdown();
}

/// Shutdown is prompt and idempotent, and the ephemeral-port bind means
/// parallel suites never collide.
#[test]
fn shutdown_is_prompt_and_idempotent() {
    let (svc, net) = front_door(NetConfig::default());
    let addr = net.local_addr();
    assert_ne!(addr.port(), 0, "ephemeral port resolved");
    let t0 = Instant::now();
    net.shutdown();
    net.shutdown();
    assert!(t0.elapsed() < Duration::from_secs(5), "accept loop joins promptly");
    assert!(
        NetClient::connect(addr.to_string()).healthz().is_err(),
        "no listener after shutdown"
    );
    svc.shutdown();
}
