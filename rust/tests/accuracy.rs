//! Accuracy regression suite (ISSUE 5): pins the paper's ~22-bit
//! mantissa recovery claim as a cargo test across every execution path
//! of the engine — the exact cube reference, the blocked fused kernel,
//! the overlapped schedules and the prepacked serving paths — over
//! fig8's regime table (offset exponents inside the Eq. (6) window for
//! the default `s_b = 12`), so schedule/path refactors cannot silently
//! regress precision recovery.
//!
//! Methodology: seeded RNG, non-negative sampling `U[0, 2^e]` (no
//! cancellation, so the max *elementwise* relative error against the
//! FP64 reference is well-conditioned), tolerance at 2^-22 scale — a
//! split-reconstruction term (the Sec. 3.3 ≥ 21.9-bit per-product
//! bound, with headroom) plus a worst-case FP32 accumulation term
//! linear in `k`. A plain-FP16 path fails these bounds by more than an
//! order of magnitude (~2^-11 per product), which is exactly the
//! regression this suite exists to catch — see
//! `recovery_beats_plain_fp16_by_an_order_of_magnitude`.

use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::gemm::backend::Backend;
use sgemm_cube::gemm::blocked::{
    cube_gemm_blocked, cube_gemm_blocked_overlapped, cube_gemm_blocked_overlapped_ab,
    cube_gemm_prepacked, family_gemm_blocked, gemm_prepacked_overlapped,
    gemm_prepacked_overlapped_ab, hgemm_blocked, sgemm_blocked,
};
use sgemm_cube::softfloat::family::SplitSpec;
use sgemm_cube::gemm::cube::{cube_gemm, Accumulation};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::{max_elementwise_error, relative_error};
use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

/// Fig. 8 regime table: offset exponents inside the Eq. (6) window for
/// the paper's default `s_b = 12` (full ~22-bit recovery), with shapes
/// straddling the engine's `MR`/`NR`/block boundaries and `k` ranging
/// across the `b_k` boundary.
const REGIMES: &[(i32, usize, usize, usize)] = &[
    (-6, 24, 48, 16),
    (-3, 16, 96, 24),
    (0, 32, 160, 24),
    (5, 8, 288, 40),
];

/// 2^-22-scale tolerance on the max elementwise relative error of the
/// cube paths: one split-reconstruction term per product (≥ 21.9
/// recovered bits, with ~8× headroom over the two-operand bound) plus
/// worst-case FP32 chain accumulation of `k` non-negative terms.
fn tol_cube(k: usize) -> f64 {
    16.0 * 2f64.powi(-22) + (k as f64 + 16.0) * 2f64.powi(-24)
}

/// FP32-path tolerance: product rounding + chain accumulation only.
fn tol_fp32(k: usize) -> f64 {
    4.0 * (k as f64 + 16.0) * 2f64.powi(-24)
}

#[test]
fn cube_paths_hold_22_bit_recovery_across_the_regime_table() {
    let cfg = SplitConfig::with_scale(12);
    for &(e, m, k, n) in REGIMES {
        let mut rng = Rng::new(9000 + e.unsigned_abs() as u64);
        let a = Matrix::random_nonneg(m, k, e, &mut rng);
        let b = Matrix::random_nonneg(k, n, e, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(cfg));
        let paths = [
            ("cube (exact termwise)", cube_gemm(&a, &b, cfg, Accumulation::Termwise)),
            ("cube_gemm_blocked", cube_gemm_blocked(&a, &b, cfg)),
            ("cube_gemm_blocked_overlapped", cube_gemm_blocked_overlapped(&a, &b, cfg)),
            ("cube_gemm_blocked_overlapped_ab", cube_gemm_blocked_overlapped_ab(&a, &b, cfg, 3)),
            ("cube_gemm_prepacked", cube_gemm_prepacked(&a, &pp)),
            ("gemm_prepacked_overlapped_ab", gemm_prepacked_overlapped_ab(&a, &pp, 3)),
        ];
        let tol = tol_cube(k);
        for (name, c) in &paths {
            let err = max_elementwise_error(&c_ref, &c.to_f64());
            assert!(
                err <= tol,
                "{name} at e={e} ({m}x{k}x{n}): max elementwise rel err {err:.3e} above \
                 2^-22-scale tolerance {tol:.3e} — precision recovery regressed"
            );
        }
    }
}

/// BF16×2 tolerance: ~16 recovered bits per product (2×8 significand
/// bits, residual truncation at 2^-16) plus FP32 chain accumulation.
fn tol_bf16x2(k: usize) -> f64 {
    16.0 * 2f64.powi(-16) + (k as f64 + 16.0) * 2f64.powi(-24)
}

/// BF16×3 tolerance: the three-component split is *exact* for normal
/// f32 (3×8 ≥ 24 significand bits) and every kept product is exact in
/// FP32, so only chain accumulation remains — FP32-class, ≥ 24 bits
/// per product.
fn tol_bf16x3(k: usize) -> f64 {
    4.0 * (k as f64 + 16.0) * 2f64.powi(-24)
}

#[test]
fn family_tiers_hold_their_bounds_across_the_regime_table() {
    // Per-tier derived bounds over fig8's regime table: FP16×2 ≈ 22
    // bits inside the Eq. (6) window (identical to the cube suite
    // above — the N = 2 FP16 spec *is* that engine), BF16×2 ≈ 16 bits,
    // BF16×3 ≥ 24 bits.
    for &(e, m, k, n) in REGIMES {
        let mut rng = Rng::new(9400 + e.unsigned_abs() as u64);
        let a = Matrix::random_nonneg(m, k, e, &mut rng);
        let b = Matrix::random_nonneg(k, n, e, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let tiers = [
            ("fp16x2", SplitSpec::fp16x2(SplitConfig::with_scale(12)), tol_cube(k)),
            ("bf16x2", SplitSpec::bf16x2(), tol_bf16x2(k)),
            ("bf16x3", SplitSpec::bf16x3(), tol_bf16x3(k)),
        ];
        for (name, spec, tol) in tiers {
            let c = family_gemm_blocked(&a, &b, spec);
            let err = max_elementwise_error(&c_ref, &c.to_f64());
            assert!(
                err <= tol,
                "{name} at e={e} ({m}x{k}x{n}): max elementwise rel err {err:.3e} above \
                 its derived bound {tol:.3e}"
            );
        }
    }
}

#[test]
fn bf16_tiers_hold_their_bounds_outside_the_fp16_window() {
    // The BF16 tiers' full-range claim: the same bounds hold at
    // exponents the scaled-FP16 scheme cannot represent at all. k is
    // kept small so the 2^-16-scale operand truncation of the 2-way
    // split stays well above the shared f32 accumulation floor
    // (~2^-24·√k), which at deep k narrows the measured gap between
    // the tiers to the point where a ratio assertion gets noisy.
    for e in [-30, 20, 45] {
        let (m, k, n) = (16, 12, 16);
        let mut rng = Rng::new(9500 + e.unsigned_abs() as u64);
        let a = Matrix::random_nonneg(m, k, e, &mut rng);
        let b = Matrix::random_nonneg(k, n, e, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e2 =
            max_elementwise_error(&c_ref, &family_gemm_blocked(&a, &b, SplitSpec::bf16x2()).to_f64());
        let e3 =
            max_elementwise_error(&c_ref, &family_gemm_blocked(&a, &b, SplitSpec::bf16x3()).to_f64());
        assert!(e2 <= tol_bf16x2(k), "bf16x2 at e={e}: {e2:.3e}");
        assert!(e3 <= tol_bf16x3(k), "bf16x3 at e={e}: {e3:.3e}");
        assert!(e3 < e2 / 8.0, "the third component must buy ≥ 3 bits: {e3:.3e} vs {e2:.3e}");
    }
}

#[test]
fn bf16x3_through_the_server_beats_the_fp32_tier() {
    // Acceptance: a tight-budget request routes to the six-pass BF16×3
    // cascade, whose measured accuracy beats the FP32 tier. The
    // operands are drawn from one binade ([1, 2)) with k ≤ 64 so the
    // win is structural, not statistical: every BF16 component product
    // carries ≤ 16 significant bits and the dominant high×high plane
    // accumulates *exactly* in f32 (16 + log2 k + carry ≤ 24 bits),
    // leaving the cascade only its final combine roundings — while
    // FP32 rounds every 46-bit product and every partial sum. On
    // unstructured operands both paths sit on the same f32
    // accumulation-noise floor and neither reliably beats the other.
    // And the policy only picks the cascade when the budget demands
    // it: a budget the cube can meet stays on the cube.
    let svc = GemmService::start(ServiceConfig::default());
    let mut rng = Rng::new(9600);
    let (m, k, n) = (24, 48, 24);
    let a = Matrix::from_fn(m, k, |_, _| rng.f32_range(1.0, 2.0));
    let b = Matrix::from_fn(k, n, |_, _| rng.f32_range(1.0, 2.0));
    let c_ref = dgemm_of_f32(&a, &b);

    let r3 = svc
        .gemm_blocking_with_precision(a.clone(), b.clone(), None, Some(1e-7))
        .expect("submit");
    assert_eq!(r3.backend, Backend::Bf16x3, "budget tighter than the cube's ~22 bits");
    let e3 = max_elementwise_error(&c_ref, &r3.result.unwrap().to_f64());

    let r32 = svc.gemm_blocking(a.clone(), b.clone(), Some(Backend::Fp32)).expect("submit");
    let e32 = max_elementwise_error(&c_ref, &r32.result.unwrap().to_f64());
    assert!(e3 < e32, "bf16x3 {e3:.3e} must beat fp32 {e32:.3e}");
    assert!(e3 <= tol_bf16x3(k), "bf16x3 {e3:.3e} above its bound");

    let r_cube = svc
        .gemm_blocking_with_precision(a.clone(), b.clone(), None, Some(1e-6))
        .expect("submit");
    assert_eq!(r_cube.backend, Backend::CubeTermwise, "satisfiable budgets stay off the cascade");
    svc.shutdown();
}

#[test]
fn recovery_beats_plain_fp16_by_an_order_of_magnitude() {
    // The discrimination that makes the suite loud: the cube path
    // recovers ~11 more mantissa bits than one FP16 pass. If the split
    // or the correction terms regress, the cube error collapses toward
    // hgemm's ~2^-11 class and both assertions below fail.
    let cfg = SplitConfig::with_scale(12);
    let mut rng = Rng::new(9100);
    let a = Matrix::random_nonneg(24, 192, 0, &mut rng);
    let b = Matrix::random_nonneg(192, 24, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let e_cube = max_elementwise_error(&c_ref, &cube_gemm_blocked(&a, &b, cfg).to_f64());
    let e_fp16 = max_elementwise_error(&c_ref, &hgemm_blocked(&a, &b).to_f64());
    assert!(e_fp16 > 2f64.powi(-14), "hgemm err {e_fp16:.3e} implausibly small");
    assert!(e_cube < e_fp16 / 16.0, "cube {e_cube:.3e} vs fp16 {e_fp16:.3e}");
}

#[test]
fn fp32_and_prepacked_fp32_paths_stay_at_reference_accuracy() {
    let mut rng = Rng::new(9200);
    let (m, k, n) = (16, 224, 24);
    let a = Matrix::random_nonneg(m, k, 0, &mut rng);
    let b = Matrix::random_nonneg(k, n, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let tol = tol_fp32(k);
    let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
    let paths = [
        ("sgemm_blocked", sgemm_blocked(&a, &b)),
        ("gemm_prepacked_overlapped (fp32)", gemm_prepacked_overlapped(&a, &pp)),
    ];
    for (name, c) in &paths {
        let err = max_elementwise_error(&c_ref, &c.to_f64());
        assert!(err <= tol, "{name}: max elementwise rel err {err:.3e} above {tol:.3e}");
    }
}

#[test]
fn frobenius_error_stays_in_the_fp32_class_under_symmetric_sampling() {
    // fig8's norm metric under the cancellation-heavy symmetric
    // sampling, at the bound the module tests already pin for the
    // blocked kernel (blocked_kernels_match_reference_accuracy_class):
    // every cube path — including both prepacked serving paths — stays
    // under 1e-6 at a 96×300×72 problem.
    let cfg = SplitConfig::with_scale(12);
    let mut rng = Rng::new(9300);
    let a = Matrix::random_symmetric(96, 300, 0, &mut rng);
    let b = Matrix::random_symmetric(300, 72, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(cfg));
    let paths = [
        ("cube_gemm_blocked", cube_gemm_blocked(&a, &b, cfg)),
        ("cube_gemm_blocked_overlapped", cube_gemm_blocked_overlapped(&a, &b, cfg)),
        ("cube_gemm_prepacked", cube_gemm_prepacked(&a, &pp)),
        ("gemm_prepacked_overlapped_ab", gemm_prepacked_overlapped_ab(&a, &pp, 2)),
    ];
    for (name, c) in &paths {
        let err = relative_error(&c_ref, &c.to_f64());
        assert!(err < 1e-6, "{name}: Frobenius rel err {err:.3e}");
    }
}
