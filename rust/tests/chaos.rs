//! Chaos suite: fault-injected resilient serving.
//!
//! Every scenario arms real failpoints ([`sgemm_cube::exec::faults`])
//! in the serving path and asserts the typed, bounded behaviour the
//! coordinator promises: a killed or failing shard is invisible to
//! clients (responses stay bit-identical to single-node serving),
//! injected batch panics/errors are retried behind the blocking entry
//! points, saturation sheds with [`GemmError::Overloaded`] instead of
//! deadlocking, deadlines surface as [`GemmError::Timeout`] instead of
//! hanging the waiter, and the same failpoint schedule replays
//! identically across runs.
//!
//! The failpoint registry is process-global, so the tests serialize on
//! one lock and reset the registry on entry (with poison recovery — an
//! injected panic unwinding through an assertion must not wedge the
//! rest of the suite).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::net::{NetClient, NetConfig, NetServer, WireError, WireOpts};
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::coordinator::shard::{ShardConfig, ShardHealth};
use sgemm_cube::exec::faults::{self, FailPolicy};
use sgemm_cube::gemm::error::GemmError;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize the test and start it from a disarmed registry.
fn chaos_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    faults::reset();
    g
}

fn cfg() -> ServiceConfig {
    ServiceConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        n_workers: 2,
        ..Default::default()
    }
}

fn assert_bits_eq(x: &Matrix<f32>, y: &Matrix<f32>, what: &str) {
    assert_eq!(x.shape(), y.shape(), "{what}");
    for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}");
    }
}

/// Kill one shard mid-stream: requests before and after the loss all
/// bit-match an unsharded reference service — failover is invisible to
/// clients, and the router's health reflects the loss.
#[test]
fn killed_shard_mid_stream_failover_bit_matches_single_node() {
    let _g = chaos_guard();
    let single = GemmService::start(cfg());
    let sharded = GemmService::start(ServiceConfig {
        shards: ShardConfig { count: 3, ..Default::default() },
        ..cfg()
    });
    let mut rng = Rng::new(71);
    let w = Matrix::random_symmetric(64, 53, 0, &mut rng);
    let id_single = single.register_weights(w.clone());
    let id_sharded = sharded.register_weights(w);
    let router = sharded.shard_router(id_sharded).expect("router built at registration");
    assert_eq!(router.live_count(), 3);
    for i in 0..6 {
        if i == 3 {
            router.kill(1); // lose a shard with traffic in flight
        }
        let a = Matrix::random_symmetric(8, 64, 0, &mut rng);
        let want = single
            .gemm_blocking_prepacked(a.clone(), id_single, None)
            .expect("submit")
            .result
            .expect("single-node request");
        let got = sharded
            .gemm_blocking_prepacked(a, id_sharded, None)
            .expect("submit")
            .result
            .expect("sharded request");
        assert_bits_eq(&want, &got, &format!("request {i}"));
    }
    assert_eq!(router.health(1), ShardHealth::Dead);
    assert_eq!(router.live_count(), 2);
    assert_eq!(sharded.metrics().report().errors, 0, "failover is invisible to clients");
    single.shutdown();
    sharded.shutdown();
}

/// Persistent injected errors on one shard march it Healthy → Suspect →
/// Dead while every response stays bit-identical; the recoveries count
/// as failovers, never as client-visible errors.
#[test]
fn injected_shard_errors_drive_health_failover_and_reassignment() {
    let _g = chaos_guard();
    faults::configure("coordinator.shard.exec.1", FailPolicy::Error);
    let single = GemmService::start(cfg());
    let sharded = GemmService::start(ServiceConfig {
        shards: ShardConfig {
            count: 3,
            suspect_after: 1,
            dead_after: 2,
            retries: 1,
            backoff: Duration::ZERO,
        },
        ..cfg()
    });
    let mut rng = Rng::new(72);
    let w = Matrix::random_symmetric(48, 30, 0, &mut rng);
    let id_single = single.register_weights(w.clone());
    let id_sharded = sharded.register_weights(w);
    let router = sharded.shard_router(id_sharded).expect("router");
    for i in 0..3 {
        let a = Matrix::random_symmetric(6, 48, 0, &mut rng);
        let want = single
            .gemm_blocking_prepacked(a.clone(), id_single, None)
            .expect("submit")
            .result
            .expect("single-node request");
        let got = sharded
            .gemm_blocking_prepacked(a, id_sharded, None)
            .expect("submit")
            .result
            .expect("sharded request");
        assert_bits_eq(&want, &got, &format!("request {i}"));
    }
    // One fan-out failure (Suspect at 1) + one same-shard retry failure
    // (Dead at 2): the first request already buries shard 1.
    assert_eq!(router.health(1), ShardHealth::Dead);
    assert_eq!(router.live_count(), 2);
    let report = sharded.metrics().report();
    assert!(report.failovers >= 1, "failovers={}", report.failovers);
    assert_eq!(report.errors, 0, "recovery must be invisible to clients");
    assert!(faults::fired("coordinator.shard.exec.1") >= 2);
    faults::reset();
    single.shutdown();
    sharded.shutdown();
}

/// A panic injected into batch execution is contained by the worker,
/// surfaced as a retryable typed error, and masked by the blocking
/// entry point's retry — and the retry is counted.
#[test]
fn injected_batch_panic_is_retried_to_success() {
    let _g = chaos_guard();
    faults::configure_nth("coordinator.batch.exec", FailPolicy::Panic, 1, 1);
    let svc = GemmService::start(ServiceConfig { retry_backoff: Duration::ZERO, ..cfg() });
    let mut rng = Rng::new(73);
    let a = Matrix::random_symmetric(8, 16, 0, &mut rng);
    let b = Matrix::random_symmetric(16, 8, 0, &mut rng);
    let resp = svc.gemm_blocking(a, b, None).expect("submit");
    assert!(resp.result.is_ok(), "retry must mask the injected panic: {:?}", resp.result);
    assert!(svc.metrics().report().retries >= 1);
    assert_eq!(faults::fired("coordinator.batch.exec"), 1);
    faults::reset();
    svc.shutdown();
}

/// The `error` policy takes the typed-injection path instead of the
/// unwind path; once the retry budget is exhausted the typed error
/// reaches the client, naming the failpoint.
#[test]
fn injected_batch_error_retries_then_surfaces_typed() {
    let _g = chaos_guard();
    faults::configure_nth("coordinator.batch.exec", FailPolicy::Error, 1, 1);
    let svc = GemmService::start(ServiceConfig { retry_backoff: Duration::ZERO, ..cfg() });
    let mut rng = Rng::new(74);
    let a = Matrix::random_symmetric(8, 16, 0, &mut rng);
    let b = Matrix::random_symmetric(16, 8, 0, &mut rng);
    let resp = svc.gemm_blocking(a.clone(), b.clone(), None).expect("submit");
    assert!(resp.result.is_ok(), "one injected error, budget of 2: {:?}", resp.result);
    // Unlimited injection exhausts the budget; the typed error surfaces.
    faults::configure("coordinator.batch.exec", FailPolicy::Error);
    let resp = svc.gemm_blocking(a, b, None).expect("submit");
    match resp.result {
        Err(GemmError::Injected(site)) => assert_eq!(site, "coordinator.batch.exec"),
        other => panic!("expected Injected, got {other:?}"),
    }
    assert!(svc.metrics().report().retries >= 3, "1 masking retry + 2 exhausted");
    faults::reset();
    svc.shutdown();
}

/// A panic injected into the prepack-cache miss path is contained (no
/// lock poisoning — the next attempt simply misses again and repacks)
/// and masked by the retry.
#[test]
fn injected_prepack_panic_is_contained_and_retried() {
    let _g = chaos_guard();
    faults::configure_nth("gemm.cache.prepack", FailPolicy::Panic, 1, 1);
    let svc = GemmService::start(ServiceConfig { retry_backoff: Duration::ZERO, ..cfg() });
    let mut rng = Rng::new(75);
    let w = Matrix::random_symmetric(24, 16, 0, &mut rng);
    let id = svc.register_weights(w);
    let a = Matrix::random_symmetric(4, 24, 0, &mut rng);
    let resp = svc.gemm_blocking_prepacked(a, id, None).expect("submit");
    assert!(resp.result.is_ok(), "{:?}", resp.result);
    assert!(svc.metrics().report().retries >= 1);
    assert_eq!(svc.prepack_stats().misses, 2, "the failed pack never inserted");
    faults::reset();
    svc.shutdown();
}

/// Saturating a 1-worker service whose batches are slowed by an
/// injected delay: admission control sheds the burst with a typed
/// `Overloaded`, every admitted request still completes, nothing
/// deadlocks.
#[test]
fn saturation_sheds_with_typed_overloaded_and_no_deadlock() {
    let _g = chaos_guard();
    faults::configure("coordinator.batch.exec", FailPolicy::Delay(25));
    let svc = GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        n_workers: 1,
        max_pending: 2,
        retries: 0,
        ..Default::default()
    });
    let mut rng = Rng::new(76);
    let a = Matrix::random_symmetric(4, 8, 0, &mut rng);
    let b = Matrix::random_symmetric(8, 4, 0, &mut rng);
    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..10 {
        match svc.submit(a.clone(), b.clone(), None) {
            Ok((_, rx)) => accepted.push(rx),
            Err(GemmError::Overloaded { in_flight, limit }) => {
                assert!(in_flight > limit);
                assert_eq!(limit, 2);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert_eq!(accepted.len() as u64 + shed, 10);
    assert!(shed >= 1, "the bound must shed under burst");
    assert!(accepted.len() >= 2, "the bound must admit up to max_pending");
    for rx in accepted {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("no deadlock");
        assert!(resp.result.is_ok());
    }
    assert_eq!(svc.metrics().report().shed, shed);
    faults::reset();
    svc.shutdown();
}

/// A request that outlives its deadline returns a typed `Timeout`
/// promptly — the waiter never hangs on a stalled batch — and the
/// expiry is counted.
#[test]
fn deadline_expiry_is_a_typed_timeout_not_a_hang() {
    let _g = chaos_guard();
    faults::configure("coordinator.batch.exec", FailPolicy::Delay(200));
    let svc = GemmService::start(ServiceConfig {
        request_timeout: Some(Duration::from_millis(30)),
        retries: 0,
        ..cfg()
    });
    let mut rng = Rng::new(77);
    let a = Matrix::random_symmetric(4, 8, 0, &mut rng);
    let b = Matrix::random_symmetric(8, 4, 0, &mut rng);
    let t0 = Instant::now();
    match svc.gemm_blocking(a, b, None) {
        // `after` is the true elapsed wall time, so it is at least the
        // 30ms budget but never exactly it.
        Err(GemmError::Timeout { after }) => {
            assert!(after >= Duration::from_millis(30), "after={after:?}");
            assert!(after < Duration::from_secs(5), "after={after:?}");
        }
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "waiter must give up promptly");
    assert!(svc.metrics().report().timeouts >= 1);
    // Disarm before shutdown so the drain isn't delayed per request.
    faults::reset();
    svc.shutdown();
}

/// Regression for the deadline-budget bug: the retry loop must fit
/// inside ONE end-to-end budget. The old code re-armed the full
/// `request_timeout` on every `wait_reply` and stamped a fresh deadline
/// on every resubmission, so R retries could block the caller for
/// (R+1)x the configured timeout. Here every attempt costs ~60ms (a
/// delayed pool pickup) and then fails retryably (an injected batch
/// panic), so a 150ms budget with 10 retries used to burn ~660ms of
/// attempts plus backoff; now it must surface a typed `Timeout` at
/// ~150ms of true wall time.
#[test]
fn retried_request_wall_time_never_exceeds_the_budget() {
    let _g = chaos_guard();
    faults::configure("exec.pool.task", FailPolicy::Delay(60));
    faults::configure("coordinator.batch.exec", FailPolicy::Panic);
    let svc = GemmService::start(ServiceConfig {
        request_timeout: Some(Duration::from_millis(150)),
        retries: 10,
        retry_backoff: Duration::from_millis(1),
        ..cfg()
    });
    let mut rng = Rng::new(78);
    let a = Matrix::random_symmetric(4, 8, 0, &mut rng);
    let b = Matrix::random_symmetric(8, 4, 0, &mut rng);
    let t0 = Instant::now();
    let outcome = svc.gemm_blocking(a, b, None);
    let elapsed = t0.elapsed();
    match outcome {
        Err(GemmError::Timeout { after }) => {
            assert!(after >= Duration::from_millis(150), "after={after:?}");
        }
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert!(
        elapsed < Duration::from_millis(600),
        "one budget end-to-end, not one per attempt: elapsed={elapsed:?}"
    );
    let report = svc.metrics().report();
    assert!(report.timeouts >= 1);
    assert!(report.retries >= 1, "the injected panic was retryable");
    faults::reset();
    svc.shutdown();
}

/// Chaos holds over the wire: the same process-global failpoints drive
/// the socket path. An injected batch error behind `POST /gemm` is
/// masked by the service retry and the reply stays bit-identical to the
/// in-process path.
#[test]
fn wire_request_masks_injected_error_and_bit_matches_in_process() {
    let _g = chaos_guard();
    faults::configure_nth("coordinator.batch.exec", FailPolicy::Error, 1, 1);
    let svc = Arc::new(GemmService::start(ServiceConfig {
        retry_backoff: Duration::ZERO,
        ..cfg()
    }));
    let net = NetServer::bind(Arc::clone(&svc), NetConfig::default()).expect("bind");
    let mut client = NetClient::connect(net.local_addr().to_string());
    let mut rng = Rng::new(79);
    let a = Matrix::random_symmetric(8, 16, 0, &mut rng);
    let b = Matrix::random_symmetric(16, 8, 0, &mut rng);
    let reply = client
        .gemm(&a, &b, &WireOpts::default())
        .expect("retry must mask the injected error over the wire");
    // The failpoint is spent, so the reference run is clean.
    let want = svc
        .gemm_blocking(a, b, None)
        .expect("submit")
        .result
        .expect("in-process reference");
    assert_bits_eq(&want, &reply.c, "wire vs in-process under chaos");
    assert!(svc.metrics().report().retries >= 1);
    assert_eq!(faults::fired("coordinator.batch.exec"), 1);
    faults::reset();
    net.shutdown();
    svc.shutdown();
}

/// Socket-level overload: with batches slowed by an injected delay and
/// a 1-deep admission bound, a second concurrent wire request is shed
/// as HTTP 503 with the typed `overloaded` kind — and the front door
/// stays live for `/healthz` afterwards.
#[test]
fn wire_overload_sheds_typed_503_and_front_door_stays_live() {
    let _g = chaos_guard();
    faults::configure("coordinator.batch.exec", FailPolicy::Delay(200));
    let svc = Arc::new(GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        n_workers: 1,
        max_pending: 1,
        retries: 0,
        ..Default::default()
    }));
    let net = NetServer::bind(Arc::clone(&svc), NetConfig::default()).expect("bind");
    let addr = net.local_addr().to_string();
    let mut rng = Rng::new(80);
    let a = Matrix::random_symmetric(4, 8, 0, &mut rng);
    let b = Matrix::random_symmetric(8, 4, 0, &mut rng);
    let slow = {
        let (addr, a, b) = (addr.clone(), a.clone(), b.clone());
        std::thread::spawn(move || {
            NetClient::connect(addr).gemm(&a, &b, &WireOpts::default())
        })
    };
    // Let the slow request occupy the 1-deep admission window (it holds
    // it for ~200ms), then hit the same service over a second socket.
    std::thread::sleep(Duration::from_millis(50));
    let mut client = NetClient::connect(addr);
    match client.gemm(&a, &b, &WireOpts::default()) {
        Err(WireError::Status { code, kind, .. }) => {
            assert_eq!(code, 503, "admission shed must surface as 503");
            assert_eq!(kind, "overloaded");
        }
        other => panic!("expected a 503 overloaded status, got {other:?}"),
    }
    let slow = slow.join().expect("slow client thread");
    assert!(slow.is_ok(), "the admitted request still completes: {slow:?}");
    assert!(svc.metrics().report().shed >= 1);
    assert!(client.healthz().expect("healthz"), "front door stays live after shedding");
    faults::reset();
    net.shutdown();
    svc.shutdown();
}

/// Submissions after shutdown fail with a typed `ChannelClosed`; they
/// never panic the submitting thread.
#[test]
fn submit_after_shutdown_is_channel_closed() {
    let _g = chaos_guard();
    let svc = GemmService::start(ServiceConfig { retries: 0, ..cfg() });
    svc.shutdown();
    let a: Matrix<f32> = Matrix::zeros(2, 3);
    let b: Matrix<f32> = Matrix::zeros(3, 2);
    match svc.submit(a.clone(), b.clone(), None) {
        Err(GemmError::ChannelClosed) => {}
        other => panic!("expected ChannelClosed, got {:?}", other.map(|(id, _)| id)),
    }
    match svc.gemm_blocking(a, b, None) {
        Err(GemmError::ChannelClosed) => {}
        other => panic!("expected ChannelClosed, got {other:?}"),
    }
}

/// The same failpoint configuration replays the same schedule, run
/// after run — chaos scenarios are reproducible, and a disarmed
/// registry is a no-op.
#[test]
fn failpoint_schedules_replay_deterministically() {
    let _g = chaos_guard();
    let site = "chaos.determinism";
    let mut runs = Vec::new();
    for _ in 0..2 {
        faults::configure_nth(site, FailPolicy::Error, 3, 2);
        let fired: Vec<usize> = (1..=10).filter(|_| faults::check(site).is_err()).collect();
        runs.push(fired);
    }
    assert_eq!(runs[0], vec![3, 4], "fires on hits 3 and 4, then goes quiet");
    assert_eq!(runs[0], runs[1], "same config, same schedule");
    assert_eq!(faults::hits(site), 10);
    assert_eq!(faults::fired(site), 2);
    faults::reset();
    assert!(!faults::armed());
    assert!(faults::check("coordinator.batch.exec").is_ok(), "disarmed sites are no-ops");
    assert_eq!(faults::hits("coordinator.batch.exec"), 0);
}
