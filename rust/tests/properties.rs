//! Property-based tests over the numerical substrates and coordinator
//! invariants (proptest substitute: `sgemm_cube::util::quickcheck`).

use sgemm_cube::coordinator::request::ShapeKey;
use sgemm_cube::coordinator::scheduler::{assign, imbalance, tiles_of};
use sgemm_cube::gemm::blocked::{
    cube_gemm_blocked, cube_gemm_blocked_overlapped, cube_gemm_blocked_overlapped_ab,
    gemm_prepacked, hgemm_blocked, hgemm_blocked_overlapped, hgemm_blocked_overlapped_ab,
    host_block, sgemm_blocked, sgemm_blocked_overlapped, sgemm_blocked_overlapped_ab,
};
use sgemm_cube::gemm::cube::{cube_gemm, Accumulation};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
use sgemm_cube::gemm::hgemm::{add_f32_rz, hgemm, AccumulateMode};
use sgemm_cube::gemm::kernels::{active_lane, kernel_cube, kernel_f32, Lane};
use sgemm_cube::gemm::sgemm::sgemm;
use sgemm_cube::qc_assert;
use sgemm_cube::softfloat::f16::{F16, Rounding};
use sgemm_cube::softfloat::split::{reconstruct, split_f32, SplitConfig};
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::quickcheck::{close, property, Gen};
use sgemm_cube::util::rng::Rng;

#[test]
fn prop_f16_roundtrip_is_identity_on_f16_values() {
    property("f16 -> f32 -> f16 identity", 2000, |g: &mut Gen| {
        let bits = (g.u64() & 0xffff) as u16;
        let h = F16::from_bits(bits);
        if h.is_nan() {
            return Ok(());
        }
        let rt = F16::from_f32_rn(h.to_f32());
        qc_assert!(rt == h, "bits {bits:#06x} -> {:#06x}", rt.to_bits());
        Ok(())
    });
}

#[test]
fn prop_rn_conversion_error_within_half_ulp() {
    property("|x - rn16(x)| <= ulp/2", 5000, |g: &mut Gen| {
        let x = g.moderate_f32();
        let h = F16::from_f32_rn(x);
        if h.is_infinite() {
            return Ok(());
        }
        let hv = h.to_f32();
        // ULP at the converted value's scale.
        let up = F16::from_bits(h.to_bits() + 1);
        if up.is_nan() || up.is_infinite() {
            return Ok(());
        }
        let ulp = (up.to_f32() - hv).abs();
        qc_assert!(
            (x - hv).abs() <= ulp / 2.0 + f32::EPSILON * x.abs(),
            "x={x} hv={hv} ulp={ulp}"
        );
        Ok(())
    });
}

#[test]
fn prop_rz_magnitude_never_exceeds_input() {
    property("|rz16(x)| <= |x|", 5000, |g: &mut Gen| {
        let x = g.moderate_f32();
        let h = F16::from_f32(x, Rounding::TowardZero);
        qc_assert!(h.to_f32().abs() <= x.abs(), "x={x} -> {}", h.to_f32());
        Ok(())
    });
}

#[test]
fn prop_split_reconstruct_error_bounded() {
    // 22-bit recovery inside the supported window (Sec. 3.3 / Fig. 2b).
    property("split keeps >= 21.9 bits for e in [-12, 14]", 3000, |g: &mut Gen| {
        let e = g.i32_in(-12, 15);
        let v = {
            let mut rng = Rng::new(g.u64());
            rng.f32_with_exponent(e)
        };
        let cfg = SplitConfig::default();
        let (h, l) = split_f32(v, &cfg);
        let approx = reconstruct(h, l, &cfg) as f64;
        let rel = ((v as f64) - approx).abs() / (v as f64).abs();
        qc_assert!(rel <= 2f64.powf(-21.9), "v={v} e={e} rel={rel:.3e}");
        Ok(())
    });
}

#[test]
fn prop_split_high_part_is_rn16() {
    property("split high == rn16(v)", 3000, |g: &mut Gen| {
        let v = g.moderate_f32();
        let (h, _) = split_f32(v, &SplitConfig::default());
        qc_assert!(h == F16::from_f32_rn(v), "v={v}");
        Ok(())
    });
}

#[test]
fn prop_rz_add_is_exact_or_truncated() {
    property("rz add below exact, within 1 ulp", 5000, |g: &mut Gen| {
        let a = g.f32_in(-1e6, 1e6);
        let b = g.f32_in(-1e6, 1e6);
        let exact = a as f64 + b as f64;
        let rz = add_f32_rz(a, b) as f64;
        qc_assert!(rz.abs() <= exact.abs(), "a={a} b={b} rz={rz} exact={exact}");
        let rn = (a + b) as f64;
        qc_assert!(
            (exact - rz).abs() <= 2.0 * (exact - rn).abs() + exact.abs() * f32::EPSILON as f64,
            "a={a} b={b}"
        );
        Ok(())
    });
}

#[test]
fn prop_cube_gemm_within_fp32_class_error() {
    property("cube gemm err < 1e-5 for moderate inputs", 25, |g: &mut Gen| {
        let m = 8 * g.usize_in(1, 5);
        let k = 8 * g.usize_in(1, 8);
        let n = 8 * g.usize_in(1, 5);
        let e = g.i32_in(-6, 7);
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(m, k, e, &mut rng);
        let b = Matrix::random_symmetric(k, n, e, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let acc = if g.bool() { Accumulation::Termwise } else { Accumulation::Elementwise };
        let c = cube_gemm(&a, &b, SplitConfig::default(), acc);
        let err = relative_error(&c_ref, &c.to_f64());
        qc_assert!(err < 1e-5, "({m},{k},{n}) e={e} err={err:.3e}");
        Ok(())
    });
}

#[test]
fn prop_gemm_linearity_in_scaling() {
    // cube_gemm(alpha*A, B) ≈ alpha*cube_gemm(A, B) for power-of-two
    // alpha. Exactly equivariant while both splits stay in the fp16
    // normal range; U[-1,1] tails can push residuals into the subnormal
    // range (fixed quantum 2^-24), so the tolerance allows fp32-class
    // noise rather than demanding bit equality.
    property("power-of-two scale equivariance", 40, |g: &mut Gen| {
        let n = 8 * g.usize_in(1, 4);
        let p = g.i32_in(-3, 4);
        let alpha = (p as f32).exp2();
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(n, n, 0, &mut rng);
        let b = Matrix::random_symmetric(n, n, 0, &mut rng);
        let a_scaled = a.map(|v| v * alpha);
        let c1 = cube_gemm(&a_scaled, &b, SplitConfig::default(), Accumulation::Termwise);
        let c2 = cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise);
        for i in 0..n {
            for j in 0..n {
                let x = c1.get(i, j) as f64;
                let y = (c2.get(i, j) * alpha) as f64;
                qc_assert!(close(x, y, 1e-5, 1e-9), "({i},{j}): {x} vs {y}");
            }
        }
        Ok(())
    });
}

/// Forward-error bound for comparing two same-algorithm GEMM variants
/// that differ only in accumulation order: per entry, the difference is
/// bounded by a small multiple of `k · eps32 · Σ|a_it·b_tj|`.
fn reorder_tolerance(abs_products: &Matrix<f64>, k: usize, i: usize, j: usize) -> f64 {
    let s = abs_products.get(i, j);
    8.0 * (k as f64 + 8.0) * f32::EPSILON as f64 * s + 1e-30
}

#[test]
fn prop_blocked_kernels_match_exact_on_awkward_shapes() {
    // ISSUE requirement: the blocked kernels agree with the exact kernels
    // within multi-accumulator noise across awkward shapes — k smaller
    // than b_k, k larger than b_k, and every non-multiple-of-MR/NR edge.
    const DIMS: [usize; 6] = [1, 7, 16, 17, 96, 257];
    let cfg = SplitConfig::default();
    let bk = host_block().bk;
    let mut rng = Rng::new(777);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = Matrix::random_symmetric(m, k, 0, &mut rng);
                let b = Matrix::random_symmetric(k, n, 0, &mut rng);
                // Σ|a·b| per entry bounds every partial sum of products.
                let abs_p = dgemm_of_f32(&a.map(f32::abs), &b.map(f32::abs));
                let ctx = format!("({m},{k},{n})");

                // FP32: bit-identical within one k block on the scalar
                // lane (the FMA lanes round each chain step once instead
                // of twice — same chain, same order; tests/dispatch.rs
                // pins the bitwise claim under a forced scalar lane),
                // reorder-bounded beyond it.
                let exact = sgemm(&a, &b);
                let blocked = sgemm_blocked(&a, &b);
                check_close(&exact, &blocked, &abs_p, k, 1.0, &format!("sgemm {ctx}"));
                if k <= bk && active_lane() == Lane::Scalar {
                    for (x, y) in exact.as_slice().iter().zip(blocked.as_slice()) {
                        assert!(x.to_bits() == y.to_bits(), "sgemm bits {ctx}");
                    }
                }

                // FP16 operands, FP32 accumulation.
                let exact = hgemm(&a, &b, AccumulateMode::Fp32Rn);
                let blocked = hgemm_blocked(&a, &b);
                check_close(&exact, &blocked, &abs_p, k, 1.1, &format!("hgemm {ctx}"));

                // Cube: termwise exact vs the fused blocked kernel. The
                // correction terms carry an extra |a|·|b|-scale bound via
                // the split residuals, covered by the scale factor.
                let exact = cube_gemm(&a, &b, cfg, Accumulation::Termwise);
                let blocked = cube_gemm_blocked(&a, &b, cfg);
                check_close(&exact, &blocked, &abs_p, k, 4.0, &format!("cube {ctx}"));
            }
        }
    }
}

/// Assert two f32 results agree within the reorder tolerance scaled by
/// `factor`.
fn check_close(
    exact: &Matrix<f32>,
    blocked: &Matrix<f32>,
    abs_products: &Matrix<f64>,
    k: usize,
    factor: f64,
    what: &str,
) {
    assert_eq!(exact.shape(), blocked.shape(), "{what}: shape");
    let (m, n) = exact.shape();
    for i in 0..m {
        for j in 0..n {
            let x = exact.get(i, j) as f64;
            let y = blocked.get(i, j) as f64;
            let tol = factor * reorder_tolerance(abs_products, k, i, j);
            assert!(
                (x - y).abs() <= tol,
                "{what} at ({i},{j}): exact {x} vs blocked {y} (tol {tol:.3e})"
            );
        }
    }
}

#[test]
fn prop_blocked_cube_preserves_termwise_ordering_at_large_k() {
    // ISSUE requirement: the fused cube micro-kernel must keep the
    // termwise-vs-elementwise accuracy ordering at large k (Fig. 9 b/c
    // regime): corrections aggregate among themselves per k block before
    // meeting the high product, so swamping never happens per step.
    let mut rng = Rng::new(778);
    let k = 4096;
    let a = Matrix::random_nonneg(16, k, 0, &mut rng);
    let b = Matrix::random_nonneg(k, 16, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let cfg = SplitConfig::default();
    let e_el = relative_error(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Elementwise).to_f64());
    let e_tw = relative_error(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise).to_f64());
    let e_blocked = relative_error(&c_ref, &cube_gemm_blocked(&a, &b, cfg).to_f64());
    assert!(e_blocked <= e_el, "blocked {e_blocked} vs elementwise {e_el}");
    assert!(e_blocked <= e_tw * 2.0, "blocked {e_blocked} vs termwise {e_tw}");
}

#[test]
fn prop_overlapped_bit_identical_to_serial_blocked() {
    // ISSUE requirement: the overlapped (prefetching) b_k pipeline must
    // be byte-for-byte equal to the serial blocked engine across the
    // fp32/fp16/cube paths and random shapes — same pack routines, same
    // block order, same sweeps, different schedule.
    let bk = host_block().bk;
    property("overlapped == serial, bitwise", 10, |g: &mut Gen| {
        let m = g.usize_in(1, 48);
        // Bias k across the b_k boundary so several panels are prefetched.
        let k = if g.bool() { g.usize_in(1, bk) } else { g.usize_in(bk + 1, 3 * bk + 5) };
        let n = g.usize_in(1, 80);
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let bitwise = |x: &Matrix<f32>, y: &Matrix<f32>, what: &str| -> Result<(), String> {
            for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
                if u.to_bits() != v.to_bits() {
                    return Err(format!("{what} ({m},{k},{n}): {u} vs {v}"));
                }
            }
            Ok(())
        };
        bitwise(&sgemm_blocked(&a, &b), &sgemm_blocked_overlapped(&a, &b), "fp32")?;
        bitwise(&hgemm_blocked(&a, &b), &hgemm_blocked_overlapped(&a, &b), "fp16")?;
        for s_b in [12, 8] {
            let cfg = SplitConfig::with_scale(s_b);
            bitwise(
                &cube_gemm_blocked(&a, &b, cfg),
                &cube_gemm_blocked_overlapped(&a, &b, cfg),
                &format!("cube s_b={s_b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_ab_prefetch_bit_identical_to_serial_blocked() {
    // ISSUE requirement: the A+B dual-panel pipeline (B panel and A
    // row-block stripe prefetched through a depth-configurable ring on
    // the persistent pool) must be byte-for-byte equal to the serial
    // blocked engine across the fp32/fp16/cube paths, random shapes
    // including zero dims, and pipeline_depth ∈ {1, 2, 3}.
    let bk = host_block().bk;
    property("A+B prefetch == serial, bitwise", 8, |g: &mut Gen| {
        // Zero extents ride along: each dimension independently has a
        // small chance of being zero.
        let m = if g.case == 1 { 0 } else { g.usize_in(1, 49) };
        // Bias k across the b_k boundary so several stripes are
        // prefetched per column block.
        let k = match g.case {
            2 => 0,
            _ if g.bool() => g.usize_in(1, bk + 1),
            _ => g.usize_in(bk + 1, 3 * bk + 5),
        };
        let n = if g.case == 3 { 0 } else { g.usize_in(1, 81) };
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let bitwise = |x: &Matrix<f32>, y: &Matrix<f32>, what: &str| -> Result<(), String> {
            if x.shape() != y.shape() {
                return Err(format!("{what} ({m},{k},{n}): shape {:?} vs {:?}", x.shape(), y.shape()));
            }
            for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
                if u.to_bits() != v.to_bits() {
                    return Err(format!("{what} ({m},{k},{n}): {u} vs {v}"));
                }
            }
            Ok(())
        };
        let s_ref = sgemm_blocked(&a, &b);
        let h_ref = hgemm_blocked(&a, &b);
        for depth in [1usize, 2, 3] {
            bitwise(&s_ref, &sgemm_blocked_overlapped_ab(&a, &b, depth), &format!("fp32 d{depth}"))?;
            bitwise(&h_ref, &hgemm_blocked_overlapped_ab(&a, &b, depth), &format!("fp16 d{depth}"))?;
            for s_b in [12, 8] {
                let cfg = SplitConfig::with_scale(s_b);
                bitwise(
                    &cube_gemm_blocked(&a, &b, cfg),
                    &cube_gemm_blocked_overlapped_ab(&a, &b, cfg, depth),
                    &format!("cube s_b={s_b} d{depth}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_prepacked_prefetch_bit_identical() {
    // ISSUE 5 requirement: the prepacked A-stripe prefetch path (cached
    // B panels + prefetched A) must be byte-for-byte equal to serial
    // `gemm_prepacked` across the fp32/fp16/cube paths, random shapes
    // including zero dims, pipeline depth ∈ {1, 2, 3}, and regardless
    // of whether the operand came fresh from a pack (cache miss) or out
    // of the LRU (cache hit).
    use sgemm_cube::gemm::backend::Backend;
    use sgemm_cube::gemm::blocked::{gemm_prepacked_overlapped, gemm_prepacked_overlapped_ab};
    use sgemm_cube::gemm::cache::{PrepackCache, PrepackKey};
    use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
    let bk = host_block().bk;
    property("prepacked A-stripe prefetch == serial prepacked, bitwise", 8, |g: &mut Gen| {
        // Zero extents ride along: each dimension independently has a
        // small chance of being zero.
        let m = if g.case == 1 { 0 } else { g.usize_in(1, 41) };
        // Bias k across the b_k boundary so several stripes are
        // prefetched per column block.
        let k = match g.case {
            2 => 0,
            _ if g.bool() => g.usize_in(1, bk + 1),
            _ => g.usize_in(bk + 1, 2 * bk + 5),
        };
        let n = if g.case == 3 { 0 } else { g.usize_in(1, 65) };
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let cache = PrepackCache::new(64 << 20);
        let cases = [
            (Backend::Fp32, 0, PrepackPath::Fp32, "fp32"),
            (Backend::Fp16, 0, PrepackPath::Fp16, "fp16"),
            (Backend::CubeTermwise, 12, PrepackPath::Cube(SplitConfig::with_scale(12)), "cube"),
        ];
        for (backend, scale_exp, path, what) in cases {
            let key = PrepackKey {
                weight: 1,
                k,
                n,
                backend,
                scale_exp,
                lane: sgemm_cube::gemm::kernels::active_lane(),
                col0: 0,
            };
            // Lookup 0 misses (packs fresh), lookup 1 hits the LRU; the
            // prefetched path must be bit-identical either way.
            for lookup in 0..2 {
                let pp = cache.get_or_insert_with(key, || PrepackedMatrix::prepack(&b, path));
                let want = gemm_prepacked(&a, &pp);
                let mut candidates = vec![(gemm_prepacked_overlapped(&a, &pp), "d2".to_string())];
                for depth in [1usize, 2, 3] {
                    let got = gemm_prepacked_overlapped_ab(&a, &pp, depth);
                    candidates.push((got, format!("ab d{depth}")));
                }
                for (got, which) in &candidates {
                    if want.shape() != got.shape() {
                        return Err(format!("{what} {which} lookup {lookup} ({m},{k},{n}): shape"));
                    }
                    for (u, v) in want.as_slice().iter().zip(got.as_slice()) {
                        if u.to_bits() != v.to_bits() {
                            return Err(format!(
                                "{what} {which} lookup {lookup} ({m},{k},{n}): {u} vs {v}"
                            ));
                        }
                    }
                }
            }
        }
        let s = cache.stats();
        qc_assert!(s.misses == 3 && s.hits == 3, "one miss + one hit per path: {s:?}");
        Ok(())
    });
}

#[test]
fn prop_kernel_lanes_agree_within_fma_rounding() {
    // ISSUE 7 requirement: every available SIMD lane agrees with the
    // scalar reference within the per-step rounding gap between fused
    // (one rounding) and unfused (two roundings) accumulation chains —
    // a standard forward-error envelope of the absolute dot product —
    // and each lane is bit-deterministic on its own. Explicit-lane
    // kernel calls only: no global dispatch state is touched, so this
    // cannot race the schedule tests running under the active lane
    // (the forced-lane schedule matrix lives in tests/dispatch.rs).
    use sgemm_cube::gemm::pack::{MR, NR};
    property("kernel lanes agree within FMA rounding", 40, |g: &mut Gen| {
        let kc = g.usize_in(1, 200);
        let mut rng = Rng::new(g.u64());
        let mut fill = |len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.f32_range(-2.0, 2.0)).collect()
        };
        let (ap, bp) = (fill(kc * MR), fill(kc * NR));
        let (dap, dbp) = (fill(kc * 2 * MR), fill(kc * 2 * NR));
        let envelope = |absdot: f32| 4.0 * (kc as f32) * f32::EPSILON * absdot.max(1.0);
        let want = kernel_f32(Lane::Scalar, &ap, &bp);
        let (whh, wcorr) = kernel_cube(Lane::Scalar, &dap, &dbp);
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let got = kernel_f32(lane, &ap, &bp);
            let (ghh, gcorr) = kernel_cube(lane, &dap, &dbp);
            for i in 0..MR {
                for j in 0..NR {
                    let mut dot = 0.0f32;
                    let (mut hi, mut co) = (0.0f32, 0.0f32);
                    for p in 0..kc {
                        dot += ap[p * MR + i].abs() * bp[p * NR + j].abs();
                        let (ah, al) = (dap[p * 2 * MR + i].abs(), dap[p * 2 * MR + MR + i].abs());
                        let (bh, bl) = (dbp[p * 2 * NR + j].abs(), dbp[p * 2 * NR + NR + j].abs());
                        hi += ah * bh;
                        co += ah * bl + al * bh;
                    }
                    let (x, y) = (want[i][j], got[i][j]);
                    qc_assert!((x - y).abs() <= envelope(dot), "{lane} f32 [{i}][{j}]: {x} vs {y}");
                    let (x, y) = (whh[i][j], ghh[i][j]);
                    qc_assert!((x - y).abs() <= envelope(hi), "{lane} hh [{i}][{j}]: {x} vs {y}");
                    let (x, y) = (wcorr[i][j], gcorr[i][j]);
                    qc_assert!((x - y).abs() <= envelope(co), "{lane} corr [{i}][{j}]: {x} vs {y}");
                }
            }
            // Bit-determinism per lane: re-running the same panels on the
            // same lane reproduces the exact bits.
            let again = kernel_f32(lane, &ap, &bp);
            for (rx, ry) in got.iter().zip(&again) {
                for (u, v) in rx.iter().zip(ry) {
                    qc_assert!(u.to_bits() == v.to_bits(), "{lane} nondeterministic f32 kernel");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_degenerate_zero_dims_never_panic() {
    // ISSUE requirement: m, n or k of zero returns an empty/zero result
    // through every engine entry point — serial, overlapped, prepacked —
    // and the packing routines accept zero extents.
    use sgemm_cube::gemm::pack;
    use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
    let cfg = SplitConfig::default();
    for (m, k, n) in [
        (0usize, 5usize, 4usize),
        (3, 0, 2),
        (3, 5, 0),
        (0, 0, 0),
        (1, 0, 0),
        (0, 1, 0),
        (0, 0, 1),
    ] {
        let a: Matrix<f32> = Matrix::zeros(m, k);
        let b: Matrix<f32> = Matrix::zeros(k, n);
        let ctx = format!("({m},{k},{n})");
        let results = [
            sgemm_blocked(&a, &b),
            hgemm_blocked(&a, &b),
            cube_gemm_blocked(&a, &b, cfg),
            sgemm_blocked_overlapped(&a, &b),
            hgemm_blocked_overlapped(&a, &b),
            cube_gemm_blocked_overlapped(&a, &b, cfg),
            sgemm_blocked_overlapped_ab(&a, &b, 2),
            hgemm_blocked_overlapped_ab(&a, &b, 3),
            cube_gemm_blocked_overlapped_ab(&a, &b, cfg, 2),
        ];
        for c in &results {
            assert_eq!(c.shape(), (m, n), "{ctx}");
            assert!(c.as_slice().iter().all(|&v| v == 0.0), "{ctx}");
        }
        for path in [PrepackPath::Fp32, PrepackPath::Fp16, PrepackPath::Cube(cfg)] {
            let pp = PrepackedMatrix::prepack(&b, path);
            assert_eq!((pp.k(), pp.n()), (k, n), "{ctx} {path:?}");
            let serial = gemm_prepacked(&a, &pp);
            let prefetched = sgemm_cube::gemm::blocked::gemm_prepacked_overlapped_ab(&a, &pp, 2);
            for c in [&serial, &prefetched] {
                assert_eq!(c.shape(), (m, n), "{ctx} {path:?}");
                assert!(c.as_slice().iter().all(|&v| v == 0.0), "{ctx} {path:?}");
            }
        }
        // Packing with zero extents yields empty panel sets, not reads
        // out of bounds.
        let mut out = vec![1.0f32];
        pack::pack_a(&a, 0, 0, 0, 0, &mut out);
        assert!(out.is_empty(), "{ctx}");
        out.push(1.0);
        pack::pack_b(&b, 0, 0, 0, 0, &mut out);
        assert!(out.is_empty(), "{ctx}");
        // Zero k steps over a nonzero row extent is also legal: panels
        // exist but carry no k steps, so the buffer stays empty.
        let mut out = Vec::new();
        pack::pack_a(&a, 0, m.min(1), 0, 0, &mut out);
        assert!(out.is_empty(), "{ctx}");
    }
}

#[test]
fn prop_family_fp16x2_bit_identical_to_cube_engine() {
    // Tentpole acceptance: the N = 2 FP16 instantiation of the
    // precision-emulation family reproduces the pre-refactor cube
    // engine bit for bit — across random shapes, both residual scales,
    // every schedule, and the generic `Family` prepacked path (whose
    // multi-component panels must lay out the same bytes the dual
    // format did).
    use sgemm_cube::gemm::blocked::{
        family_gemm_blocked, family_gemm_blocked_overlapped, family_gemm_blocked_overlapped_ab,
        gemm_prepacked_overlapped_ab,
    };
    use sgemm_cube::gemm::prepacked::{PrepackPath, PrepackedMatrix};
    use sgemm_cube::softfloat::family::SplitSpec;
    let bk = host_block().bk;
    property("family fp16x2 == cube, bitwise", 8, |g: &mut Gen| {
        let m = g.usize_in(1, 40);
        // Bias k across the b_k boundary so multi-block accumulation
        // and the prefetch ring both engage.
        let k = if g.bool() { g.usize_in(1, bk) } else { g.usize_in(bk + 1, 2 * bk + 5) };
        let n = g.usize_in(1, 64);
        let s_b = if g.bool() { 12 } else { 8 };
        let cfg = SplitConfig::with_scale(s_b);
        let spec = SplitSpec::fp16x2(cfg);
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let want = cube_gemm_blocked(&a, &b, cfg);
        let bitwise = |got: &Matrix<f32>, what: &str| -> Result<(), String> {
            for (u, v) in want.as_slice().iter().zip(got.as_slice()) {
                if u.to_bits() != v.to_bits() {
                    return Err(format!("{what} ({m},{k},{n}) s_b={s_b}: {u} vs {v}"));
                }
            }
            Ok(())
        };
        bitwise(&family_gemm_blocked(&a, &b, spec), "serial")?;
        bitwise(&family_gemm_blocked_overlapped(&a, &b, spec), "overlap-b")?;
        for depth in [1usize, 3] {
            bitwise(&family_gemm_blocked_overlapped_ab(&a, &b, spec, depth), "overlap-ab")?;
        }
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Family(spec));
        bitwise(&gemm_prepacked(&a, &pp), "prepacked(family)")?;
        bitwise(&gemm_prepacked_overlapped_ab(&a, &pp, 2), "prepacked(family) ab d2")?;
        Ok(())
    });
}

#[test]
fn prop_scheduler_tiles_partition_rows() {
    property("tiles partition 0..m", 500, |g: &mut Gen| {
        let m = g.usize_in(1, 5000);
        let bm = 16 * g.usize_in(1, 16);
        let tiles = tiles_of(m, bm);
        qc_assert!(tiles[0].row_start == 0);
        qc_assert!(tiles.last().unwrap().row_end == m);
        let mut covered = 0;
        for w in tiles.windows(2) {
            qc_assert!(w[0].row_end == w[1].row_start, "gap/overlap");
        }
        for t in &tiles {
            qc_assert!(t.rows() >= 1 && t.rows() <= bm);
            covered += t.rows();
        }
        qc_assert!(covered == m, "covered {covered} != {m}");
        Ok(())
    });
}

#[test]
fn prop_scheduler_assignment_complete_and_balanced() {
    property("assignment covers tiles, imbalance bounded", 300, |g: &mut Gen| {
        let m = g.usize_in(1, 4000);
        let bm = 16 * g.usize_in(1, 12);
        let workers = g.usize_in(1, 33);
        let key = ShapeKey { m, k: 64, n: 64 };
        let tiles = tiles_of(m, bm);
        let qs = assign(&tiles, key, workers);
        qc_assert!(qs.len() == workers);
        let assigned: usize = qs.iter().map(|q| q.iter().map(|t| t.rows()).sum::<usize>()).sum();
        qc_assert!(assigned == m, "assigned {assigned} != {m}");
        // LPT bound: max load <= mean + one largest tile.
        let imb = imbalance(&qs, key);
        let n_tiles = tiles.len();
        if n_tiles >= workers {
            qc_assert!(imb <= 1.0 + workers as f64, "imbalance {imb}");
        }
        Ok(())
    });
}

#[test]
fn prop_policy_scale_exp_within_eq6_window() {
    use sgemm_cube::coordinator::policy::PrecisionPolicy;
    use sgemm_cube::gemm::backend::Backend;
    property("policy s_b respects Eq. (6)", 300, |g: &mut Gen| {
        let e = g.i32_in(-24, 16);
        let mut rng = Rng::new(g.u64());
        let a = Matrix::from_fn(4, 4, |_, _| rng.f32_with_exponent(e.clamp(-24, 15)));
        let b = Matrix::from_fn(4, 4, |_, _| rng.f32_with_exponent(e.clamp(-24, 15)));
        let d = PrecisionPolicy::default().decide(&a, &b);
        if d.backend == Backend::Fp32 {
            return Ok(()); // out-of-range fallback
        }
        let (lo, hi) = (d.e_min.unwrap(), d.e_max.unwrap());
        qc_assert!(d.scale_exp >= 0, "negative s_b");
        // Tie-safe bound: one below Eq. (6)'s nominal 27 - e_max, so an
        // exact RN tie at e_max can never overflow the scaled residual.
        qc_assert!(d.scale_exp <= 26 - hi, "s_b {} above the tie-safe Eq.6 bound", d.scale_exp);
        // Lower bound only binds when achievable; default 12 otherwise.
        qc_assert!(d.scale_exp >= 12.min(-2 - lo).max(0) || d.scale_exp == 12, "s_b too small");
        Ok(())
    });
}
