//! Cross-module integration tests: the full stack from artifacts through
//! the runtime and coordinator, plus cross-layer consistency checks
//! (rust softfloat vs AOT Pallas numerics).

use std::time::Duration;

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::policy::PrecisionPolicy;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::gemm::backend::{Backend, GemmBackend};
#[cfg(feature = "pjrt")]
use sgemm_cube::gemm::cube::{cube_gemm, Accumulation};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
#[cfg(feature = "pjrt")]
use sgemm_cube::runtime::Engine;
#[cfg(feature = "pjrt")]
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

#[cfg(feature = "pjrt")]
fn artifacts_available() -> bool {
    Engine::default_dir().join("manifest.txt").exists()
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_cube_matches_native_cube_bitwise_error() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::from_default_dir().unwrap();
    let mut rng = Rng::new(11);
    let a = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let b = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let c_aot = engine.gemm("cube_gemm_128", &a, &b).unwrap();
    let c_native = cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise);
    let c_ref = dgemm_of_f32(&a, &b);
    let e_aot = relative_error(&c_ref, &c_aot.to_f64());
    let e_native = relative_error(&c_ref, &c_native.to_f64());
    // Same algorithm, same split: both near-fp32; each other within noise.
    assert!(e_aot < 5e-7, "aot err {e_aot}");
    assert!((e_aot - e_native).abs() / e_native < 0.5, "aot {e_aot} vs native {e_native}");
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_split_matches_rust_softfloat_bit_exact() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::from_default_dir().unwrap();
    let mut rng = Rng::new(13);
    let x = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let out = engine.run("split_128", &[&x]).unwrap();
    let native = sgemm_cube::softfloat::split::SplitMatrix::from_f32(&x, SplitConfig::default());
    for i in 0..128 {
        for j in 0..128 {
            assert_eq!(
                out[0].get(i, j).to_bits(),
                native.high.get(i, j).to_f32().to_bits(),
                "high mismatch at ({i},{j})"
            );
            assert_eq!(
                out[1].get(i, j).to_bits(),
                native.low.get(i, j).to_f32().to_bits(),
                "low mismatch at ({i},{j})"
            );
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_hgemm_matches_rust_hgemm_closely() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::from_default_dir().unwrap();
    let mut rng = Rng::new(17);
    let a = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let b = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let c_aot = engine.gemm("hgemm_128", &a, &b).unwrap();
    let c_native = sgemm_cube::gemm::hgemm::hgemm(&a, &b, sgemm_cube::gemm::hgemm::AccumulateMode::Fp32Rn);
    // Same fp16 inputs, fp32 accumulate; only summation order differs.
    let c_ref = dgemm_of_f32(&a, &b);
    let ea = relative_error(&c_ref, &c_aot.to_f64());
    let en = relative_error(&c_ref, &c_native.to_f64());
    assert!((ea / en) < 1.5 && (en / ea) < 1.5, "aot {ea} vs native {en}");
}

#[cfg(feature = "pjrt")]
#[test]
fn mlp_train_step_artifact_reduces_loss() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::from_default_dir().unwrap();
    let mut rng = Rng::new(19);
    let sizes = [64usize, 128, 128, 32];
    let mut params: Vec<Matrix<f32>> = Vec::new();
    for w in sizes.windows(2) {
        params.push(Matrix::random_normal(w[0], w[1], (2.0 / w[0] as f32).sqrt(), &mut rng));
        params.push(Matrix::zeros(1, w[1]));
    }
    let x = Matrix::random_normal(64, 64, 1.0, &mut rng);
    let teacher = Matrix::random_normal(64, 32, 0.3, &mut rng);
    let y = sgemm_cube::gemm::sgemm::sgemm(&x, &teacher);

    let mut losses = Vec::new();
    for _ in 0..5 {
        let mut inputs: Vec<&Matrix<f32>> = vec![&x, &y];
        inputs.extend(params.iter());
        let out = engine.run("mlp_train_step", &inputs).unwrap();
        losses.push(out[0].get(0, 0));
        params = out[1..].to_vec();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "AOT training must reduce loss: {losses:?}"
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn service_over_pjrt_consistency() {
    // The coordinator's native cube path and the AOT artifact agree on
    // the same inputs (both ~fp32 accurate).
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::from_default_dir().unwrap();
    let svc = GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 1,
        ..Default::default()
    });
    let mut rng = Rng::new(23);
    let a = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let b = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let served = svc.gemm_blocking(a.clone(), b.clone(), None).expect("submit").result.unwrap();
    let aot = engine.gemm("cube_gemm_128", &a, &b).unwrap();
    // Norm-relative comparison (elementwise ratios blow up on the
    // near-zero cancellation entries of a symmetric product).
    let diff = relative_error(&aot.to_f64(), &served.to_f64());
    assert!(diff < 1e-6, "served vs aot norm-rel diff {diff}");
    svc.shutdown();
}

#[cfg(feature = "pjrt")]
#[test]
fn engine_error_paths() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::from_default_dir().unwrap();
    // Unknown artifact name.
    let err = engine.spec("nonexistent").unwrap_err();
    assert!(format!("{err}").contains("unknown artifact"));
    // Wrong input arity.
    let m: Matrix<f32> = Matrix::zeros(64, 64);
    let err = engine.run("cube_gemm_64", &[&m]).unwrap_err();
    assert!(format!("{err}").contains("expects 2 inputs"));
    // Wrong input shape (element count mismatch).
    let bad: Matrix<f32> = Matrix::zeros(8, 8);
    let err = engine.run("cube_gemm_64", &[&bad, &m]).unwrap_err();
    assert!(format!("{err:#}").contains("input 0"));
    // Executable cache: second lookup is the same Arc.
    let e1 = engine.executable("cube_gemm_64").unwrap();
    let e2 = engine.executable("cube_gemm_64").unwrap();
    assert!(std::sync::Arc::ptr_eq(&e1, &e2));
}

#[test]
fn full_backend_accuracy_ladder_large() {
    // Integration-scale accuracy ladder at 192³ across every backend.
    let mut rng = Rng::new(29);
    let a = Matrix::random_symmetric(192, 192, 0, &mut rng);
    let b = Matrix::random_symmetric(192, 192, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let err = |bk: Backend| {
        relative_error(&c_ref, &GemmBackend::new(bk).gemm(&a, &b).to_f64())
    };
    let e16 = err(Backend::Fp16);
    let e32 = err(Backend::Fp32);
    let eel = err(Backend::CubeElementwise);
    let etw = err(Backend::CubeTermwise);
    assert!(e16 > 1e-5);
    assert!(etw < e16 / 100.0);
    assert!(eel < e16 / 100.0);
    assert!(etw < e32 * 10.0);
}

#[test]
fn quickcheck_service_responses_complete_and_match_ids() {
    // Property: every submitted id receives exactly one response with a
    // correct result, across random shapes/backends.
    use sgemm_cube::util::quickcheck::{property, Gen};
    let svc = GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 2,
        ..Default::default()
    });
    property("service responds to all ids", 30, |g: &mut Gen| {
        let m = 8 * g.usize_in(1, 4);
        let k = 8 * g.usize_in(1, 4);
        let n = 8 * g.usize_in(1, 4);
        let mut rng = Rng::new(g.u64());
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let backend = if g.bool() { None } else { Some(Backend::Fp32) };
        let (id, rx) = svc.submit(a, b, backend).map_err(|e| format!("submit: {e}"))?;
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .map_err(|e| format!("no response: {e}"))?;
        sgemm_cube::qc_assert!(resp.id == id, "id mismatch");
        sgemm_cube::qc_assert!(resp.result.is_ok(), "gemm failed");
        let c = resp.result.unwrap();
        sgemm_cube::qc_assert!(c.shape() == (m, n), "bad shape {:?}", c.shape());
        Ok(())
    });
    svc.shutdown();
}

#[test]
fn prepacked_serving_bit_matches_blocked_path_and_hits_cache() {
    // End-to-end register-weights-then-serve: repeated same-shape
    // requests against one registered weight must (a) bit-match the
    // unbatched blocked engine for the same scaling parameters, and
    // (b) be served from the prepack cache after the first request.
    use sgemm_cube::gemm::blocked::cube_gemm_blocked;
    use sgemm_cube::softfloat::split::SplitConfig;
    // One worker: batches drain sequentially, so the pack-exactly-once
    // assertion below is deterministic (two workers racing on a cold key
    // may legitimately both pack — see gemm::cache).
    let svc = GemmService::start(ServiceConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        policy: PrecisionPolicy::default(),
        n_workers: 1,
        ..Default::default()
    });
    let mut rng = Rng::new(31);
    let (m, kn) = (8usize, 96usize);
    let w = Matrix::random_symmetric(kn, kn, 0, &mut rng);
    let weights = svc.register_weights(w.clone());

    // Pipelined round: several in-flight requests sharing the weight
    // exercise the weight-keyed batcher, not just sequential hits.
    let activations: Vec<Matrix<f32>> =
        (0..6).map(|_| Matrix::random_symmetric(m, kn, 0, &mut rng)).collect();
    let rxs: Vec<_> = activations
        .iter()
        .map(|a| svc.submit_prepacked(a.clone(), weights, None).expect("submit"))
        .collect();
    for ((id, rx), a) in rxs.into_iter().zip(&activations) {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("response");
        assert_eq!(resp.id, id);
        assert_eq!(resp.backend, Backend::CubeTermwise);
        let c = resp.result.expect("request failed");
        let reference = cube_gemm_blocked(a, &w, SplitConfig::with_scale(resp.scale_exp));
        for (x, y) in c.as_slice().iter().zip(reference.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "served result differs from blocked path");
        }
    }

    let stats = svc.prepack_stats();
    assert_eq!(stats.misses, 1, "the weight is packed exactly once: {stats:?}");
    assert!(stats.hits >= 5, "later requests served from cache: {stats:?}");
    assert_eq!(stats.entries, 1);
    assert!(stats.bytes > 0);

    // The report still accounts every request.
    let report = svc.metrics().report();
    assert_eq!(report.requests, 6);
    assert_eq!(report.errors, 0);
    svc.shutdown();
}
