//! The GEMM service: submission API, dispatcher thread, worker pool.
//!
//! Architecture (std threads; the image has no tokio):
//!
//! ```text
//! clients --submit()--> dispatcher --(batch by shape / policy)--> workers
//!                                                              \--> reply channels
//! ```
//!
//! The dispatcher owns the [`Batcher`]; full or expired batches go to a
//! work queue consumed by `n_workers` threads. Each worker executes the
//! batch through the precision path chosen by the [`PrecisionPolicy`]
//! (or the request's explicit backend) on the native numerics engine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::PrecisionPolicy;
use crate::coordinator::request::{GemmRequest, GemmResponse};
use crate::gemm::backend::{Backend, GemmBackend};
use crate::util::mat::Matrix;

/// Service configuration.
#[derive(Debug, Clone, Default)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub policy: PrecisionPolicy,
    /// Worker threads (0 = available parallelism).
    pub n_workers: usize,
}

enum DispatchMsg {
    Request(GemmRequest),
    Shutdown,
}

/// Handle to a running GEMM service.
pub struct GemmService {
    tx: Sender<DispatchMsg>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl GemmService {
    /// Start the dispatcher and worker pool.
    pub fn start(cfg: ServiceConfig) -> GemmService {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = channel::<DispatchMsg>();
        let (work_tx, work_rx) = channel::<Vec<GemmRequest>>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let n_workers = if cfg.n_workers == 0 {
            crate::util::threads::num_threads()
        } else {
            cfg.n_workers
        };

        let mut workers = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let work_rx = work_rx.clone();
            let metrics = metrics.clone();
            let policy = cfg.policy.clone();
            workers.push(std::thread::spawn(move || worker_loop(work_rx, metrics, policy)));
        }

        let metrics_d = metrics.clone();
        let batcher_cfg = cfg.batcher.clone();
        let dispatcher = std::thread::spawn(move || {
            dispatcher_loop(rx, work_tx, batcher_cfg, metrics_d);
        });

        GemmService {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            dispatcher: Some(dispatcher),
            workers,
        }
    }

    /// Submit a GEMM; returns (request id, receiver for the response).
    pub fn submit(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
    ) -> (u64, Receiver<GemmResponse>) {
        assert_eq!(a.cols(), b.rows(), "inner dimensions must match");
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        let req = GemmRequest { id, a, b, backend, submitted: Instant::now(), reply };
        self.tx
            .send(DispatchMsg::Request(req))
            .expect("service dispatcher is gone");
        (id, rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn gemm_blocking(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
    ) -> GemmResponse {
        let (_, rx) = self.submit(a, b, backend);
        rx.recv().expect("worker dropped the reply channel")
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting work, drain, and join all threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for GemmService {
    fn drop(&mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    work_tx: Sender<Vec<GemmRequest>>,
    batcher_cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(batcher_cfg);
    loop {
        let timeout = batcher
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Request(req)) => {
                if let Some(batch) = batcher.push(req) {
                    metrics.record_batch();
                    if work_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Ok(DispatchMsg::Shutdown) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch();
                    let _ = work_tx.send(batch);
                }
                return; // dropping work_tx stops the workers
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.flush_expired(Instant::now()) {
                    metrics.record_batch();
                    if work_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch();
                    let _ = work_tx.send(batch);
                }
                return;
            }
        }
    }
}

fn worker_loop(
    work_rx: Arc<Mutex<Receiver<Vec<GemmRequest>>>>,
    metrics: Arc<Metrics>,
    policy: PrecisionPolicy,
) {
    loop {
        // Hold the lock only while receiving, not while computing.
        let batch = match work_rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return,
        };
        for req in batch {
            let decision = match req.backend {
                Some(b) => crate::coordinator::policy::PolicyDecision {
                    backend: b,
                    scale_exp: 12,
                    e_min: None,
                    e_max: None,
                },
                None => policy.decide(&req.a, &req.b),
            };
            let exec = GemmBackend::new(decision.backend).with_scale(decision.scale_exp);
            let shape = req.shape();
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                exec.gemm(&req.a, &req.b)
            }))
            .map_err(|_| "gemm panicked".to_string());
            let latency = req.submitted.elapsed().as_secs_f64();
            metrics.record_request(latency, shape.flops(), result.is_ok());
            let _ = req.reply.send(GemmResponse {
                id: req.id,
                result,
                backend: decision.backend,
                scale_exp: decision.scale_exp,
                latency,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            policy: PrecisionPolicy::default(),
            n_workers: 2,
        }
    }

    #[test]
    fn serves_one_request_accurately() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(1);
        let a = Matrix::random_symmetric(32, 48, 0, &mut rng);
        let b = Matrix::random_symmetric(48, 24, 0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone(), None);
        assert_eq!(resp.backend, Backend::CubeTermwise);
        assert_eq!(resp.scale_exp, 12);
        let c = resp.result.unwrap();
        let err = relative_error(&dgemm_of_f32(&a, &b), &c.to_f64());
        assert!(err < 1e-6, "err={err}");
        svc.shutdown();
    }

    #[test]
    fn serves_many_mixed_shapes() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(2);
        let mut rxs = Vec::new();
        for i in 0..20 {
            let (m, k, n) = if i % 2 == 0 { (16, 16, 16) } else { (24, 32, 8) };
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            rxs.push(svc.submit(a, b, None));
        }
        let mut ids = Vec::new();
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert!(resp.result.is_ok());
            ids.push(id);
        }
        assert_eq!(ids.len(), 20);
        let report = svc.metrics().report();
        assert_eq!(report.requests, 20);
        assert_eq!(report.errors, 0);
        assert!(report.batches >= 5, "batches={}", report.batches);
        svc.shutdown();
    }

    #[test]
    fn explicit_backend_is_honored() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(3);
        let a = Matrix::random_symmetric(16, 16, 0, &mut rng);
        let b = Matrix::random_symmetric(16, 16, 0, &mut rng);
        for bk in Backend::ALL {
            let resp = svc.gemm_blocking(a.clone(), b.clone(), Some(bk));
            assert_eq!(resp.backend, bk);
            assert!(resp.result.is_ok());
        }
        svc.shutdown();
    }

    #[test]
    fn out_of_range_inputs_route_to_fp32() {
        let svc = GemmService::start(small_cfg());
        let a = Matrix::from_fn(8, 8, |_, _| 1e6f32); // beyond fp16 max
        let b = Matrix::from_fn(8, 8, |_, _| 1.0f32);
        let resp = svc.gemm_blocking(a, b, None);
        assert_eq!(resp.backend, Backend::Fp32);
        let c = resp.result.unwrap();
        assert_eq!(c.get(0, 0), 8e6);
        svc.shutdown();
    }

    #[test]
    #[should_panic(expected = "inner dimensions")]
    fn dimension_mismatch_rejected_at_submit() {
        let svc = GemmService::start(small_cfg());
        let a: Matrix<f32> = Matrix::zeros(4, 5);
        let b: Matrix<f32> = Matrix::zeros(6, 4);
        let _ = svc.submit(a, b, None);
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(5);
        let a = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let b = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let _ = svc.gemm_blocking(a, b, None);
        drop(svc); // Drop impl must not hang
    }
}
