//! The GEMM service: submission API, weight registry, dispatcher
//! thread, pool-backed batch execution, prepacked-operand cache.
//!
//! Architecture (std threads; the image has no tokio):
//!
//! ```text
//! clients --register_weights()--> weight registry (Arc<WeightEntry>)
//!                                  \--> shard router ([shards] count >= 2)
//! clients --submit()---[admission]--> dispatcher --(batch by shape+weight)--> exec::pool tasks
//!                                                                          \--> reply channels
//!                                    batch tasks <--> prepack cache (LRU, Arc<PrepackedMatrix>)
//! ```
//!
//! The dispatcher (a dedicated control thread — it blocks on the
//! request channel, so it must not occupy a pool worker) owns the
//! [`Batcher`]; full or expired batches are submitted as **detached
//! jobs on the executor pool** ([`crate::exec::pool`]) — the same
//! persistent worker population that runs the blocked sweeps and the
//! pipeline prefetch, so concurrent serving load shares one thread set
//! instead of oversubscribing the host with per-service workers. A
//! counting gate bounds the batches in flight to `n_workers`
//! (back-pressure: the dispatcher stops draining submissions while the
//! pool is that far behind, so batches keep growing instead of
//! queueing). Each batch task executes its requests through the
//! precision path chosen by the [`PrecisionPolicy`] (or the request's
//! explicit backend) on the native numerics engine, under a per-path
//! host schedule: [`ServiceConfig::schedule`] for raw operands,
//! [`ServiceConfig::schedule_prepacked`] for registered weights.
//! Requests against a registered weight are served from the prepacked
//! cache: the weight's FP32→2×FP16 split and panel packing are done at
//! most once per `(weight, path, s_b)`, and under the overlapped
//! prepacked schedules the per-request A stripe is prefetched through
//! the pipeline ring too, so batch tasks run kernel-only sweeps with
//! zero pack work on the critical path ([`crate::gemm::prepacked`],
//! [`crate::gemm::blocked::gemm_prepacked_scheduled`]).
//!
//! **Resilience.** The front door is hardened end to end: bounded
//! admission sheds submissions past [`ServiceConfig::max_pending`] with
//! a typed [`GemmError::Overloaded`] instead of queueing without bound;
//! every request carries an optional absolute deadline
//! ([`ServiceConfig::request_timeout`]) that both the batch workers
//! (server-side shed) and the blocking waiters honor — no `.expect` on
//! a reply channel anywhere, a dead worker or a shut-down dispatcher is
//! [`GemmError::ChannelClosed`]; and the blocking entry points retry
//! transient failures ([`GemmError::is_retryable`]) up to
//! [`ServiceConfig::retries`] times with doubling backoff. The
//! deadline is **one end-to-end budget**: a blocking call computes its
//! absolute deadline once, every retry attempt (resubmission and
//! reply wait alike) gets only the remaining slice, backoff sleeps
//! never cross it, and [`GemmError::Timeout::after`] reports the true
//! elapsed wall time — R retries can never stretch the caller past
//! the configured budget. Weights
//! registered while `[shards] count >= 2` are column-partitioned across
//! an in-process shard router with per-shard health and failover
//! ([`crate::coordinator::shard`]) — responses stay bit-identical to
//! single-node serving. Fault injection for all of it lives in
//! [`crate::exec::faults`].
//!
//! By default batches run on the process-global pool; setting
//! [`ServiceConfig::pool_threads`] gives the service a dedicated pool
//! of that size (isolation for tests and co-tenant deployments). The
//! sweeps inside a batch always use the global pool via
//! `parallel_chunks`, with the batch task's thread participating.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{matrix_exponent_range, PolicyDecision, PrecisionPolicy};
use crate::coordinator::request::{BOperand, GemmRequest, GemmResponse, WeightEntry, WeightId};
use crate::coordinator::shard::{ShardConfig, ShardRouter};
use crate::exec::pipeline::DEFAULT_PIPELINE_DEPTH;
use crate::exec::pool::{self, Pool};
use crate::gemm::backend::{default_schedule, Backend, GemmBackend, Schedule};
use crate::gemm::cache::{CacheStats, PrepackCache, PrepackKey};
use crate::gemm::error::GemmError;
use crate::gemm::prepacked::PrepackedMatrix;
use crate::util::mat::Matrix;

/// Default prepack-cache capacity: enough for a few dozen transformer-
/// block-sized FP16/cube weights without threatening a serving host's
/// memory budget.
pub const DEFAULT_PREPACK_CAPACITY: usize = 256 << 20;

/// Default in-flight batch bound: one per available core
/// (`std::thread::available_parallelism`), honoring the operator's
/// `SGEMM_CUBE_THREADS` override, clamped to at least one.
pub fn default_workers() -> usize {
    crate::util::threads::num_threads().max(1)
}

/// Default blocking-entry retry budget for transient failures.
pub const DEFAULT_RETRIES: usize = 2;

/// Default base backoff before the first retry (doubled per attempt).
pub const DEFAULT_RETRY_BACKOFF: Duration = Duration::from_micros(200);

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Dynamic-batching knobs.
    pub batcher: BatcherConfig,
    /// Precision-path selection policy.
    pub policy: PrecisionPolicy,
    /// Maximum batches concurrently in flight on the pool
    /// (0 = available parallelism, same as the default).
    pub n_workers: usize,
    /// Prepacked-operand cache capacity in bytes. `0` disables the
    /// cache entirely (miss-through — every request repacks).
    pub prepack_capacity: usize,
    /// Host schedule for inline (non-prepacked) requests: serial /
    /// overlapped-B / overlapped-AB — bit-identical results; defaults
    /// to the `SGEMM_CUBE_SCHEDULE` / `SGEMM_CUBE_OVERLAP` env knobs,
    /// and the config file's `[server] schedule` / `[server] overlap`
    /// keys override.
    pub schedule: Schedule,
    /// Host schedule for requests against **registered weights**
    /// (prepacked B). With the weight's panels cached, the only operand
    /// movement left per request is the A row-block stripe, which the
    /// overlapped schedules route through the A-stripe prefetch ring so
    /// batch tasks run kernel-only sweeps
    /// ([`crate::gemm::blocked::gemm_prepacked_scheduled`]).
    /// Bit-identical to `serial` either way. **Defaults to
    /// [`Schedule::OverlapAB`]** — on the serving shape (cached B
    /// panels, small activations) the A-stripe prefetch ring is the
    /// measured win with no numerics cost, so it is on out of the box.
    /// The `[server] schedule` key sets both paths and
    /// `[server] schedule_prepacked` overrides this one; inline
    /// requests ([`ServiceConfig::schedule`]) keep the env-derived
    /// default.
    pub schedule_prepacked: Schedule,
    /// Prefetch-ring depth for [`Schedule::OverlapAB`]
    /// (`[server] pipeline_depth`; depth 2 = classic double buffer).
    pub pipeline_depth: usize,
    /// `0` (default): batches run on the shared global executor pool.
    /// `> 0`: the service owns a dedicated pool of that many workers
    /// (`[server] pool_threads`).
    pub pool_threads: usize,
    /// Per-request deadline (`[server] request_timeout_ms`; `None` =
    /// wait forever, the default). A request past its deadline is shed
    /// by the batch worker with [`GemmError::Timeout`] before any
    /// kernel work, and the blocking entry points bound the caller's
    /// **total** wall time — retries, backoff and reply waits all draw
    /// from this one budget.
    pub request_timeout: Option<Duration>,
    /// Admission bound: requests queued or executing at once
    /// (`[server] max_pending`; `0` = unbounded, the default). A
    /// submission over the bound is shed immediately with
    /// [`GemmError::Overloaded`] — load-shedding at the front door
    /// instead of unbounded queue growth.
    pub max_pending: usize,
    /// Retry budget of the blocking entry points for transient
    /// failures — [`GemmError::is_retryable`] — (`[server] retries`).
    pub retries: usize,
    /// Base backoff before the first retry, doubled per attempt
    /// (`[server] retry_backoff_ms`).
    pub retry_backoff: Duration,
    /// Column-shard router configuration (`[shards]` section);
    /// `count < 2` (the default) serves every weight single-node.
    pub shards: ShardConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batcher: BatcherConfig::default(),
            policy: PrecisionPolicy::default(),
            n_workers: default_workers(),
            prepack_capacity: DEFAULT_PREPACK_CAPACITY,
            schedule: default_schedule(),
            schedule_prepacked: Schedule::OverlapAB,
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
            pool_threads: 0,
            request_timeout: None,
            max_pending: 0,
            retries: DEFAULT_RETRIES,
            retry_backoff: DEFAULT_RETRY_BACKOFF,
            shards: ShardConfig::default(),
        }
    }
}

/// Per-request options for the blocking entry points — the knobs a
/// caller (notably the wire front door, which maps its `X-Backend` /
/// `X-Precision` / `X-Timeout-Ms` headers here) can set without a
/// dedicated method per combination. `Default` leaves every decision
/// to the service configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestOpts {
    /// Fixed precision path; `None` lets the policy decide (see
    /// [`GemmService::submit`]).
    pub backend: Option<Backend>,
    /// Relative-error budget for tier selection; overrides the
    /// service-wide setting (see
    /// [`GemmService::submit_with_precision`]). Ignored when
    /// [`RequestOpts::backend`] is fixed.
    pub precision: Option<f64>,
    /// End-to-end wall-time budget for this request, overriding
    /// [`ServiceConfig::request_timeout`]; `None` keeps the service
    /// default. The budget covers submission, every retry, backoff and
    /// the reply wait together.
    pub timeout: Option<Duration>,
}

enum DispatchMsg {
    Request(GemmRequest),
    Shutdown,
}

/// Which pool the service schedules batch tasks on.
#[derive(Clone)]
enum ServicePool {
    /// The process-wide executor pool ([`pool::global`]).
    Global,
    /// A pool owned by (and dropped with) this service.
    Owned(Arc<Pool>),
}

impl ServicePool {
    fn pool(&self) -> &Pool {
        match self {
            ServicePool::Global => pool::global(),
            ServicePool::Owned(p) => p.as_ref(),
        }
    }
}

/// Counting gate bounding the batches in flight; `wait_idle` is the
/// drain barrier `shutdown` uses in place of per-worker joins.
struct Gate {
    count: Mutex<usize>,
    changed: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate { count: Mutex::new(0), changed: Condvar::new() }
    }

    fn acquire(&self, max: usize) {
        let mut c = self.count.lock().unwrap();
        while *c >= max.max(1) {
            c = self.changed.wait(c).unwrap();
        }
        *c += 1;
    }

    fn release(&self) {
        let mut c = self.count.lock().unwrap();
        *c -= 1;
        self.changed.notify_all();
    }

    fn wait_idle(&self) {
        let mut c = self.count.lock().unwrap();
        while *c > 0 {
            c = self.changed.wait(c).unwrap();
        }
    }
}

/// Releases the gate when a batch task finishes — including by panic
/// (the pool contains detached panics, but the slot must still free).
struct GateRelease<'a>(&'a Gate);

impl Drop for GateRelease<'_> {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// Everything a batch task needs, shared once per service.
struct BatchCtx {
    metrics: Arc<Metrics>,
    policy: PrecisionPolicy,
    cache: Arc<PrepackCache>,
    schedule: Schedule,
    schedule_prepacked: Schedule,
    pipeline_depth: usize,
    gate: Gate,
    /// Requests admitted but not yet replied to (admission control).
    pending: AtomicUsize,
    /// Shard routers by weight id — populated at registration when
    /// `[shards] count >= 2`, consulted by batch tasks per request.
    shard_routers: Mutex<HashMap<u64, Arc<ShardRouter>>>,
}

/// Handle to a running GEMM service.
pub struct GemmService {
    tx: Sender<DispatchMsg>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    weights: Mutex<HashMap<WeightId, Arc<WeightEntry>>>,
    next_weight: AtomicU64,
    prepack: Arc<PrepackCache>,
    ctx: Arc<BatchCtx>,
    pool: ServicePool,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
    request_timeout: Option<Duration>,
    max_pending: usize,
    retries: usize,
    retry_backoff: Duration,
    shards: ShardConfig,
}

impl GemmService {
    /// Start the dispatcher and wire batch execution onto the pool.
    pub fn start(cfg: ServiceConfig) -> GemmService {
        let metrics = Arc::new(Metrics::new());
        let prepack = Arc::new(PrepackCache::new(cfg.prepack_capacity));
        let (tx, rx) = channel::<DispatchMsg>();
        let pool = if cfg.pool_threads == 0 {
            ServicePool::Global
        } else {
            ServicePool::Owned(Arc::new(Pool::new(cfg.pool_threads)))
        };
        let max_in_flight = if cfg.n_workers == 0 { default_workers() } else { cfg.n_workers };
        let ctx = Arc::new(BatchCtx {
            metrics: Arc::clone(&metrics),
            policy: cfg.policy.clone(),
            cache: Arc::clone(&prepack),
            schedule: cfg.schedule,
            schedule_prepacked: cfg.schedule_prepacked,
            pipeline_depth: cfg.pipeline_depth,
            gate: Gate::new(),
            pending: AtomicUsize::new(0),
            shard_routers: Mutex::new(HashMap::new()),
        });
        let batcher_cfg = cfg.batcher.clone();
        let ctx_d = Arc::clone(&ctx);
        let pool_d = pool.clone();
        let dispatcher = pool::spawn_named("gemm-dispatcher", move || {
            dispatcher_loop(&rx, batcher_cfg, &ctx_d, &pool_d, max_in_flight);
        });

        GemmService {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            weights: Mutex::new(HashMap::new()),
            next_weight: AtomicU64::new(1),
            prepack,
            ctx,
            pool,
            dispatcher: Mutex::new(Some(dispatcher)),
            request_timeout: cfg.request_timeout,
            max_pending: cfg.max_pending,
            retries: cfg.retries,
            retry_backoff: cfg.retry_backoff,
            shards: cfg.shards,
        }
    }

    /// The executor pool this service schedules batch tasks on (the
    /// global pool unless [`ServiceConfig::pool_threads`] carved out a
    /// dedicated one).
    pub fn pool(&self) -> &Pool {
        self.pool.pool()
    }

    /// Register a cache-stable B operand (a weight matrix). Its exponent
    /// range is computed now, once; its packed/split representation is
    /// built lazily on first use per precision path and then served from
    /// the prepack cache. With `[shards] count >= 2` the weight is also
    /// column-partitioned across the in-process shard router
    /// ([`crate::coordinator::shard`]) — same wire behaviour,
    /// bit-identical responses, per-shard health and failover. Returns
    /// the handle to pass to [`GemmService::submit_prepacked`].
    pub fn register_weights(&self, b: Matrix<f32>) -> WeightId {
        let id = WeightId(self.next_weight.fetch_add(1, Ordering::Relaxed));
        let (e_min, e_max) = matrix_exponent_range(&b);
        if self.shards.count >= 2 && b.cols() >= 2 {
            let router = Arc::new(ShardRouter::new(
                id.0,
                &b,
                self.shards.clone(),
                Arc::clone(&self.prepack),
                Arc::clone(&self.metrics),
            ));
            self.ctx.shard_routers.lock().unwrap().insert(id.0, router);
        }
        let entry = Arc::new(WeightEntry { id, matrix: b, e_min, e_max });
        self.weights.lock().unwrap().insert(id, entry);
        id
    }

    /// The registered weight entry behind `id`, if any.
    pub fn weight(&self, id: WeightId) -> Option<Arc<WeightEntry>> {
        self.weights.lock().unwrap().get(&id).cloned()
    }

    /// The shard router serving `id`, if the weight was registered
    /// under `[shards] count >= 2` (health inspection, chaos `kill`).
    pub fn shard_router(&self, id: WeightId) -> Option<Arc<ShardRouter>> {
        self.ctx.shard_routers.lock().unwrap().get(&id.0).cloned()
    }

    /// Drop a registered weight and purge its prepacked panels from the
    /// cache (weight ids are never reused, so stale entries could only
    /// waste capacity). Any shard router goes with it.
    pub fn unregister_weights(&self, id: WeightId) -> bool {
        let removed = self.weights.lock().unwrap().remove(&id).is_some();
        if removed {
            self.ctx.shard_routers.lock().unwrap().remove(&id.0);
            self.prepack.purge_weight(id.0);
        }
        removed
    }

    /// The deadline a fresh, standalone submission carries: the
    /// service-wide timeout measured from now. The blocking entry
    /// points do NOT use this per attempt — they compute one absolute
    /// deadline up front and pass the same instant to every retry.
    fn default_deadline(&self) -> Option<Instant> {
        self.request_timeout.map(|t| Instant::now() + t)
    }

    fn submit_operand(
        &self,
        a: Matrix<f32>,
        b: BOperand,
        backend: Option<Backend>,
        precision: Option<f64>,
        deadline: Option<Instant>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        // Validate here, in the caller's thread, so a malformed request
        // is a typed error instead of a panic inside a batch task. The
        // kernels keep their asserts as last-resort invariants.
        check_shapes(&a, b.matrix())?;
        // Admission: count this request in, shed if that overflows the
        // bound. The counter drops when the batch worker replies.
        let pending = self.ctx.pending.fetch_add(1, Ordering::SeqCst) + 1;
        if self.max_pending > 0 && pending > self.max_pending {
            self.ctx.pending.fetch_sub(1, Ordering::SeqCst);
            self.metrics.record_shed();
            return Err(GemmError::Overloaded { in_flight: pending, limit: self.max_pending });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        let req =
            GemmRequest { id, a, b, backend, precision, submitted: Instant::now(), deadline, reply };
        if self.tx.send(DispatchMsg::Request(req)).is_err() {
            // The dispatcher is gone (shutdown raced or completed):
            // typed error, not a panic in the caller's thread.
            self.ctx.pending.fetch_sub(1, Ordering::SeqCst);
            return Err(GemmError::ChannelClosed);
        }
        Ok((id, rx))
    }

    /// Submit a GEMM; returns (request id, receiver for the response).
    /// Typed submit-time failures: [`GemmError::ShapeMismatch`] for
    /// incompatible operands, [`GemmError::Overloaded`] when admission
    /// control sheds, [`GemmError::ChannelClosed`] after shutdown.
    pub fn submit(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        self.submit_operand(a, BOperand::Inline(b), backend, None, self.default_deadline())
    }

    /// [`GemmService::submit`] with a per-request relative-error budget
    /// (the `precision` knob): the policy picks the cheapest
    /// precision-emulation tier meeting it — one-pass FP16 for loose
    /// budgets, the FP16×2 cube in the middle, the six-pass BF16×3
    /// cascade for budgets tighter than the cube's ~22 bits, and the
    /// full-range BF16 tiers instead of FP32 for out-of-window operands.
    /// Overrides the service-wide `[server] precision` setting for this
    /// request; ignored if `backend` is fixed.
    pub fn submit_with_precision(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
        precision: Option<f64>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        self.submit_operand(a, BOperand::Inline(b), backend, precision, self.default_deadline())
    }

    /// Submit a GEMM against a registered weight: batched with other
    /// requests on the same weight and served from its prepacked panels.
    ///
    /// Returns [`GemmError::UnknownWeight`] if `id` was never registered
    /// (or was unregistered), plus the same submit-time failures as
    /// [`GemmService::submit`].
    pub fn submit_prepacked(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        backend: Option<Backend>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        self.submit_prepacked_with_precision(a, id, backend, None)
    }

    /// [`GemmService::submit_prepacked`] with a per-request error budget
    /// (see [`GemmService::submit_with_precision`]). The weight's
    /// exponent range was recorded at registration, so tier selection
    /// costs only the A scan; each tier packs the weight once and serves
    /// it from the prepack cache under its own key.
    pub fn submit_prepacked_with_precision(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        backend: Option<Backend>,
        precision: Option<f64>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        let entry = self.weight(id).ok_or(GemmError::UnknownWeight(id.0))?;
        self.submit_operand(a, BOperand::Weight(entry), backend, precision, self.default_deadline())
    }

    /// Blocking convenience: submit and wait, bounded end to end by
    /// [`ServiceConfig::request_timeout`] and retried (submit included)
    /// up to [`ServiceConfig::retries`] times on transient failures —
    /// all attempts share the one wall-time budget. Submit-time
    /// failures surface as the outer error; execution failures stay in
    /// [`GemmResponse::result`].
    pub fn gemm_blocking(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
    ) -> Result<GemmResponse, GemmError> {
        self.gemm_blocking_opts(a, b, RequestOpts { backend, ..Default::default() })
    }

    /// Blocking convenience for [`GemmService::submit_with_precision`];
    /// same deadline and retry behaviour as [`GemmService::gemm_blocking`].
    pub fn gemm_blocking_with_precision(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
        precision: Option<f64>,
    ) -> Result<GemmResponse, GemmError> {
        self.gemm_blocking_opts(a, b, RequestOpts { backend, precision, timeout: None })
    }

    /// Blocking inline-operand entry with the full per-request knob set
    /// ([`RequestOpts`]): backend, precision budget, and an end-to-end
    /// timeout override. One wall-time budget covers every retry.
    pub fn gemm_blocking_opts(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        opts: RequestOpts,
    ) -> Result<GemmResponse, GemmError> {
        self.blocking_with_retry(opts.timeout, |deadline| {
            self.submit_operand(
                a.clone(),
                BOperand::Inline(b.clone()),
                opts.backend,
                opts.precision,
                deadline,
            )
        })
    }

    /// Blocking convenience for
    /// [`GemmService::submit_prepacked_with_precision`].
    pub fn gemm_blocking_prepacked_with_precision(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        backend: Option<Backend>,
        precision: Option<f64>,
    ) -> Result<GemmResponse, GemmError> {
        self.gemm_blocking_prepacked_opts(a, id, RequestOpts { backend, precision, timeout: None })
    }

    /// Blocking convenience for the register-weights-then-serve flow;
    /// same deadline and retry behaviour as [`GemmService::gemm_blocking`].
    pub fn gemm_blocking_prepacked(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        backend: Option<Backend>,
    ) -> Result<GemmResponse, GemmError> {
        self.gemm_blocking_prepacked_opts(a, id, RequestOpts { backend, ..Default::default() })
    }

    /// Blocking registered-weight entry with the full per-request knob
    /// set ([`RequestOpts`]); the weight lookup is inside the retry
    /// loop, so a weight unregistered mid-retry is a typed
    /// [`GemmError::UnknownWeight`], not a stale serve.
    pub fn gemm_blocking_prepacked_opts(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        opts: RequestOpts,
    ) -> Result<GemmResponse, GemmError> {
        self.blocking_with_retry(opts.timeout, |deadline| {
            let entry = self.weight(id).ok_or(GemmError::UnknownWeight(id.0))?;
            self.submit_operand(
                a.clone(),
                BOperand::Weight(entry),
                opts.backend,
                opts.precision,
                deadline,
            )
        })
    }

    /// Submit-and-wait with bounded retry: transient failures
    /// ([`GemmError::is_retryable`] — a panicked batch, a dropped reply
    /// channel, an injected fault) are resubmitted with doubling
    /// backoff; everything else (including deterministic rejections and
    /// back-pressure) returns on the first attempt.
    ///
    /// **One budget end to end.** The absolute deadline is computed
    /// exactly once, up front, from `timeout` (falling back to
    /// [`ServiceConfig::request_timeout`]); every resubmission carries
    /// that same instant (so server-side shed stays honest across
    /// retries), every reply wait gets only the remaining slice, and a
    /// backoff that would sleep past the deadline becomes an immediate
    /// [`GemmError::Timeout`] instead. An earlier revision re-armed the
    /// full timeout per attempt, letting R retries block the caller for
    /// (R+1)× the configured budget.
    fn blocking_with_retry(
        &self,
        timeout: Option<Duration>,
        submit: impl Fn(Option<Instant>) -> Result<(u64, Receiver<GemmResponse>), GemmError>,
    ) -> Result<GemmResponse, GemmError> {
        let start = Instant::now();
        let deadline = timeout.or(self.request_timeout).map(|t| start + t);
        let mut attempt = 0usize;
        loop {
            let outcome =
                submit(deadline).and_then(|(_, rx)| self.wait_reply_until(&rx, start, deadline));
            let retryable = match &outcome {
                Ok(resp) => resp.result.as_ref().err().is_some_and(|e| e.is_retryable()),
                Err(e) => e.is_retryable(),
            };
            if !retryable || attempt >= self.retries {
                return outcome;
            }
            attempt += 1;
            self.metrics.record_retry();
            let shift = u32::try_from((attempt - 1).min(10)).unwrap_or(10);
            let backoff = self.retry_backoff.saturating_mul(1u32 << shift);
            if let Some(dl) = deadline {
                // Sleeping through the deadline cannot help: the
                // resubmitted attempt would be shed on arrival. Give
                // the caller the truthful timeout now.
                if Instant::now() + backoff >= dl {
                    self.metrics.record_timeout();
                    return Err(GemmError::Timeout { after: start.elapsed() });
                }
            }
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
        }
    }

    /// Wait for one reply, bounded by the remaining slice of the
    /// request's end-to-end budget. A dropped channel (shutdown, or a
    /// batch worker dying without replying) is
    /// [`GemmError::ChannelClosed`]; a deadline expiry is
    /// [`GemmError::Timeout`] carrying the **true elapsed wall time
    /// since `start`** (not the configured duration) and counts toward
    /// the timeout metric.
    fn wait_reply_until(
        &self,
        rx: &Receiver<GemmResponse>,
        start: Instant,
        deadline: Option<Instant>,
    ) -> Result<GemmResponse, GemmError> {
        match deadline {
            None => rx.recv().map_err(|_| GemmError::ChannelClosed),
            Some(dl) => {
                let remaining = dl.saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(resp) => Ok(resp),
                    Err(RecvTimeoutError::Timeout) => {
                        self.metrics.record_timeout();
                        Err(GemmError::Timeout { after: start.elapsed() })
                    }
                    Err(RecvTimeoutError::Disconnected) => Err(GemmError::ChannelClosed),
                }
            }
        }
    }

    /// The service's live metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Counters of the prepacked-operand cache (hits appear from the
    /// second request against a weight on a given precision path).
    pub fn prepack_stats(&self) -> CacheStats {
        self.prepack.stats()
    }

    /// Stop accepting work, drain, and join the dispatcher; waits until
    /// every in-flight batch task released the gate. Idempotent, and
    /// callable through a shared reference — submissions racing (or
    /// following) shutdown get [`GemmError::ChannelClosed`], they never
    /// panic the submitting thread.
    pub fn shutdown(&self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.lock().unwrap().take() {
            let _ = d.join();
        }
        self.ctx.gate.wait_idle();
    }
}

impl Drop for GemmService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn dispatcher_loop(
    rx: &Receiver<DispatchMsg>,
    batcher_cfg: BatcherConfig,
    ctx: &Arc<BatchCtx>,
    pool: &ServicePool,
    max_in_flight: usize,
) {
    let mut batcher = Batcher::new(batcher_cfg);
    loop {
        let timeout = batcher
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Request(req)) => {
                if let Some(batch) = batcher.push(req) {
                    dispatch_batch(batch, ctx, pool, max_in_flight);
                }
            }
            Ok(DispatchMsg::Shutdown) => {
                for batch in batcher.flush_all() {
                    dispatch_batch(batch, ctx, pool, max_in_flight);
                }
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.flush_expired(Instant::now()) {
                    dispatch_batch(batch, ctx, pool, max_in_flight);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.flush_all() {
                    dispatch_batch(batch, ctx, pool, max_in_flight);
                }
                return;
            }
        }
    }
}

/// Submit one batch as a detached pool task, blocking first on the
/// in-flight gate (back-pressure toward the batcher).
fn dispatch_batch(
    batch: Vec<GemmRequest>,
    ctx: &Arc<BatchCtx>,
    pool: &ServicePool,
    max_in_flight: usize,
) {
    ctx.metrics.record_batch();
    ctx.gate.acquire(max_in_flight);
    let ctx = Arc::clone(ctx);
    pool.pool().submit(move || {
        let _release = GateRelease(&ctx.gate);
        execute_batch(batch, &ctx);
    });
}

fn execute_batch(batch: Vec<GemmRequest>, ctx: &BatchCtx) {
    for req in batch {
        let decision = match req.backend {
            Some(b) => PolicyDecision { backend: b, scale_exp: 12, e_min: None, e_max: None },
            // Registered weights carry their exponent range from
            // registration time; only A is scanned per request. The
            // request's precision knob, when set, overrides the
            // service-wide error budget for tier selection.
            None => {
                let policy = match req.precision {
                    Some(budget) => {
                        PrecisionPolicy { error_budget: Some(budget), ..ctx.policy.clone() }
                    }
                    None => ctx.policy.clone(),
                };
                match req.b.weight() {
                    Some(w) => {
                        policy.decide_ranges(matrix_exponent_range(&req.a), (w.e_min, w.e_max))
                    }
                    None => policy.decide(&req.a, req.b.matrix()),
                }
            }
        };
        let shape = req.shape();
        // A request past its deadline is shed before any kernel work —
        // the client stopped waiting, so the cycles would be wasted.
        let expired = req.deadline.is_some_and(|dl| Instant::now() >= dl);
        // Revalidate before executing: submission already checked, but
        // a batch task must never be one bad request away from a panic
        // — the kernels' asserts stay as last-resort invariants behind
        // this check and the catch_unwind.
        let result = if expired {
            Err(GemmError::Timeout { after: req.submitted.elapsed() })
        } else {
            match check_shapes(&req.a, req.b.matrix()) {
                Err(e) => Err(e),
                Ok(()) => {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        execute_request(&req, &decision, ctx)
                    })) {
                        Ok(r) => r,
                        Err(p) => Err(GemmError::Panicked(panic_message(p))),
                    }
                }
            }
        };
        if matches!(result, Err(GemmError::Timeout { .. })) {
            // Server-side expiries (shed above, or a shard fan-out that
            // ran out of deadline) all count here; client-side waiter
            // expiries are counted by `wait_reply`.
            ctx.metrics.record_timeout();
        }
        let latency = req.submitted.elapsed().as_secs_f64();
        ctx.metrics.record_request(latency, shape.flops(), result.is_ok());
        let _ = req.reply.send(GemmResponse {
            id: req.id,
            result,
            backend: decision.backend,
            scale_exp: decision.scale_exp,
            latency,
        });
        ctx.pending.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Shape compatibility of a request's operands, as a typed error.
fn check_shapes(a: &Matrix<f32>, b: &Matrix<f32>) -> Result<(), GemmError> {
    let (m, k_a) = a.shape();
    let (k_b, n) = b.shape();
    if k_a != k_b {
        return Err(GemmError::ShapeMismatch { m, k_a, k_b, n });
    }
    Ok(())
}

/// Best-effort text of a caught panic payload.
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one request through one code path: a [`GemmBackend`] built
/// from the decision, dispatching prepacked and raw operands alike.
/// Registered weights go through the prepack cache and the prepacked
/// entry points under [`BatchCtx::schedule_prepacked`] — or through the
/// weight's shard router when one was built at registration; both are
/// bit-identical to the inline path for the same decision, since all
/// of them run the same sweeps over equal panel bytes
/// ([`crate::gemm::blocked::gemm_prepacked_scheduled`],
/// [`crate::coordinator::shard`]).
fn execute_request(
    req: &GemmRequest,
    decision: &PolicyDecision,
    ctx: &BatchCtx,
) -> Result<Matrix<f32>, GemmError> {
    crate::exec::faults::check("coordinator.batch.exec")?;
    let engine = GemmBackend::new(decision.backend)
        .with_scale(decision.scale_exp)
        .with_pipeline_depth(ctx.pipeline_depth);
    if let (Some(w), Some(path)) = (req.b.weight(), decision.prepack_path()) {
        // Normalize the key the way the panels are shared: both cube
        // orders execute the same fused kernel, and non-cube paths
        // ignore the scaling exponent entirely.
        let (backend, scale_exp) = match decision.backend {
            Backend::Fp32 => (Backend::Fp32, 0),
            Backend::Fp16 => (Backend::Fp16, 0),
            Backend::CubeElementwise | Backend::CubeTermwise => {
                (Backend::CubeTermwise, decision.scale_exp)
            }
            // The family tiers pack under their own spec; no scaling.
            Backend::Bf16x2 => (Backend::Bf16x2, 0),
            Backend::Bf16x3 => (Backend::Bf16x3, 0),
        };
        let router = ctx.shard_routers.lock().unwrap().get(&w.id.0).cloned();
        if let Some(router) = router {
            return router.gemm(
                &req.a,
                backend,
                scale_exp,
                path,
                ctx.schedule_prepacked,
                ctx.pipeline_depth,
                req.deadline,
            );
        }
        let key = PrepackKey {
            weight: w.id.0,
            k: w.matrix.rows(),
            n: w.matrix.cols(),
            backend,
            scale_exp,
            lane: crate::gemm::kernels::active_lane(),
            col0: 0,
        };
        let packed = ctx
            .cache
            .get_or_insert_with(key, || PrepackedMatrix::prepack(&w.matrix, path));
        // `packed` (an Arc) is held across the whole execution below:
        // cache eviction or a weight purge racing this batch can drop
        // the cache's own reference, but the panels the A-stripe
        // prefetch ring has claimed stay alive until the ring is
        // drained and this call returns (see gemm::cache module docs).
        return Ok(engine
            .with_schedule(ctx.schedule_prepacked)
            .gemm_prepacked(&req.a, &packed));
    }
    Ok(engine.with_schedule(ctx.schedule).gemm(&req.a, req.b.matrix()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            policy: PrecisionPolicy::default(),
            n_workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn default_workers_track_available_parallelism() {
        let d = ServiceConfig::default();
        assert!(d.n_workers >= 1, "clamped to at least one in-flight batch");
        // One per core (or the operator's SGEMM_CUBE_THREADS override —
        // num_threads() resolves both).
        assert_eq!(d.n_workers, crate::util::threads::num_threads().max(1));
        assert!(d.prepack_capacity > 0);
        assert_eq!(d.pool_threads, 0, "default: shared global pool");
        assert_eq!(d.pipeline_depth, DEFAULT_PIPELINE_DEPTH);
        // Inline requests follow the env-derived schedule; the
        // prepacked path defaults to the A-stripe prefetch ring.
        assert_eq!(d.schedule, default_schedule());
        assert_eq!(d.schedule_prepacked, Schedule::OverlapAB);
        // Resilience knobs: opt-in deadlines/admission/sharding, a small
        // default retry budget for transient failures.
        assert_eq!(d.request_timeout, None);
        assert_eq!(d.max_pending, 0);
        assert_eq!(d.retries, DEFAULT_RETRIES);
        assert_eq!(d.retry_backoff, DEFAULT_RETRY_BACKOFF);
        assert_eq!(d.shards.count, 0, "sharding is opt-in");
    }

    #[test]
    fn service_uses_the_global_pool_by_default() {
        let svc = GemmService::start(small_cfg());
        assert!(std::ptr::eq(svc.pool(), pool::global()));
        svc.shutdown();
    }

    #[test]
    fn dedicated_pool_is_sized_and_bounded() {
        let svc = GemmService::start(ServiceConfig { pool_threads: 2, ..small_cfg() });
        assert_eq!(svc.pool().n_workers(), 2);
        assert!(!std::ptr::eq(svc.pool(), pool::global()));
        let mut rng = Rng::new(9);
        for _ in 0..4 {
            let a = Matrix::random_symmetric(8, 12, 0, &mut rng);
            let b = Matrix::random_symmetric(12, 8, 0, &mut rng);
            let resp = svc.gemm_blocking(a, b, None).expect("submit");
            assert!(resp.result.is_ok());
        }
        assert!(svc.pool().high_water() >= 1, "batches must run on the dedicated pool");
        assert!(svc.pool().high_water() <= 2, "pool must never exceed its worker count");
        svc.shutdown();
    }

    #[test]
    fn prepacked_weight_requests_hit_cache() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(7);
        let w = Matrix::random_symmetric(24, 16, 0, &mut rng);
        let id = svc.register_weights(w.clone());
        assert!(svc.weight(id).is_some());
        for _ in 0..3 {
            let a = Matrix::random_symmetric(8, 24, 0, &mut rng);
            let resp = svc.gemm_blocking_prepacked(a, id, None).expect("submit");
            assert!(resp.result.is_ok());
            assert_eq!(resp.backend, Backend::CubeTermwise);
        }
        let stats = svc.prepack_stats();
        assert_eq!(stats.misses, 1, "one pack per (weight, path)");
        assert_eq!(stats.hits, 2, "subsequent requests served from cache");
        assert!(svc.unregister_weights(id));
        assert!(svc.weight(id).is_none());
        assert_eq!(svc.prepack_stats().entries, 0, "panels purged with the weight");
        svc.shutdown();
    }

    #[test]
    fn unknown_weight_id_rejected_at_submit() {
        let svc = GemmService::start(small_cfg());
        let a: Matrix<f32> = Matrix::zeros(2, 2);
        match svc.submit_prepacked(a, WeightId(999), None) {
            Err(GemmError::UnknownWeight(999)) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok((id, _)) => panic!("accepted unknown weight as request {id}"),
        }
        svc.shutdown();
    }

    #[test]
    fn serves_one_request_accurately() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(1);
        let a = Matrix::random_symmetric(32, 48, 0, &mut rng);
        let b = Matrix::random_symmetric(48, 24, 0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone(), None).expect("submit");
        assert_eq!(resp.backend, Backend::CubeTermwise);
        assert_eq!(resp.scale_exp, 12);
        let c = resp.result.unwrap();
        let err = relative_error(&dgemm_of_f32(&a, &b), &c.to_f64());
        assert!(err < 1e-6, "err={err}");
        svc.shutdown();
    }

    #[test]
    fn serves_many_mixed_shapes() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(2);
        let mut rxs = Vec::new();
        for i in 0..20 {
            let (m, k, n) = if i % 2 == 0 { (16, 16, 16) } else { (24, 32, 8) };
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            rxs.push(svc.submit(a, b, None).expect("submit"));
        }
        let mut ids = Vec::new();
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert!(resp.result.is_ok());
            ids.push(id);
        }
        assert_eq!(ids.len(), 20);
        let report = svc.metrics().report();
        assert_eq!(report.requests, 20);
        assert_eq!(report.errors, 0);
        assert!(report.batches >= 5, "batches={}", report.batches);
        svc.shutdown();
    }

    #[test]
    fn explicit_backend_is_honored() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(3);
        let a = Matrix::random_symmetric(16, 16, 0, &mut rng);
        let b = Matrix::random_symmetric(16, 16, 0, &mut rng);
        for bk in Backend::ALL {
            let resp = svc.gemm_blocking(a.clone(), b.clone(), Some(bk)).expect("submit");
            assert_eq!(resp.backend, bk);
            assert!(resp.result.is_ok());
        }
        svc.shutdown();
    }

    #[test]
    fn precision_knob_walks_the_tier_ladder() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(21);
        let a = Matrix::random_symmetric(16, 24, 0, &mut rng);
        let b = Matrix::random_symmetric(24, 16, 0, &mut rng);
        // Loose budget → one-pass FP16; tight budget → BF16×3 cascade;
        // no knob → the default cube path.
        for (precision, want) in [
            (Some(1e-3), Backend::Fp16),
            (Some(1e-7), Backend::Bf16x3),
            (None, Backend::CubeTermwise),
        ] {
            let resp = svc
                .gemm_blocking_with_precision(a.clone(), b.clone(), None, precision)
                .expect("submit");
            assert_eq!(resp.backend, want, "precision {precision:?}");
            assert!(resp.result.is_ok());
        }
        // The knob rides the prepacked path too: each tier packs the
        // weight once under its own cache key and serves from the LRU.
        let id = svc.register_weights(b.clone());
        for _ in 0..2 {
            let resp = svc
                .gemm_blocking_prepacked_with_precision(a.clone(), id, None, Some(1e-7))
                .expect("submit");
            assert_eq!(resp.backend, Backend::Bf16x3);
            assert!(resp.result.is_ok());
        }
        let stats = svc.prepack_stats();
        assert_eq!(stats.misses, 1, "one pack per (weight, tier)");
        assert_eq!(stats.hits, 1, "second request served from cache");
        // An explicit backend wins over the knob.
        let resp = svc
            .gemm_blocking_with_precision(a.clone(), b.clone(), Some(Backend::Fp32), Some(1e-3))
            .expect("submit");
        assert_eq!(resp.backend, Backend::Fp32);
        svc.shutdown();
    }

    #[test]
    fn out_of_range_inputs_route_to_fp32() {
        let svc = GemmService::start(small_cfg());
        let a = Matrix::from_fn(8, 8, |_, _| 1e6f32); // beyond fp16 max
        let b = Matrix::from_fn(8, 8, |_, _| 1.0f32);
        let resp = svc.gemm_blocking(a, b, None).expect("submit");
        assert_eq!(resp.backend, Backend::Fp32);
        let c = resp.result.unwrap();
        assert_eq!(c.get(0, 0), 8e6);
        svc.shutdown();
    }

    #[test]
    fn dimension_mismatch_is_a_typed_error_not_a_panic() {
        let svc = GemmService::start(small_cfg());
        let a: Matrix<f32> = Matrix::zeros(4, 5);
        let b: Matrix<f32> = Matrix::zeros(6, 4);
        match svc.submit(a, b, None) {
            Err(GemmError::ShapeMismatch { m: 4, k_a: 5, k_b: 6, n: 4 }) => {}
            other => panic!("expected ShapeMismatch, got {:?}", other.map(|(id, _)| id)),
        }
        // The service is still healthy afterwards: batch tasks never
        // saw the bad request, and a well-formed one completes.
        let mut rng = Rng::new(6);
        let a = Matrix::random_symmetric(4, 6, 0, &mut rng);
        let b = Matrix::random_symmetric(6, 4, 0, &mut rng);
        let resp = svc.gemm_blocking(a, b, None).expect("submit");
        assert!(resp.result.is_ok());
        svc.shutdown();
    }

    #[test]
    fn degenerate_zero_dim_requests_are_served() {
        // m, k or n of zero must produce an empty/zero result through
        // the full dispatcher → batcher → pool path, not a panic.
        let svc = GemmService::start(small_cfg());
        for (m, k, n) in [(0usize, 8usize, 4usize), (3, 0, 4), (3, 8, 0), (0, 0, 0)] {
            let a: Matrix<f32> = Matrix::zeros(m, k);
            let b: Matrix<f32> = Matrix::zeros(k, n);
            let resp = svc.gemm_blocking(a, b, None).expect("submit");
            let c = resp.result.expect("degenerate request must succeed");
            assert_eq!(c.shape(), (m, n), "{m}x{k}x{n}");
            assert!(c.as_slice().iter().all(|&v| v == 0.0));
        }
        svc.shutdown();
    }

    #[test]
    fn every_schedule_serves_bit_identical_results() {
        let serial = GemmService::start(ServiceConfig {
            schedule: Schedule::Serial,
            ..small_cfg()
        });
        let overlapped =
            GemmService::start(ServiceConfig { schedule: Schedule::OverlapB, ..small_cfg() });
        let ab = GemmService::start(ServiceConfig {
            schedule: Schedule::OverlapAB,
            pipeline_depth: 3,
            ..small_cfg()
        });
        let mut rng = Rng::new(8);
        let a = Matrix::random_symmetric(24, 40, 0, &mut rng);
        let b = Matrix::random_symmetric(40, 16, 0, &mut rng);
        for bk in [None, Some(Backend::Fp32), Some(Backend::CubeTermwise)] {
            let x = serial.gemm_blocking(a.clone(), b.clone(), bk).expect("submit");
            let y = overlapped.gemm_blocking(a.clone(), b.clone(), bk).expect("submit");
            let z = ab.gemm_blocking(a.clone(), b.clone(), bk).expect("submit");
            let cx = x.result.unwrap();
            for other in [y.result.unwrap(), z.result.unwrap()] {
                for (u, v) in cx.as_slice().iter().zip(other.as_slice()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "backend {bk:?}");
                }
            }
        }
        serial.shutdown();
        overlapped.shutdown();
        ab.shutdown();
    }

    #[test]
    fn prepacked_schedules_serve_bit_identical_results() {
        // The same registered weight served under every prepacked
        // schedule: responses bit-match (the panels pin the numerics;
        // only the A-stripe staging differs) and the cache still packs
        // exactly once per (weight, path).
        let serial = GemmService::start(ServiceConfig {
            schedule_prepacked: Schedule::Serial,
            ..small_cfg()
        });
        let overlapped = GemmService::start(ServiceConfig {
            schedule_prepacked: Schedule::OverlapB,
            ..small_cfg()
        });
        let ab = GemmService::start(ServiceConfig {
            schedule_prepacked: Schedule::OverlapAB,
            pipeline_depth: 3,
            ..small_cfg()
        });
        let mut rng = Rng::new(11);
        let w = Matrix::random_symmetric(40, 16, 0, &mut rng);
        let ids = [
            serial.register_weights(w.clone()),
            overlapped.register_weights(w.clone()),
            ab.register_weights(w.clone()),
        ];
        for _ in 0..3 {
            let a = Matrix::random_symmetric(8, 40, 0, &mut rng);
            let x = serial.gemm_blocking_prepacked(a.clone(), ids[0], None).expect("submit");
            let y = overlapped.gemm_blocking_prepacked(a.clone(), ids[1], None).expect("submit");
            let z = ab.gemm_blocking_prepacked(a, ids[2], None).expect("submit");
            assert_eq!(x.backend, y.backend);
            assert_eq!(x.backend, z.backend);
            let cx = x.result.unwrap();
            for other in [y.result.unwrap(), z.result.unwrap()] {
                for (u, v) in cx.as_slice().iter().zip(other.as_slice()) {
                    assert_eq!(u.to_bits(), v.to_bits());
                }
            }
        }
        for svc in [&serial, &overlapped, &ab] {
            let s = svc.prepack_stats();
            assert_eq!(s.misses, 1, "one pack per (weight, path): {s:?}");
            assert_eq!(s.hits, 2, "subsequent requests served from cache: {s:?}");
        }
        serial.shutdown();
        overlapped.shutdown();
        ab.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(5);
        let a = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let b = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let _ = svc.gemm_blocking(a, b, None).expect("submit");
        drop(svc); // Drop impl must not hang
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let svc = GemmService::start(ServiceConfig { retries: 0, ..small_cfg() });
        svc.shutdown();
        let a: Matrix<f32> = Matrix::zeros(2, 3);
        let b: Matrix<f32> = Matrix::zeros(3, 2);
        match svc.submit(a.clone(), b.clone(), None) {
            Err(GemmError::ChannelClosed) => {}
            other => panic!("expected ChannelClosed, got {:?}", other.map(|(id, _)| id)),
        }
        match svc.gemm_blocking(a, b, None) {
            Err(GemmError::ChannelClosed) => {}
            other => panic!("expected ChannelClosed, got {other:?}"),
        }
        // A second shutdown and the Drop-time one are both no-ops.
        svc.shutdown();
        drop(svc);
    }

    #[test]
    fn admission_control_sheds_when_saturated() {
        let svc = GemmService::start(ServiceConfig { max_pending: 1, ..small_cfg() });
        // Occupy the only admission slot synthetically — deterministic,
        // no timing race against the dispatcher.
        svc.ctx.pending.fetch_add(1, Ordering::SeqCst);
        let a: Matrix<f32> = Matrix::zeros(2, 2);
        let b: Matrix<f32> = Matrix::zeros(2, 2);
        match svc.submit(a.clone(), b.clone(), None) {
            Err(GemmError::Overloaded { in_flight: 2, limit: 1 }) => {}
            other => panic!("expected Overloaded, got {:?}", other.map(|(id, _)| id)),
        }
        assert_eq!(svc.metrics().report().shed, 1);
        // Freeing the slot re-opens the front door.
        svc.ctx.pending.fetch_sub(1, Ordering::SeqCst);
        let resp = svc.gemm_blocking(a, b, None).expect("slot freed");
        assert!(resp.result.is_ok());
        assert_eq!(svc.ctx.pending.load(Ordering::SeqCst), 0, "balanced after reply");
        svc.shutdown();
    }

    #[test]
    fn sharded_weight_serving_is_bit_identical_to_single_node() {
        let plain = GemmService::start(small_cfg());
        let sharded = GemmService::start(ServiceConfig {
            shards: ShardConfig { count: 3, ..Default::default() },
            ..small_cfg()
        });
        let mut rng = Rng::new(12);
        let w = Matrix::random_symmetric(40, 22, 0, &mut rng);
        let id_p = plain.register_weights(w.clone());
        let id_s = sharded.register_weights(w);
        assert!(plain.shard_router(id_p).is_none(), "count=0 keeps single-node serving");
        let router = sharded.shard_router(id_s).expect("router built at registration");
        assert_eq!(router.shard_count(), 3);
        for _ in 0..3 {
            let a = Matrix::random_symmetric(8, 40, 0, &mut rng);
            let x = plain.gemm_blocking_prepacked(a.clone(), id_p, None).expect("submit");
            let y = sharded.gemm_blocking_prepacked(a, id_s, None).expect("submit");
            assert_eq!(x.backend, y.backend);
            let cx = x.result.unwrap();
            let cy = y.result.unwrap();
            for (u, v) in cx.as_slice().iter().zip(cy.as_slice()) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
        assert_eq!(sharded.prepack_stats().misses, 3, "one pack per slice");
        // Unregistering drops the router with the weight.
        assert!(sharded.unregister_weights(id_s));
        assert!(sharded.shard_router(id_s).is_none());
        plain.shutdown();
        sharded.shutdown();
    }
}
