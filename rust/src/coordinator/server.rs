//! The GEMM service: submission API, weight registry, dispatcher
//! thread, worker pool, prepacked-operand cache.
//!
//! Architecture (std threads; the image has no tokio):
//!
//! ```text
//! clients --register_weights()--> weight registry (Arc<WeightEntry>)
//! clients --submit()-----------> dispatcher --(batch by shape+weight)--> workers
//!                                                                     \--> reply channels
//!                                        workers <--> prepack cache (LRU, Arc<PrepackedMatrix>)
//! ```
//!
//! The dispatcher owns the [`Batcher`]; full or expired batches go to a
//! work queue consumed by `n_workers` threads. Each worker executes the
//! batch through the precision path chosen by the [`PrecisionPolicy`]
//! (or the request's explicit backend) on the native numerics engine.
//! Requests against a registered weight are served from the prepacked
//! cache: the weight's FP32→2×FP16 split and panel packing are done at
//! most once per `(weight, path, s_b)` and every subsequent request pays
//! only for preparing its A operand ([`crate::gemm::prepacked`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::{matrix_exponent_range, PolicyDecision, PrecisionPolicy};
use crate::coordinator::request::{BOperand, GemmRequest, GemmResponse, WeightEntry, WeightId};
use crate::gemm::backend::{Backend, GemmBackend};
use crate::gemm::blocked;
use crate::gemm::cache::{CacheStats, PrepackCache, PrepackKey};
use crate::gemm::error::GemmError;
use crate::gemm::prepacked::PrepackedMatrix;
use crate::util::mat::Matrix;

/// Default prepack-cache capacity: enough for a few dozen transformer-
/// block-sized FP16/cube weights without threatening a serving host's
/// memory budget.
pub const DEFAULT_PREPACK_CAPACITY: usize = 256 << 20;

/// Default worker count: one per available core
/// (`std::thread::available_parallelism`), honoring the operator's
/// `SGEMM_CUBE_THREADS` override, clamped to at least one.
pub fn default_workers() -> usize {
    crate::util::threads::num_threads().max(1)
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub policy: PrecisionPolicy,
    /// Worker threads (0 = available parallelism, same as the default).
    pub n_workers: usize,
    /// Prepacked-operand cache capacity in bytes. `0` disables the
    /// cache entirely (miss-through — every request repacks).
    pub prepack_capacity: usize,
    /// Route inline (non-prepacked) requests through the overlapped
    /// (double-buffered) b_k pipeline ([`crate::gemm::overlap`]).
    /// Bit-identical results; defaults to the `SGEMM_CUBE_OVERLAP` env
    /// toggle, and the config file's `[server] overlap` key overrides.
    pub overlap: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batcher: BatcherConfig::default(),
            policy: PrecisionPolicy::default(),
            n_workers: default_workers(),
            prepack_capacity: DEFAULT_PREPACK_CAPACITY,
            overlap: crate::gemm::overlap::overlap_enabled(),
        }
    }
}

enum DispatchMsg {
    Request(GemmRequest),
    Shutdown,
}

/// Handle to a running GEMM service.
pub struct GemmService {
    tx: Sender<DispatchMsg>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    weights: Mutex<HashMap<WeightId, Arc<WeightEntry>>>,
    next_weight: AtomicU64,
    prepack: Arc<PrepackCache>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl GemmService {
    /// Start the dispatcher and worker pool.
    pub fn start(cfg: ServiceConfig) -> GemmService {
        let metrics = Arc::new(Metrics::new());
        let prepack = Arc::new(PrepackCache::new(cfg.prepack_capacity));
        let (tx, rx) = channel::<DispatchMsg>();
        let (work_tx, work_rx) = channel::<Vec<GemmRequest>>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let n_workers = if cfg.n_workers == 0 { default_workers() } else { cfg.n_workers };

        let mut workers = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let work_rx = work_rx.clone();
            let metrics = metrics.clone();
            let policy = cfg.policy.clone();
            let cache = prepack.clone();
            let overlap = cfg.overlap;
            workers.push(std::thread::spawn(move || {
                worker_loop(work_rx, metrics, policy, cache, overlap)
            }));
        }

        let metrics_d = metrics.clone();
        let batcher_cfg = cfg.batcher.clone();
        let dispatcher = std::thread::spawn(move || {
            dispatcher_loop(rx, work_tx, batcher_cfg, metrics_d);
        });

        GemmService {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            weights: Mutex::new(HashMap::new()),
            next_weight: AtomicU64::new(1),
            prepack,
            dispatcher: Some(dispatcher),
            workers,
        }
    }

    /// Register a cache-stable B operand (a weight matrix). Its exponent
    /// range is computed now, once; its packed/split representation is
    /// built lazily on first use per precision path and then served from
    /// the prepack cache. Returns the handle to pass to
    /// [`GemmService::submit_prepacked`].
    pub fn register_weights(&self, b: Matrix<f32>) -> WeightId {
        let id = WeightId(self.next_weight.fetch_add(1, Ordering::Relaxed));
        let (e_min, e_max) = matrix_exponent_range(&b);
        let entry = Arc::new(WeightEntry { id, matrix: b, e_min, e_max });
        self.weights.lock().unwrap().insert(id, entry);
        id
    }

    /// The registered weight entry behind `id`, if any.
    pub fn weight(&self, id: WeightId) -> Option<Arc<WeightEntry>> {
        self.weights.lock().unwrap().get(&id).cloned()
    }

    /// Drop a registered weight and purge its prepacked panels from the
    /// cache (weight ids are never reused, so stale entries could only
    /// waste capacity).
    pub fn unregister_weights(&self, id: WeightId) -> bool {
        let removed = self.weights.lock().unwrap().remove(&id).is_some();
        if removed {
            self.prepack.purge_weight(id.0);
        }
        removed
    }

    fn submit_operand(
        &self,
        a: Matrix<f32>,
        b: BOperand,
        backend: Option<Backend>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        // Validate here, in the caller's thread, so a malformed request
        // is a typed error instead of a panic inside a worker. The
        // kernels keep their asserts as last-resort invariants.
        check_shapes(&a, b.matrix())?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        let req = GemmRequest { id, a, b, backend, submitted: Instant::now(), reply };
        self.tx
            .send(DispatchMsg::Request(req))
            .expect("service dispatcher is gone");
        Ok((id, rx))
    }

    /// Submit a GEMM; returns (request id, receiver for the response),
    /// or [`GemmError::ShapeMismatch`] for incompatible operands.
    pub fn submit(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        self.submit_operand(a, BOperand::Inline(b), backend)
    }

    /// Submit a GEMM against a registered weight: batched with other
    /// requests on the same weight and served from its prepacked panels.
    ///
    /// Returns [`GemmError::UnknownWeight`] if `id` was never registered
    /// (or was unregistered), [`GemmError::ShapeMismatch`] for
    /// incompatible operands.
    pub fn submit_prepacked(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        backend: Option<Backend>,
    ) -> Result<(u64, Receiver<GemmResponse>), GemmError> {
        let entry = self.weight(id).ok_or(GemmError::UnknownWeight(id.0))?;
        self.submit_operand(a, BOperand::Weight(entry), backend)
    }

    /// Blocking convenience: submit and wait. Submit-time failures
    /// (shape mismatch) surface as the outer error; execution failures
    /// stay in [`GemmResponse::result`].
    pub fn gemm_blocking(
        &self,
        a: Matrix<f32>,
        b: Matrix<f32>,
        backend: Option<Backend>,
    ) -> Result<GemmResponse, GemmError> {
        let (_, rx) = self.submit(a, b, backend)?;
        Ok(rx.recv().expect("worker dropped the reply channel"))
    }

    /// Blocking convenience for the register-weights-then-serve flow.
    pub fn gemm_blocking_prepacked(
        &self,
        a: Matrix<f32>,
        id: WeightId,
        backend: Option<Backend>,
    ) -> Result<GemmResponse, GemmError> {
        let (_, rx) = self.submit_prepacked(a, id, backend)?;
        Ok(rx.recv().expect("worker dropped the reply channel"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Counters of the prepacked-operand cache (hits appear from the
    /// second request against a weight on a given precision path).
    pub fn prepack_stats(&self) -> CacheStats {
        self.prepack.stats()
    }

    /// Stop accepting work, drain, and join all threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for GemmService {
    fn drop(&mut self) {
        let _ = self.tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn dispatcher_loop(
    rx: Receiver<DispatchMsg>,
    work_tx: Sender<Vec<GemmRequest>>,
    batcher_cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(batcher_cfg);
    loop {
        let timeout = batcher
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(DispatchMsg::Request(req)) => {
                if let Some(batch) = batcher.push(req) {
                    metrics.record_batch();
                    if work_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Ok(DispatchMsg::Shutdown) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch();
                    let _ = work_tx.send(batch);
                }
                return; // dropping work_tx stops the workers
            }
            Err(RecvTimeoutError::Timeout) => {
                for batch in batcher.flush_expired(Instant::now()) {
                    metrics.record_batch();
                    if work_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.flush_all() {
                    metrics.record_batch();
                    let _ = work_tx.send(batch);
                }
                return;
            }
        }
    }
}

fn worker_loop(
    work_rx: Arc<Mutex<Receiver<Vec<GemmRequest>>>>,
    metrics: Arc<Metrics>,
    policy: PrecisionPolicy,
    cache: Arc<PrepackCache>,
    overlap: bool,
) {
    loop {
        // Hold the lock only while receiving, not while computing.
        let batch = match work_rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return,
        };
        for req in batch {
            let decision = match req.backend {
                Some(b) => PolicyDecision { backend: b, scale_exp: 12, e_min: None, e_max: None },
                // Registered weights carry their exponent range from
                // registration time; only A is scanned per request.
                None => match req.b.weight() {
                    Some(w) => {
                        policy.decide_ranges(matrix_exponent_range(&req.a), (w.e_min, w.e_max))
                    }
                    None => policy.decide(&req.a, req.b.matrix()),
                },
            };
            let shape = req.shape();
            // Revalidate before executing: submission already checked,
            // but a worker must never be one bad request away from a
            // panic — the kernels' asserts stay as last-resort
            // invariants behind this check and the catch_unwind.
            let result = match check_shapes(&req.a, req.b.matrix()) {
                Err(e) => Err(e),
                Ok(()) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    execute_request(&req, &decision, &cache, overlap)
                }))
                .map_err(|p| GemmError::Panicked(panic_message(p))),
            };
            let latency = req.submitted.elapsed().as_secs_f64();
            metrics.record_request(latency, shape.flops(), result.is_ok());
            let _ = req.reply.send(GemmResponse {
                id: req.id,
                result,
                backend: decision.backend,
                scale_exp: decision.scale_exp,
                latency,
            });
        }
    }
}

/// Shape compatibility of a request's operands, as a typed error.
fn check_shapes(a: &Matrix<f32>, b: &Matrix<f32>) -> Result<(), GemmError> {
    let (m, k_a) = a.shape();
    let (k_b, n) = b.shape();
    if k_a != k_b {
        return Err(GemmError::ShapeMismatch { m, k_a, k_b, n });
    }
    Ok(())
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one request on the decided path. Registered weights go
/// through the prepack cache and the prepacked blocked entry points —
/// bit-identical to the inline path for the same decision, since both
/// run the same sweeps over equal panel bytes
/// ([`crate::gemm::blocked::gemm_prepacked`]).
fn execute_request(
    req: &GemmRequest,
    decision: &PolicyDecision,
    cache: &PrepackCache,
    overlap: bool,
) -> Matrix<f32> {
    if let (Some(w), Some(path)) = (req.b.weight(), decision.prepack_path()) {
        // Normalize the key the way the panels are shared: both cube
        // orders execute the same fused kernel, and non-cube paths
        // ignore the scaling exponent entirely.
        let (backend, scale_exp) = match decision.backend {
            Backend::Fp32 => (Backend::Fp32, 0),
            Backend::Fp16 => (Backend::Fp16, 0),
            Backend::CubeElementwise | Backend::CubeTermwise => {
                (Backend::CubeTermwise, decision.scale_exp)
            }
        };
        let key = PrepackKey {
            weight: w.id.0,
            k: w.matrix.rows(),
            n: w.matrix.cols(),
            backend,
            scale_exp,
        };
        let packed = cache.get_or_insert_with(key, || PrepackedMatrix::prepack(&w.matrix, path));
        return blocked::gemm_prepacked(&req.a, &packed);
    }
    GemmBackend::new(decision.backend)
        .with_scale(decision.scale_exp)
        .with_overlap(overlap)
        .gemm(&req.a, req.b.matrix())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            policy: PrecisionPolicy::default(),
            n_workers: 2,
            ..Default::default()
        }
    }

    #[test]
    fn default_workers_track_available_parallelism() {
        let d = ServiceConfig::default();
        assert!(d.n_workers >= 1, "clamped to at least one worker");
        // One per core (or the operator's SGEMM_CUBE_THREADS override —
        // num_threads() resolves both).
        assert_eq!(d.n_workers, crate::util::threads::num_threads().max(1));
        assert!(d.prepack_capacity > 0);
    }

    #[test]
    fn prepacked_weight_requests_hit_cache() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(7);
        let w = Matrix::random_symmetric(24, 16, 0, &mut rng);
        let id = svc.register_weights(w.clone());
        assert!(svc.weight(id).is_some());
        for _ in 0..3 {
            let a = Matrix::random_symmetric(8, 24, 0, &mut rng);
            let resp = svc.gemm_blocking_prepacked(a, id, None).expect("submit");
            assert!(resp.result.is_ok());
            assert_eq!(resp.backend, Backend::CubeTermwise);
        }
        let stats = svc.prepack_stats();
        assert_eq!(stats.misses, 1, "one pack per (weight, path)");
        assert_eq!(stats.hits, 2, "subsequent requests served from cache");
        assert!(svc.unregister_weights(id));
        assert!(svc.weight(id).is_none());
        assert_eq!(svc.prepack_stats().entries, 0, "panels purged with the weight");
        svc.shutdown();
    }

    #[test]
    fn unknown_weight_id_rejected_at_submit() {
        let svc = GemmService::start(small_cfg());
        let a: Matrix<f32> = Matrix::zeros(2, 2);
        match svc.submit_prepacked(a, WeightId(999), None) {
            Err(GemmError::UnknownWeight(999)) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok((id, _)) => panic!("accepted unknown weight as request {id}"),
        }
        svc.shutdown();
    }

    #[test]
    fn serves_one_request_accurately() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(1);
        let a = Matrix::random_symmetric(32, 48, 0, &mut rng);
        let b = Matrix::random_symmetric(48, 24, 0, &mut rng);
        let resp = svc.gemm_blocking(a.clone(), b.clone(), None).expect("submit");
        assert_eq!(resp.backend, Backend::CubeTermwise);
        assert_eq!(resp.scale_exp, 12);
        let c = resp.result.unwrap();
        let err = relative_error(&dgemm_of_f32(&a, &b), &c.to_f64());
        assert!(err < 1e-6, "err={err}");
        svc.shutdown();
    }

    #[test]
    fn serves_many_mixed_shapes() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(2);
        let mut rxs = Vec::new();
        for i in 0..20 {
            let (m, k, n) = if i % 2 == 0 { (16, 16, 16) } else { (24, 32, 8) };
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            rxs.push(svc.submit(a, b, None).expect("submit"));
        }
        let mut ids = Vec::new();
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert!(resp.result.is_ok());
            ids.push(id);
        }
        assert_eq!(ids.len(), 20);
        let report = svc.metrics().report();
        assert_eq!(report.requests, 20);
        assert_eq!(report.errors, 0);
        assert!(report.batches >= 5, "batches={}", report.batches);
        svc.shutdown();
    }

    #[test]
    fn explicit_backend_is_honored() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(3);
        let a = Matrix::random_symmetric(16, 16, 0, &mut rng);
        let b = Matrix::random_symmetric(16, 16, 0, &mut rng);
        for bk in Backend::ALL {
            let resp = svc.gemm_blocking(a.clone(), b.clone(), Some(bk)).expect("submit");
            assert_eq!(resp.backend, bk);
            assert!(resp.result.is_ok());
        }
        svc.shutdown();
    }

    #[test]
    fn out_of_range_inputs_route_to_fp32() {
        let svc = GemmService::start(small_cfg());
        let a = Matrix::from_fn(8, 8, |_, _| 1e6f32); // beyond fp16 max
        let b = Matrix::from_fn(8, 8, |_, _| 1.0f32);
        let resp = svc.gemm_blocking(a, b, None).expect("submit");
        assert_eq!(resp.backend, Backend::Fp32);
        let c = resp.result.unwrap();
        assert_eq!(c.get(0, 0), 8e6);
        svc.shutdown();
    }

    #[test]
    fn dimension_mismatch_is_a_typed_error_not_a_panic() {
        let svc = GemmService::start(small_cfg());
        let a: Matrix<f32> = Matrix::zeros(4, 5);
        let b: Matrix<f32> = Matrix::zeros(6, 4);
        match svc.submit(a, b, None) {
            Err(GemmError::ShapeMismatch { m: 4, k_a: 5, k_b: 6, n: 4 }) => {}
            other => panic!("expected ShapeMismatch, got {:?}", other.map(|(id, _)| id)),
        }
        // The service is still healthy afterwards: workers never saw the
        // bad request, and a well-formed one completes.
        let mut rng = Rng::new(6);
        let a = Matrix::random_symmetric(4, 6, 0, &mut rng);
        let b = Matrix::random_symmetric(6, 4, 0, &mut rng);
        let resp = svc.gemm_blocking(a, b, None).expect("submit");
        assert!(resp.result.is_ok());
        svc.shutdown();
    }

    #[test]
    fn degenerate_zero_dim_requests_are_served() {
        // m, k or n of zero must produce an empty/zero result through
        // the full dispatcher → batcher → worker path, not a panic.
        let svc = GemmService::start(small_cfg());
        for (m, k, n) in [(0usize, 8usize, 4usize), (3, 0, 4), (3, 8, 0), (0, 0, 0)] {
            let a: Matrix<f32> = Matrix::zeros(m, k);
            let b: Matrix<f32> = Matrix::zeros(k, n);
            let resp = svc.gemm_blocking(a, b, None).expect("submit");
            let c = resp.result.expect("degenerate request must succeed");
            assert_eq!(c.shape(), (m, n), "{m}x{k}x{n}");
            assert!(c.as_slice().iter().all(|&v| v == 0.0));
        }
        svc.shutdown();
    }

    #[test]
    fn overlap_enabled_service_bit_matches_serial_service() {
        let serial = GemmService::start(ServiceConfig { overlap: false, ..small_cfg() });
        let overlapped = GemmService::start(ServiceConfig { overlap: true, ..small_cfg() });
        let mut rng = Rng::new(8);
        let a = Matrix::random_symmetric(24, 40, 0, &mut rng);
        let b = Matrix::random_symmetric(40, 16, 0, &mut rng);
        for bk in [None, Some(Backend::Fp32), Some(Backend::CubeTermwise)] {
            let x = serial.gemm_blocking(a.clone(), b.clone(), bk).expect("submit");
            let y = overlapped.gemm_blocking(a.clone(), b.clone(), bk).expect("submit");
            let (cx, cy) = (x.result.unwrap(), y.result.unwrap());
            for (u, v) in cx.as_slice().iter().zip(cy.as_slice()) {
                assert_eq!(u.to_bits(), v.to_bits(), "backend {bk:?}");
            }
        }
        serial.shutdown();
        overlapped.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let svc = GemmService::start(small_cfg());
        let mut rng = Rng::new(5);
        let a = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let b = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let _ = svc.gemm_blocking(a, b, None).expect("submit");
        drop(svc); // Drop impl must not hang
    }
}
