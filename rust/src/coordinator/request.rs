//! Request/response types of the GEMM service.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::gemm::backend::Backend;
use crate::util::mat::Matrix;

/// Shape key used for batching: requests with equal keys can execute in
/// the same batch (same executable / same kernel configuration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeKey {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl ShapeKey {
    pub fn of(a: &Matrix<f32>, b: &Matrix<f32>) -> ShapeKey {
        ShapeKey { m: a.rows(), k: a.cols(), n: b.cols() }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
}

/// A GEMM job submitted to the service.
pub struct GemmRequest {
    pub id: u64,
    pub a: Matrix<f32>,
    pub b: Matrix<f32>,
    /// Fixed precision path, or `None` to let the policy decide.
    pub backend: Option<Backend>,
    /// When the request entered the service (for latency accounting).
    pub submitted: Instant,
    /// Where to deliver the result.
    pub reply: Sender<GemmResponse>,
}

impl GemmRequest {
    pub fn shape(&self) -> ShapeKey {
        ShapeKey::of(&self.a, &self.b)
    }
}

/// The service's answer.
#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub result: Result<Matrix<f32>, String>,
    /// Which path actually executed.
    pub backend: Backend,
    /// Residual scaling exponent used (cube paths).
    pub scale_exp: i32,
    /// End-to-end latency in seconds.
    pub latency: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_key_of_operands() {
        let a: Matrix<f32> = Matrix::zeros(3, 5);
        let b: Matrix<f32> = Matrix::zeros(5, 7);
        let k = ShapeKey::of(&a, &b);
        assert_eq!(k, ShapeKey { m: 3, k: 5, n: 7 });
        assert_eq!(k.flops(), 2.0 * 3.0 * 5.0 * 7.0);
    }

    #[test]
    fn shape_keys_hash_and_order() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(ShapeKey { m: 1, k: 2, n: 3 });
        s.insert(ShapeKey { m: 1, k: 2, n: 3 });
        assert_eq!(s.len(), 1);
        assert!(ShapeKey { m: 1, k: 2, n: 3 } < ShapeKey { m: 2, k: 0, n: 0 });
    }
}
