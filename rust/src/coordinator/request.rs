//! Request/response types of the GEMM service.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use crate::gemm::backend::Backend;
use crate::gemm::error::GemmError;
use crate::util::mat::Matrix;

/// Shape key used for batching: requests with equal keys can execute in
/// the same batch (same executable / same kernel configuration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeKey {
    /// Rows of A and C.
    pub m: usize,
    /// Inner (contraction) dimension.
    pub k: usize,
    /// Columns of B and C.
    pub n: usize,
}

impl ShapeKey {
    /// The shape key of an `(A, B)` operand pair.
    pub fn of(a: &Matrix<f32>, b: &Matrix<f32>) -> ShapeKey {
        ShapeKey { m: a.rows(), k: a.cols(), n: b.cols() }
    }

    /// FLOP count of one GEMM at this shape (`2·m·n·k`).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
}

/// Identity of a weight matrix registered with the service
/// ([`crate::coordinator::server::GemmService::register_weights`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WeightId(pub u64);

/// A registered, cache-stable B operand. The exponent range is computed
/// once at registration so the per-request policy scan only touches A —
/// and the packed/split representation is cached per precision path
/// ([`crate::gemm::cache`]), which is the point of registering at all.
#[derive(Debug)]
pub struct WeightEntry {
    /// Identity the weight was registered under.
    pub id: WeightId,
    /// The weight values.
    pub matrix: Matrix<f32>,
    /// Unbiased exponent range of the weight's finite non-zero entries
    /// (see [`crate::coordinator::policy::matrix_exponent_range`]).
    pub e_min: Option<i32>,
    /// Upper end of the same exponent range.
    pub e_max: Option<i32>,
}

/// The B operand of a request: a one-shot inline matrix, or a registered
/// weight shared (via `Arc`) with the service registry and every other
/// request against it.
pub enum BOperand {
    /// A one-shot B matrix owned by the request.
    Inline(Matrix<f32>),
    /// A registered weight shared with the service registry.
    Weight(Arc<WeightEntry>),
}

impl BOperand {
    /// The operand values, wherever they live.
    pub fn matrix(&self) -> &Matrix<f32> {
        match self {
            BOperand::Inline(m) => m,
            BOperand::Weight(w) => &w.matrix,
        }
    }

    /// The registered weight entry, if this operand is cache-stable.
    pub fn weight(&self) -> Option<&Arc<WeightEntry>> {
        match self {
            BOperand::Inline(_) => None,
            BOperand::Weight(w) => Some(w),
        }
    }

    /// The registered weight identity, if this operand is cache-stable.
    pub fn weight_id(&self) -> Option<WeightId> {
        self.weight().map(|w| w.id)
    }
}

/// Batching key: the shape plus the weight identity, so requests sharing
/// a prepacked B land in the same batch (one cache lookup, maximal panel
/// reuse) and never mix with inline requests that merely share a shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// The GEMM shape.
    pub shape: ShapeKey,
    /// The registered weight identity, `None` for inline operands.
    pub weight: Option<WeightId>,
}

/// A GEMM job submitted to the service.
pub struct GemmRequest {
    /// Caller-chosen request identifier, echoed in the response.
    pub id: u64,
    /// The A operand.
    pub a: Matrix<f32>,
    /// The B operand (inline or registered weight).
    pub b: BOperand,
    /// Fixed precision path, or `None` to let the policy decide.
    pub backend: Option<Backend>,
    /// Per-request relative-error budget (the `precision` knob):
    /// overrides the service policy's configured budget for this request
    /// only, letting the policy pick the cheapest precision-emulation
    /// tier that meets it — one-pass FP16 for loose budgets up to the
    /// six-pass BF16×3 cascade for budgets tighter than the FP16×2
    /// cube's ~22 bits. Ignored when `backend` is fixed; `None` defers
    /// to the service-wide `[server] precision` setting.
    pub precision: Option<f64>,
    /// When the request entered the service (for latency accounting).
    pub submitted: Instant,
    /// Absolute deadline: batch workers shed the request with
    /// [`GemmError::Timeout`] once this instant passes, and the
    /// blocking entry points stop waiting for the reply
    /// (`None` = no deadline; set from
    /// [`ServiceConfig::request_timeout`](crate::coordinator::server::ServiceConfig::request_timeout)).
    pub deadline: Option<Instant>,
    /// Where to deliver the result.
    pub reply: Sender<GemmResponse>,
}

impl GemmRequest {
    /// The request's GEMM shape.
    pub fn shape(&self) -> ShapeKey {
        ShapeKey::of(&self.a, self.b.matrix())
    }

    /// The key this request batches under (shape + weight identity).
    pub fn batch_key(&self) -> BatchKey {
        BatchKey { shape: self.shape(), weight: self.b.weight_id() }
    }
}

/// The service's answer.
#[derive(Debug)]
pub struct GemmResponse {
    /// The `id` of the request this answers.
    pub id: u64,
    /// The product, or the typed failure ([`GemmError`]) — a worker
    /// never panics on a bad request; it reports here.
    pub result: Result<Matrix<f32>, GemmError>,
    /// Which path actually executed.
    pub backend: Backend,
    /// Residual scaling exponent used (cube paths).
    pub scale_exp: i32,
    /// End-to-end latency in seconds.
    pub latency: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_key_of_operands() {
        let a: Matrix<f32> = Matrix::zeros(3, 5);
        let b: Matrix<f32> = Matrix::zeros(5, 7);
        let k = ShapeKey::of(&a, &b);
        assert_eq!(k, ShapeKey { m: 3, k: 5, n: 7 });
        assert_eq!(k.flops(), 2.0 * 3.0 * 5.0 * 7.0);
    }

    #[test]
    fn shape_keys_hash_and_order() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(ShapeKey { m: 1, k: 2, n: 3 });
        s.insert(ShapeKey { m: 1, k: 2, n: 3 });
        assert_eq!(s.len(), 1);
        assert!(ShapeKey { m: 1, k: 2, n: 3 } < ShapeKey { m: 2, k: 0, n: 0 });
    }

    #[test]
    fn b_operand_views_and_batch_keys() {
        let inline = BOperand::Inline(Matrix::zeros(5, 7));
        assert_eq!(inline.matrix().shape(), (5, 7));
        assert_eq!(inline.weight_id(), None);

        let entry = Arc::new(WeightEntry {
            id: WeightId(9),
            matrix: Matrix::zeros(5, 7),
            e_min: None,
            e_max: None,
        });
        let weight = BOperand::Weight(entry.clone());
        assert_eq!(weight.matrix().shape(), (5, 7));
        assert_eq!(weight.weight_id(), Some(WeightId(9)));
        assert_eq!(weight.weight().unwrap().id, entry.id);

        // Same shape, different stability → different batch keys.
        let (tx, _rx) = std::sync::mpsc::channel();
        let mk = |b: BOperand| GemmRequest {
            id: 1,
            a: Matrix::zeros(3, 5),
            b,
            backend: None,
            precision: None,
            submitted: Instant::now(),
            deadline: None,
            reply: tx.clone(),
        };
        let k_inline = mk(BOperand::Inline(Matrix::zeros(5, 7))).batch_key();
        let k_weight = mk(BOperand::Weight(entry)).batch_key();
        assert_eq!(k_inline.shape, k_weight.shape);
        assert_ne!(k_inline, k_weight);
    }
}
