//! Shape- and weight-keyed dynamic batching.
//!
//! Requests accumulate per [`BatchKey`] — the shape plus the registered
//! weight identity, if any; a batch flushes when it reaches `max_batch`
//! or when its oldest member has waited `max_wait`. This is the standard
//! dynamic-batching shape of serving routers (vLLM-style), specialized
//! to GEMM: batched requests share one compiled executable / kernel
//! configuration, and requests against the same registered weight share
//! one prepacked operand ([`crate::gemm::prepacked`]), so grouping them
//! maximizes cache-panel reuse within a worker.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::coordinator::request::{BatchKey, GemmRequest};

/// Batching knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush a batch as soon as it reaches this many requests.
    pub max_batch: usize,
    /// Flush a batch once its oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates requests into shape- and weight-homogeneous batches.
pub struct Batcher {
    cfg: BatcherConfig,
    pending: HashMap<BatchKey, Vec<GemmRequest>>,
}

impl Batcher {
    /// An empty batcher with the given knobs.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher { cfg, pending: HashMap::new() }
    }

    /// Add a request; returns a full batch if this push filled one.
    pub fn push(&mut self, req: GemmRequest) -> Option<Vec<GemmRequest>> {
        let key = req.batch_key();
        let queue = self.pending.entry(key).or_default();
        queue.push(req);
        if queue.len() >= self.cfg.max_batch {
            return self.pending.remove(&key);
        }
        None
    }

    /// Flush every batch whose oldest request has exceeded `max_wait`
    /// (call periodically from the service loop).
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Vec<GemmRequest>> {
        let expired: Vec<BatchKey> = self
            .pending
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .map(|r| now.duration_since(r.submitted) >= self.cfg.max_wait)
                    .unwrap_or(false)
            })
            .map(|(k, _)| *k)
            .collect();
        expired
            .into_iter()
            .filter_map(|k| self.pending.remove(&k))
            .collect()
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Vec<GemmRequest>> {
        let keys: Vec<BatchKey> = self.pending.keys().copied().collect();
        keys.into_iter().filter_map(|k| self.pending.remove(&k)).collect()
    }

    /// Number of requests currently queued across all pending batches.
    pub fn pending_count(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    /// Time until the next expiry deadline, if any batch is pending.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending
            .values()
            .filter_map(|q| q.first())
            .map(|r| {
                self.cfg
                    .max_wait
                    .saturating_sub(now.duration_since(r.submitted))
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{BOperand, WeightEntry, WeightId};
    use crate::util::mat::Matrix;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(id: u64, m: usize, k: usize, n: usize) -> GemmRequest {
        let (tx, _rx) = channel();
        GemmRequest {
            id,
            a: Matrix::zeros(m, k),
            b: BOperand::Inline(Matrix::zeros(k, n)),
            backend: None,
            submitted: Instant::now(),
            deadline: None,
            reply: tx,
        }
    }

    fn weight_req(id: u64, weight: u64, m: usize, k: usize, n: usize) -> GemmRequest {
        let (tx, _rx) = channel();
        GemmRequest {
            id,
            a: Matrix::zeros(m, k),
            b: BOperand::Weight(Arc::new(WeightEntry {
                id: WeightId(weight),
                matrix: Matrix::zeros(k, n),
                e_min: None,
                e_max: None,
            })),
            backend: None,
            submitted: Instant::now(),
            deadline: None,
            reply: tx,
        }
    }

    #[test]
    fn batches_fill_by_shape() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10) });
        assert!(b.push(req(1, 4, 4, 4)).is_none());
        assert!(b.push(req(2, 8, 8, 8)).is_none()); // different shape
        assert!(b.push(req(3, 4, 4, 4)).is_none());
        let batch = b.push(req(4, 4, 4, 4)).expect("third 4³ fills the batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(b.pending_count(), 1); // the 8³ request remains
    }

    #[test]
    fn weight_requests_group_by_weight_not_just_shape() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        // Same 4×4×4 shape throughout: inline, weight 1, weight 2.
        assert!(b.push(req(1, 4, 4, 4)).is_none());
        assert!(b.push(weight_req(2, 1, 4, 4, 4)).is_none());
        assert!(b.push(weight_req(3, 2, 4, 4, 4)).is_none());
        assert_eq!(b.pending_count(), 3, "three distinct batch keys");
        // A second request on weight 1 fills that batch alone.
        let batch = b.push(weight_req(4, 1, 4, 4, 4)).expect("weight-1 batch full");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert!(batch.iter().all(|r| r.b.weight_id() == Some(WeightId(1))));
        assert_eq!(b.pending_count(), 2);
    }

    #[test]
    fn expiry_flushes_old_batches() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push(req(1, 4, 4, 4));
        b.push(req(2, 8, 8, 8));
        assert!(b.flush_expired(Instant::now()).is_empty() || true); // may not be due yet
        std::thread::sleep(Duration::from_millis(3));
        let flushed = b.flush_expired(Instant::now());
        assert_eq!(flushed.len(), 2);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn flush_all_empties() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.push(req(1, 4, 4, 4));
        b.push(req(2, 8, 4, 4));
        let all = b.flush_all();
        assert_eq!(all.iter().map(Vec::len).sum::<usize>(), 2);
        assert_eq!(b.pending_count(), 0);
        assert!(b.next_deadline(Instant::now()).is_none());
    }

    #[test]
    fn next_deadline_reflects_oldest() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 10, max_wait: Duration::from_millis(50) });
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(req(1, 4, 4, 4));
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(50));
    }
}
