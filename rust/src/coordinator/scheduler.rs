//! Row-block tile scheduler.
//!
//! Mirrors how the Ascend kernel distributes `b_m` row blocks across the
//! 32 AI cores (Algorithm 1's outer parallel loop): a GEMM is cut into
//! row-block tiles, placed on per-worker queues with a longest-
//! processing-time-first heuristic, and executed by the worker pool.

use crate::coordinator::request::ShapeKey;

/// One schedulable tile: rows `[row_start, row_end)` of a GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    /// First row of the tile (inclusive).
    pub row_start: usize,
    /// One past the last row of the tile.
    pub row_end: usize,
}

impl Tile {
    /// Number of rows in the tile.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Cut `m` rows into tiles of at most `block_m` rows.
pub fn tiles_of(m: usize, block_m: usize) -> Vec<Tile> {
    assert!(block_m > 0);
    (0..m.div_ceil(block_m))
        .map(|i| Tile { row_start: i * block_m, row_end: ((i + 1) * block_m).min(m) })
        .collect()
}

/// Assign tiles to `workers` queues, LPT-first (largest tile to the
/// currently-least-loaded worker), returning per-worker tile lists.
/// Load is measured in rows × k × n FLOPs-proportional units.
pub fn assign(tiles: &[Tile], shape: ShapeKey, workers: usize) -> Vec<Vec<Tile>> {
    assert!(workers > 0);
    let mut queues: Vec<Vec<Tile>> = vec![Vec::new(); workers];
    let mut load = vec![0usize; workers];
    let mut sorted: Vec<Tile> = tiles.to_vec();
    sorted.sort_by_key(|t| std::cmp::Reverse(t.rows()));
    for t in sorted {
        let (idx, _) = load.iter().enumerate().min_by_key(|(_, &l)| l).unwrap();
        load[idx] += t.rows() * shape.k * shape.n;
        queues[idx].push(t);
    }
    queues
}

/// Imbalance of an assignment: max-load / mean-load (1.0 = perfect).
pub fn imbalance(queues: &[Vec<Tile>], shape: ShapeKey) -> f64 {
    let loads: Vec<f64> = queues
        .iter()
        .map(|q| q.iter().map(|t| (t.rows() * shape.k * shape.n) as f64).sum())
        .collect();
    let max = loads.iter().cloned().fold(0.0, f64::max);
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: usize) -> ShapeKey {
        ShapeKey { m, k: 64, n: 64 }
    }

    #[test]
    fn tiles_cover_all_rows_disjointly() {
        let ts = tiles_of(1000, 96);
        assert_eq!(ts.first().unwrap().row_start, 0);
        assert_eq!(ts.last().unwrap().row_end, 1000);
        for w in ts.windows(2) {
            assert_eq!(w[0].row_end, w[1].row_start);
        }
        assert_eq!(ts.iter().map(Tile::rows).sum::<usize>(), 1000);
        // Last tile is the remainder.
        assert_eq!(ts.last().unwrap().rows(), 1000 % 96);
    }

    #[test]
    fn exact_division_has_uniform_tiles() {
        let ts = tiles_of(192, 96);
        assert_eq!(ts.len(), 2);
        assert!(ts.iter().all(|t| t.rows() == 96));
    }

    #[test]
    fn assignment_covers_all_tiles() {
        let ts = tiles_of(1000, 64);
        let qs = assign(&ts, key(1000), 4);
        assert_eq!(qs.len(), 4);
        let total: usize = qs.iter().map(|q| q.iter().map(Tile::rows).sum::<usize>()).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn assignment_is_balanced() {
        let ts = tiles_of(32 * 176, 176); // the 910A regime: 32 equal blocks
        let qs = assign(&ts, key(32 * 176), 32);
        assert!((imbalance(&qs, key(32 * 176)) - 1.0).abs() < 1e-12);
        // Uneven case stays within one tile of perfect.
        let ts = tiles_of(33 * 176, 176);
        let qs = assign(&ts, key(33 * 176), 32);
        let imb = imbalance(&qs, key(33 * 176));
        assert!(imb <= 2.0, "imbalance {imb}");
    }

    #[test]
    fn single_worker_gets_everything() {
        let ts = tiles_of(500, 128);
        let qs = assign(&ts, key(500), 1);
        assert_eq!(qs[0].len(), ts.len());
    }
}
