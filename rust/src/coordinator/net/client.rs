//! [`NetClient`]: a small blocking HTTP/1.1 client for the wire front
//! door — what the wire tests and the `serving_load` bench drive their
//! traffic through, and a usable library client for anything else that
//! wants to talk to a [`super::NetServer`] without pulling in an HTTP
//! stack.
//!
//! The client keeps one keep-alive connection and reconnects lazily:
//! the server closes the connection after any framing error and after
//! `Connection: close`, so after a non-2xx reply or a transport error
//! the cached socket is dropped and the next call dials fresh.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Duration;

use crate::coordinator::net::http;
use crate::util::mat::Matrix;

/// Client-side failure talking to the wire front door.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The server answered with a non-success status: the typed
    /// mapping of [`crate::gemm::error::GemmError`] (503 overloaded,
    /// 504 timeout, ...) or a framing status (400/408/413/431).
    Status {
        /// HTTP status code.
        code: u16,
        /// The server's `x-error-kind` slug (empty if absent).
        kind: String,
        /// The plain-text error body, trimmed.
        message: String,
    },
    /// Transport-level failure (connect, send, or a dropped socket).
    Io(String),
    /// The reply arrived but violated the protocol (bad framing,
    /// missing headers, wrong body size).
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Status { code, kind, message } => {
                write!(f, "server status {code} ({kind}): {message}")
            }
            WireError::Io(m) => write!(f, "wire i/o: {m}"),
            WireError::Protocol(m) => write!(f, "wire protocol: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Per-request knobs a wire client can set, mirroring
/// [`crate::coordinator::server::RequestOpts`] as headers.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireOpts {
    /// `X-Backend`: fixed precision path by name (`fp32`, `cube`, ...).
    pub backend: Option<&'static str>,
    /// `X-Precision`: relative-error budget for tier selection.
    pub precision: Option<f64>,
    /// `X-Timeout-Ms`: end-to-end budget for this request on the
    /// server side.
    pub timeout_ms: Option<u64>,
}

/// A successful `/gemm` reply.
#[derive(Debug, Clone)]
pub struct WireReply {
    /// The result matrix, bit-identical to the in-process path.
    pub c: Matrix<f32>,
    /// The precision path the policy (or the `X-Backend` pin) chose.
    pub backend: String,
    /// The cube scaling exponent used.
    pub scale_exp: i32,
    /// Server-side latency in microseconds (submission to reply).
    pub latency_us: f64,
}

/// Blocking wire client; see the module docs for connection handling.
pub struct NetClient {
    addr: String,
    read_timeout: Duration,
    conn: Option<(BufReader<TcpStream>, TcpStream)>,
}

/// Client-side cap on a buffered reply body (a result matrix of this
/// size would already have failed the server's own body cap).
const MAX_REPLY_BODY: usize = 256 << 20;

impl NetClient {
    /// A client for the front door at `addr` (e.g. `"127.0.0.1:8080"`).
    /// Dials lazily on first use.
    pub fn connect(addr: impl Into<String>) -> NetClient {
        NetClient { addr: addr.into(), read_timeout: Duration::from_secs(30), conn: None }
    }

    /// Override the client's reply-wait deadline (default 30 s).
    pub fn with_read_timeout(mut self, t: Duration) -> NetClient {
        self.read_timeout = t;
        self
    }

    fn ensure(&mut self) -> Result<&mut (BufReader<TcpStream>, TcpStream), WireError> {
        if self.conn.is_none() {
            let stream =
                TcpStream::connect(&self.addr).map_err(|e| WireError::Io(e.to_string()))?;
            stream
                .set_read_timeout(Some(self.read_timeout))
                .map_err(|e| WireError::Io(e.to_string()))?;
            let _ = stream.set_nodelay(true);
            let writer = stream.try_clone().map_err(|e| WireError::Io(e.to_string()))?;
            self.conn = Some((BufReader::new(stream), writer));
        }
        Ok(self.conn.as_mut().expect("just connected"))
    }

    /// One request/reply exchange; non-2xx becomes
    /// [`WireError::Status`] and drops the cached connection (the
    /// server closes after errors).
    fn call(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, String)],
        body: &[u8],
    ) -> Result<(Vec<(String, String)>, Vec<u8>), WireError> {
        let (reader, writer) = self.ensure()?;
        let sent = http::write_request(writer, method, path, headers, body);
        let read = sent
            .map_err(|e| WireError::Io(e.to_string()))
            .and_then(|()| {
                http::read_response(reader, MAX_REPLY_BODY)
                    .map_err(|e| WireError::Protocol(e.to_string()))
            });
        match read {
            Ok((status, headers, body)) if (200..300).contains(&status) => Ok((headers, body)),
            Ok((status, headers, body)) => {
                self.conn = None;
                let kind = headers
                    .iter()
                    .find(|(k, _)| k == "x-error-kind")
                    .map(|(_, v)| v.clone())
                    .unwrap_or_default();
                let message = String::from_utf8_lossy(&body).trim().to_string();
                Err(WireError::Status { code: status, kind, message })
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }

    /// Liveness probe: `GET /healthz`.
    pub fn healthz(&mut self) -> Result<bool, WireError> {
        let (_, body) = self.call("GET", "/healthz", &[], &[])?;
        Ok(body.starts_with(b"ok"))
    }

    /// The server's `text/plain` metrics dump (`GET /metrics`).
    pub fn metrics(&mut self) -> Result<String, WireError> {
        let (_, body) = self.call("GET", "/metrics", &[], &[])?;
        String::from_utf8(body).map_err(|_| WireError::Protocol("non-UTF-8 metrics".into()))
    }

    /// Register a weight matrix (`POST /register`); returns the
    /// [`WeightId`] value to pass to [`NetClient::gemm_weight`].
    ///
    /// [`WeightId`]: crate::coordinator::request::WeightId
    pub fn register(&mut self, b: &Matrix<f32>) -> Result<u64, WireError> {
        let headers = [
            ("x-b-rows", b.rows().to_string()),
            ("x-b-cols", b.cols().to_string()),
        ];
        let (headers, _) =
            self.call("POST", "/register", &headers, &http::f32s_to_le(b.as_slice()))?;
        let id = headers
            .iter()
            .find(|(k, _)| k == "x-weight-id")
            .map(|(_, v)| v.as_str())
            .ok_or_else(|| WireError::Protocol("register reply without x-weight-id".into()))?;
        id.parse::<u64>()
            .map_err(|_| WireError::Protocol(format!("bad x-weight-id: {id:?}")))
    }

    /// `POST /gemm` with an inline B operand.
    pub fn gemm(
        &mut self,
        a: &Matrix<f32>,
        b: &Matrix<f32>,
        opts: &WireOpts,
    ) -> Result<WireReply, WireError> {
        let mut headers = vec![
            ("x-a-rows", a.rows().to_string()),
            ("x-a-cols", a.cols().to_string()),
            ("x-b-rows", b.rows().to_string()),
            ("x-b-cols", b.cols().to_string()),
        ];
        push_opts(&mut headers, opts);
        let mut body = http::f32s_to_le(a.as_slice());
        body.extend_from_slice(&http::f32s_to_le(b.as_slice()));
        let reply = self.call("POST", "/gemm", &headers, &body)?;
        parse_gemm_reply(reply)
    }

    /// `POST /gemm` against a registered weight (register-then-serve).
    pub fn gemm_weight(
        &mut self,
        a: &Matrix<f32>,
        weight: u64,
        opts: &WireOpts,
    ) -> Result<WireReply, WireError> {
        let mut headers = vec![
            ("x-a-rows", a.rows().to_string()),
            ("x-a-cols", a.cols().to_string()),
            ("x-weight", weight.to_string()),
        ];
        push_opts(&mut headers, opts);
        let reply = self.call("POST", "/gemm", &headers, &http::f32s_to_le(a.as_slice()))?;
        parse_gemm_reply(reply)
    }
}

fn push_opts(headers: &mut Vec<(&str, String)>, opts: &WireOpts) {
    if let Some(b) = opts.backend {
        headers.push(("x-backend", b.to_string()));
    }
    if let Some(p) = opts.precision {
        headers.push(("x-precision", format!("{p:e}")));
    }
    if let Some(t) = opts.timeout_ms {
        headers.push(("x-timeout-ms", t.to_string()));
    }
}

fn parse_gemm_reply(
    (headers, body): (Vec<(String, String)>, Vec<u8>),
) -> Result<WireReply, WireError> {
    let find = |n: &str| headers.iter().find(|(k, _)| k == n).map(|(_, v)| v.as_str());
    let rows = find("x-rows")
        .and_then(|v| v.parse::<usize>().ok())
        .ok_or_else(|| WireError::Protocol("gemm reply without x-rows".into()))?;
    let cols = find("x-cols")
        .and_then(|v| v.parse::<usize>().ok())
        .ok_or_else(|| WireError::Protocol("gemm reply without x-cols".into()))?;
    let want = rows * cols * 4;
    if body.len() != want {
        return Err(WireError::Protocol(format!(
            "gemm reply body is {} bytes, want {want} ({rows} x {cols} f32)",
            body.len()
        )));
    }
    Ok(WireReply {
        c: Matrix::from_vec(rows, cols, http::f32s_from_le(&body)),
        backend: find("x-backend").unwrap_or("").to_string(),
        scale_exp: find("x-scale-exp").and_then(|v| v.parse().ok()).unwrap_or(0),
        latency_us: find("x-latency-us").and_then(|v| v.parse().ok()).unwrap_or(0.0),
    })
}
