//! Minimal HTTP/1.1 framing for the wire front door: just the subset
//! the protocol needs — request/status lines, `name: value` headers,
//! `Content-Length`-framed bodies — with hard bounds (header bytes,
//! body bytes) and typed errors so the server can answer truncation,
//! oversize and read-deadline conditions with the right status instead
//! of hanging or dying. Chunked transfer encoding is deliberately not
//! implemented (501): both sides of this protocol always know the body
//! length up front.

use std::io::{BufRead, Write};

/// Cap on the total request-head bytes (request line + headers); a
/// head larger than this is answered `431`.
pub const MAX_HEADER_BYTES: usize = 8192;

/// One parsed request: method, path, lower-cased headers, raw body.
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method, upper-case as received (`GET`, `POST`, ...).
    pub method: String,
    /// Request path (`/gemm`, `/metrics`, ...). Query strings are not
    /// split off — the protocol does not use them.
    pub path: String,
    /// Headers in arrival order; names lower-cased, values trimmed.
    pub headers: Vec<(String, String)>,
    /// Raw body, exactly `Content-Length` bytes.
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First value of header `name` (give it lower-case), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to close the connection after this
    /// exchange (`Connection: close`).
    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Typed framing failures, each mapped to a status by the server (or
/// surfaced as a protocol error by the client).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HttpError {
    /// The peer closed the connection cleanly before starting a
    /// request — not an error condition, just end-of-stream.
    Closed,
    /// The read deadline (`SO_RCVTIMEO`) expired mid-exchange: a slow
    /// or stalled client. Answered `408`.
    TimedOut,
    /// Malformed request line, header, or a body cut short by EOF
    /// (truncated frame). Answered `400`.
    BadRequest(String),
    /// Declared `Content-Length` over the configured body cap.
    /// Answered `413` without reading the body.
    PayloadTooLarge {
        /// Declared body length.
        length: usize,
        /// The configured cap it exceeded.
        limit: usize,
    },
    /// Request head over [`MAX_HEADER_BYTES`]. Answered `431`.
    HeadersTooLarge,
    /// A framing feature this subset does not speak (chunked transfer
    /// encoding). Answered `501`.
    NotImplemented(String),
    /// Any other socket-level failure; the connection is dropped.
    Io(String),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => write!(f, "connection closed"),
            HttpError::TimedOut => write!(f, "read deadline expired"),
            HttpError::BadRequest(m) => write!(f, "bad request: {m}"),
            HttpError::PayloadTooLarge { length, limit } => {
                write!(f, "body of {length} bytes exceeds the {limit}-byte limit")
            }
            HttpError::HeadersTooLarge => {
                write!(f, "request head exceeds {MAX_HEADER_BYTES} bytes")
            }
            HttpError::NotImplemented(m) => write!(f, "not implemented: {m}"),
            HttpError::Io(m) => write!(f, "i/o error: {m}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// The status line and reason the server answers `e` with, or `None`
/// when no response can be written (clean close, transport failure).
pub fn status_for(e: &HttpError) -> Option<(u16, &'static str)> {
    match e {
        HttpError::Closed | HttpError::Io(_) => None,
        HttpError::TimedOut => Some((408, "Request Timeout")),
        HttpError::BadRequest(_) => Some((400, "Bad Request")),
        HttpError::PayloadTooLarge { .. } => Some((413, "Payload Too Large")),
        HttpError::HeadersTooLarge => Some((431, "Request Header Fields Too Large")),
        HttpError::NotImplemented(_) => Some((501, "Not Implemented")),
    }
}

fn io_to_http(e: std::io::Error) -> HttpError {
    match e.kind() {
        // SO_RCVTIMEO surfaces as WouldBlock on Unix, TimedOut on
        // Windows; either way it is the read deadline.
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => HttpError::TimedOut,
        std::io::ErrorKind::UnexpectedEof => {
            HttpError::BadRequest("truncated frame: peer closed mid-body".into())
        }
        k => HttpError::Io(format!("{k}: {e}")),
    }
}

/// Read one `\n`-terminated line (CRLF tolerated), stripped; `None` on
/// clean EOF before the first byte. `total` accumulates head bytes for
/// the [`MAX_HEADER_BYTES`] bound.
fn read_line(r: &mut impl BufRead, total: &mut usize) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    match r.read_until(b'\n', &mut buf) {
        Ok(0) => Ok(None),
        Ok(n) => {
            *total += n;
            if *total > MAX_HEADER_BYTES {
                return Err(HttpError::HeadersTooLarge);
            }
            if buf.last() == Some(&b'\n') {
                buf.pop();
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            String::from_utf8(buf)
                .map(Some)
                .map_err(|_| HttpError::BadRequest("non-UTF-8 request head".into()))
        }
        Err(e) => Err(io_to_http(e)),
    }
}

/// Read the head lines and body shared by requests and responses:
/// returns (headers, body) once the start line has been consumed.
fn read_head_and_body(
    r: &mut impl BufRead,
    total: &mut usize,
    max_body: usize,
) -> Result<(Vec<(String, String)>, Vec<u8>), HttpError> {
    let mut headers = Vec::new();
    loop {
        let line = match read_line(r, total)? {
            None => return Err(HttpError::BadRequest("truncated head: EOF before body".into())),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::BadRequest(format!("header without ':': {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let find = |n: &str| headers.iter().find(|(k, _)| k == n).map(|(_, v)| v.as_str());
    if find("transfer-encoding").is_some() {
        return Err(HttpError::NotImplemented("transfer-encoding (use Content-Length)".into()));
    }
    let length = match find("content-length") {
        None => 0usize,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::BadRequest(format!("bad Content-Length: {v:?}")))?,
    };
    if length > max_body {
        return Err(HttpError::PayloadTooLarge { length, limit: max_body });
    }
    let mut body = vec![0u8; length];
    r.read_exact(&mut body).map_err(io_to_http)?;
    Ok((headers, body))
}

/// Read one request off the connection. [`HttpError::Closed`] means
/// the peer hung up cleanly between requests (keep-alive end); every
/// other error is answered per [`status_for`].
pub fn read_request(r: &mut impl BufRead, max_body: usize) -> Result<HttpRequest, HttpError> {
    let mut total = 0usize;
    let start = loop {
        match read_line(r, &mut total)? {
            None => return Err(HttpError::Closed),
            // Robustness: tolerate stray blank lines before the
            // request line (RFC 9112 §2.2).
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = start.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => return Err(HttpError::BadRequest(format!("malformed request line: {start:?}"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::BadRequest(format!("unsupported version: {version:?}")));
    }
    let (headers, body) = read_head_and_body(r, &mut total, max_body)?;
    Ok(HttpRequest { method, path, headers, body })
}

/// Read one response off the connection: (status, headers, body).
/// `max_body` bounds what the client will buffer.
pub fn read_response(
    r: &mut impl BufRead,
    max_body: usize,
) -> Result<(u16, Vec<(String, String)>, Vec<u8>), HttpError> {
    let mut total = 0usize;
    let start = match read_line(r, &mut total)? {
        None => return Err(HttpError::Closed),
        Some(l) => l,
    };
    let mut parts = start.split_whitespace();
    let status = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse::<u16>()
            .map_err(|_| HttpError::BadRequest(format!("bad status code: {code:?}")))?,
        _ => return Err(HttpError::BadRequest(format!("malformed status line: {start:?}"))),
    };
    let (headers, body) = read_head_and_body(r, &mut total, max_body)?;
    Ok((status, headers, body))
}

/// Write one response (status line, headers, `Content-Length`, body)
/// and flush.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!("HTTP/1.1 {status} {reason}\r\n");
    for (k, v) in headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Write one request (request line, headers, `Content-Length`, body)
/// and flush.
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    path: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!("{method} {path} HTTP/1.1\r\n");
    for (k, v) in headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Decode a little-endian `f32` body. The caller has already validated
/// `bytes.len()` against the expected element count, so a ragged tail
/// (`len % 4 != 0`) can only mean a framing bug — it is dropped.
pub fn f32s_from_le(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Encode `f32`s as the little-endian wire body.
pub fn f32s_to_le(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &[u8], max_body: usize) -> Result<HttpRequest, HttpError> {
        read_request(&mut BufReader::new(raw), max_body)
    }

    #[test]
    fn parses_a_request_with_body() {
        let raw = b"POST /gemm HTTP/1.1\r\nX-A-Rows: 2\r\ncontent-length: 4\r\n\r\nabcd";
        let req = parse(raw, 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/gemm");
        assert_eq!(req.header("x-a-rows"), Some("2"));
        assert_eq!(req.body, b"abcd");
        assert!(!req.wants_close());
    }

    #[test]
    fn tolerates_bare_lf_and_preamble_blank_lines() {
        let raw = b"\r\n\nGET /healthz HTTP/1.0\nconnection: Close\n\n";
        let req = parse(raw, 0).unwrap();
        assert_eq!((req.method.as_str(), req.path.as_str()), ("GET", "/healthz"));
        assert!(req.wants_close());
    }

    #[test]
    fn clean_eof_is_closed_truncation_is_bad_request() {
        assert_eq!(parse(b"", 0), Err(HttpError::Closed));
        // Head cut off before the blank line.
        assert!(matches!(
            parse(b"POST /gemm HTTP/1.1\r\nx: 1\r\n", 0),
            Err(HttpError::BadRequest(_))
        ));
        // Body shorter than Content-Length (truncated frame).
        assert!(matches!(
            parse(b"POST /gemm HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc", 1024),
            Err(HttpError::BadRequest(_))
        ));
    }

    #[test]
    fn typed_limits_and_unsupported_framing() {
        assert_eq!(
            parse(b"POST /g HTTP/1.1\r\ncontent-length: 100\r\n\r\n", 10),
            Err(HttpError::PayloadTooLarge { length: 100, limit: 10 })
        );
        let mut big = b"GET / HTTP/1.1\r\n".to_vec();
        big.extend(std::iter::repeat(b'a').take(MAX_HEADER_BYTES));
        assert_eq!(parse(&big, 0), Err(HttpError::HeadersTooLarge));
        assert!(matches!(
            parse(b"POST /g HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n", 10),
            Err(HttpError::NotImplemented(_))
        ));
        assert!(matches!(parse(b"GET / SPDY/9\r\n\r\n", 0), Err(HttpError::BadRequest(_))));
        assert!(matches!(
            parse(b"POST /g HTTP/1.1\r\ncontent-length: nope\r\n\r\n", 10),
            Err(HttpError::BadRequest(_))
        ));
    }

    #[test]
    fn response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "OK", &[("x-rows", "3".into())], b"xyz").unwrap();
        let (status, headers, body) =
            read_response(&mut BufReader::new(wire.as_slice()), 1024).unwrap();
        assert_eq!(status, 200);
        assert_eq!(headers.iter().find(|(k, _)| k == "x-rows").unwrap().1, "3");
        assert_eq!(body, b"xyz");
    }

    #[test]
    fn request_roundtrip() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/register", &[("x-b-rows", "4".into())], b"pp").unwrap();
        let req = parse(&wire, 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/register");
        assert_eq!(req.header("x-b-rows"), Some("4"));
        assert_eq!(req.body, b"pp");
    }

    #[test]
    fn f32_codec_roundtrip() {
        let vals = [0.0f32, -1.5, f32::MIN_POSITIVE, 3.0e38, -0.0];
        let bytes = f32s_to_le(&vals);
        assert_eq!(bytes.len(), vals.len() * 4);
        let back = f32s_from_le(&bytes);
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
