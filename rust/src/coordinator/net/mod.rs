//! The wire front door: a hand-rolled HTTP/1.1-over-TCP layer for the
//! GEMM service — the measurement harness every scale claim runs
//! through, in the same hermetic-build discipline as the `anyhow`/`xla`
//! vendoring (no tokio, no hyper, nothing new vendored; `std::net`
//! blocking sockets plus the repo's own thread primitives).
//!
//! Layout:
//!
//! * [`http`] — minimal HTTP/1.1 framing: request/response parse and
//!   write with `Content-Length` bodies, bounded headers, typed errors
//!   for truncation / oversize / read-deadline, and the little-endian
//!   `f32` body codec both sides share.
//! * [`server`] — [`NetServer`]: a non-blocking accept loop on a
//!   [`crate::exec::pool::spawn_named`] control thread, one dedicated
//!   connection thread per client (bounded; over the bound the server
//!   answers 503 at accept — connection handlers must *not* occupy the
//!   executor pool, they block on reply channels whose batch tasks run
//!   there), requests decoded straight into the existing service entry
//!   points ([`crate::coordinator::server::GemmService`]).
//! * [`client`] — [`NetClient`]: a small blocking client used by the
//!   wire tests and the `serving_load` bench (and usable as a library
//!   client), speaking exactly the protocol below.
//!
//! **Protocol.** Matrices travel as raw little-endian `f32`, row-major;
//! dimensions and options ride in headers, so the body is pure payload:
//!
//! | endpoint | body | headers |
//! |----------|------|---------|
//! | `POST /gemm` | A (then B when inline) | `X-A-Rows`, `X-A-Cols`; `X-Weight` *or* `X-B-Rows` + `X-B-Cols`; optional `X-Backend`, `X-Precision`, `X-Timeout-Ms` |
//! | `POST /register` | B | `X-B-Rows`, `X-B-Cols`; reply carries `X-Weight-Id` |
//! | `GET /metrics` | — | reply is the `text/plain` counter dump of [`crate::coordinator::metrics`] |
//! | `GET /healthz` | — | liveness: `200 ok` |
//!
//! A `/gemm` reply is the result matrix in the same encoding
//! (`X-Rows`/`X-Cols`/`X-Backend`/`X-Scale-Exp`/`X-Latency-Us`
//! headers). Service errors map to typed statuses: shape mismatch →
//! 400, unknown weight → 404, admission shed ([`Overloaded`]) → 503,
//! deadline expiry ([`Timeout`]) → 504, execution faults → 500; framing
//! errors map to 400 (truncated body), 408 (read deadline), 413
//! (oversized body), 431 (oversized headers). The wire path calls the
//! same deadline-budgeted blocking helpers as in-process callers, so
//! responses are bit-identical to [`GemmService::gemm_blocking`] and
//! the `tests/chaos.rs` failpoint scenarios hold over the socket.
//!
//! [`Overloaded`]: crate::gemm::error::GemmError::Overloaded
//! [`Timeout`]: crate::gemm::error::GemmError::Timeout
//! [`GemmService::gemm_blocking`]: crate::coordinator::server::GemmService::gemm_blocking

pub mod client;
pub mod http;
pub mod server;

pub use client::{NetClient, WireError, WireOpts, WireReply};
pub use server::{NetConfig, NetServer};
