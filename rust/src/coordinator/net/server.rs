//! [`NetServer`]: the TCP listener and connection loop of the wire
//! front door.
//!
//! Threading follows the repo's executor discipline
//! ([`crate::exec::pool`] module docs): the accept loop and every
//! connection handler run on **dedicated control threads**
//! ([`crate::exec::pool::spawn_named`]), never on the shared executor
//! pool — a handler blocks inside [`GemmService::gemm_blocking_opts`]
//! waiting for a reply produced by a batch task *on that pool*, so
//! parking handlers there could deadlock the service under load. The
//! accept socket is non-blocking and polled with a short sleep so
//! shutdown needs no self-connect tricks; connection sockets are
//! blocking with an `SO_RCVTIMEO` read deadline, which is what turns a
//! stalled client into a typed `408` instead of a leaked thread.
//!
//! Admission is bounded twice: [`NetConfig::max_connections`] caps
//! handler threads (over the cap the server answers `503` at accept
//! and closes — wire-level load shedding), and inside a connection the
//! service's own `max_pending` admission can shed a `/gemm` with the
//! typed [`GemmError::Overloaded`] → `503`.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::net::http::{self, HttpError, HttpRequest};
use crate::coordinator::request::WeightId;
use crate::coordinator::server::{GemmService, RequestOpts};
use crate::exec::pool;
use crate::gemm::backend::Backend;
use crate::gemm::error::GemmError;
use crate::util::mat::Matrix;

/// Wire front-door configuration (`[net]` section of the config file;
/// see [`crate::config::schema::NetSection`]).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address (`[net] listen`). Port 0 picks an ephemeral port —
    /// the tests' and bench's default; read it back with
    /// [`NetServer::local_addr`].
    pub listen: String,
    /// Request-body cap in bytes (`[net] max_body_mb`); a larger
    /// declared `Content-Length` is answered `413` without reading the
    /// body.
    pub max_body: usize,
    /// Per-connection socket read deadline (`[net] read_timeout_ms`):
    /// a client that stalls mid-request this long gets `408` and the
    /// connection is closed; an *idle* keep-alive connection is closed
    /// silently.
    pub read_timeout: Duration,
    /// Concurrent connection cap (`[net] max_connections`); accepts
    /// over the cap are answered `503` and closed immediately.
    pub max_connections: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: "127.0.0.1:0".to_string(),
            max_body: 64 << 20,
            read_timeout: Duration::from_secs(10),
            max_connections: 64,
        }
    }
}

/// Handle to a running wire front door; dropping it (or calling
/// [`NetServer::shutdown`]) stops the accept loop. Connection handler
/// threads drain on their own as clients disconnect or their read
/// deadline fires.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicUsize>,
    accept: Mutex<Option<JoinHandle<()>>>,
}

impl NetServer {
    /// Bind `cfg.listen` and start accepting; requests are served
    /// against `svc`. The service handle is shared — in-process callers
    /// and wire clients see the same weights, metrics and admission.
    pub fn bind(svc: Arc<GemmService>, cfg: NetConfig) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(&cfg.listen)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(AtomicUsize::new(0));
        let accept = {
            let (stop, conns) = (Arc::clone(&stop), Arc::clone(&conns));
            pool::spawn_named("net-accept", move || accept_loop(&listener, &svc, &cfg, &stop, &conns))
        };
        Ok(NetServer { addr, stop, conns, accept: Mutex::new(Some(accept)) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections currently being served.
    pub fn active_connections(&self) -> usize {
        self.conns.load(Ordering::SeqCst)
    }

    /// Stop accepting and join the accept loop. Idempotent; callable
    /// through a shared reference. Live connection handlers finish
    /// their current exchange and exit at the next keep-alive
    /// boundary (or their read deadline).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Decrements the live-connection counter when a handler exits —
/// including by panic, so the cap can never leak shut.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: &TcpListener,
    svc: &Arc<GemmService>,
    cfg: &NetConfig,
    stop: &Arc<AtomicBool>,
    conns: &Arc<AtomicUsize>,
) {
    let mut next_conn = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Wire-level admission: past the cap, shed at accept
                // with a typed 503 instead of queueing handler threads
                // without bound.
                if conns.fetch_add(1, Ordering::SeqCst) >= cfg.max_connections.max(1) {
                    conns.fetch_sub(1, Ordering::SeqCst);
                    let mut s = stream;
                    let _ = http::write_response(
                        &mut s,
                        503,
                        "Service Unavailable",
                        &[("x-error-kind", "overloaded".into()), ("connection", "close".into())],
                        b"connection limit reached\n",
                    );
                    continue;
                }
                next_conn += 1;
                let svc = Arc::clone(svc);
                let cfg = cfg.clone();
                let stop = Arc::clone(stop);
                let guard = ConnGuard(Arc::clone(conns));
                // Detached: the handle is dropped, the guard above ties
                // the counter to the thread's lifetime.
                let _ = pool::spawn_named(&format!("net-conn-{next_conn}"), move || {
                    let _guard = guard;
                    handle_connection(stream, &svc, &cfg, &stop);
                });
            }
            // Non-blocking accept: nothing pending — poll again after a
            // short sleep (cheap enough at the front door; the data
            // path is on the connection threads).
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            // Transient accept failure (EMFILE, ECONNABORTED, ...):
            // back off briefly and keep serving.
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Serve one connection: keep-alive request loop, typed error replies,
/// close on framing errors (the stream position is untrustworthy after
/// one) and on `Connection: close`.
fn handle_connection(stream: TcpStream, svc: &GemmService, cfg: &NetConfig, stop: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    while !stop.load(Ordering::SeqCst) {
        match http::read_request(&mut reader, cfg.max_body) {
            Ok(req) => {
                let close = req.wants_close();
                let (status, reason, mut headers, body) = route(&req, svc);
                if close {
                    headers.push(("connection", "close".into()));
                }
                if http::write_response(&mut writer, status, reason, &headers, &body).is_err() {
                    return;
                }
                if close {
                    return;
                }
            }
            Err(HttpError::Closed) => return,
            Err(e) => {
                if let Some((status, reason)) = http::status_for(&e) {
                    let headers = [
                        ("x-error-kind", error_kind_of_http(&e).to_string()),
                        ("connection", "close".to_string()),
                    ];
                    let _ = http::write_response(
                        &mut writer,
                        status,
                        reason,
                        &headers,
                        format!("{e}\n").as_bytes(),
                    );
                }
                return;
            }
        }
    }
}

/// One response: (status, reason, headers, body).
type Reply = (u16, &'static str, Vec<(&'static str, String)>, Vec<u8>);

fn route(req: &HttpRequest, svc: &GemmService) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/gemm") => handle_gemm(req, svc),
        ("POST", "/register") => handle_register(req, svc),
        ("GET", "/metrics") => {
            let body = metrics_body(svc);
            (200, "OK", vec![("content-type", "text/plain".into())], body.into_bytes())
        }
        ("GET", "/healthz") => {
            (200, "OK", vec![("content-type", "text/plain".into())], b"ok\n".to_vec())
        }
        ("POST", "/metrics" | "/healthz") | ("GET", "/gemm" | "/register") => (
            405,
            "Method Not Allowed",
            vec![("x-error-kind", "method-not-allowed".into())],
            format!("{} not allowed on {}\n", req.method, req.path).into_bytes(),
        ),
        (_, path) => (
            404,
            "Not Found",
            vec![("x-error-kind", "unknown-path".into())],
            format!("no such endpoint: {path}\n").into_bytes(),
        ),
    }
}

fn bad_request(msg: String) -> Reply {
    (400, "Bad Request", vec![("x-error-kind", "bad-request".into())], (msg + "\n").into_bytes())
}

/// Parse a required dimension header as `usize`.
fn dim(req: &HttpRequest, name: &str) -> Result<usize, Reply> {
    match req.header(name) {
        None => Err(bad_request(format!("missing required header {name}"))),
        Some(v) => {
            v.parse::<usize>().map_err(|_| bad_request(format!("bad {name}: {v:?} (want usize)")))
        }
    }
}

/// Parse the optional per-request knobs shared by `/gemm` requests.
fn request_opts(req: &HttpRequest) -> Result<RequestOpts, Reply> {
    let backend = match req.header("x-backend") {
        None => None,
        Some(v) => Some(Backend::parse(v).ok_or_else(|| {
            bad_request(format!(
                "unknown x-backend: {v:?} (one of {})",
                Backend::ALL.map(|b| b.name()).join(", ")
            ))
        })?),
    };
    let precision = match req.header("x-precision") {
        None => None,
        Some(v) => Some(
            v.parse::<f64>().map_err(|_| bad_request(format!("bad x-precision: {v:?}")))?,
        ),
    };
    let timeout = match req.header("x-timeout-ms") {
        None => None,
        Some(v) => Some(Duration::from_millis(
            v.parse::<u64>().map_err(|_| bad_request(format!("bad x-timeout-ms: {v:?}")))?,
        )),
    };
    Ok(RequestOpts { backend, precision, timeout })
}

/// `rows * cols * 4` with overflow turned into a typed 400.
fn body_bytes(rows: usize, cols: usize, what: &str) -> Result<usize, Reply> {
    rows.checked_mul(cols)
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| bad_request(format!("{what} dimensions overflow: {rows} x {cols}")))
}

fn handle_gemm(req: &HttpRequest, svc: &GemmService) -> Reply {
    let (a_rows, a_cols) = match (dim(req, "x-a-rows"), dim(req, "x-a-cols")) {
        (Ok(r), Ok(c)) => (r, c),
        (Err(e), _) | (_, Err(e)) => return e,
    };
    let opts = match request_opts(req) {
        Ok(o) => o,
        Err(e) => return e,
    };
    let a_bytes = match body_bytes(a_rows, a_cols, "A") {
        Ok(b) => b,
        Err(e) => return e,
    };
    let outcome = if let Some(w) = req.header("x-weight") {
        // Register-then-serve: the body is A alone, B is the weight.
        let id = match w.parse::<u64>() {
            Ok(id) => id,
            Err(_) => return bad_request(format!("bad x-weight: {w:?} (want u64)")),
        };
        if req.body.len() != a_bytes {
            return bad_request(format!(
                "body is {} bytes, want {a_bytes} ({a_rows} x {a_cols} f32 A)",
                req.body.len()
            ));
        }
        let a = Matrix::from_vec(a_rows, a_cols, http::f32s_from_le(&req.body));
        svc.gemm_blocking_prepacked_opts(a, WeightId(id), opts)
    } else {
        // Inline B appended to A in the body.
        let (b_rows, b_cols) = match (dim(req, "x-b-rows"), dim(req, "x-b-cols")) {
            (Ok(r), Ok(c)) => (r, c),
            (Err(e), _) | (_, Err(e)) => return e,
        };
        let b_bytes = match body_bytes(b_rows, b_cols, "B") {
            Ok(b) => b,
            Err(e) => return e,
        };
        if req.body.len() != a_bytes + b_bytes {
            return bad_request(format!(
                "body is {} bytes, want {} ({a_rows} x {a_cols} A + {b_rows} x {b_cols} B, f32)",
                req.body.len(),
                a_bytes + b_bytes
            ));
        }
        let a = Matrix::from_vec(a_rows, a_cols, http::f32s_from_le(&req.body[..a_bytes]));
        let b = Matrix::from_vec(b_rows, b_cols, http::f32s_from_le(&req.body[a_bytes..]));
        svc.gemm_blocking_opts(a, b, opts)
    };
    // Submit-time and execution errors alike map to one typed status.
    let resp = match outcome {
        Ok(resp) => resp,
        Err(e) => return error_reply(&e),
    };
    let (backend, scale_exp, latency) = (resp.backend, resp.scale_exp, resp.latency);
    match resp.result {
        Ok(c) => {
            let headers = vec![
                ("x-rows", c.rows().to_string()),
                ("x-cols", c.cols().to_string()),
                ("x-backend", backend.name().to_string()),
                ("x-scale-exp", scale_exp.to_string()),
                ("x-latency-us", format!("{:.0}", latency * 1e6)),
                ("content-type", "application/octet-stream".into()),
            ];
            (200, "OK", headers, http::f32s_to_le(c.as_slice()))
        }
        Err(e) => error_reply(&e),
    }
}

fn handle_register(req: &HttpRequest, svc: &GemmService) -> Reply {
    let (b_rows, b_cols) = match (dim(req, "x-b-rows"), dim(req, "x-b-cols")) {
        (Ok(r), Ok(c)) => (r, c),
        (Err(e), _) | (_, Err(e)) => return e,
    };
    let b_bytes = match body_bytes(b_rows, b_cols, "B") {
        Ok(b) => b,
        Err(e) => return e,
    };
    if req.body.len() != b_bytes {
        return bad_request(format!(
            "body is {} bytes, want {b_bytes} ({b_rows} x {b_cols} f32 B)",
            req.body.len()
        ));
    }
    let b = Matrix::from_vec(b_rows, b_cols, http::f32s_from_le(&req.body));
    let id = svc.register_weights(b);
    (200, "OK", vec![("x-weight-id", id.0.to_string())], Vec::new())
}

/// The `text/plain` counter dump `/metrics` serves: one `name value`
/// pair per line (stable names, easy to scrape), preceded by the
/// human-readable one-liner as a comment.
fn metrics_body(svc: &GemmService) -> String {
    let r = svc.metrics().report();
    let mut out = format!("# {}\n", r.line());
    let mut push = |name: &str, v: String| {
        out.push_str(name);
        out.push(' ');
        out.push_str(&v);
        out.push('\n');
    };
    push("requests_total", r.requests.to_string());
    push("batches_total", r.batches.to_string());
    push("errors_total", r.errors.to_string());
    push("shed_total", r.shed.to_string());
    push("timeouts_total", r.timeouts.to_string());
    push("retries_total", r.retries.to_string());
    push("failovers_total", r.failovers.to_string());
    push("pool_steals_total", r.pool_steals.to_string());
    push("pool_steal_fails_total", r.pool_steal_fails.to_string());
    push("mean_batch_size", format!("{:.3}", r.mean_batch_size));
    push("throughput_flops", format!("{:.3e}", r.flops_per_sec));
    if let (Some(p50), Some(p95), Some(p99)) = (r.p50, r.p95, r.p99) {
        push("latency_p50_s", format!("{p50:.6}"));
        push("latency_p95_s", format!("{p95:.6}"));
        push("latency_p99_s", format!("{p99:.6}"));
    }
    push("latency_samples_held", svc.metrics().latency_samples_held().to_string());
    out
}

/// Status mapping for the service's typed errors.
fn error_reply(e: &GemmError) -> Reply {
    let (status, reason) = match e {
        GemmError::ShapeMismatch { .. } => (400, "Bad Request"),
        GemmError::UnknownWeight(_) => (404, "Not Found"),
        GemmError::Overloaded { .. } => (503, "Service Unavailable"),
        GemmError::Timeout { .. } => (504, "Gateway Timeout"),
        GemmError::Panicked(_)
        | GemmError::ShardFailed { .. }
        | GemmError::ChannelClosed
        | GemmError::Injected(_) => (500, "Internal Server Error"),
    };
    let headers = vec![("x-error-kind", error_kind(e).to_string())];
    (status, reason, headers, format!("{e}\n").into_bytes())
}

/// Stable machine-readable kind slug for the `x-error-kind` header.
fn error_kind(e: &GemmError) -> &'static str {
    match e {
        GemmError::ShapeMismatch { .. } => "shape-mismatch",
        GemmError::UnknownWeight(_) => "unknown-weight",
        GemmError::Overloaded { .. } => "overloaded",
        GemmError::Timeout { .. } => "timeout",
        GemmError::Panicked(_) => "panicked",
        GemmError::ShardFailed { .. } => "shard-failed",
        GemmError::ChannelClosed => "channel-closed",
        GemmError::Injected(_) => "injected",
    }
}

/// Kind slug for framing-level errors (body of the 4xx/5xx reply).
fn error_kind_of_http(e: &HttpError) -> &'static str {
    match e {
        HttpError::Closed | HttpError::Io(_) => "io",
        HttpError::TimedOut => "read-deadline",
        HttpError::BadRequest(_) => "bad-request",
        HttpError::PayloadTooLarge { .. } => "payload-too-large",
        HttpError::HeadersTooLarge => "headers-too-large",
        HttpError::NotImplemented(_) => "not-implemented",
    }
}
