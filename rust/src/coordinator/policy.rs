//! Precision policy: pick the execution path and residual scaling from
//! the operands' dynamic range.
//!
//! This implements the input-dependent scaling the paper lists as future
//! work ("incorporating dynamic scaling for input-dependent
//! distributions"), grounded in Eq. (6):
//!
//! ```text
//! -24 + 22 - e_min  <=  s_b  <=  15 + 12 - e_max
//! ```
//!
//! * operands whose magnitudes exceed the FP16 range (`e_max > 15`)
//!   cannot use the FP16 cube path at all (Sec. 3.1);
//! * otherwise `s_b` is chosen inside the Eq. (6) window, preferring the
//!   paper's default 12, shrinking only when large inputs force it —
//!   with the upper bound tightened by one below Eq. (6)'s nominal
//!   `15 + 12 - e_max` to cover round-to-nearest *ties* (see
//!   `decide_ranges`);
//! * a caller-provided error budget selects the cheapest member of the
//!   precision-emulation family ([`crate::softfloat::family`]) whose
//!   derived bound meets it: one-pass FP16 when ~11 bits suffice
//!   (3× cheaper than the cube, Table 2 note), the FP16×2 cube by
//!   default, BF16×3 when the budget demands more than the cube's ~22
//!   bits, and the full-range BF16 tiers instead of the FP32 fallback
//!   when the operands leave the FP16 window but the budget is
//!   satisfiable at 16 (BF16×2) or 24 (BF16×3) bits.

use crate::gemm::backend::Backend;
use crate::gemm::prepacked::PrepackPath;
use crate::softfloat::family::SplitSpec;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;

/// What the policy decided for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyDecision {
    /// The precision path to execute.
    pub backend: Backend,
    /// Residual scaling exponent for cube paths (ignored otherwise).
    pub scale_exp: i32,
    /// Unbiased exponent range observed in the operands, if any finite
    /// non-zero entry exists.
    pub e_min: Option<i32>,
    /// Upper end of the same exponent range.
    pub e_max: Option<i32>,
}

impl PolicyDecision {
    /// The prepacked-operand format this decision executes against
    /// ([`crate::gemm::prepacked`]), or `None` for a path that must run
    /// from the raw matrix (every current backend is prepackable; a
    /// future path that is not — e.g. an out-of-process PJRT artifact —
    /// returns `None` from its match arm here). Mirrors the hot-path
    /// dispatch of [`crate::gemm::backend::GemmBackend::gemm`]: both
    /// cube accumulation orders run the fused blocked kernel, so they
    /// share one packed format.
    pub fn prepack_path(&self) -> Option<PrepackPath> {
        Some(match self.backend {
            Backend::Fp32 => PrepackPath::Fp32,
            Backend::Fp16 => PrepackPath::Fp16,
            Backend::CubeElementwise | Backend::CubeTermwise => {
                PrepackPath::Cube(SplitConfig::with_scale(self.scale_exp))
            }
            Backend::Bf16x2 | Backend::Bf16x3 => PrepackPath::Family(
                self.backend.family_spec().expect("bf16 tier has a family spec"),
            ),
        })
    }
}

/// Range-aware precision selection.
#[derive(Debug, Clone)]
pub struct PrecisionPolicy {
    /// Relative-error budget the caller can tolerate; `None` = best
    /// effort (always precision-recovery).
    pub error_budget: Option<f64>,
    /// Default backend for in-range inputs.
    pub default_backend: Backend,
}

impl Default for PrecisionPolicy {
    fn default() -> Self {
        PrecisionPolicy { error_budget: None, default_backend: Backend::CubeTermwise }
    }
}

/// Relative-error class a tier recovering `bits` mantissa bits can meet
/// (`2^-bits`), compared against the caller's budget. `bits` comes from
/// [`SplitSpec::bound_bits`] so the ladder tracks the family's derived
/// bounds rather than restating them.
fn tier_error(bits: f64) -> f64 {
    2f64.powf(-bits)
}

/// Unbiased exponent of a finite non-zero f32.
fn exponent_of(v: f32) -> Option<i32> {
    if v == 0.0 || !v.is_finite() {
        return None;
    }
    Some(((v.to_bits() >> 23) & 0xff) as i32 - 127)
}

/// Observed exponent range of a single matrix. For cache-stable operands
/// (registered weights) this is computed once at registration, so the
/// per-request policy scan only touches the activation operand.
pub fn matrix_exponent_range(m: &Matrix<f32>) -> (Option<i32>, Option<i32>) {
    let mut e_min = None;
    let mut e_max = None;
    for v in m.as_slice() {
        if let Some(e) = exponent_of(*v) {
            e_min = Some(e_min.map_or(e, |m: i32| m.min(e)));
            e_max = Some(e_max.map_or(e, |m: i32| m.max(e)));
        }
    }
    (e_min, e_max)
}

/// Union of two exponent ranges.
fn merge_ranges(
    x: (Option<i32>, Option<i32>),
    y: (Option<i32>, Option<i32>),
) -> (Option<i32>, Option<i32>) {
    let lo = match (x.0, y.0) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    };
    let hi = match (x.1, y.1) {
        (Some(a), Some(b)) => Some(a.max(b)),
        (a, b) => a.or(b),
    };
    (lo, hi)
}

/// Observed exponent range over both operands.
pub fn exponent_range(a: &Matrix<f32>, b: &Matrix<f32>) -> (Option<i32>, Option<i32>) {
    merge_ranges(matrix_exponent_range(a), matrix_exponent_range(b))
}

impl PrecisionPolicy {
    /// Decide the path for `(a, b)`.
    pub fn decide(&self, a: &Matrix<f32>, b: &Matrix<f32>) -> PolicyDecision {
        self.decide_ranges(matrix_exponent_range(a), matrix_exponent_range(b))
    }

    /// Decide from precomputed per-operand exponent ranges — the serving
    /// path for registered weights, whose range is recorded once at
    /// registration ([`crate::coordinator::request::WeightEntry`])
    /// instead of rescanned per request. `decide(a, b)` is exactly
    /// `decide_ranges(range(a), range(b))`, so routing is identical
    /// whether or not B is cached.
    pub fn decide_ranges(
        &self,
        a_range: (Option<i32>, Option<i32>),
        b_range: (Option<i32>, Option<i32>),
    ) -> PolicyDecision {
        let (e_min, e_max) = merge_ranges(a_range, b_range);
        let (lo, hi) = match (e_min, e_max) {
            (Some(lo), Some(hi)) => (lo, hi),
            _ => {
                // All zeros: any path is exact; use the cheapest.
                return PolicyDecision {
                    backend: Backend::Fp16,
                    scale_exp: 12,
                    e_min,
                    e_max,
                };
            }
        };

        // Out of the FP16 high-component range the scaled-FP16 scheme is
        // unusable (Sec 3.1: "inputs larger than the FP16 maximum may
        // overflow ..."). The low side is out too when *all* magnitudes
        // sit below 2^-12: there the high component is (or nearly is)
        // subnormal and the contiguous high+low mantissa tops out well
        // under 22 bits — growing s_b cannot recover it (both parts
        // would need scaling, which the paper leaves out of scope;
        // measured in `experiments::ablations::run_dynamic_scaling`).
        // BF16 components carry FP32's full exponent, so with an error
        // budget the full-range BF16 tiers take these inputs at 3 resp.
        // 6 cube passes; without one (best effort) the conservative
        // FP32 fallback stands.
        if hi > 15 || hi < -12 || lo < -24 {
            let backend = match self.error_budget {
                Some(budget) if budget >= tier_error(SplitSpec::bf16x2().bound_bits()) => {
                    Backend::Bf16x2
                }
                Some(budget) if budget >= tier_error(SplitSpec::bf16x3().bound_bits()) => {
                    Backend::Bf16x3
                }
                _ => Backend::Fp32,
            };
            return PolicyDecision { backend, scale_exp: 0, e_min, e_max };
        }

        if let Some(budget) = self.error_budget {
            // >= ~2^-11 is satisfiable by one FP16 pass — three times
            // cheaper than any recovery tier.
            if budget >= 2f64.powi(-11) {
                return PolicyDecision { backend: Backend::Fp16, scale_exp: 0, e_min, e_max };
            }
            // Tighter than the FP16×2 cube's ~22 recovered bits: only
            // the six-pass BF16×3 cascade (≈ 24 bits) can satisfy it.
            if budget < 2f64.powi(-22) {
                return PolicyDecision { backend: Backend::Bf16x3, scale_exp: 0, e_min, e_max };
            }
        }

        // Eq. (6) upper bound: s_b <= 15 + 12 - e_max — tightened by one
        // to 26 - e_max. The nominal bound sizes the *rounded* residual
        // (|v - RN_fp16(v)| <= 2^{e_max-12}, so s_f·residual fits), but
        // an exact round-to-nearest tie attains weight 2^{e_max-11}: at
        // e_max = 15 the witness 61936.0 rounds to 61952 leaving a
        // residual of -16, which s_b = 12 scales to -65536 — past the
        // FP16 maximum, reconstructing ±inf from an in-range input.
        // Shrinking the cap by one keeps every tie's scaled residual
        // representable. Prefer the paper's default 12 otherwise
        // (growing beyond 12 for small inputs buys nothing — the high
        // component's subnormal quantization is the binding constraint
        // there, see the fallback above).
        let sb_hi = 26 - hi;
        let scale_exp = 12.min(sb_hi).max(0);
        PolicyDecision { backend: self.default_backend, scale_exp, e_min, e_max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat_with_exponents(es: &[i32]) -> Matrix<f32> {
        let mut rng = Rng::new(1);
        Matrix::from_fn(1, es.len(), |_, j| rng.f32_with_exponent(es[j]))
    }

    #[test]
    fn moderate_range_uses_cube_with_sb12() {
        let a = mat_with_exponents(&[-3, 0, 5]);
        let b = mat_with_exponents(&[-1, 2, 3]);
        let d = PrecisionPolicy::default().decide(&a, &b);
        assert_eq!(d.backend, Backend::CubeTermwise);
        assert_eq!(d.scale_exp, 12);
        assert_eq!(d.e_min, Some(-3));
        assert_eq!(d.e_max, Some(5));
    }

    #[test]
    fn oversized_inputs_fall_back_to_fp32() {
        let a = mat_with_exponents(&[0, 17]); // 2^17 > fp16 max
        let b = mat_with_exponents(&[0]);
        let d = PrecisionPolicy::default().decide(&a, &b);
        assert_eq!(d.backend, Backend::Fp32);
    }

    #[test]
    fn subnormal_range_falls_back_to_fp32() {
        let a = mat_with_exponents(&[-30]);
        let b = mat_with_exponents(&[0]);
        let d = PrecisionPolicy::default().decide(&a, &b);
        assert_eq!(d.backend, Backend::Fp32);
    }

    #[test]
    fn large_inputs_shrink_scale_exp() {
        // e_max = 15 → s_b ≤ 26 - 15 = 11: the tie-safe bound shaves one
        // off Eq. (6)'s nominal 27 - e_max so exact round-to-nearest
        // ties (residual weight 2^{e_max-11}) cannot overflow the scaled
        // low component. e_max = 14 → s_b ≤ 12, the paper's default.
        let b = mat_with_exponents(&[0]);
        let d = PrecisionPolicy::default().decide(&mat_with_exponents(&[15]), &b);
        assert_eq!(d.backend, Backend::CubeTermwise);
        assert_eq!(d.scale_exp, 11);
        let d14 = PrecisionPolicy::default().decide(&mat_with_exponents(&[14]), &b);
        assert_eq!(d14.scale_exp, 12);
    }

    #[test]
    fn rule2_tie_at_emax_never_overflows_the_residual() {
        // 61936.0 sits exactly midway between the FP16 neighbours 61920
        // and 61952 (spacing 32 at e = 15); round-to-nearest-even picks
        // 61952, leaving residual -16. Under the nominal s_b = 12 the
        // scaled residual is -65536 — past the FP16 max of 65504, so the
        // split reconstructs -inf from a perfectly in-range input. The
        // policy's tightened cap keeps it finite.
        use crate::softfloat::split::split_f32;
        let a = Matrix::from_vec(1, 1, vec![61936.0f32]);
        let b = mat_with_exponents(&[0]);
        let d = PrecisionPolicy::default().decide(&a, &b);
        assert_eq!(d.backend, Backend::CubeTermwise);
        assert_eq!(d.scale_exp, 11);
        let (_, low) = split_f32(61936.0, &SplitConfig::with_scale(d.scale_exp));
        assert!(low.to_f32().is_finite(), "tie residual must stay representable");
        let (_, bad) = split_f32(61936.0, &SplitConfig::with_scale(12));
        assert!(!bad.to_f32().is_finite(), "witness: nominal bound does overflow");
    }

    #[test]
    fn tiny_inputs_fall_back_to_fp32() {
        // All entries near 2^-20: the high component is fp16-subnormal,
        // so no residual scaling can reach near-fp32 accuracy — the
        // policy routes to FP32 instead (measured justification in
        // experiments::ablations::run_dynamic_scaling).
        let a = mat_with_exponents(&[-20, -19]);
        let b = mat_with_exponents(&[-20]);
        let d = PrecisionPolicy::default().decide(&a, &b);
        assert_eq!(d.backend, Backend::Fp32);
        // Mixed range with large entries present stays on the cube path.
        let a2 = mat_with_exponents(&[-20, 0]);
        let d2 = PrecisionPolicy::default().decide(&a2, &b);
        assert_eq!(d2.backend, Backend::CubeTermwise);
    }

    #[test]
    fn decide_ranges_matches_decide_and_maps_prepack_path() {
        let a = mat_with_exponents(&[-3, 0, 5]);
        let b = mat_with_exponents(&[-1, 2, 3]);
        let p = PrecisionPolicy::default();
        let joint = p.decide(&a, &b);
        let split = p.decide_ranges(matrix_exponent_range(&a), matrix_exponent_range(&b));
        assert_eq!(joint, split);
        assert_eq!(
            joint.prepack_path(),
            Some(PrepackPath::Cube(SplitConfig::with_scale(joint.scale_exp)))
        );
        // FP32 fallback still advertises a prepackable path.
        let big = mat_with_exponents(&[17]);
        let d = p.decide(&big, &b);
        assert_eq!(d.prepack_path(), Some(PrepackPath::Fp32));
    }

    #[test]
    fn zero_matrices_take_cheapest_path() {
        let a: Matrix<f32> = Matrix::zeros(4, 4);
        let b: Matrix<f32> = Matrix::zeros(4, 4);
        let d = PrecisionPolicy::default().decide(&a, &b);
        assert_eq!(d.backend, Backend::Fp16);
    }

    #[test]
    fn error_budget_walks_the_tier_ladder() {
        let a = mat_with_exponents(&[0, 1]);
        let b = mat_with_exponents(&[0]);
        let with = |budget| PrecisionPolicy { error_budget: Some(budget), ..Default::default() };
        // ~11 bits: one FP16 pass suffices.
        assert_eq!(with(1e-3).decide(&a, &b).backend, Backend::Fp16);
        // Up to ~22 bits: the FP16×2 cube (the default) meets it.
        assert_eq!(with(1e-6).decide(&a, &b).backend, Backend::CubeTermwise);
        // Tighter than the cube's bound: only BF16×3 (≈ 24 bits) can —
        // the one case where the six-pass cascade earns its cost.
        assert_eq!(with(1e-7).decide(&a, &b).backend, Backend::Bf16x3);
        // Best effort (no budget) never picks the expensive cascade.
        assert_eq!(PrecisionPolicy::default().decide(&a, &b).backend, Backend::CubeTermwise);
    }

    #[test]
    fn out_of_window_budget_selects_full_range_bf16() {
        // Exponent 17 exceeds the FP16 window, so the scaled-FP16 cube
        // is out; BF16 components carry the full FP32 exponent.
        let a = mat_with_exponents(&[0, 17]);
        let b = mat_with_exponents(&[0]);
        let with = |budget| PrecisionPolicy { error_budget: Some(budget), ..Default::default() };
        assert_eq!(with(1e-4).decide(&a, &b).backend, Backend::Bf16x2);
        assert_eq!(with(1e-6).decide(&a, &b).backend, Backend::Bf16x3);
        // Tighter than BF16×3's bound → conservative FP32 fallback.
        assert_eq!(with(1e-9).decide(&a, &b).backend, Backend::Fp32);
        // Same ladder below the window.
        let tiny = mat_with_exponents(&[-20]);
        assert_eq!(with(1e-4).decide(&tiny, &b).backend, Backend::Bf16x2);
        // Bf16 tiers advertise the family prepack format.
        let d = with(1e-4).decide(&a, &b);
        assert_eq!(d.prepack_path(), Some(PrepackPath::Family(SplitSpec::bf16x2())));
    }

    #[test]
    fn fallback_preserves_accuracy_at_tiny_exponents() {
        // End-to-end: the policy's routing beats forcing the cube path
        // for inputs below the paper's supported window.
        use crate::gemm::backend::GemmBackend;
        use crate::gemm::cube::{cube_gemm, Accumulation};
        use crate::gemm::dgemm::dgemm_of_f32;
        use crate::gemm::error::relative_error;
        use crate::softfloat::split::SplitConfig;
        let mut rng = Rng::new(9);
        let a = Matrix::from_fn(32, 32, |_, _| rng.f32_with_exponent(-20));
        let b = Matrix::from_fn(32, 32, |_, _| rng.f32_with_exponent(-20));
        let d = PrecisionPolicy::default().decide(&a, &b);
        let c_ref = dgemm_of_f32(&a, &b);
        let err_policy = relative_error(
            &c_ref,
            &GemmBackend::new(d.backend).with_scale(d.scale_exp).gemm(&a, &b).to_f64(),
        );
        let err_forced_cube = relative_error(
            &c_ref,
            &cube_gemm(&a, &b, SplitConfig::with_scale(12), Accumulation::Termwise).to_f64(),
        );
        assert!(
            err_policy < err_forced_cube / 10.0,
            "policy {err_policy} vs forced cube {err_forced_cube}"
        );
    }
}
