//! L3 coordinator: a GEMM service in the shape of a serving router.
//!
//! The paper's contribution is the kernel, so this layer is the thin-but-
//! real driver a production deployment would put around it: clients
//! submit GEMM requests; the service
//!
//! 1. analyses operand ranges and picks a precision path
//!    ([`policy`] — including the dynamic `s_b` selection the paper
//!    lists as future work),
//! 2. groups compatible requests into batches ([`batcher`]),
//! 3. executes them on a worker pool ([`server`]) over either the
//!    native numerics engine or the PJRT artifacts ([`crate::runtime`]),
//!    scheduling row-block tiles across workers ([`scheduler`]) the way
//!    the Ascend kernel distributes row blocks across AI cores,
//! 4. and records latency/throughput metrics ([`metrics`]).

pub mod batcher;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use policy::{PolicyDecision, PrecisionPolicy};
pub use request::{GemmRequest, GemmResponse, ShapeKey};
pub use server::{GemmService, ServiceConfig};
