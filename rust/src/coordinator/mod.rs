//! L3 coordinator: a GEMM service in the shape of a serving router.
//!
//! The paper's contribution is the kernel, so this layer is the thin-but-
//! real driver a production deployment would put around it: clients
//! submit GEMM requests; the service
//!
//! 1. analyses operand ranges and picks a precision path
//!    ([`policy`] — including the dynamic `s_b` selection the paper
//!    lists as future work),
//! 2. groups compatible requests into batches ([`batcher`]) — keyed by
//!    shape *and* registered-weight identity, so requests sharing a
//!    prepacked B execute together,
//! 3. executes them on a worker pool ([`server`]) over either the
//!    native numerics engine or the PJRT artifacts ([`crate::runtime`]),
//!    scheduling row-block tiles across workers ([`scheduler`]) the way
//!    the Ascend kernel distributes row blocks across AI cores — with
//!    cache-stable weights served from prepacked panels
//!    ([`crate::gemm::prepacked`], [`crate::gemm::cache`]) so the
//!    split + pack cost is paid once per weight, not once per request,
//! 4. records latency/throughput metrics, a fixed-bucket latency
//!    histogram, and the resilience counters ([`metrics`]),
//! 5. hardens the whole front door: bounded admission, per-request
//!    deadlines, typed channel-loss errors, bounded retry, and an
//!    in-process column-shard router with health tracking and failover
//!    ([`shard`]) — responses bit-identical to single-node serving,
//! 6. and speaks HTTP/1.1 over TCP ([`net`]): a hand-rolled wire front
//!    door (`/gemm`, `/register`, `/metrics`, `/healthz` — no tokio,
//!    nothing vendored) that threads deadlines, admission and the
//!    failpoint registry through the socket path, bit-identical to the
//!    in-process blocking entry points.

pub mod batcher;
pub mod metrics;
pub mod net;
pub mod policy;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use net::{NetClient, NetConfig, NetServer};
pub use policy::{PolicyDecision, PrecisionPolicy};
pub use request::{BOperand, GemmRequest, GemmResponse, ShapeKey, WeightEntry, WeightId};
pub use server::{GemmService, RequestOpts, ServiceConfig};
pub use shard::{ShardConfig, ShardHealth, ShardRouter};
