//! In-process shard router: column-partitioned serving of a registered
//! weight across S logical shards, with per-shard health, bounded
//! retry, and failover — responses **bit-identical** to single-node
//! execution.
//!
//! A weight's `k × n` matrix is split once, at registration, into S
//! contiguous column slices (widths differ by at most one). Each slice
//! is an independent serving unit: its panels are prepacked and cached
//! per `(path, s_b)` like any whole weight ([`crate::gemm::cache`],
//! keyed by the slice origin `col0`), and a request fans out as one
//! GEMM per slice whose `m × w` result is bit-copied into the full
//! `m × n` response.
//!
//! **Why recombination is bit-identical.** In the blocked engine every
//! output cell `(i, j)` is produced by one per-cell accumulation chain
//! that depends only on the k-blocking (`bk` from
//! [`crate::gemm::blocked::host_block`], identical for the slice and
//! the full matrix — it does not depend on `n`), the per-lane kernel
//! order (lanes accumulate independently, so a column's position within
//! its micro-panel does not change its arithmetic), and the operand
//! *values* `A(i, :)` / `B(:, j)` — the FP32→2×FP16 split is
//! elementwise, so slicing columns first changes nothing. Computing
//! columns `[n0, n0+w)` standalone therefore replays exactly the chains
//! the full sweep would run for those columns, every schedule included
//! (all schedules run the same shared sweeps). The chaos suite pins
//! this against a single-node service with a shard killed mid-stream.
//!
//! **Execution and deadlock safety.** The router is called from inside
//! a batch task that already occupies one of the gate-bounded pool
//! slots, so it must not block on detached-task progress alone (a
//! saturated pool would deadlock). Fan-out follows the
//! [`Pool::run_chunks`](crate::exec::pool::Pool::run_chunks)
//! philosophy: slice jobs go into a shared claim queue drained by
//! detached helpers **and the calling thread together** — worst case
//! the caller computes every slice serially, which always terminates.
//! Failure handling (retry with backoff on the owner, then failover
//! across survivors) runs inline on the caller.
//!
//! **Health.** Consecutive failures drive Healthy → Suspect
//! ([`ShardConfig::suspect_after`]) → Dead ([`ShardConfig::dead_after`]);
//! a success resets a Suspect shard to Healthy. Death permanently
//! reassigns the shard's slices round-robin to survivors, so later
//! requests never touch it; the in-flight request recovers via
//! failover ([`Metrics::record_failover`] counts each slice recovered
//! away from its owner). The `coordinator.shard.exec` failpoint
//! ([`crate::exec::faults`], indexed per shard) injects all of this
//! deterministically.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::exec::{faults, pool};
use crate::gemm::backend::{Backend, GemmBackend, Schedule};
use crate::gemm::cache::{PrepackCache, PrepackKey};
use crate::gemm::error::GemmError;
use crate::gemm::prepacked::{PrepackPath, PrepackedMatrix};
use crate::util::mat::Matrix;

/// `[shards]` section: column-shard router configuration.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Number of column shards a registered weight is partitioned
    /// across; `< 2` disables the router (single-node serving).
    pub count: usize,
    /// Consecutive failures before a Healthy shard turns Suspect.
    pub suspect_after: u32,
    /// Consecutive failures before a shard is declared Dead and its
    /// slices are permanently reassigned to survivors.
    pub dead_after: u32,
    /// Per-slice retries on the owning shard before failing over.
    pub retries: usize,
    /// Backoff before each same-shard retry, doubled per attempt.
    pub backoff: Duration,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            count: 0,
            suspect_after: 1,
            dead_after: 3,
            retries: 1,
            backoff: Duration::from_micros(200),
        }
    }
}

/// Health of one shard, driven by consecutive failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving normally.
    Healthy,
    /// Failing but still assigned traffic (and still retried first).
    Suspect,
    /// Removed from the assignment; its slices belong to survivors.
    Dead,
}

/// One column slice of the weight: columns `[n0, n0 + matrix.cols())`.
struct SliceSpec {
    n0: usize,
    matrix: Matrix<f32>,
}

struct ShardState {
    health: ShardHealth,
    consecutive_failures: u32,
    /// Slices this shard currently owns (moves on death).
    slices: Vec<Arc<SliceSpec>>,
}

/// The router behind one registered weight. Shards are logical (one
/// process, shared pool and prepack cache — per ROADMAP that is what
/// makes this cheap); their independent failure behaviour comes from
/// the health state machine plus the per-shard failpoints.
pub struct ShardRouter {
    weight: u64,
    k: usize,
    n: usize,
    cfg: ShardConfig,
    cache: Arc<PrepackCache>,
    metrics: Arc<Metrics>,
    state: Mutex<Vec<ShardState>>,
}

impl ShardRouter {
    /// Partition `matrix` (the registered weight `weight`) into
    /// `cfg.count` contiguous column slices (clamped to at least 2 and
    /// at most one shard per column). Slices are materialized once,
    /// here; panels are packed lazily through `cache` on first use per
    /// precision path.
    pub fn new(
        weight: u64,
        matrix: &Matrix<f32>,
        cfg: ShardConfig,
        cache: Arc<PrepackCache>,
        metrics: Arc<Metrics>,
    ) -> ShardRouter {
        let (k, n) = matrix.shape();
        let count = cfg.count.max(2).min(n.max(1));
        let base = n / count;
        let rem = n % count;
        let mut shards = Vec::with_capacity(count);
        let mut n0 = 0usize;
        for i in 0..count {
            let w = base + usize::from(i < rem);
            let slice = Matrix::from_fn(k, w, |r, c| matrix.get(r, n0 + c));
            shards.push(ShardState {
                health: ShardHealth::Healthy,
                consecutive_failures: 0,
                slices: vec![Arc::new(SliceSpec { n0, matrix: slice })],
            });
            n0 += w;
        }
        ShardRouter {
            weight,
            k,
            n,
            cfg: ShardConfig { count, ..cfg },
            cache,
            metrics,
            state: Mutex::new(shards),
        }
    }

    /// Number of shards (fixed at construction; dead shards count).
    pub fn shard_count(&self) -> usize {
        self.cfg.count
    }

    /// Current health of shard `i`.
    pub fn health(&self, i: usize) -> ShardHealth {
        self.state.lock().unwrap()[i].health
    }

    /// Shards not yet declared Dead.
    pub fn live_count(&self) -> usize {
        self.state.lock().unwrap().iter().filter(|s| s.health != ShardHealth::Dead).count()
    }

    /// Current slice assignment, `(n0, width)` per shard — empty for
    /// dead shards once their slices moved.
    pub fn assignments(&self) -> Vec<Vec<(usize, usize)>> {
        self.state
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.slices.iter().map(|sl| (sl.n0, sl.matrix.cols())).collect())
            .collect()
    }

    /// Kill shard `i` (test/chaos API): mark it Dead and reassign its
    /// slices to survivors, exactly as `dead_after` consecutive
    /// failures would.
    pub fn kill(&self, i: usize) {
        let mut st = self.state.lock().unwrap();
        if st[i].health != ShardHealth::Dead {
            Self::mark_dead(&mut st, i);
        }
    }

    /// Serve one request: fan out over the live slice assignment,
    /// recover failures (retry on the owner, then failover across
    /// survivors), and recombine into the full `m × n` product —
    /// bit-identical to single-node execution of the same decision.
    ///
    /// `backend`/`scale_exp` are the cache-normalized decision the
    /// server computed; `path` is its prepack format. `deadline` bounds
    /// the whole fan-out ([`GemmError::Timeout`] on expiry).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm(
        self: &Arc<Self>,
        a: &Matrix<f32>,
        backend: Backend,
        scale_exp: i32,
        path: PrepackPath,
        schedule: Schedule,
        pipeline_depth: usize,
        deadline: Option<Instant>,
    ) -> Result<Matrix<f32>, GemmError> {
        let started = Instant::now();
        let (m, k_a) = a.shape();
        if k_a != self.k {
            return Err(GemmError::ShapeMismatch { m, k_a, k_b: self.k, n: self.n });
        }
        let mut c = Matrix::zeros(m, self.n);
        // Snapshot the live assignment: (owner, slice) jobs.
        let jobs: Vec<(usize, Arc<SliceSpec>)> = {
            let st = self.state.lock().unwrap();
            st.iter()
                .enumerate()
                .filter(|(_, s)| s.health != ShardHealth::Dead)
                .flat_map(|(i, s)| s.slices.iter().map(move |sl| (i, Arc::clone(sl))))
                .collect()
        };
        if jobs.is_empty() {
            return Err(GemmError::ShardFailed {
                shard: 0,
                reason: "no live shards hold a slice assignment".into(),
            });
        }
        let n_jobs = jobs.len();
        let exec = ExecParams { backend, scale_exp, path, schedule, pipeline_depth };
        // Fan out through a shared claim queue: detached pool helpers
        // plus the calling thread, so a saturated pool degrades to the
        // caller computing slices serially instead of deadlocking (the
        // caller is itself a gate-bounded pool task).
        let queue = Arc::new(Mutex::new(jobs));
        let (tx, rx) = channel();
        let helpers = (n_jobs - 1).min(pool::global().n_workers());
        let a_shared = Arc::new(a.clone());
        for _ in 0..helpers {
            let router = Arc::clone(self);
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let a_shared = Arc::clone(&a_shared);
            pool::global().submit(move || loop {
                let job = queue.lock().unwrap().pop();
                let Some((owner, slice)) = job else { return };
                let r = router.compute_slice(&a_shared, owner, &slice, exec);
                if tx.send((owner, slice, r)).is_err() {
                    return; // the caller gave up (deadline); drain out
                }
            });
        }
        drop(tx);
        // The caller drains the queue too, handling its claims inline.
        let mut outcomes = Vec::with_capacity(n_jobs);
        loop {
            let job = queue.lock().unwrap().pop();
            let Some((owner, slice)) = job else { break };
            let r = self.compute_slice(a, owner, &slice, exec);
            outcomes.push((owner, slice, r));
        }
        // Collect what the helpers claimed, bounded by the deadline.
        while outcomes.len() < n_jobs {
            let wait = match deadline {
                None => Duration::from_secs(3600),
                Some(dl) => {
                    let left = dl.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return Err(GemmError::Timeout { after: started.elapsed() });
                    }
                    left
                }
            };
            match rx.recv_timeout(wait) {
                Ok(o) => outcomes.push(o),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(GemmError::Timeout { after: started.elapsed() })
                }
                // All helper senders dropped without delivering: only
                // possible if helper tasks died before claiming (e.g.
                // an armed exec.pool.task panic) — the jobs they never
                // claimed were drained by the caller above, so this
                // means every remaining job already produced a result.
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Successes land in C; failures drive health and recovery.
        let mut failed: Vec<(usize, Arc<SliceSpec>)> = Vec::new();
        for (owner, slice, r) in outcomes {
            match r {
                Ok(cs) => {
                    self.on_success(owner);
                    copy_slice(&mut c, &slice, &cs);
                }
                Err(_) => {
                    self.on_failure(owner);
                    failed.push((owner, slice));
                }
            }
        }
        for (owner, slice) in failed {
            let cs = self.recover_slice(a, owner, &slice, exec, deadline, started)?;
            copy_slice(&mut c, &slice, &cs);
        }
        Ok(c)
    }

    /// Recover one failed slice: bounded retries on the owner (while it
    /// lives), then one failover attempt per survivor.
    fn recover_slice(
        &self,
        a: &Matrix<f32>,
        owner: usize,
        slice: &SliceSpec,
        exec: ExecParams,
        deadline: Option<Instant>,
        started: Instant,
    ) -> Result<Matrix<f32>, GemmError> {
        let expired = |dl: Option<Instant>| dl.is_some_and(|d| Instant::now() >= d);
        let mut last = String::new();
        for attempt in 0..self.cfg.retries {
            if self.health(owner) == ShardHealth::Dead {
                break;
            }
            if expired(deadline) {
                return Err(GemmError::Timeout { after: started.elapsed() });
            }
            let backoff = self.cfg.backoff.saturating_mul(1u32 << attempt.min(10));
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            match self.compute_slice(a, owner, slice, exec) {
                Ok(cs) => {
                    self.on_success(owner);
                    return Ok(cs);
                }
                Err(e) => {
                    self.on_failure(owner);
                    last = e.to_string();
                }
            }
        }
        // Failover: one attempt per surviving shard, in index order.
        for target in 0..self.cfg.count {
            if target == owner || self.health(target) == ShardHealth::Dead {
                continue;
            }
            if expired(deadline) {
                return Err(GemmError::Timeout { after: started.elapsed() });
            }
            match self.compute_slice(a, target, slice, exec) {
                Ok(cs) => {
                    self.on_success(target);
                    self.metrics.record_failover();
                    return Ok(cs);
                }
                Err(e) => {
                    self.on_failure(target);
                    last = e.to_string();
                }
            }
        }
        Err(GemmError::ShardFailed {
            shard: owner,
            reason: format!(
                "slice at column {} ({} wide) exhausted retries and failover: {last}",
                slice.n0,
                slice.matrix.cols()
            ),
        })
    }

    /// Compute one slice "on" shard `shard`: panels from the shared
    /// cache (keyed by the slice origin), executed through the same
    /// prepacked entry point single-node serving uses. Panics are
    /// contained to a typed [`GemmError::ShardFailed`].
    fn compute_slice(
        &self,
        a: &Matrix<f32>,
        shard: usize,
        slice: &SliceSpec,
        exec: ExecParams,
    ) -> Result<Matrix<f32>, GemmError> {
        if self.health(shard) == ShardHealth::Dead {
            return Err(GemmError::ShardFailed { shard, reason: "shard is dead".into() });
        }
        faults::check_indexed("coordinator.shard.exec", shard).map_err(GemmError::from)?;
        let key = PrepackKey {
            weight: self.weight,
            k: self.k,
            n: slice.matrix.cols(),
            backend: exec.backend,
            scale_exp: exec.scale_exp,
            lane: crate::gemm::kernels::active_lane(),
            col0: slice.n0,
        };
        catch_unwind(AssertUnwindSafe(|| {
            let packed = self
                .cache
                .get_or_insert_with(key, || PrepackedMatrix::prepack(&slice.matrix, exec.path));
            GemmBackend::new(exec.backend)
                .with_scale(exec.scale_exp)
                .with_schedule(exec.schedule)
                .with_pipeline_depth(exec.pipeline_depth)
                .gemm_prepacked(a, &packed)
        }))
        .map_err(|p| GemmError::ShardFailed {
            shard,
            reason: format!(
                "slice execution panicked: {}",
                crate::coordinator::server::panic_message(p)
            ),
        })
    }

    fn on_success(&self, shard: usize) {
        let mut st = self.state.lock().unwrap();
        let s = &mut st[shard];
        if s.health == ShardHealth::Dead {
            return;
        }
        s.consecutive_failures = 0;
        s.health = ShardHealth::Healthy;
    }

    fn on_failure(&self, shard: usize) {
        let mut st = self.state.lock().unwrap();
        let s = &mut st[shard];
        if s.health == ShardHealth::Dead {
            return;
        }
        s.consecutive_failures += 1;
        if s.consecutive_failures >= self.cfg.dead_after {
            Self::mark_dead(&mut st, shard);
        } else if s.consecutive_failures >= self.cfg.suspect_after {
            s.health = ShardHealth::Suspect;
        }
    }

    /// Declare `shard` Dead and move its slices round-robin onto
    /// survivors. If no shard survives, the slices stay stranded on the
    /// dead shard (requests then fail with a typed `ShardFailed`).
    fn mark_dead(st: &mut [ShardState], shard: usize) {
        st[shard].health = ShardHealth::Dead;
        let orphans = std::mem::take(&mut st[shard].slices);
        let live: Vec<usize> = st
            .iter()
            .enumerate()
            .filter(|(_, s)| s.health != ShardHealth::Dead)
            .map(|(i, _)| i)
            .collect();
        if live.is_empty() {
            st[shard].slices = orphans;
            return;
        }
        for (j, sl) in orphans.into_iter().enumerate() {
            st[live[j % live.len()]].slices.push(sl);
        }
    }
}

/// The per-request execution parameters threaded through fan-out.
#[derive(Clone, Copy)]
struct ExecParams {
    backend: Backend,
    scale_exp: i32,
    path: PrepackPath,
    schedule: Schedule,
    pipeline_depth: usize,
}

/// Bit-copy an `m × w` slice result into columns `[n0, n0+w)` of `c`.
fn copy_slice(c: &mut Matrix<f32>, slice: &SliceSpec, cs: &Matrix<f32>) {
    let w = slice.matrix.cols();
    for i in 0..cs.rows() {
        c.row_mut(i)[slice.n0..slice.n0 + w].copy_from_slice(cs.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softfloat::split::SplitConfig;
    use crate::util::rng::Rng;

    fn router(weight: u64, b: &Matrix<f32>, count: usize) -> Arc<ShardRouter> {
        Arc::new(ShardRouter::new(
            weight,
            b,
            ShardConfig { count, ..Default::default() },
            Arc::new(PrepackCache::new(64 << 20)),
            Arc::new(Metrics::new()),
        ))
    }

    fn assert_bits_eq(x: &Matrix<f32>, y: &Matrix<f32>, what: &str) {
        assert_eq!(x.shape(), y.shape(), "{what}");
        for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
            assert_eq!(u.to_bits(), v.to_bits(), "{what}");
        }
    }

    #[test]
    fn partition_covers_all_columns_with_balanced_widths() {
        let mut rng = Rng::new(31);
        let b = Matrix::random_symmetric(16, 53, 0, &mut rng);
        let r = router(1, &b, 4);
        assert_eq!(r.shard_count(), 4);
        assert_eq!(r.live_count(), 4);
        let asn = r.assignments();
        let mut expect_n0 = 0usize;
        for slices in &asn {
            assert_eq!(slices.len(), 1);
            let (n0, w) = slices[0];
            assert_eq!(n0, expect_n0, "contiguous, in order");
            assert!(w == 13 || w == 14, "53 over 4 shards: widths 14,13,13,13 — got {w}");
            expect_n0 += w;
        }
        assert_eq!(expect_n0, 53, "every column assigned exactly once");
        // Count is clamped: at most one shard per column, at least two.
        let tiny = Matrix::zeros(4, 3);
        assert_eq!(router(2, &tiny, 8).shard_count(), 3);
    }

    #[test]
    fn sharded_gemm_bit_matches_full_prepack_for_every_count() {
        let mut rng = Rng::new(32);
        let b = Matrix::random_symmetric(48, 37, 0, &mut rng);
        let a = Matrix::random_symmetric(8, 48, 0, &mut rng);
        let split = SplitConfig::with_scale(12);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(split));
        let want = GemmBackend::new(Backend::CubeTermwise)
            .with_scale(12)
            .gemm_prepacked(&a, &pp);
        for count in [2usize, 3, 5] {
            let r = router(count as u64, &b, count);
            let got = r
                .gemm(
                    &a,
                    Backend::CubeTermwise,
                    12,
                    PrepackPath::Cube(split),
                    Schedule::Serial,
                    2,
                    None,
                )
                .expect("sharded gemm");
            assert_bits_eq(&want, &got, &format!("count={count}"));
        }
        // Fp32 path too (different panel format).
        let pp32 = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
        let want32 = GemmBackend::new(Backend::Fp32).gemm_prepacked(&a, &pp32);
        let r = router(9, &b, 3);
        let got32 = r
            .gemm(&a, Backend::Fp32, 0, PrepackPath::Fp32, Schedule::Serial, 2, None)
            .expect("sharded fp32 gemm");
        assert_bits_eq(&want32, &got32, "fp32");
    }

    #[test]
    fn slice_panels_are_cached_per_slice() {
        let mut rng = Rng::new(33);
        let b = Matrix::random_symmetric(32, 24, 0, &mut rng);
        let cache = Arc::new(PrepackCache::new(64 << 20));
        let r = Arc::new(ShardRouter::new(
            5,
            &b,
            ShardConfig { count: 3, ..Default::default() },
            Arc::clone(&cache),
            Arc::new(Metrics::new()),
        ));
        let a = Matrix::random_symmetric(4, 32, 0, &mut rng);
        let split = SplitConfig::with_scale(12);
        for _ in 0..3 {
            r.gemm(
                &a,
                Backend::CubeTermwise,
                12,
                PrepackPath::Cube(split),
                Schedule::Serial,
                2,
                None,
            )
            .expect("sharded gemm");
        }
        let s = cache.stats();
        assert_eq!(s.misses, 3, "one pack per slice: {s:?}");
        assert_eq!(s.hits, 6, "later requests served from cache: {s:?}");
    }

    #[test]
    fn kill_reassigns_slices_and_results_stay_bit_identical() {
        let mut rng = Rng::new(34);
        let b = Matrix::random_symmetric(40, 30, 0, &mut rng);
        let a = Matrix::random_symmetric(6, 40, 0, &mut rng);
        let split = SplitConfig::with_scale(12);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(split));
        let want = GemmBackend::new(Backend::CubeTermwise)
            .with_scale(12)
            .gemm_prepacked(&a, &pp);
        let r = router(6, &b, 3);
        let run = |r: &Arc<ShardRouter>| {
            r.gemm(
                &a,
                Backend::CubeTermwise,
                12,
                PrepackPath::Cube(split),
                Schedule::Serial,
                2,
                None,
            )
            .expect("sharded gemm")
        };
        assert_bits_eq(&want, &run(&r), "before kill");
        r.kill(1);
        assert_eq!(r.health(1), ShardHealth::Dead);
        assert_eq!(r.live_count(), 2);
        // Shard 1's slice moved to a survivor; coverage is still total.
        let widths: usize = r.assignments().iter().flatten().map(|&(_, w)| w).sum();
        assert_eq!(widths, 30);
        assert!(r.assignments()[1].is_empty(), "dead shard owns nothing");
        assert_bits_eq(&want, &run(&r), "after kill");
        // Killing the rest leaves no live shard: typed error, no panic.
        r.kill(0);
        r.kill(2);
        assert_eq!(r.live_count(), 0);
        match r.gemm(
            &a,
            Backend::CubeTermwise,
            12,
            PrepackPath::Cube(split),
            Schedule::Serial,
            2,
            None,
        ) {
            Err(GemmError::ShardFailed { .. }) => {}
            other => panic!("expected ShardFailed, got {other:?}"),
        }
    }

    #[test]
    fn shape_mismatch_is_typed() {
        let b = Matrix::zeros(8, 12);
        let r = router(7, &b, 2);
        let a = Matrix::zeros(2, 9);
        match r.gemm(&a, Backend::Fp32, 0, PrepackPath::Fp32, Schedule::Serial, 2, None) {
            Err(GemmError::ShapeMismatch { m: 2, k_a: 9, k_b: 8, n: 12 }) => {}
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn zero_row_requests_are_served() {
        let mut rng = Rng::new(35);
        let b = Matrix::random_symmetric(16, 10, 0, &mut rng);
        let r = router(8, &b, 2);
        let a: Matrix<f32> = Matrix::zeros(0, 16);
        let c = r
            .gemm(&a, Backend::Fp32, 0, PrepackPath::Fp32, Schedule::Serial, 2, None)
            .expect("empty request");
        assert_eq!(c.shape(), (0, 10));
    }

    #[test]
    fn health_transitions_and_default_config() {
        let d = ShardConfig::default();
        assert_eq!(d.count, 0, "sharding is opt-in");
        assert!(d.dead_after >= d.suspect_after);
        let mut rng = Rng::new(36);
        let b = Matrix::random_symmetric(8, 8, 0, &mut rng);
        let r = router(9, &b, 2);
        // Failures march Healthy → Suspect → Dead at the thresholds.
        r.on_failure(0);
        assert_eq!(r.health(0), ShardHealth::Suspect, "suspect_after=1");
        r.on_success(0);
        assert_eq!(r.health(0), ShardHealth::Healthy, "success resets");
        r.on_failure(0);
        r.on_failure(0);
        assert_eq!(r.health(0), ShardHealth::Suspect);
        r.on_failure(0);
        assert_eq!(r.health(0), ShardHealth::Dead, "dead_after=3");
        // Dead is terminal.
        r.on_success(0);
        assert_eq!(r.health(0), ShardHealth::Dead);
    }
}
