//! Service metrics: request counts, latency distribution, throughput.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

/// Shared metrics registry (interior mutability; cheap enough for the
/// request rates this service sees).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies: Vec<f64>,
    flops: f64,
    batches: u64,
    requests: u64,
    errors: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// A snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    /// Latency summary in seconds (None until the first request).
    pub latency: Option<Summary>,
    /// Aggregate achieved FLOP/s over the active window.
    pub flops_per_sec: f64,
    /// Mean requests per batch.
    pub mean_batch_size: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request.
    pub fn record_request(&self, latency_secs: f64, flops: f64, ok: bool) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
        g.requests += 1;
        if ok {
            g.latencies.push(latency_secs);
            g.flops += flops;
        } else {
            g.errors += 1;
        }
    }

    /// Record one executed batch.
    pub fn record_batch(&self) {
        self.inner.lock().unwrap().batches += 1;
    }

    pub fn report(&self) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        let window = match (g.started, g.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64().max(1e-9),
            _ => f64::INFINITY,
        };
        MetricsReport {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            latency: if g.latencies.is_empty() { None } else { Some(Summary::of(&g.latencies)) },
            flops_per_sec: g.flops / window,
            mean_batch_size: if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 },
        }
    }
}

impl MetricsReport {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        let lat = self
            .latency
            .as_ref()
            .map(|l| format!("p50={:.3}ms p95={:.3}ms", l.median * 1e3, l.p95 * 1e3))
            .unwrap_or_else(|| "no-latency".into());
        format!(
            "requests={} batches={} (mean {:.1}/batch) errors={} {} throughput={:.2} GFLOP/s",
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.errors,
            lat,
            self.flops_per_sec / 1e9
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_batch();
        m.record_request(0.010, 1e9, true);
        m.record_request(0.020, 1e9, true);
        m.record_request(0.5, 0.0, false);
        let r = m.report();
        assert_eq!(r.requests, 3);
        assert_eq!(r.errors, 1);
        assert_eq!(r.batches, 1);
        let lat = r.latency.unwrap();
        assert_eq!(lat.n, 2);
        assert!((lat.median - 0.015).abs() < 1e-12);
        assert!(r.line().contains("requests=3"));
    }

    #[test]
    fn empty_report() {
        let r = Metrics::new().report();
        assert_eq!(r.requests, 0);
        assert!(r.latency.is_none());
        assert_eq!(r.mean_batch_size, 0.0);
        assert_eq!(r.flops_per_sec, 0.0);
    }
}
