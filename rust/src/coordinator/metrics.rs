//! Service metrics: request counts, latency distribution, throughput,
//! the resilience counters (shed / timeout / retry / failover), and the
//! global pool's work-stealing counters (sampled at report time from
//! [`crate::exec::pool::global`] — they are process-wide, not
//! per-service, so concurrent services see the same stream).
//!
//! **Latency estimators.** Two bounded structures cover the
//! distribution, and neither grows with request count (an earlier
//! revision kept every sample in a `Vec<f64>` — a memory leak in a
//! long-running server):
//!
//! * the fixed-bucket [`LatencyHistogram`] is the **authoritative
//!   p50/p95/p99 source** — exact rank selection over log-spaced
//!   buckets, conservative by at most one bucket ratio;
//! * a fixed-capacity **reservoir** ([`RESERVOIR_CAPACITY`] samples,
//!   Algorithm R over a deterministic [`crate::util::rng`] stream)
//!   holds a uniform subsample of successful latencies and feeds the
//!   [`Summary`] in [`MetricsReport::latency`]. Past capacity the
//!   summary's moments are unbiased estimates and its `min`/`max` are
//!   the extremes *of the subsample*, not of the full stream — use the
//!   histogram quantiles for tail claims.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Number of fixed log-spaced latency buckets. Bucket `i` covers
/// `(2^{i-1} µs, 2^i µs]` (bucket 0 is `(0, 1 µs]`); the last bucket —
/// `2^27 µs ≈ 134 s` and up — is the catch-all.
pub const LATENCY_BUCKETS: usize = 28;

/// Lower edge of the histogram: one microsecond.
const BUCKET_FLOOR_S: f64 = 1e-6;

/// Capacity of the latency reservoir: enough for stable summary
/// moments, small enough (32 KiB of `f64`) to be irrelevant to a
/// serving host's memory budget.
pub const RESERVOIR_CAPACITY: usize = 4096;

/// Bounded uniform subsample of the successful-latency stream —
/// classic Algorithm R: the first [`RESERVOIR_CAPACITY`] samples are
/// kept verbatim; sample `i > capacity` replaces a random held slot
/// with probability `capacity / i`, so every sample seen so far is in
/// the reservoir with equal probability. The RNG is the repo's seeded
/// xoshiro generator — deterministic given the sample order, and free
/// of platform entropy sources.
#[derive(Debug)]
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Rng,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir { samples: Vec::new(), seen: 0, rng: Rng::new(0x5a7e_11ce_5eed) }
    }
}

impl Reservoir {
    fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAPACITY {
            self.samples.push(v);
        } else {
            let j = self.rng.usize_below(self.seen as usize);
            if j < RESERVOIR_CAPACITY {
                self.samples[j] = v;
            }
        }
    }

    fn summary(&self) -> Option<Summary> {
        if self.samples.is_empty() {
            None
        } else {
            Some(Summary::of(&self.samples))
        }
    }
}

/// Fixed-bucket latency histogram: log-spaced, O(1) per record,
/// constant memory regardless of request count — the **authoritative**
/// p50/p95/p99 source (the reservoir-fed [`Summary`] is a uniform
/// subsample). Quantiles are conservative: [`LatencyHistogram::quantile`]
/// returns the *upper bound* of the bucket holding the requested rank,
/// so a reported p99 never understates the true p99 by more than one
/// bucket ratio (2×).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; LATENCY_BUCKETS],
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; LATENCY_BUCKETS], total: 0 }
    }
}

impl LatencyHistogram {
    fn bucket(secs: f64) -> usize {
        if secs.is_nan() || secs <= BUCKET_FLOOR_S {
            // NaN/negative/zero and anything at or under the floor all
            // land in bucket 0.
            return 0;
        }
        let b = (secs / BUCKET_FLOOR_S).log2().ceil() as usize;
        b.min(LATENCY_BUCKETS - 1)
    }

    /// Count one latency sample (seconds).
    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket(secs)] += 1;
        self.total += 1;
    }

    /// Samples recorded.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Upper bound (seconds) of the bucket holding the `q`-quantile
    /// sample, `0 < q <= 1`; `None` until the first sample.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(BUCKET_FLOOR_S * (1u64 << i) as f64);
            }
        }
        None
    }
}

/// Shared metrics registry (interior mutability; cheap enough for the
/// request rates this service sees).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies: Reservoir,
    hist: LatencyHistogram,
    flops: f64,
    batches: u64,
    requests: u64,
    errors: u64,
    shed: u64,
    timeouts: u64,
    retries: u64,
    failovers: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// A snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    /// Completed requests, successes and failures alike.
    pub requests: u64,
    /// Executed batches.
    pub batches: u64,
    /// Requests that completed with an error.
    pub errors: u64,
    /// Latency summary in seconds over the bounded reservoir subsample
    /// (`None` until the first successful request). Exact while fewer
    /// than [`RESERVOIR_CAPACITY`] successes have been recorded;
    /// past that, an unbiased uniform subsample — `min`/`max` are the
    /// subsample's extremes, and `p50`/`p95`/`p99` below (histogram-
    /// derived) stay the authoritative quantiles.
    pub latency: Option<Summary>,
    /// Histogram quantiles in seconds (bucket upper bounds; None until
    /// the first successful request).
    pub p50: Option<f64>,
    /// 95th-percentile latency bucket bound, seconds.
    pub p95: Option<f64>,
    /// 99th-percentile latency bucket bound, seconds.
    pub p99: Option<f64>,
    /// Requests shed by admission control ([`GemmError::Overloaded`]).
    ///
    /// [`GemmError::Overloaded`]: crate::gemm::error::GemmError::Overloaded
    pub shed: u64,
    /// Deadline expiries observed (client waits and server-side sheds).
    pub timeouts: u64,
    /// Retries attempted by the blocking entry points.
    pub retries: u64,
    /// Column slices recovered on a shard other than their owner.
    pub failovers: u64,
    /// Aggregate achieved FLOP/s over the active window.
    pub flops_per_sec: f64,
    /// Mean requests per batch.
    pub mean_batch_size: f64,
    /// Tasks stolen from a peer worker's queue on the process-wide pool
    /// ([`crate::exec::pool::Pool::steals`]; cumulative since process
    /// start, sampled at report time).
    pub pool_steals: u64,
    /// Idle scans on the process-wide pool that parked a worker without
    /// work to run or steal ([`crate::exec::pool::Pool::steal_fails`]).
    pub pool_steal_fails: u64,
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completed request. Successful latencies feed both the
    /// bounded reservoir (summary moments) and the histogram (quantile
    /// truth); failures only count as errors (error latencies say more
    /// about the failure mode than the service).
    pub fn record_request(&self, latency_secs: f64, flops: f64, ok: bool) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
        g.requests += 1;
        if ok {
            g.latencies.record(latency_secs);
            g.hist.record(latency_secs);
            g.flops += flops;
        } else {
            g.errors += 1;
        }
    }

    /// Latency samples currently held by the reservoir — never more
    /// than [`RESERVOIR_CAPACITY`], regardless of request count (the
    /// bounded-memory regression guard).
    pub fn latency_samples_held(&self) -> usize {
        self.inner.lock().unwrap().latencies.samples.len()
    }

    /// Record one executed batch.
    pub fn record_batch(&self) {
        self.inner.lock().unwrap().batches += 1;
    }

    /// Record one request shed by admission control.
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Record one deadline expiry.
    pub fn record_timeout(&self) {
        self.inner.lock().unwrap().timeouts += 1;
    }

    /// Record one retry attempt.
    pub fn record_retry(&self) {
        self.inner.lock().unwrap().retries += 1;
    }

    /// Record one slice failed over to a surviving shard.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    /// Snapshot everything recorded so far into a [`MetricsReport`].
    pub fn report(&self) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        let window = match (g.started, g.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64().max(1e-9),
            _ => f64::INFINITY,
        };
        MetricsReport {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            latency: g.latencies.summary(),
            p50: g.hist.quantile(0.50),
            p95: g.hist.quantile(0.95),
            p99: g.hist.quantile(0.99),
            shed: g.shed,
            timeouts: g.timeouts,
            retries: g.retries,
            failovers: g.failovers,
            flops_per_sec: g.flops / window,
            mean_batch_size: if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 },
            pool_steals: crate::exec::pool::global().steals(),
            pool_steal_fails: crate::exec::pool::global().steal_fails(),
        }
    }
}

impl MetricsReport {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        let lat = match (self.p50, self.p95, self.p99) {
            (Some(p50), Some(p95), Some(p99)) => format!(
                "p50≤{:.3}ms p95≤{:.3}ms p99≤{:.3}ms",
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            ),
            _ => "no-latency".into(),
        };
        format!(
            "requests={} batches={} (mean {:.1}/batch) errors={} shed={} timeouts={} retries={} failovers={} steals={} steal_fails={} {} throughput={:.2} GFLOP/s",
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.errors,
            self.shed,
            self.timeouts,
            self.retries,
            self.failovers,
            self.pool_steals,
            self.pool_steal_fails,
            lat,
            self.flops_per_sec / 1e9
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_batch();
        m.record_request(0.010, 1e9, true);
        m.record_request(0.020, 1e9, true);
        m.record_request(0.5, 0.0, false);
        let r = m.report();
        assert_eq!(r.requests, 3);
        assert_eq!(r.errors, 1);
        assert_eq!(r.batches, 1);
        let lat = r.latency.unwrap();
        assert_eq!(lat.n, 2);
        assert!((lat.median - 0.015).abs() < 1e-12);
        assert!(r.line().contains("requests=3"));
    }

    #[test]
    fn empty_report() {
        let r = Metrics::new().report();
        assert_eq!(r.requests, 0);
        assert!(r.latency.is_none());
        assert!(r.p99.is_none());
        assert_eq!(r.mean_batch_size, 0.0);
        assert_eq!(r.flops_per_sec, 0.0);
        assert_eq!((r.shed, r.timeouts, r.retries, r.failovers), (0, 0, 0, 0));
        assert!(r.line().contains("no-latency"));
    }

    #[test]
    fn histogram_bucket_edges() {
        // Bucket i covers (2^{i-1} µs, 2^i µs]; the floor and below land
        // in bucket 0, the far tail saturates into the last bucket.
        assert_eq!(LatencyHistogram::bucket(0.0), 0);
        assert_eq!(LatencyHistogram::bucket(-1.0), 0);
        assert_eq!(LatencyHistogram::bucket(f64::NAN), 0);
        assert_eq!(LatencyHistogram::bucket(1e-6), 0);
        assert_eq!(LatencyHistogram::bucket(1.5e-6), 1);
        assert_eq!(LatencyHistogram::bucket(2e-6), 1);
        assert_eq!(LatencyHistogram::bucket(2.1e-6), 2);
        assert_eq!(LatencyHistogram::bucket(1e9), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn histogram_quantiles_are_bucket_upper_bounds() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.5), None);
        // 99 samples at ~1 ms, one at ~100 ms.
        for _ in 0..99 {
            h.record(0.0009);
        }
        h.record(0.100);
        assert_eq!(h.total(), 100);
        // 0.9 ms sits in the bucket with upper bound 2^10 µs = 1.024 ms.
        let ms = 1024.0 * 1e-6;
        assert_eq!(h.quantile(0.50), Some(ms));
        assert_eq!(h.quantile(0.95), Some(ms));
        assert_eq!(h.quantile(0.99), Some(ms));
        // The single outlier owns the tail: 100 ms ≤ 2^17 µs = 131.072 ms.
        assert_eq!(h.quantile(1.0), Some(131072.0 * 1e-6));
    }

    #[test]
    fn resilience_counters_reach_report_and_line() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_timeout();
        m.record_retry();
        m.record_retry();
        m.record_retry();
        m.record_failover();
        m.record_request(0.002, 1e6, true);
        let r = m.report();
        assert_eq!((r.shed, r.timeouts, r.retries, r.failovers), (2, 1, 3, 1));
        let line = r.line();
        assert!(line.contains("shed=2"), "{line}");
        assert!(line.contains("timeouts=1"), "{line}");
        assert!(line.contains("retries=3"), "{line}");
        assert!(line.contains("failovers=1"), "{line}");
        assert!(line.contains("p99≤"), "{line}");
    }

    #[test]
    fn pool_steal_counters_reach_report_and_line() {
        // The counters are process-wide (shared global pool), so other
        // tests may have advanced them — assert presence, not values.
        let r = Metrics::new().report();
        let line = r.line();
        assert!(line.contains(" steals="), "{line}");
        assert!(line.contains(&format!(" steal_fails={} ", r.pool_steal_fails)), "{line}");
    }

    #[test]
    fn latency_memory_is_bounded_past_reservoir_capacity() {
        // Regression: the pre-reservoir Metrics pushed every sample
        // into a Vec forever. Feed 4× capacity and check both the
        // bound and that the estimators stay sane.
        let m = Metrics::new();
        let total = 4 * RESERVOIR_CAPACITY;
        for i in 0..total {
            // Flat 1..2 ms ramp, plus a 100 ms outlier every 100th.
            let lat = if i % 100 == 99 { 0.100 } else { 0.001 + (i % 100) as f64 * 1e-5 };
            m.record_request(lat, 1e6, true);
        }
        assert!(m.latency_samples_held() <= RESERVOIR_CAPACITY);
        assert_eq!(m.latency_samples_held(), RESERVOIR_CAPACITY);
        let r = m.report();
        assert_eq!(r.requests, total as u64);
        // Histogram quantiles are exact-rank over every sample: the
        // bulk sits under 2.048 ms, the outliers own the extreme tail.
        assert_eq!(r.p50, Some(2048.0 * 1e-6));
        assert_eq!(r.p95, Some(2048.0 * 1e-6));
        // Reservoir summary: the subsample's moments must land inside
        // the population's possible range (mean ≈ 2.4 ms with the 1%
        // outliers; a broken reservoir that kept only early or only
        // late samples would still pass, hence the histogram above is
        // the authoritative check — this guards gross corruption).
        let lat = r.latency.expect("summary present");
        assert_eq!(lat.n, RESERVOIR_CAPACITY);
        assert!(lat.mean > 0.001 && lat.mean < 0.01, "mean={}", lat.mean);
        assert!(lat.min >= 0.001 && lat.max <= 0.100, "[{}, {}]", lat.min, lat.max);
    }

    #[test]
    fn reservoir_replacement_is_uniform_ish() {
        // After 8× capacity from a monotonically increasing stream, a
        // correct Algorithm R holds a mix of early and late samples; a
        // "keep first capacity" bug would hold only values < capacity.
        let mut res = Reservoir::default();
        let total = 8 * RESERVOIR_CAPACITY;
        for i in 0..total {
            res.record(i as f64);
        }
        assert_eq!(res.samples.len(), RESERVOIR_CAPACITY);
        assert_eq!(res.seen, total as u64);
        let late = res.samples.iter().filter(|&&v| v >= RESERVOIR_CAPACITY as f64).count();
        // Expected ~7/8 of slots replaced by later samples; demand a
        // loose majority so the test is robust to the fixed seed.
        assert!(late > RESERVOIR_CAPACITY / 2, "late={late}");
    }

    #[test]
    fn histogram_quantiles_track_successes_only() {
        let m = Metrics::new();
        m.record_request(0.001, 0.0, true);
        m.record_request(10.0, 0.0, false); // error latency excluded
        let r = m.report();
        assert_eq!(r.p99, Some(1024.0 * 1e-6));
        assert_eq!(r.errors, 1);
    }
}
