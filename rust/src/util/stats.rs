//! Small statistics helpers shared by the bench harness, the metrics
//! registry and the experiment reports.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (0 for a single sample).
    pub stddev: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (linear interpolation, see [`percentile_sorted`]).
    pub median: f64,
    /// 95th percentile (linear interpolation).
    pub p95: f64,
}

impl Summary {
    /// Compute a summary of `xs`. Panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|&x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// `log2` of a relative error, clamped for zero error — "bits of accuracy".
pub fn accuracy_bits(rel_err: f64) -> f64 {
    if rel_err <= 0.0 {
        53.0 // exact at f64 resolution
    } else {
        (-rel_err.log2()).clamp(0.0, 53.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_bits_bounds() {
        assert_eq!(accuracy_bits(0.0), 53.0);
        assert!((accuracy_bits(0.25) - 2.0).abs() < 1e-12);
        assert_eq!(accuracy_bits(2.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
