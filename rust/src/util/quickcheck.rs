//! Minimal property-testing framework (proptest substitute; the offline
//! image does not vendor proptest).
//!
//! Provides seeded random-input property checks with a simple failure
//! report including the seed and case index, so failures replay
//! deterministically. No shrinking — cases are kept small instead.
//!
//! ```no_run
//! # // no_run: doctest binaries miss the libxla rpath of the cargo config
//! use sgemm_cube::qc_assert;
//! use sgemm_cube::util::quickcheck::{property, Gen};
//! property("addition commutes", 200, |g: &mut Gen| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     qc_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Zero-based index of the current case (for failure reports).
    pub case: usize,
}

impl Gen {
    /// Generator for one case, seeded deterministically from
    /// `(seed, case)`.
    pub fn new(seed: u64, case: usize) -> Self {
        Gen { rng: Rng::new(seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)), case }
    }

    /// Direct access to the underlying [`Rng`].
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// A uniform random `u64`.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A uniform `usize` in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.usize_below(hi - lo)
    }

    /// A uniform `i32` in `[lo, hi)`.
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo < hi);
        lo + self.rng.usize_below((hi - lo) as usize) as i32
    }

    /// A uniform `f32` in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    /// An arbitrary finite f32 drawn from random bits (resampling
    /// NaN/inf), biased toward the full exponent range rather than
    /// uniform magnitude — good for conversion edge cases.
    pub fn finite_f32(&mut self) -> f32 {
        loop {
            let v = f32::from_bits(self.rng.next_u32());
            if v.is_finite() {
                return v;
            }
        }
    }

    /// Finite f32 within the FP16-splittable range the paper targets
    /// (|v| representable by an FP16 high part: |v| <= 65504).
    pub fn moderate_f32(&mut self) -> f32 {
        let e = self.i32_in(-20, 16);
        let m = self.f32_in(1.0, 2.0);
        let s = if self.u64() & 1 == 0 { 1.0 } else { -1.0 };
        s * m * (e as f32).exp2()
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.u64() & 1 == 1
    }
}

/// Run `cases` random cases of `prop`. Panics with a replayable report on
/// the first failure. Returns the number of executed cases.
pub fn property(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) -> usize {
    let seed = std::env::var("QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_5eed_5eed_5eedu64);
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (QC_SEED={seed}): {msg}"
            );
        }
    }
    cases
}

/// Assertion macro producing `Err(String)` instead of panicking, so the
/// property runner can attach seed/case context.
#[macro_export]
macro_rules! qc_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!("assertion failed: {}: {}", stringify!($cond), format!($($fmt)+)));
        }
    };
}

/// Approximate-equality helper for property bodies.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes() {
        let ran = property("tautology", 50, |g| {
            let x = g.f32_in(0.0, 1.0);
            qc_assert!((0.0..1.0).contains(&x));
            Ok(())
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn property_reports_failure() {
        property("fails", 10, |g| {
            qc_assert!(g.case != 7, "deterministic failure at case 7");
            Ok(())
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut a = Gen::new(1, 3);
        let mut b = Gen::new(1, 3);
        assert_eq!(a.u64(), b.u64());
        let mut c = Gen::new(1, 4);
        assert_ne!(a.u64(), c.u64());
    }

    #[test]
    fn moderate_f32_in_fp16_range() {
        let mut g = Gen::new(2, 0);
        for _ in 0..1000 {
            let v = g.moderate_f32();
            assert!(v.is_finite() && v.abs() <= 65504.0, "v={v}");
        }
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 1e-6));
        assert!(close(0.0, 1e-9, 0.0, 1e-6));
    }
}
