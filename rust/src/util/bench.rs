//! Micro-benchmark harness (criterion substitute).
//!
//! Benches in `rust/benches/` are plain binaries (`harness = false`) that
//! call into this module. Each measurement does a warm-up phase, then runs
//! timed iterations until both a minimum iteration count and a minimum
//! wall-clock budget are met, and reports summary statistics.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (the JSON record key).
    pub name: String,
    /// Per-iteration wall time statistics, in seconds.
    pub seconds: Summary,
    /// Optional work term (e.g. FLOPs per iteration) to derive throughput.
    pub work_per_iter: Option<f64>,
}

impl BenchResult {
    /// Throughput in work-units/second (e.g. FLOP/s) if work was declared.
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.seconds.median)
    }

    /// One machine-readable JSON record: name, per-iteration seconds
    /// (median/mean/stddev), sample count, and GFLOP/s when a work term
    /// was declared (`null` otherwise). [`Bencher::write_json`] emits
    /// these for a whole run (e.g. `BENCH_gemm.json`); committing that
    /// file tracks the perf trajectory across PRs (EXPERIMENTS.md
    /// §Perf-iteration-log).
    pub fn to_json(&self) -> String {
        let gflops = match self.throughput() {
            Some(tp) => format!("{:.3}", tp / 1e9),
            None => "null".to_string(),
        };
        format!(
            "{{\"name\":\"{}\",\"median_s\":{:e},\"mean_s\":{:e},\"stddev_s\":{:e},\"n\":{},\"gflops\":{}}}",
            json_escape(&self.name),
            self.seconds.median,
            self.seconds.mean,
            self.seconds.stddev,
            self.seconds.n,
            gflops
        )
    }

    /// Render one human-readable line.
    pub fn line(&self) -> String {
        let t = self.seconds.median;
        let base = format!(
            "{:<44} {:>12}  ±{:>9}  (n={})",
            self.name,
            fmt_duration(t),
            fmt_duration(self.seconds.stddev),
            self.seconds.n
        );
        match self.throughput() {
            Some(tp) => format!("{base}  {:>10}/s", fmt_si(tp)),
            None => base,
        }
    }
}

/// Per-stage wall-time breakdown of one blocked-GEMM execution, in
/// seconds — the measurement the overlapped-pipeline work feeds back
/// into the simulator ([`crate::sim::pipeline::IterTiming::from_measured`]).
///
/// Stages follow the executed nest (`crate::gemm::overlap` staged
/// drivers): `pack_b` is the B-panel preparation the prefetch pipeline
/// hides (the paper's `T_mem` analogue); `pack_a`, `kernel` and
/// `c_update` stay on the compute path (the `T_comp` analogue).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageBreakdown {
    /// A row-block packing (`pack_a` / `pack_a_dual`).
    pub pack_a: f64,
    /// B panel packing (`pack_b` / `pack_b_dual`) — the overlappable span.
    pub pack_b: f64,
    /// Register micro-kernel time.
    pub kernel: f64,
    /// C tile accumulate/store time.
    pub c_update: f64,
}

impl StageBreakdown {
    /// Sum of every stage.
    pub fn total(&self) -> f64 {
        self.pack_a + self.pack_b + self.kernel + self.c_update
    }

    /// The span that stays on the critical path under overlap
    /// (everything but the B-panel preparation) — the engine's `T_comp`.
    pub fn compute(&self) -> f64 {
        self.pack_a + self.kernel + self.c_update
    }

    /// The span the double-buffered pipeline hides (B-panel
    /// preparation) — the engine's `T_mem`.
    pub fn transfer(&self) -> f64 {
        self.pack_b
    }

    /// Human-readable one-liner with per-stage shares.
    pub fn line(&self) -> String {
        let t = self.total();
        let pct = |s: f64| if t > 0.0 { 100.0 * s / t } else { 0.0 };
        format!(
            "pack_a {} ({:.1}%)  pack_b {} ({:.1}%)  kernel {} ({:.1}%)  c_update {} ({:.1}%)",
            fmt_duration(self.pack_a),
            pct(self.pack_a),
            fmt_duration(self.pack_b),
            pct(self.pack_b),
            fmt_duration(self.kernel),
            pct(self.kernel),
            fmt_duration(self.c_update),
            pct(self.c_update),
        )
    }
}

/// Escape a string for embedding in a JSON literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format seconds with an adaptive unit.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a rate with SI prefixes.
pub fn fmt_si(v: f64) -> String {
    const UNITS: [(&str, f64); 5] = [
        ("T", 1e12),
        ("G", 1e9),
        ("M", 1e6),
        ("k", 1e3),
        ("", 1.0),
    ];
    for (u, scale) in UNITS {
        if v >= scale {
            return format!("{:.2} {u}", v / scale);
        }
    }
    format!("{v:.2} ")
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Untimed warm-up budget before sampling starts.
    pub warmup: Duration,
    /// Minimum total sampling wall time.
    pub min_time: Duration,
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Hard cap on timed iterations.
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            min_time: Duration::from_millis(500),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// The default full-measurement profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            min_time: Duration::from_millis(150),
            min_iters: 3,
            max_iters: 1_000,
            ..Self::default()
        }
    }

    /// Measure `f`, which performs one iteration of work per call and
    /// returns a value that is black-boxed to keep the optimizer honest.
    pub fn bench<R>(&mut self, name: &str, work_per_iter: Option<f64>, mut f: impl FnMut() -> R) -> &BenchResult {
        // Warm-up.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed iterations.
        let mut samples = Vec::new();
        let timed_start = Instant::now();
        while (samples.len() < self.min_iters || timed_start.elapsed() < self.min_time)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            seconds: Summary::of(&samples),
            work_per_iter,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record a derived scalar (e.g. a speedup ratio) as a result row:
    /// the value rides in `median_s` (single-sample summary, no work
    /// term), so derived metrics land in the same JSON file as the raw
    /// timings — the CI bench-smoke gate reads the serving prepack
    /// speedup this way.
    pub fn record_scalar(&mut self, name: &str, value: f64) -> &BenchResult {
        let result = BenchResult {
            name: name.to_string(),
            seconds: Summary::of(&[value]),
            work_per_iter: None,
        };
        println!("{:<44} {value:>12.3}  (scalar)", result.name);
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record a measured [`StageBreakdown`] as four scalar rows
    /// (`<prefix>/pack_a_s` … `<prefix>/c_update_s`), so the per-stage
    /// wall times land in `BENCH_gemm.json` next to the timings they
    /// decompose.
    pub fn record_stages(&mut self, prefix: &str, stages: &StageBreakdown) {
        self.record_scalar(&format!("{prefix}/pack_a_s"), stages.pack_a);
        self.record_scalar(&format!("{prefix}/pack_b_s"), stages.pack_b);
        self.record_scalar(&format!("{prefix}/kernel_s"), stages.kernel);
        self.record_scalar(&format!("{prefix}/c_update_s"), stages.c_update);
    }

    /// Every result recorded so far, in measurement order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write every result recorded by this `Bencher` as a JSON array.
    /// **Replaces** the file: the output reflects the latest run only —
    /// the cross-PR trajectory comes from committing the file per PR
    /// (EXPERIMENTS.md §Perf-iteration-log).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        let body: Vec<String> = self.results.iter().map(BenchResult::to_json).collect();
        std::fs::write(path, format!("[\n  {}\n]\n", body.join(",\n  ")))
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`,
/// which is available since 1.66 — use the std one).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(5),
            min_iters: 3,
            max_iters: 100,
            results: Vec::new(),
        };
        let r = b.bench("noop", Some(1.0), || 1 + 1).clone();
        assert_eq!(r.name, "noop");
        assert!(r.seconds.n >= 3);
        assert!(r.throughput().unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn to_json_and_writer() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(2),
            min_iters: 2,
            max_iters: 50,
            results: Vec::new(),
        };
        b.bench("with \"quotes\"", Some(1e9), || 0u8);
        b.bench("no-work", None, || 0u8);
        let j = b.results()[0].to_json();
        assert!(j.contains("\\\"quotes\\\""), "{j}");
        assert!(j.contains("\"median_s\":"), "{j}");
        assert!(j.contains("\"gflops\":"), "{j}");
        assert!(b.results()[1].to_json().contains("\"gflops\":null"));
        let path = std::env::temp_dir().join("sgemm_cube_bench_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert_eq!(text.matches("\"name\"").count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_scalar_lands_in_json() {
        let mut b = Bencher::quick();
        b.record_scalar("serving/speedup", 3.5);
        let j = b.results()[0].to_json();
        assert!(j.contains("\"name\":\"serving/speedup\""), "{j}");
        assert!(j.contains("\"median_s\":3.5"), "{j}");
        assert!(j.contains("\"gflops\":null"), "{j}");
        assert_eq!(b.results()[0].seconds.n, 1);
    }

    #[test]
    fn stage_breakdown_accounting_and_records() {
        let s = StageBreakdown { pack_a: 0.1, pack_b: 0.2, kernel: 0.6, c_update: 0.1 };
        assert!((s.total() - 1.0).abs() < 1e-12);
        assert!((s.compute() - 0.8).abs() < 1e-12);
        assert!((s.transfer() - 0.2).abs() < 1e-12);
        assert!(s.line().contains("pack_b"));
        // Zero breakdown: shares render as 0, no division blowups.
        assert!(StageBreakdown::default().line().contains("0.0%"));
        let mut b = Bencher::quick();
        b.record_stages("blocked/stage/256^3", &s);
        let names: Vec<&str> = b.results().iter().map(|r| r.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "blocked/stage/256^3/pack_a_s",
                "blocked/stage/256^3/pack_b_s",
                "blocked/stage/256^3/kernel_s",
                "blocked/stage/256^3/c_update_s"
            ]
        );
        assert_eq!(b.results()[1].seconds.median, 0.2);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
        assert!(fmt_duration(2e-9).ends_with("ns"));
    }

    #[test]
    fn fmt_si_units() {
        assert_eq!(fmt_si(1.5e12), "1.50 T");
        assert_eq!(fmt_si(2e9), "2.00 G");
        assert_eq!(fmt_si(5.0), "5.00 ");
    }
}
