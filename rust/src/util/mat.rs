//! Dense row-major matrices over a generic scalar.
//!
//! This is the host-side container shared by the exact numerics engine
//! (`crate::gemm`), the coordinator request path and the PJRT literal
//! conversion. It is deliberately minimal: contiguous `Vec<T>` storage,
//! row-major, no strides or views — the blocked GEMM kernels do their own
//! packing where layout matters.

use crate::util::rng::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix<T> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> Matrix<T> {
    /// Zero-initialized (well, `T::default()`) matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![T::default(); rows * cols] }
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length != rows*cols");
        Matrix { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)` pair.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// The element at `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Overwrite the element at `(i, j)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Full backing buffer (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    /// Mutable full backing buffer (row-major).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Matrix<T> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Map every element.
    pub fn map<U: Copy + Default>(&self, mut f: impl FnMut(T) -> U) -> Matrix<U> {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }
}

impl Matrix<f32> {
    /// Matrix with entries from the paper's symmetric generator
    /// `U[-2^e, 2^e]` (Sec 6.1).
    pub fn random_symmetric(rows: usize, cols: usize, e: i32, rng: &mut Rng) -> Self {
        Matrix::from_fn(rows, cols, |_, _| rng.symmetric_pow2(e))
    }

    /// Matrix with entries from the non-negative generator `U[0, 2^e]`.
    pub fn random_nonneg(rows: usize, cols: usize, e: i32, rng: &mut Rng) -> Self {
        Matrix::from_fn(rows, cols, |_, _| rng.nonneg_pow2(e))
    }

    /// Standard-normal entries scaled by `std` (training example init).
    pub fn random_normal(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        Matrix::from_fn(rows, cols, |_, _| rng.normal() * std)
    }

    /// Promote to f64 (for reference computations).
    pub fn to_f64(&self) -> Matrix<f64> {
        self.map(|v| v as f64)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }
}

impl Matrix<f64> {
    /// Demote to f32 (RN, hardware conversion).
    pub fn to_f32(&self) -> Matrix<f32> {
        self.map(|v| v as f32)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_values() {
        let m: Matrix<f32> = Matrix::zeros(3, 4);
        assert_eq!(m.shape(), (3, 4));
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_fn_indexing() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(1, 2), 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_length_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0f32, 2.0, 3.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.shape(), (5, 3));
        assert_eq!(t.get(4, 2), m.get(2, 4));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn map_and_promote() {
        let m = Matrix::from_fn(2, 2, |i, j| (i + j) as f32);
        let d = m.to_f64();
        assert_eq!(d.get(1, 1), 2.0f64);
        assert_eq!(d.to_f32(), m);
    }

    #[test]
    fn random_generators_in_range() {
        let mut rng = Rng::new(1);
        let s = Matrix::random_symmetric(8, 8, 2, &mut rng);
        assert!(s.as_slice().iter().all(|&v| (-4.0..4.0).contains(&v)));
        let n = Matrix::random_nonneg(8, 8, 0, &mut rng);
        assert!(n.as_slice().iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn frobenius_simple() {
        let m = Matrix::from_vec(1, 2, vec![3.0f32, 4.0]);
        assert!((m.frobenius() - 5.0).abs() < 1e-12);
    }
}
