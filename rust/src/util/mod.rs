//! Shared utilities: deterministic PRNG, dense matrices, statistics,
//! a micro-benchmark harness (criterion substitute) and a minimal
//! property-testing framework (proptest substitute).
//!
//! The offline build image only vendors the `xla` crate closure, so the
//! usual ecosystem crates (`rand`, `criterion`, `proptest`, `rayon`) are
//! re-implemented here at the scale this project needs.

pub mod bench;
pub mod mat;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod threads;

pub use bench::Bencher;
pub use mat::Matrix;
pub use rng::Rng;
