//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! small amount of PRNG machinery the experiments need: splitmix64 for
//! seeding and xoshiro256** as the main generator. All experiments seed
//! explicitly so every figure is reproducible run-to-run.

/// splitmix64 step — used to expand a single `u64` seed into a full
/// xoshiro state and as a cheap standalone generator in tests.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG (Blackman & Vigna). Fast, high-quality, `Copy`-free.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start from the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9e37_79b9_7f4a_7c15;
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 random bits.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n64 = n as u64;
        let zone = u64::MAX - (u64::MAX % n64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n64) as usize;
            }
        }
    }

    /// Symmetric uniform sample from `U[-2^e, 2^e]` — the paper's
    /// "symmetric range" input generator (Sec 6.1), `e` = offset exponent.
    #[inline]
    pub fn symmetric_pow2(&mut self, e: i32) -> f32 {
        let scale = (e as f32).exp2();
        self.f32_range(-scale, scale)
    }

    /// Non-negative uniform sample from `U[0, 2^e]` (Sec 6.1).
    #[inline]
    pub fn nonneg_pow2(&mut self, e: i32) -> f32 {
        let scale = (e as f32).exp2();
        self.f32() * scale
    }

    /// Standard normal sample (Box–Muller; one value per call for
    /// simplicity — the training example is not PRNG-bound).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// A random finite `f32` whose *unbiased* exponent equals `e`
    /// (i.e. magnitude in `[2^e, 2^(e+1))`), random sign and mantissa.
    /// Used by the bit-level splitting analyses.
    pub fn f32_with_exponent(&mut self, e: i32) -> f32 {
        assert!((-126..=127).contains(&e), "normal f32 exponent required");
        let mant = self.next_u32() & 0x007f_ffff;
        let sign = (self.next_u32() & 1) << 31;
        let bits = sign | (((e + 127) as u32) << 23) | mant;
        f32::from_bits(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f32_unit_interval_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn usize_below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.usize_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn symmetric_pow2_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.symmetric_pow2(3);
            assert!((-8.0..8.0).contains(&v));
        }
    }

    #[test]
    fn nonneg_pow2_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.nonneg_pow2(-2);
            assert!((0.0..0.25).contains(&v));
        }
    }

    #[test]
    fn f32_with_exponent_has_exponent() {
        let mut r = Rng::new(11);
        for e in [-14, -3, 0, 7, 15] {
            for _ in 0..100 {
                let v = r.f32_with_exponent(e);
                let got = ((v.to_bits() >> 23) & 0xff) as i32 - 127;
                assert_eq!(got, e);
            }
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
