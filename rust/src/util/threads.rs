//! Tiny data-parallel helpers (rayon substitute), backed by the
//! persistent worker pool.
//!
//! `parallel_chunks` splits an index range into contiguous chunks and
//! runs a closure per chunk — historically on scoped std threads
//! (one spawn/join round per call), now on the process-wide
//! [`crate::exec::pool`] with the calling thread participating, which
//! keeps the exact same contract (same chunk geometry, panics re-thrown
//! on the caller, serial degeneration at `n <= 1` or one worker) while
//! amortizing thread creation across the process. Used by the blocked
//! GEMM kernels and the experiment sweeps.

use std::sync::OnceLock;

/// Number of worker threads to use: `SGEMM_CUBE_THREADS` env override,
/// else `available_parallelism`.
///
/// Resolved **once** per process (same pattern as the cached
/// `SGEMM_CUBE_OVERLAP` toggle): this sits inside hot sweeps
/// (`exec_bm`, the serial-path check of every `parallel_chunks` round),
/// where a per-call `getenv` is both measurable overhead and a
/// syscall-shaped wart in multi-threaded request loops. The cached
/// value also sizes the global pool, so the two can never disagree.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("SGEMM_CUBE_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on up to
/// `num_threads()` pool workers (plus the calling thread). `f` must be
/// `Sync` — interior mutability (or disjoint output regions via raw
/// pointers at the caller) is the caller's responsibility.
pub fn parallel_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    crate::exec::pool::global().run_chunks(n, f);
}

/// Map `0..n` to a `Vec<R>` in parallel, preserving order.
pub fn parallel_map<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send + Default + Clone,
    F: Fn(usize) -> R + Sync,
{
    let mut out = vec![R::default(); n];
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_chunks(n, |start, end| {
        let p = out_ptr; // copy the Send wrapper into the closure
        for i in start..end {
            // SAFETY: chunks are disjoint, so each index is written by
            // exactly one thread; the Vec outlives the blocking
            // parallel_chunks call.
            unsafe { *p.0.add(i) = f(i) };
        }
    });
    out
}

/// Raw-pointer wrapper asserting cross-thread transfer is safe for
/// disjoint-index writes. Shared by the blocked GEMM engine and the
/// kernel drivers — keep the safety argument (callers write disjoint
/// index ranges per thread and the buffer outlives the blocking
/// parallel call) here.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let counter = AtomicUsize::new(0);
        parallel_chunks(1000, |s, e| {
            counter.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn handles_zero() {
        parallel_chunks(0, |s, e| assert_eq!(s, e));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v = parallel_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * 3);
        }
    }

    #[test]
    fn num_threads_at_least_one_and_cached() {
        assert!(num_threads() >= 1);
        // The resolution is process-stable: repeated calls agree (the
        // OnceLock read never consults the environment again).
        assert_eq!(num_threads(), num_threads());
        assert_eq!(num_threads(), crate::exec::pool::global().n_workers());
    }
}
