//! Tiny data-parallel helper (rayon substitute).
//!
//! `parallel_chunks` splits an index range into contiguous chunks and runs
//! a closure per chunk on scoped std threads. Used by the blocked GEMM
//! kernels and the experiment sweeps. On the 1-core CI image this
//! degenerates to a serial loop (zero thread overhead), but scales on
//! multi-core hosts.

/// Number of worker threads to use: `SGEMM_CUBE_THREADS` env override,
/// else `available_parallelism`.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("SGEMM_CUBE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on up to
/// `num_threads()` scoped threads. `f` must be `Sync` — interior
/// mutability (or disjoint output regions via raw pointers at the caller)
/// is the caller's responsibility.
pub fn parallel_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

/// Map `0..n` to a `Vec<R>` in parallel, preserving order.
pub fn parallel_map<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send + Default + Clone,
    F: Fn(usize) -> R + Sync,
{
    let mut out = vec![R::default(); n];
    let out_ptr = SendPtr(out.as_mut_ptr());
    parallel_chunks(n, |start, end| {
        let p = out_ptr; // copy the Send wrapper into the closure
        for i in start..end {
            // SAFETY: chunks are disjoint, so each index is written by
            // exactly one thread; the Vec outlives the scope.
            unsafe { *p.0.add(i) = f(i) };
        }
    });
    out
}

/// Raw-pointer wrapper asserting cross-thread transfer is safe for
/// disjoint-index writes. Shared by the blocked GEMM engine and the
/// kernel drivers — keep the safety argument (callers write disjoint
/// index ranges per thread and the buffer outlives the scope) here.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let counter = AtomicUsize::new(0);
        parallel_chunks(1000, |s, e| {
            counter.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn handles_zero() {
        parallel_chunks(0, |s, e| assert_eq!(s, e));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v = parallel_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * 3);
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
