//! Hand-rolled CLI (the offline image has no clap).
//!
//! `sgemm-cube <subcommand> [--flag value ...]` — see `print_usage` for
//! the command table. Flag parsing is a simple key/value scan with typed
//! getters; unknown flags are errors.

pub mod args;

pub use args::Args;

/// Top-level usage text.
pub const USAGE: &str = "\
sgemm-cube — precision-recovery FP32 GEMM on FP16 matrix engines

USAGE:
    sgemm-cube <COMMAND> [OPTIONS]

COMMANDS:
    info       Show chip models, artifacts and build configuration
    gemm       Run one GEMM through a chosen backend and report error
    accuracy   Fig 8/9 accuracy sweeps               (--fig 8|9)
    figures    Regenerate paper tables/figures       (--fig 2|6|8|9|10|11|12|t1|t2|abl|all)
    perf       Simulator throughput for a config     (--bm/--bk/--bn/--buffer)
    serve      Start the GEMM service demo; --listen HOST:PORT starts
               the HTTP wire front door instead (POST /gemm, POST
               /register, GET /metrics, GET /healthz; [net] config keys)
    train      Train the e2e MLP                     (--backend fp32|fp16|cube)

OPTIONS (common):
    --config <path>      TOML config file (see README)
    --seed <u64>         PRNG seed (default 42)
    --csv <dir>          also write CSV outputs
    -h, --help           show this help
";

/// Print [`USAGE`] to stdout.
pub fn print_usage() {
    print!("{USAGE}");
}
