//! Flag parsing: `--key value` pairs after a subcommand.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-flag token; `help` when absent).
    pub command: String,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = match it.next() {
            Some(c) if c == "-h" || c == "--help" => "help".to_string(),
            Some(c) if !c.starts_with('-') => c,
            Some(c) => bail!("expected a subcommand, got flag {c}"),
            None => "help".to_string(),
        };
        let mut flags = BTreeMap::new();
        while let Some(tok) = it.next() {
            if tok == "-h" || tok == "--help" {
                flags.insert("help".into(), "true".into());
                continue;
            }
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {tok}"))?;
            if key.is_empty() {
                bail!("empty flag name");
            }
            // `--flag value` or boolean `--flag` (next token is a flag/eof).
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            if flags.insert(key.to_string(), value).is_some() {
                bail!("duplicate flag --{key}");
            }
        }
        Ok(Args { command, flags, consumed: Default::default() })
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key`, if present (marks the flag consumed).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(String::as_str)
    }

    /// Raw value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse `--key` as a `usize`; `default` when absent, `Err` on a
    /// malformed value.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} {v}: not an integer")),
        }
    }

    /// Parse `--key` as an `i32` (same contract as [`Args::get_usize`]).
    pub fn get_i32(&self, key: &str, default: i32) -> Result<i32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} {v}: not an integer")),
        }
    }

    /// Parse `--key` as a `u64` (same contract as [`Args::get_usize`]).
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} {v}: not an integer")),
        }
    }

    /// True when `--key` is present as `true`/`1`/`yes` (bare `--key`
    /// parses as `true`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on flags nobody consumed (typo protection). Call last.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(*k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("gemm --m 64 --backend cube --verbose").unwrap();
        assert_eq!(a.command, "gemm");
        assert_eq!(a.get_usize("m", 0).unwrap(), 64);
        assert_eq!(a.get("backend"), Some("cube"));
        assert!(a.get_bool("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn no_args_is_help() {
        assert_eq!(parse("").unwrap().command, "help");
        assert_eq!(parse("--help").unwrap().command, "help");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("gemm --m 4 --oops 1").unwrap();
        let _ = a.get("m");
        assert!(a.finish().is_err());
    }

    #[test]
    fn duplicate_and_malformed_flags_error() {
        assert!(parse("gemm --x 1 --x 2").is_err());
        assert!(parse("gemm -x 1").is_err());
        assert!(parse("--flag-before-command 1").is_err());
    }

    #[test]
    fn typed_getters_validate() {
        let a = parse("gemm --m abc").unwrap();
        assert!(a.get_usize("m", 0).is_err());
        let b = parse("gemm --sb -6").unwrap();
        assert_eq!(b.get_i32("sb", 0).unwrap(), -6);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("gemm").unwrap();
        assert_eq!(a.get_usize("m", 128).unwrap(), 128);
        assert_eq!(a.get_or("backend", "cube-termwise"), "cube-termwise");
    }
}
