//! `sgemm-cube` launcher: the L3 coordinator binary.

use anyhow::{bail, Result};

use sgemm_cube::cli::{self, Args};
use sgemm_cube::config::{BlockingConfig, ChipConfig, ConfigFile, NetSection, ServerConfig};
use sgemm_cube::coordinator::net::NetServer;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::experiments as exp;
use sgemm_cube::gemm::backend::{Backend, GemmBackend};
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
use sgemm_cube::sim::blocking::GemmShape;
use sgemm_cube::sim::executor::simulate_sgemm_cube;
use sgemm_cube::sim::pipeline::Buffering;
use sgemm_cube::sim::Chip;
use sgemm_cube::train::{teacher_dataset, Mlp};
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            cli::print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<ConfigFile> {
    match args.get("config") {
        Some(p) => ConfigFile::load(std::path::Path::new(p)),
        None => Ok(ConfigFile::default()),
    }
}

fn csv_path(args: &Args, name: &str) -> Option<std::path::PathBuf> {
    args.get("csv").map(|d| std::path::Path::new(d).join(format!("{name}.csv")))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "help" => {
            cli::print_usage();
            Ok(())
        }
        "info" => cmd_info(args),
        "gemm" => cmd_gemm(args),
        "perf" => cmd_perf(args),
        "figures" => cmd_figures(args),
        "accuracy" => cmd_accuracy(args),
        "serve" => cmd_serve(args),
        "train" => cmd_train(args),
        other => bail!("unknown command '{other}' (try --help)"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    for chip in [Chip::ascend_910a(), Chip::ascend_910b3_fp32()] {
        println!(
            "{:<28} cores={:<3} peak={:>6.1} TF/s  fp32-equiv={:>5.1} TF/s  bw={} GB/s  L1={} KB",
            chip.name,
            chip.n_cores,
            chip.peak_tflops(),
            chip.fp32_equiv_peak_tflops(),
            chip.mem_bw_gbs,
            chip.l1_bytes / 1024,
        );
    }
    print_pjrt_info();
    let block = sgemm_cube::gemm::blocked::host_block();
    println!(
        "host blocked engine: block = ({}, {}, {}) from sim::blocking on {}",
        block.bm,
        block.bk,
        block.bn,
        Chip::host_cpu().name
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn print_pjrt_info() {
    use sgemm_cube::runtime::Engine;
    match Engine::from_default_dir() {
        Ok(engine) => {
            println!("PJRT platform: {}", engine.platform());
            println!("artifacts: {:?}", engine.manifest().names());
        }
        Err(e) => println!("artifacts not available ({e}); run `make artifacts`"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn print_pjrt_info() {
    println!("PJRT runtime: disabled at build time (rebuild with --features pjrt)");
}

fn cmd_gemm(args: &Args) -> Result<()> {
    let m = args.get_usize("m", 128)?;
    let k = args.get_usize("k", 128)?;
    let n = args.get_usize("n", 128)?;
    let sb = args.get_i32("sb", 12)?;
    let e = args.get_i32("exp", 0)?;
    let seed = args.get_u64("seed", 42)?;
    let backend = Backend::parse(args.get_or("backend", "cube-termwise"))
        .ok_or_else(|| anyhow::anyhow!("unknown backend"))?;
    args.finish()?;

    let mut rng = Rng::new(seed);
    let a = Matrix::random_symmetric(m, k, e, &mut rng);
    let b = Matrix::random_symmetric(k, n, e, &mut rng);
    let exec = GemmBackend::new(backend).with_scale(sb);
    let t0 = std::time::Instant::now();
    let c = exec.gemm(&a, &b);
    let dt = t0.elapsed().as_secs_f64();
    let err = relative_error(&dgemm_of_f32(&a, &b), &c.to_f64());
    println!(
        "{m}x{k}x{n} backend={backend} sb={sb}: err={err:.3e} time={:.1}ms ({:.2} GFLOP/s host)",
        dt * 1e3,
        2.0 * (m * k * n) as f64 / dt / 1e9
    );
    Ok(())
}

fn cmd_perf(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let chip = ChipConfig::from_config(&cfg)?.0;
    let bm = args.get_usize("bm", 176)?;
    let bk = args.get_usize("bk", 64)?;
    let bn = args.get_usize("bn", 176)?;
    let m = args.get_usize("m", 5632)?;
    let k = args.get_usize("k", 4096)?;
    let n = args.get_usize("n", 5632)?;
    let buffer = match args.get_or("buffer", "double") {
        "single" => Buffering::Single,
        "double" => Buffering::Double,
        other => bail!("--buffer {other}: expected single|double"),
    };
    args.finish()?;
    let block = BlockingConfig::from_config(
        &ConfigFile::parse(&format!("[blocking]\nbm={bm}\nbk={bk}\nbn={bn}"))?,
        &chip,
    )?
    .0;
    let r = simulate_sgemm_cube(&chip, GemmShape::new(m, k, n), block, buffer);
    println!(
        "{} {}x{}x{} block=({},{},{}) {}: {:.1} TF/s fp32-equiv (OI={:.0} F/B, roof={:.1}, util={:.2})",
        chip.name, m, k, n, bm, bk, bn, buffer.name(), r.tflops, r.oi, r.roof, r.utilization
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args.get_or("fig", "all").to_string();
    let seed = args.get_u64("seed", 42)?;
    let quick = args.get_bool("quick");
    let _ = args.get("csv"); // consumed lazily by the closure below
    let csv = |name: &str| csv_path(args, name);
    args.finish()?;
    let seeds = if quick { 1 } else { 5 };
    let n_acc = if quick { 48 } else { 128 };
    let shape = GemmShape::new(5632, 4096, 5632);

    let want = |f: &str| which == "all" || which == f;
    if want("t1") {
        exp::table1::run().emit(csv("table1").as_deref());
    }
    if want("2") {
        exp::fig2_analysis::run_underflow(if quick { 2_000 } else { 50_000 }, seed)
            .emit(csv("fig2a").as_deref());
        exp::fig2_analysis::run_precision_bits(if quick { 500 } else { 5_000 }, seed)
            .emit(csv("fig2b").as_deref());
    }
    if want("6") {
        exp::fig6_blocking::run().emit(csv("fig6").as_deref());
        println!("{}\n", exp::fig6_blocking::optimal_bm_summary());
    }
    if want("8") {
        let exps: Vec<i32> = (-14..=12).step_by(2).collect();
        exp::fig8_accuracy::run(exp::fig8_accuracy::Sampling::Symmetric, n_acc, &exps, seeds)
            .emit(csv("fig8_symmetric").as_deref());
        exp::fig8_accuracy::run(exp::fig8_accuracy::Sampling::NonNegative, n_acc, &exps, seeds)
            .emit(csv("fig8_nonneg").as_deref());
    }
    if want("9") {
        exp::fig9_size_accuracy::run_mn_sweep(&[32, 64, 128, 256], 512, seeds)
            .emit(csv("fig9a").as_deref());
        exp::fig9_size_accuracy::run_k_sweep(32, &[128, 512, 2048, 8192], seeds)
            .emit(csv("fig9bc").as_deref());
    }
    if want("10") {
        exp::fig10_roofline::run(shape).emit(csv("fig10").as_deref());
    }
    if want("11") {
        exp::fig11_blocking_perf::run(shape).emit(csv("fig11").as_deref());
        let (s, d, frac) = exp::fig11_blocking_perf::headline(shape);
        println!(
            "headline: single={s:.1} TF/s (paper 41.7), double={d:.1} TF/s (paper 65.3), {:.0}% of 3-GEMM peak (paper 77%)\n",
            frac * 100.0
        );
    }
    if want("12") {
        exp::fig12_size_scaling::run_mn(2816, &[704, 1408, 2816, 5632, 11264])
            .emit(csv("fig12a").as_deref());
        exp::fig12_size_scaling::run_k(5632, &[704, 1408, 2816, 5632, 11264])
            .emit(csv("fig12b").as_deref());
        exp::fig12_size_scaling::run_mkn(&[1408, 2816, 5632, 11264])
            .emit(csv("fig12c").as_deref());
    }
    if want("t2") {
        exp::table2::run().emit(csv("table2").as_deref());
    }
    if want("abl") {
        let (n, s) = if quick { (48, 1) } else { (96, 3) };
        exp::ablations::run_low_low(n, s).emit(csv("ablation_low_low").as_deref());
        exp::ablations::run_rounding(n, s).emit(csv("ablation_rounding").as_deref());
        exp::ablations::run_dynamic_scaling(n.min(48), s)
            .emit(csv("ablation_policy").as_deref());
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let fig = args.get_or("fig", "8").to_string();
    let n = args.get_usize("n", 96)?;
    let seeds = args.get_u64("seeds", 3)?;
    args.finish()?;
    match fig.as_str() {
        "8" => {
            let exps: Vec<i32> = (-14..=12).step_by(2).collect();
            exp::fig8_accuracy::run(exp::fig8_accuracy::Sampling::Symmetric, n, &exps, seeds)
                .emit(None);
        }
        "9" => {
            exp::fig9_size_accuracy::run_k_sweep(32, &[128, 512, 2048, 8192], seeds).emit(None);
        }
        other => bail!("--fig {other}: expected 8|9"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let svc_cfg: ServiceConfig = ServerConfig::from_config(&cfg)?.0;
    let listen = args.get("listen").map(str::to_string);
    let requests = args.get_usize("requests", 64)?;
    let m = args.get_usize("m", 128)?;
    let seed = args.get_u64("seed", 42)?;
    args.finish()?;

    if let Some(addr) = listen {
        // Wire mode: start the HTTP front door and serve until killed.
        let mut net_cfg = NetSection::from_config(&cfg)?.0;
        net_cfg.listen = addr;
        let svc = std::sync::Arc::new(GemmService::start(svc_cfg));
        let srv = NetServer::bind(std::sync::Arc::clone(&svc), net_cfg)
            .map_err(|e| anyhow::anyhow!("binding the wire front door: {e}"))?;
        println!(
            "serving on http://{} — POST /gemm, POST /register, GET /metrics, GET /healthz (^C to stop)",
            srv.local_addr()
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
            println!("{}", svc.metrics().report().line());
        }
    }

    let svc = GemmService::start(svc_cfg);
    let mut rng = Rng::new(seed);
    let mut rxs = Vec::new();
    for _ in 0..requests {
        let a = Matrix::random_symmetric(m, m, 0, &mut rng);
        let b = Matrix::random_symmetric(m, m, 0, &mut rng);
        rxs.push(svc.submit(a, b, None)?);
    }
    for (_, rx) in rxs {
        let resp = rx.recv().expect("service reply");
        resp.result?;
    }
    println!("{}", svc.metrics().report().line());
    svc.shutdown();
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let backend = Backend::parse(args.get_or("backend", "cube-termwise"))
        .ok_or_else(|| anyhow::anyhow!("unknown backend"))?;
    let steps = args.get_usize("steps", 200)?;
    let seed = args.get_u64("seed", 42)?;
    args.finish()?;
    let mut rng = Rng::new(seed);
    let (x, y) = teacher_dataset(256, 64, 16, 0.01, &mut rng);
    let mut mlp = Mlp::new(&[64, 128, 128, 16], GemmBackend::new(backend), &mut rng);
    println!("training {} params with backend={backend}", mlp.n_params());
    for rec in mlp.train(&x, &y, steps, 0.02, steps.div_ceil(10)) {
        println!("step {:>4}  loss {:.6}", rec.step, rec.loss);
    }
    Ok(())
}
