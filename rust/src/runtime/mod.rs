//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! Python never runs at request time — `make artifacts` lowers the L2/L1
//! graphs once, and this module owns the PJRT CPU client, the artifact
//! manifest, per-artifact compiled-executable caching and host↔device
//! conversion.

pub mod artifact;
pub mod engine;
pub mod literal;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use engine::Engine;
