//! Host `Matrix<f32>` ↔ `xla::Literal` conversion.

use anyhow::{bail, Result};

use crate::runtime::artifact::TensorSpec;
use crate::util::mat::Matrix;

/// Build an input literal for `spec` from a row-major f32 matrix.
/// The element count must match; the literal is reshaped to the spec's
/// dims (row-major layouts agree).
pub fn matrix_to_literal(m: &Matrix<f32>, spec: &TensorSpec) -> Result<xla::Literal> {
    if m.rows() * m.cols() != spec.element_count() {
        bail!(
            "input has {} elements but spec {:?} wants {}",
            m.rows() * m.cols(),
            spec.dims,
            spec.element_count()
        );
    }
    let lit = xla::Literal::vec1(m.as_slice());
    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
    let lit = lit.reshape(&dims)?;
    Ok(match spec.dtype {
        crate::runtime::artifact::DType::F32 => lit,
        crate::runtime::artifact::DType::F16 => lit.convert(xla::PrimitiveType::F16)?,
    })
}

/// Read an output literal back into a row-major f32 matrix shaped by
/// `spec`. FP16 outputs (e.g. the split kernel's components) are widened
/// to f32 — exact, every binary16 value is representable.
pub fn literal_to_matrix(lit: &xla::Literal, spec: &TensorSpec) -> Result<Matrix<f32>> {
    let converted;
    let lit = match spec.dtype {
        crate::runtime::artifact::DType::F32 => lit,
        crate::runtime::artifact::DType::F16 => {
            converted = lit.convert(xla::PrimitiveType::F32)?;
            &converted
        }
    };
    let data = lit.to_vec::<f32>()?;
    if data.len() != spec.element_count() {
        bail!(
            "output literal has {} elements but spec {:?} wants {}",
            data.len(),
            spec.dims,
            spec.element_count()
        );
    }
    let (r, c) = spec.matrix_dims();
    Ok(Matrix::from_vec(r, c, data))
}

/// Convenience: a plain vector input (e.g. biases).
pub fn vec_to_literal(v: &[f32], spec: &TensorSpec) -> Result<xla::Literal> {
    if v.len() != spec.element_count() {
        bail!("vector length {} != spec {:?}", v.len(), spec.dims);
    }
    let lit = xla::Literal::vec1(v);
    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::DType;

    fn spec(dims: &[usize]) -> TensorSpec {
        TensorSpec { dtype: DType::F32, dims: dims.to_vec() }
    }

    #[test]
    fn roundtrip_matrix() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let s = spec(&[3, 4]);
        let lit = matrix_to_literal(&m, &s).unwrap();
        let back = literal_to_matrix(&lit, &s).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn scalar_spec() {
        let m = Matrix::from_vec(1, 1, vec![42.0f32]);
        let s = spec(&[]);
        let lit = matrix_to_literal(&m, &s).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![42.0]);
    }

    #[test]
    fn element_count_mismatch_errors() {
        let m = Matrix::from_fn(2, 2, |_, _| 0.0f32);
        assert!(matrix_to_literal(&m, &spec(&[3, 3])).is_err());
        let v = [1.0f32, 2.0];
        assert!(vec_to_literal(&v, &spec(&[3])).is_err());
    }

    #[test]
    fn vector_literal() {
        let v = [1.0f32, 2.0, 3.0];
        let lit = vec_to_literal(&v, &spec(&[3])).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), v.to_vec());
    }
}
