//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` with one
//! whitespace-separated record per artifact (no JSON dependency needed):
//!
//! ```text
//! name file n_inputs in_spec... n_outputs out_spec...
//! ```
//!
//! where each spec is `dtype:d0xd1x...` (empty dims = scalar).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Element type of a tensor (only what the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float (`float32`).
    F32,
    /// 16-bit IEEE float (`float16`).
    F16,
}

impl DType {
    /// Parse a manifest dtype name (`float32` / `float16`).
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "float16" => Ok(DType::F16),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }

    /// The manifest spelling of this dtype.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::F16 => "float16",
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Dimensions, outermost first (empty = scalar).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse `float32:64x64` (scalar: `float32:`).
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (ty, dims) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed tensor spec: {s}"))?;
        let dims = if dims.is_empty() {
            vec![]
        } else {
            dims.split('x')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype: DType::parse(ty)?, dims })
    }

    /// Total number of elements (product of `dims`; 1 for scalars).
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Matrix interpretation `(rows, cols)`; scalars/vectors map to one row.
    pub fn matrix_dims(&self) -> (usize, usize) {
        match self.dims.len() {
            0 => (1, 1),
            1 => (1, self.dims[0]),
            2 => (self.dims[0], self.dims[1]),
            _ => (self.dims[..self.dims.len() - 1].iter().product(), *self.dims.last().unwrap()),
        }
    }
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Artifact name (lookup key).
    pub name: String,
    /// Path to the artifact file (absolute once parsed).
    pub path: PathBuf,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, in return order.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Every artifact record, in file order.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse manifest text; `dir` anchors relative artifact paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let ctx = || format!("manifest line {}", lineno + 1);
            let name = it.next().ok_or_else(|| anyhow!("{}: missing name", ctx()))?;
            let file = it.next().ok_or_else(|| anyhow!("{}: missing file", ctx()))?;
            let n_in: usize = it
                .next()
                .ok_or_else(|| anyhow!("{}: missing n_inputs", ctx()))?
                .parse()
                .with_context(ctx)?;
            let mut inputs = Vec::with_capacity(n_in);
            for _ in 0..n_in {
                let spec = it.next().ok_or_else(|| anyhow!("{}: truncated inputs", ctx()))?;
                inputs.push(TensorSpec::parse(spec).with_context(ctx)?);
            }
            let n_out: usize = it
                .next()
                .ok_or_else(|| anyhow!("{}: missing n_outputs", ctx()))?
                .parse()
                .with_context(ctx)?;
            let mut outputs = Vec::with_capacity(n_out);
            for _ in 0..n_out {
                let spec = it.next().ok_or_else(|| anyhow!("{}: truncated outputs", ctx()))?;
                outputs.push(TensorSpec::parse(spec).with_context(ctx)?);
            }
            if it.next().is_some() {
                bail!("{}: trailing fields", ctx());
            }
            artifacts.push(ArtifactSpec {
                name: name.to_string(),
                path: dir.join(file),
                inputs,
                outputs,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Load `manifest.txt` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text, dir)
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifact names, in manifest order.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
cube_gemm_64 cube_gemm_64.hlo.txt 2 float32:64x64 float32:64x64 1 float32:64x64
mlp_train_step mlp.hlo.txt 3 float32:64x64 float32: float16:8 2 float32: float32:4x4
";

    #[test]
    fn parses_records() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let g = m.get("cube_gemm_64").unwrap();
        assert_eq!(g.path, PathBuf::from("/a/cube_gemm_64.hlo.txt"));
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.outputs[0].dims, vec![64, 64]);
        let t = m.get("mlp_train_step").unwrap();
        assert_eq!(t.inputs[1].dims, Vec::<usize>::new()); // scalar
        assert_eq!(t.inputs[2].dtype, DType::F16);
        assert_eq!(t.outputs.len(), 2);
    }

    #[test]
    fn tensor_spec_parsing() {
        let s = TensorSpec::parse("float32:3x5x7").unwrap();
        assert_eq!(s.dims, vec![3, 5, 7]);
        assert_eq!(s.element_count(), 105);
        assert_eq!(s.matrix_dims(), (15, 7));
        let scalar = TensorSpec::parse("float32:").unwrap();
        assert_eq!(scalar.element_count(), 1);
        assert_eq!(scalar.matrix_dims(), (1, 1));
        assert!(TensorSpec::parse("int8:4").is_err());
        assert!(TensorSpec::parse("no-colon").is_err());
    }

    #[test]
    fn malformed_manifest_errors() {
        assert!(Manifest::parse("name file 2 float32:4", Path::new(".")).is_err());
        assert!(Manifest::parse("name file x", Path::new(".")).is_err());
        assert!(
            Manifest::parse("a f 0 1 float32:2 extra", Path::new(".")).is_err(),
            "trailing fields must error"
        );
    }

    #[test]
    fn missing_file_load_error_mentions_make() {
        let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration: if `make artifacts` has run, the real manifest parses.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("cube_gemm_128").is_some());
            assert!(m.get("mlp_train_step").is_some());
        }
    }
}
