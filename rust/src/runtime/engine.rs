//! The execution engine: PJRT client, compiled-executable cache, and
//! registered-weight literal cache.
//!
//! The weight cache is the runtime-layer face of the serving stack's
//! register-weights-then-serve flow (see
//! [`crate::coordinator::server::GemmService::register_weights`] for the
//! native-engine counterpart): a stable operand is registered once, its
//! host→literal conversion is performed at most once per
//! `(weight, artifact input spec)`, and subsequent executions reuse the
//! cached literal instead of re-converting `k·n` elements per request.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifact::{ArtifactSpec, Manifest};
use crate::runtime::literal::{literal_to_matrix, matrix_to_literal};
use crate::util::mat::Matrix;

/// Owns the PJRT CPU client, the artifact manifest and a lazily-populated
/// cache of compiled executables (one compile per artifact per process).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Registered stable operands, by caller-chosen name.
    weights: Mutex<HashMap<String, Arc<Matrix<f32>>>>,
    /// Converted literals per `(weight name, artifact name)`.
    weight_literals: Mutex<HashMap<(String, String), Arc<xla::Literal>>>,
}

impl Engine {
    /// Create an engine over an artifacts directory (must contain
    /// `manifest.txt`; run `make artifacts` to produce it).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            weights: Mutex::new(HashMap::new()),
            weight_literals: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$SGEMM_CUBE_ARTIFACTS` or
    /// `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SGEMM_CUBE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Engine over [`Engine::default_dir`].
    pub fn from_default_dir() -> Result<Engine> {
        Engine::new(&Engine::default_dir())
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Name of the PJRT platform the client runs on (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact spec for `name`, or an error naming what exists.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'; have {:?}", self.manifest.names()))
    }

    /// Compile (or fetch from cache) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.spec(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .ok_or_else(|| anyhow!("non-UTF8 artifact path {:?}", spec.path))?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Register a stable operand (a weight matrix) under `name`. The
    /// host→literal conversion for a given artifact happens on first use
    /// and is cached; re-registering a name invalidates its cached
    /// literals.
    pub fn register_weight(&self, name: impl Into<String>, m: Matrix<f32>) {
        let name = name.into();
        // Swap first, purge second: weight_literal() holds the weights
        // lock across its currency check + literal insert, so a literal
        // converted from the previous registration can only land before
        // the swap below — and the purge then removes it. (Purging first
        // would leave a window for a stale literal to be cached after.)
        self.weights.lock().unwrap().insert(name.clone(), Arc::new(m));
        self.weight_literals.lock().unwrap().retain(|(w, _), _| *w != name);
    }

    /// The raw matrix registered under `name`, if any.
    pub fn weight(&self, name: &str) -> Option<Arc<Matrix<f32>>> {
        self.weights.lock().unwrap().get(name).cloned()
    }

    /// The cached input literal for weight `name` as input `input_idx`
    /// of artifact `artifact`, converting on first use.
    fn weight_literal(
        &self,
        artifact: &str,
        spec: &ArtifactSpec,
        input_idx: usize,
        name: &str,
    ) -> Result<Arc<xla::Literal>> {
        let key = (name.to_string(), artifact.to_string());
        if let Some(lit) = self.weight_literals.lock().unwrap().get(&key) {
            return Ok(lit.clone());
        }
        let w = self
            .weight(name)
            .ok_or_else(|| anyhow!("unknown weight '{name}'; call register_weight first"))?;
        let lit = Arc::new(
            matrix_to_literal(&w, &spec.inputs[input_idx])
                .with_context(|| format!("converting weight '{name}' for '{artifact}'"))?,
        );
        // Cache only if the registration we converted is still current —
        // a concurrent register_weight() may have replaced the matrix
        // while we converted. The weights lock is held across the check
        // AND the insert so a concurrent swap cannot slip between them;
        // register_weight() purges this name's literals *after* its swap,
        // so whichever side loses the lock race, no stale literal
        // survives. (Lock order weights → weight_literals is nested only
        // here; register_weight takes them sequentially — no deadlock.)
        {
            let weights = self.weights.lock().unwrap();
            if weights.get(name).is_some_and(|cur| Arc::ptr_eq(cur, &w)) {
                self.weight_literals.lock().unwrap().insert(key, lit.clone());
            }
        }
        Ok(lit)
    }

    /// Execute artifact `name` on row-major f32 inputs; returns the
    /// outputs as row-major f32 matrices per the manifest specs.
    ///
    /// All shipped artifacts are lowered with `return_tuple=True`, so the
    /// single result literal is a tuple decomposed against the manifest.
    pub fn run(&self, name: &str, inputs: &[&Matrix<f32>]) -> Result<Vec<Matrix<f32>>> {
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(spec.inputs.iter())
            .enumerate()
            .map(|(i, (m, s))| {
                matrix_to_literal(m, s).with_context(|| format!("input {i} of '{name}'"))
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.execute_decoded(name, &spec, &refs)
    }

    /// Execute prepared input literals and decode the tuple result
    /// against the manifest (shared by [`Engine::run`] and the
    /// cached-weight path).
    fn execute_decoded(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        literals: &[&xla::Literal],
    ) -> Result<Vec<Matrix<f32>>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<&xla::Literal>(literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        parts
            .iter()
            .zip(spec.outputs.iter())
            .enumerate()
            .map(|(i, (lit, s))| {
                literal_to_matrix(lit, s).with_context(|| format!("output {i} of '{name}'"))
            })
            .collect()
    }

    /// Convenience for the GEMM artifacts: `C = artifact(A, B)`.
    pub fn gemm(&self, name: &str, a: &Matrix<f32>, b: &Matrix<f32>) -> Result<Matrix<f32>> {
        let out = self.run(name, &[a, b])?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow!("artifact '{name}' returned no outputs"))
    }

    /// `C = artifact(A, W)` with `W` a registered weight
    /// ([`Engine::register_weight`]): only A is converted per call, the
    /// weight literal comes from the cache.
    pub fn gemm_with_weight(
        &self,
        name: &str,
        a: &Matrix<f32>,
        weight: &str,
    ) -> Result<Matrix<f32>> {
        let spec = self.spec(name)?.clone();
        if spec.inputs.len() != 2 {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs; gemm_with_weight needs (A, W)",
                spec.inputs.len()
            ));
        }
        let lit_a =
            matrix_to_literal(a, &spec.inputs[0]).with_context(|| format!("input A of '{name}'"))?;
        let lit_w = self.weight_literal(name, &spec, 1, weight)?;
        let out = self.execute_decoded(name, &spec, &[&lit_a, lit_w.as_ref()])?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow!("artifact '{name}' returned no outputs"))
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.platform())
            .field("artifacts", &self.manifest.names())
            .finish()
    }
}
