//! The execution engine: PJRT client + compiled-executable cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifact::{ArtifactSpec, Manifest};
use crate::runtime::literal::{literal_to_matrix, matrix_to_literal};
use crate::util::mat::Matrix;

/// Owns the PJRT CPU client, the artifact manifest and a lazily-populated
/// cache of compiled executables (one compile per artifact per process).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine over an artifacts directory (must contain
    /// `manifest.txt`; run `make artifacts` to produce it).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts directory: `$SGEMM_CUBE_ARTIFACTS` or
    /// `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SGEMM_CUBE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Engine over [`Engine::default_dir`].
    pub fn from_default_dir() -> Result<Engine> {
        Engine::new(&Engine::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'; have {:?}", self.manifest.names()))
    }

    /// Compile (or fetch from cache) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.spec(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .ok_or_else(|| anyhow!("non-UTF8 artifact path {:?}", spec.path))?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` on row-major f32 inputs; returns the
    /// outputs as row-major f32 matrices per the manifest specs.
    ///
    /// All shipped artifacts are lowered with `return_tuple=True`, so the
    /// single result literal is a tuple decomposed against the manifest.
    pub fn run(&self, name: &str, inputs: &[&Matrix<f32>]) -> Result<Vec<Matrix<f32>>> {
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(spec.inputs.iter())
            .enumerate()
            .map(|(i, (m, s))| {
                matrix_to_literal(m, s).with_context(|| format!("input {i} of '{name}'"))
            })
            .collect::<Result<_>>()?;

        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        parts
            .iter()
            .zip(spec.outputs.iter())
            .enumerate()
            .map(|(i, (lit, s))| {
                literal_to_matrix(lit, s).with_context(|| format!("output {i} of '{name}'"))
            })
            .collect()
    }

    /// Convenience for the GEMM artifacts: `C = artifact(A, B)`.
    pub fn gemm(&self, name: &str, a: &Matrix<f32>, b: &Matrix<f32>) -> Result<Matrix<f32>> {
        let out = self.run(name, &[a, b])?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow!("artifact '{name}' returned no outputs"))
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.platform())
            .field("artifacts", &self.manifest.names())
            .finish()
    }
}
