//! # SGEMM-cube
//!
//! Reproduction of *"SGEMM-cube: Emulating FP32 GEMM on Ascend NPUs Using
//! FP16 Cube Units with Precision Recovery"* (Xue et al., 2025).
//!
//! The library is organized as a three-layer stack:
//!
//! * **L1 (Pallas, build time)** — the split / three-term GEMM kernels live
//!   in `python/compile/kernels/` and are AOT-lowered to HLO text.
//! * **L2 (JAX, build time)** — `python/compile/model.py` composes the
//!   kernels into full compute graphs (cube matmul, MLP fwd/bwd).
//! * **L3 (this crate, runtime)** — loads the artifacts through PJRT
//!   ([`runtime`]), serves GEMM requests ([`coordinator`]), and hosts the
//!   substrates the paper's evaluation needs: a bit-exact software FP16
//!   ([`softfloat`]), an exact numerics engine ([`gemm`]), and a DaVinci
//!   performance simulator ([`sim`]) standing in for Ascend 910A hardware.
//!
//! See `DESIGN.md` for the experiment index mapping every table and figure
//! of the paper to a module and a bench target.

#![warn(missing_docs)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod experiments;
pub mod gemm;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod softfloat;
pub mod train;
pub mod util;
