//! Bit-exact software implementation of IEEE-754 binary16 ("FP16") with
//! explicit control over rounding mode and subnormal support.
//!
//! This substrate stands in for the Ascend Cube unit's FP16 datapath: the
//! paper's accuracy results depend only on binary16 conversion/rounding
//! semantics (round-to-nearest-even on Ascend), which are reproduced here
//! exactly. The round-toward-zero mode exists to reproduce the *prior
//! work* behaviour the paper contrasts against (Markidis et al., and the
//! Tensor Core internal RZ accumulation identified by Ootomo & Yokota).
//!
//! Submodules:
//! * [`f16`] — the `F16` type: conversion, arithmetic helpers, ULP tools.
//! * [`split`] — the two-component FP32→2×FP16 split of Eq. (7).
//! * [`family`] — the N-component precision-emulation family
//!   generalizing the split over component count and format.
//! * [`analysis`] — the RN underflow-probability and precision-bits
//!   analysis of Sec. 4 (Fig. 2).

pub mod analysis;
pub mod bf16;
pub mod f16;
pub mod family;
pub mod split;

pub use f16::{F16, Rounding, SubnormalMode};
pub use family::{split_family, reconstruct_family, ComponentFormat, FamilySplit, SplitSpec, MAX_COMPONENTS};
pub use split::{split_f32, reconstruct, SplitConfig, SplitMatrix};
