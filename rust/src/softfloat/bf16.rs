//! IEEE-style bfloat16 (1 sign, 8 exponent, 7 mantissa) — the "other
//! low-precision matrix engine" format of the paper's future-work list.
//!
//! BF16 shares FP32's exponent range, so a two-component BF16 split has
//! **no range limitation** (unlike the FP16 scheme, which is confined to
//! the FP16-representable window and needs residual scaling at all).
//! The trade is mantissa: 2×(7+1) explicit+hidden bits recover ≈ 16
//! bits instead of the FP16 scheme's ≈ 22. This mirrors the TF32
//! fallback Ootomo & Yokota added for full-range inputs (Sec. 2).

/// A bfloat16 value stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Bf16(pub u16);

const EXP_MASK: u16 = 0x7f80;
const MAN_MASK: u16 = 0x007f;

impl Bf16 {
    /// Positive zero.
    pub const ZERO: Bf16 = Bf16(0);
    /// The value 1.0.
    pub const ONE: Bf16 = Bf16(0x3f80);
    /// Positive infinity.
    pub const INFINITY: Bf16 = Bf16(0x7f80);
    /// Largest finite value ≈ 3.39e38.
    pub const MAX: Bf16 = Bf16(0x7f7f);

    /// Round-to-nearest-even conversion from f32 (bf16 is the upper 16
    /// bits of the f32 pattern, so RN is a 16-bit mantissa round).
    #[inline]
    pub fn from_f32_rn(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            return Bf16(((bits >> 16) as u16) | 0x0040); // quiet, keep payload top
        }
        let round_bit = (bits >> 15) & 1;
        let sticky = bits & 0x7fff;
        let mut hi = (bits >> 16) as u16;
        if round_bit == 1 && (sticky != 0x0 || hi & 1 == 1) {
            // halfway w/ odd, or above halfway -> round up (may carry to inf)
            if sticky == 0x0 {
                // exact tie handled by the hi&1 check above
            }
            hi = hi.wrapping_add(1);
        }
        Bf16(hi)
    }

    /// Truncating conversion (RZ) — for the rounding-mode ablations.
    #[inline]
    pub fn from_f32_rz(x: f32) -> Bf16 {
        if x.is_nan() {
            return Bf16(((x.to_bits() >> 16) as u16) | 0x0040);
        }
        Bf16((x.to_bits() >> 16) as u16)
    }

    /// Exact widening to f32 (pad with zero low bits).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// True for any NaN pattern.
    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & EXP_MASK) == EXP_MASK && (self.0 & MAN_MASK) != 0
    }

    /// True for ±infinity.
    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & EXP_MASK) == EXP_MASK && (self.0 & MAN_MASK) == 0
    }
}

/// Split an f32 into two BF16 components: `v ≈ high + low`. No residual
/// scaling is needed — BF16's exponent range covers every f32 residual.
#[inline]
pub fn split_bf16(v: f32) -> (Bf16, Bf16) {
    let high = Bf16::from_f32_rn(v);
    if !v.is_finite() {
        // Family-wide non-finite contract (see `softfloat::family`): the
        // first component carries the converted NaN/Inf, the residual is
        // exactly zero. (`v - high.to_f32()` would otherwise be NaN for
        // both Inf and NaN inputs.)
        return (high, Bf16::ZERO);
    }
    if high.is_infinite() {
        // |v| rounded past BF16::MAX (only the very top of the f32
        // range): keep the truncated high part so the pair stays finite.
        let high = Bf16::from_f32_rz(v);
        let low = Bf16::from_f32_rn(v - high.to_f32());
        return (high, low);
    }
    let low = Bf16::from_f32_rn(v - high.to_f32());
    (high, low)
}

/// Reconstruct `high + low`.
#[inline]
pub fn reconstruct_bf16(high: Bf16, low: Bf16) -> f32 {
    high.to_f32() + low.to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_values() {
        assert_eq!(Bf16::from_f32_rn(1.0), Bf16::ONE);
        assert_eq!(Bf16::ONE.to_f32(), 1.0);
        assert_eq!(Bf16::from_f32_rn(f32::INFINITY), Bf16::INFINITY);
        assert!(Bf16::from_f32_rn(f32::NAN).is_nan());
        assert_eq!(Bf16::from_f32_rn(-2.0).to_f32(), -2.0);
    }

    #[test]
    fn roundtrip_exact_for_bf16_values() {
        for hi in (0u16..0x7f80).step_by(3) {
            let b = Bf16(hi);
            assert_eq!(Bf16::from_f32_rn(b.to_f32()), b, "hi={hi:#06x}");
        }
    }

    #[test]
    fn rn_is_nearest() {
        let mut rng = Rng::new(1);
        for _ in 0..50_000 {
            let v = f32::from_bits(rng.next_u32() & 0x7f7f_ffff); // finite, positive exp field < max
            if !v.is_finite() {
                continue;
            }
            let h = Bf16::from_f32_rn(v);
            if h.is_infinite() || h.is_nan() {
                continue;
            }
            let hv = h.to_f32() as f64;
            let up = Bf16(h.0.wrapping_add(1));
            let down = Bf16(h.0.wrapping_sub(1));
            let d = (v as f64 - hv).abs();
            if !up.is_nan() && !up.is_infinite() && up.0 > h.0 {
                assert!(d <= (v as f64 - up.to_f32() as f64).abs() + 1e-30, "v={v}");
            }
            if !down.is_nan() && down.0 < h.0 && (h.0 & 0x7fff) != 0 {
                assert!(d <= (v as f64 - down.to_f32() as f64).abs() + 1e-30, "v={v}");
            }
        }
    }

    #[test]
    fn split_recovers_about_16_bits_any_exponent() {
        // The headline property: the full f32 *normal* exponent range at
        // ~16 bits. (Below ~2^-110 the residual itself dips into f32's
        // subnormal range and the guarantee tapers off — an f32 storage
        // artifact, not a bf16 one.)
        let mut rng = Rng::new(2);
        for e in [-110, -60, -12, 0, 15, 40, 90, 120] {
            for _ in 0..2_000 {
                let v = rng.f32_with_exponent(e);
                let (h, l) = split_bf16(v);
                let rel = ((v as f64) - reconstruct_bf16(h, l) as f64).abs() / (v as f64).abs();
                assert!(rel <= 2f64.powi(-15), "e={e} v={v} rel={rel:.3e}");
            }
        }
    }

    #[test]
    fn fp16_cube_range_fails_where_bf16_works() {
        // Contrast with the FP16 scheme: e = 40 overflows the FP16 high
        // component entirely.
        use crate::softfloat::split::{split_f32, SplitConfig};
        let mut rng = Rng::new(3);
        let v = rng.f32_with_exponent(40);
        let (h16, _) = split_f32(v, &SplitConfig::default());
        assert!(h16.is_infinite());
        let (hb, lb) = split_bf16(v);
        assert!(!hb.is_infinite());
        let rel = ((v as f64) - reconstruct_bf16(hb, lb) as f64).abs() / (v as f64).abs();
        assert!(rel <= 2f64.powi(-15));
    }

    #[test]
    fn non_finite_inputs_have_zero_residual() {
        // Family-wide non-finite contract: component 0 carries the
        // converted NaN/Inf, the residual is exactly zero (previously
        // `inf - inf` / NaN propagation gave a NaN low component).
        let (h, l) = split_bf16(f32::NAN);
        assert!(h.is_nan());
        assert_eq!(l, Bf16::ZERO);
        assert!(reconstruct_bf16(h, l).is_nan());
        for v in [f32::INFINITY, f32::NEG_INFINITY] {
            let (h, l) = split_bf16(v);
            assert!(h.is_infinite());
            assert_eq!(l, Bf16::ZERO);
            assert_eq!(reconstruct_bf16(h, l), v);
        }
    }

    #[test]
    fn rz_truncates_toward_zero() {
        let v = 1.0 + 2f32.powi(-8) + 2f32.powi(-9); // rounds up under RN
        assert_eq!(Bf16::from_f32_rz(v).to_f32(), 1.0); // bits below ulp=2^-7 dropped
        assert!(Bf16::from_f32_rz(v).to_f32() <= v);
        assert!(Bf16::from_f32_rn(v).to_f32() > Bf16::from_f32_rz(v).to_f32());
    }
}
