//! The two-component FP32 → 2×FP16 splitting of Eq. (7):
//!
//! ```text
//! A_half   = to_half(A_single)
//! R_A,half = to_half((A_single - to_single(A_half)) * s_f)
//! A_single ≈ to_single(A_half) + to_single(R_A,half) / s_f
//! ```
//!
//! The scaling factor `s_f = 2^{s_b}` amplifies the residual before the
//! second conversion so that small residuals stay clear of the FP16
//! subnormal range (Rule 1), while `s_b <= 12` avoids residual overflow
//! for inputs up to the FP16 maximum (Rule 2). The paper's default — and
//! ours — is `s_b = 12`.

use crate::softfloat::f16::{F16, Rounding, SubnormalMode};
use crate::util::mat::Matrix;

/// Configuration of the splitting operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SplitConfig {
    /// Scaling exponent `s_b` (factor is `2^{s_b}`). Paper default: 12.
    pub scale_exp: i32,
    /// Conversion rounding mode. Ascend: RN.
    pub rounding: Rounding,
    /// FP16 subnormal handling.
    pub subnormals: SubnormalMode,
}

impl Default for SplitConfig {
    fn default() -> Self {
        SplitConfig {
            scale_exp: 12,
            rounding: Rounding::Nearest,
            subnormals: SubnormalMode::Supported,
        }
    }
}

impl SplitConfig {
    /// Default configuration with an explicit scaling exponent `s_b`.
    pub fn with_scale(scale_exp: i32) -> Self {
        SplitConfig { scale_exp, ..Default::default() }
    }

    /// `s_f = 2^{s_b}` as f32 (exact for |s_b| < 128).
    #[inline]
    pub fn scale_factor(&self) -> f32 {
        (self.scale_exp as f32).exp2()
    }
}

/// Split one FP32 value into `(high, scaled residual)`.
#[inline]
pub fn split_f32(v: f32, cfg: &SplitConfig) -> (F16, F16) {
    let high = F16::from_f32(v, cfg.rounding).apply_subnormal_mode(cfg.subnormals);
    // `to_single(high)` is exact; the subtraction is exact by Sterbenz-ish
    // closeness whenever `high` is finite and near `v` (error analysis in
    // Sec. 4); multiplication by a power of two is exact absent
    // overflow/underflow.
    // Non-finite contract (shared by every split in the precision
    // family, see `softfloat::family`): the *first* component carries the
    // format-converted NaN/Inf; every residual component is exactly zero.
    // Without this, `v - high.to_f32()` is NaN for NaN *and* overflowed
    // inputs, and the policy's range scan / shard recombination would see
    // a NaN low component where reconstruction promises ±inf.
    let residual = if !v.is_finite() || high.is_infinite() {
        // Overflowed or non-finite high part: the scheme is out of range
        // (Sec. 3.1). Keep the residual at zero; reconstruction returns
        // the high component's ±inf / NaN.
        0.0
    } else {
        (v - high.to_f32()) * cfg.scale_factor()
    };
    let low = F16::from_f32(residual, cfg.rounding).apply_subnormal_mode(cfg.subnormals);
    (high, low)
}

/// Reconstruct the FP32 approximation `high + low / s_f`.
#[inline]
pub fn reconstruct(high: F16, low: F16, cfg: &SplitConfig) -> f32 {
    high.to_f32() + low.to_f32() / cfg.scale_factor()
}

/// A matrix split into its high and scaled-residual FP16 components —
/// the operand format consumed by the three-term cube GEMM.
#[derive(Debug, Clone)]
pub struct SplitMatrix {
    /// FP16 high components.
    pub high: Matrix<F16>,
    /// FP16 scaled-residual components.
    pub low: Matrix<F16>,
    /// The split configuration both components were produced under.
    pub cfg: SplitConfig,
}

impl SplitMatrix {
    /// Split every element of `m`.
    pub fn from_f32(m: &Matrix<f32>, cfg: SplitConfig) -> SplitMatrix {
        let mut high = Matrix::zeros(m.rows(), m.cols());
        let mut low = Matrix::zeros(m.rows(), m.cols());
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                let (h, l) = split_f32(m.get(i, j), &cfg);
                high.set(i, j, h);
                low.set(i, j, l);
            }
        }
        SplitMatrix { high, low, cfg }
    }

    /// Reconstruct the FP32 approximation of the original matrix.
    pub fn reconstruct(&self) -> Matrix<f32> {
        let mut out = Matrix::zeros(self.high.rows(), self.high.cols());
        for i in 0..out.rows() {
            for j in 0..out.cols() {
                out.set(i, j, reconstruct(self.high.get(i, j), self.low.get(i, j), &self.cfg));
            }
        }
        out
    }

    /// `(rows, cols)` of the split matrix.
    pub fn shape(&self) -> (usize, usize) {
        self.high.shape()
    }
}

/// Count the retained mantissa bits of the split representation of `v`:
/// `-log2(|v - reconstruct| / |v|)` (∞-clamped at 24 when exact). Used by
/// the Fig. 2(b) empirical curve.
pub fn retained_bits(v: f32, cfg: &SplitConfig) -> f64 {
    if v == 0.0 {
        return 24.0;
    }
    let (h, l) = split_f32(v, cfg);
    let approx = reconstruct(h, l, cfg) as f64;
    let rel = ((v as f64) - approx).abs() / (v as f64).abs();
    if rel == 0.0 {
        24.0
    } else {
        (-rel.log2()).clamp(0.0, 24.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn split_is_exact_for_fp16_values() {
        // Values already representable in FP16 have zero residual.
        for v in [1.0f32, -0.5, 1024.0, 65504.0, 2.0f32.powi(-14)] {
            let cfg = SplitConfig::default();
            let (h, l) = split_f32(v, &cfg);
            assert_eq!(h.to_f32(), v);
            assert_eq!(l.to_f32(), 0.0);
            assert_eq!(reconstruct(h, l, &cfg), v);
        }
    }

    #[test]
    fn split_recovers_about_22_bits_moderate_range() {
        let cfg = SplitConfig::default();
        let mut rng = Rng::new(99);
        for _ in 0..50_000 {
            let e = (rng.usize_below(25) as i32) - 12; // e in [-12, 12]
            let v = rng.f32_with_exponent(e);
            let bits = retained_bits(v, &cfg);
            assert!(bits >= 21.9, "v={v} (e={e}) retained only {bits:.2} bits");
        }
    }

    #[test]
    fn unscaled_split_loses_bits_at_small_exponents() {
        // Without scaling, e = -13 inputs lose residual precision to
        // gradual underflow (Rule 1).
        let cfg = SplitConfig::with_scale(0);
        let mut rng = Rng::new(7);
        let mut min_bits: f64 = 24.0;
        for _ in 0..20_000 {
            let v = rng.f32_with_exponent(-13);
            min_bits = min_bits.min(retained_bits(v, &cfg));
        }
        assert!(min_bits < 22.0, "expected precision loss, min_bits={min_bits:.2}");
        // With s_b = 12 the same regime retains full precision.
        let cfg12 = SplitConfig::with_scale(12);
        let mut rng = Rng::new(7);
        let mut min_bits12: f64 = 24.0;
        for _ in 0..20_000 {
            let v = rng.f32_with_exponent(-13);
            min_bits12 = min_bits12.min(retained_bits(v, &cfg12));
        }
        assert!(min_bits12 >= 21.9, "min_bits12={min_bits12:.2}");
    }

    #[test]
    fn residual_subtraction_is_exact() {
        // (v - to_single(to_half(v))) must be exact in f32: verify by
        // recomputing in f64.
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            let e = (rng.usize_below(30) as i32) - 14;
            let v = rng.f32_with_exponent(e);
            let h = F16::from_f32_rn(v);
            let r32 = v - h.to_f32();
            let r64 = v as f64 - h.to_f32() as f64;
            assert_eq!(r32 as f64, r64, "inexact residual for v={v}");
        }
    }

    #[test]
    fn overflowing_high_part_reconstructs_to_inf() {
        let cfg = SplitConfig::default();
        let (h, l) = split_f32(1e7, &cfg);
        assert!(h.is_infinite());
        assert_eq!(l, F16::ZERO);
        assert!(reconstruct(h, l, &cfg).is_infinite());
    }

    #[test]
    fn rule2_residual_overflow_beyond_sb12() {
        // With s_b > 12 a large input's residual can overflow FP16
        // (Rule 2). Find a witness near the FP16 max.
        let cfg15 = SplitConfig::with_scale(15);
        let mut overflowed = false;
        let mut rng = Rng::new(11);
        for _ in 0..50_000 {
            let v = rng.f32_with_exponent(15);
            let (h, l) = split_f32(v, &cfg15);
            if !h.is_infinite() && l.is_infinite() {
                overflowed = true;
                break;
            }
        }
        assert!(overflowed, "expected at least one residual overflow at s_b=15");
        // ... and s_b = 12 never overflows the residual for e <= 14.
        let cfg12 = SplitConfig::default();
        let mut rng = Rng::new(11);
        for _ in 0..50_000 {
            let v = rng.f32_with_exponent(14);
            let (h, l) = split_f32(v, &cfg12);
            if !h.is_infinite() {
                assert!(!l.is_infinite(), "residual overflow at s_b=12 for v={v}");
            }
        }
    }

    #[test]
    fn non_finite_inputs_have_zero_residual() {
        // The family-wide non-finite contract: component 0 carries the
        // converted NaN/Inf, all residuals are exactly zero.
        let cfg = SplitConfig::default();
        let (h, l) = split_f32(f32::NAN, &cfg);
        assert!(h.is_nan());
        assert_eq!(l, F16::ZERO);
        assert!(reconstruct(h, l, &cfg).is_nan());
        for v in [f32::INFINITY, f32::NEG_INFINITY] {
            let (h, l) = split_f32(v, &cfg);
            assert!(h.is_infinite());
            assert_eq!(l, F16::ZERO);
            assert_eq!(reconstruct(h, l, &cfg), v);
        }
        // Matrix-level: a NaN element must not poison its residual plane.
        let mut m = Matrix::zeros(2, 2);
        m.set(0, 0, f32::NAN);
        m.set(1, 1, 3.5);
        let sm = SplitMatrix::from_f32(&m, cfg);
        assert!(sm.high.get(0, 0).is_nan());
        assert_eq!(sm.low.get(0, 0), F16::ZERO);
        assert_eq!(sm.high.get(1, 1).to_f32(), 3.5);
    }

    #[test]
    fn rule2_tie_edge_case_at_e15() {
        // Reproduction finding: the paper's Rule 2 analysis (N = 0 →
        // residual weight 2^{E-12}) misses exact RN ties, whose residual
        // magnitude is 2^{E-11}. At E = 15 and s_b = 12 the scaled
        // residual is then 2^16 > 65504 and overflows FP16.
        // v = 61936 = (1935.5) * 32 is exactly halfway between the fp16
        // neighbours 61920 and 61952; ties-to-even picks 61952, leaving
        // residual -16 = -2^4, which scales to -65536 -> -inf.
        let cfg = SplitConfig::default();
        let (h, l) = split_f32(61936.0, &cfg);
        assert_eq!(h.to_f32(), 61952.0);
        assert!(l.is_infinite(), "expected the tie-case residual to overflow");
        // Any non-tie neighbour is fine.
        let (h2, l2) = split_f32(61937.0, &cfg);
        assert!(!h2.is_infinite() && !l2.is_infinite());
    }

    #[test]
    fn matrix_split_reconstruct_close() {
        let mut rng = Rng::new(21);
        let m = Matrix::random_symmetric(16, 24, 0, &mut rng);
        let sm = SplitMatrix::from_f32(&m, SplitConfig::default());
        assert_eq!(sm.shape(), (16, 24));
        let r = sm.reconstruct();
        for i in 0..16 {
            for j in 0..24 {
                let v = m.get(i, j) as f64;
                let w = r.get(i, j) as f64;
                let tol = v.abs().max(2f64.powi(-30)) * 2f64.powi(-21);
                assert!((v - w).abs() <= tol, "({i},{j}): {v} vs {w}");
            }
        }
    }

    #[test]
    fn rz_split_biased_vs_rn() {
        // RZ residuals are systematically non-negative-biased for positive
        // inputs (truncation always rounds |.| down): reconstruction error
        // mean should be worse than RN's.
        let mut rng = Rng::new(5);
        let (mut rn_err, mut rz_err) = (0.0f64, 0.0f64);
        let n = 20_000;
        for _ in 0..n {
            let v = rng.f32_with_exponent(0);
            let rn = SplitConfig { rounding: Rounding::Nearest, ..Default::default() };
            let rz = SplitConfig { rounding: Rounding::TowardZero, ..Default::default() };
            let (h1, l1) = split_f32(v, &rn);
            let (h2, l2) = split_f32(v, &rz);
            rn_err += ((reconstruct(h1, l1, &rn) as f64) - v as f64).abs();
            rz_err += ((reconstruct(h2, l2, &rz) as f64) - v as f64).abs();
        }
        assert!(rz_err > rn_err, "rz_err={rz_err} rn_err={rn_err}");
    }
}
