//! The N-component precision-emulation family.
//!
//! The paper's Eq. (7) decomposition — FP32 → high + scaled residual in
//! FP16 — is one point in a family of split-and-correct schemes (Ozaki
//! et al.; Bayraktar et al.'s BF16×3 "exceeds FP32"; Mukunoki's
//! FP8-based emulated DGEMM). This module makes the component **count**
//! and component **format** parameters instead of structure:
//!
//! * a value `v` splits into components `c_0 .. c_{N-1}` such that
//!   `v ≈ Σ c_i · w^i` where `w` is the per-format component weight
//!   (`2^{-s_b}` for the FP16 scheme, `1` for BF16);
//! * a GEMM over two split operands keeps the cross terms
//!   `A_i · B_j` with `i + j ≤ N - 1` (the terms of order `d = i + j`
//!   share the weight `w^d`), generalizing the paper's three-term
//!   recovery (N = 2: `A_h·B_h`, `A_h·B_l`, `A_l·B_h`);
//! * each spec carries its derived error bound so the coordinator's
//!   policy can pick the cheapest spec meeting a requested budget.
//!
//! **Non-finite contract** (shared with [`split_f32`] and
//! [`split_bf16`]): for NaN/Inf inputs the *first* component carries the
//! format-converted non-finite value and every residual component is
//! exactly zero, so reconstruction — and therefore the GEMM's output —
//! propagates the NaN/Inf through the order-0 term only.

use crate::softfloat::bf16::{split_bf16, Bf16};
use crate::softfloat::f16::F16;
use crate::softfloat::split::{split_f32, SplitConfig};
use crate::util::mat::Matrix;

/// Upper bound on the component count any spec in the family may carry.
/// Sized so kernel accumulator arrays can be fixed-size; raising it is a
/// mechanical change (the kernels loop over the runtime count).
pub const MAX_COMPONENTS: usize = 4;

/// The storage/conversion format of each split component.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComponentFormat {
    /// FP16 components with the paper's power-of-two residual scaling
    /// (`s_f = 2^{s_b}`): high accuracy (≈ 11 bits per component) but
    /// confined to the FP16-representable exponent window of Eq. (6).
    Fp16Scaled(SplitConfig),
    /// BF16 components, unscaled — BF16 shares FP32's exponent range, so
    /// the scheme covers the full f32 normal range at ≈ 8 bits per
    /// component.
    Bf16,
}

/// A point in the precision-emulation family: component format ×
/// component count, plus the derived term schedule and error bound.
///
/// The spec is `Copy + Eq + Hash` so it can key prepack caches directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SplitSpec {
    /// Format every component is stored/rounded in.
    pub format: ComponentFormat,
    /// Number of components `N` (2 ..= [`MAX_COMPONENTS`]).
    pub components: u8,
}

impl SplitSpec {
    /// The paper's scheme: 2×FP16 with residual scaling `cfg`.
    pub fn fp16x2(cfg: SplitConfig) -> SplitSpec {
        SplitSpec { format: ComponentFormat::Fp16Scaled(cfg), components: 2 }
    }

    /// 2×BF16, unscaled: ≈ 16 bits over the full f32 exponent range.
    pub fn bf16x2() -> SplitSpec {
        SplitSpec { format: ComponentFormat::Bf16, components: 2 }
    }

    /// 3×BF16, unscaled: ≈ 24 bits (meets/exceeds FP32) full-range.
    pub fn bf16x3() -> SplitSpec {
        SplitSpec { format: ComponentFormat::Bf16, components: 3 }
    }

    /// Component count as a usize (always in `2 ..= MAX_COMPONENTS`).
    #[inline]
    pub fn ncomp(&self) -> usize {
        let n = self.components as usize;
        assert!((2..=MAX_COMPONENTS).contains(&n), "component count {n} out of range");
        n
    }

    /// Number of kept `A_i·B_j` cross terms: `N(N+1)/2` — the cube-pass
    /// count of the tier (3 for N = 2, 6 for N = 3).
    #[inline]
    pub fn passes(&self) -> usize {
        let n = self.ncomp();
        n * (n + 1) / 2
    }

    /// The kept cross terms `(i, j)` in the paper's termwise order:
    /// grouped by order `d = i + j` ascending (terms of one order share
    /// an accumulator plane), `i` ascending within an order.
    pub fn kept_terms(&self) -> Vec<(usize, usize)> {
        let n = self.ncomp();
        let mut terms = Vec::with_capacity(self.passes());
        for d in 0..n {
            for i in 0..=d {
                terms.push((i, d - i));
            }
        }
        terms
    }

    /// Weight of component `i` (and equally of the order-`d = i`
    /// accumulator plane at combine time): `2^{-i·s_b}` for the FP16
    /// scheme, `1` for BF16. Exact powers of two, so multiplying by the
    /// weight is exact absent underflow.
    #[inline]
    pub fn comp_weight(&self, i: usize) -> f32 {
        match self.format {
            ComponentFormat::Fp16Scaled(cfg) => (-(cfg.scale_exp * i as i32) as f32).exp2(),
            ComponentFormat::Bf16 => 1.0,
        }
    }

    /// The per-order combine weights `w^0 .. w^{N-1}` (padded with zeros
    /// beyond `N`), in the layout the fused kernels consume.
    pub fn order_weights(&self) -> [f32; MAX_COMPONENTS] {
        let mut w = [0.0f32; MAX_COMPONENTS];
        for (d, slot) in w.iter_mut().enumerate().take(self.ncomp()) {
            *slot = self.comp_weight(d);
        }
        w
    }

    /// Approximate recovered mantissa bits of the tier — the derived
    /// error bound the policy compares against a requested budget.
    /// FP16: ≈ 11 bits per component *inside the Eq. (6) window*
    /// (22 for the paper's N = 2). BF16: ≈ 8 bits per component over the
    /// full f32 range (16 for ×2, 24 for ×3). Clamped at FP32-storage
    /// limits.
    pub fn bound_bits(&self) -> f64 {
        let n = self.components as i32;
        match self.format {
            ComponentFormat::Fp16Scaled(_) => (11 * n).min(24) as f64,
            ComponentFormat::Bf16 => (8 * n).min(30) as f64,
        }
    }

    /// True when the tier covers the full f32 normal exponent range
    /// (BF16); false for the window-limited FP16 scheme.
    #[inline]
    pub fn full_range(&self) -> bool {
        matches!(self.format, ComponentFormat::Bf16)
    }

    /// Canonical tier name: `fp16x2`, `bf16x2`, `bf16x3`, …
    pub fn name(&self) -> String {
        let tag = match self.format {
            ComponentFormat::Fp16Scaled(_) => "fp16",
            ComponentFormat::Bf16 => "bf16",
        };
        format!("{tag}x{}", self.components)
    }

    /// Parse a tier name (`fp16xN` uses the default `SplitConfig`).
    pub fn parse(s: &str) -> Option<SplitSpec> {
        let (tag, n) = s.split_once('x')?;
        let n: u8 = n.parse().ok()?;
        if !(2..=MAX_COMPONENTS as u8).contains(&n) {
            return None;
        }
        match tag {
            "fp16" => Some(SplitSpec { format: ComponentFormat::Fp16Scaled(SplitConfig::default()), components: n }),
            "bf16" => Some(SplitSpec { format: ComponentFormat::Bf16, components: n }),
            _ => None,
        }
    }
}

/// Split one f32 into the spec's components, each widened back to f32
/// (the engine packs and multiplies components as f32 — widening is
/// exact for both FP16 and BF16). Slots past `N` are zero.
///
/// Bit-compatibility: at `N = 2` this is exactly [`split_f32`] /
/// [`split_bf16`] (the first two components are produced *by* them).
/// Extra components cascade: `c_i = round(r_i)`, `r_{i+1} = (r_i − c_i)`
/// rescaled by `s_f` for the FP16 scheme.
pub fn split_family(v: f32, spec: &SplitSpec) -> [f32; MAX_COMPONENTS] {
    let n = spec.ncomp();
    let mut out = [0.0f32; MAX_COMPONENTS];
    match spec.format {
        ComponentFormat::Fp16Scaled(cfg) => {
            let (h, l) = split_f32(v, &cfg);
            out[0] = h.to_f32();
            out[1] = l.to_f32();
            if n > 2 && v.is_finite() && !h.is_infinite() {
                // Continue the Eq. (7) cascade past the paper's two
                // components: r_1 is exact (see split.rs), and each
                // further residual subtraction is exact by Sterbenz.
                let mut r = (v - h.to_f32()) * cfg.scale_factor();
                let mut c = l;
                for slot in out.iter_mut().take(n).skip(2) {
                    if c.is_infinite() {
                        break; // Rule-2 residual overflow: stop the cascade
                    }
                    r = (r - c.to_f32()) * cfg.scale_factor();
                    c = F16::from_f32(r, cfg.rounding).apply_subnormal_mode(cfg.subnormals);
                    *slot = c.to_f32();
                }
            }
        }
        ComponentFormat::Bf16 => {
            let (h, l) = split_bf16(v);
            out[0] = h.to_f32();
            out[1] = l.to_f32();
            if n > 2 && v.is_finite() && !h.is_infinite() && !l.is_infinite() {
                // r_2 = (v - c_0) - c_1: both subtractions are exact
                // (c_1 = RN(v - c_0), so Sterbenz applies).
                let mut r = v - out[0] - out[1];
                for slot in out.iter_mut().take(n).skip(2) {
                    let c = Bf16::from_f32_rn(r);
                    *slot = c.to_f32();
                    r -= c.to_f32();
                }
            }
        }
    }
    out
}

/// Reconstruct `Σ c_i · w^i`, folding from the smallest term up (the
/// same tail-first order the fused kernels use at combine time). At
/// `N = 2` FP16 this is bit-identical to [`crate::softfloat::split::reconstruct`].
pub fn reconstruct_family(comps: &[f32; MAX_COMPONENTS], spec: &SplitSpec) -> f32 {
    let n = spec.ncomp();
    let mut tail = 0.0f32;
    for i in (1..n).rev() {
        tail = comps[i] * spec.comp_weight(i) + tail;
    }
    comps[0] + tail
}

/// A matrix split into N f32-widened component planes — the operand
/// format consumed by the family GEMM engine. Replaces the former
/// `SplitMatrix`/`BfSplit` pair for every tier except the fp16×2 fast
/// path (which keeps the dedicated dual-panel layout for bit-identity
/// with the pre-family engine).
#[derive(Debug, Clone)]
pub struct FamilySplit {
    comps: Vec<Matrix<f32>>,
    spec: SplitSpec,
}

impl FamilySplit {
    /// Split every element of `m` under `spec`.
    pub fn of(m: &Matrix<f32>, spec: SplitSpec) -> FamilySplit {
        let n = spec.ncomp();
        let mut comps: Vec<Matrix<f32>> =
            (0..n).map(|_| Matrix::zeros(m.rows(), m.cols())).collect();
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                let c = split_family(m.get(i, j), &spec);
                for (p, plane) in comps.iter_mut().enumerate() {
                    plane.set(i, j, c[p]);
                }
            }
        }
        FamilySplit { comps, spec }
    }

    /// The spec this operand was split under.
    #[inline]
    pub fn spec(&self) -> SplitSpec {
        self.spec
    }

    /// The component planes, order 0 (high) first.
    #[inline]
    pub fn comps(&self) -> &[Matrix<f32>] {
        &self.comps
    }

    /// Component plane `i`.
    #[inline]
    pub fn comp(&self, i: usize) -> &Matrix<f32> {
        &self.comps[i]
    }

    /// `(rows, cols)` of the split matrix.
    pub fn shape(&self) -> (usize, usize) {
        self.comps[0].shape()
    }

    /// Reconstruct the f32 approximation of the original matrix.
    pub fn reconstruct(&self) -> Matrix<f32> {
        let (r, c) = self.shape();
        let mut out = Matrix::zeros(r, c);
        let mut comps = [0.0f32; MAX_COMPONENTS];
        for i in 0..r {
            for j in 0..c {
                for (p, plane) in self.comps.iter().enumerate() {
                    comps[p] = plane.get(i, j);
                }
                out.set(i, j, reconstruct_family(&comps, &self.spec));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softfloat::split::reconstruct;
    use crate::util::rng::Rng;

    fn rel_err(v: f64, w: f64) -> f64 {
        if v == 0.0 {
            w.abs()
        } else {
            (v - w).abs() / v.abs()
        }
    }

    #[test]
    fn fp16x2_matches_split_f32_bitwise() {
        let cfg = SplitConfig::default();
        let spec = SplitSpec::fp16x2(cfg);
        let mut rng = Rng::new(17);
        for _ in 0..50_000 {
            let e = (rng.usize_below(32) as i32) - 16;
            let v = rng.f32_with_exponent(e);
            let c = split_family(v, &spec);
            let (h, l) = split_f32(v, &cfg);
            assert_eq!(c[0].to_bits(), h.to_f32().to_bits(), "v={v}");
            assert_eq!(c[1].to_bits(), l.to_f32().to_bits(), "v={v}");
            assert_eq!(c[2], 0.0);
            let rec = reconstruct_family(&c, &spec);
            assert_eq!(rec.to_bits(), reconstruct(h, l, &cfg).to_bits(), "v={v}");
        }
    }

    #[test]
    fn bf16x2_matches_split_bf16_bitwise() {
        let spec = SplitSpec::bf16x2();
        let mut rng = Rng::new(18);
        for e in [-60, -12, 0, 15, 40, 90] {
            for _ in 0..5_000 {
                let v = rng.f32_with_exponent(e);
                let c = split_family(v, &spec);
                let (h, l) = split_bf16(v);
                assert_eq!(c[0].to_bits(), h.to_f32().to_bits(), "v={v}");
                assert_eq!(c[1].to_bits(), l.to_f32().to_bits(), "v={v}");
                assert_eq!(c[2], 0.0);
            }
        }
    }

    #[test]
    fn bf16x3_recovers_about_24_bits_full_range() {
        let spec = SplitSpec::bf16x3();
        let mut rng = Rng::new(19);
        for e in [-60, -20, -5, 0, 8, 20, 45, 90] {
            for _ in 0..5_000 {
                let v = rng.f32_with_exponent(e);
                let c = split_family(v, &spec);
                let rec = reconstruct_family(&c, &spec) as f64;
                // Three BF16 components carry >= 24 significand bits;
                // the reconstruction is exact at f32 precision for all
                // but tie patterns, and never worse than ~2^-22.
                assert!(rel_err(v as f64, rec) <= 2f64.powi(-22), "e={e} v={v} rec={rec}");
            }
        }
    }

    #[test]
    fn fp16x3_extends_the_cascade_inside_the_window() {
        let spec = SplitSpec { format: ComponentFormat::Fp16Scaled(SplitConfig::default()), components: 3 };
        let mut rng = Rng::new(20);
        for _ in 0..20_000 {
            let e = (rng.usize_below(21) as i32) - 10;
            let v = rng.f32_with_exponent(e);
            let c = split_family(v, &spec);
            let rec = reconstruct_family(&c, &spec) as f64;
            assert!(rel_err(v as f64, rec) <= 2f64.powi(-23), "e={e} v={v}");
        }
    }

    #[test]
    fn non_finite_contract_all_formats() {
        for spec in [
            SplitSpec::fp16x2(SplitConfig::default()),
            SplitSpec::bf16x2(),
            SplitSpec::bf16x3(),
            SplitSpec { format: ComponentFormat::Fp16Scaled(SplitConfig::default()), components: 3 },
        ] {
            let c = split_family(f32::NAN, &spec);
            assert!(c[0].is_nan(), "{}", spec.name());
            assert!(c[1..].iter().all(|&x| x == 0.0), "{}", spec.name());
            assert!(reconstruct_family(&c, &spec).is_nan(), "{}", spec.name());
            for v in [f32::INFINITY, f32::NEG_INFINITY] {
                let c = split_family(v, &spec);
                assert!(c[0].is_infinite(), "{}", spec.name());
                assert!(c[1..].iter().all(|&x| x == 0.0), "{}", spec.name());
                assert_eq!(reconstruct_family(&c, &spec), v, "{}", spec.name());
            }
        }
    }

    #[test]
    fn term_schedule_and_passes() {
        let s2 = SplitSpec::fp16x2(SplitConfig::default());
        assert_eq!(s2.passes(), 3);
        assert_eq!(s2.kept_terms(), vec![(0, 0), (0, 1), (1, 0)]);
        let s3 = SplitSpec::bf16x3();
        assert_eq!(s3.passes(), 6);
        assert_eq!(s3.kept_terms(), vec![(0, 0), (0, 1), (1, 0), (0, 2), (1, 1), (2, 0)]);
        // Every kept term's order is < N; weights match the order.
        for (i, j) in s3.kept_terms() {
            assert!(i + j < s3.ncomp());
        }
        let w = s2.order_weights();
        assert_eq!(w[0], 1.0);
        assert_eq!(w[1], 2f32.powi(-12));
        assert_eq!(w[2], 0.0);
        assert_eq!(s3.order_weights(), [1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn names_parse_roundtrip() {
        for spec in [SplitSpec::fp16x2(SplitConfig::default()), SplitSpec::bf16x2(), SplitSpec::bf16x3()] {
            assert_eq!(SplitSpec::parse(&spec.name()), Some(spec));
        }
        assert_eq!(SplitSpec::parse("fp16x2"), Some(SplitSpec::fp16x2(SplitConfig::default())));
        assert!(SplitSpec::parse("fp16x1").is_none());
        assert!(SplitSpec::parse("fp16x9").is_none());
        assert!(SplitSpec::parse("fp8x2").is_none());
        assert!(SplitSpec::parse("bf16").is_none());
    }

    #[test]
    fn matrix_family_split_reconstructs() {
        let mut rng = Rng::new(23);
        let m = Matrix::random_symmetric(9, 13, 0, &mut rng);
        for spec in [SplitSpec::fp16x2(SplitConfig::default()), SplitSpec::bf16x2(), SplitSpec::bf16x3()] {
            let fs = FamilySplit::of(&m, spec);
            assert_eq!(fs.shape(), (9, 13));
            assert_eq!(fs.comps().len(), spec.ncomp());
            let r = fs.reconstruct();
            let tol = 2f64.powf(-(spec.bound_bits() - 1.5));
            for i in 0..9 {
                for j in 0..13 {
                    let v = m.get(i, j) as f64;
                    let w = r.get(i, j) as f64;
                    assert!(rel_err(v, w) <= tol, "{} ({i},{j}): {v} vs {w}", spec.name());
                }
            }
        }
    }

    #[test]
    fn bound_bits_ladder() {
        assert_eq!(SplitSpec::fp16x2(SplitConfig::default()).bound_bits(), 22.0);
        assert_eq!(SplitSpec::bf16x2().bound_bits(), 16.0);
        assert_eq!(SplitSpec::bf16x3().bound_bits(), 24.0);
        assert!(SplitSpec::bf16x2().full_range());
        assert!(!SplitSpec::fp16x2(SplitConfig::default()).full_range());
    }
}
