//! IEEE-754 binary16 implemented over `u16` bit patterns.
//!
//! Layout: 1 sign bit, 5 exponent bits (bias 15), 10 mantissa bits,
//! implicit leading 1 for normal values, gradual underflow via
//! subnormals. This mirrors the FP16 format of the Ascend Cube units
//! (Sec. 3.3 of the paper).

/// Rounding mode for `f32 -> f16` conversion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// Round-to-nearest, ties-to-even — what Ascend NPUs implement and
    /// what the paper's analysis (Sec. 4) assumes.
    Nearest,
    /// Round-toward-zero (truncation) — used by prior GPU work
    /// (Markidis et al.) and by Tensor Core internal accumulation;
    /// reproduced for the comparison experiments.
    TowardZero,
}

/// Whether subnormal (denormal) FP16 values are kept or flushed to zero.
/// Fig. 2(a) contrasts both behaviours.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SubnormalMode {
    /// Gradual underflow: subnormal results are kept.
    Supported,
    /// Subnormal results flush to (sign-preserving) zero.
    FlushToZero,
}

const EXP_MASK: u16 = 0x7c00;
const MAN_MASK: u16 = 0x03ff;
const SIGN_MASK: u16 = 0x8000;

/// A binary16 value stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct F16(pub u16);

impl F16 {
    /// Positive zero.
    pub const ZERO: F16 = F16(0);
    /// Negative zero.
    pub const NEG_ZERO: F16 = F16(0x8000);
    /// The value 1.0.
    pub const ONE: F16 = F16(0x3c00);
    /// Positive infinity.
    pub const INFINITY: F16 = F16(0x7c00);
    /// Negative infinity.
    pub const NEG_INFINITY: F16 = F16(0xfc00);
    /// Largest finite value: (2 - 2^-10) * 2^15 = 65504.
    pub const MAX: F16 = F16(0x7bff);
    /// Smallest positive normal value: 2^-14.
    pub const MIN_POSITIVE: F16 = F16(0x0400);
    /// Smallest positive subnormal value: 2^-24.
    pub const MIN_SUBNORMAL: F16 = F16(0x0001);
    /// A quiet NaN.
    pub const NAN: F16 = F16(0x7e00);

    /// Value with the given bit pattern.
    #[inline]
    pub fn from_bits(bits: u16) -> F16 {
        F16(bits)
    }

    /// The raw bit pattern.
    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// True for any NaN pattern.
    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & EXP_MASK) == EXP_MASK && (self.0 & MAN_MASK) != 0
    }

    /// True for ±infinity.
    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & EXP_MASK) == EXP_MASK && (self.0 & MAN_MASK) == 0
    }

    /// True for nonzero values with a zero biased exponent.
    #[inline]
    pub fn is_subnormal(self) -> bool {
        (self.0 & EXP_MASK) == 0 && (self.0 & MAN_MASK) != 0
    }

    /// True for ±0.
    #[inline]
    pub fn is_zero(self) -> bool {
        (self.0 & !SIGN_MASK) == 0
    }

    /// True when the sign bit is set (including -0 and negative NaNs).
    #[inline]
    pub fn is_sign_negative(self) -> bool {
        (self.0 & SIGN_MASK) != 0
    }

    /// Convert with round-to-nearest-even (the Ascend behaviour).
    #[inline]
    pub fn from_f32_rn(x: f32) -> F16 {
        F16(f32_to_f16_bits(x, Rounding::Nearest))
    }

    /// Convert with round-toward-zero.
    #[inline]
    pub fn from_f32_rz(x: f32) -> F16 {
        F16(f32_to_f16_bits(x, Rounding::TowardZero))
    }

    /// Convert with an explicit rounding mode.
    #[inline]
    pub fn from_f32(x: f32, mode: Rounding) -> F16 {
        F16(f32_to_f16_bits(x, mode))
    }

    /// Exact widening conversion to f32 (every binary16 value is exactly
    /// representable in binary32).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    /// Flush subnormals to (sign-preserving) zero if `mode` says so.
    #[inline]
    pub fn apply_subnormal_mode(self, mode: SubnormalMode) -> F16 {
        match mode {
            SubnormalMode::Supported => self,
            SubnormalMode::FlushToZero => {
                if self.is_subnormal() {
                    F16(self.0 & SIGN_MASK)
                } else {
                    self
                }
            }
        }
    }

    /// Unbiased exponent of a finite non-zero value (subnormals report
    /// their effective exponent based on the leading significand bit).
    pub fn exponent(self) -> Option<i32> {
        if self.is_nan() || self.is_infinite() || self.is_zero() {
            return None;
        }
        let e = ((self.0 & EXP_MASK) >> 10) as i32;
        if e != 0 {
            Some(e - 15)
        } else {
            // Subnormal: 0.M * 2^-14 — effective exponent from the
            // position of the highest set mantissa bit.
            let m = self.0 & MAN_MASK;
            let lead = 15 - m.leading_zeros() as i32; // bit index of MSB (0..=9)
            Some(-15 - (9 - lead)) // m == 0x200 -> 2^-15, m == 1 -> 2^-24
        }
    }
}

/// Core f32 -> f16 bit conversion.
pub fn f32_to_f16_bits(x: f32, mode: Rounding) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN: preserve NaN-ness (quiet bit set).
        return if man != 0 { sign | 0x7e00 } else { sign | 0x7c00 };
    }

    let e = exp - 127; // unbiased f32 exponent (exp == 0 handled below)

    if exp == 0 {
        // f32 subnormal: magnitude < 2^-126, far below the f16 range.
        return sign; // rounds to zero under both modes
    }

    if e >= 16 {
        // Overflow.
        return match mode {
            Rounding::Nearest => sign | 0x7c00,    // -> inf
            Rounding::TowardZero => sign | 0x7bff, // -> max finite
        };
    }

    if e >= -14 {
        // Normal f16 range.
        let out = (((e + 15) as u32) << 10) | (man >> 13);
        let rem = man & 0x1fff;
        let rounded = match mode {
            Rounding::TowardZero => out,
            Rounding::Nearest => {
                if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
                    out + 1 // carry may roll into the exponent and even to inf — correct RN behaviour
                } else {
                    out
                }
            }
        };
        return sign | rounded as u16;
    }

    if e >= -25 {
        // Subnormal f16 range: represent as 0.M * 2^-14.
        let sig = 0x0080_0000u32 | man; // 24-bit significand of 1.M
        let shift = (13 + (-14 - e)) as u32; // 14..=24
        let out = sig >> shift;
        let rem = sig & ((1u32 << shift) - 1);
        let rounded = match mode {
            Rounding::TowardZero => out,
            Rounding::Nearest => {
                let half = 1u32 << (shift - 1);
                if rem > half || (rem == half && (out & 1) == 1) {
                    out + 1
                } else {
                    out
                }
            }
        };
        return sign | rounded as u16;
    }

    // |x| < 2^-25: underflows to zero under RN (nearest is 0) and RZ.
    sign
}

/// Exact f16 -> f32 bit conversion.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & SIGN_MASK) as u32) << 16;
    let exp = ((h & EXP_MASK) >> 10) as u32;
    let man = (h & MAN_MASK) as u32;

    if exp == 0x1f {
        // Inf / NaN.
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: value = man * 2^-24 with man in [1, 0x3ff].
        let p = 31 - man.leading_zeros(); // MSB index, 0..=9
        let frac = (man << (10 - p)) & (MAN_MASK as u32); // implicit bit dropped
        let e32 = p + 103; // biased exponent of 2^(p - 24)
        return f32::from_bits(sign | (e32 << 23) | (frac << 13));
    }
    // Normal.
    f32::from_bits(sign | ((exp + 127 - 15) << 23) | (man << 13))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(h: u16) -> u16 {
        f32_to_f16_bits(f16_bits_to_f32(h), Rounding::Nearest)
    }

    #[test]
    fn exact_roundtrip_all_finite_f16() {
        // Every finite f16 is exactly representable in f32; RN back must
        // be the identity. Exhaustive over all 65536 patterns.
        for bits in 0u16..=0xffff {
            let h = F16(bits);
            if h.is_nan() {
                let rt = F16(roundtrip(bits));
                assert!(rt.is_nan(), "NaN-ness lost for {bits:#06x}");
            } else {
                assert_eq!(roundtrip(bits), bits, "roundtrip failed for {bits:#06x}");
            }
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(F16::from_f32_rn(1.0).to_bits(), 0x3c00);
        assert_eq!(F16::from_f32_rn(-2.0).to_bits(), 0xc000);
        assert_eq!(F16::from_f32_rn(65504.0).to_bits(), 0x7bff);
        assert_eq!(F16::from_f32_rn(0.5).to_bits(), 0x3800);
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(F16::MAX.to_f32(), 65504.0);
        assert_eq!(F16::MIN_POSITIVE.to_f32(), 2.0f32.powi(-14));
        assert_eq!(F16::MIN_SUBNORMAL.to_f32(), 2.0f32.powi(-24));
    }

    #[test]
    fn rn_ties_to_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10: ties to even -> 1.0.
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(F16::from_f32_rn(halfway).to_bits(), 0x3c00);
        // (1 + 2^-10) + 2^-11 is halfway with odd lower bit: rounds up.
        let halfway_odd = 1.0 + 2.0f32.powi(-10) + 2.0f32.powi(-11);
        assert_eq!(F16::from_f32_rn(halfway_odd).to_bits(), 0x3c02);
        // Just above halfway always rounds up.
        let above = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20);
        assert_eq!(F16::from_f32_rn(above).to_bits(), 0x3c01);
    }

    #[test]
    fn rz_truncates() {
        let v = 1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-12); // would RN to 0x3c01
        assert_eq!(F16::from_f32_rz(v).to_bits(), 0x3c00);
        assert_eq!(F16::from_f32_rn(v).to_bits(), 0x3c01);
    }

    #[test]
    fn overflow_behaviour_by_mode() {
        assert_eq!(F16::from_f32_rn(1e6).to_bits(), 0x7c00); // inf
        assert_eq!(F16::from_f32_rz(1e6).to_bits(), 0x7bff); // max finite
        assert_eq!(F16::from_f32_rn(-1e6).to_bits(), 0xfc00);
        // RN boundary: values below 65520 round to max finite, >= 65520 to inf.
        assert_eq!(F16::from_f32_rn(65519.0).to_bits(), 0x7bff);
        assert_eq!(F16::from_f32_rn(65520.0).to_bits(), 0x7c00);
    }

    #[test]
    fn subnormal_conversion() {
        // 2^-24 is the smallest subnormal.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(F16::from_f32_rn(tiny).to_bits(), 0x0001);
        // 2^-25 is exactly halfway between 0 and 2^-24 -> ties to even -> 0.
        assert_eq!(F16::from_f32_rn(2.0f32.powi(-25)).to_bits(), 0x0000);
        // Slightly above 2^-25 rounds to 2^-24.
        assert_eq!(F16::from_f32_rn(2.0f32.powi(-25) * 1.5).to_bits(), 0x0001);
        // Below 2^-25 underflows to zero.
        assert_eq!(F16::from_f32_rn(2.0f32.powi(-26)).to_bits(), 0x0000);
        // A mid-range subnormal: 3 * 2^-16 = 0.0000457763671875.
        let v = 3.0 * 2.0f32.powi(-16);
        let h = F16::from_f32_rn(v);
        assert!(h.is_subnormal());
        assert_eq!(h.to_f32(), v);
    }

    #[test]
    fn flush_to_zero_mode() {
        let sub = F16::from_f32_rn(2.0f32.powi(-20));
        assert!(sub.is_subnormal());
        assert_eq!(sub.apply_subnormal_mode(SubnormalMode::FlushToZero), F16::ZERO);
        assert_eq!(sub.apply_subnormal_mode(SubnormalMode::Supported), sub);
        let neg_sub = F16::from_f32_rn(-(2.0f32.powi(-20)));
        assert_eq!(neg_sub.apply_subnormal_mode(SubnormalMode::FlushToZero), F16::NEG_ZERO);
        // Normals are untouched.
        assert_eq!(F16::ONE.apply_subnormal_mode(SubnormalMode::FlushToZero), F16::ONE);
    }

    #[test]
    fn nan_and_inf_conversion() {
        assert!(F16::from_f32_rn(f32::NAN).is_nan());
        assert_eq!(F16::from_f32_rn(f32::INFINITY), F16::INFINITY);
        assert_eq!(F16::from_f32_rn(f32::NEG_INFINITY), F16::NEG_INFINITY);
        assert!(F16::NAN.to_f32().is_nan());
        assert_eq!(F16::INFINITY.to_f32(), f32::INFINITY);
    }

    #[test]
    fn signed_zero() {
        assert_eq!(F16::from_f32_rn(0.0).to_bits(), 0x0000);
        assert_eq!(F16::from_f32_rn(-0.0).to_bits(), 0x8000);
        assert_eq!(F16::NEG_ZERO.to_f32().to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f32_subnormal_input_flushes() {
        let tiny32 = f32::from_bits(1); // smallest f32 subnormal
        assert_eq!(F16::from_f32_rn(tiny32).to_bits(), 0);
        assert_eq!(F16::from_f32_rz(-tiny32).to_bits(), 0x8000);
    }

    #[test]
    fn exponent_extraction() {
        assert_eq!(F16::ONE.exponent(), Some(0));
        assert_eq!(F16::from_f32_rn(0.25).exponent(), Some(-2));
        assert_eq!(F16::MIN_POSITIVE.exponent(), Some(-14));
        assert_eq!(F16::MIN_SUBNORMAL.exponent(), Some(-24));
        assert_eq!(F16::from_f32_rn(2.0f32.powi(-15)).exponent(), Some(-15));
        assert_eq!(F16::ZERO.exponent(), None);
        assert_eq!(F16::INFINITY.exponent(), None);
        assert_eq!(F16::NAN.exponent(), None);
    }

    #[test]
    fn conversion_matches_native_as_cast() {
        // Rust's `as` cast f32->f16 isn't available pre-1.78 w/o feature,
        // but f16->f32 widening via our table must agree with the IEEE
        // values; spot-check a dense grid through exact arithmetic.
        for bits in (0u16..0x7c00).step_by(7) {
            let v = f16_bits_to_f32(bits);
            // Reconvert and ensure exactness (v is exactly representable).
            assert_eq!(f32_to_f16_bits(v, Rounding::TowardZero), bits);
        }
    }

    #[test]
    fn rn_is_nearest_exhaustive_sample() {
        // For a sample of f32 values, verify RN picks the closer of the
        // two neighbouring f16 values (distance via f64 exactness).
        let mut state = 0x1234_5678_9abc_def0u64;
        for _ in 0..20_000 {
            let r = crate::util::rng::splitmix64(&mut state);
            let v = f32::from_bits((r as u32) & 0x477f_ffff); // |v| <= ~65504, positive exp range
            if !v.is_finite() {
                continue;
            }
            let h = F16::from_f32_rn(v);
            if h.is_infinite() {
                continue;
            }
            let hv = h.to_f32() as f64;
            // neighbours
            let up = F16(h.to_bits() + 1);
            let down = if h.to_bits() & 0x7fff != 0 { Some(F16(h.to_bits() - 1)) } else { None };
            let d = (v as f64 - hv).abs();
            if !up.is_infinite() && !up.is_nan() {
                assert!(d <= (v as f64 - up.to_f32() as f64).abs() + 1e-30, "v={v}");
            }
            if let Some(dn) = down {
                if !dn.is_nan() {
                    assert!(d <= (v as f64 - dn.to_f32() as f64).abs() + 1e-30, "v={v}");
                }
            }
        }
    }
}
