//! The RN-based accuracy and range analysis of Sec. 4 (Fig. 2).
//!
//! * Eq. (3): probability of truncation/rounding events given `N` leading
//!   zeros in the residual mantissa.
//! * Eq. (4)–(5): underflow and gradual-underflow probabilities of the
//!   residual as a function of the input's offset exponent.
//! * Eq. (6) and Fig. 2(b): retained precision bits vs input exponent,
//!   with and without residual scaling.
//!
//! Each analytic curve has a Monte-Carlo counterpart (measured on the
//! bit-exact [`crate::softfloat::f16`] implementation) so the benches can
//! print *analytic vs measured* side by side.

use crate::softfloat::f16::SubnormalMode;
use crate::softfloat::split::{retained_bits, SplitConfig};
use crate::util::rng::Rng;

/// FP32 mantissa bits.
pub const L_M: i32 = 23;
/// FP16 mantissa bits.
pub const L_M_HIGH: i32 = 10;
/// FP16 exponent bias.
pub const B_LOW: i32 = 15;

/// Eq. (3): `P(X | N = n)` for X ∈ {truncation, rounding}. Both events
/// have equal probability in every nonterminal case, so we return the
/// *combined* probability `P(T, n) + P(R, n)` of observing `N = n`.
pub fn prob_n(n: i32) -> f64 {
    let max_n = L_M - L_M_HIGH - 1; // 12
    if n < -1 || n > max_n {
        0.0
    } else if n == -1 {
        // 11th mantissa bit set, rest zero: 2 * (1/2)^(l_M - l_Mhigh + 1)
        2.0 * 0.5f64.powi(L_M - L_M_HIGH + 1)
    } else if n < max_n {
        // 2 * (1/2)^(n+2)
        2.0 * 0.5f64.powi(n + 2)
    } else {
        // n == 12: only truncation contributes.
        0.5f64.powi(L_M - L_M_HIGH)
    }
}

/// Eq. (5), first line: probability of underflow *or* gradual underflow
/// of the residual for offset exponent `e` (subnormals unsupported →
/// any gradual-underflow case already loses bits).
pub fn prob_underflow_or_gradual(e_offset: i32) -> f64 {
    // Gradual underflow when N > E_offset - l_Mhigh + b_low - 3,
    // i.e. N >= E_offset + 3 (Eq. 4 with l_Mhigh=10, b_low=15).
    let start = e_offset - L_M_HIGH + B_LOW - 2;
    sum_prob_from(start)
}

/// Eq. (5), second line: probability of complete underflow (below the
/// FP16 subnormal range) for offset exponent `e`.
pub fn prob_underflow(e_offset: i32) -> f64 {
    let start = e_offset + B_LOW - 2;
    sum_prob_from(start)
}

fn sum_prob_from(start: i32) -> f64 {
    let max_n = L_M - L_M_HIGH - 1;
    let lo = start.max(-1);
    if lo > max_n {
        return 0.0;
    }
    (lo..=max_n).map(prob_n).sum()
}

/// Monte-Carlo measurement of residual underflow rates on the bit-exact
/// FP16: returns `(underflow_or_gradual, underflow)` observed fractions
/// for random FP32 inputs with the given offset exponent.
///
/// Events are classified by the *true* (pre-rounding) residual exponent,
/// matching Eq. (4): the residual's leading bit sits at weight
/// `2^{E - 12 - N}`, so gradual underflow ⇔ that weight `< 2^{-14}` and
/// complete underflow ⇔ `< 2^{-24}`.
pub fn measure_underflow(e_offset: i32, samples: usize, rng: &mut Rng) -> (f64, f64) {
    let mut gradual_or_under = 0usize;
    let mut under = 0usize;
    for _ in 0..samples {
        let v = rng.f32_with_exponent(e_offset);
        let h = crate::softfloat::f16::F16::from_f32_rn(v);
        let residual = v - h.to_f32();
        if residual == 0.0 {
            continue; // exactly representable: no residual to lose
        }
        let e_r = residual.abs().log2().floor() as i32;
        if e_r < -14 {
            gradual_or_under += 1;
        }
        if e_r < -24 {
            under += 1;
        }
    }
    (
        gradual_or_under as f64 / samples as f64,
        under as f64 / samples as f64,
    )
}

/// Eq. (6)-style analytic model of retained mantissa bits as a function
/// of the input offset exponent `e` and scaling exponent `s_b`
/// (Fig. 2(b)). The model:
///
/// * high part overflows for `e > 15` → 0 bits (out of the method's range);
/// * the scaled residual can represent weights down to `2^{-24 - s_b}`
///   (unscaled), so retained bits ≈ `min(22, e + 24 + s_b + 1)` on the
///   underflow side (the `+1` accounting for RN recovering up to half an
///   ULP on average is omitted — we report the guaranteed floor);
/// * the scaled residual overflows FP16 when `e - 12 + s_b > 15`
///   (Rule 2), costing the overflowed bits.
pub fn precision_bits_model(e_offset: i32, s_b: i32, subnormals: SubnormalMode) -> f64 {
    if e_offset > 15 {
        return 0.0; // high part overflow: not representable
    }
    if e_offset < -24 {
        return 0.0; // below even FP16 subnormal for the high part
    }
    // Smallest unscaled residual weight that survives conversion.
    let min_weight = match subnormals {
        SubnormalMode::Supported => -24 - s_b,
        SubnormalMode::FlushToZero => -14 - s_b,
    };
    // Residual-overflow penalty (Rule 2): the residual's leading bit sits
    // at weight 2^{e-12-N}; worst typical case N = 0 gives 2^{e-12}
    // (the paper's analysis). Exact RN *ties* can produce |r| = 2^{e-11},
    // one weight higher — a measure-zero set the paper's rule ignores;
    // our reproduction observes it empirically (see split.rs tests and
    // EXPERIMENTS.md) but the model follows the paper.
    let resid_exp = e_offset - 12 + s_b;
    let overflow_loss = (resid_exp - 15).max(0);
    // Bits spanned from the leading bit (weight 2^e) down to min_weight,
    // capped by the 22 explicit bits the two mantissas hold.
    let span = (e_offset - min_weight) as f64;
    // High part alone holds 11 explicit bits (if within range); below
    // 2^-14 it is subnormal and holds fewer.
    let high_bits = if e_offset >= -14 {
        11.0
    } else {
        (11 + (e_offset + 14)).max(0) as f64 // gradual underflow of the high part
    };
    // Contiguity cap: the low component extends the high one by at most
    // 11 more significant bits, however large s_b is — once the high
    // part is subnormal, extra residual scaling cannot add information
    // (Sec. 3.1: recovering that range would require scaling *both*
    // components). This cap is what makes "grow s_b below the window"
    // a non-feature; see experiments::ablations::run_dynamic_scaling.
    let contiguous_cap = high_bits + 11.0;
    (span.min(22.0).min(contiguous_cap) - overflow_loss as f64).max(high_bits.min(22.0))
}

/// Monte-Carlo measurement of the retained-bits curve: the *minimum*
/// retained bits over `samples` random inputs at exponent `e` (the
/// worst-case curve the paper plots).
pub fn measure_precision_bits(e_offset: i32, s_b: i32, samples: usize, rng: &mut Rng) -> f64 {
    let cfg = SplitConfig::with_scale(s_b);
    let mut min_bits: f64 = 24.0;
    for _ in 0..samples {
        let v = rng.f32_with_exponent(e_offset);
        min_bits = min_bits.min(retained_bits(v, &cfg));
    }
    min_bits
}

/// One row of the Fig. 2(a) sweep.
#[derive(Debug, Clone, Copy)]
pub struct UnderflowRow {
    /// Input offset exponent `e`.
    pub e_offset: i32,
    /// Eq. (5) analytic P(underflow or gradual underflow).
    pub analytic_gradual_or_under: f64,
    /// Eq. (5) analytic P(complete underflow).
    pub analytic_under: f64,
    /// Monte-Carlo measured gradual-or-under fraction.
    pub measured_gradual_or_under: f64,
    /// Monte-Carlo measured complete-underflow fraction.
    pub measured_under: f64,
}

/// Sweep Fig. 2(a) over `e ∈ [lo, hi]`.
pub fn underflow_sweep(lo: i32, hi: i32, samples: usize, seed: u64) -> Vec<UnderflowRow> {
    let mut rng = Rng::new(seed);
    (lo..=hi)
        .map(|e| {
            let (mg, mu) = measure_underflow(e, samples, &mut rng);
            UnderflowRow {
                e_offset: e,
                analytic_gradual_or_under: prob_underflow_or_gradual(e),
                analytic_under: prob_underflow(e),
                measured_gradual_or_under: mg,
                measured_under: mu,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prob_n_is_a_distribution() {
        let total: f64 = (-2..=13).map(prob_n).sum();
        assert!((total - 1.0).abs() < 1e-12, "total={total}");
        assert_eq!(prob_n(-2), 0.0);
        assert_eq!(prob_n(13), 0.0);
        // n = -1: 2 * 2^-14
        assert!((prob_n(-1) - 2.0 * 0.5f64.powi(14)).abs() < 1e-15);
        // n = 0: 2 * 2^-2 = 0.5
        assert!((prob_n(0) - 0.5).abs() < 1e-15);
        // n = 12 (terminal): 2^-13
        assert!((prob_n(12) - 0.5f64.powi(13)).abs() < 1e-15);
    }

    #[test]
    fn underflow_monotone_decreasing_in_exponent() {
        for e in -20..20 {
            assert!(
                prob_underflow_or_gradual(e) >= prob_underflow_or_gradual(e + 1) - 1e-15,
                "not monotone at e={e}"
            );
            assert!(prob_underflow(e) >= prob_underflow(e + 1) - 1e-15);
        }
    }

    #[test]
    fn underflow_paper_anchor_points() {
        // Paper (Fig. 2a): without subnormals, gradual-underflow prob
        // exceeds 10% at E_offset = 0.
        assert!(prob_underflow_or_gradual(0) > 0.10, "{}", prob_underflow_or_gradual(0));
        // With subnormals, significant underflow only below -10,
        // approaching 100% below -12.
        assert!(prob_underflow(-10) < 0.35);
        assert!(prob_underflow(-13) > 0.95);
        // Large exponents: no underflow at all.
        assert_eq!(prob_underflow_or_gradual(15), 0.0);
        assert_eq!(prob_underflow(3), 0.0);
    }

    #[test]
    fn measured_matches_analytic_underflow() {
        let mut rng = Rng::new(42);
        for e in [-13, -11, -6, 0, 2] {
            let (mg, mu) = measure_underflow(e, 60_000, &mut rng);
            let ag = prob_underflow_or_gradual(e);
            let au = prob_underflow(e);
            assert!((mg - ag).abs() < 0.02, "e={e}: measured {mg} vs analytic {ag}");
            assert!((mu - au).abs() < 0.02, "e={e}: measured {mu} vs analytic {au}");
        }
    }

    #[test]
    fn precision_model_shifts_left_by_scaling() {
        // Fig. 2(b): s_b = 12 shifts the degradation curve 12 exponents
        // down.
        for e in -10..=0 {
            let unscaled = precision_bits_model(e, 0, SubnormalMode::Supported);
            let scaled = precision_bits_model(e - 12, 12, SubnormalMode::Supported);
            assert!((unscaled - scaled).abs() <= 1.0 + 1e-9, "e={e}: {unscaled} vs {scaled}");
        }
    }

    #[test]
    fn precision_model_full_bits_in_moderate_range() {
        for e in -12..=15 {
            let bits = precision_bits_model(e, 12, SubnormalMode::Supported);
            assert!(bits >= 22.0 - 1e-9, "e={e}: {bits}");
        }
        // Without scaling, e = -12 has collapsed to ~the high part alone.
        let collapsed = precision_bits_model(-12, 0, SubnormalMode::Supported);
        assert!(collapsed <= 12.0, "collapsed={collapsed}");
    }

    #[test]
    fn measured_precision_not_worse_than_model_floor() {
        let mut rng = Rng::new(17);
        for (e, sb) in [(0, 0), (-6, 0), (-12, 12), (0, 12), (-20, 12)] {
            let measured = measure_precision_bits(e, sb, 4_000, &mut rng);
            let model = precision_bits_model(e, sb, SubnormalMode::Supported);
            assert!(
                measured + 1.0 >= model,
                "e={e} sb={sb}: measured {measured:.2} < model {model:.2}"
            );
        }
    }

    #[test]
    fn sweep_has_expected_shape() {
        let rows = underflow_sweep(-14, 4, 2_000, 1);
        assert_eq!(rows.len(), 19);
        assert!(rows.first().unwrap().analytic_under > 0.9);
        assert!(rows.last().unwrap().analytic_gradual_or_under < 0.05);
    }
}
