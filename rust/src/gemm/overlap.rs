//! Compatibility shim over the executor pipeline
//! ([`crate::exec::pipeline`]) plus the instrumented serial drivers.
//!
//! PR 3 introduced this module as the overlapped (double-buffered) host
//! pipeline for the blocked engine's `b_n → b_k` panel loop: a
//! dedicated per-call prefetch thread packing the next B panel through
//! a two-slot ring. The executor refactor generalized that ring —
//! depth-configurable slots, A-panel prefetch, persistent pool workers
//! instead of per-call spawns — and moved the machinery to
//! `exec/pipeline.rs`. What remains here:
//!
//! * the `SGEMM_CUBE_OVERLAP` toggle ([`overlap_enabled`]) feeding the
//!   default execution schedule
//!   ([`crate::gemm::backend::default_schedule`]);
//! * re-exports of the panel-schedule types ([`PanelJob`],
//!   [`panel_jobs`]) and the `run_overlapped` driver, now thin
//!   delegations to the pipeline at the classic depth 2;
//! * the **instrumented serial drivers** (`*_staged`): single-threaded
//!   passes timing each stage (pack-A, pack-B, micro-kernel, C update)
//!   into a [`crate::util::bench::StageBreakdown`]. The fig11 bench
//!   feeds those spans into
//!   [`crate::sim::pipeline::IterTiming::from_measured`] to calibrate
//!   the simulator's non-overlapped fraction α from real engine
//!   timings — see EXPERIMENTS.md §Overlap.
//!
//! **Bit identity** is unchanged: every `*_overlapped` entry point
//! packs with the same [`crate::gemm::pack`] routines, consumes blocks
//! in the same `b_n → b_k` order, and runs the same shared sweeps as
//! the serial drivers (enforced by `tests/properties.rs`).

use std::time::Instant;

pub use crate::exec::pipeline::{panel_jobs, PanelJob};

pub(crate) use crate::exec::pipeline::PanelSource;

use crate::exec::pipeline::{run_prefetch, PanelSlot, DEFAULT_PIPELINE_DEPTH};
use crate::gemm::blocked::{add_tile, add_tile_cube, exec_bm, host_block};
use crate::gemm::kernels;
use crate::gemm::pack::{self, MAX_MR, MAX_NR};
use crate::util::bench::StageBreakdown;
use crate::util::mat::Matrix;
use crate::util::threads::SendPtr;

/// Whether the pack-on-the-fly hot-path entry points should run the
/// overlapped pipeline: `SGEMM_CUBE_OVERLAP=1|true|on|yes` enables it,
/// anything else (or unset) keeps the serial driver. Results are
/// bit-identical either way; this only selects the schedule (the
/// richer `SGEMM_CUBE_SCHEDULE` env knob and the `[server] schedule`
/// config key supersede it, see
/// [`crate::gemm::backend::default_schedule`]).
///
/// The environment is read **once** per process (like
/// [`crate::gemm::blocked::host_block`]): this sits on the hot path of
/// every `fast::*` call and `GemmBackend::new`, and a cached read also
/// keeps the getenv out of multi-threaded request loops.
pub fn overlap_enabled() -> bool {
    static ENABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ENABLED.get_or_init(|| {
        std::env::var("SGEMM_CUBE_OVERLAP").is_ok_and(|v| parse_overlap_toggle(&v))
    })
}

/// The `SGEMM_CUBE_OVERLAP` value parser (split out so tests can cover
/// it without mutating process-global environment state).
fn parse_overlap_toggle(v: &str) -> bool {
    matches!(v.trim(), "1" | "true" | "on" | "yes")
}

/// Run `consume` over every job's packed B panel, with the next panel
/// packed ahead by a pool prefetch job (the classic two-slot schedule:
/// pipeline depth 2). Panels are packed at the width `nr` of the lane
/// the consumer will sweep with. Thin shim over
/// [`crate::exec::pipeline::run_prefetch`].
pub(crate) fn run_overlapped<F>(src: PanelSource<'_>, jobs: &[PanelJob], nr: usize, mut consume: F)
where
    F: FnMut(&PanelJob, &[f32]),
{
    run_prefetch(
        DEFAULT_PIPELINE_DEPTH,
        jobs.len(),
        |i: usize, slot: &mut PanelSlot| src.pack(&jobs[i], nr, &mut slot.b),
        |i: usize, slot: &PanelSlot| consume(&jobs[i], &slot.b),
    );
}

#[inline]
fn elapsed(t: Instant) -> f64 {
    t.elapsed().as_secs_f64()
}

/// Instrumented single-component serial driver: the exact serial nest,
/// single-threaded, with per-stage wall times accumulated into a
/// [`StageBreakdown`]. Calibration path only — the timer reads add a few
/// percent of overhead at small `kc`, so serving traffic never runs it.
/// The result is bit-identical to `sgemm_blocked` (same ops, same
/// order).
pub(crate) fn gemm_staged_core(a: &Matrix<f32>, b: &Matrix<f32>) -> (Matrix<f32>, StageBreakdown) {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let mut stages = StageBreakdown::default();
    if m == 0 || n == 0 || k == 0 {
        return (c, stages);
    }
    let block = host_block();
    // Same lane as the shared sweeps: resolved once per call, so the
    // staged timings measure the kernel (and panel geometry) the
    // serving paths actually run.
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let bm = exec_bm(m, block.bm, mr);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    let mut ap = Vec::new();
    let mut acc = [0.0f32; MAX_MR * MAX_NR];
    for job in panel_jobs(n, k, block.bn, block.bk) {
        let t = Instant::now();
        pack::pack_b(b, job.p0, job.kc, job.j0, job.nc, nr, &mut bp);
        stages.pack_b += elapsed(t);
        for i0 in (0..m).step_by(bm) {
            let mc = bm.min(m - i0);
            let t = Instant::now();
            pack::pack_a(a, i0, mc, job.p0, job.kc, mr, &mut ap);
            stages.pack_a += elapsed(t);
            for (rp, apanel) in ap.chunks_exact(job.kc * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(job.kc * nr).enumerate() {
                    let cj = job.j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    let t = Instant::now();
                    kernels::kernel_f32(lane, apanel, bpanel, &mut acc[..mr * nr]);
                    stages.kernel += elapsed(t);
                    let t = Instant::now();
                    add_tile(&cp, n, ci, cj, mr_eff, nr_eff, nr, &acc[..mr * nr]);
                    stages.c_update += elapsed(t);
                }
            }
        }
    }
    (c, stages)
}

/// Instrumented dual-component serial driver (cube counterpart of
/// [`gemm_staged_core`]); bit-identical to `cube_gemm_blocked` for the
/// same split operands.
pub(crate) fn cube_staged_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
) -> (Matrix<f32>, StageBreakdown) {
    let (m, k) = ah.shape();
    let n = bh.cols();
    let mut c = Matrix::zeros(m, n);
    let mut stages = StageBreakdown::default();
    if m == 0 || n == 0 || k == 0 {
        return (c, stages);
    }
    let block = host_block();
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let bm = exec_bm(m, block.bm, mr);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    let mut ap = Vec::new();
    let mut hh = [0.0f32; MAX_MR * MAX_NR];
    let mut corr = [0.0f32; MAX_MR * MAX_NR];
    for job in panel_jobs(n, k, block.bn, block.bk) {
        let t = Instant::now();
        pack::pack_b_dual(bh, bl, job.p0, job.kc, job.j0, job.nc, nr, &mut bp);
        stages.pack_b += elapsed(t);
        for i0 in (0..m).step_by(bm) {
            let mc = bm.min(m - i0);
            let t = Instant::now();
            pack::pack_a_dual(ah, al, i0, mc, job.p0, job.kc, mr, &mut ap);
            stages.pack_a += elapsed(t);
            for (rp, apanel) in ap.chunks_exact(job.kc * 2 * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(job.kc * 2 * nr).enumerate() {
                    let cj = job.j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    let t = Instant::now();
                    kernels::kernel_cube(
                        lane,
                        apanel,
                        bpanel,
                        &mut hh[..mr * nr],
                        &mut corr[..mr * nr],
                    );
                    stages.kernel += elapsed(t);
                    let t = Instant::now();
                    add_tile_cube(
                        &cp,
                        n,
                        ci,
                        cj,
                        mr_eff,
                        nr_eff,
                        nr,
                        &hh[..mr * nr],
                        &corr[..mr * nr],
                        inv_sf,
                    );
                    stages.c_update += elapsed(t);
                }
            }
        }
    }
    (c, stages)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn run_overlapped_delivers_every_panel_in_order() {
        use crate::gemm::pack::NR;
        let mut rng = Rng::new(91);
        let b = Matrix::random_symmetric(100, 50, 0, &mut rng);
        let jobs = panel_jobs(50, 100, 16, 32);
        // Both panel widths stage byte-identically to the serial packs.
        for nr in [NR, MAX_NR] {
            let mut want = Vec::new();
            let mut buf = Vec::new();
            for job in &jobs {
                pack::pack_b(&b, job.p0, job.kc, job.j0, job.nc, nr, &mut buf);
                want.push(buf.clone());
            }
            let mut got: Vec<Vec<f32>> = Vec::new();
            run_overlapped(PanelSource::Single(&b), &jobs, nr, |_, bp| got.push(bp.to_vec()));
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g, w, "nr={nr} overlapped panel differs from serial pack");
            }
        }
    }

    #[test]
    fn run_overlapped_handles_tiny_job_lists() {
        use crate::gemm::pack::NR;
        let b = Matrix::zeros(4, 4);
        let mut seen = 0;
        run_overlapped(PanelSource::Single(&b), &[], NR, |_, _| seen += 1);
        assert_eq!(seen, 0);
        let jobs = panel_jobs(4, 4, 16, 16);
        assert_eq!(jobs.len(), 1);
        run_overlapped(PanelSource::Single(&b), &jobs, NR, |_, bp| {
            seen += 1;
            assert_eq!(bp.len(), pack::b_panels(4, NR) * 4 * NR);
        });
        assert_eq!(seen, 1);
    }

    #[test]
    fn overlap_toggle_parsing() {
        // Parser covered directly — mutating the process environment in
        // a test would race other threads' getenv (and overlap_enabled
        // caches the first read anyway).
        for on in ["1", "true", "on", "yes", " 1 ", "true\n"] {
            assert!(parse_overlap_toggle(on), "{on:?}");
        }
        for off in ["0", "false", "off", "no", "", "2", "TRUE", "enabled"] {
            assert!(!parse_overlap_toggle(off), "{off:?}");
        }
        // The cached read agrees with the parser for this process's env.
        let want = std::env::var("SGEMM_CUBE_OVERLAP").is_ok_and(|v| parse_overlap_toggle(&v));
        assert_eq!(overlap_enabled(), want);
    }

    #[test]
    fn staged_breakdown_accounts_positive_stage_time() {
        let mut rng = Rng::new(92);
        let a = Matrix::random_symmetric(33, 65, 0, &mut rng);
        let b = Matrix::random_symmetric(65, 24, 0, &mut rng);
        let (c, stages) = gemm_staged_core(&a, &b);
        let serial = crate::gemm::blocked::sgemm_blocked(&a, &b);
        for (x, y) in c.as_slice().iter().zip(serial.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "staged result must be bit-identical");
        }
        assert!(stages.pack_a > 0.0 && stages.pack_b > 0.0);
        assert!(stages.kernel > 0.0 && stages.c_update > 0.0);
        assert!((stages.total() - stages.compute() - stages.transfer()).abs() < 1e-12);
    }
}
