//! Overlapped (double-buffered) host pipeline for the blocked engine's
//! `b_n → b_k` panel loop — the executed counterpart of the paper's
//! Fig. 7 double-buffered B stream.
//!
//! The serial blocked driver ([`crate::gemm::blocked`]) alternates two
//! phases per `(j, k)` block: pack the B panel (single-threaded, the
//! "transfer" analogue of the Ascend's main-memory → L1 B stream), then
//! sweep the row blocks against it (parallel, the "compute" analogue).
//! Packing therefore sits on the critical path exactly like the
//! non-overlapped `T_comp + T_mem` of Sec. 5.1.2.
//!
//! This module hides it the way the paper's double buffer does: a
//! dedicated **prefetch worker** packs the *next* `(k, j)` block's panel
//! (including the dual high/low split format) while the micro-kernel
//! consumes the current one, through a **two-slot panel ring** — two
//! `Vec<f32>` buffers whose ownership rotates main ⇄ prefetcher over a
//! pair of channels, so neither side ever waits on a lock and at most
//! one panel is in flight ahead of the consumer.
//!
//! **Bit identity.** The overlapped driver packs with the same
//! [`crate::gemm::pack`] routines, consumes blocks in the same
//! `b_n → b_k` order, and runs the same shared sweeps
//! ([`crate::gemm::blocked::sweep_rows_f32`] /
//! [`crate::gemm::blocked::sweep_rows_cube`]) over the same panel bytes
//! — so every `*_overlapped` entry point is byte-for-byte identical to
//! its serial counterpart (enforced by `tests/properties.rs`).
//!
//! On a single-core host (`num_threads() < 2`) the ring degenerates to
//! the serial pack-then-sweep loop — same code path as the serial
//! driver, no thread spawn, no oversubscription.
//!
//! Cost model: one scoped thread spawn/join plus two channel setups per
//! GEMM call — the same order as the per-block spawns the blocked
//! engine already accepts (see the parallelism note in
//! [`crate::gemm::blocked`]), worthwhile when the hidden pack-B span
//! exceeds it (large inline GEMMs), marginal at tiny serving shapes
//! (where the prepacked path skips B packing entirely anyway). The
//! persistent-worker-pool upgrade that would amortize both is tracked
//! in ROADMAP.md.
//!
//! The module also carries the **instrumented serial drivers**
//! (`*_staged`): single-threaded passes that time each stage (pack-A,
//! pack-B, micro-kernel, C update) into a
//! [`crate::util::bench::StageBreakdown`]. The fig11 bench feeds those
//! measured spans into [`crate::sim::pipeline::IterTiming::from_measured`]
//! to calibrate the simulator's non-overlapped fraction α from real
//! engine timings instead of the hard-coded guess — see EXPERIMENTS.md
//! §Overlap.

use std::sync::mpsc::channel;
use std::time::Instant;

use crate::gemm::blocked::{
    add_tile, add_tile_cube, exec_bm, host_block, kernel_cube, kernel_f32, sweep_rows_cube,
    sweep_rows_f32,
};
use crate::gemm::pack::{self, MR, NR};
use crate::util::bench::StageBreakdown;
use crate::util::mat::Matrix;
use crate::util::threads::SendPtr;

/// Whether the pack-on-the-fly hot-path entry points should run the
/// overlapped pipeline: `SGEMM_CUBE_OVERLAP=1|true|on|yes` enables it,
/// anything else (or unset) keeps the serial driver. Results are
/// bit-identical either way; this only selects the schedule. The serving
/// tier carries the same knob as `[server] overlap`
/// ([`crate::coordinator::server::ServiceConfig`]).
///
/// The environment is read **once** per process (like
/// [`crate::gemm::blocked::host_block`]): this sits on the hot path of
/// every `fast::*` call and `GemmBackend::new`, and a cached read also
/// keeps the getenv out of multi-threaded request loops. Callers that
/// need per-call control use the explicit knobs
/// (`GemmBackend::with_overlap`, the `*_overlapped` entry points).
pub fn overlap_enabled() -> bool {
    static ENABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ENABLED.get_or_init(|| {
        std::env::var("SGEMM_CUBE_OVERLAP").is_ok_and(|v| parse_overlap_toggle(&v))
    })
}

/// The `SGEMM_CUBE_OVERLAP` value parser (split out so tests can cover
/// it without mutating process-global environment state).
fn parse_overlap_toggle(v: &str) -> bool {
    matches!(v.trim(), "1" | "true" | "on" | "yes")
}

/// One `(column block, k block)` iteration of the `b_n → b_k` panel
/// loop, in consumption order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanelJob {
    /// Column-block index (`j0 / b_n`).
    pub jb: usize,
    /// k-block index (`p0 / b_k`).
    pub pb: usize,
    /// First column of the block.
    pub j0: usize,
    /// Columns in the block (`≤ b_n`).
    pub nc: usize,
    /// First k step of the block.
    pub p0: usize,
    /// k steps in the block (`≤ b_k`).
    pub kc: usize,
}

/// The `b_n → b_k` block schedule of the serial drivers, as a flat job
/// list (outer loop over columns, inner over k — the exact consumption
/// order both the serial and the overlapped nests use).
pub fn panel_jobs(n: usize, k: usize, bn: usize, bk: usize) -> Vec<PanelJob> {
    let mut jobs = Vec::new();
    if n == 0 || k == 0 {
        return jobs;
    }
    for (jb, j0) in (0..n).step_by(bn).enumerate() {
        let nc = bn.min(n - j0);
        for (pb, p0) in (0..k).step_by(bk).enumerate() {
            let kc = bk.min(k - p0);
            jobs.push(PanelJob { jb, pb, j0, nc, p0, kc });
        }
    }
    jobs
}

/// What the prefetch worker packs from: the plain B matrix
/// (single-component panels) or the split high/low pair (dual-component
/// panels for the fused cube kernel).
pub(crate) enum PanelSource<'a> {
    Single(&'a Matrix<f32>),
    Dual { high: &'a Matrix<f32>, low: &'a Matrix<f32> },
}

impl PanelSource<'_> {
    /// Pack `job`'s block into `out` — exactly what the serial drivers
    /// call, so overlapped panels are byte-identical.
    fn pack(&self, job: &PanelJob, out: &mut Vec<f32>) {
        match self {
            PanelSource::Single(b) => pack::pack_b(b, job.p0, job.kc, job.j0, job.nc, out),
            PanelSource::Dual { high, low } => {
                pack::pack_b_dual(high, low, job.p0, job.kc, job.j0, job.nc, out)
            }
        }
    }
}

/// Run `consume` over every job's packed panel, with the next panel
/// packed by a prefetch worker while the current one is consumed.
///
/// The two-slot ring: two buffers circulate main → (`job_tx`) →
/// prefetcher → (`ready_tx`) → main. Channels are FIFO and the
/// prefetcher is single, so panels arrive in job order; the consumer
/// never holds more than one buffer and the prefetcher never runs more
/// than one job ahead.
pub(crate) fn run_overlapped<F>(src: PanelSource<'_>, jobs: &[PanelJob], mut consume: F)
where
    F: FnMut(&PanelJob, &[f32]),
{
    // One worker (or one job): nothing to overlap with — degenerate to
    // the serial pack-then-consume loop, one reused buffer, no threads.
    if crate::util::threads::num_threads() < 2 || jobs.len() < 2 {
        let mut buf = Vec::new();
        for job in jobs {
            src.pack(job, &mut buf);
            consume(job, &buf);
        }
        return;
    }
    std::thread::scope(|scope| {
        let (job_tx, job_rx) = channel::<(usize, Vec<f32>)>();
        let (ready_tx, ready_rx) = channel::<(usize, Vec<f32>)>();
        scope.spawn(move || {
            for (idx, mut buf) in job_rx {
                src.pack(&jobs[idx], &mut buf);
                if ready_tx.send((idx, buf)).is_err() {
                    return; // consumer is gone (panic unwind)
                }
            }
        });
        // Seed both ring slots: the prefetcher starts on jobs 0 and 1.
        job_tx.send((0, Vec::new())).expect("prefetch worker alive");
        job_tx.send((1, Vec::new())).expect("prefetch worker alive");
        let mut next = 2;
        for expect in 0..jobs.len() {
            let (idx, buf) = ready_rx.recv().expect("prefetch worker died");
            debug_assert_eq!(idx, expect, "panels must arrive in job order");
            consume(&jobs[idx], &buf);
            if next < jobs.len() {
                job_tx.send((next, buf)).expect("prefetch worker alive");
                next += 1;
            }
        }
        drop(job_tx); // prefetcher's job loop ends; scope joins it
    });
}

/// Single-component overlapped driver — the pipeline counterpart of
/// `blocked::gemm_blocked_core`, bit-identical by shared sweeps.
pub(crate) fn gemm_overlapped_core(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    let bm = exec_bm(m, block.bm);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let jobs = panel_jobs(n, k, block.bn, block.bk);
    run_overlapped(PanelSource::Single(b), &jobs, |job, bp| {
        sweep_rows_f32(a, bp, &cp, n, bm, job.j0, job.p0, job.kc);
    });
    c
}

/// Dual-component overlapped driver — the pipeline counterpart of
/// `blocked::cube_blocked_core`.
pub(crate) fn cube_overlapped_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
) -> Matrix<f32> {
    let (m, k) = ah.shape();
    let n = bh.cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    let bm = exec_bm(m, block.bm);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let jobs = panel_jobs(n, k, block.bn, block.bk);
    run_overlapped(PanelSource::Dual { high: bh, low: bl }, &jobs, |job, bp| {
        sweep_rows_cube(ah, al, bp, &cp, n, bm, job.j0, job.p0, job.kc, inv_sf);
    });
    c
}

#[inline]
fn elapsed(t: Instant) -> f64 {
    t.elapsed().as_secs_f64()
}

/// Instrumented single-component serial driver: the exact serial nest,
/// single-threaded, with per-stage wall times accumulated into a
/// [`StageBreakdown`]. Calibration path only — the timer reads add a few
/// percent of overhead at small `kc`, so serving traffic never runs it.
/// The result is bit-identical to `sgemm_blocked` (same ops, same
/// order).
pub(crate) fn gemm_staged_core(a: &Matrix<f32>, b: &Matrix<f32>) -> (Matrix<f32>, StageBreakdown) {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let mut stages = StageBreakdown::default();
    if m == 0 || n == 0 || k == 0 {
        return (c, stages);
    }
    let block = host_block();
    let bm = exec_bm(m, block.bm);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    let mut ap = Vec::new();
    for job in panel_jobs(n, k, block.bn, block.bk) {
        let t = Instant::now();
        pack::pack_b(b, job.p0, job.kc, job.j0, job.nc, &mut bp);
        stages.pack_b += elapsed(t);
        for i0 in (0..m).step_by(bm) {
            let mc = bm.min(m - i0);
            let t = Instant::now();
            pack::pack_a(a, i0, mc, job.p0, job.kc, &mut ap);
            stages.pack_a += elapsed(t);
            for (rp, apanel) in ap.chunks_exact(job.kc * MR).enumerate() {
                let ci = i0 + rp * MR;
                let mr_eff = MR.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(job.kc * NR).enumerate() {
                    let cj = job.j0 + cpnl * NR;
                    let nr_eff = NR.min(n - cj);
                    let t = Instant::now();
                    let acc = kernel_f32(apanel, bpanel);
                    stages.kernel += elapsed(t);
                    let t = Instant::now();
                    add_tile(&cp, n, ci, cj, mr_eff, nr_eff, &acc);
                    stages.c_update += elapsed(t);
                }
            }
        }
    }
    (c, stages)
}

/// Instrumented dual-component serial driver (cube counterpart of
/// [`gemm_staged_core`]); bit-identical to `cube_gemm_blocked` for the
/// same split operands.
pub(crate) fn cube_staged_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
) -> (Matrix<f32>, StageBreakdown) {
    let (m, k) = ah.shape();
    let n = bh.cols();
    let mut c = Matrix::zeros(m, n);
    let mut stages = StageBreakdown::default();
    if m == 0 || n == 0 || k == 0 {
        return (c, stages);
    }
    let block = host_block();
    let bm = exec_bm(m, block.bm);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    let mut ap = Vec::new();
    for job in panel_jobs(n, k, block.bn, block.bk) {
        let t = Instant::now();
        pack::pack_b_dual(bh, bl, job.p0, job.kc, job.j0, job.nc, &mut bp);
        stages.pack_b += elapsed(t);
        for i0 in (0..m).step_by(bm) {
            let mc = bm.min(m - i0);
            let t = Instant::now();
            pack::pack_a_dual(ah, al, i0, mc, job.p0, job.kc, &mut ap);
            stages.pack_a += elapsed(t);
            for (rp, apanel) in ap.chunks_exact(job.kc * 2 * MR).enumerate() {
                let ci = i0 + rp * MR;
                let mr_eff = MR.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(job.kc * 2 * NR).enumerate() {
                    let cj = job.j0 + cpnl * NR;
                    let nr_eff = NR.min(n - cj);
                    let t = Instant::now();
                    let (hh, corr) = kernel_cube(apanel, bpanel);
                    stages.kernel += elapsed(t);
                    let t = Instant::now();
                    add_tile_cube(&cp, n, ci, cj, mr_eff, nr_eff, &hh, &corr, inv_sf);
                    stages.c_update += elapsed(t);
                }
            }
        }
    }
    (c, stages)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn panel_jobs_cover_the_nest_in_order() {
        let jobs = panel_jobs(70, 130, 32, 64);
        // 3 column blocks × 3 k blocks... n=70/bn=32 → j0 in {0,32,64};
        // k=130/bk=64 → p0 in {0,64,128}.
        assert_eq!(jobs.len(), 9);
        assert_eq!(jobs[0], PanelJob { jb: 0, pb: 0, j0: 0, nc: 32, p0: 0, kc: 64 });
        assert_eq!(jobs[2], PanelJob { jb: 0, pb: 2, j0: 0, nc: 32, p0: 128, kc: 2 });
        assert_eq!(jobs[8], PanelJob { jb: 2, pb: 2, j0: 64, nc: 6, p0: 128, kc: 2 });
        // Consumption order: outer j, inner p — exactly the serial nest.
        for w in jobs.windows(2) {
            assert!((w[0].jb, w[0].pb) < (w[1].jb, w[1].pb));
        }
        assert!(panel_jobs(0, 64, 32, 32).is_empty());
        assert!(panel_jobs(64, 0, 32, 32).is_empty());
    }

    #[test]
    fn run_overlapped_delivers_every_panel_in_order() {
        let mut rng = Rng::new(91);
        let b = Matrix::random_symmetric(100, 50, 0, &mut rng);
        let jobs = panel_jobs(50, 100, 16, 32);
        // Serial reference panels.
        let mut want = Vec::new();
        let mut buf = Vec::new();
        for job in &jobs {
            pack::pack_b(&b, job.p0, job.kc, job.j0, job.nc, &mut buf);
            want.push(buf.clone());
        }
        let mut got: Vec<Vec<f32>> = Vec::new();
        run_overlapped(PanelSource::Single(&b), &jobs, |_, bp| got.push(bp.to_vec()));
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g, w, "overlapped panel differs from serial pack");
        }
    }

    #[test]
    fn run_overlapped_handles_tiny_job_lists() {
        let b = Matrix::zeros(4, 4);
        let mut seen = 0;
        run_overlapped(PanelSource::Single(&b), &[], |_, _| seen += 1);
        assert_eq!(seen, 0);
        let jobs = panel_jobs(4, 4, 16, 16);
        assert_eq!(jobs.len(), 1);
        run_overlapped(PanelSource::Single(&b), &jobs, |_, bp| {
            seen += 1;
            assert_eq!(bp.len(), pack::b_panels(4) * 4 * NR);
        });
        assert_eq!(seen, 1);
    }

    #[test]
    fn overlap_toggle_parsing() {
        // Parser covered directly — mutating the process environment in
        // a test would race other threads' getenv (and overlap_enabled
        // caches the first read anyway).
        for on in ["1", "true", "on", "yes", " 1 ", "true\n"] {
            assert!(parse_overlap_toggle(on), "{on:?}");
        }
        for off in ["0", "false", "off", "no", "", "2", "TRUE", "enabled"] {
            assert!(!parse_overlap_toggle(off), "{off:?}");
        }
        // The cached read agrees with the parser for this process's env.
        let want = std::env::var("SGEMM_CUBE_OVERLAP").is_ok_and(|v| parse_overlap_toggle(&v));
        assert_eq!(overlap_enabled(), want);
    }

    #[test]
    fn staged_breakdown_accounts_positive_stage_time() {
        let mut rng = Rng::new(92);
        let a = Matrix::random_symmetric(33, 65, 0, &mut rng);
        let b = Matrix::random_symmetric(65, 24, 0, &mut rng);
        let (c, stages) = gemm_staged_core(&a, &b);
        let serial = crate::gemm::blocked::sgemm_blocked(&a, &b);
        for (x, y) in c.as_slice().iter().zip(serial.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "staged result must be bit-identical");
        }
        assert!(stages.pack_a > 0.0 && stages.pack_b > 0.0);
        assert!(stages.kernel > 0.0 && stages.c_update > 0.0);
        assert!((stages.total() - stages.compute() - stages.transfer()).abs() < 1e-12);
    }
}
