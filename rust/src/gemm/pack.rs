//! Panel packing for the blocked GEMM engine ([`crate::gemm::blocked`]).
//!
//! The micro-kernel reads operands from contiguous, interleaved panels
//! instead of strided matrix rows/columns:
//!
//! * **A row panels** (`mr`-interleaved): an `mc × kc` block of A becomes
//!   `⌈mc/mr⌉` panels; panel `r` stores, for each k step `p`, the `mr`
//!   column-`p` values of rows `r·mr .. r·mr+mr`. The micro-kernel's k
//!   loop then walks one contiguous stream.
//! * **B column panels** (`nr`-interleaved): a `kc × nc` block of B
//!   becomes `⌈nc/nr⌉` panels; panel `c` stores, per k step, the `nr`
//!   row-`p` values of columns `c·nr .. c·nr+nr`.
//! * **Dual-component panels** for the cube kernel: the split high/low
//!   FP16 components (widened to f32, see
//!   [`crate::gemm::cube::WideSplit`]) are interleaved per k step —
//!   `mr` highs then `mr` lows (resp. `nr`/`nr`) — so the fused
//!   three-term micro-kernel reads both components of both operands in
//!   one forward stream.
//!
//! Edge blocks are zero-padded up to the `mr`/`nr` boundary: the
//! micro-kernel stays branch-free (padded lanes accumulate exact zeros)
//! and the store path simply drops the padded rows/columns. Padding only
//! ever adds rows/columns, never k steps, so every *valid* output cell
//! accumulates exactly the true products in k order.
//!
//! **Panel geometry is a function of the kernel lane.** The scalar,
//! AVX2 and NEON lanes all derive the same [`MR`]` × `[`NR`] = 4 × 8
//! micro-tile from their register files, but the AVX-512 lane's 32-zmm
//! file supports a genuinely wider [`MAX_MR`]` × `[`MAX_NR`] = 8 × 16
//! tile ([`crate::sim::blocking::micro_tile`]). Every packer therefore
//! takes the tile dims (`mr` / `nr`) explicitly — callers resolve them
//! once per GEMM call from the active lane
//! ([`crate::gemm::kernels::Lane::tile_dims`]) and use the *same* dims
//! for packing and kernel dispatch. Lane-dependent layout is why
//! prepacked operands ([`crate::gemm::prepacked`]) record the lane they
//! were packed for and why the prepack cache key
//! ([`crate::gemm::cache`]) includes it: a cached panel is never
//! consumed by a mismatched lane. Zero-padding keeps SIMD loads safe in
//! either geometry — each panel is a full `kc·nr` (or `kc·ncomp·nr`
//! multi-component) multiple, so vector loads never run past the
//! buffer.

use crate::util::mat::Matrix;

/// Rows of the narrow register micro-tile; A panels for the scalar,
/// AVX2 and NEON lanes are `MR`-interleaved. Derived from the 16-entry
/// vector register budget by [`crate::sim::blocking::micro_tile`] and
/// pinned by const asserts in the SIMD kernels.
pub const MR: usize = 4;
/// Columns of the narrow register micro-tile; one AVX2 YMM register
/// (or a NEON q-register pair) of f32 lanes — see
/// [`crate::sim::blocking::micro_tile`].
pub const NR: usize = 8;

/// Rows of the widest micro-tile any lane uses (the AVX-512 lane's,
/// from the 32-zmm register file). Stack-allocated kernel output tiles
/// are sized `MAX_MR × MAX_NR` and sliced down to the active lane's
/// dims.
pub const MAX_MR: usize = 8;
/// Columns of the widest micro-tile any lane uses: one AVX-512 ZMM
/// register of f32 lanes.
pub const MAX_NR: usize = 16;

/// Number of `mr`-row panels covering `mc` rows.
#[inline]
pub fn a_panels(mc: usize, mr: usize) -> usize {
    mc.div_ceil(mr)
}

/// Number of `nr`-column panels covering `nc` columns.
#[inline]
pub fn b_panels(nc: usize, nr: usize) -> usize {
    nc.div_ceil(nr)
}

/// Pack the `mc × kc` block of `a` with origin `(i0, p0)` into
/// `mr`-interleaved row panels. `out` is cleared first.
pub fn pack_a(
    a: &Matrix<f32>,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    mr: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(a_panels(mc, mr) * kc * mr);
    for r in 0..a_panels(mc, mr) {
        for p in 0..kc {
            for i in 0..mr {
                let row = r * mr + i;
                out.push(if row < mc { a.get(i0 + row, p0 + p) } else { 0.0 });
            }
        }
    }
}

/// Pack the `kc × nc` block of `b` with origin `(p0, j0)` into
/// `nr`-interleaved column panels. `out` is cleared first.
pub fn pack_b(
    b: &Matrix<f32>,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    nr: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(b_panels(nc, nr) * kc * nr);
    for c in 0..b_panels(nc, nr) {
        for p in 0..kc {
            let row = b.row(p0 + p);
            for j in 0..nr {
                let col = c * nr + j;
                out.push(if col < nc { row[j0 + col] } else { 0.0 });
            }
        }
    }
}

/// Dual-component A packing: per k step, `mr` high values then `mr` low
/// values (stride `2·mr` per step). `high` and `low` must share a shape.
pub fn pack_a_dual(
    high: &Matrix<f32>,
    low: &Matrix<f32>,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    mr: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(high.shape(), low.shape());
    out.clear();
    out.reserve(a_panels(mc, mr) * kc * 2 * mr);
    for r in 0..a_panels(mc, mr) {
        for p in 0..kc {
            for i in 0..mr {
                let row = r * mr + i;
                out.push(if row < mc { high.get(i0 + row, p0 + p) } else { 0.0 });
            }
            for i in 0..mr {
                let row = r * mr + i;
                out.push(if row < mc { low.get(i0 + row, p0 + p) } else { 0.0 });
            }
        }
    }
}

/// Dual-component B packing: per k step, `nr` high values then `nr` low
/// values (stride `2·nr` per step).
pub fn pack_b_dual(
    high: &Matrix<f32>,
    low: &Matrix<f32>,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    nr: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(high.shape(), low.shape());
    out.clear();
    out.reserve(b_panels(nc, nr) * kc * 2 * nr);
    for c in 0..b_panels(nc, nr) {
        for p in 0..kc {
            let hrow = high.row(p0 + p);
            let lrow = low.row(p0 + p);
            for j in 0..nr {
                let col = c * nr + j;
                out.push(if col < nc { hrow[j0 + col] } else { 0.0 });
            }
            for j in 0..nr {
                let col = c * nr + j;
                out.push(if col < nc { lrow[j0 + col] } else { 0.0 });
            }
        }
    }
}

/// N-component A packing for the precision family: per k step, `mr`
/// values of component 0, then `mr` of component 1, … (stride
/// `ncomp·mr` per step). All component planes must share a shape. At
/// `ncomp = 2` the layout is exactly [`pack_a_dual`]'s.
pub fn pack_a_multi(
    comps: &[Matrix<f32>],
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    mr: usize,
    out: &mut Vec<f32>,
) {
    let ncomp = comps.len();
    debug_assert!(ncomp >= 2);
    debug_assert!(comps.iter().all(|c| c.shape() == comps[0].shape()));
    out.clear();
    out.reserve(a_panels(mc, mr) * kc * ncomp * mr);
    for r in 0..a_panels(mc, mr) {
        for p in 0..kc {
            for comp in comps {
                for i in 0..mr {
                    let row = r * mr + i;
                    out.push(if row < mc { comp.get(i0 + row, p0 + p) } else { 0.0 });
                }
            }
        }
    }
}

/// N-component B packing: per k step, `nr` values of component 0, then
/// `nr` of component 1, … (stride `ncomp·nr` per step). At `ncomp = 2`
/// the layout is exactly [`pack_b_dual`]'s.
pub fn pack_b_multi(
    comps: &[Matrix<f32>],
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    nr: usize,
    out: &mut Vec<f32>,
) {
    let ncomp = comps.len();
    debug_assert!(ncomp >= 2);
    debug_assert!(comps.iter().all(|c| c.shape() == comps[0].shape()));
    out.clear();
    out.reserve(b_panels(nc, nr) * kc * ncomp * nr);
    for c in 0..b_panels(nc, nr) {
        for p in 0..kc {
            for comp in comps {
                let row = comp.row(p0 + p);
                for j in 0..nr {
                    let col = c * nr + j;
                    out.push(if col < nc { row[j0 + col] } else { 0.0 });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat(rows: usize, cols: usize, seed: u64) -> Matrix<f32> {
        let mut rng = Rng::new(seed);
        Matrix::random_symmetric(rows, cols, 0, &mut rng)
    }

    #[test]
    fn pack_a_layout_and_padding() {
        let a = mat(7, 5, 1);
        let mut out = Vec::new();
        pack_a(&a, 1, 6, 2, 3, MR, &mut out); // 6 rows from row 1, 3 cols from col 2
        assert_eq!(out.len(), a_panels(6, MR) * 3 * MR); // 2 panels
        // Panel 0, k step p, lane i -> a[1 + i][2 + p].
        for p in 0..3 {
            for i in 0..MR {
                assert_eq!(out[p * MR + i], a.get(1 + i, 2 + p), "panel 0 p={p} i={i}");
            }
        }
        // Panel 1 covers rows 5..7 of the block (matrix rows 5, 6), lanes
        // 2-3 are padding.
        let base = 3 * MR;
        for p in 0..3 {
            assert_eq!(out[base + p * MR], a.get(5, 2 + p));
            assert_eq!(out[base + p * MR + 1], a.get(6, 2 + p));
            assert_eq!(out[base + p * MR + 2], 0.0);
            assert_eq!(out[base + p * MR + 3], 0.0);
        }
    }

    #[test]
    fn pack_b_layout_and_padding() {
        let b = mat(4, 19, 2);
        let mut out = Vec::new();
        pack_b(&b, 1, 3, 2, 13, NR, &mut out); // 3 k steps from row 1, 13 cols from col 2
        assert_eq!(out.len(), b_panels(13, NR) * 3 * NR); // 2 panels
        for p in 0..3 {
            for j in 0..NR {
                assert_eq!(out[p * NR + j], b.get(1 + p, 2 + j), "panel 0 p={p} j={j}");
            }
        }
        let base = 3 * NR;
        for p in 0..3 {
            for j in 0..NR {
                let col = NR + j;
                let want = if col < 13 { b.get(1 + p, 2 + col) } else { 0.0 };
                assert_eq!(out[base + p * NR + j], want, "panel 1 p={p} j={j}");
            }
        }
    }

    #[test]
    fn wide_tile_packing_changes_panel_geometry() {
        // The same block packed for the wide (AVX-512) tile dims carries
        // the same values under a different interleave: one 8-row panel
        // where the narrow layout makes two 4-row panels.
        let a = mat(8, 3, 11);
        let (mut narrow, mut wide) = (Vec::new(), Vec::new());
        pack_a(&a, 0, 8, 0, 3, MR, &mut narrow);
        pack_a(&a, 0, 8, 0, 3, MAX_MR, &mut wide);
        assert_eq!(narrow.len(), wide.len());
        assert_ne!(narrow, wide, "wide interleave must differ from narrow");
        assert_eq!(a_panels(8, MR), 2);
        assert_eq!(a_panels(8, MAX_MR), 1);
        for p in 0..3 {
            for i in 0..MAX_MR {
                assert_eq!(wide[p * MAX_MR + i], a.get(i, p), "wide panel p={p} i={i}");
            }
        }
        let b = mat(3, 20, 12);
        let mut bp = Vec::new();
        pack_b(&b, 0, 3, 0, 20, MAX_NR, &mut bp);
        assert_eq!(bp.len(), b_panels(20, MAX_NR) * 3 * MAX_NR); // 2 panels
        for p in 0..3 {
            for j in 0..MAX_NR {
                assert_eq!(bp[p * MAX_NR + j], b.get(p, j), "wide B panel p={p} j={j}");
            }
            // Second panel: columns 16..20 then zero padding.
            let base = 3 * MAX_NR;
            for j in 0..MAX_NR {
                let col = MAX_NR + j;
                let want = if col < 20 { b.get(p, col) } else { 0.0 };
                assert_eq!(bp[base + p * MAX_NR + j], want);
            }
        }
    }

    #[test]
    fn multi_packing_at_two_components_matches_dual_bitwise() {
        let high = mat(7, 6, 5);
        let low = mat(7, 6, 6);
        let comps = [high.clone(), low.clone()];
        let (mut dual, mut multi) = (Vec::new(), Vec::new());
        pack_a_dual(&high, &low, 1, 5, 2, 3, MR, &mut dual);
        pack_a_multi(&comps, 1, 5, 2, 3, MR, &mut multi);
        assert_eq!(dual, multi);
        pack_b_dual(&high, &low, 1, 3, 2, 4, NR, &mut dual);
        pack_b_multi(&comps, 1, 3, 2, 4, NR, &mut multi);
        assert_eq!(dual, multi);
        // The equivalence is geometry-independent: it holds for the wide
        // tile dims too.
        pack_a_dual(&high, &low, 1, 5, 2, 3, MAX_MR, &mut dual);
        pack_a_multi(&comps, 1, 5, 2, 3, MAX_MR, &mut multi);
        assert_eq!(dual, multi);
        pack_b_dual(&high, &low, 1, 3, 2, 4, MAX_NR, &mut dual);
        pack_b_multi(&comps, 1, 3, 2, 4, MAX_NR, &mut multi);
        assert_eq!(dual, multi);
    }

    #[test]
    fn multi_packing_three_components_layout() {
        let c0 = mat(5, 4, 7);
        let c1 = mat(5, 4, 8);
        let c2 = mat(5, 4, 9);
        let comps = [c0.clone(), c1.clone(), c2.clone()];
        let mut ap = Vec::new();
        pack_a_multi(&comps, 0, 5, 0, 4, MR, &mut ap);
        assert_eq!(ap.len(), a_panels(5, MR) * 4 * 3 * MR);
        for p in 0..4 {
            let s = p * 3 * MR;
            for i in 0..MR {
                assert_eq!(ap[s + i], c0.get(i, p));
                assert_eq!(ap[s + MR + i], c1.get(i, p));
                assert_eq!(ap[s + 2 * MR + i], c2.get(i, p));
            }
        }
        let mut bp = Vec::new();
        pack_b_multi(&comps, 0, 5, 0, 4, NR, &mut bp);
        assert_eq!(bp.len(), b_panels(4, NR) * 5 * 3 * NR);
        for p in 0..5 {
            let s = p * 3 * NR;
            for j in 0..4 {
                assert_eq!(bp[s + j], c0.get(p, j));
                assert_eq!(bp[s + NR + j], c1.get(p, j));
                assert_eq!(bp[s + 2 * NR + j], c2.get(p, j));
            }
            for j in 4..NR {
                assert_eq!(bp[s + j], 0.0);
                assert_eq!(bp[s + NR + j], 0.0);
                assert_eq!(bp[s + 2 * NR + j], 0.0);
            }
        }
    }

    #[test]
    fn dual_packing_interleaves_components() {
        let high = mat(5, 4, 3);
        let low = mat(5, 4, 4);
        let mut ap = Vec::new();
        pack_a_dual(&high, &low, 0, 5, 0, 4, MR, &mut ap);
        assert_eq!(ap.len(), a_panels(5, MR) * 4 * 2 * MR);
        // Panel 0, k step p: MR highs then MR lows.
        for p in 0..4 {
            let s = p * 2 * MR;
            for i in 0..MR {
                assert_eq!(ap[s + i], high.get(i, p));
                assert_eq!(ap[s + MR + i], low.get(i, p));
            }
        }
        let mut bp = Vec::new();
        pack_b_dual(&high, &low, 0, 5, 0, 4, NR, &mut bp);
        assert_eq!(bp.len(), b_panels(4, NR) * 5 * 2 * NR);
        for p in 0..5 {
            let s = p * 2 * NR;
            for j in 0..4 {
                assert_eq!(bp[s + j], high.get(p, j));
                assert_eq!(bp[s + NR + j], low.get(p, j));
            }
            for j in 4..NR {
                assert_eq!(bp[s + j], 0.0);
                assert_eq!(bp[s + NR + j], 0.0);
            }
        }
    }
}
