//! Panel packing for the blocked GEMM engine ([`crate::gemm::blocked`]).
//!
//! The micro-kernel reads operands from contiguous, interleaved panels
//! instead of strided matrix rows/columns:
//!
//! * **A row panels** (`MR`-interleaved): an `mc × kc` block of A becomes
//!   `⌈mc/MR⌉` panels; panel `r` stores, for each k step `p`, the `MR`
//!   column-`p` values of rows `r·MR .. r·MR+MR`. The micro-kernel's k
//!   loop then walks one contiguous stream.
//! * **B column panels** (`NR`-interleaved): a `kc × nc` block of B
//!   becomes `⌈nc/NR⌉` panels; panel `c` stores, per k step, the `NR`
//!   row-`p` values of columns `c·NR .. c·NR+NR`.
//! * **Dual-component panels** for the cube kernel: the split high/low
//!   FP16 components (widened to f32, see
//!   [`crate::gemm::cube::WideSplit`]) are interleaved per k step —
//!   `MR` highs then `MR` lows (resp. `NR`/`NR`) — so the fused
//!   three-term micro-kernel reads both components of both operands in
//!   one forward stream.
//!
//! Edge blocks are zero-padded up to the `MR`/`NR` boundary: the
//! micro-kernel stays branch-free (padded lanes accumulate exact zeros)
//! and the store path simply drops the padded rows/columns. Padding only
//! ever adds rows/columns, never k steps, so every *valid* output cell
//! accumulates exactly the true products in k order.
//!
//! This panel format is shared by **every** kernel lane
//! ([`crate::gemm::kernels`]): the SIMD lanes read whole `NR`-wide (or
//! half-row) vectors per k step, which the zero-padding makes safe —
//! each panel is a full `kc·NR` (or `kc·2·NR` dual) multiple, so vector
//! loads never run past the buffer. Because packing is lane-independent,
//! prepacked operands ([`crate::gemm::prepacked`]) and the prefetch ring
//! carry no lane state and schedules stay bit-identical per lane.

use crate::util::mat::Matrix;

/// Rows of the register micro-tile; A panels are `MR`-interleaved.
/// Derived from the vector register budget by
/// [`crate::sim::blocking::micro_tile`] (both SIMD register files give
/// 4) and pinned by const asserts in the SIMD kernels.
pub const MR: usize = 4;
/// Columns of the register micro-tile; B panels are `NR`-interleaved.
/// One AVX2 YMM register (or a NEON q-register pair) of f32 lanes —
/// see [`crate::sim::blocking::micro_tile`].
pub const NR: usize = 8;

/// Number of `MR`-row panels covering `mc` rows.
#[inline]
pub fn a_panels(mc: usize) -> usize {
    mc.div_ceil(MR)
}

/// Number of `NR`-column panels covering `nc` columns.
#[inline]
pub fn b_panels(nc: usize) -> usize {
    nc.div_ceil(NR)
}

/// Pack the `mc × kc` block of `a` with origin `(i0, p0)` into
/// `MR`-interleaved row panels. `out` is cleared first.
pub fn pack_a(a: &Matrix<f32>, i0: usize, mc: usize, p0: usize, kc: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(a_panels(mc) * kc * MR);
    for r in 0..a_panels(mc) {
        for p in 0..kc {
            for i in 0..MR {
                let row = r * MR + i;
                out.push(if row < mc { a.get(i0 + row, p0 + p) } else { 0.0 });
            }
        }
    }
}

/// Pack the `kc × nc` block of `b` with origin `(p0, j0)` into
/// `NR`-interleaved column panels. `out` is cleared first.
pub fn pack_b(b: &Matrix<f32>, p0: usize, kc: usize, j0: usize, nc: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(b_panels(nc) * kc * NR);
    for c in 0..b_panels(nc) {
        for p in 0..kc {
            let row = b.row(p0 + p);
            for j in 0..NR {
                let col = c * NR + j;
                out.push(if col < nc { row[j0 + col] } else { 0.0 });
            }
        }
    }
}

/// Dual-component A packing: per k step, `MR` high values then `MR` low
/// values (stride `2·MR` per step). `high` and `low` must share a shape.
pub fn pack_a_dual(
    high: &Matrix<f32>,
    low: &Matrix<f32>,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(high.shape(), low.shape());
    out.clear();
    out.reserve(a_panels(mc) * kc * 2 * MR);
    for r in 0..a_panels(mc) {
        for p in 0..kc {
            for i in 0..MR {
                let row = r * MR + i;
                out.push(if row < mc { high.get(i0 + row, p0 + p) } else { 0.0 });
            }
            for i in 0..MR {
                let row = r * MR + i;
                out.push(if row < mc { low.get(i0 + row, p0 + p) } else { 0.0 });
            }
        }
    }
}

/// Dual-component B packing: per k step, `NR` high values then `NR` low
/// values (stride `2·NR` per step).
pub fn pack_b_dual(
    high: &Matrix<f32>,
    low: &Matrix<f32>,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(high.shape(), low.shape());
    out.clear();
    out.reserve(b_panels(nc) * kc * 2 * NR);
    for c in 0..b_panels(nc) {
        for p in 0..kc {
            let hrow = high.row(p0 + p);
            let lrow = low.row(p0 + p);
            for j in 0..NR {
                let col = c * NR + j;
                out.push(if col < nc { hrow[j0 + col] } else { 0.0 });
            }
            for j in 0..NR {
                let col = c * NR + j;
                out.push(if col < nc { lrow[j0 + col] } else { 0.0 });
            }
        }
    }
}

/// N-component A packing for the precision family: per k step, `MR`
/// values of component 0, then `MR` of component 1, … (stride
/// `ncomp·MR` per step). All component planes must share a shape. At
/// `ncomp = 2` the layout is exactly [`pack_a_dual`]'s.
pub fn pack_a_multi(
    comps: &[Matrix<f32>],
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    out: &mut Vec<f32>,
) {
    let ncomp = comps.len();
    debug_assert!(ncomp >= 2);
    debug_assert!(comps.iter().all(|c| c.shape() == comps[0].shape()));
    out.clear();
    out.reserve(a_panels(mc) * kc * ncomp * MR);
    for r in 0..a_panels(mc) {
        for p in 0..kc {
            for comp in comps {
                for i in 0..MR {
                    let row = r * MR + i;
                    out.push(if row < mc { comp.get(i0 + row, p0 + p) } else { 0.0 });
                }
            }
        }
    }
}

/// N-component B packing: per k step, `NR` values of component 0, then
/// `NR` of component 1, … (stride `ncomp·NR` per step). At `ncomp = 2`
/// the layout is exactly [`pack_b_dual`]'s.
pub fn pack_b_multi(
    comps: &[Matrix<f32>],
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    out: &mut Vec<f32>,
) {
    let ncomp = comps.len();
    debug_assert!(ncomp >= 2);
    debug_assert!(comps.iter().all(|c| c.shape() == comps[0].shape()));
    out.clear();
    out.reserve(b_panels(nc) * kc * ncomp * NR);
    for c in 0..b_panels(nc) {
        for p in 0..kc {
            for comp in comps {
                let row = comp.row(p0 + p);
                for j in 0..NR {
                    let col = c * NR + j;
                    out.push(if col < nc { row[j0 + col] } else { 0.0 });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat(rows: usize, cols: usize, seed: u64) -> Matrix<f32> {
        let mut rng = Rng::new(seed);
        Matrix::random_symmetric(rows, cols, 0, &mut rng)
    }

    #[test]
    fn pack_a_layout_and_padding() {
        let a = mat(7, 5, 1);
        let mut out = Vec::new();
        pack_a(&a, 1, 6, 2, 3, &mut out); // 6 rows from row 1, 3 cols from col 2
        assert_eq!(out.len(), a_panels(6) * 3 * MR); // 2 panels
        // Panel 0, k step p, lane i -> a[1 + i][2 + p].
        for p in 0..3 {
            for i in 0..MR {
                assert_eq!(out[p * MR + i], a.get(1 + i, 2 + p), "panel 0 p={p} i={i}");
            }
        }
        // Panel 1 covers rows 5..7 of the block (matrix rows 5, 6), lanes
        // 2-3 are padding.
        let base = 3 * MR;
        for p in 0..3 {
            assert_eq!(out[base + p * MR], a.get(5, 2 + p));
            assert_eq!(out[base + p * MR + 1], a.get(6, 2 + p));
            assert_eq!(out[base + p * MR + 2], 0.0);
            assert_eq!(out[base + p * MR + 3], 0.0);
        }
    }

    #[test]
    fn pack_b_layout_and_padding() {
        let b = mat(4, 19, 2);
        let mut out = Vec::new();
        pack_b(&b, 1, 3, 2, 13, &mut out); // 3 k steps from row 1, 13 cols from col 2
        assert_eq!(out.len(), b_panels(13) * 3 * NR); // 2 panels
        for p in 0..3 {
            for j in 0..NR {
                assert_eq!(out[p * NR + j], b.get(1 + p, 2 + j), "panel 0 p={p} j={j}");
            }
        }
        let base = 3 * NR;
        for p in 0..3 {
            for j in 0..NR {
                let col = NR + j;
                let want = if col < 13 { b.get(1 + p, 2 + col) } else { 0.0 };
                assert_eq!(out[base + p * NR + j], want, "panel 1 p={p} j={j}");
            }
        }
    }

    #[test]
    fn multi_packing_at_two_components_matches_dual_bitwise() {
        let high = mat(7, 6, 5);
        let low = mat(7, 6, 6);
        let comps = [high.clone(), low.clone()];
        let (mut dual, mut multi) = (Vec::new(), Vec::new());
        pack_a_dual(&high, &low, 1, 5, 2, 3, &mut dual);
        pack_a_multi(&comps, 1, 5, 2, 3, &mut multi);
        assert_eq!(dual, multi);
        pack_b_dual(&high, &low, 1, 3, 2, 4, &mut dual);
        pack_b_multi(&comps, 1, 3, 2, 4, &mut multi);
        assert_eq!(dual, multi);
    }

    #[test]
    fn multi_packing_three_components_layout() {
        let c0 = mat(5, 4, 7);
        let c1 = mat(5, 4, 8);
        let c2 = mat(5, 4, 9);
        let comps = [c0.clone(), c1.clone(), c2.clone()];
        let mut ap = Vec::new();
        pack_a_multi(&comps, 0, 5, 0, 4, &mut ap);
        assert_eq!(ap.len(), a_panels(5) * 4 * 3 * MR);
        for p in 0..4 {
            let s = p * 3 * MR;
            for i in 0..MR {
                assert_eq!(ap[s + i], c0.get(i, p));
                assert_eq!(ap[s + MR + i], c1.get(i, p));
                assert_eq!(ap[s + 2 * MR + i], c2.get(i, p));
            }
        }
        let mut bp = Vec::new();
        pack_b_multi(&comps, 0, 5, 0, 4, &mut bp);
        assert_eq!(bp.len(), b_panels(4) * 5 * 3 * NR);
        for p in 0..5 {
            let s = p * 3 * NR;
            for j in 0..4 {
                assert_eq!(bp[s + j], c0.get(p, j));
                assert_eq!(bp[s + NR + j], c1.get(p, j));
                assert_eq!(bp[s + 2 * NR + j], c2.get(p, j));
            }
            for j in 4..NR {
                assert_eq!(bp[s + j], 0.0);
                assert_eq!(bp[s + NR + j], 0.0);
                assert_eq!(bp[s + 2 * NR + j], 0.0);
            }
        }
    }

    #[test]
    fn dual_packing_interleaves_components() {
        let high = mat(5, 4, 3);
        let low = mat(5, 4, 4);
        let mut ap = Vec::new();
        pack_a_dual(&high, &low, 0, 5, 0, 4, &mut ap);
        assert_eq!(ap.len(), a_panels(5) * 4 * 2 * MR);
        // Panel 0, k step p: MR highs then MR lows.
        for p in 0..4 {
            let s = p * 2 * MR;
            for i in 0..MR {
                assert_eq!(ap[s + i], high.get(i, p));
                assert_eq!(ap[s + MR + i], low.get(i, p));
            }
        }
        let mut bp = Vec::new();
        pack_b_dual(&high, &low, 0, 5, 0, 4, &mut bp);
        assert_eq!(bp.len(), b_panels(4) * 5 * 2 * NR);
        for p in 0..5 {
            let s = p * 2 * NR;
            for j in 0..4 {
                assert_eq!(bp[s + j], high.get(p, j));
                assert_eq!(bp[s + NR + j], low.get(p, j));
            }
            for j in 4..NR {
                assert_eq!(bp[s + j], 0.0);
                assert_eq!(bp[s + NR + j], 0.0);
            }
        }
    }
}
