//! The relative error metric of Eq. (13):
//! `err = ||C_true - C_calc||_2 / ||C_true||_2` (Frobenius norms).

use crate::util::mat::Matrix;

/// Relative Frobenius-norm error of `calc` against `truth` (both f64;
/// promote f32 results with [`Matrix::to_f64`] first).
pub fn relative_error(truth: &Matrix<f64>, calc: &Matrix<f64>) -> f64 {
    assert_eq!(truth.shape(), calc.shape(), "shape mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (t, c) in truth.as_slice().iter().zip(calc.as_slice().iter()) {
        let d = t - c;
        num += d * d;
        den += t * t;
    }
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (num / den).sqrt()
    }
}

/// Maximum elementwise relative error (secondary diagnostic; the paper
/// reports the norm-based metric).
pub fn max_elementwise_error(truth: &Matrix<f64>, calc: &Matrix<f64>) -> f64 {
    assert_eq!(truth.shape(), calc.shape());
    truth
        .as_slice()
        .iter()
        .zip(calc.as_slice().iter())
        .map(|(t, c)| {
            let denom = t.abs().max(f64::MIN_POSITIVE);
            (t - c).abs() / denom
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_for_identical() {
        let m = Matrix::from_fn(3, 3, |i, j| (i + j) as f64);
        assert_eq!(relative_error(&m, &m), 0.0);
        assert_eq!(max_elementwise_error(&m, &m), 0.0);
    }

    #[test]
    fn known_relative_error() {
        let truth = Matrix::from_vec(1, 2, vec![3.0, 4.0]); // norm 5
        let calc = Matrix::from_vec(1, 2, vec![3.0, 4.5]); // diff norm 0.5
        assert!((relative_error(&truth, &calc) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_truth_nonzero_calc_is_infinite() {
        let truth: Matrix<f64> = Matrix::zeros(2, 2);
        let mut calc = Matrix::zeros(2, 2);
        calc.set(0, 0, 1.0);
        assert!(relative_error(&truth, &calc).is_infinite());
        assert_eq!(relative_error(&truth, &truth), 0.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a: Matrix<f64> = Matrix::zeros(2, 2);
        let b: Matrix<f64> = Matrix::zeros(2, 3);
        let _ = relative_error(&a, &b);
    }
}
