//! The relative error metric of Eq. (13):
//! `err = ||C_true - C_calc||_2 / ||C_true||_2` (Frobenius norms) —
//! plus [`GemmError`], the typed failure the serving path returns.

use std::time::Duration;

use crate::util::mat::Matrix;

/// Typed failure of a GEMM request through the serving path
/// ([`crate::coordinator::server::GemmService`]).
///
/// The executing kernels keep their shape `assert_eq!`s as last-resort
/// invariants; the coordinator validates first — at submit time and
/// again in the worker — and returns one of these to the caller instead
/// of panicking a worker thread (or the submitting thread).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GemmError {
    /// Inner dimensions disagree: `A` is `m × k_a` but `B` is `k_b × n`.
    ShapeMismatch { m: usize, k_a: usize, k_b: usize, n: usize },
    /// The request named a weight id that was never registered (or was
    /// already unregistered).
    UnknownWeight(u64),
    /// The kernel panicked while executing; carries the panic message.
    Panicked(String),
    /// The request's deadline elapsed before a result was produced
    /// (`[server] request_timeout_ms`); `after` is how long the request
    /// had been outstanding when the caller (or server) gave up.
    Timeout { after: Duration },
    /// Admission control shed the request at submit time: `in_flight`
    /// requests were already queued or executing against a bound of
    /// `limit` (`[server] max_pending`).
    Overloaded { in_flight: usize, limit: usize },
    /// The shard router could not produce the column slice owned by
    /// `shard`, even after its retry and failover budget.
    ShardFailed { shard: usize, reason: String },
    /// The dispatcher or a batch task dropped the channel — the service
    /// shut down, or a worker died mid-request.
    ChannelClosed,
    /// A failpoint injected this failure
    /// ([`crate::exec::faults`]; chaos tests only) — carries the site.
    Injected(String),
}

impl GemmError {
    /// Whether a retry of the same request could plausibly succeed.
    ///
    /// Transient worker-side failures (a panicked batch, a dropped
    /// reply channel, an injected fault) are retryable — the blocking
    /// entry points resubmit them under
    /// [`ServiceConfig::retries`](crate::coordinator::server::ServiceConfig::retries).
    /// Deterministic rejections ([`GemmError::ShapeMismatch`],
    /// [`GemmError::UnknownWeight`]) and back-pressure signals
    /// ([`GemmError::Timeout`], [`GemmError::Overloaded`]) are not;
    /// neither is [`GemmError::ShardFailed`], which the router only
    /// returns after exhausting its own per-slice retry + failover
    /// budget.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            GemmError::Panicked(_) | GemmError::ChannelClosed | GemmError::Injected(_)
        )
    }
}

impl std::fmt::Display for GemmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GemmError::ShapeMismatch { m, k_a, k_b, n } => write!(
                f,
                "inner dimensions must match: A is {m}x{k_a} but B is {k_b}x{n}"
            ),
            GemmError::UnknownWeight(id) => {
                write!(f, "unknown weight id {id}; call register_weights first")
            }
            GemmError::Panicked(msg) => write!(f, "gemm panicked: {msg}"),
            GemmError::Timeout { after } => {
                write!(f, "request timed out after {:.3} ms", after.as_secs_f64() * 1e3)
            }
            GemmError::Overloaded { in_flight, limit } => write!(
                f,
                "service overloaded: {in_flight} requests pending against a bound of {limit}"
            ),
            GemmError::ShardFailed { shard, reason } => {
                write!(f, "shard {shard} failed: {reason}")
            }
            GemmError::ChannelClosed => {
                write!(f, "service channel closed (shut down, or a worker died mid-request)")
            }
            GemmError::Injected(site) => write!(f, "injected fault at failpoint '{site}'"),
        }
    }
}

impl std::error::Error for GemmError {}

impl From<crate::exec::faults::InjectedFault> for GemmError {
    fn from(f: crate::exec::faults::InjectedFault) -> GemmError {
        GemmError::Injected(f.site)
    }
}

/// Relative Frobenius-norm error of `calc` against `truth` (both f64;
/// promote f32 results with [`Matrix::to_f64`] first).
pub fn relative_error(truth: &Matrix<f64>, calc: &Matrix<f64>) -> f64 {
    assert_eq!(truth.shape(), calc.shape(), "shape mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (t, c) in truth.as_slice().iter().zip(calc.as_slice().iter()) {
        let d = t - c;
        num += d * d;
        den += t * t;
    }
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (num / den).sqrt()
    }
}

/// Maximum elementwise relative error (secondary diagnostic; the paper
/// reports the norm-based metric).
pub fn max_elementwise_error(truth: &Matrix<f64>, calc: &Matrix<f64>) -> f64 {
    assert_eq!(truth.shape(), calc.shape());
    truth
        .as_slice()
        .iter()
        .zip(calc.as_slice().iter())
        .map(|(t, c)| {
            let denom = t.abs().max(f64::MIN_POSITIVE);
            (t - c).abs() / denom
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_for_identical() {
        let m = Matrix::from_fn(3, 3, |i, j| (i + j) as f64);
        assert_eq!(relative_error(&m, &m), 0.0);
        assert_eq!(max_elementwise_error(&m, &m), 0.0);
    }

    #[test]
    fn known_relative_error() {
        let truth = Matrix::from_vec(1, 2, vec![3.0, 4.0]); // norm 5
        let calc = Matrix::from_vec(1, 2, vec![3.0, 4.5]); // diff norm 0.5
        assert!((relative_error(&truth, &calc) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_truth_nonzero_calc_is_infinite() {
        let truth: Matrix<f64> = Matrix::zeros(2, 2);
        let mut calc = Matrix::zeros(2, 2);
        calc.set(0, 0, 1.0);
        assert!(relative_error(&truth, &calc).is_infinite());
        assert_eq!(relative_error(&truth, &truth), 0.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a: Matrix<f64> = Matrix::zeros(2, 2);
        let b: Matrix<f64> = Matrix::zeros(2, 3);
        let _ = relative_error(&a, &b);
    }

    #[test]
    fn gemm_error_displays_and_converts() {
        let e = GemmError::ShapeMismatch { m: 4, k_a: 5, k_b: 6, n: 4 };
        assert_eq!(format!("{e}"), "inner dimensions must match: A is 4x5 but B is 6x4");
        assert!(format!("{}", GemmError::UnknownWeight(9)).contains("weight id 9"));
        assert!(format!("{}", GemmError::Panicked("boom".into())).contains("boom"));
        // std::error::Error + the anyhow blanket From both apply.
        let any: anyhow::Error = e.clone().into();
        assert!(format!("{any}").contains("inner dimensions"));
        assert_eq!(e, e.clone());
    }

    #[test]
    fn resilience_errors_display() {
        let t = GemmError::Timeout { after: Duration::from_millis(25) };
        assert!(format!("{t}").contains("25.000 ms"), "{t}");
        let o = GemmError::Overloaded { in_flight: 9, limit: 8 };
        assert!(format!("{o}").contains("9 requests pending"), "{o}");
        let s = GemmError::ShardFailed { shard: 2, reason: "boom".into() };
        assert!(format!("{s}").contains("shard 2"), "{s}");
        assert!(format!("{}", GemmError::ChannelClosed).contains("channel closed"));
        let i = GemmError::Injected("coordinator.batch.exec".into());
        assert!(format!("{i}").contains("coordinator.batch.exec"), "{i}");
    }

    #[test]
    fn retryability_classification() {
        // Transient worker-side failures: a retry may succeed.
        assert!(GemmError::Panicked("x".into()).is_retryable());
        assert!(GemmError::ChannelClosed.is_retryable());
        assert!(GemmError::Injected("site".into()).is_retryable());
        // Deterministic rejections and back-pressure: never retried.
        assert!(!GemmError::ShapeMismatch { m: 1, k_a: 2, k_b: 3, n: 4 }.is_retryable());
        assert!(!GemmError::UnknownWeight(1).is_retryable());
        assert!(!GemmError::Timeout { after: Duration::ZERO }.is_retryable());
        assert!(!GemmError::Overloaded { in_flight: 1, limit: 1 }.is_retryable());
        assert!(!GemmError::ShardFailed { shard: 0, reason: String::new() }.is_retryable());
    }

    #[test]
    fn injected_fault_converts_to_typed_error() {
        let f = crate::exec::faults::InjectedFault { site: "a.b".into(), hit: 3 };
        assert_eq!(GemmError::from(f), GemmError::Injected("a.b".into()));
    }
}
