//! Exact-numerics GEMM engine.
//!
//! This module is the *numerical* substrate of the reproduction: every
//! precision variant the paper evaluates, implemented bit-faithfully on
//! the host CPU so that accuracy experiments (Figs. 8–9) measure the same
//! arithmetic the Ascend pipeline performs:
//!
//! * [`dgemm`] — FP64 reference (the paper's ground truth, Eq. 13).
//! * [`sgemm`] — FP32 GEMM with plain FP32 running-sum accumulation
//!   (OpenBLAS-SGEMM stand-in for the accuracy comparison).
//! * [`hgemm`] — FP16 GEMM as the Cube executes it: FP16 operands,
//!   exact FP16×FP16 products (exactly representable in FP32), FP32
//!   accumulation — with an optional RZ-accumulate mode reproducing the
//!   Tensor-Core behaviour Ootomo & Yokota identified.
//! * [`cube`] — SGEMM-cube itself: two-component split + three dominant
//!   GEMM terms, with elementwise and termwise accumulation orders
//!   (Fig. 3).
//! * [`error`] — the relative error metric of Eq. (13).
//! * [`backend`] — a dynamic `GemmBackend` abstraction used by the
//!   coordinator and the training example to switch precision paths.
//!
//! The engine is two-tier: the exact, order-faithful kernels above serve
//! the accuracy experiments, while the serving/training hot path runs
//! through the cache-blocked packed engine —
//!
//! * [`pack`] — `MR`/`NR`-interleaved panel packing, including the
//!   dual-component format that carries the split high/low FP16
//!   components in one stream.
//! * [`blocked`] — the `b_n → b_k → b_m` loop nest driving the
//!   micro-kernels over packed panels; block sizes come from
//!   [`crate::sim::blocking`] on the host cache model.
//! * [`kernels`] — the `MR × NR` register micro-kernels themselves:
//!   scalar reference plus explicit AVX2+FMA and NEON variants,
//!   runtime-selected once per process ([`kernels::active_lane`],
//!   `SGEMM_CUBE_KERNEL` override) with a pinned per-lane
//!   accumulation-order contract.
//! * [`fast`] — the hot-path entry points (wrappers over [`blocked`],
//!   plus the retained pre-blocking baselines).
//! * [`overlap`] — compatibility shim over the executor pipeline
//!   ([`crate::exec::pipeline`]), which prefetches the next block's B
//!   panel (and, on the A+B schedule, its A row-block stripe) through a
//!   depth-configurable ring on the persistent pool; bit-identical
//!   `*_overlapped` / `*_overlapped_ab` entry points plus the
//!   instrumented `*_staged` drivers that calibrate
//!   [`crate::sim::pipeline`] from measured stage times.
//! * [`prepacked`] — stable B operands with the split + pack work done
//!   once ([`prepacked::PrepackedMatrix`]), consumed bit-identically by
//!   [`blocked::gemm_prepacked`].
//! * [`cache`] — the byte-bounded LRU the coordinator serves prepacked
//!   weights from.

pub mod backend;
pub mod bfcube;
pub mod blocked;
pub mod cache;
pub mod cube;
pub mod dgemm;
pub mod error;
pub mod fast;
pub mod hgemm;
pub mod kernels;
pub mod overlap;
pub mod pack;
pub mod prepacked;
pub mod sgemm;

pub use backend::{default_schedule, Backend, GemmBackend, Schedule};
pub use blocked::{
    cube_gemm_blocked, cube_gemm_blocked_overlapped, cube_gemm_blocked_overlapped_ab,
    cube_gemm_prepacked, family_gemm_blocked, family_gemm_blocked_overlapped,
    family_gemm_blocked_overlapped_ab, family_gemm_prepacked, gemm_prepacked,
    gemm_prepacked_overlapped, gemm_prepacked_overlapped_ab, gemm_prepacked_scheduled,
    hgemm_blocked, hgemm_blocked_overlapped, hgemm_blocked_overlapped_ab, sgemm_blocked,
    sgemm_blocked_overlapped, sgemm_blocked_overlapped_ab,
};
pub use cache::{CacheStats, PrepackCache, PrepackKey};
pub use cube::{cube_gemm, cube_gemm_split, Accumulation};
pub use dgemm::dgemm;
pub use error::{relative_error, GemmError};
pub use hgemm::{hgemm, AccumulateMode};
pub use kernels::{active_lane, detect_lane, force_lane, Lane};
pub use overlap::overlap_enabled;
pub use prepacked::{PrepackPath, PrepackedMatrix};
pub use sgemm::sgemm;
