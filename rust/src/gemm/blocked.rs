//! Cache-blocked, packed GEMM engine — the executed counterpart of the
//! paper's Sec. 5.1 blocking analysis.
//!
//! The reference kernels ([`crate::gemm::sgemm`], [`crate::gemm::hgemm`],
//! [`crate::gemm::cube`]) are accuracy-faithful but stream the full B
//! panel from memory once per output row. This module is the serving
//! tier: a three-level `b_n → b_k → b_m` loop nest over packed panels
//! ([`crate::gemm::pack`]) with a lane-sized `mr × nr` register
//! micro-kernel, and — for SGEMM-cube — a **fused three-term
//! micro-kernel** that accumulates the high·high product and both
//! correction terms in a single pass over dual-component interleaved
//! panels, instead of the reference's three separate traversals. The
//! micro-kernels themselves live in [`crate::gemm::kernels`]: a
//! runtime-dispatched lane (scalar fallback, AVX2+FMA or AVX-512F on
//! x86_64, NEON on aarch64, `SGEMM_CUBE_KERNEL` override) resolved
//! **once per GEMM call**, so one call never mixes lanes — which
//! matters doubly now that the micro-tile (and hence the packed-panel
//! interleave) follows the lane ([`Lane::tile_dims`]): the AVX-512
//! lane runs the wide 8×16 tile, every other lane the narrow 4×8. The
//! drivers below resolve the lane, pack with its dims, and thread it
//! into the shared sweeps explicitly; prepacked operands carry the
//! lane they were packed for ([`PrepackedMatrix::lane`]).
//!
//! Block sizes are not hand-tuned: [`host_block`] runs the repo's own
//! Eq. (12) feasibility machinery ([`crate::sim::blocking`]) against the
//! [`Chip::host_cpu`] cache descriptor and picks the feasible
//! configuration minimizing the Eq. (9) traffic model mapped onto this
//! loop nest ([`Traffic::host_blocked`]). Eq. 8/9 therefore drive real
//! execution, not just the simulator figures.
//!
//! Accumulation semantics: within one k block each output cell is a
//! single FP32 chain in k order. For the *single-component* kernels
//! ([`sgemm_blocked`], [`hgemm_blocked`]) that makes results
//! bit-identical to the exact kernels whenever `k ≤ b_k` **on the
//! scalar lane** (the exact kernels round multiply-then-add; the FMA
//! lanes fuse each step into one rounding — same chain, same order,
//! different per-step rounding, see the [`crate::gemm::kernels`]
//! contract); across k blocks, per-block partials combine once per
//! block. The fused cube kernel is the same accuracy *class* but not
//! bit-identical to the termwise reference even for small k: it merges
//! the two correction terms into one chain (`a_h·b_l + a_l·b_h` per
//! step) where the reference keeps `s_hl`/`s_lh` separate — the
//! corrections still aggregate among themselves before meeting the high
//! product, which is the property Sec. 4.4 actually needs. For a fixed
//! lane, every schedule and serving path below is bit-identical to this
//! module's serial nest; the lane is the only numerics degree of
//! freedom, and it is pinned per host (or per `SGEMM_CUBE_KERNEL`).
//!
//! Parallelism: one `parallel_chunks` round per `(b_n, b_k)` block, so
//! every thread reads the same freshly packed B panel. Rounds execute
//! on the **persistent worker pool** ([`crate::exec::pool`]) — the
//! calling thread participates and the pool threads live for the
//! process, so the per-round cost is a queue push per worker instead of
//! a spawn/join, and concurrent GEMM calls share one thread population
//! instead of oversubscribing the host (the fig11 bench records the
//! round-trip as `exec/pool_spawn_overhead_ns`). The prefetching
//! schedules ride the same pool: `*_overlapped` (B panel prefetch) and
//! `*_overlapped_ab` (B panel + A row-block stripe prefetch through a
//! depth-configurable ring, [`crate::exec::pipeline`]).
//! The model's `b_m` is an *upper* bound on the row-block
//! grain: when `m` is too small to give every worker a `b_m` block, the
//! executed row block shrinks (to a multiple of the lane's `mr`) so the
//! engine keeps all cores busy — `b_m` governs packing/cache reuse, not
//! the thread count (see [`exec_bm`]).
//!
//! Serving path: the split + pack cost of a *stable* B operand (a
//! weight matrix) is `O(k·n)` work independent of `m`, so at serving
//! shapes (small `m`, repeated requests) it dominates the request. The
//! prepacked entry points ([`gemm_prepacked`], [`cube_gemm_prepacked`])
//! run the same sweeps over panels cached in a [`PrepackedMatrix`],
//! paying that cost once per weight — outputs are bit-identical to the
//! pack-on-the-fly path because the sweeps are shared
//! (`sweep_rows_f32`/`sweep_rows_cube`) and the panel bytes are
//! equal. The prepacked-overlapped entry points
//! ([`gemm_prepacked_overlapped`], [`gemm_prepacked_overlapped_ab`],
//! dispatched per [`Schedule`] by [`gemm_prepacked_scheduled`]) go one
//! step further and route the remaining per-request pack work — the A
//! row-block stripe — through the prefetch ring, so registered-weight
//! serving runs the kernel-only packed sweeps with zero pack work on
//! the critical path. See EXPERIMENTS.md §Serving-amortization.
//!
//! The measured before/after for this engine is recorded in
//! EXPERIMENTS.md §Perf-iteration-log.

use std::sync::OnceLock;
use std::time::Instant;

use crate::exec::pipeline::{self, PrefetchStats};
use crate::gemm::backend::Schedule;
use crate::gemm::cube::WideSplit;
use crate::gemm::kernels::{self, Lane};
use crate::gemm::overlap;
use crate::gemm::pack::{self, MAX_MR, MAX_NR, MR, NR};
use crate::gemm::prepacked::{PrepackPath, PrepackedMatrix};
use crate::sim::blocking::{feasible_blocks, BlockConfig, GemmShape, Traffic};
use crate::sim::chip::Chip;
use crate::softfloat::f16::F16;
use crate::softfloat::family::{ComponentFormat, FamilySplit, SplitSpec, MAX_COMPONENTS};
use crate::softfloat::split::SplitConfig;
use crate::util::bench::StageBreakdown;
use crate::util::mat::Matrix;
use crate::util::threads::{parallel_chunks, SendPtr};

/// The block configuration every blocked kernel uses on this host.
///
/// Computed once: the Eq. (12)-feasible configuration on
/// [`Chip::host_cpu`] minimizing [`Traffic::host_blocked`] at the
/// serving-scale reference shape 1024³ (the traffic ranking is nearly
/// shape-free — every term scales with the problem volume — so one
/// selection serves all sizes).
pub fn host_block() -> BlockConfig {
    static BLOCK: OnceLock<BlockConfig> = OnceLock::new();
    *BLOCK.get_or_init(|| select_block(&Chip::host_cpu()))
}

/// Enumerate the feasible blocks on `chip` (Eq. 12) and pick the one
/// minimizing the executed-nest traffic model (Eq. 9 mapped onto the
/// host loop nest). Ties break toward larger `b_m` (fewer, larger packed
/// row blocks amortize per-block overhead).
pub fn select_block(chip: &Chip) -> BlockConfig {
    let shape = GemmShape::new(1024, 1024, 1024);
    feasible_blocks(chip, 256)
        .into_iter()
        .min_by(|x, y| {
            let tx = Traffic::host_blocked(shape, *x).total_elems();
            let ty = Traffic::host_blocked(shape, *y).total_elems();
            tx.total_cmp(&ty).then_with(|| y.bm.cmp(&x.bm))
        })
        .expect("host chip admits at least one feasible block")
}

/// FP32 blocked GEMM with single-chain-per-cell accumulation inside each
/// k block (bit-identical to [`crate::gemm::sgemm::sgemm`] for
/// `k ≤ b_k`).
pub fn sgemm_blocked(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    gemm_blocked_core(a, b)
}

/// FP16 Cube GEMM (operands converted to FP16 RN and widened exactly,
/// FP32 accumulation), through the blocked engine.
pub fn hgemm_blocked(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
    let bh = b.map(|v| F16::from_f32_rn(v).to_f32());
    gemm_blocked_core(&ah, &bh)
}

/// SGEMM-cube through the blocked engine: split, then the fused
/// three-term micro-kernel over dual-component packed panels.
pub fn cube_gemm_blocked(a: &Matrix<f32>, b: &Matrix<f32>, cfg: SplitConfig) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let asp = WideSplit::of(a, cfg);
    let bsp = WideSplit::of(b, cfg);
    cube_gemm_blocked_split(&asp, &bsp)
}

/// SGEMM-cube over pre-split operands — for callers that already hold
/// `WideSplit` components and want to skip the per-call split. (The
/// serving path goes further and skips the per-call *packing* of B too:
/// see [`cube_gemm_prepacked`].)
pub fn cube_gemm_blocked_split(a: &WideSplit, b: &WideSplit) -> Matrix<f32> {
    assert_eq!(a.cfg, b.cfg, "operands must be split with the same configuration");
    let (_, k) = a.high.shape();
    let kb = b.high.rows();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let inv_sf = 1.0f32 / a.cfg.scale_factor();
    cube_blocked_core(&a.high, &a.low, &b.high, &b.low, inv_sf)
}

/// Precision-family GEMM through the blocked engine: split both
/// operands under `spec`, then run the generic N-term fused sweep over
/// `ncomp`-component packed panels.
///
/// The N = 2 FP16 spec routes **structurally** onto the existing cube
/// path ([`cube_gemm_blocked`]) — the paper's scheme *is* that family
/// member, and reusing the original entry point keeps it bit-identical
/// to the pre-family engine by construction. Every other spec (BF16
/// tiers, N ≥ 3 cascades) runs the generic family core, whose `N = 2`
/// kernels and combine are themselves bit-compatible with the cube ones
/// (see [`crate::gemm::kernels::kernel_family`]).
pub fn family_gemm_blocked(a: &Matrix<f32>, b: &Matrix<f32>, spec: SplitSpec) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    if let ComponentFormat::Fp16Scaled(cfg) = spec.format {
        if spec.components == 2 {
            return cube_gemm_blocked(a, b, cfg);
        }
    }
    let asp = FamilySplit::of(a, spec);
    let bsp = FamilySplit::of(b, spec);
    family_blocked_core(asp.comps(), bsp.comps(), &spec)
}

/// FP32 blocked GEMM through the overlapped (double-buffered) pipeline:
/// a prefetch worker packs the next `(k, j)` B panel while the
/// micro-kernel consumes the current one ([`crate::gemm::overlap`]).
/// **Bit-identical** to [`sgemm_blocked`] — same pack routines, same
/// block order, same shared sweeps.
pub fn sgemm_blocked_overlapped(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    pipeline::gemm_overlapped_core(a, b)
}

/// FP16 Cube GEMM through the overlapped pipeline; bit-identical to
/// [`hgemm_blocked`].
pub fn hgemm_blocked_overlapped(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
    let bh = b.map(|v| F16::from_f32_rn(v).to_f32());
    pipeline::gemm_overlapped_core(&ah, &bh)
}

/// SGEMM-cube through the overlapped pipeline: the dual high/low split
/// panels are prefetched while the fused three-term micro-kernel
/// consumes the current block. Bit-identical to [`cube_gemm_blocked`].
pub fn cube_gemm_blocked_overlapped(
    a: &Matrix<f32>,
    b: &Matrix<f32>,
    cfg: SplitConfig,
) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let asp = WideSplit::of(a, cfg);
    let bsp = WideSplit::of(b, cfg);
    cube_gemm_blocked_split_overlapped(&asp, &bsp)
}

/// Overlapped counterpart of [`cube_gemm_blocked_split`].
pub fn cube_gemm_blocked_split_overlapped(a: &WideSplit, b: &WideSplit) -> Matrix<f32> {
    assert_eq!(a.cfg, b.cfg, "operands must be split with the same configuration");
    let (_, k) = a.high.shape();
    let kb = b.high.rows();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let inv_sf = 1.0f32 / a.cfg.scale_factor();
    pipeline::cube_overlapped_core(&a.high, &a.low, &b.high, &b.low, inv_sf)
}

/// FP32 blocked GEMM through the A+B dual-panel pipeline: a pool
/// prefetch job packs **both** the next `(k, j)` block's B panel and
/// its A row-block stripe through a `depth`-slot ring
/// ([`crate::exec::pipeline`]) while the kernel-only sweeps consume the
/// current one. **Bit-identical** to [`sgemm_blocked`] for every
/// `depth` — same pack routines, same block order, same kernel loops.
pub fn sgemm_blocked_overlapped_ab(a: &Matrix<f32>, b: &Matrix<f32>, depth: usize) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    pipeline::gemm_ab_core(a, b, depth)
}

/// FP16 Cube GEMM through the A+B dual-panel pipeline; bit-identical to
/// [`hgemm_blocked`].
pub fn hgemm_blocked_overlapped_ab(a: &Matrix<f32>, b: &Matrix<f32>, depth: usize) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
    let bh = b.map(|v| F16::from_f32_rn(v).to_f32());
    pipeline::gemm_ab_core(&ah, &bh, depth)
}

/// SGEMM-cube through the A+B dual-panel pipeline: the dual high/low
/// split B panels **and** dual A row-block stripes are prefetched while
/// the fused three-term micro-kernel consumes the current block.
/// Bit-identical to [`cube_gemm_blocked`].
pub fn cube_gemm_blocked_overlapped_ab(
    a: &Matrix<f32>,
    b: &Matrix<f32>,
    cfg: SplitConfig,
    depth: usize,
) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let asp = WideSplit::of(a, cfg);
    let bsp = WideSplit::of(b, cfg);
    cube_gemm_blocked_split_overlapped_ab(&asp, &bsp, depth)
}

/// A+B-pipeline counterpart of [`cube_gemm_blocked_split`].
pub fn cube_gemm_blocked_split_overlapped_ab(
    a: &WideSplit,
    b: &WideSplit,
    depth: usize,
) -> Matrix<f32> {
    assert_eq!(a.cfg, b.cfg, "operands must be split with the same configuration");
    let (_, k) = a.high.shape();
    let kb = b.high.rows();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let inv_sf = 1.0f32 / a.cfg.scale_factor();
    pipeline::cube_ab_core(&a.high, &a.low, &b.high, &b.low, inv_sf, depth)
}

/// Precision-family GEMM through the overlapped (double-buffered)
/// pipeline: the `ncomp`-component B panels are prefetched while the
/// N-term family micro-kernel consumes the current block. The N = 2
/// FP16 spec routes onto [`cube_gemm_blocked_overlapped`]; every
/// schedule is bit-identical to [`family_gemm_blocked`].
pub fn family_gemm_blocked_overlapped(
    a: &Matrix<f32>,
    b: &Matrix<f32>,
    spec: SplitSpec,
) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    if let ComponentFormat::Fp16Scaled(cfg) = spec.format {
        if spec.components == 2 {
            return cube_gemm_blocked_overlapped(a, b, cfg);
        }
    }
    let asp = FamilySplit::of(a, spec);
    let bsp = FamilySplit::of(b, spec);
    pipeline::family_overlapped_core(asp.comps(), bsp.comps(), &spec)
}

/// Precision-family GEMM through the A+B dual-panel pipeline
/// (multi-component B panels **and** A row-block stripes prefetched
/// through a `depth`-slot ring). The N = 2 FP16 spec routes onto
/// [`cube_gemm_blocked_overlapped_ab`]; bit-identical to
/// [`family_gemm_blocked`] at every depth.
pub fn family_gemm_blocked_overlapped_ab(
    a: &Matrix<f32>,
    b: &Matrix<f32>,
    spec: SplitSpec,
    depth: usize,
) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    if let ComponentFormat::Fp16Scaled(cfg) = spec.format {
        if spec.components == 2 {
            return cube_gemm_blocked_overlapped_ab(a, b, cfg, depth);
        }
    }
    let asp = FamilySplit::of(a, spec);
    let bsp = FamilySplit::of(b, spec);
    pipeline::family_ab_core(asp.comps(), bsp.comps(), &spec, depth)
}

/// Instrumented serial FP32 blocked GEMM: the exact serial nest run
/// single-threaded with per-stage wall times (pack-A, pack-B,
/// micro-kernel, C update). Calibration/diagnostics path — see
/// [`crate::gemm::overlap`] and EXPERIMENTS.md §Overlap.
pub fn sgemm_blocked_staged(a: &Matrix<f32>, b: &Matrix<f32>) -> (Matrix<f32>, StageBreakdown) {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    overlap::gemm_staged_core(a, b)
}

/// Instrumented serial SGEMM-cube (dual-component counterpart of
/// [`sgemm_blocked_staged`]). The split itself is not part of the
/// breakdown — at serving sizes it is the prepack path's one-off cost;
/// the four stages cover the per-request nest.
pub fn cube_gemm_blocked_staged(
    a: &Matrix<f32>,
    b: &Matrix<f32>,
    cfg: SplitConfig,
) -> (Matrix<f32>, StageBreakdown) {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match: {} vs {}", a.cols(), b.rows());
    let asp = WideSplit::of(a, cfg);
    let bsp = WideSplit::of(b, cfg);
    let inv_sf = 1.0f32 / cfg.scale_factor();
    overlap::cube_staged_core(&asp.high, &asp.low, &bsp.high, &bsp.low, inv_sf)
}

/// GEMM against a prepacked B operand, dispatching on the path the
/// panels were prepared for ([`PrepackPath`]). The split/convert + pack
/// cost of B is already paid ([`PrepackedMatrix::prepack`]); per request
/// only A is prepared. Output is **bit-identical** to the corresponding
/// pack-on-the-fly entry point ([`sgemm_blocked`], [`hgemm_blocked`],
/// [`cube_gemm_blocked`] with the same [`SplitConfig`]) because both
/// run the same sweeps over the same panel bytes.
pub fn gemm_prepacked(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    match b.path() {
        PrepackPath::Fp32 => sgemm_prepacked(a, b),
        PrepackPath::Fp16 => hgemm_prepacked(a, b),
        PrepackPath::Cube(_) => cube_gemm_prepacked(a, b),
        PrepackPath::Family(_) => family_gemm_prepacked(a, b),
    }
}

/// GEMM against a prepacked B operand under an explicit host
/// [`Schedule`] — the serving tier's single dispatch point
/// ([`crate::gemm::backend::GemmBackend::gemm_prepacked`] and the
/// coordinator's batch tasks land here). Every schedule is
/// **bit-identical** to [`gemm_prepacked`]: the panel bytes were fixed
/// at prepack time and all schedules run the same shared sweeps.
///
/// With B already packed, the only operand movement left to hide is
/// the per-row-block A stripe: [`Schedule::Serial`] packs it inside the
/// sweeps, [`Schedule::OverlapB`] routes it through the A-stripe
/// prefetch ring at the classic double-buffer depth (the closest
/// prepacked analogue of the B-panel prefetch), and
/// [`Schedule::OverlapAB`] uses the configured ring `depth`.
pub fn gemm_prepacked_scheduled(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    schedule: Schedule,
    depth: usize,
) -> Matrix<f32> {
    match schedule {
        Schedule::Serial => gemm_prepacked(a, b),
        Schedule::OverlapB => gemm_prepacked_overlapped(a, b),
        Schedule::OverlapAB => gemm_prepacked_overlapped_ab(a, b, depth),
    }
}

/// [`gemm_prepacked`] with the next block's A row-block stripe
/// prefetched through the classic two-slot ring (pipeline depth 2); B
/// panels stream straight from the cached operand. Bit-identical to
/// [`gemm_prepacked`].
pub fn gemm_prepacked_overlapped(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    gemm_prepacked_overlapped_ab(a, b, pipeline::DEFAULT_PIPELINE_DEPTH)
}

/// [`gemm_prepacked`] through the depth-configurable A-stripe ring
/// ([`crate::exec::pipeline`]): a pool prefetch job packs only the next
/// k block's A row-block stripe (dual high/low split included on the
/// cube path, one ring job per k block — each stripe is packed exactly
/// once and swept across every column block) while the kernel-only
/// packed sweeps consume the current one against cached B panels —
/// zero pack-A/pack-B work on the critical path once the ring is
/// primed. Bit-identical to [`gemm_prepacked`] at every depth.
pub fn gemm_prepacked_overlapped_ab(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> Matrix<f32> {
    match b.path() {
        PrepackPath::Fp32 => sgemm_prepacked_overlapped_ab(a, b, depth),
        PrepackPath::Fp16 => hgemm_prepacked_overlapped_ab(a, b, depth),
        PrepackPath::Cube(_) => cube_gemm_prepacked_overlapped_ab(a, b, depth),
        PrepackPath::Family(_) => family_gemm_prepacked_overlapped_ab(a, b, depth),
    }
}

/// FP32 prepacked GEMM with the A stripe prefetched; bit-identical to
/// [`sgemm_prepacked`].
pub fn sgemm_prepacked_overlapped_ab(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> Matrix<f32> {
    assert_eq!(b.path(), PrepackPath::Fp32, "operand was prepacked for {:?}", b.path());
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    pipeline::gemm_prepacked_ab_core(a, b, depth)
}

/// FP16 prepacked GEMM with the A stripe prefetched (A converted per
/// call exactly as [`hgemm_prepacked`] does); bit-identical to it.
pub fn hgemm_prepacked_overlapped_ab(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> Matrix<f32> {
    assert_eq!(b.path(), PrepackPath::Fp16, "operand was prepacked for {:?}", b.path());
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
    pipeline::gemm_prepacked_ab_core(&ah, b, depth)
}

/// SGEMM-cube over prepacked dual-component B panels with the dual A
/// stripe prefetched; bit-identical to [`cube_gemm_prepacked`].
pub fn cube_gemm_prepacked_overlapped_ab(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> Matrix<f32> {
    let cfg = match b.path() {
        PrepackPath::Cube(cfg) => cfg,
        p => panic!("operand was prepacked for {p:?}, not the cube path"),
    };
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let asp = WideSplit::of(a, cfg);
    let inv_sf = 1.0f32 / cfg.scale_factor();
    pipeline::cube_prepacked_ab_core(&asp.high, &asp.low, b, inv_sf, depth)
}

/// Precision-family GEMM over prepacked multi-component B panels with
/// the multi-component A stripe prefetched; bit-identical to
/// [`family_gemm_prepacked`].
pub fn family_gemm_prepacked_overlapped_ab(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> Matrix<f32> {
    let spec = match b.path() {
        PrepackPath::Family(spec) => spec,
        p => panic!("operand was prepacked for {p:?}, not the family path"),
    };
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let asp = FamilySplit::of(a, spec);
    pipeline::family_prepacked_ab_core(asp.comps(), b, &spec, depth)
}

/// Instrumented [`gemm_prepacked_overlapped_ab`]: same computation,
/// same bits, plus consumer-side critical-path accounting. The
/// returned [`StageBreakdown`] carries the only A-staging time that
/// can reach the critical path of this schedule — `pack_b` is
/// **structurally zero** (B panels come prepacked) and `pack_a` is
/// inline fallback packs **plus** stalls waiting on a mid-pack
/// prefetcher ([`PrefetchStats::inline_pack_s`] + `wait_s`), zero
/// whenever the ring kept up; `kernel` carries the remaining (sweep)
/// span. The per-request A operand prep (FP16 rounding / cube split)
/// is excluded from the breakdown, exactly as
/// [`cube_gemm_blocked_staged`] excludes the operand split — the
/// stages cover the consuming nest only. This is the acceptance probe
/// for the kernel-only serving claim — see EXPERIMENTS.md
/// §Serving-amortization.
pub fn gemm_prepacked_overlapped_staged(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> (Matrix<f32>, StageBreakdown, PrefetchStats) {
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let (c, stats, total) = match b.path() {
        PrepackPath::Fp32 => {
            let t0 = Instant::now();
            let (c, stats) = pipeline::gemm_prepacked_ab_with_stats(a, b, depth);
            (c, stats, t0.elapsed().as_secs_f64())
        }
        PrepackPath::Fp16 => {
            let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
            let t0 = Instant::now();
            let (c, stats) = pipeline::gemm_prepacked_ab_with_stats(&ah, b, depth);
            (c, stats, t0.elapsed().as_secs_f64())
        }
        PrepackPath::Cube(cfg) => {
            let asp = WideSplit::of(a, cfg);
            let inv_sf = 1.0f32 / cfg.scale_factor();
            let t0 = Instant::now();
            let (c, stats) =
                pipeline::cube_prepacked_ab_with_stats(&asp.high, &asp.low, b, inv_sf, depth);
            (c, stats, t0.elapsed().as_secs_f64())
        }
        PrepackPath::Family(spec) => {
            let asp = FamilySplit::of(a, spec);
            let t0 = Instant::now();
            let (c, stats) =
                pipeline::family_prepacked_ab_with_stats(asp.comps(), b, &spec, depth);
            (c, stats, t0.elapsed().as_secs_f64())
        }
    };
    let staging = stats.inline_pack_s + stats.wait_s;
    let stages = StageBreakdown {
        pack_a: staging,
        pack_b: 0.0,
        kernel: (total - staging).max(0.0),
        c_update: 0.0,
    };
    (c, stages, stats)
}

/// FP32 blocked GEMM over prepacked B panels.
pub fn sgemm_prepacked(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    assert_eq!(b.path(), PrepackPath::Fp32, "operand was prepacked for {:?}", b.path());
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    prepacked_core_single(a, b)
}

/// FP16 Cube GEMM over prepacked B panels (B was FP16-rounded at pack
/// time; A is converted per call, exactly as [`hgemm_blocked`] does).
pub fn hgemm_prepacked(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    assert_eq!(b.path(), PrepackPath::Fp16, "operand was prepacked for {:?}", b.path());
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
    prepacked_core_single(&ah, b)
}

/// SGEMM-cube over prepacked dual-component B panels: A is split per
/// call with the configuration recorded in the packed operand, then the
/// fused three-term sweep runs against the cached panels.
pub fn cube_gemm_prepacked(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    let cfg = match b.path() {
        PrepackPath::Cube(cfg) => cfg,
        p => panic!("operand was prepacked for {p:?}, not the cube path"),
    };
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let asp = WideSplit::of(a, cfg);
    let inv_sf = 1.0f32 / cfg.scale_factor();
    prepacked_core_cube(&asp.high, &asp.low, b, inv_sf)
}

/// Precision-family GEMM over prepacked multi-component B panels: A is
/// split per call under the [`SplitSpec`] recorded in the packed
/// operand, then the N-term family sweep runs against the cached
/// panels. Bit-identical to [`family_gemm_blocked`] with the same spec
/// — including the N = 2 FP16 spec, whose family panels and kernels are
/// bit-compatible with the cube path's.
pub fn family_gemm_prepacked(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    let spec = match b.path() {
        PrepackPath::Family(spec) => spec,
        p => panic!("operand was prepacked for {p:?}, not the family path"),
    };
    assert_eq!(a.cols(), b.k(), "inner dimensions must match: {} vs {}", a.cols(), b.k());
    let asp = FamilySplit::of(a, spec);
    prepacked_core_family(asp.comps(), b, &spec)
}

/// Single-component nest over prepacked panels: the `b_n → b_k` loops of
/// [`gemm_blocked_core`] with `pack_b` replaced by a panel lookup.
fn prepacked_core_single(a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
    let (m, k) = a.shape();
    let n = b.n();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    // The sweep must consume these panels with the interleave they were
    // packed for: the lane is the one recorded at prepack time, not
    // whatever is active now.
    let lane = b.lane();
    let bm = exec_bm(m, host_block().bm, lane.tile_dims().0);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    for (jb, j0) in (0..n).step_by(b.bn()).enumerate() {
        for (pb, p0) in (0..k).step_by(b.bk()).enumerate() {
            let kc = b.bk().min(k - p0);
            sweep_rows_f32(a, b.panel(jb, pb), &cp, n, bm, j0, p0, kc, lane);
        }
    }
    c
}

/// Dual-component nest over prepacked panels (cube counterpart of
/// [`prepacked_core_single`]).
fn prepacked_core_cube(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    b: &PrepackedMatrix,
    inv_sf: f32,
) -> Matrix<f32> {
    let (m, k) = ah.shape();
    let n = b.n();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let lane = b.lane();
    let bm = exec_bm(m, host_block().bm, lane.tile_dims().0);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    for (jb, j0) in (0..n).step_by(b.bn()).enumerate() {
        for (pb, p0) in (0..k).step_by(b.bk()).enumerate() {
            let kc = b.bk().min(k - p0);
            sweep_rows_cube(ah, al, b.panel(jb, pb), &cp, n, bm, j0, p0, kc, inv_sf, lane);
        }
    }
    c
}

/// Multi-component nest over prepacked panels (family counterpart of
/// [`prepacked_core_cube`]).
fn prepacked_core_family(
    a_comps: &[Matrix<f32>],
    b: &PrepackedMatrix,
    spec: &SplitSpec,
) -> Matrix<f32> {
    let (m, k) = a_comps[0].shape();
    let n = b.n();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let lane = b.lane();
    let bm = exec_bm(m, host_block().bm, lane.tile_dims().0);
    let weights = spec.order_weights();
    let ncomp = spec.ncomp();
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    for (jb, j0) in (0..n).step_by(b.bn()).enumerate() {
        for (pb, p0) in (0..k).step_by(b.bk()).enumerate() {
            let kc = b.bk().min(k - p0);
            sweep_rows_family(
                a_comps,
                b.panel(jb, pb),
                &cp,
                n,
                bm,
                j0,
                p0,
                kc,
                &weights,
                ncomp,
                lane,
            );
        }
    }
    c
}

/// The executed row-block size: the model's `b_m` capped so that `m`
/// yields at least one row block per worker (keeping all cores busy on
/// serving-size problems), rounded to the active lane's `mr` panel
/// geometry (the model block itself is alignment-sized, a multiple of
/// every lane's `mr`).
pub fn exec_bm(m: usize, model_bm: usize, mr: usize) -> usize {
    let workers = crate::util::threads::num_threads().max(1);
    // Rounded *down* to an mr multiple so small m still splits into at
    // least one block per worker whenever m >= mr·workers.
    let per_worker = (m.div_ceil(workers) / mr * mr).max(mr);
    model_bm.min(per_worker)
}

/// Single-component blocked driver: `b_n → b_k → row blocks`, packed B
/// panel shared per (j, k) block, per-thread packed A row blocks.
fn gemm_blocked_core(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    // One lane for the whole call — it fixes both the panel interleave
    // packed below and the micro-kernel the sweep dispatches, so a
    // concurrent `force_lane` can never split one GEMM across lanes.
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let (bm, bk, bn) = (exec_bm(m, block.bm, mr), block.bk, block.bn);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    for j0 in (0..n).step_by(bn) {
        let nc = bn.min(n - j0);
        for p0 in (0..k).step_by(bk) {
            let kc = bk.min(k - p0);
            pack::pack_b(b, p0, kc, j0, nc, nr, &mut bp);
            sweep_rows_f32(a, &bp, &cp, n, bm, j0, p0, kc, lane);
        }
    }
    c
}

/// One `(j, k)` block of the single-component nest: every row block of A
/// packed per thread and run against the packed B panel `bp` (whether
/// freshly packed or served from a [`PrepackedMatrix`] — both paths
/// execute this exact sweep, which is what makes the prepacked results
/// bit-identical).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_rows_f32(
    a: &Matrix<f32>,
    bp: &[f32],
    cp: &SendPtr<f32>,
    n: usize,
    bm: usize,
    j0: usize,
    p0: usize,
    kc: usize,
    lane: Lane,
) {
    let m = a.rows();
    let row_blocks = m.div_ceil(bm);
    // The caller resolved `lane` once for the whole GEMM call and packed
    // `bp` with its tile dims; the same dims drive pack_a, the panel
    // chunking, and the kernel dispatch here, so one call can never mix
    // lanes (or interleaves) even under a concurrent `force_lane`.
    let (mr, nr) = lane.tile_dims();
    parallel_chunks(row_blocks, |rb0, rb1| {
        let mut ap = Vec::new();
        let mut acc = [0.0f32; MAX_MR * MAX_NR];
        for rb in rb0..rb1 {
            let i0 = rb * bm;
            let mc = bm.min(m - i0);
            pack::pack_a(a, i0, mc, p0, kc, mr, &mut ap);
            for (rp, apanel) in ap.chunks_exact(kc * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(kc * nr).enumerate() {
                    let cj = j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    kernels::kernel_f32(lane, apanel, bpanel, &mut acc[..mr * nr]);
                    add_tile(cp, n, ci, cj, mr_eff, nr_eff, nr, &acc[..mr * nr]);
                }
            }
        }
    });
}

/// [`sweep_rows_f32`] over a **prepacked A stripe**: the A+B pipeline's
/// consumption side. `ap_all`/`a_off` carry one `pack_a` output segment
/// per executed row block (packed ahead by the prefetcher,
/// [`crate::exec::pipeline`]); everything else — chunking, panel
/// iteration, kernel, C update — is the exact sweep above, which is
/// what keeps the A+B schedule bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_rows_f32_packed(
    ap_all: &[f32],
    a_off: &[usize],
    m: usize,
    bp: &[f32],
    cp: &SendPtr<f32>,
    n: usize,
    bm: usize,
    j0: usize,
    kc: usize,
    lane: Lane,
) {
    let row_blocks = m.div_ceil(bm);
    debug_assert_eq!(a_off.len(), row_blocks + 1);
    let (mr, nr) = lane.tile_dims();
    parallel_chunks(row_blocks, |rb0, rb1| {
        let mut acc = [0.0f32; MAX_MR * MAX_NR];
        for rb in rb0..rb1 {
            let i0 = rb * bm;
            let ap = &ap_all[a_off[rb]..a_off[rb + 1]];
            for (rp, apanel) in ap.chunks_exact(kc * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(kc * nr).enumerate() {
                    let cj = j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    kernels::kernel_f32(lane, apanel, bpanel, &mut acc[..mr * nr]);
                    add_tile(cp, n, ci, cj, mr_eff, nr_eff, nr, &acc[..mr * nr]);
                }
            }
        }
    });
}

/// Dual-component blocked driver with the fused three-term micro-kernel.
fn cube_blocked_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
) -> Matrix<f32> {
    let (m, k) = ah.shape();
    let n = bh.cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let (bm, bk, bn) = (exec_bm(m, block.bm, mr), block.bk, block.bn);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    for j0 in (0..n).step_by(bn) {
        let nc = bn.min(n - j0);
        for p0 in (0..k).step_by(bk) {
            let kc = bk.min(k - p0);
            pack::pack_b_dual(bh, bl, p0, kc, j0, nc, nr, &mut bp);
            sweep_rows_cube(ah, al, &bp, &cp, n, bm, j0, p0, kc, inv_sf, lane);
        }
    }
    c
}

/// Multi-component blocked driver with the generic N-term family
/// micro-kernel (family counterpart of [`cube_blocked_core`]).
fn family_blocked_core(
    a_comps: &[Matrix<f32>],
    b_comps: &[Matrix<f32>],
    spec: &SplitSpec,
) -> Matrix<f32> {
    let (m, k) = a_comps[0].shape();
    let n = b_comps[0].cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let (bm, bk, bn) = (exec_bm(m, block.bm, mr), block.bk, block.bn);
    let weights = spec.order_weights();
    let ncomp = spec.ncomp();
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mut bp = Vec::new();
    for j0 in (0..n).step_by(bn) {
        let nc = bn.min(n - j0);
        for p0 in (0..k).step_by(bk) {
            let kc = bk.min(k - p0);
            pack::pack_b_multi(b_comps, p0, kc, j0, nc, nr, &mut bp);
            sweep_rows_family(a_comps, &bp, &cp, n, bm, j0, p0, kc, &weights, ncomp, lane);
        }
    }
    c
}

/// Dual-component counterpart of [`sweep_rows_f32`]: one `(j, k)` block
/// of the fused cube nest against the dual-format packed B panel `bp`
/// (freshly packed or prepacked — the shared sweep keeps both paths
/// bit-identical).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_rows_cube(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bp: &[f32],
    cp: &SendPtr<f32>,
    n: usize,
    bm: usize,
    j0: usize,
    p0: usize,
    kc: usize,
    inv_sf: f32,
    lane: Lane,
) {
    let m = ah.rows();
    let row_blocks = m.div_ceil(bm);
    let (mr, nr) = lane.tile_dims();
    parallel_chunks(row_blocks, |rb0, rb1| {
        let mut ap = Vec::new();
        let mut hh = [0.0f32; MAX_MR * MAX_NR];
        let mut corr = [0.0f32; MAX_MR * MAX_NR];
        for rb in rb0..rb1 {
            let i0 = rb * bm;
            let mc = bm.min(m - i0);
            pack::pack_a_dual(ah, al, i0, mc, p0, kc, mr, &mut ap);
            for (rp, apanel) in ap.chunks_exact(kc * 2 * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(kc * 2 * nr).enumerate() {
                    let cj = j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    kernels::kernel_cube(
                        lane,
                        apanel,
                        bpanel,
                        &mut hh[..mr * nr],
                        &mut corr[..mr * nr],
                    );
                    add_tile_cube(
                        cp,
                        n,
                        ci,
                        cj,
                        mr_eff,
                        nr_eff,
                        nr,
                        &hh[..mr * nr],
                        &corr[..mr * nr],
                        inv_sf,
                    );
                }
            }
        }
    });
}

/// [`sweep_rows_cube`] over a prepacked dual-component A stripe (cube
/// counterpart of [`sweep_rows_f32_packed`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_rows_cube_packed(
    ap_all: &[f32],
    a_off: &[usize],
    m: usize,
    bp: &[f32],
    cp: &SendPtr<f32>,
    n: usize,
    bm: usize,
    j0: usize,
    kc: usize,
    inv_sf: f32,
    lane: Lane,
) {
    let row_blocks = m.div_ceil(bm);
    debug_assert_eq!(a_off.len(), row_blocks + 1);
    let (mr, nr) = lane.tile_dims();
    parallel_chunks(row_blocks, |rb0, rb1| {
        let mut hh = [0.0f32; MAX_MR * MAX_NR];
        let mut corr = [0.0f32; MAX_MR * MAX_NR];
        for rb in rb0..rb1 {
            let i0 = rb * bm;
            let ap = &ap_all[a_off[rb]..a_off[rb + 1]];
            for (rp, apanel) in ap.chunks_exact(kc * 2 * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(kc * 2 * nr).enumerate() {
                    let cj = j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    kernels::kernel_cube(
                        lane,
                        apanel,
                        bpanel,
                        &mut hh[..mr * nr],
                        &mut corr[..mr * nr],
                    );
                    add_tile_cube(
                        cp,
                        n,
                        ci,
                        cj,
                        mr_eff,
                        nr_eff,
                        nr,
                        &hh[..mr * nr],
                        &corr[..mr * nr],
                        inv_sf,
                    );
                }
            }
        }
    });
}

/// Multi-component counterpart of [`sweep_rows_cube`]: one `(j, k)`
/// block of the N-term family nest against the `ncomp`-component packed
/// B panel `bp` (freshly packed or prepacked — the shared sweep keeps
/// both paths bit-identical).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_rows_family(
    a_comps: &[Matrix<f32>],
    bp: &[f32],
    cp: &SendPtr<f32>,
    n: usize,
    bm: usize,
    j0: usize,
    p0: usize,
    kc: usize,
    weights: &[f32; MAX_COMPONENTS],
    ncomp: usize,
    lane: Lane,
) {
    let m = a_comps[0].rows();
    let row_blocks = m.div_ceil(bm);
    let (mr, nr) = lane.tile_dims();
    parallel_chunks(row_blocks, |rb0, rb1| {
        let mut ap = Vec::new();
        let mut acc = [0.0f32; MAX_COMPONENTS * MAX_MR * MAX_NR];
        for rb in rb0..rb1 {
            let i0 = rb * bm;
            let mc = bm.min(m - i0);
            pack::pack_a_multi(a_comps, i0, mc, p0, kc, mr, &mut ap);
            for (rp, apanel) in ap.chunks_exact(kc * ncomp * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(kc * ncomp * nr).enumerate() {
                    let cj = j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    kernels::kernel_family(lane, apanel, bpanel, ncomp, &mut acc);
                    add_tile_family(cp, n, ci, cj, mr_eff, nr_eff, mr, nr, &acc, weights, ncomp);
                }
            }
        }
    });
}

/// [`sweep_rows_family`] over a prepacked multi-component A stripe
/// (family counterpart of [`sweep_rows_cube_packed`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_rows_family_packed(
    ap_all: &[f32],
    a_off: &[usize],
    m: usize,
    bp: &[f32],
    cp: &SendPtr<f32>,
    n: usize,
    bm: usize,
    j0: usize,
    kc: usize,
    weights: &[f32; MAX_COMPONENTS],
    ncomp: usize,
    lane: Lane,
) {
    let row_blocks = m.div_ceil(bm);
    debug_assert_eq!(a_off.len(), row_blocks + 1);
    let (mr, nr) = lane.tile_dims();
    parallel_chunks(row_blocks, |rb0, rb1| {
        let mut acc = [0.0f32; MAX_COMPONENTS * MAX_MR * MAX_NR];
        for rb in rb0..rb1 {
            let i0 = rb * bm;
            let ap = &ap_all[a_off[rb]..a_off[rb + 1]];
            for (rp, apanel) in ap.chunks_exact(kc * ncomp * mr).enumerate() {
                let ci = i0 + rp * mr;
                let mr_eff = mr.min(m - ci);
                for (cpnl, bpanel) in bp.chunks_exact(kc * ncomp * nr).enumerate() {
                    let cj = j0 + cpnl * nr;
                    let nr_eff = nr.min(n - cj);
                    kernels::kernel_family(lane, apanel, bpanel, ncomp, &mut acc);
                    add_tile_family(cp, n, ci, cj, mr_eff, nr_eff, mr, nr, &acc, weights, ncomp);
                }
            }
        }
    });
}

/// `C[ci.., cj..] += acc` for the valid `mr_eff × nr_eff` sub-tile.
/// `acc` is the flat row-major `mr × nr` tile a kernel wrote (row `i`
/// at `acc[i·nr..]`), for whichever lane's `nr` the caller is running.
#[allow(clippy::too_many_arguments)]
pub(crate) fn add_tile(
    cp: &SendPtr<f32>,
    n: usize,
    ci: usize,
    cj: usize,
    mr_eff: usize,
    nr_eff: usize,
    nr: usize,
    acc: &[f32],
) {
    for i in 0..mr_eff {
        let base = (ci + i) * n + cj;
        for (j, &v) in acc[i * nr..i * nr + nr_eff].iter().enumerate() {
            // SAFETY: row-block chunks are disjoint across threads and the
            // output buffer outlives the parallel scope.
            unsafe { *cp.0.add(base + j) += v };
        }
    }
}

/// Cube tile combine: corrections (already aggregated together) are
/// scaled and meet the high product once per k block. `hh`/`corr` are
/// flat row-major `mr × nr` tiles (row `i` at `[i·nr..]`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn add_tile_cube(
    cp: &SendPtr<f32>,
    n: usize,
    ci: usize,
    cj: usize,
    mr_eff: usize,
    nr_eff: usize,
    nr: usize,
    hh: &[f32],
    corr: &[f32],
    inv_sf: f32,
) {
    for i in 0..mr_eff {
        let base = (ci + i) * n + cj;
        for j in 0..nr_eff {
            // SAFETY: row-block chunks are disjoint across threads and the
            // output buffer outlives the parallel scope.
            unsafe { *cp.0.add(base + j) += hh[i * nr + j] + corr[i * nr + j] * inv_sf };
        }
    }
}

/// Family tile combine: the per-order accumulator planes fold highest
/// order first — `tail = Σ_d acc_d · w_d` joined as
/// `acc_{n-1}·w_{n-1}`, then `fma`-shaped `acc_d·w_d + tail` down to
/// `d = 1` — and meet the order-0 plane once per k block. At
/// `ncomp == 2` this is *exactly* [`add_tile_cube`]'s
/// `hh + corr·inv_sf` (same operations, same order), which is what
/// keeps the N = 2 family instantiation bit-identical to the cube
/// path.
/// `acc` is the flat `MAX_COMPONENTS` planes of row-major `mr × nr`
/// tiles a family kernel wrote (plane `d` at `acc[d·mr·nr..]`, row `i`
/// of a plane at `[i·nr..]`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn add_tile_family(
    cp: &SendPtr<f32>,
    n: usize,
    ci: usize,
    cj: usize,
    mr_eff: usize,
    nr_eff: usize,
    mr: usize,
    nr: usize,
    acc: &[f32],
    weights: &[f32; MAX_COMPONENTS],
    ncomp: usize,
) {
    let plane = mr * nr;
    for i in 0..mr_eff {
        let base = (ci + i) * n + cj;
        for j in 0..nr_eff {
            let mut tail = acc[(ncomp - 1) * plane + i * nr + j] * weights[ncomp - 1];
            for d in (1..ncomp - 1).rev() {
                tail = acc[d * plane + i * nr + j] * weights[d] + tail;
            }
            // SAFETY: row-block chunks are disjoint across threads and the
            // output buffer outlives the parallel scope.
            unsafe { *cp.0.add(base + j) += acc[i * nr + j] + tail };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cube::{cube_gemm, Accumulation};
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::gemm::hgemm::{hgemm, AccumulateMode};
    use crate::gemm::sgemm::sgemm;
    use crate::util::rng::Rng;

    #[test]
    fn selected_block_is_feasible_and_model_driven() {
        let chip = Chip::host_cpu();
        let block = host_block();
        assert!(block.validate(&chip).is_ok(), "{block:?}");
        assert!(block.n_fused(&chip) >= 1);
        // Multiples of the alignment, hence of the micro-kernel geometry
        // — for the narrow lanes *and* the wide AVX-512 tile, so one
        // model block serves every lane.
        assert_eq!(block.bm % MR, 0);
        assert_eq!(block.bn % NR, 0);
        assert_eq!(block.bm % MAX_MR, 0);
        assert_eq!(block.bn % MAX_NR, 0);
        // It is the argmin of the host traffic model over the feasible set.
        let shape = GemmShape::new(1024, 1024, 1024);
        let best = Traffic::host_blocked(shape, block).total_elems();
        for cand in feasible_blocks(&chip, 256) {
            assert!(
                Traffic::host_blocked(shape, cand).total_elems() >= best - 1e-6,
                "{cand:?} beats selected {block:?}"
            );
        }
    }

    #[test]
    fn exec_bm_caps_model_block_and_keeps_workers_busy() {
        let workers = crate::util::threads::num_threads().max(1);
        // Both the narrow and the wide lane grains obey the same law.
        for mr in [MR, MAX_MR] {
            for m in [1usize, 7, 96, 128, 1024, 5000] {
                let e = exec_bm(m, 128, mr);
                assert!(e >= mr && e <= 128 && e % mr == 0, "m={m} mr={mr} e={e}");
                if m >= workers * 128 {
                    // Large m keeps the model block and every worker busy.
                    assert_eq!(e, 128, "m={m} mr={mr}");
                    assert!(m.div_ceil(e) >= workers, "m={m} mr={mr} e={e}");
                }
            }
            // Tiny m degrades to the mr panel grain, never below.
            assert_eq!(exec_bm(1, 128, mr), mr);
        }
    }

    #[test]
    fn sgemm_blocked_bit_identical_to_exact_within_one_k_block() {
        // For k <= b_k every cell is one FP32 chain in k order — exactly
        // the reference accumulation. Bitwise equality with the exact
        // kernel additionally requires the reference's per-step rounding
        // (multiply then add), i.e. the scalar lane; on FMA lanes the
        // chain is the same but each step rounds once, so the comparison
        // relaxes to the fused-rounding envelope. tests/dispatch.rs pins
        // the bitwise claim under a *forced* scalar lane in a process
        // where forcing cannot race other tests.
        let bk = host_block().bk;
        let lane = kernels::active_lane();
        let mut rng = Rng::new(50);
        for (m, k, n) in [(5, 1, 3), (33, 65, 17), (64, bk.min(96), 40)] {
            if k > bk {
                continue; // bit-identity only claimed within one k block
            }
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            let exact = sgemm(&a, &b);
            let blocked = sgemm_blocked(&a, &b);
            if lane == kernels::Lane::Scalar {
                for (x, y) in exact.as_slice().iter().zip(blocked.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            } else {
                let abs_p = dgemm_of_f32(&a.map(f32::abs), &b.map(f32::abs));
                for i in 0..m {
                    for j in 0..n {
                        let (x, y) = (exact.get(i, j) as f64, blocked.get(i, j) as f64);
                        let tol = 4.0 * k as f64 * f32::EPSILON as f64 * abs_p.get(i, j) + 1e-30;
                        assert!((x - y).abs() <= tol, "({i},{j}) lane {lane}: {x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_kernels_match_reference_accuracy_class() {
        let mut rng = Rng::new(51);
        let a = Matrix::random_symmetric(96, 300, 0, &mut rng);
        let b = Matrix::random_symmetric(300, 72, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e = |c: &Matrix<f32>| relative_error(&c_ref, &c.to_f64());
        let e_s = e(&sgemm_blocked(&a, &b));
        let e_h = e(&hgemm_blocked(&a, &b));
        let e_c = e(&cube_gemm_blocked(&a, &b, SplitConfig::default()));
        assert!(e_s < 1e-6, "sgemm_blocked {e_s}");
        assert!((1e-5..1e-3).contains(&e_h), "hgemm_blocked {e_h}");
        assert!(e_c < 1e-6, "cube_gemm_blocked {e_c}");
        assert!(e_c < e_h / 50.0, "cube {e_c} vs hgemm {e_h}");
        // Within multi-accumulator noise of the exact kernels.
        let x_s = e(&sgemm(&a, &b));
        let x_c = e(&cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise));
        let x_h = e(&hgemm(&a, &b, AccumulateMode::Fp32Rn));
        assert!(e_s <= x_s.max(1e-8) * 2.0, "sgemm {e_s} vs exact {x_s}");
        assert!(e_c <= x_c.max(1e-8) * 2.0, "cube {e_c} vs exact {x_c}");
        assert!(e_h <= x_h * 2.0, "hgemm {e_h} vs exact {x_h}");
    }

    #[test]
    fn cube_blocked_exact_for_fp16_exact_inputs() {
        let a = Matrix::from_vec(2, 2, vec![1.5f32, -2.0, 0.25, 8.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0f32, 0.5, -1.0, 2.0]);
        let c = cube_gemm_blocked(&a, &b, SplitConfig::default());
        let r = dgemm_of_f32(&a, &b);
        for (x, y) in c.as_slice().iter().zip(r.as_slice().iter()) {
            assert_eq!(*x as f64, *y);
        }
    }

    #[test]
    fn prepacked_paths_bit_identical_to_blocked() {
        let mut rng = Rng::new(52);
        // Serving-like shapes (small m, wide weight) plus awkward edges.
        for (m, k, n) in [(1, 17, 9), (8, 96, 40), (33, 65, 24)] {
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);

            let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
            let (x, y) = (sgemm_blocked(&a, &b), gemm_prepacked(&a, &pp));
            for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
                assert_eq!(u.to_bits(), v.to_bits(), "fp32 {m}x{k}x{n}");
            }

            let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp16);
            let (x, y) = (hgemm_blocked(&a, &b), gemm_prepacked(&a, &pp));
            for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
                assert_eq!(u.to_bits(), v.to_bits(), "fp16 {m}x{k}x{n}");
            }

            for s_b in [12, 8] {
                let cfg = SplitConfig::with_scale(s_b);
                let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(cfg));
                let (x, y) = (cube_gemm_blocked(&a, &b, cfg), cube_gemm_prepacked(&a, &pp));
                for (u, v) in x.as_slice().iter().zip(y.as_slice()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "cube s_b={s_b} {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn prepacked_overlapped_bit_identical_at_every_depth_and_schedule() {
        // Awkward edges, including multiple k blocks (several prefetched
        // A stripes per column block); the random-shape sweep lives in
        // tests/properties.rs (prop_prepacked_prefetch_bit_identical).
        let bk = host_block().bk;
        let mut rng = Rng::new(56);
        for (m, k, n) in [(1, 1, 1), (5, 2 * bk + 3, 9), (33, 65, 24)] {
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            let paths = [
                PrepackPath::Fp32,
                PrepackPath::Fp16,
                PrepackPath::Cube(SplitConfig::with_scale(12)),
            ];
            for path in paths {
                let pp = PrepackedMatrix::prepack(&b, path);
                let want = gemm_prepacked(&a, &pp);
                let check = |got: &Matrix<f32>, what: &str| {
                    for (x, y) in want.as_slice().iter().zip(got.as_slice()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{what} {path:?} {m}x{k}x{n}");
                    }
                };
                check(&gemm_prepacked_overlapped(&a, &pp), "overlapped");
                for depth in [1usize, 2, 3] {
                    check(&gemm_prepacked_overlapped_ab(&a, &pp, depth), "ab");
                }
                for schedule in Schedule::ALL {
                    check(&gemm_prepacked_scheduled(&a, &pp, schedule, 2), schedule.name());
                }
            }
        }
    }

    #[test]
    fn prepacked_staged_driver_is_kernel_only_on_the_critical_path() {
        let mut rng = Rng::new(57);
        let a = Matrix::random_symmetric(20, 70, 0, &mut rng);
        let b = Matrix::random_symmetric(70, 30, 0, &mut rng);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Cube(SplitConfig::default()));
        let want = gemm_prepacked(&a, &pp);
        let (c, st, stats) = gemm_prepacked_overlapped_staged(&a, &pp, 2);
        for (x, y) in c.as_slice().iter().zip(want.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // B panels come prepacked: pack-B can never reach the critical
        // path — it is structurally zero, not merely small.
        assert_eq!(st.pack_b, 0.0);
        // One ring job per k block (each stripe packed exactly once),
        // and the only critical-path A-staging time is inline fallback
        // packs plus ring stalls (zero when the ring kept up).
        assert_eq!(stats.prefetched + stats.inline_packs, pp.k_blocks());
        assert_eq!(st.pack_a, stats.inline_pack_s + stats.wait_s);
        if stats.inline_packs == 0 && stats.wait_s == 0.0 {
            assert_eq!(st.pack_a, 0.0, "kernel-only consumption must show zero pack stages");
        }
        assert!(st.kernel > 0.0);
        assert_eq!(st.c_update, 0.0);
    }

    #[test]
    fn prepacked_overlapped_path_mismatch_panics() {
        let b = Matrix::zeros(4, 4);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp16);
        let a = Matrix::zeros(2, 4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cube_gemm_prepacked_overlapped_ab(&a, &pp, 2)
        }));
        assert!(r.is_err());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sgemm_prepacked_overlapped_ab(&a, &pp, 2)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn prepacked_path_mismatch_panics() {
        let b = Matrix::zeros(4, 4);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
        let a = Matrix::zeros(2, 4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cube_gemm_prepacked(&a, &pp)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn split_config_mismatch_panics() {
        let a = Matrix::zeros(4, 4);
        let asp = WideSplit::of(&a, SplitConfig::with_scale(12));
        let bsp = WideSplit::of(&a, SplitConfig::with_scale(6));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cube_gemm_blocked_split(&asp, &bsp)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn degenerate_shapes() {
        let a: Matrix<f32> = Matrix::zeros(0, 5);
        let b: Matrix<f32> = Matrix::zeros(5, 4);
        assert_eq!(sgemm_blocked(&a, &b).shape(), (0, 4));
        let a: Matrix<f32> = Matrix::zeros(3, 0);
        let b: Matrix<f32> = Matrix::zeros(0, 2);
        let c = sgemm_blocked(&a, &b);
        assert_eq!(c.shape(), (3, 2));
        assert!(c.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn overlapped_bit_identical_to_serial() {
        // The full random-shape sweep lives in tests/properties.rs; this
        // pins the invariant at module level on awkward edges, including
        // multiple k blocks (several prefetched panels per column).
        let bk = host_block().bk;
        let mut rng = Rng::new(53);
        for (m, k, n) in [(1, 1, 1), (5, 2 * bk + 3, 9), (33, 65, 24)] {
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            let pairs = [
                (sgemm_blocked(&a, &b), sgemm_blocked_overlapped(&a, &b)),
                (hgemm_blocked(&a, &b), hgemm_blocked_overlapped(&a, &b)),
            ];
            for (serial, over) in &pairs {
                for (x, y) in serial.as_slice().iter().zip(over.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{m}x{k}x{n}");
                }
            }
            let cfg = SplitConfig::default();
            let serial = cube_gemm_blocked(&a, &b, cfg);
            let over = cube_gemm_blocked_overlapped(&a, &b, cfg);
            for (x, y) in serial.as_slice().iter().zip(over.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "cube {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn ab_overlapped_bit_identical_to_serial_at_every_depth() {
        // The full random-shape sweep lives in tests/properties.rs
        // (prop_ab_prefetch_bit_identical_to_serial_blocked); this pins
        // the invariant at module level on awkward edges, including
        // multiple k blocks (several prefetched A stripes per column).
        let bk = host_block().bk;
        let mut rng = Rng::new(55);
        for depth in [1usize, 2, 3] {
            for (m, k, n) in [(1, 1, 1), (5, 2 * bk + 3, 9), (33, 65, 24)] {
                let a = Matrix::random_symmetric(m, k, 0, &mut rng);
                let b = Matrix::random_symmetric(k, n, 0, &mut rng);
                let pairs = [
                    (sgemm_blocked(&a, &b), sgemm_blocked_overlapped_ab(&a, &b, depth)),
                    (hgemm_blocked(&a, &b), hgemm_blocked_overlapped_ab(&a, &b, depth)),
                ];
                for (serial, ab) in &pairs {
                    for (x, y) in serial.as_slice().iter().zip(ab.as_slice()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "depth {depth} {m}x{k}x{n}");
                    }
                }
                let cfg = SplitConfig::default();
                let serial = cube_gemm_blocked(&a, &b, cfg);
                let ab = cube_gemm_blocked_overlapped_ab(&a, &b, cfg, depth);
                for (x, y) in serial.as_slice().iter().zip(ab.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "cube depth {depth} {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn staged_drivers_bit_identical_with_full_breakdown() {
        let mut rng = Rng::new(54);
        let a = Matrix::random_symmetric(20, 70, 0, &mut rng);
        let b = Matrix::random_symmetric(70, 30, 0, &mut rng);
        let (c, st) = sgemm_blocked_staged(&a, &b);
        let serial = sgemm_blocked(&a, &b);
        for (x, y) in c.as_slice().iter().zip(serial.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(st.total() > 0.0);
        let cfg = SplitConfig::default();
        let (c, st) = cube_gemm_blocked_staged(&a, &b, cfg);
        let serial = cube_gemm_blocked(&a, &b, cfg);
        for (x, y) in c.as_slice().iter().zip(serial.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(st.transfer() > 0.0, "pack-B span must be accounted: {st:?}");
        assert!(st.compute() > 0.0);
    }

    #[test]
    fn family_fp16x2_is_the_cube_engine() {
        // The N = 2 FP16 spec must reproduce today's cube engine exactly:
        // the split entry routes onto it structurally, and the *generic*
        // family path (exercised through a Family-prepacked operand) packs
        // bit-equal panels, dispatches ncomp == 2 to the cube kernel, and
        // combines with the same `hh + corr·inv_sf` shape.
        let mut rng = Rng::new(58);
        for s_b in [12u32, 8] {
            let cfg = SplitConfig::with_scale(s_b as i32);
            let spec = SplitSpec::fp16x2(cfg);
            for (m, k, n) in [(5, 17, 9), (33, 65, 24)] {
                let a = Matrix::random_symmetric(m, k, 0, &mut rng);
                let b = Matrix::random_symmetric(k, n, 0, &mut rng);
                let want = cube_gemm_blocked(&a, &b, cfg);
                let via_family = family_gemm_blocked(&a, &b, spec);
                for (x, y) in want.as_slice().iter().zip(via_family.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "entry s_b={s_b} {m}x{k}x{n}");
                }
                let pp = PrepackedMatrix::prepack(&b, PrepackPath::Family(spec));
                let generic = family_gemm_prepacked(&a, &pp);
                for (x, y) in want.as_slice().iter().zip(generic.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "generic s_b={s_b} {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn family_schedules_and_prepacked_bit_identical() {
        let bk = host_block().bk;
        let mut rng = Rng::new(59);
        let specs = [
            SplitSpec::bf16x2(),
            SplitSpec::bf16x3(),
            SplitSpec { format: ComponentFormat::Fp16Scaled(SplitConfig::default()), components: 3 },
        ];
        for spec in specs {
            for (m, k, n) in [(1, 1, 1), (5, 2 * bk + 3, 9), (33, 65, 24)] {
                let a = Matrix::random_symmetric(m, k, 0, &mut rng);
                let b = Matrix::random_symmetric(k, n, 0, &mut rng);
                let want = family_gemm_blocked(&a, &b, spec);
                let check = |got: &Matrix<f32>, what: &str| {
                    for (x, y) in want.as_slice().iter().zip(got.as_slice()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{what} {spec:?} {m}x{k}x{n}");
                    }
                };
                check(&family_gemm_blocked_overlapped(&a, &b, spec), "overlapped");
                let pp = PrepackedMatrix::prepack(&b, PrepackPath::Family(spec));
                check(&family_gemm_prepacked(&a, &pp), "prepacked");
                for depth in [1usize, 2, 3] {
                    check(&family_gemm_blocked_overlapped_ab(&a, &b, spec, depth), "ab");
                    check(&family_gemm_prepacked_overlapped_ab(&a, &pp, depth), "prepacked-ab");
                }
                check(&gemm_prepacked(&a, &pp), "dispatched");
                for schedule in Schedule::ALL {
                    check(&gemm_prepacked_scheduled(&a, &pp, schedule, 2), schedule.name());
                }
            }
        }
    }

    #[test]
    fn family_accuracy_ladder_bf16() {
        // BF16×3 keeps six kept terms / three planes and must land far
        // inside BF16×2's error; the full per-tier bound table lives in
        // tests/accuracy.rs.
        let mut rng = Rng::new(60);
        let a = Matrix::random_symmetric(48, 200, 0, &mut rng);
        let b = Matrix::random_symmetric(200, 40, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e2 = relative_error(&c_ref, &family_gemm_blocked(&a, &b, SplitSpec::bf16x2()).to_f64());
        let e3 = relative_error(&c_ref, &family_gemm_blocked(&a, &b, SplitSpec::bf16x3()).to_f64());
        assert!(e3 < e2 / 20.0, "bf16x3 {e3} vs bf16x2 {e2}");
        assert!(e3 < 1e-6, "bf16x3 {e3}");
    }

    #[test]
    fn family_prepacked_path_mismatch_panics() {
        let b = Matrix::zeros(4, 4);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
        let a = Matrix::zeros(2, 4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            family_gemm_prepacked(&a, &pp)
        }));
        assert!(r.is_err());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            family_gemm_prepacked_overlapped_ab(&a, &pp, 2)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn family_degenerate_shapes() {
        let a: Matrix<f32> = Matrix::zeros(0, 5);
        let b: Matrix<f32> = Matrix::zeros(5, 4);
        assert_eq!(family_gemm_blocked(&a, &b, SplitSpec::bf16x3()).shape(), (0, 4));
        let a: Matrix<f32> = Matrix::zeros(3, 0);
        let b: Matrix<f32> = Matrix::zeros(0, 2);
        let c = family_gemm_blocked_overlapped(&a, &b, SplitSpec::bf16x2());
        assert_eq!(c.shape(), (3, 2));
        assert!(c.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn overlapped_degenerate_shapes() {
        let a: Matrix<f32> = Matrix::zeros(0, 5);
        let b: Matrix<f32> = Matrix::zeros(5, 4);
        assert_eq!(sgemm_blocked_overlapped(&a, &b).shape(), (0, 4));
        let a: Matrix<f32> = Matrix::zeros(3, 0);
        let b: Matrix<f32> = Matrix::zeros(0, 2);
        let c = cube_gemm_blocked_overlapped(&a, &b, SplitConfig::default());
        assert_eq!(c.shape(), (3, 2));
        assert!(c.as_slice().iter().all(|&v| v == 0.0));
    }
}
