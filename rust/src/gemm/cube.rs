//! SGEMM-cube: the paper's precision-recovery GEMM (Eq. 7).
//!
//! Each FP32 operand matrix is split into an FP16 high component and a
//! scaled FP16 residual (see [`crate::softfloat::split`]); the product is
//! reconstructed from the three dominant terms
//!
//! ```text
//! C ≈ A_h·B_h  +  A_h·R_B/s_f  +  R_A·B_h/s_f        (R_A·R_B/s_f² omitted)
//! ```
//!
//! each computed by the FP16 "Cube" datapath (exact FP16×FP16 products,
//! FP32 accumulation — see [`crate::gemm::hgemm`]).
//!
//! Two accumulation orders (Sec. 4.4, Fig. 3):
//! * **Elementwise** — one FP32 running sum per output element combines
//!   all three terms inside the k loop; sensitive to the magnitude gap
//!   between the high product and the corrections.
//! * **Termwise** — the three term matrices accumulate independently;
//!   the two correction terms are summed first, then added to the
//!   high-high product. This aggregates small-magnitude contributions
//!   before they meet the large term, improving stability in
//!   low-exponent regimes.

use crate::softfloat::split::{SplitConfig, SplitMatrix};
use crate::util::mat::Matrix;
use crate::util::threads::parallel_chunks;

/// Accumulation order of the three-term reconstruction (Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Accumulation {
    /// Combine all three expansion terms per element inside the k loop.
    Elementwise,
    /// Accumulate each term matrix independently; sum corrections first.
    #[default]
    Termwise,
}

/// Split operands in the widened representation used by the compute
/// kernels: FP16 values stored exactly as f32 (so products/sums execute
/// on the f32 datapath exactly as the Cube would).
pub struct WideSplit {
    /// FP16 high component, widened exactly to f32.
    pub high: Matrix<f32>,
    /// Scaled FP16 residual component, widened exactly to f32.
    pub low: Matrix<f32>,
    /// The split configuration (residual scaling exponent) used.
    pub cfg: SplitConfig,
}

impl WideSplit {
    /// Split every element of `m` under `cfg` and widen to f32.
    pub fn of(m: &Matrix<f32>, cfg: SplitConfig) -> WideSplit {
        let sm = SplitMatrix::from_f32(m, cfg);
        WideSplit {
            high: sm.high.map(|h| h.to_f32()),
            low: sm.low.map(|l| l.to_f32()),
            cfg,
        }
    }
}

/// SGEMM-cube over pre-split operands.
pub fn cube_gemm_split(a: &WideSplit, b: &WideSplit, acc: Accumulation) -> Matrix<f32> {
    assert_eq!(
        a.cfg, b.cfg,
        "operands must be split with the same configuration"
    );
    let (m, k) = a.high.shape();
    let (kb, n) = b.high.shape();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let inv_sf = 1.0f32 / a.cfg.scale_factor();

    // Pack B components transposed for contiguous inner loops.
    let bh_t = b.high.transpose();
    let bl_t = b.low.transpose();

    let mut c = Matrix::zeros(m, n);
    let cp = crate::util::threads::SendPtr(c.as_mut_slice().as_mut_ptr());

    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let ah = a.high.row(i);
            let al = a.low.row(i);
            for j in 0..n {
                let bh = bh_t.row(j);
                let bl = bl_t.row(j);
                let out = match acc {
                    Accumulation::Elementwise => {
                        // Single running sum mixing the large high-high
                        // products with the scaled corrections.
                        let mut s = 0.0f32;
                        for t in 0..k {
                            let hh = ah[t] * bh[t];
                            let hl = ah[t] * bl[t];
                            let lh = al[t] * bh[t];
                            s += hh;
                            s += (hl + lh) * inv_sf;
                        }
                        s
                    }
                    Accumulation::Termwise => {
                        // Three independent FP32 accumulators — exactly
                        // what three separate Cube GEMM passes produce.
                        let mut s_hh = 0.0f32;
                        let mut s_hl = 0.0f32;
                        let mut s_lh = 0.0f32;
                        for t in 0..k {
                            s_hh += ah[t] * bh[t];
                            s_hl += ah[t] * bl[t];
                            s_lh += al[t] * bh[t];
                        }
                        // Corrections aggregate first (small + small),
                        // then meet the high-order product once.
                        s_hh + (s_hl + s_lh) * inv_sf
                    }
                };
                // SAFETY: row chunks are disjoint across threads.
                unsafe { *cp.0.add(i * n + j) = out };
            }
        }
    });
    c
}

/// Convenience wrapper: split FP32 operands and run SGEMM-cube.
pub fn cube_gemm(
    a: &Matrix<f32>,
    b: &Matrix<f32>,
    cfg: SplitConfig,
    acc: Accumulation,
) -> Matrix<f32> {
    let asp = WideSplit::of(a, cfg);
    let bsp = WideSplit::of(b, cfg);
    cube_gemm_split(&asp, &bsp, acc)
}

/// Four-term variant **including** the low·low product the paper omits
/// (Sec. 4.3: "typically negligible ... can be safely omitted").
/// Exists for the ablation quantifying that claim: it costs a fourth
/// GEMM pass (4/3× the decomposition cost) for whatever accuracy the
/// `R_A·R_B / s_f²` term recovers.
pub fn cube_gemm_four_term(a: &Matrix<f32>, b: &Matrix<f32>, cfg: SplitConfig) -> Matrix<f32> {
    let asp = WideSplit::of(a, cfg);
    let bsp = WideSplit::of(b, cfg);
    let (m, k) = asp.high.shape();
    let n = bsp.high.cols();
    let inv_sf = 1.0f32 / cfg.scale_factor();
    let inv_sf2 = inv_sf * inv_sf;
    let bh_t = bsp.high.transpose();
    let bl_t = bsp.low.transpose();
    let mut c = Matrix::zeros(m, n);
    let cp = crate::util::threads::SendPtr(c.as_mut_slice().as_mut_ptr());
    // Shares the row-parallel driver with the other kernels; per-row
    // arithmetic (four independent term chains) is unchanged, so results
    // are bit-identical to the previous serial loop.
    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let ah = asp.high.row(i);
            let al = asp.low.row(i);
            for j in 0..n {
                let bh = bh_t.row(j);
                let bl = bl_t.row(j);
                let mut s_hh = 0.0f32;
                let mut s_hl = 0.0f32;
                let mut s_lh = 0.0f32;
                let mut s_ll = 0.0f32;
                for t in 0..k {
                    s_hh += ah[t] * bh[t];
                    s_hl += ah[t] * bl[t];
                    s_lh += al[t] * bh[t];
                    s_ll += al[t] * bl[t];
                }
                // SAFETY: row chunks are disjoint across threads.
                unsafe { *cp.0.add(i * n + j) = s_hh + (s_hl + s_lh) * inv_sf + s_ll * inv_sf2 };
            }
        }
    });
    c
}

/// RZ-conversion variant (Markidis-style, Table 2): identical three-term
/// structure but round-toward-zero operand splitting — reproduces the
/// systematic ~2-bit loss of truncation-based prior work.
pub fn cube_gemm_rz(a: &Matrix<f32>, b: &Matrix<f32>, scale_exp: i32) -> Matrix<f32> {
    let cfg = SplitConfig {
        scale_exp,
        rounding: crate::softfloat::f16::Rounding::TowardZero,
        ..SplitConfig::default()
    };
    cube_gemm(a, b, cfg, Accumulation::Termwise)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::gemm::hgemm::{hgemm, AccumulateMode};
    use crate::gemm::sgemm::sgemm;
    use crate::util::rng::Rng;

    fn err_of(c_ref: &Matrix<f64>, c: &Matrix<f32>) -> f64 {
        relative_error(c_ref, &c.to_f64())
    }

    #[test]
    fn recovers_far_beyond_hgemm() {
        // Paper Fig. 8: cube (s_b = 12) improves 1–2 orders of magnitude
        // over HGEMM and approaches SGEMM.
        let mut rng = Rng::new(10);
        let a = Matrix::random_symmetric(96, 96, 0, &mut rng);
        let b = Matrix::random_symmetric(96, 96, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let cfg = SplitConfig::default();
        let e_cube = err_of(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise));
        let e_h = err_of(&c_ref, &hgemm(&a, &b, AccumulateMode::Fp32Rn));
        let e_s = err_of(&c_ref, &sgemm(&a, &b));
        assert!(e_cube < e_h / 50.0, "cube={e_cube} hgemm={e_h}");
        assert!(e_cube < e_s * 10.0, "cube={e_cube} sgemm={e_s}");
    }

    #[test]
    fn elementwise_and_termwise_agree_without_scaling_missing() {
        // Both orders compute the same three terms; results are close
        // (not bit-identical) at moderate exponents.
        let mut rng = Rng::new(11);
        let a = Matrix::random_symmetric(48, 64, 0, &mut rng);
        let b = Matrix::random_symmetric(64, 48, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let cfg = SplitConfig::default();
        let e_el = err_of(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Elementwise));
        let e_tw = err_of(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise));
        assert!(e_el < 5e-7, "elementwise err={e_el}");
        assert!(e_tw < 5e-7, "termwise err={e_tw}");
    }

    #[test]
    fn termwise_wins_at_large_k() {
        // Paper Fig. 9(b,c): increasing k stresses summation stability;
        // termwise consistently beats elementwise.
        let mut rng = Rng::new(12);
        let k = 4096;
        let a = Matrix::random_nonneg(16, k, 0, &mut rng);
        let b = Matrix::random_nonneg(k, 16, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let cfg = SplitConfig::default();
        let e_el = err_of(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Elementwise));
        let e_tw = err_of(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise));
        assert!(e_tw <= e_el, "termwise={e_tw} elementwise={e_el}");
    }

    #[test]
    fn scaling_required_at_low_exponents() {
        // Paper Fig. 8: s_b = 0 trails FP32 SGEMM at negative exponents;
        // s_b = 12 restores it.
        let mut rng = Rng::new(13);
        let e = -10;
        let a = Matrix::random_symmetric(64, 64, e, &mut rng);
        let b = Matrix::random_symmetric(64, 64, e, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e0 = err_of(&c_ref, &cube_gemm(&a, &b, SplitConfig::with_scale(0), Accumulation::Termwise));
        let e12 = err_of(&c_ref, &cube_gemm(&a, &b, SplitConfig::with_scale(12), Accumulation::Termwise));
        assert!(e12 < e0 / 10.0, "s_b=12 err={e12}, s_b=0 err={e0}");
    }

    #[test]
    fn split_config_mismatch_panics() {
        let a = Matrix::zeros(4, 4);
        let b = Matrix::zeros(4, 4);
        let asp = WideSplit::of(&a, SplitConfig::with_scale(12));
        let bsp = WideSplit::of(&b, SplitConfig::with_scale(6));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cube_gemm_split(&asp, &bsp, Accumulation::Termwise)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn low_low_term_is_negligible() {
        // Sec. 4.3 ablation: the omitted R_A·R_B/s_f² term changes the
        // result by less than the three-term error itself.
        let mut rng = Rng::new(14);
        let a = Matrix::random_symmetric(64, 96, 0, &mut rng);
        let b = Matrix::random_symmetric(96, 64, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let cfg = SplitConfig::default();
        let e3 = err_of(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise));
        let e4 = err_of(&c_ref, &cube_gemm_four_term(&a, &b, cfg));
        // Four-term is not substantially better: the omission is safe.
        assert!(e3 < e4 * 4.0, "three-term {e3} vs four-term {e4}");
        assert!(e3 < 5e-7 && e4 < 5e-7);
    }

    #[test]
    fn rz_split_costs_about_two_bits() {
        // Table 2: truncation-based splitting (Markidis et al.) loses
        // ~2 bits relative to RN splitting.
        let mut rng = Rng::new(15);
        let a = Matrix::random_symmetric(96, 96, 0, &mut rng);
        let b = Matrix::random_symmetric(96, 96, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e_rn = err_of(&c_ref, &cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise));
        let e_rz = err_of(&c_ref, &cube_gemm_rz(&a, &b, 12));
        let bits_lost = (e_rz / e_rn).log2();
        assert!(bits_lost > 0.7, "RZ should lose ≥ ~1 bit, lost {bits_lost:.2}");
        assert!(bits_lost < 4.0, "RZ loss implausibly large: {bits_lost:.2}");
    }

    #[test]
    fn exact_for_fp16_exact_inputs() {
        // If inputs are exactly FP16-representable and sums stay exact,
        // cube GEMM is exact.
        let a = Matrix::from_vec(2, 2, vec![1.5f32, -2.0, 0.25, 8.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0f32, 0.5, -1.0, 2.0]);
        let c = cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise);
        let r = dgemm_of_f32(&a, &b);
        for (x, y) in c.as_slice().iter().zip(r.as_slice().iter()) {
            assert_eq!(*x as f64, *y);
        }
    }
}
