//! BGEMM-cube: the paper's future-work extension to another
//! low-precision matrix engine — a two-component **BF16** split with the
//! same three-dominant-term reconstruction.
//!
//! Where it differs from the FP16 scheme:
//!
//! * **No residual scaling** and **no range limitation**: BF16 carries
//!   FP32's 8-bit exponent, so both components represent any normal f32
//!   magnitude. The Eq. (6) scaling rules — and the policy's FP32
//!   fallbacks — become unnecessary.
//! * **Lower accuracy ceiling**: 2×8 significand bits recover ≈ 16
//!   mantissa bits (vs ≈ 22 for FP16+scaling), matching the trade
//!   Ootomo & Yokota made with their TF32 full-range fallback.
//!
//! BF16×BF16 products are exact in FP32 (8+8 ≤ 24), so the widened-f32
//! execution below is bit-faithful to a BF16 matrix engine with FP32
//! accumulation.
//!
//! Since the precision-family generalization, this module is a thin
//! veneer: [`bf16_cube_gemm`] *is* the family engine's `bf16x2` tier
//! ([`crate::gemm::blocked::family_gemm_blocked`] with
//! [`SplitSpec::bf16x2`]) — packed panels, the fused N-term
//! micro-kernel, the worker pool, every host schedule and the prepacked
//! serving path all come for free. The pre-family flat
//! `parallel_chunks` loop survives only as the `#[cfg(test)]` oracle
//! pinning the engine's accumulation order (its split type, `BfSplit`,
//! is replaced by [`crate::softfloat::family::FamilySplit`]).

use crate::gemm::blocked::family_gemm_blocked;
use crate::softfloat::family::SplitSpec;
use crate::util::mat::Matrix;

/// `C ≈ A_h·B_h + A_h·B_l + A_l·B_h` over BF16 components (termwise
/// accumulation; the low·low term is omitted as in Eq. 7).
///
/// Serves the `bf16x2` tier through the blocked family engine — one k
/// chain per output cell per k block on the active kernel lane; for
/// `k ≤ b_k` on the scalar lane this is bit-identical to the flat
/// termwise loop it replaced (pinned by `oracle_matches_engine_*`
/// below and by the lane-forced test in `tests/dispatch.rs`).
pub fn bf16_cube_gemm(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match");
    family_gemm_blocked(a, b, SplitSpec::bf16x2())
}

/// Direct one-pass BF16 GEMM (the "native BF16" baseline).
pub fn bgemm(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match");
    let ah = a.map(|v| crate::softfloat::bf16::Bf16::from_f32_rn(v).to_f32());
    let bh = b.map(|v| crate::softfloat::bf16::Bf16::from_f32_rn(v).to_f32());
    crate::gemm::sgemm::sgemm(&ah, &bh)
}

/// The pre-family flat termwise loop, kept verbatim as the oracle the
/// engine's BF16×2 tier is measured against: one `s_hh` and one
/// `s_corr` FP32 chain per cell over the full k extent, rounded
/// multiply-then-add per step — the scalar lane's accumulation
/// contract.
#[cfg(test)]
pub(crate) fn bf16_cube_gemm_oracle(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    use crate::softfloat::family::FamilySplit;
    use crate::util::threads::{parallel_chunks, SendPtr};
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match");
    let asp = FamilySplit::of(a, SplitSpec::bf16x2());
    let bsp = FamilySplit::of(b, SplitSpec::bf16x2());
    let (m, k) = asp.shape();
    let n = bsp.shape().1;
    let bh_t = bsp.comp(0).transpose();
    let bl_t = bsp.comp(1).transpose();

    let mut c = Matrix::zeros(m, n);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let ah = asp.comp(0).row(i);
            let al = asp.comp(1).row(i);
            for j in 0..n {
                let bh = bh_t.row(j);
                let bl = bl_t.row(j);
                let mut s_hh = 0.0f32;
                let mut s_corr = 0.0f32;
                for t in 0..k {
                    s_hh += ah[t] * bh[t];
                    s_corr += ah[t] * bl[t] + al[t] * bh[t];
                }
                // SAFETY: disjoint row chunks.
                unsafe { *cp.0.add(i * n + j) = s_hh + s_corr };
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cube::{cube_gemm, Accumulation};
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::gemm::kernels;
    use crate::softfloat::split::SplitConfig;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_about_16_bits_at_moderate_range() {
        let mut rng = Rng::new(1);
        let a = Matrix::random_symmetric(96, 96, 0, &mut rng);
        let b = Matrix::random_symmetric(96, 96, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e_bf = relative_error(&c_ref, &bf16_cube_gemm(&a, &b).to_f64());
        let e_b1 = relative_error(&c_ref, &bgemm(&a, &b).to_f64());
        // Two-component bf16: ~1e-5 class; single bf16: ~1e-2 class.
        assert!(e_bf < 1e-4, "bf16-cube {e_bf}");
        assert!(e_bf < e_b1 / 50.0, "bf16-cube {e_bf} vs bgemm {e_b1}");
    }

    #[test]
    fn fp16_cube_beats_bf16_cube_inside_the_window() {
        // Inside the FP16 window the FP16 scheme is ~6 bits better.
        let mut rng = Rng::new(2);
        let a = Matrix::random_symmetric(64, 64, 0, &mut rng);
        let b = Matrix::random_symmetric(64, 64, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e_fp16 = relative_error(
            &c_ref,
            &cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise).to_f64(),
        );
        let e_bf16 = relative_error(&c_ref, &bf16_cube_gemm(&a, &b).to_f64());
        assert!(e_fp16 < e_bf16 / 8.0, "fp16 {e_fp16} vs bf16 {e_bf16}");
    }

    #[test]
    fn bf16_cube_works_across_the_full_exponent_range() {
        // The extension's point: accuracy holds where the FP16 scheme
        // cannot represent the inputs at all. (Bounded by FP32's own
        // product range: e_a + e_b must stay below ~127, which binds any
        // FP32-accumulating engine equally.)
        let mut rng = Rng::new(3);
        for e in [-55, -20, 18, 40, 60] {
            let a = Matrix::from_fn(24, 24, |_, _| rng.f32_with_exponent(e));
            let b = Matrix::from_fn(24, 24, |_, _| rng.f32_with_exponent(e));
            let c_ref = dgemm_of_f32(&a, &b);
            let err = relative_error(&c_ref, &bf16_cube_gemm(&a, &b).to_f64());
            assert!(err < 1e-4, "e={e} err={err}");
            // FP16 cube either overflows (inf/NaN) or collapses here.
            let fp16 = cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise);
            let e16 = relative_error(&c_ref, &fp16.to_f64());
            assert!(
                !e16.is_finite() || e16 > err * 10.0,
                "e={e}: fp16 cube unexpectedly fine ({e16} vs bf16 {err})"
            );
        }
    }

    #[test]
    fn exact_for_bf16_exact_inputs() {
        let a = Matrix::from_vec(2, 2, vec![1.5f32, -2.0, 0.25, 8.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0f32, 0.5, -1.0, 2.0]);
        let c = bf16_cube_gemm(&a, &b);
        let r = dgemm_of_f32(&a, &b);
        for (x, y) in c.as_slice().iter().zip(r.as_slice().iter()) {
            assert_eq!(*x as f64, *y);
        }
    }

    #[test]
    fn oracle_matches_engine_accumulation() {
        // For k within one k block the engine runs one s_hh-style chain
        // and one merged correction chain per cell — the oracle's exact
        // structure. On the scalar lane (rounded multiply-then-add, the
        // oracle's arithmetic) that makes the match bitwise; FMA lanes
        // fuse each step into one rounding, so the comparison relaxes to
        // the fused-rounding envelope. tests/dispatch.rs pins the
        // bitwise claim under a *forced* scalar lane.
        let bk = crate::gemm::blocked::host_block().bk;
        let lane = kernels::active_lane();
        let mut rng = Rng::new(4);
        for (m, k, n) in [(5, 9, 7), (33, bk.min(65), 24)] {
            let a = Matrix::random_symmetric(m, k, 0, &mut rng);
            let b = Matrix::random_symmetric(k, n, 0, &mut rng);
            let want = bf16_cube_gemm_oracle(&a, &b);
            let got = bf16_cube_gemm(&a, &b);
            if lane == kernels::Lane::Scalar {
                for (x, y) in want.as_slice().iter().zip(got.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{m}x{k}x{n}");
                }
            } else {
                let abs_p = dgemm_of_f32(&a.map(f32::abs), &b.map(f32::abs));
                for i in 0..m {
                    for j in 0..n {
                        let (x, y) = (want.get(i, j) as f64, got.get(i, j) as f64);
                        let tol = 8.0 * k as f64 * f32::EPSILON as f64 * abs_p.get(i, j) + 1e-30;
                        assert!((x - y).abs() <= tol, "({i},{j}) lane {lane}: {x} vs {y}");
                    }
                }
            }
        }
    }
}
