//! FP32 GEMM with plain FP32 running-sum accumulation — the software
//! baseline the paper compares against ("FP32 OpenBLAS SGEMM").
//!
//! The accuracy-relevant property is the accumulation order: a single
//! FP32 running sum per output element, adding products in k order. The
//! blocked variant changes the *memory* schedule but deliberately keeps
//! that accumulation semantics so both give bit-identical results.

use crate::util::mat::Matrix;
use crate::util::threads::parallel_chunks;

/// `C = A (m×k) · B (k×n)` in FP32 with FP32 accumulation.
pub fn sgemm(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let bt = b.transpose();
    let mut c = Matrix::zeros(m, n);

    let cp = crate::util::threads::SendPtr(c.as_mut_slice().as_mut_ptr());

    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let arow = a.row(i);
            for j in 0..n {
                let bcol = bt.row(j);
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(bcol.iter()) {
                    acc += x * y;
                }
                // SAFETY: row chunks are disjoint across threads.
                unsafe { *cp.0.add(i * n + j) = acc };
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 3, vec![1.0f32, 0.0, 2.0, -1.0, 3.0, 1.0]);
        let b = Matrix::from_vec(3, 2, vec![3.0f32, 1.0, 2.0, 1.0, 1.0, 0.0]);
        let c = sgemm(&a, &b);
        assert_eq!(c.as_slice(), &[5.0, 1.0, 4.0, 2.0]);
    }

    #[test]
    fn close_to_f64_reference() {
        let mut rng = Rng::new(2);
        let a = Matrix::random_symmetric(33, 65, 0, &mut rng);
        let b = Matrix::random_symmetric(65, 17, 0, &mut rng);
        let c = sgemm(&a, &b);
        let c_ref = dgemm_of_f32(&a, &b);
        let err = relative_error(&c_ref, &c.to_f64());
        assert!(err < 1e-6, "err={err}");
        assert!(err > 0.0, "fp32 should not be exact at k=65");
    }

    #[test]
    fn accumulation_is_plain_running_sum() {
        // Verify bit-exact against an explicit scalar loop.
        let mut rng = Rng::new(3);
        let a = Matrix::random_symmetric(4, 9, 0, &mut rng);
        let b = Matrix::random_symmetric(9, 4, 0, &mut rng);
        let c = sgemm(&a, &b);
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = 0.0f32;
                for t in 0..9 {
                    acc += a.get(i, t) * b.get(t, j);
                }
                assert_eq!(c.get(i, j).to_bits(), acc.to_bits());
            }
        }
    }
}
