//! Prepacked B operands: the split + panel-pack work of the blocked
//! engine ([`crate::gemm::blocked`]) paid once per weight matrix.
//!
//! The serving workload the coordinator targets is dominated by
//! repeated GEMMs against a *stable* B operand (a weight matrix) with a
//! small, changing A (a batch of activations, often `m ≤ 32`). On that
//! shape the per-request cost of the blocked path is not the micro-kernel
//! — it is preparing B: the FP32→2×FP16 split runs one software-f16
//! conversion pair per element of B (`softfloat::split`), and
//! `pack::pack_b_dual` rewrites the whole `k × n` panel set, all `O(k·n)`
//! work that is independent of `m` and identical across requests.
//!
//! [`PrepackedMatrix`] snapshots exactly the bytes the blocked loop nest
//! consumes — one packed panel buffer per `(column block, k block)` of
//! the `b_n → b_k` nest, in the same block geometry
//! ([`crate::gemm::blocked::host_block`]) and the same panel layout
//! ([`crate::gemm::pack`]) — so
//! [`crate::gemm::blocked::gemm_prepacked`] replays the identical
//! traversal over cached panels and its output is **bit-identical** to
//! the pack-on-the-fly path for the same scaling parameters.
//!
//! Four formats, one per precision path the policy can choose
//! ([`PrepackPath`]): plain FP32 panels, FP16-rounded panels (widened to
//! f32, the Cube operand convention), dual high/low split panels for
//! SGEMM-cube, and `N`-component panels for the precision-emulation
//! family tiers (BF16×2, BF16×3, …). The split configuration/spec is
//! part of the format — a weight prepacked at `s_b = 12` cannot serve a
//! request decided at `s_b = 8` — and so is the **kernel lane**: panels
//! are interleaved with the micro-tile dims of the lane active at
//! prepack time ([`crate::gemm::kernels::Lane::tile_dims`] — the
//! AVX-512 lane's wide 8×16 interleave is not consumable by a narrow
//! lane or vice versa), recorded in the operand
//! ([`PrepackedMatrix::lane`]) so every consuming sweep replays the
//! matching geometry. The serving cache ([`crate::gemm::cache`])
//! therefore keys on the scaling parameters **and the lane** as well as
//! the shape and path.
//!
//! Consumption is schedule-agnostic: the panel bytes here feed the
//! serial prepacked nest and the A-stripe prefetch pipeline alike
//! ([`crate::gemm::blocked::gemm_prepacked_scheduled`] threads the
//! host [`crate::gemm::backend::Schedule`] knob through), and every
//! schedule is bit-identical because the panels are immutable after
//! [`PrepackedMatrix::prepack`] and all schedules run the same shared
//! sweeps. The panel grid accessors ([`PrepackedMatrix::k_blocks`],
//! [`PrepackedMatrix::n_blocks`]) expose the geometry the pipeline's
//! job list must replay.

use crate::gemm::blocked::host_block;
use crate::gemm::cube::WideSplit;
use crate::gemm::kernels::{self, Lane};
use crate::gemm::pack;
use crate::sim::blocking::BlockConfig;
use crate::softfloat::f16::F16;
use crate::softfloat::family::{FamilySplit, SplitSpec};
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;

/// Which precision path a [`PrepackedMatrix`] was prepared for. Mirrors
/// the hot-path dispatch of [`crate::gemm::backend::GemmBackend::gemm`]:
/// both cube accumulation orders execute through the same fused blocked
/// kernel, so they share the [`PrepackPath::Cube`] format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrepackPath {
    /// Plain FP32 panels (`pack_b`).
    Fp32,
    /// FP16-rounded values widened to f32 (`pack_b` over the converted
    /// matrix) — what [`crate::gemm::blocked::hgemm_blocked`] feeds the
    /// single-component kernel.
    Fp16,
    /// Dual high/low split panels (`pack_b_dual`) for the fused
    /// three-term cube kernel, split with this configuration.
    Cube(SplitConfig),
    /// Multi-component panels (`pack_b_multi`) for the generic N-term
    /// family kernel, split under this [`SplitSpec`] — the BF16 tiers
    /// and N ≥ 3 cascades. (The fp16×2 spec also packs here when
    /// requested explicitly; its panels are bit-compatible with
    /// [`PrepackPath::Cube`]'s at N = 2, but the serving policy prefers
    /// the dedicated cube path for cache sharing.)
    Family(SplitSpec),
}

/// A B operand with the blocked engine's split + pack work already done:
/// the packed panel buffers for every `(column block, k block)` of the
/// `b_n → b_k` loop nest.
#[derive(Debug, Clone)]
pub struct PrepackedMatrix {
    k: usize,
    n: usize,
    bk: usize,
    bn: usize,
    path: PrepackPath,
    /// The kernel lane whose tile dims the panels were interleaved for
    /// (resolved once at prepack time).
    lane: Lane,
    /// Panel buffer for column block `jb`, k block `pb` at index
    /// `jb * k_blocks + pb`; contents are exactly what `pack_b` /
    /// `pack_b_dual` produce for that block at [`Self::lane`]'s dims.
    panels: Vec<Vec<f32>>,
    k_blocks: usize,
}

impl PrepackedMatrix {
    /// Prepack `b` for `path` with the engine's model-selected host
    /// block ([`host_block`]) — the geometry [`gemm_prepacked`] replays.
    ///
    /// [`gemm_prepacked`]: crate::gemm::blocked::gemm_prepacked
    pub fn prepack(b: &Matrix<f32>, path: PrepackPath) -> PrepackedMatrix {
        PrepackedMatrix::prepack_with_block(b, path, host_block())
    }

    /// Prepack with an explicit block geometry (tests and tools; the
    /// serving path always uses [`host_block`] so cached panels match
    /// the executing nest).
    pub fn prepack_with_block(
        b: &Matrix<f32>,
        path: PrepackPath,
        block: BlockConfig,
    ) -> PrepackedMatrix {
        let (k, n) = b.shape();
        let (bk, bn) = (block.bk, block.bn);
        // Panel interleave follows the lane active *now*; consumers must
        // replay the same geometry, so it is recorded in the operand.
        let lane = kernels::active_lane();
        let nr = lane.tile_dims().1;
        let k_blocks = k.div_ceil(bk);
        let n_blocks = n.div_ceil(bn);
        let mut panels = Vec::with_capacity(k_blocks * n_blocks);
        // Converted/split form of B, shared across every block.
        let converted;
        let split;
        let family;
        #[derive(Clone, Copy)]
        enum Src<'a> {
            Single(&'a Matrix<f32>),
            Dual(&'a WideSplit),
            Multi(&'a FamilySplit),
        }
        let src = match path {
            PrepackPath::Fp32 => Src::Single(b),
            PrepackPath::Fp16 => {
                converted = b.map(|v| F16::from_f32_rn(v).to_f32());
                Src::Single(&converted)
            }
            PrepackPath::Cube(cfg) => {
                split = WideSplit::of(b, cfg);
                Src::Dual(&split)
            }
            PrepackPath::Family(spec) => {
                family = FamilySplit::of(b, spec);
                Src::Multi(&family)
            }
        };
        for j0 in (0..n).step_by(bn) {
            let nc = bn.min(n - j0);
            for p0 in (0..k).step_by(bk) {
                let kc = bk.min(k - p0);
                let mut out = Vec::new();
                match src {
                    Src::Single(m) => pack::pack_b(m, p0, kc, j0, nc, nr, &mut out),
                    Src::Dual(sp) => {
                        pack::pack_b_dual(&sp.high, &sp.low, p0, kc, j0, nc, nr, &mut out)
                    }
                    Src::Multi(fs) => {
                        pack::pack_b_multi(fs.comps(), p0, kc, j0, nc, nr, &mut out)
                    }
                }
                panels.push(out);
            }
        }
        PrepackedMatrix { k, n, bk, bn, path, lane, panels, k_blocks }
    }

    /// Inner (k) dimension of the original matrix.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output (n) dimension of the original matrix.
    pub fn n(&self) -> usize {
        self.n
    }

    /// k-block size the panels were packed with.
    pub fn bk(&self) -> usize {
        self.bk
    }

    /// Column-block size the panels were packed with.
    pub fn bn(&self) -> usize {
        self.bn
    }

    /// Number of k blocks in the packed panel grid
    /// (`ceil(k / bk)`; 0 when `k == 0`).
    pub fn k_blocks(&self) -> usize {
        self.k_blocks
    }

    /// Number of column blocks in the packed panel grid
    /// (`ceil(n / bn)`; 0 when `n == 0` or `k == 0`).
    pub fn n_blocks(&self) -> usize {
        self.panels.len() / self.k_blocks.max(1)
    }

    /// The precision path this operand was prepared for.
    pub fn path(&self) -> PrepackPath {
        self.path
    }

    /// The kernel lane the panels were interleaved for. The panel bytes
    /// are only consumable with this lane's micro-tile geometry
    /// ([`Lane::tile_dims`]); every prepacked sweep resolves its pack
    /// and dispatch lane from here rather than from the lane active at
    /// execution time.
    pub fn lane(&self) -> Lane {
        self.lane
    }

    /// Packed panel buffer for column block `jb`, k block `pb`.
    #[inline]
    pub fn panel(&self, jb: usize, pb: usize) -> &[f32] {
        &self.panels[jb * self.k_blocks + pb]
    }

    /// Resident size in bytes (panel buffers + bookkeeping) — what the
    /// serving cache charges against its capacity.
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<PrepackedMatrix>()
            + self
                .panels
                .iter()
                .map(|p| p.capacity() * std::mem::size_of::<f32>())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn panels_match_on_the_fly_packing() {
        let mut rng = Rng::new(7);
        let b = Matrix::random_symmetric(70, 37, 0, &mut rng);
        let block = BlockConfig::new(16, 32, 16);
        let pp = PrepackedMatrix::prepack_with_block(&b, PrepackPath::Fp32, block);
        assert_eq!(pp.k(), 70);
        assert_eq!(pp.n(), 37);
        // The recorded lane is whatever was active at prepack time, and
        // the panels follow its interleave.
        assert_eq!(pp.lane(), kernels::active_lane());
        let nr = pp.lane().tile_dims().1;
        // 70 / bk=32 → 3 k blocks; 37 / bn=16 → 3 column blocks.
        assert_eq!(pp.k_blocks(), 3);
        assert_eq!(pp.n_blocks(), 3);
        let mut out = Vec::new();
        for (jb, j0) in (0..37).step_by(block.bn).enumerate() {
            let nc = block.bn.min(37 - j0);
            for (pb, p0) in (0..70).step_by(block.bk).enumerate() {
                let kc = block.bk.min(70 - p0);
                pack::pack_b(&b, p0, kc, j0, nc, nr, &mut out);
                assert_eq!(pp.panel(jb, pb), &out[..], "block ({jb}, {pb})");
            }
        }
    }

    #[test]
    fn cube_panels_match_dual_packing_of_split() {
        let mut rng = Rng::new(8);
        let b = Matrix::random_symmetric(40, 24, 0, &mut rng);
        let cfg = SplitConfig::default();
        let block = BlockConfig::new(16, 32, 16);
        let pp = PrepackedMatrix::prepack_with_block(&b, PrepackPath::Cube(cfg), block);
        assert_eq!(pp.path(), PrepackPath::Cube(cfg));
        let sp = WideSplit::of(&b, cfg);
        let nr = pp.lane().tile_dims().1;
        let mut out = Vec::new();
        pack::pack_b_dual(&sp.high, &sp.low, 0, 32, 0, 16, nr, &mut out);
        assert_eq!(pp.panel(0, 0), &out[..]);
        pack::pack_b_dual(&sp.high, &sp.low, 32, 8, 16, 8, nr, &mut out);
        assert_eq!(pp.panel(1, 1), &out[..]);
    }

    #[test]
    fn family_panels_match_multi_packing_of_split() {
        let mut rng = Rng::new(10);
        let b = Matrix::random_symmetric(40, 24, 0, &mut rng);
        let spec = SplitSpec::bf16x3();
        let block = BlockConfig::new(16, 32, 16);
        let pp = PrepackedMatrix::prepack_with_block(&b, PrepackPath::Family(spec), block);
        assert_eq!(pp.path(), PrepackPath::Family(spec));
        let fs = FamilySplit::of(&b, spec);
        let nr = pp.lane().tile_dims().1;
        let mut out = Vec::new();
        pack::pack_b_multi(fs.comps(), 0, 32, 0, 16, nr, &mut out);
        assert_eq!(pp.panel(0, 0), &out[..]);
        pack::pack_b_multi(fs.comps(), 32, 8, 16, 8, nr, &mut out);
        assert_eq!(pp.panel(1, 1), &out[..]);
    }

    #[test]
    fn bytes_accounts_for_panel_storage() {
        let mut rng = Rng::new(9);
        let b = Matrix::random_symmetric(32, 32, 0, &mut rng);
        let single = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
        let dual = PrepackedMatrix::prepack(&b, PrepackPath::Cube(SplitConfig::default()));
        assert!(single.bytes() >= 32 * 32 * 4);
        // Dual panels carry both components.
        assert!(dual.bytes() > single.bytes());
    }

    #[test]
    fn degenerate_shapes_produce_no_panels() {
        let b: Matrix<f32> = Matrix::zeros(0, 5);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp32);
        assert_eq!(pp.k(), 0);
        assert_eq!(pp.n(), 5);
        assert_eq!(pp.k_blocks(), 0);
        assert_eq!(pp.n_blocks(), 0);
        let b: Matrix<f32> = Matrix::zeros(5, 0);
        let pp = PrepackedMatrix::prepack(&b, PrepackPath::Fp16);
        assert_eq!(pp.n(), 0);
        assert_eq!(pp.n_blocks(), 0);
        assert!(pp.bytes() < 1024);
    }
}
