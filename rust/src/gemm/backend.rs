//! Dynamic GEMM backend selection.
//!
//! The coordinator and the end-to-end examples switch between precision
//! paths at runtime; `Backend` names them and [`GemmBackend`] executes
//! them with one call signature.

use crate::gemm::cube::{cube_gemm, Accumulation};
use crate::gemm::hgemm::{hgemm, AccumulateMode};
use crate::gemm::sgemm::sgemm;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;

/// The precision paths the system can serve. (`Hash`: the prepacked
/// serving cache keys on the path, see [`crate::gemm::cache`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Plain FP32 GEMM (software baseline).
    Fp32,
    /// Direct FP16 Cube GEMM (fastest, ~11-bit accuracy).
    Fp16,
    /// SGEMM-cube with elementwise accumulation.
    CubeElementwise,
    /// SGEMM-cube with termwise accumulation (the paper's default).
    CubeTermwise,
}

impl Backend {
    pub const ALL: [Backend; 4] = [
        Backend::Fp32,
        Backend::Fp16,
        Backend::CubeElementwise,
        Backend::CubeTermwise,
    ];

    /// Stable identifier used by the CLI/config layer.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Fp32 => "fp32",
            Backend::Fp16 => "fp16",
            Backend::CubeElementwise => "cube-elementwise",
            Backend::CubeTermwise => "cube-termwise",
        }
    }

    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "fp32" => Some(Backend::Fp32),
            "fp16" => Some(Backend::Fp16),
            "cube-elementwise" | "cube-el" => Some(Backend::CubeElementwise),
            "cube-termwise" | "cube" | "cube-tw" => Some(Backend::CubeTermwise),
            _ => None,
        }
    }

    /// Number of Cube GEMM passes this backend issues per logical GEMM —
    /// the basis of the paper's "FP32-equivalent peak = FP16 peak / 3"
    /// convention (Table 2 note).
    pub fn cube_passes(self) -> u32 {
        match self {
            Backend::Fp32 => 0,
            Backend::Fp16 => 1,
            Backend::CubeElementwise | Backend::CubeTermwise => 3,
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Executable GEMM backend with its numeric configuration.
#[derive(Debug, Clone)]
pub struct GemmBackend {
    pub backend: Backend,
    pub split: SplitConfig,
    pub accumulate: AccumulateMode,
    /// Hot-path mode (default): the cache-blocked packed engine
    /// (`crate::gemm::fast` → `crate::gemm::blocked`) — panel packing,
    /// register micro-kernels and the fused three-term cube pass, with
    /// block sizes from `crate::sim::blocking` on the host cache model.
    /// Set `false` for the bit-faithful single-chain accumulation order
    /// the accuracy experiments study.
    pub fast: bool,
    /// Run the hot path through the overlapped (double-buffered) b_k
    /// pipeline (`crate::gemm::overlap`): the next B panel is packed by
    /// a prefetch worker while the current one is consumed. Results are
    /// bit-identical; defaults to the `SGEMM_CUBE_OVERLAP` env toggle.
    pub overlap: bool,
}

impl GemmBackend {
    pub fn new(backend: Backend) -> GemmBackend {
        GemmBackend {
            backend,
            split: SplitConfig::default(),
            accumulate: AccumulateMode::Fp32Rn,
            fast: true,
            overlap: crate::gemm::overlap::overlap_enabled(),
        }
    }

    pub fn with_scale(mut self, s_b: i32) -> GemmBackend {
        self.split.scale_exp = s_b;
        self
    }

    /// Select the overlapped (prefetching) schedule for the hot path.
    pub fn with_overlap(mut self, overlap: bool) -> GemmBackend {
        self.overlap = overlap;
        self
    }

    /// Bit-faithful single-chain accumulation (experiment semantics).
    pub fn exact(mut self) -> GemmBackend {
        self.fast = false;
        self
    }

    /// `C = A · B` through the selected precision path.
    pub fn gemm(&self, a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
        use crate::gemm::blocked;
        if self.fast && self.accumulate == AccumulateMode::Fp32Rn {
            // The elementwise/termwise distinction is an accuracy-
            // experiment concern; the hot path serves the paper's
            // default (termwise) structure through the blocked fused
            // three-term kernel — serial or overlapped schedule, same
            // bits either way.
            return match (self.backend, self.overlap) {
                (Backend::Fp32, false) => blocked::sgemm_blocked(a, b),
                (Backend::Fp32, true) => blocked::sgemm_blocked_overlapped(a, b),
                (Backend::Fp16, false) => blocked::hgemm_blocked(a, b),
                (Backend::Fp16, true) => blocked::hgemm_blocked_overlapped(a, b),
                (Backend::CubeElementwise | Backend::CubeTermwise, false) => {
                    blocked::cube_gemm_blocked(a, b, self.split)
                }
                (Backend::CubeElementwise | Backend::CubeTermwise, true) => {
                    blocked::cube_gemm_blocked_overlapped(a, b, self.split)
                }
            };
        }
        match self.backend {
            Backend::Fp32 => sgemm(a, b),
            Backend::Fp16 => hgemm(a, b, self.accumulate),
            Backend::CubeElementwise => cube_gemm(a, b, self.split, Accumulation::Elementwise),
            Backend::CubeTermwise => cube_gemm(a, b, self.split, Accumulation::Termwise),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    #[test]
    fn name_parse_roundtrip() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("cube"), Some(Backend::CubeTermwise));
        assert_eq!(Backend::parse("nope"), None);
    }

    #[test]
    fn cube_passes_convention() {
        assert_eq!(Backend::Fp32.cube_passes(), 0);
        assert_eq!(Backend::Fp16.cube_passes(), 1);
        assert_eq!(Backend::CubeTermwise.cube_passes(), 3);
    }

    #[test]
    fn accuracy_ordering_across_backends() {
        let mut rng = Rng::new(20);
        let a = Matrix::random_symmetric(64, 96, 0, &mut rng);
        let b = Matrix::random_symmetric(96, 64, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let err = |bk: Backend| {
            let c = GemmBackend::new(bk).gemm(&a, &b);
            relative_error(&c_ref, &c.to_f64())
        };
        let e16 = err(Backend::Fp16);
        let e32 = err(Backend::Fp32);
        let ecube = err(Backend::CubeTermwise);
        assert!(ecube < e16, "cube {ecube} vs fp16 {e16}");
        assert!(e32 < e16);
        // Cube approaches fp32 accuracy (within an order of magnitude).
        assert!(ecube < e32 * 10.0, "cube {ecube} vs fp32 {e32}");
    }

    #[test]
    fn with_scale_applies() {
        let g = GemmBackend::new(Backend::CubeTermwise).with_scale(6);
        assert_eq!(g.split.scale_exp, 6);
    }

    #[test]
    fn overlap_schedule_is_bit_identical_per_backend() {
        let mut rng = Rng::new(21);
        let a = Matrix::random_symmetric(17, 50, 0, &mut rng);
        let b = Matrix::random_symmetric(50, 23, 0, &mut rng);
        for bk in Backend::ALL {
            let serial = GemmBackend::new(bk).with_overlap(false).gemm(&a, &b);
            let over = GemmBackend::new(bk).with_overlap(true).gemm(&a, &b);
            for (x, y) in serial.as_slice().iter().zip(over.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{bk}");
            }
        }
    }
}
