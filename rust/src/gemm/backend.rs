//! Dynamic GEMM backend selection.
//!
//! The coordinator and the end-to-end examples switch between precision
//! paths at runtime; `Backend` names them and [`GemmBackend`] executes
//! them with one call signature.

use crate::exec::pipeline::DEFAULT_PIPELINE_DEPTH;
use crate::gemm::cube::{cube_gemm, Accumulation};
use crate::gemm::hgemm::{hgemm, AccumulateMode};
use crate::gemm::prepacked::PrepackedMatrix;
use crate::gemm::sgemm::sgemm;
use crate::softfloat::family::SplitSpec;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;

/// The precision paths the system can serve. (`Hash`: the prepacked
/// serving cache keys on the path, see [`crate::gemm::cache`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Plain FP32 GEMM (software baseline).
    Fp32,
    /// Direct FP16 Cube GEMM (fastest, ~11-bit accuracy).
    Fp16,
    /// SGEMM-cube with elementwise accumulation.
    CubeElementwise,
    /// SGEMM-cube with termwise accumulation (the paper's default).
    CubeTermwise,
    /// BF16×2 precision-family tier: two unscaled BF16 components,
    /// ≈ 16 recovered bits over the **full** f32 exponent range (no
    /// Eq. (6) window limit).
    Bf16x2,
    /// BF16×3 precision-family tier: three unscaled BF16 components,
    /// ≈ 24 recovered bits (meets/exceeds FP32 storage accuracy) over
    /// the full range — the Ozaki-style "exceeds FP32" point.
    Bf16x3,
}

impl Backend {
    /// Every precision path, in report order.
    pub const ALL: [Backend; 6] = [
        Backend::Fp32,
        Backend::Fp16,
        Backend::CubeElementwise,
        Backend::CubeTermwise,
        Backend::Bf16x2,
        Backend::Bf16x3,
    ];

    /// Stable identifier used by the CLI/config layer.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Fp32 => "fp32",
            Backend::Fp16 => "fp16",
            Backend::CubeElementwise => "cube-elementwise",
            Backend::CubeTermwise => "cube-termwise",
            Backend::Bf16x2 => "bf16x2",
            Backend::Bf16x3 => "bf16x3",
        }
    }

    /// Parse a CLI/config backend name (accepts the short aliases).
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "fp32" => Some(Backend::Fp32),
            "fp16" => Some(Backend::Fp16),
            "cube-elementwise" | "cube-el" => Some(Backend::CubeElementwise),
            "cube-termwise" | "cube" | "cube-tw" => Some(Backend::CubeTermwise),
            "bf16x2" => Some(Backend::Bf16x2),
            "bf16x3" => Some(Backend::Bf16x3),
            _ => None,
        }
    }

    /// Number of Cube GEMM passes this backend issues per logical GEMM —
    /// the basis of the paper's "FP32-equivalent peak = FP16 peak / 3"
    /// convention (Table 2 note). For the family tiers this is the kept
    /// cross-term count `N(N+1)/2` ([`SplitSpec::passes`]).
    pub fn cube_passes(self) -> u32 {
        match self {
            Backend::Fp32 => 0,
            Backend::Fp16 => 1,
            Backend::CubeElementwise | Backend::CubeTermwise => 3,
            Backend::Bf16x2 => 3,
            Backend::Bf16x3 => 6,
        }
    }

    /// The family [`SplitSpec`] this backend executes through, when it
    /// is an N-component tier served by the generic family engine
    /// (`None` for the dedicated fp32/fp16/cube paths).
    pub fn family_spec(self) -> Option<SplitSpec> {
        match self {
            Backend::Bf16x2 => Some(SplitSpec::bf16x2()),
            Backend::Bf16x3 => Some(SplitSpec::bf16x3()),
            _ => None,
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Host execution schedule of the blocked engine's panel loop. Every
/// schedule produces **bit-identical** results (same pack routines,
/// same block order, same shared sweeps) — this knob only selects how
/// much operand movement is hidden behind compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Schedule {
    /// Pack-then-sweep on the critical path (the serial nest).
    Serial,
    /// Double-buffered B-panel prefetch: the next `(j, k)` B panel is
    /// packed by a pool prefetch job while the sweeps consume the
    /// current one (the paper's Fig. 7 B stream).
    OverlapB,
    /// A+B dual-panel prefetch: the next block's B panel **and** A
    /// row-block stripe are packed ahead through a depth-configurable
    /// ring ([`crate::exec::pipeline`]); the consuming sweeps run
    /// kernel-only.
    OverlapAB,
}

impl Schedule {
    /// Every schedule, in increasing pipeline depth.
    pub const ALL: [Schedule; 3] = [Schedule::Serial, Schedule::OverlapB, Schedule::OverlapAB];

    /// Stable identifier used by the CLI/config layer.
    pub fn name(self) -> &'static str {
        match self {
            Schedule::Serial => "serial",
            Schedule::OverlapB => "overlap-b",
            Schedule::OverlapAB => "overlap-ab",
        }
    }

    /// Parse a CLI/config schedule name (accepts the short aliases).
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "serial" => Some(Schedule::Serial),
            "overlap-b" | "overlap" => Some(Schedule::OverlapB),
            "overlap-ab" | "ab" => Some(Schedule::OverlapAB),
            _ => None,
        }
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The process default schedule, resolved **once**: the
/// `SGEMM_CUBE_SCHEDULE` env knob (`serial` / `overlap-b` /
/// `overlap-ab`) when set to a recognized value, else the legacy
/// `SGEMM_CUBE_OVERLAP` boolean toggle mapped to
/// [`Schedule::OverlapB`], else [`Schedule::Serial`].
pub fn default_schedule() -> Schedule {
    static DEFAULT: std::sync::OnceLock<Schedule> = std::sync::OnceLock::new();
    *DEFAULT.get_or_init(|| {
        let fallback = || {
            if crate::gemm::overlap::overlap_enabled() {
                Schedule::OverlapB
            } else {
                Schedule::Serial
            }
        };
        match std::env::var("SGEMM_CUBE_SCHEDULE") {
            Ok(v) => match Schedule::parse(v.trim()) {
                Some(s) => s,
                None => {
                    // Unlike the config-file path (which hard-errors),
                    // an env typo cannot abort every binary that links
                    // the engine — but it must not fail silently either.
                    eprintln!(
                        "warning: SGEMM_CUBE_SCHEDULE={v:?} not recognized \
                         (expected serial, overlap-b or overlap-ab); using the default schedule"
                    );
                    fallback()
                }
            },
            Err(_) => fallback(),
        }
    })
}

/// Executable GEMM backend with its numeric configuration.
#[derive(Debug, Clone)]
pub struct GemmBackend {
    /// The precision path to execute.
    pub backend: Backend,
    /// Two-component split configuration for the cube paths.
    pub split: SplitConfig,
    /// FP16-path accumulation mode (RN vs. the Tensor-Core RZ model).
    pub accumulate: AccumulateMode,
    /// Hot-path mode (default): the cache-blocked packed engine
    /// (`crate::gemm::fast` → `crate::gemm::blocked`) — panel packing,
    /// register micro-kernels and the fused three-term cube pass, with
    /// block sizes from `crate::sim::blocking` on the host cache model.
    /// Set `false` for the bit-faithful single-chain accumulation order
    /// the accuracy experiments study.
    pub fast: bool,
    /// Host schedule of the hot path (serial / overlapped-B /
    /// overlapped-AB; bit-identical results either way). Defaults to
    /// [`default_schedule`] (`SGEMM_CUBE_SCHEDULE` /
    /// `SGEMM_CUBE_OVERLAP` env knobs).
    pub schedule: Schedule,
    /// Prefetch-ring depth for [`Schedule::OverlapAB`] (clamped into
    /// `[1, MAX_PIPELINE_DEPTH]` by the pipeline; depth 2 = classic
    /// double buffer).
    pub pipeline_depth: usize,
}

impl GemmBackend {
    /// A backend on the hot path with default split/accumulation and the
    /// process-default schedule.
    pub fn new(backend: Backend) -> GemmBackend {
        GemmBackend {
            backend,
            split: SplitConfig::default(),
            accumulate: AccumulateMode::Fp32Rn,
            fast: true,
            schedule: default_schedule(),
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
        }
    }

    /// Builder: set the residual scaling exponent `s_b` for cube paths.
    pub fn with_scale(mut self, s_b: i32) -> GemmBackend {
        self.split.scale_exp = s_b;
        self
    }

    /// Legacy boolean schedule selector: `true` = overlapped-B
    /// prefetch, `false` = serial. Kept for the PR-3 call sites;
    /// [`GemmBackend::with_schedule`] is the full knob.
    pub fn with_overlap(mut self, overlap: bool) -> GemmBackend {
        self.schedule = if overlap { Schedule::OverlapB } else { Schedule::Serial };
        self
    }

    /// Select the host execution schedule for the hot path.
    pub fn with_schedule(mut self, schedule: Schedule) -> GemmBackend {
        self.schedule = schedule;
        self
    }

    /// Prefetch-ring depth used by [`Schedule::OverlapAB`].
    pub fn with_pipeline_depth(mut self, depth: usize) -> GemmBackend {
        self.pipeline_depth = depth;
        self
    }

    /// Bit-faithful single-chain accumulation (experiment semantics).
    pub fn exact(mut self) -> GemmBackend {
        self.fast = false;
        self
    }

    /// `C = A · B` through the selected precision path.
    pub fn gemm(&self, a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
        use crate::gemm::blocked;
        if self.fast && self.accumulate == AccumulateMode::Fp32Rn {
            // The elementwise/termwise distinction is an accuracy-
            // experiment concern; the hot path serves the paper's
            // default (termwise) structure through the blocked fused
            // three-term kernel — any schedule, same bits either way.
            let d = self.pipeline_depth;
            return match (self.backend, self.schedule) {
                (Backend::Fp32, Schedule::Serial) => blocked::sgemm_blocked(a, b),
                (Backend::Fp32, Schedule::OverlapB) => blocked::sgemm_blocked_overlapped(a, b),
                (Backend::Fp32, Schedule::OverlapAB) => {
                    blocked::sgemm_blocked_overlapped_ab(a, b, d)
                }
                (Backend::Fp16, Schedule::Serial) => blocked::hgemm_blocked(a, b),
                (Backend::Fp16, Schedule::OverlapB) => blocked::hgemm_blocked_overlapped(a, b),
                (Backend::Fp16, Schedule::OverlapAB) => {
                    blocked::hgemm_blocked_overlapped_ab(a, b, d)
                }
                (Backend::CubeElementwise | Backend::CubeTermwise, Schedule::Serial) => {
                    blocked::cube_gemm_blocked(a, b, self.split)
                }
                (Backend::CubeElementwise | Backend::CubeTermwise, Schedule::OverlapB) => {
                    blocked::cube_gemm_blocked_overlapped(a, b, self.split)
                }
                (Backend::CubeElementwise | Backend::CubeTermwise, Schedule::OverlapAB) => {
                    blocked::cube_gemm_blocked_overlapped_ab(a, b, self.split, d)
                }
                (Backend::Bf16x2 | Backend::Bf16x3, schedule) => {
                    let spec = self.backend.family_spec().expect("bf16 tier has a family spec");
                    match schedule {
                        Schedule::Serial => blocked::family_gemm_blocked(a, b, spec),
                        Schedule::OverlapB => blocked::family_gemm_blocked_overlapped(a, b, spec),
                        Schedule::OverlapAB => {
                            blocked::family_gemm_blocked_overlapped_ab(a, b, spec, d)
                        }
                    }
                }
            };
        }
        match self.backend {
            Backend::Fp32 => sgemm(a, b),
            Backend::Fp16 => hgemm(a, b, self.accumulate),
            Backend::CubeElementwise => cube_gemm(a, b, self.split, Accumulation::Elementwise),
            Backend::CubeTermwise => cube_gemm(a, b, self.split, Accumulation::Termwise),
            Backend::Bf16x2 | Backend::Bf16x3 => {
                // The family tiers have no separate order-faithful
                // reference kernel: the N-term engine's serial nest *is*
                // their definition (gemm::bfcube keeps a flat-loop
                // oracle under #[cfg(test)] for the BF16×2 tier).
                let spec = self.backend.family_spec().expect("bf16 tier has a family spec");
                crate::gemm::blocked::family_gemm_blocked(a, b, spec)
            }
        }
    }

    /// `C = A · B` against a prepacked B operand, under this backend's
    /// host schedule and pipeline depth — the serving tier's unified
    /// dispatch ([`crate::gemm::blocked::gemm_prepacked_scheduled`]).
    /// The packed panels fix the precision path and the numerics at
    /// prepack time, so the result is independent of `self.backend` /
    /// `self.split` / `self.fast` and **bit-identical** across
    /// schedules and to the pack-on-the-fly entry point the operand
    /// was prepared for (prepacked operands always execute through the
    /// blocked engine — they *are* its panel format).
    pub fn gemm_prepacked(&self, a: &Matrix<f32>, b: &PrepackedMatrix) -> Matrix<f32> {
        crate::gemm::blocked::gemm_prepacked_scheduled(a, b, self.schedule, self.pipeline_depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    #[test]
    fn name_parse_roundtrip() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("cube"), Some(Backend::CubeTermwise));
        assert_eq!(Backend::parse("nope"), None);
    }

    #[test]
    fn cube_passes_convention() {
        assert_eq!(Backend::Fp32.cube_passes(), 0);
        assert_eq!(Backend::Fp16.cube_passes(), 1);
        assert_eq!(Backend::CubeTermwise.cube_passes(), 3);
        // Family tiers: N(N+1)/2 kept cross terms.
        assert_eq!(Backend::Bf16x2.cube_passes(), 3);
        assert_eq!(Backend::Bf16x3.cube_passes(), 6);
    }

    #[test]
    fn family_spec_maps_tiers_only() {
        assert_eq!(Backend::Bf16x2.family_spec(), Some(SplitSpec::bf16x2()));
        assert_eq!(Backend::Bf16x3.family_spec(), Some(SplitSpec::bf16x3()));
        for bk in [Backend::Fp32, Backend::Fp16, Backend::CubeElementwise, Backend::CubeTermwise] {
            assert_eq!(bk.family_spec(), None, "{bk}");
        }
        for bk in Backend::ALL {
            if let Some(spec) = bk.family_spec() {
                assert_eq!(spec.passes() as u32, bk.cube_passes(), "{bk}");
                assert_eq!(spec.name(), bk.name(), "{bk}");
            }
        }
    }

    #[test]
    fn accuracy_ordering_across_backends() {
        let mut rng = Rng::new(20);
        let a = Matrix::random_symmetric(64, 96, 0, &mut rng);
        let b = Matrix::random_symmetric(96, 64, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let err = |bk: Backend| {
            let c = GemmBackend::new(bk).gemm(&a, &b);
            relative_error(&c_ref, &c.to_f64())
        };
        let e16 = err(Backend::Fp16);
        let e32 = err(Backend::Fp32);
        let ecube = err(Backend::CubeTermwise);
        assert!(ecube < e16, "cube {ecube} vs fp16 {e16}");
        assert!(e32 < e16);
        // Cube approaches fp32 accuracy (within an order of magnitude).
        assert!(ecube < e32 * 10.0, "cube {ecube} vs fp32 {e32}");
    }

    #[test]
    fn with_scale_applies() {
        let g = GemmBackend::new(Backend::CubeTermwise).with_scale(6);
        assert_eq!(g.split.scale_exp, 6);
    }

    #[test]
    fn overlap_schedule_is_bit_identical_per_backend() {
        let mut rng = Rng::new(21);
        let a = Matrix::random_symmetric(17, 50, 0, &mut rng);
        let b = Matrix::random_symmetric(50, 23, 0, &mut rng);
        for bk in Backend::ALL {
            let serial = GemmBackend::new(bk).with_overlap(false).gemm(&a, &b);
            let over = GemmBackend::new(bk).with_overlap(true).gemm(&a, &b);
            for (x, y) in serial.as_slice().iter().zip(over.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{bk}");
            }
        }
    }

    #[test]
    fn every_schedule_is_bit_identical_per_backend() {
        let mut rng = Rng::new(22);
        let a = Matrix::random_symmetric(19, 140, 0, &mut rng);
        let b = Matrix::random_symmetric(140, 21, 0, &mut rng);
        for bk in Backend::ALL {
            let serial = GemmBackend::new(bk).with_schedule(Schedule::Serial).gemm(&a, &b);
            for schedule in Schedule::ALL {
                for depth in [1usize, 3] {
                    let c = GemmBackend::new(bk)
                        .with_schedule(schedule)
                        .with_pipeline_depth(depth)
                        .gemm(&a, &b);
                    for (x, y) in serial.as_slice().iter().zip(c.as_slice()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{bk} {schedule} depth {depth}");
                    }
                }
            }
        }
    }

    #[test]
    fn prepacked_dispatch_is_bit_identical_across_schedules() {
        use crate::gemm::blocked::gemm_prepacked;
        use crate::gemm::prepacked::PrepackPath;
        let mut rng = Rng::new(23);
        let a = Matrix::random_symmetric(9, 90, 0, &mut rng);
        let b = Matrix::random_symmetric(90, 21, 0, &mut rng);
        let cases = [
            (Backend::Fp32, PrepackPath::Fp32),
            (Backend::Fp16, PrepackPath::Fp16),
            (Backend::CubeTermwise, PrepackPath::Cube(SplitConfig::with_scale(12))),
            (Backend::Bf16x2, PrepackPath::Family(SplitSpec::bf16x2())),
            (Backend::Bf16x3, PrepackPath::Family(SplitSpec::bf16x3())),
        ];
        for (bk, path) in cases {
            let pp = PrepackedMatrix::prepack(&b, path);
            let want = gemm_prepacked(&a, &pp);
            for schedule in Schedule::ALL {
                let got = GemmBackend::new(bk)
                    .with_schedule(schedule)
                    .with_pipeline_depth(3)
                    .gemm_prepacked(&a, &pp);
                for (x, y) in want.as_slice().iter().zip(got.as_slice()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{bk} {schedule}");
                }
            }
        }
    }

    #[test]
    fn schedule_name_parse_roundtrip() {
        for s in Schedule::ALL {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("overlap"), Some(Schedule::OverlapB));
        assert_eq!(Schedule::parse("ab"), Some(Schedule::OverlapAB));
        assert_eq!(Schedule::parse("nope"), None);
        // with_overlap maps onto the schedule knob.
        let g = GemmBackend::new(Backend::Fp32).with_overlap(true);
        assert_eq!(g.schedule, Schedule::OverlapB);
        let g = g.with_overlap(false);
        assert_eq!(g.schedule, Schedule::Serial);
        // The process default agrees with the env-derived resolution.
        let want = match std::env::var("SGEMM_CUBE_SCHEDULE").ok().and_then(|v| {
            Schedule::parse(v.trim())
        }) {
            Some(s) => s,
            None => {
                if crate::gemm::overlap::overlap_enabled() {
                    Schedule::OverlapB
                } else {
                    Schedule::Serial
                }
            }
        };
        assert_eq!(default_schedule(), want);
    }
}
