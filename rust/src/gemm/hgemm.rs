//! FP16 GEMM as the Cube unit executes it.
//!
//! Operands are converted to FP16 (RN, as on Ascend); each FP16×FP16
//! product is *exact* when computed in FP32 (11-bit × 11-bit significands
//! need 22 bits ≤ 24), so the model multiplies widened `f32` values —
//! bit-identical to the hardware datapath — and accumulates in FP32.
//!
//! Two accumulate modes:
//! * [`AccumulateMode::Fp32Rn`] — FP32 adds with RN, the Ascend Cube
//!   behaviour the paper assumes.
//! * [`AccumulateMode::Fp32Rz`] — FP32 adds rounded toward zero,
//!   reproducing the NVIDIA Tensor-Core internal accumulation bias that
//!   Ootomo & Yokota worked around (kept for the related-work ablation).

use crate::softfloat::f16::F16;
use crate::util::mat::Matrix;
use crate::util::threads::parallel_chunks;

/// Accumulator rounding behaviour of the matrix engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AccumulateMode {
    /// FP32 round-to-nearest adds (Ascend Cube).
    #[default]
    Fp32Rn,
    /// FP32 round-toward-zero adds (Tensor-Core-style bias).
    Fp32Rz,
}

/// FP32 addition with round-toward-zero, via an exact f64 intermediate
/// (the sum of two f32 values is exactly representable in f64).
#[inline]
pub fn add_f32_rz(a: f32, b: f32) -> f32 {
    let exact = a as f64 + b as f64;
    let rn = exact as f32; // RN conversion
    if rn.is_infinite() {
        // RZ never rounds a finite sum to infinity.
        return if rn > 0.0 { f32::MAX } else { f32::MIN };
    }
    if rn as f64 == exact {
        return rn;
    }
    // If RN overshot away from zero, step one ULP toward zero.
    if (rn as f64).abs() > exact.abs() {
        f32::from_bits(rn.to_bits() - 1) // same sign: decrement magnitude
    } else {
        rn
    }
}

/// `C = to_half(A) · to_half(B)` with FP32 accumulation.
///
/// Inputs are FP32 matrices; conversion to FP16 happens inside (RN),
/// mirroring a direct "cast and multiply" use of the Cube.
pub fn hgemm(a: &Matrix<f32>, b: &Matrix<f32>, mode: AccumulateMode) -> Matrix<f32> {
    let ah = a.map(|v| F16::from_f32_rn(v).to_f32());
    let bh = b.map(|v| F16::from_f32_rn(v).to_f32());
    hgemm_preconverted(&ah, &bh, mode)
}

/// Cube GEMM over matrices whose entries are already exact FP16 values
/// widened to f32 (the representation used by the split pipeline — it
/// avoids re-conversion per term).
pub fn hgemm_preconverted(ah: &Matrix<f32>, bh: &Matrix<f32>, mode: AccumulateMode) -> Matrix<f32> {
    let (m, k) = ah.shape();
    let (kb, n) = bh.shape();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let bt = bh.transpose();
    let mut c = Matrix::zeros(m, n);

    let cp = crate::util::threads::SendPtr(c.as_mut_slice().as_mut_ptr());

    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let arow = ah.row(i);
            for j in 0..n {
                let bcol = bt.row(j);
                let acc = match mode {
                    AccumulateMode::Fp32Rn => {
                        let mut acc = 0.0f32;
                        for (x, y) in arow.iter().zip(bcol.iter()) {
                            acc += x * y; // product exact, add RN — hardware path
                        }
                        acc
                    }
                    AccumulateMode::Fp32Rz => {
                        let mut acc = 0.0f32;
                        for (x, y) in arow.iter().zip(bcol.iter()) {
                            acc = add_f32_rz(acc, x * y);
                        }
                        acc
                    }
                };
                // SAFETY: row chunks are disjoint across threads.
                unsafe { *cp.0.add(i * n + j) = acc };
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    #[test]
    fn exact_for_fp16_representable_inputs() {
        // Small integers are exact in fp16; short k keeps the sum exact.
        let a = Matrix::from_vec(2, 2, vec![1.0f32, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0f32, 6.0, 7.0, 8.0]);
        let c = hgemm(&a, &b, AccumulateMode::Fp32Rn);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn error_magnitude_matches_paper() {
        // Paper Fig. 8: HGEMM relative error ~1e-4 at moderate exponents.
        let mut rng = Rng::new(4);
        let a = Matrix::random_symmetric(128, 128, 0, &mut rng);
        let b = Matrix::random_symmetric(128, 128, 0, &mut rng);
        let c = hgemm(&a, &b, AccumulateMode::Fp32Rn);
        let c_ref = dgemm_of_f32(&a, &b);
        let err = relative_error(&c_ref, &c.to_f64());
        assert!((1e-5..1e-3).contains(&err), "err={err}");
    }

    #[test]
    fn rz_accumulation_is_worse_than_rn() {
        let mut rng = Rng::new(5);
        let a = Matrix::random_nonneg(64, 256, 0, &mut rng);
        let b = Matrix::random_nonneg(256, 64, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let rn = relative_error(&c_ref, &hgemm(&a, &b, AccumulateMode::Fp32Rn).to_f64());
        let rz = relative_error(&c_ref, &hgemm(&a, &b, AccumulateMode::Fp32Rz).to_f64());
        // RZ systematically under-accumulates positive sums.
        assert!(rz > rn, "rz={rz} rn={rn}");
    }

    #[test]
    fn add_f32_rz_properties() {
        // Exact sums are returned exactly.
        assert_eq!(add_f32_rz(1.0, 2.0), 3.0);
        assert_eq!(add_f32_rz(-1.5, 0.25), -1.25);
        // Inexact positive sum truncates downward (vs RN rounding up).
        let a = 1.0f32;
        let b = f32::EPSILON * 0.75; // 1 + 1.5*ulp/2 -> RN rounds up, RZ truncates
        let rz = add_f32_rz(a, b);
        let rn = a + b;
        assert!(rz <= rn);
        assert!(rz as f64 <= a as f64 + b as f64);
        // Negative mirror: RZ result magnitude never exceeds the exact sum.
        let rzn = add_f32_rz(-a, -b);
        assert!((rzn as f64).abs() <= (a as f64 + b as f64).abs());
        assert_eq!(rzn, -rz);
    }

    #[test]
    fn add_f32_rz_randomized_invariant() {
        let mut rng = Rng::new(6);
        for _ in 0..100_000 {
            let a = rng.symmetric_pow2(3);
            let b = rng.symmetric_pow2(3);
            let exact = a as f64 + b as f64;
            let rz = add_f32_rz(a, b) as f64;
            assert!(rz.abs() <= exact.abs() + 1e-300, "a={a} b={b}");
            // Within one ULP below the exact value.
            let rn = (a + b) as f64;
            assert!((exact - rz).abs() <= (rn - exact).abs() * 2.0 + f32::EPSILON as f64 * exact.abs());
        }
    }
}
