//! FP64 reference GEMM — the accuracy ground truth (`C_true` in Eq. 13).
//!
//! Blocked over the k dimension only as much as needed for decent cache
//! behaviour; B is packed transposed so the inner loop runs over two
//! contiguous slices (autovectorizes well even at `opt-level=3` on one
//! core).

use crate::util::mat::Matrix;
use crate::util::threads::parallel_chunks;

/// `C = A (m×k) · B (k×n)` in FP64.
pub fn dgemm(a: &Matrix<f64>, b: &Matrix<f64>) -> Matrix<f64> {
    let (m, k) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "inner dimensions must match: {k} vs {kb}");
    let bt = b.transpose(); // pack B columns contiguously
    let mut c = Matrix::zeros(m, n);

    let cp = crate::util::threads::SendPtr(c.as_mut_slice().as_mut_ptr());

    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let arow = a.row(i);
            for j in 0..n {
                let bcol = bt.row(j);
                let mut acc = 0.0f64;
                for (x, y) in arow.iter().zip(bcol.iter()) {
                    acc += x * y;
                }
                // SAFETY: row chunks are disjoint across threads.
                unsafe { *cp.0.add(i * n + j) = acc };
            }
        }
    });
    c
}

/// Convenience: FP64 reference of an FP32 problem (promote, multiply).
pub fn dgemm_of_f32(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f64> {
    dgemm(&a.to_f64(), &b.to_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_multiplication() {
        let a = Matrix::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        let b = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(dgemm(&a, &b), b);
    }

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = dgemm(&a, &b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn rectangular_shapes() {
        let mut rng = Rng::new(1);
        let a = Matrix::from_fn(5, 7, |_, _| rng.f64());
        let b = Matrix::from_fn(7, 3, |_, _| rng.f64());
        let c = dgemm(&a, &b);
        assert_eq!(c.shape(), (5, 3));
        // Spot-check one element against a manual dot product.
        let mut acc = 0.0;
        for t in 0..7 {
            acc += a.get(2, t) * b.get(t, 1);
        }
        assert!((c.get(2, 1) - acc).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "inner dimensions")]
    fn dimension_mismatch_panics() {
        let a: Matrix<f64> = Matrix::zeros(2, 3);
        let b: Matrix<f64> = Matrix::zeros(4, 2);
        let _ = dgemm(&a, &b);
    }
}
