//! Hot-path GEMM entry points.
//!
//! The *exact* kernels ([`crate::gemm::sgemm`], [`crate::gemm::hgemm`],
//! [`crate::gemm::cube`]) keep a single FP32 running sum per output so
//! their accumulation order is bit-faithful to the semantics the
//! accuracy experiments study — which also makes them latency-bound on
//! one dependent FP-add chain.
//!
//! The serving/training hot path does not need a *specific* order, only
//! a correct one. These entry points are now thin wrappers over the
//! cache-blocked packed engine ([`crate::gemm::blocked`]): panel packing,
//! an `MR × NR` register micro-kernel, and — for SGEMM-cube — a fused
//! micro-kernel computing all three dominant terms in one traversal of
//! dual-component interleaved panels, with block sizes chosen by the
//! repo's own Eq. 8/9/12 machinery against the host cache descriptor.
//!
//! [`dot8`] (the original eight-lane dot product) and
//! [`cube_gemm_three_pass`] (the pre-blocking row×column kernel that
//! walks the three correction terms in three separate passes) are kept
//! as the measured baselines — EXPERIMENTS.md §Perf-iteration-log and
//! `cargo bench --bench fig11_blocking_perf` compare the blocked engine
//! against them and record the trajectory in `BENCH_gemm.json`.

use crate::gemm::backend::{Backend, GemmBackend};
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;
use crate::util::threads::parallel_chunks;

/// Eight-lane partial-sum dot product (autovectorizes). Baseline for the
/// blocked micro-kernel; still used by callers wanting a flat dot.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let (ai, bi) = (&a[i * 8..i * 8 + 8], &b[i * 8..i * 8 + 8]);
        for l in 0..8 {
            acc[l] += ai[l] * bi[l];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    let s01 = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let s23 = (acc[4] + acc[5]) + (acc[6] + acc[7]);
    (s01 + s23) + tail
}

/// FP32 GEMM through the blocked packed engine. Thin sugar over
/// [`GemmBackend`], which owns the schedule dispatch
/// (serial / overlap-b / overlap-ab, defaulting to the
/// `SGEMM_CUBE_SCHEDULE` / `SGEMM_CUBE_OVERLAP` env knobs — results
/// are bit-identical either way, see [`crate::exec::pipeline`]).
pub fn sgemm_fast(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    GemmBackend::new(Backend::Fp32).gemm(a, b)
}

/// FP16 Cube GEMM (fp16 operands widened exactly, fp32 accumulate)
/// through the blocked packed engine.
pub fn hgemm_fast(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    GemmBackend::new(Backend::Fp16).gemm(a, b)
}

/// SGEMM-cube through the blocked engine's fused three-term micro-kernel.
/// The termwise *structure* (corrections aggregated before meeting the
/// high product) is preserved; see [`crate::gemm::blocked`].
pub fn cube_gemm_fast(a: &Matrix<f32>, b: &Matrix<f32>, cfg: SplitConfig) -> Matrix<f32> {
    GemmBackend { split: cfg, ..GemmBackend::new(Backend::CubeTermwise) }.gemm(a, b)
}

/// The pre-blocking SGEMM-cube hot path: row × transposed-column `dot8`
/// over the full width of B, one pass per term (`s_hh`, `s_hl`, `s_lh`).
/// Kept as the perf baseline the blocked fused kernel is measured
/// against (EXPERIMENTS.md §Perf-iteration-log).
pub fn cube_gemm_three_pass(a: &Matrix<f32>, b: &Matrix<f32>, cfg: SplitConfig) -> Matrix<f32> {
    assert_eq!(a.cols(), b.rows(), "inner dimensions must match");
    let asp = crate::gemm::cube::WideSplit::of(a, cfg);
    let bsp = crate::gemm::cube::WideSplit::of(b, cfg);
    let (m, _) = asp.high.shape();
    let n = bsp.high.cols();
    let bh_t = bsp.high.transpose();
    let bl_t = bsp.low.transpose();
    let inv_sf = 1.0f32 / cfg.scale_factor();

    let mut c = Matrix::zeros(m, n);
    let cp = crate::util::threads::SendPtr(c.as_mut_slice().as_mut_ptr());
    parallel_chunks(m, |i0, i1| {
        let cp = &cp;
        for i in i0..i1 {
            let ah = asp.high.row(i);
            let al = asp.low.row(i);
            for j in 0..n {
                let bh = bh_t.row(j);
                let bl = bl_t.row(j);
                let s_hh = dot8(ah, bh);
                let s_hl = dot8(ah, bl);
                let s_lh = dot8(al, bh);
                // SAFETY: disjoint row chunks.
                unsafe { *cp.0.add(i * n + j) = s_hh + (s_hl + s_lh) * inv_sf };
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dgemm::dgemm_of_f32;
    use crate::gemm::error::relative_error;
    use crate::util::rng::Rng;

    #[test]
    fn dot8_matches_f64_reference() {
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 7, 8, 9, 64, 257] {
            let a: Vec<f32> = (0..len).map(|_| rng.symmetric_pow2(0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.symmetric_pow2(0)).collect();
            let exact: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let got = dot8(&a, &b) as f64;
            assert!((got - exact).abs() <= 1e-5 * exact.abs().max(1.0), "len={len}");
        }
    }

    #[test]
    fn fast_variants_match_exact_accuracy_class() {
        let mut rng = Rng::new(2);
        let a = Matrix::random_symmetric(96, 128, 0, &mut rng);
        let b = Matrix::random_symmetric(128, 64, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let e_s = relative_error(&c_ref, &sgemm_fast(&a, &b).to_f64());
        let e_h = relative_error(&c_ref, &hgemm_fast(&a, &b).to_f64());
        let e_c = relative_error(&c_ref, &cube_gemm_fast(&a, &b, SplitConfig::default()).to_f64());
        assert!(e_s < 1e-6, "sgemm_fast {e_s}");
        assert!((1e-5..1e-3).contains(&e_h), "hgemm_fast {e_h}");
        assert!(e_c < 1e-6, "cube_fast {e_c}");
        assert!(e_c < e_h / 50.0);
    }

    #[test]
    fn fast_vs_exact_within_accumulation_noise() {
        let mut rng = Rng::new(3);
        let a = Matrix::random_symmetric(64, 512, 0, &mut rng);
        let b = Matrix::random_symmetric(512, 64, 0, &mut rng);
        let exact = crate::gemm::sgemm::sgemm(&a, &b);
        let fast = sgemm_fast(&a, &b);
        let c_ref = dgemm_of_f32(&a, &b);
        let e_exact = relative_error(&c_ref, &exact.to_f64());
        let e_fast = relative_error(&c_ref, &fast.to_f64());
        // Blocked accumulation is at least comparable in accuracy.
        assert!(e_fast <= e_exact * 2.0, "fast {e_fast} vs exact {e_exact}");
    }

    #[test]
    fn three_pass_baseline_matches_blocked_class() {
        let mut rng = Rng::new(4);
        let a = Matrix::random_symmetric(48, 200, 0, &mut rng);
        let b = Matrix::random_symmetric(200, 56, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let cfg = SplitConfig::default();
        let e_three = relative_error(&c_ref, &cube_gemm_three_pass(&a, &b, cfg).to_f64());
        let e_blocked = relative_error(&c_ref, &cube_gemm_fast(&a, &b, cfg).to_f64());
        assert!(e_three < 1e-6, "three-pass {e_three}");
        assert!(e_blocked < 1e-6, "blocked {e_blocked}");
    }
}
