//! LRU cache of prepacked weight operands ([`crate::gemm::prepacked`]).
//!
//! The serving tier treats the packed/split representation of a stable B
//! operand as a cached artifact: keyed by the weight's identity and
//! shape **plus** the precision path, scaling parameters, and the
//! kernel lane, because a weight prepacked for one `(path, s_b, lane)`
//! triple is not valid for another (the split itself depends on `s_b`,
//! the panel format differs between the single- and dual-component
//! paths, and the panel interleave follows the lane's micro-tile dims —
//! an entry packed under a forced narrow lane must not be served to the
//! wide AVX-512 sweeps or vice versa).
//!
//! Capacity is bounded in bytes (weights dominate; entry counts would be
//! a poor proxy). Eviction is least-recently-used via a monotonic use
//! stamp — an `O(entries)` scan per eviction, which is irrelevant at the
//! dozens-of-weights scale this cache holds. A single entry larger than
//! the whole capacity is admitted anyway (evicting everything else):
//! refusing it would livelock the serving path that needs it.
//!
//! Packing runs *outside* the lock: a miss releases the mutex, packs,
//! then re-checks on insert, so a large weight being prepacked never
//! stalls workers hitting other entries. Two workers racing on the same
//! cold key may both pack; the second insert discards its copy and
//! adopts the first — wasted work once per race, no inconsistency.
//!
//! **Eviction vs in-flight batches.** Lookups hand out
//! `Arc<PrepackedMatrix>`, and batch tasks hold that `Arc` for the
//! request's whole execution — including the prepacked A-stripe
//! prefetch pipeline, whose detached pool job reads the panels through
//! a lifetime-erased borrow that the driver joins before returning
//! ([`crate::exec::pipeline`]). Eviction and [`PrepackCache::purge_weight`]
//! therefore only drop the *cache's* reference: panels already claimed
//! by an in-flight ring stay alive and byte-stable until the batch
//! finishes, while the freed bytes stop counting against capacity
//! immediately (the entry's memory is reclaimed when the last holder
//! drops). Pinned by `evicted_entry_stays_alive_for_holders` below and
//! the eviction-race test in `tests/executor.rs`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::gemm::backend::Backend;
use crate::gemm::kernels::Lane;
use crate::gemm::prepacked::PrepackedMatrix;

/// Cache key for a prepacked operand. `weight` is the registered weight
/// identity (two distinct weights of equal shape must not collide);
/// `backend`/`scale_exp` pin the precision path and scaling the panels
/// were prepared for (callers normalize: both cube orders share packed
/// panels, and `scale_exp` is 0 on non-cube paths). `lane` pins the
/// micro-tile interleave the panels were packed with
/// ([`Lane::tile_dims`]): callers pass the lane that will execute the
/// request ([`crate::gemm::kernels::active_lane`]), so a lane override
/// mid-flight repacks instead of consuming mismatched panels. `col0` is
/// the first weight column covered by the entry: 0 with `n` = the full
/// width for whole-weight packs, the slice origin for the shard router's
/// column-partition packs ([`crate::coordinator::shard`]) — so slices
/// of one weight coexist with each other and with the full pack.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrepackKey {
    /// Registered weight identity.
    pub weight: u64,
    /// Weight rows (GEMM inner dimension).
    pub k: usize,
    /// Weight columns covered by this entry.
    pub n: usize,
    /// Precision path the panels were prepared for (normalized).
    pub backend: Backend,
    /// Residual scaling exponent baked into the split (0 off cube paths).
    pub scale_exp: i32,
    /// Kernel lane whose micro-tile geometry the panels follow.
    pub lane: Lane,
    /// First weight column covered (nonzero for shard column slices).
    pub col0: usize,
}

/// Point-in-time cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups served from cache.
    pub hits: u64,
    /// Lookups that had to pack.
    pub misses: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Bytes currently resident.
    pub bytes: usize,
    /// Configured capacity in bytes (0 = cache disabled).
    pub capacity_bytes: usize,
}

impl CacheStats {
    /// Fraction of lookups served from cache (0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Slot {
    value: Arc<PrepackedMatrix>,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<PrepackKey, Slot>,
    clock: u64,
    bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Byte-bounded LRU of prepacked operands, shared across the service's
/// worker threads.
pub struct PrepackCache {
    capacity_bytes: usize,
    inner: Mutex<Inner>,
}

impl PrepackCache {
    /// A cache bounded to `capacity_bytes`. A capacity of `0` means
    /// **disabled**: lookups miss, packs run, and nothing is ever
    /// retained — no unbounded growth and no evict loop (the operator's
    /// `prepack_cache_mb = 0` knob). A nonzero capacity smaller than a
    /// single entry keeps the admit-anyway semantics documented above.
    pub fn new(capacity_bytes: usize) -> PrepackCache {
        PrepackCache { capacity_bytes, inner: Mutex::new(Inner::default()) }
    }

    /// Fetch `key`, packing (outside the lock) on a miss.
    pub fn get_or_insert_with(
        &self,
        key: PrepackKey,
        pack: impl FnOnce() -> PrepackedMatrix,
    ) -> Arc<PrepackedMatrix> {
        {
            let mut g = self.inner.lock().unwrap();
            g.clock += 1;
            let stamp = g.clock;
            if let Some(slot) = g.map.get_mut(&key) {
                slot.last_used = stamp;
                let value = slot.value.clone();
                g.hits += 1;
                return value;
            }
            g.misses += 1;
        }
        // Failpoint on the miss path, outside the lock like the pack
        // itself: an armed panic unwinds through the caller's
        // containment without poisoning the cache mutex, and a retry
        // simply misses again and repacks.
        crate::exec::faults::fire("gemm.cache.prepack");
        let packed = Arc::new(pack());
        if self.capacity_bytes == 0 {
            // Disabled cache: serve the packed operand without retaining
            // it — the map stays empty, so there is nothing to evict and
            // nothing grows.
            return packed;
        }
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let stamp = g.clock;
        if let Some(slot) = g.map.get_mut(&key) {
            // A racing worker packed the same key first; adopt its copy.
            slot.last_used = stamp;
            return slot.value.clone();
        }
        g.bytes += packed.bytes();
        g.map.insert(key, Slot { value: packed.clone(), last_used: stamp });
        while g.bytes > self.capacity_bytes && g.map.len() > 1 {
            // The fresh entry holds the newest stamp, so the scan never
            // selects it while anything older remains.
            let lru = g
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k)
                .expect("len > 1");
            let evicted = g.map.remove(&lru).expect("key just observed");
            g.bytes -= evicted.value.bytes();
            g.evictions += 1;
        }
        packed
    }

    /// Lookup without packing (hit/miss counted).
    pub fn get(&self, key: &PrepackKey) -> Option<Arc<PrepackedMatrix>> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let stamp = g.clock;
        match g.map.get_mut(key) {
            Some(slot) => {
                slot.last_used = stamp;
                let value = slot.value.clone();
                g.hits += 1;
                Some(value)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    /// Remove every entry belonging to `weight` (all paths/scales) —
    /// the unregistration path: weight ids are never reused, so dead
    /// entries would otherwise sit charged against capacity until
    /// eviction pressure finds them. Returns the number removed. (A
    /// request already in flight against the weight may re-insert one
    /// entry afterwards; it ages out like any other.)
    pub fn purge_weight(&self, weight: u64) -> usize {
        let mut g = self.inner.lock().unwrap();
        let before = g.map.len();
        let mut freed = 0usize;
        g.map.retain(|k, slot| {
            if k.weight == weight {
                freed += slot.value.bytes();
                false
            } else {
                true
            }
        });
        g.bytes -= freed;
        before - g.map.len()
    }

    /// Drop every entry (counters are kept).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.bytes = 0;
    }

    /// Point-in-time snapshot of the cache counters.
    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            entries: g.map.len(),
            bytes: g.bytes,
            capacity_bytes: self.capacity_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::prepacked::PrepackPath;
    use crate::util::mat::Matrix;
    use crate::util::rng::Rng;

    fn key(weight: u64, n: usize) -> PrepackKey {
        PrepackKey {
            weight,
            k: n,
            n,
            backend: Backend::Fp32,
            scale_exp: 0,
            lane: crate::gemm::kernels::active_lane(),
            col0: 0,
        }
    }

    fn packed(n: usize, seed: u64) -> PrepackedMatrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::random_symmetric(n, n, 0, &mut rng);
        PrepackedMatrix::prepack(&b, PrepackPath::Fp32)
    }

    #[test]
    fn hit_after_first_insert() {
        let cache = PrepackCache::new(64 << 20);
        let mut packs = 0;
        for _ in 0..3 {
            let p = cache.get_or_insert_with(key(1, 16), || {
                packs += 1;
                packed(16, 1)
            });
            assert_eq!(p.n(), 16);
        }
        assert_eq!(packs, 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 1));
        assert!(s.hit_rate() > 0.6);
        assert!(s.bytes > 0);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cache = PrepackCache::new(64 << 20);
        cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        cache.get_or_insert_with(key(2, 16), || packed(16, 2));
        let mut k3 = key(1, 16);
        k3.scale_exp = 8;
        cache.get_or_insert_with(k3, || packed(16, 3));
        // A column slice of weight 1 (same shape, nonzero origin) is its
        // own entry — the shard router relies on this.
        let mut k4 = key(1, 16);
        k4.col0 = 16;
        cache.get_or_insert_with(k4, || packed(16, 4));
        assert_eq!(cache.stats().entries, 4);
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn lane_is_part_of_the_key() {
        // Regression: panels are interleaved per lane, so the same
        // weight prepacked under two different lanes must occupy two
        // entries — a lookup under lane X must never return panels
        // packed for lane Y's micro-tile geometry.
        let cache = PrepackCache::new(64 << 20);
        cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        let mut wide = key(1, 16);
        wide.lane = if wide.lane == Lane::Scalar { Lane::Avx512 } else { Lane::Scalar };
        assert!(cache.get(&wide).is_none(), "other-lane key must miss");
        cache.get_or_insert_with(wide, || packed(16, 1));
        assert_eq!(cache.stats().entries, 2, "per-lane entries coexist");
        // purge_weight still removes every lane's entries for the weight.
        assert_eq!(cache.purge_weight(1), 2);
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        // Each 16×16 FP32 entry packs to a bit over 1 KiB; cap the cache
        // so only two fit.
        let one = packed(16, 1).bytes();
        let cache = PrepackCache::new(2 * one + one / 2);
        cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        cache.get_or_insert_with(key(2, 16), || packed(16, 2));
        assert_eq!(cache.stats().evictions, 0);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(cache.get(&key(1, 16)).is_some());
        cache.get_or_insert_with(key(3, 16), || packed(16, 3));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(cache.get(&key(2, 16)).is_none(), "LRU entry 2 evicted");
        assert!(cache.get(&key(1, 16)).is_some(), "recently used entry 1 kept");
        assert!(cache.get(&key(3, 16)).is_some(), "fresh entry 3 kept");
        assert!(s.bytes <= 2 * one + one / 2);
    }

    #[test]
    fn oversized_entry_is_admitted_alone() {
        let cache = PrepackCache::new(1); // nothing "fits"
        cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        cache.get_or_insert_with(key(2, 16), || packed(16, 2));
        let s = cache.stats();
        // The newest oversized entry survives; the older one is evicted.
        assert_eq!(s.entries, 1);
        assert_eq!(s.evictions, 1);
        assert!(cache.get(&key(2, 16)).is_some());
    }

    #[test]
    fn single_entry_larger_than_budget_never_accumulates() {
        // Every entry exceeds the (nonzero) budget: each insert admits
        // the newcomer and evicts the previous one — bounded residency,
        // no evict-loop, byte accounting stays consistent.
        let one = packed(16, 1).bytes();
        let cache = PrepackCache::new(one / 2);
        for w in 1..=4u64 {
            let p = cache.get_or_insert_with(key(w, 16), || packed(16, w));
            assert_eq!(p.n(), 16);
            let s = cache.stats();
            assert_eq!(s.entries, 1, "oversized entries must not accumulate");
            assert_eq!(s.evictions, w - 1);
            assert!(s.bytes >= one, "the resident entry stays charged");
        }
        // The survivor is the most recent insert.
        assert!(cache.get(&key(4, 16)).is_some());
        assert!(cache.get(&key(1, 16)).is_none());
    }

    #[test]
    fn evicted_entry_stays_alive_for_holders() {
        // An Arc handed out before eviction keeps the packed panels
        // alive and byte-stable while the cache moves on — the property
        // in-flight prefetched batches rely on (the server holds the
        // Arc for the request's lifetime; see module docs).
        let one = packed(16, 1).bytes();
        let cache = PrepackCache::new(one + one / 2); // room for ~1 entry
        let held = cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        let before: Vec<f32> = held.panel(0, 0).to_vec();
        for w in 2..=5u64 {
            cache.get_or_insert_with(key(w, 16), || packed(16, w));
        }
        assert!(cache.get(&key(1, 16)).is_none(), "entry 1 evicted");
        assert!(cache.stats().evictions >= 1);
        assert_eq!(held.panel(0, 0), &before[..], "held Arc unaffected by eviction");
        assert_eq!(held.n(), 16);
        // purge_weight on a held entry is equally harmless.
        let held2 = cache.get_or_insert_with(key(9, 16), || packed(16, 9));
        cache.purge_weight(9);
        assert_eq!(held2.n(), 16);
        assert!(!held2.panel(0, 0).is_empty());
    }

    #[test]
    fn zero_capacity_disables_the_cache() {
        // prepack_cache_mb = 0 ⇒ miss-through: packs happen per call,
        // nothing is retained, no growth, no evictions, `get` never hits.
        let cache = PrepackCache::new(0);
        let mut packs = 0;
        for _ in 0..3 {
            let p = cache.get_or_insert_with(key(1, 16), || {
                packs += 1;
                packed(16, 1)
            });
            assert_eq!(p.n(), 16);
        }
        assert_eq!(packs, 3, "every lookup repacks");
        assert!(cache.get(&key(1, 16)).is_none());
        let s = cache.stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.bytes, 0);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 4, "3 insert lookups + 1 get");
        assert_eq!(s.capacity_bytes, 0);
    }

    #[test]
    fn purge_weight_removes_all_its_paths_and_frees_bytes() {
        let cache = PrepackCache::new(64 << 20);
        cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        let mut cube_key = key(1, 16);
        cube_key.backend = Backend::CubeTermwise;
        cube_key.scale_exp = 12;
        cache.get_or_insert_with(cube_key, || packed(16, 1));
        cache.get_or_insert_with(key(2, 16), || packed(16, 2));
        assert_eq!(cache.purge_weight(1), 2);
        let s = cache.stats();
        assert_eq!(s.entries, 1);
        assert!(cache.get(&key(2, 16)).is_some(), "other weights untouched");
        assert!(cache.get(&key(1, 16)).is_none());
        assert_eq!(cache.purge_weight(1), 0, "idempotent");
    }

    #[test]
    fn clear_empties_but_keeps_counters() {
        let cache = PrepackCache::new(64 << 20);
        cache.get_or_insert_with(key(1, 16), || packed(16, 1));
        cache.clear();
        let s = cache.stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.bytes, 0);
        assert_eq!(s.misses, 1);
    }
}
