//! Portable scalar micro-kernels — the always-available fallback lane.
//!
//! This is the code the blocked engine shipped with before the SIMD
//! lanes existed, moved here verbatim so [`super::dispatch`] can treat
//! it as one lane among equals. The inner loops are written so LLVM
//! can autovectorize the `NR`-wide row updates, but nothing is
//! guaranteed beyond scalar IEEE-754 semantics: each `acc += a·b` step
//! is a rounded multiply followed by a rounded add (two roundings),
//! which is the lane's pinned accumulation contract (see the
//! [`super`] module docs for the cross-lane comparison).

use crate::gemm::pack::{MR, NR};
use crate::softfloat::family::MAX_COMPONENTS;

/// `MR × NR` register micro-kernel: one FP32 chain per cell over the
/// panel's k steps, `NR`-lane rows autovectorizing to SIMD FMAs where
/// the compiler finds them profitable (the *explicit* FMA lanes live in
/// the arch-gated `super::avx2` / `super::neon` modules).
///
/// `apanel` is one `MR`-interleaved A row panel (`kc·MR` values),
/// `bpanel` one `NR`-interleaved B column panel (`kc·NR` values); see
/// [`crate::gemm::pack`].
#[inline]
pub fn kernel_f32(apanel: &[f32], bpanel: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0.0f32; NR]; MR];
    for (av, bv) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let a = av[i];
            for (dst, &bj) in acc_row.iter_mut().zip(bv) {
                *dst += a * bj;
            }
        }
    }
    acc
}

/// Fused three-term cube micro-kernel over dual-component panels: per k
/// step it reads `(a_h, a_l)` and `(b_h, b_l)` once and feeds two
/// accumulator planes — the high·high product and the combined
/// corrections `a_h·b_l + a_l·b_h`. The corrections therefore aggregate
/// among themselves and meet the high product only at the tile combine
/// (the paper's termwise order, Sec. 4.4), while the three terms share a
/// single traversal instead of the reference's three passes.
///
/// Panels are in the dual format of [`crate::gemm::pack::pack_a_dual`] /
/// [`crate::gemm::pack::pack_b_dual`]: per k step, `MR` highs then `MR`
/// lows (resp. `NR`/`NR`).
#[inline]
pub fn kernel_cube(apanel: &[f32], bpanel: &[f32]) -> ([[f32; NR]; MR], [[f32; NR]; MR]) {
    let mut hh = [[0.0f32; NR]; MR];
    let mut corr = [[0.0f32; NR]; MR];
    for (av, bv) in apanel.chunks_exact(2 * MR).zip(bpanel.chunks_exact(2 * NR)) {
        let (ahs, als) = av.split_at(MR);
        let (bhs, bls) = bv.split_at(NR);
        for i in 0..MR {
            let vh = ahs[i];
            let vl = als[i];
            let hh_row = &mut hh[i];
            let corr_row = &mut corr[i];
            for j in 0..NR {
                hh_row[j] += vh * bhs[j];
                corr_row[j] += vh * bls[j] + vl * bhs[j];
            }
        }
    }
    (hh, corr)
}

/// Generic N-term family micro-kernel over `ncomp`-component panels
/// ([`crate::gemm::pack::pack_a_multi`] / `pack_b_multi` layout): one
/// accumulator plane per term order `d = i + j < ncomp`. Per k step each
/// order's kept products are summed left-to-right with `i` ascending
/// (`a_0·b_d + a_1·b_{d-1} + …`) and folded into the plane with **one**
/// rounded `+=` — the same per-step rounding shape as
/// [`kernel_cube`]'s correction plane, generalized. Planes of order ≥
/// `ncomp` stay exactly zero.
///
/// The engine dispatches `ncomp == 2` to [`kernel_cube`] instead (the
/// layouts coincide), keeping the N = 2 tiers bit-identical to the
/// pre-family kernels; this generic path serves `ncomp ≥ 3`.
#[inline]
pub fn kernel_family(
    apanel: &[f32],
    bpanel: &[f32],
    ncomp: usize,
) -> [[[f32; NR]; MR]; MAX_COMPONENTS] {
    debug_assert!((2..=MAX_COMPONENTS).contains(&ncomp));
    let mut acc = [[[0.0f32; NR]; MR]; MAX_COMPONENTS];
    for (av, bv) in apanel.chunks_exact(ncomp * MR).zip(bpanel.chunks_exact(ncomp * NR)) {
        for i in 0..MR {
            for (d, plane) in acc.iter_mut().enumerate().take(ncomp) {
                let row = &mut plane[i];
                for (j, dst) in row.iter_mut().enumerate() {
                    let mut t = av[i] * bv[d * NR + j];
                    for ci in 1..=d {
                        t += av[ci * MR + i] * bv[(d - ci) * NR + j];
                    }
                    *dst += t;
                }
            }
        }
    }
    acc
}
