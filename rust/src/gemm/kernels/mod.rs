//! SIMD micro-kernels with runtime dispatch.
//!
//! The blocked engine ([`crate::gemm::blocked`]) executes exactly three
//! inner loops: the `mr × nr` f32 micro-kernel, the fused three-term
//! cube micro-kernel, and the generic N-term family micro-kernel
//! ([`kernel_family`], serving the `ncomp ≥ 3` precision-emulation
//! tiers; `ncomp == 2` routes to the cube kernel for bit-identity).
//! This module holds every implementation of those loops — one per
//! **lane** — plus the machinery that picks a lane at runtime:
//!
//! * [`scalar`] — portable Rust, always available, the reference the
//!   other lanes are measured against (narrow 4×8 tile);
//! * `avx2` (compiled on x86_64 only) — explicit `std::arch` AVX2 + FMA
//!   intrinsics, one 8-lane YMM accumulator per micro-tile row (narrow
//!   4×8 tile);
//! * `neon` (compiled on aarch64 only) — explicit NEON intrinsics, two
//!   4-lane q-register accumulators per micro-tile row (narrow 4×8
//!   tile);
//! * `avx512` (compiled on x86_64 only) — explicit AVX-512F intrinsics,
//!   one 16-lane ZMM accumulator per row of the **wide 8×16 tile** the
//!   32-zmm register file supports;
//! * [`dispatch`] — the [`Lane`] enum, CPU feature detection, the
//!   `SGEMM_CUBE_KERNEL` environment override, [`force_lane`] for
//!   benches/tests, and the dispatching [`kernel_f32`] /
//!   [`kernel_cube`] entry points the sweeps call.
//!
//! # The per-lane accumulation-order contract
//!
//! Every lane consumes panels packed with **its own tile dims**
//! ([`Lane::tile_dims`], feeding [`crate::gemm::pack`]) in the same k
//! order and accumulates one FP32 chain per output cell per k block.
//! What differs between lanes is **rounding within each chain step**
//! (and, for the wide lane, how cells group into tiles — which never
//! changes any single cell's chain), so results are bit-identical *per
//! lane*, not across lanes:
//!
//! * **scalar**: `acc += a·b` is a rounded multiply followed by a
//!   rounded add (two roundings per step); the cube correction chain is
//!   `corr += (a_h·b_l + a_l·b_h)` — both products rounded, their sum
//!   rounded, then the accumulate rounded.
//! * **avx2** / **neon** / **avx512**: `acc = fma(a, b, acc)` fuses
//!   each multiply-add into a single rounding; the cube correction
//!   chain is pinned as `corr = fma(a_h, b_l, fma(a_l, b_h, corr))` —
//!   the `a_l·b_h` term joins the chain first, each join a single
//!   rounding.
//!
//! Both shapes keep the paper's Sec. 4.4 termwise property — the two
//! correction terms aggregate *with each other* across all k steps and
//! meet the high·high product only at the tile combine — and both land
//! in the same ≤ 2⁻²² accuracy class (`tests/accuracy.rs` runs its
//! bounds against whichever lane is active; `tests/dispatch.rs` forces
//! each lane in turn). FMA's single rounding is never *less* accurate
//! per step than the scalar double rounding.
//!
//! What **is** guaranteed across schedules: for a fixed lane, every
//! path through the engine — serial, overlap-B, overlap-AB, prepacked,
//! sharded — produces bit-identical output, because block order and
//! the sweeps are shared, panels are packed with that lane's dims on
//! every path, and the lane is resolved once per GEMM call. Lane
//! selection is the *only* numerics degree of freedom this module
//! adds, and it is observable/forcible via `SGEMM_CUBE_KERNEL` (see
//! [`dispatch::active_lane`]).
//!
//! The micro-tile geometry is derived per register file in
//! [`crate::sim::blocking::micro_tile`]: the 16-YMM AVX2 file and the
//! 32-q NEON file both land on the narrow `MR × NR = 4 × 8` tile
//! ([`crate::gemm::pack::MR`]/[`crate::gemm::pack::NR`]) the scalar
//! lane shares, while the 32-zmm AVX-512 file affords the wide
//! `MAX_MR × MAX_NR = 8 × 16` tile. Panel formats therefore follow the
//! lane ([`Lane::tile_dims`]): prepacked operands record the lane they
//! were packed for ([`crate::gemm::prepacked`]) and the prepack cache
//! key includes it ([`crate::gemm::cache`]).

pub mod dispatch;
pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "x86_64")]
pub mod avx512;

#[cfg(target_arch = "aarch64")]
pub mod neon;

pub use dispatch::{active_lane, detect_lane, force_lane, kernel_cube, kernel_f32, kernel_family, Lane};
