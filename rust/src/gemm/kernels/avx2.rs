//! AVX2 + FMA micro-kernels (x86_64).
//!
//! `NR = 8` is exactly one 256-bit YMM register of f32 lanes, so each
//! micro-tile row is a single vector accumulator: the f32 kernel holds
//! `MR = 4` accumulators and the fused cube kernel holds `2·MR = 8`
//! (high·high plane + correction plane), leaving half the 16-register
//! YMM file for the B vectors and the broadcast A value — the register
//! budget [`crate::sim::blocking::micro_tile`] derives.
//!
//! Pinned accumulation contract of this lane (see [`super`] for the
//! cross-lane comparison): every chain step is a **fused** multiply-add
//! (`_mm256_fmadd_ps`, one rounding), and the cube correction chain is
//! `corr = fma(a_h, b_l, fma(a_l, b_h, corr))` — the `a_l·b_h` term
//! joins first. Packed panels are read with unaligned loads
//! (`_mm256_loadu_ps`); the pack layer guarantees panel lengths are
//! `NR`-step multiples, not pointer alignment.

use core::arch::x86_64::{
    __m256, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
};

use crate::gemm::pack::{MR, NR};
use crate::softfloat::family::MAX_COMPONENTS;

// The kernels below hard-code "one row == one YMM"; refuse to compile
// if the shared micro-tile geometry ever drifts.
const _: () = assert!(MR == 4 && NR == 8, "AVX2 lane is written for a 4x8 micro-tile");

/// AVX2+FMA `MR × NR` f32 micro-kernel: one YMM accumulator per row,
/// one fused multiply-add per row per k step. Panel layout and the
/// chain-per-cell semantics match [`super::scalar::kernel_f32`]; only
/// the per-step rounding differs (fused, one rounding).
///
/// # Safety
///
/// The caller must ensure the executing CPU supports AVX2 and FMA
/// (`Lane::Avx2.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be panels for the same `kc`:
/// `apanel.len() == kc·MR` and `bpanel.len() == kc·NR`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn kernel_f32(apanel: &[f32], bpanel: &[f32]) -> [[f32; NR]; MR] {
    let steps = bpanel.len() / NR;
    debug_assert_eq!(apanel.len(), steps * MR);
    debug_assert_eq!(bpanel.len(), steps * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut acc = [_mm256_setzero_ps(); MR];
    for p in 0..steps {
        let bv = _mm256_loadu_ps(b.add(p * NR));
        let ap = a.add(p * MR);
        for (i, accr) in acc.iter_mut().enumerate() {
            *accr = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(i)), bv, *accr);
        }
    }
    store_tile(&acc)
}

/// AVX2+FMA fused three-term cube micro-kernel over dual-component
/// panels (layout of [`crate::gemm::pack::pack_a_dual`] /
/// [`crate::gemm::pack::pack_b_dual`]): per k step, the high·high plane
/// takes `hh = fma(a_h, b_h, hh)` and the correction plane takes
/// `corr = fma(a_h, b_l, fma(a_l, b_h, corr))` — this lane's pinned
/// correction-chain order. Corrections aggregate among themselves and
/// meet the high product only at the tile combine (Sec. 4.4), exactly
/// as in [`super::scalar::kernel_cube`].
///
/// # Safety
///
/// The caller must ensure the executing CPU supports AVX2 and FMA
/// (`Lane::Avx2.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be dual panels for the same `kc`:
/// `apanel.len() == kc·2·MR` and `bpanel.len() == kc·2·NR`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn kernel_cube(apanel: &[f32], bpanel: &[f32]) -> ([[f32; NR]; MR], [[f32; NR]; MR]) {
    let steps = bpanel.len() / (2 * NR);
    debug_assert_eq!(apanel.len(), steps * 2 * MR);
    debug_assert_eq!(bpanel.len(), steps * 2 * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut hh = [_mm256_setzero_ps(); MR];
    let mut corr = [_mm256_setzero_ps(); MR];
    for p in 0..steps {
        let bh = _mm256_loadu_ps(b.add(p * 2 * NR));
        let bl = _mm256_loadu_ps(b.add(p * 2 * NR + NR));
        let ap = a.add(p * 2 * MR);
        for (i, (hhr, corrr)) in hh.iter_mut().zip(corr.iter_mut()).enumerate() {
            let ah = _mm256_set1_ps(*ap.add(i));
            let al = _mm256_set1_ps(*ap.add(MR + i));
            *hhr = _mm256_fmadd_ps(ah, bh, *hhr);
            *corrr = _mm256_fmadd_ps(ah, bl, _mm256_fmadd_ps(al, bh, *corrr));
        }
    }
    (store_tile(&hh), store_tile(&corr))
}

/// AVX2+FMA generic N-term family micro-kernel over `ncomp`-component
/// panels ([`crate::gemm::pack::pack_a_multi`] / `pack_b_multi`
/// layout): one YMM accumulator plane per term order `d < ncomp`. Per k
/// step each order chains its kept products as nested FMAs with the
/// *highest* `a` component joining first —
/// `acc_d = fma(a_0, b_d, … fma(a_d, b_0, acc_d))` — the same
/// convention as [`kernel_cube`]'s correction chain (`a_l·b_h` joins
/// first), generalized. Planes of order ≥ `ncomp` stay exactly zero.
///
/// The engine dispatches `ncomp == 2` to [`kernel_cube`] instead; this
/// generic path serves `ncomp ≥ 3`.
///
/// # Safety
///
/// The caller must ensure the executing CPU supports AVX2 and FMA
/// (`Lane::Avx2.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be `ncomp`-component panels for the same
/// `kc`: `apanel.len() == kc·ncomp·MR` and
/// `bpanel.len() == kc·ncomp·NR`, with `2 <= ncomp <= MAX_COMPONENTS`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn kernel_family(
    apanel: &[f32],
    bpanel: &[f32],
    ncomp: usize,
) -> [[[f32; NR]; MR]; MAX_COMPONENTS] {
    debug_assert!((2..=MAX_COMPONENTS).contains(&ncomp));
    let steps = bpanel.len() / (ncomp * NR);
    debug_assert_eq!(apanel.len(), steps * ncomp * MR);
    debug_assert_eq!(bpanel.len(), steps * ncomp * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut acc = [[_mm256_setzero_ps(); MR]; MAX_COMPONENTS];
    for p in 0..steps {
        let mut bv = [_mm256_setzero_ps(); MAX_COMPONENTS];
        for (c, slot) in bv.iter_mut().enumerate().take(ncomp) {
            *slot = _mm256_loadu_ps(b.add(p * ncomp * NR + c * NR));
        }
        let ap = a.add(p * ncomp * MR);
        for i in 0..MR {
            let mut av = [_mm256_setzero_ps(); MAX_COMPONENTS];
            for (c, slot) in av.iter_mut().enumerate().take(ncomp) {
                *slot = _mm256_set1_ps(*ap.add(c * MR + i));
            }
            for (d, plane) in acc.iter_mut().enumerate().take(ncomp) {
                let mut v = plane[i];
                for ci in (0..=d).rev() {
                    v = _mm256_fmadd_ps(av[ci], bv[d - ci], v);
                }
                plane[i] = v;
            }
        }
    }
    let mut out = [[[0.0f32; NR]; MR]; MAX_COMPONENTS];
    for (dst, plane) in out.iter_mut().zip(&acc) {
        *dst = store_tile(plane);
    }
    out
}

/// Spill `MR` YMM accumulators into the `[[f32; NR]; MR]` tile shape the
/// shared C-update path ([`crate::gemm::blocked`]) consumes. Compiled
/// with the same target features as its callers so the stores lower to
/// plain YMM moves.
#[target_feature(enable = "avx2,fma")]
unsafe fn store_tile(acc: &[__m256; MR]) -> [[f32; NR]; MR] {
    let mut out = [[0.0f32; NR]; MR];
    for (dst, v) in out.iter_mut().zip(acc) {
        _mm256_storeu_ps(dst.as_mut_ptr(), *v);
    }
    out
}
