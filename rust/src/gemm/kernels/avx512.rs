//! AVX-512F micro-kernels (x86_64) over the **wide** micro-tile.
//!
//! One ZMM register is 512 bits — sixteen f32 lanes — so a micro-tile
//! row is a *single* register and the 32-entry zmm file affords a
//! genuinely larger tile than AVX2's 4×8: `MAX_MR × MAX_NR = 8 × 16`,
//! exactly what [`crate::sim::blocking::micro_tile`] derives for
//! `(regs, lanes) = (32, 16)`. The f32 kernel holds `MAX_MR = 8`
//! accumulators and the fused cube kernel `2·MAX_MR = 16` (high·high
//! plane + correction plane), both comfortably inside the file with
//! room for the operand broadcasts.
//!
//! Because the tile is wider, panels for this lane are packed with the
//! wide interleave ([`crate::gemm::pack`] with
//! `(mr, nr) = (MAX_MR, MAX_NR)`) — operands packed for a narrow lane
//! are *not* consumable here, which is why prepacked matrices record
//! their lane and the cache key includes it.
//!
//! Pinned accumulation contract of this lane (see [`super`] for the
//! cross-lane comparison): every chain step is a **fused** multiply-add
//! (`_mm512_fmadd_ps`, one rounding — 512-bit FMA is part of the
//! AVX512F feature itself), and the cube correction chain is
//! `corr = fma(a_h, b_l, fma(a_l, b_h, corr))` — the `a_l·b_h` term
//! joins first, the same order the AVX2 and NEON lanes pin. Lanes are
//! still not bit-interchangeable in general; the contract is pinned per
//! lane, and this lane additionally reduces each output cell over a
//! different `(i, j)` tiling of the same k-ordered chain — which is
//! irrelevant to bit-identity *per lane* and stays inside the shared
//! FMA-rounding envelope *across* lanes.
//!
//! Unlike the narrow lanes, these kernels write straight into the
//! caller's flat `mr·nr` row-major output slices (one
//! `_mm512_storeu_ps` per row) instead of returning register-tile
//! arrays by value.

use core::arch::x86_64::{
    __m512, _mm512_fmadd_ps, _mm512_loadu_ps, _mm512_set1_ps, _mm512_setzero_ps, _mm512_storeu_ps,
};

use crate::gemm::pack::{MAX_MR, MAX_NR};
use crate::softfloat::family::MAX_COMPONENTS;

// The kernels below hard-code "one row == one zmm register"; refuse to
// compile if the wide micro-tile geometry ever drifts.
const _: () = assert!(MAX_MR == 8 && MAX_NR == 16, "AVX-512 lane is written for an 8x16 micro-tile");

/// AVX-512 `MAX_MR × MAX_NR` f32 micro-kernel: one ZMM accumulator per
/// row, one fused multiply-add per row per k step. Panel layout and the
/// chain-per-cell semantics match [`super::scalar::kernel_f32`] at the
/// wide tile dims; only the per-step rounding differs (fused, one
/// rounding). Fully overwrites `out[..MAX_MR·MAX_NR]` (row `i` at
/// `out[i·MAX_NR..]`).
///
/// # Safety
///
/// The caller must ensure the executing CPU supports AVX-512F
/// (`Lane::Avx512.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be **wide** panels for the same `kc`:
/// `apanel.len() == kc·MAX_MR` and `bpanel.len() == kc·MAX_NR`; `out`
/// must hold at least `MAX_MR·MAX_NR` elements.
#[target_feature(enable = "avx512f")]
pub unsafe fn kernel_f32(apanel: &[f32], bpanel: &[f32], out: &mut [f32]) {
    let steps = bpanel.len() / MAX_NR;
    debug_assert_eq!(apanel.len(), steps * MAX_MR);
    debug_assert_eq!(bpanel.len(), steps * MAX_NR);
    debug_assert!(out.len() >= MAX_MR * MAX_NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut acc = [_mm512_setzero_ps(); MAX_MR];
    for p in 0..steps {
        let bv = _mm512_loadu_ps(b.add(p * MAX_NR));
        let ap = a.add(p * MAX_MR);
        for (i, accr) in acc.iter_mut().enumerate() {
            *accr = _mm512_fmadd_ps(_mm512_set1_ps(*ap.add(i)), bv, *accr);
        }
    }
    store_tile(&acc, out);
}

/// AVX-512 fused three-term cube micro-kernel over dual-component wide
/// panels (layout of [`crate::gemm::pack::pack_a_dual`] /
/// [`crate::gemm::pack::pack_b_dual`] at `(MAX_MR, MAX_NR)`): per k
/// step, the high·high plane takes `hh = fma(a_h, b_h, hh)` and the
/// correction plane takes `corr = fma(a_h, b_l, fma(a_l, b_h, corr))`
/// — this lane's pinned correction-chain order, applied per 16-lane
/// row. Corrections aggregate among themselves and meet the high
/// product only at the tile combine (Sec. 4.4), exactly as in
/// [`super::scalar::kernel_cube`]. Fully overwrites
/// `hh[..MAX_MR·MAX_NR]` and `corr[..MAX_MR·MAX_NR]`.
///
/// # Safety
///
/// The caller must ensure the executing CPU supports AVX-512F
/// (`Lane::Avx512.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be wide dual panels for the same `kc`:
/// `apanel.len() == kc·2·MAX_MR` and `bpanel.len() == kc·2·MAX_NR`;
/// `hh`/`corr` must each hold at least `MAX_MR·MAX_NR` elements.
#[target_feature(enable = "avx512f")]
pub unsafe fn kernel_cube(apanel: &[f32], bpanel: &[f32], hh: &mut [f32], corr: &mut [f32]) {
    let steps = bpanel.len() / (2 * MAX_NR);
    debug_assert_eq!(apanel.len(), steps * 2 * MAX_MR);
    debug_assert_eq!(bpanel.len(), steps * 2 * MAX_NR);
    debug_assert!(hh.len() >= MAX_MR * MAX_NR && corr.len() >= MAX_MR * MAX_NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut hacc = [_mm512_setzero_ps(); MAX_MR];
    let mut cacc = [_mm512_setzero_ps(); MAX_MR];
    for p in 0..steps {
        let bh = _mm512_loadu_ps(b.add(p * 2 * MAX_NR));
        let bl = _mm512_loadu_ps(b.add(p * 2 * MAX_NR + MAX_NR));
        let ap = a.add(p * 2 * MAX_MR);
        for (i, (hhr, corrr)) in hacc.iter_mut().zip(cacc.iter_mut()).enumerate() {
            let ah = _mm512_set1_ps(*ap.add(i));
            let al = _mm512_set1_ps(*ap.add(MAX_MR + i));
            *hhr = _mm512_fmadd_ps(ah, bh, *hhr);
            *corrr = _mm512_fmadd_ps(ah, bl, _mm512_fmadd_ps(al, bh, *corrr));
        }
    }
    store_tile(&hacc, hh);
    store_tile(&cacc, corr);
}

/// AVX-512 generic N-term family micro-kernel over `ncomp`-component
/// wide panels ([`crate::gemm::pack::pack_a_multi`] / `pack_b_multi`
/// layout at `(MAX_MR, MAX_NR)`): one ZMM accumulator plane per term
/// order `d < ncomp`. Per k step each order chains its kept products as
/// nested FMAs with the *highest* `a` component joining first — the
/// same convention as [`kernel_cube`]'s correction chain, generalized.
/// Fully overwrites `out[..MAX_COMPONENTS·MAX_MR·MAX_NR]` (plane `d` at
/// `out[d·MAX_MR·MAX_NR..]`); planes of order ≥ `ncomp` are exactly
/// zero.
///
/// The engine dispatches `ncomp == 2` to [`kernel_cube`] instead; this
/// generic path serves `ncomp ≥ 3`.
///
/// # Safety
///
/// The caller must ensure the executing CPU supports AVX-512F
/// (`Lane::Avx512.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be `ncomp`-component wide panels for the same
/// `kc`: `apanel.len() == kc·ncomp·MAX_MR` and
/// `bpanel.len() == kc·ncomp·MAX_NR`, with
/// `2 <= ncomp <= MAX_COMPONENTS`; `out` must hold at least
/// `MAX_COMPONENTS·MAX_MR·MAX_NR` elements.
#[target_feature(enable = "avx512f")]
pub unsafe fn kernel_family(apanel: &[f32], bpanel: &[f32], ncomp: usize, out: &mut [f32]) {
    debug_assert!((2..=MAX_COMPONENTS).contains(&ncomp));
    let steps = bpanel.len() / (ncomp * MAX_NR);
    debug_assert_eq!(apanel.len(), steps * ncomp * MAX_MR);
    debug_assert_eq!(bpanel.len(), steps * ncomp * MAX_NR);
    debug_assert!(out.len() >= MAX_COMPONENTS * MAX_MR * MAX_NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut acc = [[_mm512_setzero_ps(); MAX_MR]; MAX_COMPONENTS];
    for p in 0..steps {
        let mut bv = [_mm512_setzero_ps(); MAX_COMPONENTS];
        for (c, slot) in bv.iter_mut().enumerate().take(ncomp) {
            *slot = _mm512_loadu_ps(b.add(p * ncomp * MAX_NR + c * MAX_NR));
        }
        let ap = a.add(p * ncomp * MAX_MR);
        for i in 0..MAX_MR {
            let mut av = [_mm512_setzero_ps(); MAX_COMPONENTS];
            for (c, slot) in av.iter_mut().enumerate().take(ncomp) {
                *slot = _mm512_set1_ps(*ap.add(c * MAX_MR + i));
            }
            for (d, plane) in acc.iter_mut().enumerate().take(ncomp) {
                let mut v = plane[i];
                for ci in (0..=d).rev() {
                    v = _mm512_fmadd_ps(av[ci], bv[d - ci], v);
                }
                plane[i] = v;
            }
        }
    }
    for (d, plane) in acc.iter().enumerate() {
        store_tile(plane, &mut out[d * MAX_MR * MAX_NR..(d + 1) * MAX_MR * MAX_NR]);
    }
}

/// Spill `MAX_MR` ZMM accumulators into the flat row-major tile the
/// shared C-update path ([`crate::gemm::blocked`]) consumes. Compiled
/// with the same target features as its callers.
#[target_feature(enable = "avx512f")]
unsafe fn store_tile(acc: &[__m512; MAX_MR], out: &mut [f32]) {
    let p = out.as_mut_ptr();
    for (i, v) in acc.iter().enumerate() {
        _mm512_storeu_ps(p.add(i * MAX_NR), *v);
    }
}
