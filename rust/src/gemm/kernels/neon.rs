//! NEON micro-kernels (aarch64).
//!
//! NEON q-registers are 128 bits — four f32 lanes — so the shared
//! `NR = 8` micro-tile row is a **pair** of q-register accumulators.
//! The f32 kernel holds `2·MR = 8` accumulators and the fused cube
//! kernel `4·MR = 16` (high·high plane + correction plane, two vectors
//! each), comfortably inside the 32-register file — the register
//! budget [`crate::sim::blocking::micro_tile`] derives.
//!
//! Pinned accumulation contract of this lane (see [`super`] for the
//! cross-lane comparison): every chain step is a **fused** multiply-add
//! (`vfmaq_f32`, one rounding), and the cube correction chain is
//! `corr = fma(a_h, b_l, fma(a_l, b_h, corr))` — the `a_l·b_h` term
//! joins first, the same order the AVX2 lane pins. The two lanes are
//! *still* not bit-interchangeable in general (they only ever run on
//! different hosts); the contract is pinned per lane.

use core::arch::aarch64::{float32x4_t, vdupq_n_f32, vfmaq_f32, vld1q_f32, vst1q_f32};

use crate::gemm::pack::{MR, NR};
use crate::softfloat::family::MAX_COMPONENTS;

// The kernels below hard-code "one row == two q-registers"; refuse to
// compile if the shared micro-tile geometry ever drifts.
const _: () = assert!(MR == 4 && NR == 8, "NEON lane is written for a 4x8 micro-tile");

/// NEON `MR × NR` f32 micro-kernel: two q-register accumulators per
/// row, one fused multiply-add per half-row per k step. Panel layout
/// and the chain-per-cell semantics match
/// [`super::scalar::kernel_f32`]; only the per-step rounding differs
/// (fused, one rounding).
///
/// # Safety
///
/// The caller must ensure the executing CPU supports NEON
/// (`Lane::Neon.is_available()`, checked by [`super::dispatch`] —
/// always true on aarch64). `apanel`/`bpanel` must be panels for the
/// same `kc`: `apanel.len() == kc·MR` and `bpanel.len() == kc·NR`.
#[target_feature(enable = "neon")]
pub unsafe fn kernel_f32(apanel: &[f32], bpanel: &[f32]) -> [[f32; NR]; MR] {
    let steps = bpanel.len() / NR;
    debug_assert_eq!(apanel.len(), steps * MR);
    debug_assert_eq!(bpanel.len(), steps * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut acc = [[vdupq_n_f32(0.0); 2]; MR];
    for p in 0..steps {
        let b0 = vld1q_f32(b.add(p * NR));
        let b1 = vld1q_f32(b.add(p * NR + 4));
        let ap = a.add(p * MR);
        for (i, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f32(*ap.add(i));
            accr[0] = vfmaq_f32(accr[0], av, b0);
            accr[1] = vfmaq_f32(accr[1], av, b1);
        }
    }
    store_tile(&acc)
}

/// NEON fused three-term cube micro-kernel over dual-component panels
/// (layout of [`crate::gemm::pack::pack_a_dual`] /
/// [`crate::gemm::pack::pack_b_dual`]): per k step, the high·high plane
/// takes `hh = fma(a_h, b_h, hh)` and the correction plane takes
/// `corr = fma(a_h, b_l, fma(a_l, b_h, corr))` — this lane's pinned
/// correction-chain order, applied per 4-lane half-row. Corrections
/// aggregate among themselves and meet the high product only at the
/// tile combine (Sec. 4.4), exactly as in
/// [`super::scalar::kernel_cube`].
///
/// # Safety
///
/// The caller must ensure the executing CPU supports NEON
/// (`Lane::Neon.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be dual panels for the same `kc`:
/// `apanel.len() == kc·2·MR` and `bpanel.len() == kc·2·NR`.
#[target_feature(enable = "neon")]
pub unsafe fn kernel_cube(apanel: &[f32], bpanel: &[f32]) -> ([[f32; NR]; MR], [[f32; NR]; MR]) {
    let steps = bpanel.len() / (2 * NR);
    debug_assert_eq!(apanel.len(), steps * 2 * MR);
    debug_assert_eq!(bpanel.len(), steps * 2 * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut hh = [[vdupq_n_f32(0.0); 2]; MR];
    let mut corr = [[vdupq_n_f32(0.0); 2]; MR];
    for p in 0..steps {
        let bh0 = vld1q_f32(b.add(p * 2 * NR));
        let bh1 = vld1q_f32(b.add(p * 2 * NR + 4));
        let bl0 = vld1q_f32(b.add(p * 2 * NR + NR));
        let bl1 = vld1q_f32(b.add(p * 2 * NR + NR + 4));
        let ap = a.add(p * 2 * MR);
        for (i, (hhr, corrr)) in hh.iter_mut().zip(corr.iter_mut()).enumerate() {
            let ah = vdupq_n_f32(*ap.add(i));
            let al = vdupq_n_f32(*ap.add(MR + i));
            hhr[0] = vfmaq_f32(hhr[0], ah, bh0);
            hhr[1] = vfmaq_f32(hhr[1], ah, bh1);
            corrr[0] = vfmaq_f32(vfmaq_f32(corrr[0], al, bh0), ah, bl0);
            corrr[1] = vfmaq_f32(vfmaq_f32(corrr[1], al, bh1), ah, bl1);
        }
    }
    (store_tile(&hh), store_tile(&corr))
}

/// NEON generic N-term family micro-kernel over `ncomp`-component
/// panels ([`crate::gemm::pack::pack_a_multi`] / `pack_b_multi`
/// layout): one q-register-pair accumulator plane per term order
/// `d < ncomp`. Per k step each order chains its kept products as
/// nested FMAs with the *highest* `a` component joining first — the
/// same convention as [`kernel_cube`]'s correction chain, generalized,
/// applied per 4-lane half-row. Planes of order ≥ `ncomp` stay exactly
/// zero.
///
/// The engine dispatches `ncomp == 2` to [`kernel_cube`] instead; this
/// generic path serves `ncomp ≥ 3`.
///
/// # Safety
///
/// The caller must ensure the executing CPU supports NEON
/// (`Lane::Neon.is_available()`, checked by [`super::dispatch`]).
/// `apanel`/`bpanel` must be `ncomp`-component panels for the same
/// `kc`: `apanel.len() == kc·ncomp·MR` and
/// `bpanel.len() == kc·ncomp·NR`, with `2 <= ncomp <= MAX_COMPONENTS`.
#[target_feature(enable = "neon")]
pub unsafe fn kernel_family(
    apanel: &[f32],
    bpanel: &[f32],
    ncomp: usize,
) -> [[[f32; NR]; MR]; MAX_COMPONENTS] {
    debug_assert!((2..=MAX_COMPONENTS).contains(&ncomp));
    let steps = bpanel.len() / (ncomp * NR);
    debug_assert_eq!(apanel.len(), steps * ncomp * MR);
    debug_assert_eq!(bpanel.len(), steps * ncomp * NR);
    let a = apanel.as_ptr();
    let b = bpanel.as_ptr();
    let mut acc = [[[vdupq_n_f32(0.0); 2]; MR]; MAX_COMPONENTS];
    for p in 0..steps {
        let mut bv = [[vdupq_n_f32(0.0); 2]; MAX_COMPONENTS];
        for (c, slot) in bv.iter_mut().enumerate().take(ncomp) {
            slot[0] = vld1q_f32(b.add(p * ncomp * NR + c * NR));
            slot[1] = vld1q_f32(b.add(p * ncomp * NR + c * NR + 4));
        }
        let ap = a.add(p * ncomp * MR);
        for i in 0..MR {
            let mut av = [vdupq_n_f32(0.0); MAX_COMPONENTS];
            for (c, slot) in av.iter_mut().enumerate().take(ncomp) {
                *slot = vdupq_n_f32(*ap.add(c * MR + i));
            }
            for (d, plane) in acc.iter_mut().enumerate().take(ncomp) {
                let mut v0 = plane[i][0];
                let mut v1 = plane[i][1];
                for ci in (0..=d).rev() {
                    v0 = vfmaq_f32(v0, av[ci], bv[d - ci][0]);
                    v1 = vfmaq_f32(v1, av[ci], bv[d - ci][1]);
                }
                plane[i][0] = v0;
                plane[i][1] = v1;
            }
        }
    }
    let mut out = [[[0.0f32; NR]; MR]; MAX_COMPONENTS];
    for (dst, plane) in out.iter_mut().zip(&acc) {
        *dst = store_tile(plane);
    }
    out
}

/// Spill `MR` q-register accumulator pairs into the `[[f32; NR]; MR]`
/// tile shape the shared C-update path ([`crate::gemm::blocked`])
/// consumes. Compiled with the same target features as its callers.
#[target_feature(enable = "neon")]
unsafe fn store_tile(acc: &[[float32x4_t; 2]; MR]) -> [[f32; NR]; MR] {
    let mut out = [[0.0f32; NR]; MR];
    for (dst, v) in out.iter_mut().zip(acc) {
        vst1q_f32(dst.as_mut_ptr(), v[0]);
        vst1q_f32(dst.as_mut_ptr().add(4), v[1]);
    }
    out
}
