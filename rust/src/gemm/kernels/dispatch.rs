//! Runtime lane selection for the micro-kernels.
//!
//! The engine resolves a [`Lane`] once per sweep (the hot loops never
//! re-check CPU features) from, in priority order:
//!
//! 1. a programmatic [`force_lane`] call (benches and the dispatch test
//!    suite use this to pin a lane mid-process);
//! 2. the `SGEMM_CUBE_KERNEL` environment variable — `scalar`, `avx2`,
//!    `neon`, `avx512` or `auto`; an unavailable or unrecognized value
//!    warns on stderr and falls back to detection, it never aborts
//!    (same contract as `SGEMM_CUBE_SCHEDULE`,
//!    [`crate::gemm::backend::default_schedule`]);
//! 3. CPU feature detection ([`detect_lane`]): AVX-512F, then AVX2+FMA
//!    on x86_64, NEON on aarch64, scalar otherwise.
//!
//! Selection state is one relaxed `AtomicU8`: a load on the sweep path,
//! a store in [`force_lane`]. Forcing a lane affects *subsequent*
//! sweeps; tests that force lanes serialize themselves (see
//! `tests/dispatch.rs`) because the knob is process-global.
//!
//! **Lanes carry their micro-tile geometry** ([`Lane::tile_dims`]): the
//! scalar/AVX2/NEON lanes run the narrow `MR × NR = 4 × 8` tile, the
//! AVX-512 lane the wide `MAX_MR × MAX_NR = 8 × 16` tile its 32-zmm
//! register file supports. Because panel layout follows the tile dims,
//! a caller must resolve the lane **once** per GEMM call and use it for
//! both packing and kernel dispatch — the sweep drivers in
//! [`crate::gemm::blocked`] and the ring drivers in
//! [`crate::exec::pipeline`] all take the lane as an explicit
//! parameter for exactly this reason.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::gemm::kernels::scalar;
use crate::gemm::pack::{MAX_MR, MAX_NR, MR, NR};
use crate::softfloat::family::MAX_COMPONENTS;

/// One micro-kernel implementation family. The lane decides how each
/// FP32 accumulation-chain step rounds (see the
/// [`crate::gemm::kernels`] contract) **and** the micro-tile / panel
/// geometry ([`Lane::tile_dims`]); block order and schedules remain
/// lane-independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Portable Rust ([`super::scalar`]): rounded multiply + rounded
    /// add per step. Always available. Narrow 4×8 tile.
    Scalar,
    /// AVX2 + FMA intrinsics (the arch-gated `super::avx2` module):
    /// fused multiply-add, one rounding per step. x86_64 with AVX2 and
    /// FMA only. Narrow 4×8 tile.
    Avx2,
    /// NEON intrinsics (the arch-gated `super::neon` module): fused
    /// multiply-add, one rounding per step. aarch64 only. Narrow 4×8
    /// tile.
    Neon,
    /// AVX-512F intrinsics (the arch-gated `super::avx512` module):
    /// fused multiply-add, one rounding per step, over the wide 8×16
    /// tile re-derived from the 32-entry zmm register file
    /// ([`crate::sim::blocking::micro_tile`]). x86_64 with AVX-512F
    /// only (512-bit FMA is part of AVX-512F).
    Avx512,
}

impl Lane {
    /// Every lane, in preference order (widest first, most portable
    /// last).
    pub const ALL: [Lane; 4] = [Lane::Avx512, Lane::Avx2, Lane::Neon, Lane::Scalar];

    /// The lane's `SGEMM_CUBE_KERNEL` spelling (also the bench/EXPERIMENTS
    /// label).
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Avx2 => "avx2",
            Lane::Neon => "neon",
            Lane::Avx512 => "avx512",
        }
    }

    /// Parse an `SGEMM_CUBE_KERNEL` value. `None` for anything that is
    /// not a known lane name (including `auto`, which callers map to
    /// detection).
    pub fn parse(s: &str) -> Option<Lane> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Lane::Scalar),
            "avx2" => Some(Lane::Avx2),
            "neon" => Some(Lane::Neon),
            "avx512" => Some(Lane::Avx512),
            _ => None,
        }
    }

    /// Whether this lane can execute on the current host. Scalar is
    /// always available; the SIMD lanes require both the compile target
    /// and the runtime CPU features (cached by `std`'s detection
    /// macros, so this is an atomic load after the first call).
    pub fn is_available(self) -> bool {
        match self {
            Lane::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Lane::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            Lane::Avx2 => false,
            #[cfg(target_arch = "x86_64")]
            Lane::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(not(target_arch = "x86_64"))]
            Lane::Avx512 => false,
            #[cfg(target_arch = "aarch64")]
            Lane::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(not(target_arch = "aarch64"))]
            Lane::Neon => false,
        }
    }

    /// Stable numeric code for bench records (`kernel/lane` in
    /// BENCH_gemm.json): scalar = 0, avx2 = 1, neon = 2, avx512 = 3.
    pub fn code(self) -> u8 {
        match self {
            Lane::Scalar => 0,
            Lane::Avx2 => 1,
            Lane::Neon => 2,
            Lane::Avx512 => 3,
        }
    }

    /// The `(mr, nr)` micro-tile this lane runs — and therefore the
    /// panel interleave every operand packed for this lane uses. The
    /// narrow lanes share `(MR, NR) = (4, 8)`; the AVX-512 lane's
    /// 32-zmm file supports `(MAX_MR, MAX_NR) = (8, 16)`
    /// (`sim::blocking::micro_tile(32, 16)`).
    pub fn tile_dims(self) -> (usize, usize) {
        match self {
            Lane::Avx512 => (MAX_MR, MAX_NR),
            Lane::Scalar | Lane::Avx2 | Lane::Neon => (MR, NR),
        }
    }

    fn from_code(code: u8) -> Lane {
        match code {
            0 => Lane::Scalar,
            1 => Lane::Avx2,
            2 => Lane::Neon,
            3 => Lane::Avx512,
            _ => unreachable!("invalid lane code {code}"),
        }
    }
}

impl std::fmt::Display for Lane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The best lane the current host supports, ignoring the environment
/// override: the first available entry of [`Lane::ALL`].
pub fn detect_lane() -> Lane {
    Lane::ALL.into_iter().find(|l| l.is_available()).unwrap_or(Lane::Scalar)
}

/// Resolve the process-initial lane: `SGEMM_CUBE_KERNEL` if set and
/// usable, detection otherwise. Split out of [`active_lane`] so the
/// fallback policy is unit-testable without touching process state.
fn initial_lane(env: Option<&str>) -> Lane {
    let Some(v) = env else { return detect_lane() };
    if v.trim().is_empty() || v.trim().eq_ignore_ascii_case("auto") {
        return detect_lane();
    }
    match Lane::parse(v) {
        Some(lane) if lane.is_available() => lane,
        Some(lane) => {
            eprintln!(
                "SGEMM_CUBE_KERNEL={v}: lane '{lane}' is not available on this host; \
                 falling back to '{}'",
                detect_lane()
            );
            detect_lane()
        }
        None => {
            eprintln!(
                "SGEMM_CUBE_KERNEL={v}: unrecognized lane \
                 (expected scalar|avx2|neon|avx512|auto); falling back to '{}'",
                detect_lane()
            );
            detect_lane()
        }
    }
}

/// Unset marker for the lane cell; real lanes use [`Lane::code`] 0–3.
const LANE_UNSET: u8 = u8::MAX;

static LANE: AtomicU8 = AtomicU8::new(LANE_UNSET);

/// The lane the sweeps will use, resolving and caching the
/// `SGEMM_CUBE_KERNEL` / detection decision on first use. One relaxed
/// atomic load thereafter — cheap enough to call once per GEMM call,
/// which is exactly what [`crate::gemm::blocked`] does (the lane is
/// *not* re-read per sweep or per micro-tile, so a concurrent
/// [`force_lane`] never splits one call's pack geometry from its
/// kernels).
pub fn active_lane() -> Lane {
    match LANE.load(Ordering::Relaxed) {
        LANE_UNSET => {
            let lane = initial_lane(std::env::var("SGEMM_CUBE_KERNEL").ok().as_deref());
            // First writer wins so concurrent initializers agree.
            match LANE.compare_exchange(
                LANE_UNSET,
                lane.code(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => lane,
                Err(cur) => Lane::from_code(cur),
            }
        }
        code => Lane::from_code(code),
    }
}

/// Pin the active lane for all subsequent sweeps. Returns `false`
/// (changing nothing) if the lane is unavailable on this host. This is
/// process-global state for benches (`blocked/simd_speedup` measures
/// forced-scalar vs. detected) and the dispatch test suite; serving
/// code configures lanes via `SGEMM_CUBE_KERNEL` instead.
pub fn force_lane(lane: Lane) -> bool {
    if !lane.is_available() {
        return false;
    }
    LANE.store(lane.code(), Ordering::Relaxed);
    true
}

/// Copy a narrow-lane `[MR][NR]` register tile into the flat
/// `mr·nr`-row-major output the sweeps consume (row `i` at
/// `out[i·NR..]`).
#[inline]
fn copy_narrow_tile(tile: &[[f32; NR]; MR], out: &mut [f32]) {
    for (i, row) in tile.iter().enumerate() {
        out[i * NR..(i + 1) * NR].copy_from_slice(row);
    }
}

/// Run the lane's f32 micro-kernel, fully overwriting
/// `out[..mr·nr]` (row-major: cell `(i, j)` at `out[i·nr + j]`, dims
/// from [`Lane::tile_dims`]). Panels must be packed with the *same*
/// lane's tile dims. Panics if a SIMD lane is requested on a host that
/// cannot execute it (the check is what makes this safe to expose;
/// [`active_lane`] / [`force_lane`] only ever hand out available
/// lanes).
#[inline]
pub fn kernel_f32(lane: Lane, apanel: &[f32], bpanel: &[f32], out: &mut [f32]) {
    match lane {
        Lane::Scalar => copy_narrow_tile(&scalar::kernel_f32(apanel, bpanel), out),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => {
            assert!(lane.is_available(), "avx2 lane dispatched on a host without AVX2+FMA");
            // SAFETY: availability checked above; panel lengths are
            // validated by the kernel's debug asserts.
            copy_narrow_tile(unsafe { &super::avx2::kernel_f32(apanel, bpanel) }, out)
        }
        #[cfg(target_arch = "x86_64")]
        Lane::Avx512 => {
            assert!(lane.is_available(), "avx512 lane dispatched on a host without AVX-512F");
            // SAFETY: availability checked above.
            unsafe { super::avx512::kernel_f32(apanel, bpanel, out) }
        }
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => {
            assert!(lane.is_available(), "neon lane dispatched on a host without NEON");
            // SAFETY: availability checked above.
            copy_narrow_tile(unsafe { &super::neon::kernel_f32(apanel, bpanel) }, out)
        }
        other => panic!("lane '{other}' cannot execute on this target"),
    }
}

/// Run the lane's fused three-term cube micro-kernel over
/// dual-component panels, fully overwriting the high·high plane
/// `hh[..mr·nr]` and the correction plane `corr[..mr·nr]` (row-major,
/// dims from [`Lane::tile_dims`]; see [`kernel_f32`] for the dispatch
/// contract).
#[inline]
pub fn kernel_cube(lane: Lane, apanel: &[f32], bpanel: &[f32], hh: &mut [f32], corr: &mut [f32]) {
    match lane {
        Lane::Scalar => {
            let (h, c) = scalar::kernel_cube(apanel, bpanel);
            copy_narrow_tile(&h, hh);
            copy_narrow_tile(&c, corr);
        }
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => {
            assert!(lane.is_available(), "avx2 lane dispatched on a host without AVX2+FMA");
            // SAFETY: availability checked above.
            let (h, c) = unsafe { super::avx2::kernel_cube(apanel, bpanel) };
            copy_narrow_tile(&h, hh);
            copy_narrow_tile(&c, corr);
        }
        #[cfg(target_arch = "x86_64")]
        Lane::Avx512 => {
            assert!(lane.is_available(), "avx512 lane dispatched on a host without AVX-512F");
            // SAFETY: availability checked above.
            unsafe { super::avx512::kernel_cube(apanel, bpanel, hh, corr) }
        }
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => {
            assert!(lane.is_available(), "neon lane dispatched on a host without NEON");
            // SAFETY: availability checked above.
            let (h, c) = unsafe { super::neon::kernel_cube(apanel, bpanel) };
            copy_narrow_tile(&h, hh);
            copy_narrow_tile(&c, corr);
        }
        other => panic!("lane '{other}' cannot execute on this target"),
    }
}

/// Run the generic N-term family micro-kernel on an explicit lane over
/// `ncomp`-component panels, fully overwriting
/// `out[..MAX_COMPONENTS·mr·nr]`: one row-major accumulator plane per
/// term order, plane `d` at `out[d·mr·nr..]`, planes past `ncomp`
/// exactly zero.
///
/// `ncomp == 2` dispatches to the dedicated [`kernel_cube`] — the dual
/// and 2-component panel layouts coincide, and routing through the
/// original kernel keeps every N = 2 tier bit-identical to the
/// pre-family engine. `ncomp >= 3` runs the lane's generic fused sweep.
#[inline]
pub fn kernel_family(lane: Lane, apanel: &[f32], bpanel: &[f32], ncomp: usize, out: &mut [f32]) {
    let (mr, nr) = lane.tile_dims();
    let plane = mr * nr;
    if ncomp == 2 {
        out[2 * plane..MAX_COMPONENTS * plane].fill(0.0);
        let (hh, rest) = out.split_at_mut(plane);
        kernel_cube(lane, apanel, bpanel, hh, &mut rest[..plane]);
        return;
    }
    match lane {
        Lane::Scalar => {
            let planes = scalar::kernel_family(apanel, bpanel, ncomp);
            for (d, p) in planes.iter().enumerate() {
                copy_narrow_tile(p, &mut out[d * plane..(d + 1) * plane]);
            }
        }
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => {
            assert!(lane.is_available(), "avx2 lane dispatched on a host without AVX2+FMA");
            // SAFETY: availability checked above.
            let planes = unsafe { super::avx2::kernel_family(apanel, bpanel, ncomp) };
            for (d, p) in planes.iter().enumerate() {
                copy_narrow_tile(p, &mut out[d * plane..(d + 1) * plane]);
            }
        }
        #[cfg(target_arch = "x86_64")]
        Lane::Avx512 => {
            assert!(lane.is_available(), "avx512 lane dispatched on a host without AVX-512F");
            // SAFETY: availability checked above.
            unsafe { super::avx512::kernel_family(apanel, bpanel, ncomp, out) }
        }
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => {
            assert!(lane.is_available(), "neon lane dispatched on a host without NEON");
            // SAFETY: availability checked above.
            let planes = unsafe { super::neon::kernel_family(apanel, bpanel, ncomp) };
            for (d, p) in planes.iter().enumerate() {
                copy_narrow_tile(p, &mut out[d * plane..(d + 1) * plane]);
            }
        }
        other => panic!("lane '{other}' cannot execute on this target"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random single-component panels for a `mr × nr` lane tile.
    fn panels(kc: usize, mr: usize, nr: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let ap: Vec<f32> = (0..kc * mr).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let bp: Vec<f32> = (0..kc * nr).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        (ap, bp)
    }

    fn multi_panels(kc: usize, ncomp: usize, mr: usize, nr: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let ap: Vec<f32> = (0..kc * ncomp * mr).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let bp: Vec<f32> = (0..kc * ncomp * nr).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        (ap, bp)
    }

    #[test]
    fn lane_names_round_trip() {
        for lane in Lane::ALL {
            assert_eq!(Lane::parse(lane.name()), Some(lane));
            assert_eq!(Lane::parse(&lane.name().to_uppercase()), Some(lane));
            assert_eq!(Lane::from_code(lane.code()), lane);
            assert_eq!(format!("{lane}"), lane.name());
        }
        assert_eq!(Lane::parse("auto"), None);
        assert_eq!(Lane::parse("avx"), None);
        assert_eq!(Lane::parse(""), None);
    }

    #[test]
    fn tile_dims_follow_the_register_files() {
        // Narrow lanes share the 4×8 tile; the AVX-512 lane runs the
        // wide 8×16 tile micro_tile derives from 32 zmm registers.
        for lane in [Lane::Scalar, Lane::Avx2, Lane::Neon] {
            assert_eq!(lane.tile_dims(), (MR, NR), "{lane}");
        }
        assert_eq!(Lane::Avx512.tile_dims(), (MAX_MR, MAX_NR));
        // MAX_* really is the maximum over the registry — the sweeps'
        // stack tiles depend on it.
        for lane in Lane::ALL {
            let (mr, nr) = lane.tile_dims();
            assert!(mr <= MAX_MR && nr <= MAX_NR, "{lane}");
        }
    }

    #[test]
    fn initial_lane_fallback_policy() {
        // Unset / auto / empty -> detection.
        assert_eq!(initial_lane(None), detect_lane());
        assert_eq!(initial_lane(Some("auto")), detect_lane());
        assert_eq!(initial_lane(Some(" AUTO ")), detect_lane());
        assert_eq!(initial_lane(Some("")), detect_lane());
        // Unrecognized -> warn + detection, never abort.
        assert_eq!(initial_lane(Some("fastest")), detect_lane());
        // Scalar is always honored.
        assert_eq!(initial_lane(Some("scalar")), Lane::Scalar);
        // Available lanes are honored; unavailable ones fall back.
        for lane in Lane::ALL {
            let got = initial_lane(Some(lane.name()));
            if lane.is_available() {
                assert_eq!(got, lane);
            } else {
                assert_eq!(got, detect_lane());
            }
        }
    }

    #[test]
    fn detection_is_available_and_preferred() {
        let lane = detect_lane();
        assert!(lane.is_available());
        // No lane earlier in preference order is available.
        for cand in Lane::ALL {
            if cand == lane {
                break;
            }
            assert!(!cand.is_available(), "{cand} available but {lane} detected");
        }
        // The scalar fallback can always execute.
        assert!(Lane::Scalar.is_available());
        // active_lane only ever hands out an executable lane.
        assert!(active_lane().is_available());
    }

    #[test]
    fn force_rejects_unavailable_lanes() {
        for lane in Lane::ALL {
            if !lane.is_available() {
                let before = active_lane();
                assert!(!force_lane(lane));
                assert_eq!(active_lane(), before, "rejected force must not change the lane");
            }
        }
    }

    #[test]
    fn lanes_agree_within_fma_rounding() {
        // Every available lane against a direct f64 reference on
        // logically identical operands (each lane packs its own tile
        // geometry from common coefficient streams): each f32 chain
        // step differs from exact by at most a couple of roundings, so
        // the results agree within a standard forward-error envelope of
        // the absolute-value dot product. Explicit-lane calls — no
        // global state, no races with concurrently running sweeps.
        let kc = 96;
        let envelope = |absdot: f64| 4.0 * (kc as f64) * (f32::EPSILON as f64) * absdot.max(1.0);
        // Common logical operands: A is MAX_MR × kc, B is kc × MAX_NR
        // (dual components for the cube check).
        let mut rng = Rng::new(7);
        let a: Vec<f32> = (0..MAX_MR * kc).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..kc * MAX_NR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let al: Vec<f32> = (0..MAX_MR * kc).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let bl: Vec<f32> = (0..kc * MAX_NR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (mr, nr) = lane.tile_dims();
            // Pack this lane's panels from the common operands.
            let mut ap = vec![0.0f32; kc * mr];
            let mut bp = vec![0.0f32; kc * nr];
            let mut dap = vec![0.0f32; kc * 2 * mr];
            let mut dbp = vec![0.0f32; kc * 2 * nr];
            for p in 0..kc {
                for i in 0..mr {
                    ap[p * mr + i] = a[i * kc + p];
                    dap[p * 2 * mr + i] = a[i * kc + p];
                    dap[p * 2 * mr + mr + i] = al[i * kc + p];
                }
                for j in 0..nr {
                    bp[p * nr + j] = b[p * MAX_NR + j];
                    dbp[p * 2 * nr + j] = b[p * MAX_NR + j];
                    dbp[p * 2 * nr + nr + j] = bl[p * MAX_NR + j];
                }
            }
            let mut tile = vec![0.0f32; mr * nr];
            kernel_f32(lane, &ap, &bp, &mut tile);
            let mut hh = vec![0.0f32; mr * nr];
            let mut corr = vec![0.0f32; mr * nr];
            kernel_cube(lane, &dap, &dbp, &mut hh, &mut corr);
            for i in 0..mr {
                for j in 0..nr {
                    let mut dot = 0.0f64;
                    let mut absdot = 0.0f64;
                    let mut hi = 0.0f64;
                    let mut abshi = 0.0f64;
                    let mut co = 0.0f64;
                    let mut absco = 0.0f64;
                    for p in 0..kc {
                        let (ah, alo) = (a[i * kc + p] as f64, al[i * kc + p] as f64);
                        let (bh, blo) = (b[p * MAX_NR + j] as f64, bl[p * MAX_NR + j] as f64);
                        dot += ah * bh;
                        absdot += (ah * bh).abs();
                        hi += ah * bh;
                        abshi += (ah * bh).abs();
                        co += ah * blo + alo * bh;
                        absco += (ah * blo).abs() + (alo * bh).abs();
                    }
                    let got = tile[i * nr + j] as f64;
                    assert!((got - dot).abs() <= envelope(absdot), "{lane} f32 [{i}][{j}]");
                    let ghh = hh[i * nr + j] as f64;
                    assert!((ghh - hi).abs() <= envelope(abshi), "{lane} hh [{i}][{j}]");
                    let gco = corr[i * nr + j] as f64;
                    assert!((gco - co).abs() <= envelope(absco), "{lane} corr [{i}][{j}]");
                }
            }
        }
    }

    #[test]
    fn family_at_two_components_is_kernel_cube_bitwise() {
        // The N = 2 family tier must be served by the original cube
        // kernel — same panels in, same bits out, on every lane.
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (mr, nr) = lane.tile_dims();
            let plane = mr * nr;
            let (dap, dbp) = multi_panels(96, 2, mr, nr, 21);
            let mut hh = vec![0.0f32; plane];
            let mut corr = vec![0.0f32; plane];
            kernel_cube(lane, &dap, &dbp, &mut hh, &mut corr);
            let mut fam = vec![f32::NAN; MAX_COMPONENTS * plane];
            kernel_family(lane, &dap, &dbp, 2, &mut fam);
            for c in 0..plane {
                assert_eq!(fam[c].to_bits(), hh[c].to_bits(), "{lane}");
                assert_eq!(fam[plane + c].to_bits(), corr[c].to_bits(), "{lane}");
                assert_eq!(fam[2 * plane + c], 0.0, "{lane}");
                assert_eq!(fam[3 * plane + c], 0.0, "{lane}");
            }
        }
    }

    #[test]
    fn family_three_components_lanes_agree_within_fma_rounding() {
        let kc = 64;
        let ncomp = 3;
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (mr, nr) = lane.tile_dims();
            let plane = mr * nr;
            let (ap, bp) = multi_panels(kc, ncomp, mr, nr, 22);
            let mut got = vec![f32::NAN; MAX_COMPONENTS * plane];
            kernel_family(lane, &ap, &bp, ncomp, &mut got);
            // Unused planes are exactly zero, and plane d holds the
            // kept order-d products (checked against a direct f64 sum).
            for i in 0..mr {
                for j in 0..nr {
                    assert_eq!(got[3 * plane + i * nr + j], 0.0, "{lane}");
                    for d in 0..ncomp {
                        let mut sum = 0.0f64;
                        for p in 0..kc {
                            for ci in 0..=d {
                                sum += ap[p * ncomp * mr + ci * mr + i] as f64
                                    * bp[p * ncomp * nr + (d - ci) * nr + j] as f64;
                            }
                        }
                        let v = got[d * plane + i * nr + j] as f64;
                        assert!(
                            (sum - v).abs() <= 1e-4 * sum.abs().max(1.0),
                            "{lane} d={d} [{i}][{j}]: {sum} vs {v}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn family_kernel_is_deterministic_per_lane() {
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (mr, nr) = lane.tile_dims();
            let plane = mr * nr;
            let (ap, bp) = multi_panels(48, 3, mr, nr, 23);
            let mut x = vec![0.0f32; MAX_COMPONENTS * plane];
            let mut y = vec![0.0f32; MAX_COMPONENTS * plane];
            kernel_family(lane, &ap, &bp, 3, &mut x);
            kernel_family(lane, &ap, &bp, 3, &mut y);
            for (u, v) in x.iter().zip(&y) {
                assert_eq!(u.to_bits(), v.to_bits(), "{lane}");
            }
        }
    }

    #[test]
    fn every_lane_is_deterministic() {
        // Same lane + same panels -> identical bits, the kernel-level
        // half of the per-lane bit-identity contract (the schedule-level
        // half lives in tests/dispatch.rs).
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (mr, nr) = lane.tile_dims();
            let plane = mr * nr;
            let (ap, bp) = panels(64, mr, nr, 9);
            let (dap, dbp) = multi_panels(64, 2, mr, nr, 10);
            let mut x = vec![0.0f32; plane];
            let mut y = vec![0.0f32; plane];
            kernel_f32(lane, &ap, &bp, &mut x);
            kernel_f32(lane, &ap, &bp, &mut y);
            for (u, v) in x.iter().zip(&y) {
                assert_eq!(u.to_bits(), v.to_bits(), "{lane}");
            }
            let (mut hx, mut cx) = (vec![0.0f32; plane], vec![0.0f32; plane]);
            let (mut hy, mut cy) = (vec![0.0f32; plane], vec![0.0f32; plane]);
            kernel_cube(lane, &dap, &dbp, &mut hx, &mut cx);
            kernel_cube(lane, &dap, &dbp, &mut hy, &mut cy);
            for (px, py) in [(hx, hy), (cx, cy)] {
                for (u, v) in px.iter().zip(&py) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{lane}");
                }
            }
        }
    }

    #[test]
    fn zero_step_panels_yield_zero_tiles() {
        // Empty panels must fully overwrite the (garbage-prefilled)
        // output with exact zeros — the sweeps rely on kernels never
        // reading the previous tile.
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (mr, nr) = lane.tile_dims();
            let plane = mr * nr;
            let mut tile = vec![f32::NAN; plane];
            kernel_f32(lane, &[], &[], &mut tile);
            assert!(tile.iter().all(|&v| v == 0.0), "{lane}");
            let mut hh = vec![f32::NAN; plane];
            let mut corr = vec![f32::NAN; plane];
            kernel_cube(lane, &[], &[], &mut hh, &mut corr);
            assert!(hh.iter().all(|&v| v == 0.0), "{lane}");
            assert!(corr.iter().all(|&v| v == 0.0), "{lane}");
        }
    }
}
