//! Runtime lane selection for the micro-kernels.
//!
//! The engine resolves a [`Lane`] once per sweep (the hot loops never
//! re-check CPU features) from, in priority order:
//!
//! 1. a programmatic [`force_lane`] call (benches and the dispatch test
//!    suite use this to pin a lane mid-process);
//! 2. the `SGEMM_CUBE_KERNEL` environment variable — `scalar`, `avx2`,
//!    `neon` or `auto`; an unavailable or unrecognized value warns on
//!    stderr and falls back to detection, it never aborts (same
//!    contract as `SGEMM_CUBE_SCHEDULE`,
//!    [`crate::gemm::backend::default_schedule`]);
//! 3. CPU feature detection ([`detect_lane`]): AVX2+FMA on x86_64,
//!    NEON on aarch64, scalar otherwise.
//!
//! Selection state is one relaxed `AtomicU8`: a load on the sweep path,
//! a store in [`force_lane`]. Forcing a lane affects *subsequent*
//! sweeps; tests that force lanes serialize themselves (see
//! `tests/dispatch.rs`) because the knob is process-global.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::gemm::kernels::scalar;
use crate::gemm::pack::{MR, NR};
use crate::softfloat::family::MAX_COMPONENTS;

/// One micro-kernel implementation family. The lane decides how each
/// FP32 accumulation-chain step rounds (see the
/// [`crate::gemm::kernels`] contract); everything above the kernels —
/// packing, block order, schedules — is lane-independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Portable Rust ([`super::scalar`]): rounded multiply + rounded
    /// add per step. Always available.
    Scalar,
    /// AVX2 + FMA intrinsics (the arch-gated `super::avx2` module):
    /// fused multiply-add, one rounding per step. x86_64 with AVX2 and
    /// FMA only.
    Avx2,
    /// NEON intrinsics (the arch-gated `super::neon` module): fused
    /// multiply-add, one rounding per step. aarch64 only.
    Neon,
}

impl Lane {
    /// Every lane, in preference order (most portable last).
    pub const ALL: [Lane; 3] = [Lane::Avx2, Lane::Neon, Lane::Scalar];

    /// The lane's `SGEMM_CUBE_KERNEL` spelling (also the bench/EXPERIMENTS
    /// label).
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Avx2 => "avx2",
            Lane::Neon => "neon",
        }
    }

    /// Parse an `SGEMM_CUBE_KERNEL` value. `None` for anything that is
    /// not a known lane name (including `auto`, which callers map to
    /// detection).
    pub fn parse(s: &str) -> Option<Lane> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Lane::Scalar),
            "avx2" => Some(Lane::Avx2),
            "neon" => Some(Lane::Neon),
            _ => None,
        }
    }

    /// Whether this lane can execute on the current host. Scalar is
    /// always available; the SIMD lanes require both the compile target
    /// and the runtime CPU features (cached by `std`'s detection
    /// macros, so this is an atomic load after the first call).
    pub fn is_available(self) -> bool {
        match self {
            Lane::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Lane::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            Lane::Avx2 => false,
            #[cfg(target_arch = "aarch64")]
            Lane::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(not(target_arch = "aarch64"))]
            Lane::Neon => false,
        }
    }

    /// Stable numeric code for bench records (`kernel/lane` in
    /// BENCH_gemm.json): scalar = 0, avx2 = 1, neon = 2.
    pub fn code(self) -> u8 {
        match self {
            Lane::Scalar => 0,
            Lane::Avx2 => 1,
            Lane::Neon => 2,
        }
    }

    fn from_code(code: u8) -> Lane {
        match code {
            0 => Lane::Scalar,
            1 => Lane::Avx2,
            2 => Lane::Neon,
            _ => unreachable!("invalid lane code {code}"),
        }
    }
}

impl std::fmt::Display for Lane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The best lane the current host supports, ignoring the environment
/// override: the first available entry of [`Lane::ALL`].
pub fn detect_lane() -> Lane {
    Lane::ALL.into_iter().find(|l| l.is_available()).unwrap_or(Lane::Scalar)
}

/// Resolve the process-initial lane: `SGEMM_CUBE_KERNEL` if set and
/// usable, detection otherwise. Split out of [`active_lane`] so the
/// fallback policy is unit-testable without touching process state.
fn initial_lane(env: Option<&str>) -> Lane {
    let Some(v) = env else { return detect_lane() };
    if v.trim().is_empty() || v.trim().eq_ignore_ascii_case("auto") {
        return detect_lane();
    }
    match Lane::parse(v) {
        Some(lane) if lane.is_available() => lane,
        Some(lane) => {
            eprintln!(
                "SGEMM_CUBE_KERNEL={v}: lane '{lane}' is not available on this host; \
                 falling back to '{}'",
                detect_lane()
            );
            detect_lane()
        }
        None => {
            eprintln!(
                "SGEMM_CUBE_KERNEL={v}: unrecognized lane (expected scalar|avx2|neon|auto); \
                 falling back to '{}'",
                detect_lane()
            );
            detect_lane()
        }
    }
}

/// Unset marker for the lane cell; real lanes use [`Lane::code`] 0–2.
const LANE_UNSET: u8 = u8::MAX;

static LANE: AtomicU8 = AtomicU8::new(LANE_UNSET);

/// The lane the sweeps will use, resolving and caching the
/// `SGEMM_CUBE_KERNEL` / detection decision on first use. One relaxed
/// atomic load thereafter — cheap enough to call once per sweep, which
/// is exactly what [`crate::gemm::blocked`] does (the lane is *not*
/// re-read per micro-tile, so a concurrent [`force_lane`] never splits
/// a single sweep across lanes).
pub fn active_lane() -> Lane {
    match LANE.load(Ordering::Relaxed) {
        LANE_UNSET => {
            let lane = initial_lane(std::env::var("SGEMM_CUBE_KERNEL").ok().as_deref());
            // First writer wins so concurrent initializers agree.
            match LANE.compare_exchange(
                LANE_UNSET,
                lane.code(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => lane,
                Err(cur) => Lane::from_code(cur),
            }
        }
        code => Lane::from_code(code),
    }
}

/// Pin the active lane for all subsequent sweeps. Returns `false`
/// (changing nothing) if the lane is unavailable on this host. This is
/// process-global state for benches (`blocked/simd_speedup` measures
/// forced-scalar vs. detected) and the dispatch test suite; serving
/// code configures lanes via `SGEMM_CUBE_KERNEL` instead.
pub fn force_lane(lane: Lane) -> bool {
    if !lane.is_available() {
        return false;
    }
    LANE.store(lane.code(), Ordering::Relaxed);
    true
}

/// Run the `MR × NR` f32 micro-kernel on an explicit lane. Panics if a
/// SIMD lane is requested on a host that cannot execute it (the check
/// is what makes this safe to expose; [`active_lane`] / [`force_lane`]
/// only ever hand out available lanes).
#[inline]
pub fn kernel_f32(lane: Lane, apanel: &[f32], bpanel: &[f32]) -> [[f32; NR]; MR] {
    match lane {
        Lane::Scalar => scalar::kernel_f32(apanel, bpanel),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => {
            assert!(lane.is_available(), "avx2 lane dispatched on a host without AVX2+FMA");
            // SAFETY: availability checked above; panel lengths are
            // validated by the kernel's debug asserts.
            unsafe { super::avx2::kernel_f32(apanel, bpanel) }
        }
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => {
            assert!(lane.is_available(), "neon lane dispatched on a host without NEON");
            // SAFETY: availability checked above.
            unsafe { super::neon::kernel_f32(apanel, bpanel) }
        }
        other => panic!("lane '{other}' cannot execute on this target"),
    }
}

/// Run the fused three-term cube micro-kernel on an explicit lane
/// (dual-component panels; see [`kernel_f32`] for the dispatch
/// contract).
#[inline]
pub fn kernel_cube(
    lane: Lane,
    apanel: &[f32],
    bpanel: &[f32],
) -> ([[f32; NR]; MR], [[f32; NR]; MR]) {
    match lane {
        Lane::Scalar => scalar::kernel_cube(apanel, bpanel),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => {
            assert!(lane.is_available(), "avx2 lane dispatched on a host without AVX2+FMA");
            // SAFETY: availability checked above.
            unsafe { super::avx2::kernel_cube(apanel, bpanel) }
        }
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => {
            assert!(lane.is_available(), "neon lane dispatched on a host without NEON");
            // SAFETY: availability checked above.
            unsafe { super::neon::kernel_cube(apanel, bpanel) }
        }
        other => panic!("lane '{other}' cannot execute on this target"),
    }
}

/// Run the generic N-term family micro-kernel on an explicit lane over
/// `ncomp`-component panels; returns one accumulator plane per term
/// order (planes past `ncomp` are exactly zero).
///
/// `ncomp == 2` dispatches to the dedicated [`kernel_cube`] — the dual
/// and 2-component panel layouts coincide, and routing through the
/// original kernel keeps every N = 2 tier bit-identical to the
/// pre-family engine. `ncomp >= 3` runs the lane's generic fused sweep.
#[inline]
pub fn kernel_family(
    lane: Lane,
    apanel: &[f32],
    bpanel: &[f32],
    ncomp: usize,
) -> [[[f32; NR]; MR]; MAX_COMPONENTS] {
    if ncomp == 2 {
        let (hh, corr) = kernel_cube(lane, apanel, bpanel);
        let mut out = [[[0.0f32; NR]; MR]; MAX_COMPONENTS];
        out[0] = hh;
        out[1] = corr;
        return out;
    }
    match lane {
        Lane::Scalar => scalar::kernel_family(apanel, bpanel, ncomp),
        #[cfg(target_arch = "x86_64")]
        Lane::Avx2 => {
            assert!(lane.is_available(), "avx2 lane dispatched on a host without AVX2+FMA");
            // SAFETY: availability checked above.
            unsafe { super::avx2::kernel_family(apanel, bpanel, ncomp) }
        }
        #[cfg(target_arch = "aarch64")]
        Lane::Neon => {
            assert!(lane.is_available(), "neon lane dispatched on a host without NEON");
            // SAFETY: availability checked above.
            unsafe { super::neon::kernel_family(apanel, bpanel, ncomp) }
        }
        other => panic!("lane '{other}' cannot execute on this target"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn panels(kc: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let ap: Vec<f32> = (0..kc * MR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        (ap, bp)
    }

    fn dual_panels(kc: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let ap: Vec<f32> = (0..kc * 2 * MR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let bp: Vec<f32> = (0..kc * 2 * NR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        (ap, bp)
    }

    #[test]
    fn lane_names_round_trip() {
        for lane in Lane::ALL {
            assert_eq!(Lane::parse(lane.name()), Some(lane));
            assert_eq!(Lane::parse(&lane.name().to_uppercase()), Some(lane));
            assert_eq!(Lane::from_code(lane.code()), lane);
            assert_eq!(format!("{lane}"), lane.name());
        }
        assert_eq!(Lane::parse("auto"), None);
        assert_eq!(Lane::parse("avx512"), None);
        assert_eq!(Lane::parse(""), None);
    }

    #[test]
    fn initial_lane_fallback_policy() {
        // Unset / auto / empty -> detection.
        assert_eq!(initial_lane(None), detect_lane());
        assert_eq!(initial_lane(Some("auto")), detect_lane());
        assert_eq!(initial_lane(Some(" AUTO ")), detect_lane());
        assert_eq!(initial_lane(Some("")), detect_lane());
        // Unrecognized -> warn + detection, never abort.
        assert_eq!(initial_lane(Some("fastest")), detect_lane());
        // Scalar is always honored.
        assert_eq!(initial_lane(Some("scalar")), Lane::Scalar);
        // Available lanes are honored; unavailable ones fall back.
        for lane in Lane::ALL {
            let got = initial_lane(Some(lane.name()));
            if lane.is_available() {
                assert_eq!(got, lane);
            } else {
                assert_eq!(got, detect_lane());
            }
        }
    }

    #[test]
    fn detection_is_available_and_preferred() {
        let lane = detect_lane();
        assert!(lane.is_available());
        // No lane earlier in preference order is available.
        for cand in Lane::ALL {
            if cand == lane {
                break;
            }
            assert!(!cand.is_available(), "{cand} available but {lane} detected");
        }
        // The scalar fallback can always execute.
        assert!(Lane::Scalar.is_available());
        // active_lane only ever hands out an executable lane.
        assert!(active_lane().is_available());
    }

    #[test]
    fn force_rejects_unavailable_lanes() {
        for lane in Lane::ALL {
            if !lane.is_available() {
                let before = active_lane();
                assert!(!force_lane(lane));
                assert_eq!(active_lane(), before, "rejected force must not change the lane");
            }
        }
    }

    #[test]
    fn lanes_agree_within_fma_rounding() {
        // Scalar vs. every available SIMD lane on the same panels: each
        // chain step differs by at most a couple of roundings, so the
        // results agree within a standard forward-error envelope of the
        // absolute-value dot product. Explicit-lane calls — no global
        // state, no races with concurrently running sweeps.
        let kc = 96;
        let envelope = |absdot: f32| 4.0 * (kc as f32) * f32::EPSILON * absdot.max(1.0);
        let (ap, bp) = panels(kc, 7);
        let want = kernel_f32(Lane::Scalar, &ap, &bp);
        let (dap, dbp) = dual_panels(kc, 8);
        let (whh, wcorr) = kernel_cube(Lane::Scalar, &dap, &dbp);
        for lane in Lane::ALL {
            if !lane.is_available() || lane == Lane::Scalar {
                continue;
            }
            let got = kernel_f32(lane, &ap, &bp);
            for i in 0..MR {
                for j in 0..NR {
                    let mut absdot = 0.0f32;
                    for p in 0..kc {
                        absdot += ap[p * MR + i].abs() * bp[p * NR + j].abs();
                    }
                    let (x, y) = (want[i][j], got[i][j]);
                    assert!((x - y).abs() <= envelope(absdot), "{lane} f32 [{i}][{j}]: {x} vs {y}");
                }
            }
            let (ghh, gcorr) = kernel_cube(lane, &dap, &dbp);
            for i in 0..MR {
                for j in 0..NR {
                    let mut hi = 0.0f32;
                    let mut co = 0.0f32;
                    for p in 0..kc {
                        let (ah, al) = (dap[p * 2 * MR + i].abs(), dap[p * 2 * MR + MR + i].abs());
                        let (bh, bl) = (dbp[p * 2 * NR + j].abs(), dbp[p * 2 * NR + NR + j].abs());
                        hi += ah * bh;
                        co += ah * bl + al * bh;
                    }
                    let (x, y) = (whh[i][j], ghh[i][j]);
                    assert!((x - y).abs() <= envelope(hi), "{lane} hh [{i}][{j}]: {x} vs {y}");
                    let (x, y) = (wcorr[i][j], gcorr[i][j]);
                    assert!((x - y).abs() <= envelope(co), "{lane} corr [{i}][{j}]: {x} vs {y}");
                }
            }
        }
    }

    fn multi_panels(kc: usize, ncomp: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let ap: Vec<f32> = (0..kc * ncomp * MR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let bp: Vec<f32> = (0..kc * ncomp * NR).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        (ap, bp)
    }

    #[test]
    fn family_at_two_components_is_kernel_cube_bitwise() {
        // The N = 2 family tier must be served by the original cube
        // kernel — same panels in, same bits out, on every lane.
        let (dap, dbp) = dual_panels(96, 21);
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let (hh, corr) = kernel_cube(lane, &dap, &dbp);
            let fam = kernel_family(lane, &dap, &dbp, 2);
            for i in 0..MR {
                for j in 0..NR {
                    assert_eq!(fam[0][i][j].to_bits(), hh[i][j].to_bits(), "{lane}");
                    assert_eq!(fam[1][i][j].to_bits(), corr[i][j].to_bits(), "{lane}");
                    assert_eq!(fam[2][i][j], 0.0, "{lane}");
                    assert_eq!(fam[3][i][j], 0.0, "{lane}");
                }
            }
        }
    }

    #[test]
    fn family_three_components_lanes_agree_within_fma_rounding() {
        let kc = 64;
        let ncomp = 3;
        let envelope = |absdot: f32| 4.0 * (kc as f32) * f32::EPSILON * absdot.max(1.0);
        let (ap, bp) = multi_panels(kc, ncomp, 22);
        let want = kernel_family(Lane::Scalar, &ap, &bp, ncomp);
        // Unused planes are exactly zero, and plane d holds the kept
        // order-d products (checked against a direct f64 sum).
        for i in 0..MR {
            for j in 0..NR {
                assert_eq!(want[3][i][j], 0.0);
                for d in 0..ncomp {
                    let mut sum = 0.0f64;
                    for p in 0..kc {
                        for ci in 0..=d {
                            sum += ap[p * ncomp * MR + ci * MR + i] as f64
                                * bp[p * ncomp * NR + (d - ci) * NR + j] as f64;
                        }
                    }
                    let got = want[d][i][j] as f64;
                    assert!(
                        (sum - got).abs() <= 1e-4 * sum.abs().max(1.0),
                        "d={d} [{i}][{j}]: {sum} vs {got}"
                    );
                }
            }
        }
        for lane in Lane::ALL {
            if !lane.is_available() || lane == Lane::Scalar {
                continue;
            }
            let got = kernel_family(lane, &ap, &bp, ncomp);
            for d in 0..ncomp {
                for i in 0..MR {
                    for j in 0..NR {
                        let mut absdot = 0.0f32;
                        for p in 0..kc {
                            for ci in 0..=d {
                                absdot += ap[p * ncomp * MR + ci * MR + i].abs()
                                    * bp[p * ncomp * NR + (d - ci) * NR + j].abs();
                            }
                        }
                        let (x, y) = (want[d][i][j], got[d][i][j]);
                        assert!(
                            (x - y).abs() <= envelope(absdot),
                            "{lane} d={d} [{i}][{j}]: {x} vs {y}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn family_kernel_is_deterministic_per_lane() {
        let (ap, bp) = multi_panels(48, 3, 23);
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let x = kernel_family(lane, &ap, &bp, 3);
            let y = kernel_family(lane, &ap, &bp, 3);
            for (px, py) in x.iter().zip(&y) {
                for (rx, ry) in px.iter().zip(py) {
                    for (u, v) in rx.iter().zip(ry) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{lane}");
                    }
                }
            }
        }
    }

    #[test]
    fn every_lane_is_deterministic() {
        // Same lane + same panels -> identical bits, the kernel-level
        // half of the per-lane bit-identity contract (the schedule-level
        // half lives in tests/dispatch.rs).
        let (ap, bp) = panels(64, 9);
        let (dap, dbp) = dual_panels(64, 10);
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let x = kernel_f32(lane, &ap, &bp);
            let y = kernel_f32(lane, &ap, &bp);
            for (rx, ry) in x.iter().zip(&y) {
                for (u, v) in rx.iter().zip(ry) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{lane}");
                }
            }
            let (hx, cx) = kernel_cube(lane, &dap, &dbp);
            let (hy, cy) = kernel_cube(lane, &dap, &dbp);
            for (px, py) in [(hx, hy), (cx, cy)] {
                for (rx, ry) in px.iter().zip(&py) {
                    for (u, v) in rx.iter().zip(ry) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{lane}");
                    }
                }
            }
        }
    }

    #[test]
    fn zero_step_panels_yield_zero_tiles() {
        for lane in Lane::ALL {
            if !lane.is_available() {
                continue;
            }
            let tile = kernel_f32(lane, &[], &[]);
            assert!(tile.iter().all(|r| r.iter().all(|&v| v == 0.0)), "{lane}");
            let (hh, corr) = kernel_cube(lane, &[], &[]);
            assert!(hh.iter().all(|r| r.iter().all(|&v| v == 0.0)), "{lane}");
            assert!(corr.iter().all(|r| r.iter().all(|&v| v == 0.0)), "{lane}");
        }
    }
}
