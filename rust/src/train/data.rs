//! Synthetic datasets for the end-to-end training runs.

use crate::util::mat::Matrix;
use crate::util::rng::Rng;

/// Regression: targets from a random linear teacher with noise.
/// Returns `(x, y)` with `x: n×d_in`, `y: n×d_out`.
pub fn teacher_dataset(
    n: usize,
    d_in: usize,
    d_out: usize,
    noise: f32,
    rng: &mut Rng,
) -> (Matrix<f32>, Matrix<f32>) {
    let teacher = Matrix::random_normal(d_in, d_out, 1.0 / (d_in as f32).sqrt(), rng);
    let x = Matrix::random_normal(n, d_in, 1.0, rng);
    let mut y = crate::gemm::sgemm::sgemm(&x, &teacher);
    for v in y.as_mut_slice() {
        *v += rng.normal() * noise;
    }
    (x, y)
}

/// Classification: the classic two-spiral problem embedded in `d_in`
/// dimensions; labels one-hot in `y: n×2`.
pub fn spiral_dataset(n: usize, d_in: usize, rng: &mut Rng) -> (Matrix<f32>, Matrix<f32>) {
    assert!(d_in >= 2);
    let mut x = Matrix::zeros(n, d_in);
    let mut y = Matrix::zeros(n, 2);
    for i in 0..n {
        let class = i % 2;
        let t = (i / 2) as f32 / (n as f32 / 2.0) * 3.0 * std::f32::consts::PI;
        let r = t / (3.0 * std::f32::consts::PI);
        let (s, c) = t.sin_cos();
        let sign = if class == 0 { 1.0 } else { -1.0 };
        x.set(i, 0, sign * r * c + rng.normal() * 0.02);
        x.set(i, 1, sign * r * s + rng.normal() * 0.02);
        for j in 2..d_in {
            x.set(i, j, rng.normal() * 0.05); // uninformative padding dims
        }
        y.set(i, class, 1.0);
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn teacher_shapes_and_signal() {
        let mut rng = Rng::new(1);
        let (x, y) = teacher_dataset(128, 16, 4, 0.01, &mut rng);
        assert_eq!(x.shape(), (128, 16));
        assert_eq!(y.shape(), (128, 4));
        // Targets carry signal: variance well above the noise floor.
        let var = y.as_slice().iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / y.as_slice().len() as f64;
        assert!(var > 0.1, "var={var}");
    }

    #[test]
    fn spiral_labels_one_hot_balanced() {
        let mut rng = Rng::new(2);
        let (x, y) = spiral_dataset(100, 8, &mut rng);
        assert_eq!(x.shape(), (100, 8));
        let mut counts = [0, 0];
        for i in 0..100 {
            let row = y.row(i);
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            counts[if row[0] == 1.0 { 0 } else { 1 }] += 1;
        }
        assert_eq!(counts, [50, 50]);
    }
}
