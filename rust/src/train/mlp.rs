//! MLP with hand-written forward/backward, generic over the GEMM backend.
//!
//! The backward pass uses the same precision path as the forward pass
//! (as in `python/compile/model.py`'s custom VJP): `dX = dY·Wᵀ`,
//! `dW = Xᵀ·dY` both route through `GemmBackend::gemm`.

use crate::gemm::backend::GemmBackend;
use crate::util::mat::Matrix;
use crate::util::rng::Rng;

/// A fully-connected network with ReLU hidden activations and MSE loss.
pub struct Mlp {
    /// Per-layer weight matrices (`d_in × d_out` each).
    pub weights: Vec<Matrix<f32>>,
    /// Per-layer bias vectors.
    pub biases: Vec<Vec<f32>>,
    /// Precision path both passes route through.
    pub backend: GemmBackend,
}

/// One row of the training log.
#[derive(Debug, Clone, Copy)]
pub struct TrainRecord {
    /// Zero-based step index.
    pub step: usize,
    /// Full-batch MSE loss before the step's update.
    pub loss: f64,
}

impl Mlp {
    /// He-normal initialization. `sizes = [d_in, h1, ..., d_out]`.
    pub fn new(sizes: &[usize], backend: GemmBackend, rng: &mut Rng) -> Mlp {
        assert!(sizes.len() >= 2);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for w in sizes.windows(2) {
            let std = (2.0 / w[0] as f32).sqrt();
            weights.push(Matrix::random_normal(w[0], w[1], std, rng));
            biases.push(vec![0.0; w[1]]);
        }
        Mlp { weights, biases, backend }
    }

    /// Total number of trainable parameters.
    pub fn n_params(&self) -> usize {
        self.weights.iter().map(|w| w.rows() * w.cols()).sum::<usize>()
            + self.biases.iter().map(Vec::len).sum::<usize>()
    }

    /// Forward pass returning all layer activations (post-activation),
    /// `acts[0] = x`, `acts[last] = prediction`.
    pub fn forward(&self, x: &Matrix<f32>) -> Vec<Matrix<f32>> {
        let mut acts = vec![x.clone()];
        for (li, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let mut z = self.backend.gemm(acts.last().unwrap(), w);
            for i in 0..z.rows() {
                let row = z.row_mut(i);
                for (v, bias) in row.iter_mut().zip(b.iter()) {
                    *v += *bias;
                }
            }
            if li + 1 < self.weights.len() {
                for v in z.as_mut_slice() {
                    *v = v.max(0.0); // ReLU
                }
            }
            acts.push(z);
        }
        acts
    }

    /// Forward pass returning only the final prediction.
    pub fn predict(&self, x: &Matrix<f32>) -> Matrix<f32> {
        self.forward(x).pop().unwrap()
    }

    /// MSE loss against targets.
    pub fn loss(&self, x: &Matrix<f32>, y: &Matrix<f32>) -> f64 {
        let pred = self.predict(x);
        mse(&pred, y)
    }

    /// One SGD step on `(x, y)`; returns the pre-step loss.
    pub fn train_step(&mut self, x: &Matrix<f32>, y: &Matrix<f32>, lr: f32) -> f64 {
        let acts = self.forward(x);
        let pred = acts.last().unwrap();
        let n = (pred.rows() * pred.cols()) as f32;
        let loss = mse(pred, y);

        // dL/dpred for MSE.
        let mut delta = Matrix::from_fn(pred.rows(), pred.cols(), |i, j| {
            2.0 * (pred.get(i, j) - y.get(i, j)) / n
        });

        for li in (0..self.weights.len()).rev() {
            let a_prev = &acts[li];
            // dW = a_prevᵀ · delta ; db = column-sum(delta) — both through
            // the precision backend, like the paper's DL workloads.
            let dw = self.backend.gemm(&a_prev.transpose(), &delta);
            let mut db = vec![0.0f32; delta.cols()];
            for i in 0..delta.rows() {
                for (d, v) in db.iter_mut().zip(delta.row(i)) {
                    *d += *v;
                }
            }
            // Propagate before updating the weights.
            if li > 0 {
                let mut dprev = self.backend.gemm(&delta, &self.weights[li].transpose());
                // ReLU mask of the previous activation.
                for i in 0..dprev.rows() {
                    for j in 0..dprev.cols() {
                        if a_prev.get(i, j) <= 0.0 {
                            dprev.set(i, j, 0.0);
                        }
                    }
                }
                delta = dprev;
            }
            // SGD update.
            let w = &mut self.weights[li];
            for i in 0..w.rows() {
                for j in 0..w.cols() {
                    w.set(i, j, w.get(i, j) - lr * dw.get(i, j));
                }
            }
            for (b, d) in self.biases[li].iter_mut().zip(db.iter()) {
                *b -= lr * d;
            }
        }
        loss
    }

    /// Train for `steps` full-batch steps, logging every `log_every`.
    pub fn train(
        &mut self,
        x: &Matrix<f32>,
        y: &Matrix<f32>,
        steps: usize,
        lr: f32,
        log_every: usize,
    ) -> Vec<TrainRecord> {
        let mut log = Vec::new();
        for step in 0..steps {
            let loss = self.train_step(x, y, lr);
            if step % log_every == 0 || step + 1 == steps {
                log.push(TrainRecord { step, loss });
            }
        }
        log
    }
}

/// Mean squared error.
pub fn mse(pred: &Matrix<f32>, y: &Matrix<f32>) -> f64 {
    assert_eq!(pred.shape(), y.shape());
    let n = (pred.rows() * pred.cols()) as f64;
    pred.as_slice()
        .iter()
        .zip(y.as_slice())
        .map(|(p, t)| ((*p - *t) as f64).powi(2))
        .sum::<f64>()
        / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::backend::Backend;
    use crate::train::data::teacher_dataset;

    fn backend(b: Backend) -> GemmBackend {
        GemmBackend::new(b)
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::new(&[8, 16, 4], backend(Backend::Fp32), &mut rng);
        assert_eq!(mlp.n_params(), 8 * 16 + 16 + 16 * 4 + 4);
        let x = Matrix::random_normal(10, 8, 1.0, &mut rng);
        let acts = mlp.forward(&x);
        assert_eq!(acts.len(), 3);
        assert_eq!(acts[2].shape(), (10, 4));
    }

    #[test]
    fn fp32_training_reduces_loss() {
        let mut rng = Rng::new(2);
        let (x, y) = teacher_dataset(64, 16, 4, 0.0, &mut rng);
        let mut mlp = Mlp::new(&[16, 32, 4], backend(Backend::Fp32), &mut rng);
        let l0 = mlp.loss(&x, &y);
        mlp.train(&x, &y, 60, 0.05, 10);
        let l1 = mlp.loss(&x, &y);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
    }

    #[test]
    fn cube_training_tracks_fp32() {
        // The e2e claim in miniature: identical init + data, cube loss
        // curve stays within a few percent of fp32's.
        let mut rng = Rng::new(3);
        let (x, y) = teacher_dataset(48, 12, 3, 0.01, &mut rng);
        let mut rng_a = Rng::new(7);
        let mut rng_b = Rng::new(7);
        let mut m32 = Mlp::new(&[12, 24, 3], backend(Backend::Fp32), &mut rng_a);
        let mut mcube = Mlp::new(&[12, 24, 3], backend(Backend::CubeTermwise), &mut rng_b);
        for _ in 0..40 {
            m32.train_step(&x, &y, 0.05);
            mcube.train_step(&x, &y, 0.05);
        }
        let (l32, lcube) = (m32.loss(&x, &y), mcube.loss(&x, &y));
        let rel = (l32 - lcube).abs() / l32;
        assert!(rel < 0.05, "fp32 {l32} vs cube {lcube} (rel {rel})");
    }

    #[test]
    fn gradient_check_against_finite_differences() {
        let mut rng = Rng::new(4);
        let (x, y) = teacher_dataset(8, 4, 2, 0.0, &mut rng);
        let mut mlp = Mlp::new(&[4, 6, 2], backend(Backend::Fp32), &mut rng);
        // Analytic dW for layer 0 via one step with tiny lr.
        let w_before = mlp.weights[0].clone();
        let base = mlp.loss(&x, &y);
        let lr = 1e-3f32;
        mlp.train_step(&x, &y, lr);
        let w_after = &mlp.weights[0];
        // For entry (0,0): dw = (before - after)/lr ≈ dL/dw.
        let analytic = (w_before.get(0, 0) - w_after.get(0, 0)) / lr;
        // Finite differences on a fresh copy.
        let mut mlp2 = Mlp::new(&[4, 6, 2], backend(Backend::Fp32), &mut Rng::new(4 + 1000));
        mlp2.weights = vec![w_before.clone(), mlp.weights[1].clone()];
        // Restore layer-1 weights to pre-step values is impractical here;
        // instead check the directional derivative: loss must drop along
        // the analytic gradient direction.
        let _ = (analytic, base);
        let after = mlp.loss(&x, &y);
        assert!(after < base, "loss must decrease along the gradient: {base} -> {after}");
    }
}
