//! End-to-end training substrate: a small MLP trained with every matmul
//! routed through a selectable precision backend.
//!
//! This is the workload behind `examples/train_mlp.rs` (the e2e
//! validation driver): the paper motivates SGEMM-cube with deep-learning
//! workloads whose weights/activations have small magnitudes, so the
//! success criterion is *cube-backend training tracks FP32 training
//! while pure FP16 degrades*.

pub mod data;
pub mod mlp;

pub use data::{spiral_dataset, teacher_dataset};
pub use mlp::{Mlp, TrainRecord};
