//! Regeneration of every table and figure in the paper's evaluation
//! (the experiment index lives in DESIGN.md §5).
//!
//! Each submodule exposes a `run(...)` returning printable rows plus the
//! paper's expected anchors, so the bench binaries and the CLI `figures`
//! subcommand print *paper vs measured* side by side.

pub mod ablations;
pub mod fig10_roofline;
pub mod fig11_blocking_perf;
pub mod fig12_size_scaling;
pub mod fig2_analysis;
pub mod fig6_blocking;
pub mod fig8_accuracy;
pub mod fig9_size_accuracy;
pub mod report;
pub mod table1;
pub mod table2;

pub use report::Table;
