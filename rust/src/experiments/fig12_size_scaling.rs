//! Fig. 12: throughput vs matrix sizes, SGEMM-cube on 910A vs CANN FP32
//! on 910B3 — (a) m = n sweep, (b) k sweep, (c) joint m = k = n sweep.
//!
//! The CANN comparator runs the same pipeline model on the 910B3 chip
//! description (native FP32 engine, half L1, 20 cores @1.8 GHz) with a
//! generic blocking that its L1 supports. The paper observes CANN
//! degrading at very large joint sizes while the L1-aware cube kernel
//! holds; in the model this emerges from 910B3's smaller `N_fused`
//! (half L1, 4-byte elements) pushing C-tile traffic up as k grows.

use crate::experiments::report::{fixed, Table};
use crate::sim::blocking::{BlockConfig, GemmShape};
use crate::sim::chip::Chip;
use crate::sim::executor::{simulate_gemm, simulate_sgemm_cube};
use crate::sim::pipeline::Buffering;

/// Best feasible block for the 910B3 FP32 comparator.
pub fn b3_block() -> BlockConfig {
    BlockConfig::new(96, 64, 96)
}

fn measure(shape: GemmShape) -> (f64, f64) {
    let a910 = Chip::ascend_910a();
    let b3 = Chip::ascend_910b3_fp32();
    let cube = simulate_sgemm_cube(&a910, shape, BlockConfig::paper_best(), Buffering::Double);
    let cann = simulate_gemm(&b3, shape, b3_block(), Buffering::Double);
    (cube.tflops, cann.tflops.min(cann.roof))
}

/// Fig. 12(a): m = n sweep at fixed k.
pub fn run_mn(k: usize, sizes: &[usize]) -> Table {
    let mut t = Table::new(
        &format!("Fig 12(a): TF/s vs m=n (k={k})"),
        &["m=n", "cube@910A", "CANN-fp32@910B3"],
    );
    for &mn in sizes {
        let (c, b) = measure(GemmShape::new(mn, k, mn));
        t.row(vec![mn.to_string(), fixed(c, 1), fixed(b, 1)]);
    }
    t
}

/// Fig. 12(b): k sweep at fixed m = n.
pub fn run_k(mn: usize, ks: &[usize]) -> Table {
    let mut t = Table::new(
        &format!("Fig 12(b): TF/s vs k (m=n={mn})"),
        &["k", "cube@910A", "CANN-fp32@910B3"],
    );
    for &k in ks {
        let (c, b) = measure(GemmShape::new(mn, k, mn));
        t.row(vec![k.to_string(), fixed(c, 1), fixed(b, 1)]);
    }
    t
}

/// Fig. 12(c): joint m = k = n sweep.
pub fn run_mkn(sizes: &[usize]) -> Table {
    let mut t = Table::new(
        "Fig 12(c): TF/s vs m=k=n",
        &["m=k=n", "cube@910A", "CANN-fp32@910B3"],
    );
    for &s in sizes {
        let (c, b) = measure(GemmShape::new(s, s, s));
        t.row(vec![s.to_string(), fixed(c, 1), fixed(b, 1)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mn_growth_pushes_cube_past_60() {
        // Paper: increasing m, n pushes 910A cube past 60 TF/s.
        let t = run_mn(2816, &[704, 1408, 2816, 5632]);
        let last: f64 = t.rows.last().unwrap()[1].parse().unwrap();
        assert!(last > 60.0, "cube {last}");
        // Throughput grows with m=n.
        let first: f64 = t.rows[0][1].parse().unwrap();
        assert!(last > first);
    }

    #[test]
    fn k_sweep_both_stable() {
        // Paper: cube ≈ 60, CANN ≈ 63, both stable in k.
        let t = run_k(5632, &[1024, 2048, 4096, 8192]);
        for r in &t.rows {
            let c: f64 = r[1].parse().unwrap();
            let b: f64 = r[2].parse().unwrap();
            assert!((55.0..70.0).contains(&c), "cube {c}");
            assert!((55.0..74.0).contains(&b), "cann {b}");
        }
    }

    #[test]
    fn cube_stable_at_large_joint_sizes() {
        // Paper: cube maintains stable performance as m=k=n grows large
        // (small sizes underfill the 32 cores — visible in the sweep as
        // the rising left edge, matching Fig. 12(c)'s shape).
        let t = run_mkn(&[1408, 2816, 5632, 11264]);
        let vals: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(vals[0] < vals[2], "throughput must rise with size: {vals:?}");
        // Stability on the large end: 5632 vs 11264 within a few TF/s.
        let spread = (vals[3] - vals[2]).abs();
        assert!(spread < 6.0, "large-size cube spread {spread} ({vals:?})");
        assert!(vals[3] > 60.0, "large-size cube {vals:?}");
    }
}
