//! Table 1: peak throughput of representative AI accelerators (TFLOP/s).
//! Static published data, reproduced verbatim; the Ascend 910A row is
//! cross-checked against the simulator's chip model.

use crate::experiments::report::Table;
use crate::sim::chip::Chip;

/// (chip, fp16, fp32, fp64) — `None` renders as "-".
pub const PEAKS: &[(&str, Option<f64>, Option<f64>, Option<f64>)] = &[
    ("Nvidia H100 SXM", Some(989.0), Some(67.0), Some(34.0)),
    ("Nvidia A100 SXM", Some(312.0), Some(19.5), Some(9.7)),
    ("AMD MI300X", Some(1307.0), Some(163.0), Some(81.0)),
    ("Intel Gaudi3", Some(1678.0), Some(14.3), None),
    ("Huawei Ascend 910A", Some(256.0), None, None),
    ("Cambricon MLU370-X8", Some(96.0), Some(24.0), None),
    ("Baidu Kunlun XPU-R", Some(400.0), None, None),
    ("Muxi Xiyun C500", Some(280.0), Some(36.0), None),
    ("Shenwei SW26010-Pro", Some(55.3), Some(14.0), Some(14.0)),
    ("Moore Threads MTT S4000", Some(100.0), Some(25.0), None),
];

fn cell(v: Option<f64>) -> String {
    v.map(|x| format!("{x}")).unwrap_or_else(|| "-".into())
}

/// Build the table; also verifies the 910A row against the chip model.
pub fn run() -> Table {
    let mut t = Table::new(
        "Table 1: peak throughput of representative AI accelerators (TFLOP/s)",
        &["Chip Model", "FP16", "FP32", "FP64", "sim-model"],
    );
    let model_910a = Chip::ascend_910a().peak_tflops();
    for (name, f16, f32_, f64_) in PEAKS {
        let model = if *name == "Huawei Ascend 910A" {
            format!("{model_910a:.1}")
        } else {
            "-".into()
        };
        t.row(vec![name.to_string(), cell(*f16), cell(*f32_), cell(*f64_), model]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_published_rows() {
        let t = run();
        assert_eq!(t.rows.len(), 10);
        assert!(t.render().contains("Huawei Ascend 910A"));
    }

    #[test]
    fn sim_chip_matches_published_910a_peak() {
        assert_eq!(Chip::ascend_910a().peak_tflops(), 256.0);
    }
}
