//! Plain-text table rendering + CSV export for the experiment reports.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title (rendered as a `== title ==` banner).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows; each must match the header width.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV export (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print to stdout and optionally persist a CSV next to the bench.
    pub fn emit(&self, csv_path: Option<&std::path::Path>) {
        print!("{}", self.render());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(p, self.to_csv()) {
                eprintln!("warning: could not write {p:?}: {e}");
            } else {
                println!("[csv] {}", p.display());
            }
        }
        println!();
    }
}

/// Scientific-notation cell formatting (`0` stays `0`).
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else {
        format!("{v:.2e}")
    }
}

/// Fixed-point cell formatting with `digits` decimals.
pub fn fixed(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["10".into(), "2000".into(), "xyz".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("long-header"));
        assert_eq!(r.lines().count(), 5);
    }

    #[test]
    fn csv_round() {
        let mut t = Table::new("x", &["h1", "h2"]);
        t.row(vec!["a".into(), "b".into()]);
        assert_eq!(t.to_csv(), "h1,h2\na,b\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["h1", "h2"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sci_and_fixed() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1234.0), "1.23e3");
        assert_eq!(fixed(1.23456, 2), "1.23");
    }
}
