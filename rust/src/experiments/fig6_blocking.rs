//! Fig. 6: impact of blocking size on `N_fused` and the fusion factor
//! `f`, over the feasible `(b_m, b_k, b_n)` space of Eq. (12).

use crate::experiments::report::{fixed, Table};
use crate::sim::blocking::{feasible_blocks, optimal_bm, BlockConfig};
use crate::sim::chip::Chip;

/// Sweep N_fused and f as a function of `b_m·b_k` (square-ish blocks,
/// b_n = b_m, as in the paper's plot).
pub fn run() -> Table {
    let chip = Chip::ascend_910a();
    let mut t = Table::new(
        "Fig 6: N_fused and fusion factor f vs blocking size (910A)",
        &["bm", "bk", "bn", "bm*bk", "N_fused", "f"],
    );
    for cfg in feasible_blocks(&chip, 256) {
        // The paper plots bn/bm in [0.5, 2]; keep the square diagonal
        // plus the paper's best block for readability.
        if cfg.bn != cfg.bm && cfg != BlockConfig::paper_best() {
            continue;
        }
        if cfg.bk != 64 && cfg.bk != 128 && cfg.bk != 32 {
            continue;
        }
        let nf = cfg.n_fused(&chip);
        if nf == 0 {
            continue;
        }
        t.row(vec![
            cfg.bm.to_string(),
            cfg.bk.to_string(),
            cfg.bn.to_string(),
            (cfg.bm * cfg.bk).to_string(),
            nf.to_string(),
            fixed(cfg.fusion_factor(&chip), 4),
        ]);
    }
    t
}

/// The optimal-b_m derivation printed alongside (Sec. 5.1.1).
pub fn optimal_bm_summary() -> String {
    let chip = Chip::ascend_910a();
    let opt = optimal_bm(&chip);
    format!(
        "b_m,opt = sqrt(f*L1 / 2*N_core) = {opt:.1}  (paper: 86 < b_m,opt < 90, rounded to 96)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nfused_decreases_with_block_area() {
        let t = run();
        // Extract (bm*bk, N_fused) at bk = 64 and check monotone decrease.
        let mut pairs: Vec<(usize, u64)> = t
            .rows
            .iter()
            .filter(|r| r[1] == "64" && r[0] == r[2])
            .map(|r| (r[3].parse().unwrap(), r[4].parse().unwrap()))
            .collect();
        pairs.sort();
        for w in pairs.windows(2) {
            assert!(w[1].1 <= w[0].1, "N_fused not decreasing: {pairs:?}");
        }
    }

    #[test]
    fn fusion_factor_in_paper_band_for_moderate_blocks() {
        let t = run();
        for r in &t.rows {
            let bm: usize = r[0].parse().unwrap();
            let f: f64 = r[5].parse().unwrap();
            if bm >= 80 {
                assert!((0.85..=1.0).contains(&f), "bm={bm} f={f}");
            }
        }
    }

    #[test]
    fn summary_mentions_96() {
        assert!(optimal_bm_summary().contains("96"));
    }
}
