//! Table 2: comparison of FP32-approximation methods. The prior-work
//! rows are published claims (static); the SGEMM-cube row is *measured*
//! on this reproduction: accuracy from the numerics engine, throughput
//! from the calibrated 910A model.

use crate::experiments::fig11_blocking_perf::headline;
use crate::experiments::report::Table;
use crate::gemm::cube::{cube_gemm, Accumulation};
use crate::gemm::dgemm::dgemm_of_f32;
use crate::gemm::error::relative_error;
use crate::sim::blocking::GemmShape;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;
use crate::util::rng::Rng;

/// Prior-work rows of Table 2: `(method, hardware, bits, throughput)`.
pub const PRIOR_WORK: &[(&str, &str, &str, &str)] = &[
    ("Markidis et al.", "NVIDIA V100", "Truncation-based (RZ)", "2 bits"),
    ("Feng et al.", "NVIDIA T4/RTX6000", "No hidden bit, RZ", "2 bits"),
    ("Ootomo et al.", "NVIDIA A100", "Amplified decomposition, RN", "1 bit"),
    ("Ma et al.", "NVIDIA V100/T4/A100", "Optimized decomposition, RN", "1 bit"),
    ("Li et al. (QuanTensor)", "NVIDIA T4/2080Ti", "Multi-pass low-precision", "N/A"),
    ("Lin et al. (MixPert)", "NVIDIA A100", "INT8 fixed-point, RN", "3 bits"),
];

/// Measured precision loss of this implementation in bits:
/// `log2(err_cube / err_fp32-ulp-floor)` style estimate via direct
/// comparison of achieved bits vs FP32's 24.
pub fn measured_precision_bits(n: usize) -> f64 {
    let mut rng = Rng::new(77);
    let a = Matrix::random_symmetric(n, n, 0, &mut rng);
    let b = Matrix::random_symmetric(n, n, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let err = relative_error(
        &c_ref,
        &cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise).to_f64(),
    );
    -err.log2()
}

/// Render Table 2 (prior work vs this reproduction).
pub fn run() -> Table {
    let mut t = Table::new(
        "Table 2: FP32 approximation methods (prior rows = published claims)",
        &["Work", "Hardware", "Method", "Precision loss", "Performance"],
    );
    for (work, hw, method, loss) in PRIOR_WORK {
        let perf = match *work {
            "Markidis et al." => "trade-off study",
            "Feng et al." => "3.13x over cuBLAS FP32",
            "Ootomo et al." => "51 TFLOPS",
            "Ma et al." => "64.15 TFLOPS (61.7% peak)",
            "Li et al. (QuanTensor)" => "tunable",
            _ => "1.72x over cuBLAS FP32",
        };
        t.row(vec![
            work.to_string(),
            hw.to_string(),
            method.to_string(),
            loss.to_string(),
            perf.to_string(),
        ]);
    }
    // Our measured row.
    let shape = GemmShape::new(5632, 4096, 5632);
    let (_, double, frac) = headline(shape);
    let bits = measured_precision_bits(96);
    let loss = (24.0 - bits).max(0.0);
    t.row(vec![
        "SGEMM-cube (this repro)".into(),
        "Ascend 910A (simulated)".into(),
        "Ootomo-style FP16 split, RN, s_b=12".into(),
        format!("{loss:.1} bits ({bits:.1} achieved)"),
        format!("{double:.1} TFLOPS, {:.0}% of 3-GEMM peak", frac * 100.0),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_contains_our_measured_row() {
        let t = run();
        assert_eq!(t.rows.len(), 7);
        let ours = t.rows.last().unwrap();
        assert!(ours[0].contains("this repro"));
        // Paper claims "approx. 1–2 bits, range-dependent" loss.
        let loss: f64 = ours[3].split(' ').next().unwrap().parse().unwrap();
        assert!(loss <= 3.0, "precision loss {loss} bits");
        // And 65.3 TFLOPS @ 77%.
        assert!(ours[4].contains("TFLOPS"));
    }

    #[test]
    fn measured_bits_above_21() {
        let bits = measured_precision_bits(64);
        assert!(bits > 21.0, "achieved {bits} bits");
    }
}
