//! Fig. 8: relative error vs the FP32 offset exponent, under symmetric
//! `U[-2^e, 2^e]` and non-negative `U[0, 2^e]` sampling, for FP16 HGEMM,
//! FP32 SGEMM and SGEMM-cube (elementwise/termwise × s_b ∈ {0, 6, 12}).

use crate::experiments::report::{sci, Table};
use crate::gemm::cube::{cube_gemm, Accumulation};
use crate::gemm::dgemm::dgemm_of_f32;
use crate::gemm::error::relative_error;
use crate::gemm::hgemm::{hgemm, AccumulateMode};
use crate::gemm::sgemm::sgemm;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;
use crate::util::rng::Rng;

/// Input distribution of Sec. 6.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// Zero-mean uniform `U[-2^e, 2^e]`.
    Symmetric,
    /// Non-negative uniform `U[0, 2^e]` (the error-amplifying case).
    NonNegative,
}

impl Sampling {
    /// Human-readable distribution label.
    pub fn name(self) -> &'static str {
        match self {
            Sampling::Symmetric => "U[-2^e, 2^e]",
            Sampling::NonNegative => "U[0, 2^e]",
        }
    }

    fn matrix(self, r: usize, c: usize, e: i32, rng: &mut Rng) -> Matrix<f32> {
        match self {
            Sampling::Symmetric => Matrix::random_symmetric(r, c, e, rng),
            Sampling::NonNegative => Matrix::random_nonneg(r, c, e, rng),
        }
    }
}

/// Mean relative error over `seeds` trials at matrix size n³.
#[allow(clippy::too_many_arguments)]
fn mean_err(
    method: &dyn Fn(&Matrix<f32>, &Matrix<f32>) -> Matrix<f32>,
    sampling: Sampling,
    n: usize,
    e: i32,
    seeds: u64,
) -> f64 {
    let mut total = 0.0;
    for s in 0..seeds {
        let mut rng = Rng::new(1000 + s);
        let a = sampling.matrix(n, n, e, &mut rng);
        let b = sampling.matrix(n, n, e, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        total += relative_error(&c_ref, &method(&a, &b).to_f64());
    }
    total / seeds as f64
}

/// Run the Fig. 8 sweep. `n` is the matrix size (paper uses larger
/// matrices; the error *ordering* is size-independent, see Fig. 9a).
pub fn run(sampling: Sampling, n: usize, exponents: &[i32], seeds: u64) -> Table {
    let mut t = Table::new(
        &format!("Fig 8: relative error vs offset exponent, {} (n={n})", sampling.name()),
        &[
            "e", "hgemm", "sgemm-fp32",
            "cube-el sb=0", "cube-tw sb=0",
            "cube-el sb=6", "cube-tw sb=6",
            "cube-el sb=12", "cube-tw sb=12",
        ],
    );
    for &e in exponents {
        let h = mean_err(&|a, b| hgemm(a, b, AccumulateMode::Fp32Rn), sampling, n, e, seeds);
        let s = mean_err(&|a, b| sgemm(a, b), sampling, n, e, seeds);
        let cube = |sb: i32, acc: Accumulation| {
            mean_err(
                &move |a: &Matrix<f32>, b: &Matrix<f32>| {
                    cube_gemm(a, b, SplitConfig::with_scale(sb), acc)
                },
                sampling,
                n,
                e,
                seeds,
            )
        };
        t.row(vec![
            e.to_string(),
            sci(h),
            sci(s),
            sci(cube(0, Accumulation::Elementwise)),
            sci(cube(0, Accumulation::Termwise)),
            sci(cube(6, Accumulation::Elementwise)),
            sci(cube(6, Accumulation::Termwise)),
            sci(cube(12, Accumulation::Elementwise)),
            sci(cube(12, Accumulation::Termwise)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(t: &Table, row: usize, col: usize) -> f64 {
        t.rows[row][col].parse().unwrap()
    }

    #[test]
    fn error_ordering_matches_paper_at_e0() {
        // hgemm ~1e-4 >> cube sb=12 ~ sgemm; sb=0 worse than sb=12.
        let t = run(Sampling::Symmetric, 64, &[0], 2);
        let h = parse(&t, 0, 1);
        let s = parse(&t, 0, 2);
        let c0 = parse(&t, 0, 4);
        let c12 = parse(&t, 0, 8);
        assert!(h > 1e-5, "hgemm err {h}");
        assert!(c12 < h / 50.0, "cube {c12} vs hgemm {h}");
        assert!(c12 < s * 10.0, "cube {c12} vs sgemm {s}");
        assert!(c0 >= c12, "sb=0 {c0} vs sb=12 {c12}");
    }

    #[test]
    fn scaling_gap_grows_at_negative_exponents() {
        // Paper: s_b=12 improves 1–2 orders at low exponents; s_b=6
        // insufficient.
        let t = run(Sampling::Symmetric, 48, &[-10], 2);
        let c0 = parse(&t, 0, 4);
        let c6 = parse(&t, 0, 6);
        let c12 = parse(&t, 0, 8);
        assert!(c12 < c0 / 10.0, "sb12 {c12} vs sb0 {c0}");
        assert!(c12 <= c6, "sb12 {c12} vs sb6 {c6}");
    }

    #[test]
    fn nonnegative_sampling_lower_relative_error() {
        // Cancellation inflates the symmetric metric (Sec. 6.2).
        let sym = run(Sampling::Symmetric, 48, &[0], 2);
        let non = run(Sampling::NonNegative, 48, &[0], 2);
        let e_sym = parse(&sym, 0, 2);
        let e_non = parse(&non, 0, 2);
        assert!(e_non <= e_sym, "sgemm: nonneg {e_non} vs sym {e_sym}");
    }
}
