//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Omitted low·low term** (Sec. 4.3) — three-term vs four-term
//!    reconstruction: is `R_A·R_B/s_f²` really negligible, and what
//!    would its fourth GEMM pass cost?
//! 2. **RN vs RZ splitting** (Table 2 axis) — reproduces the ~2-bit
//!    penalty of truncation-based prior work (Markidis et al.).
//! 3. **RN vs RZ accumulation** (Ootomo & Yokota's Tensor-Core finding)
//!    — FP32 accumulator rounding mode under HGEMM.
//! 4. **Dynamic vs fixed scaling** (the future-work extension in
//!    `coordinator::policy`) — error at out-of-window exponents.

use crate::coordinator::policy::PrecisionPolicy;
use crate::experiments::report::{fixed, sci, Table};
use crate::gemm::cube::{cube_gemm, cube_gemm_four_term, cube_gemm_rz, Accumulation};
use crate::gemm::dgemm::dgemm_of_f32;
use crate::gemm::error::relative_error;
use crate::gemm::hgemm::{hgemm, AccumulateMode};
use crate::sim::blocking::{BlockConfig, GemmShape};
use crate::sim::chip::Chip;
use crate::sim::executor::simulate_sgemm_cube;
use crate::sim::pipeline::Buffering;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;
use crate::util::rng::Rng;

fn pair(n: usize, e: i32, seed: u64) -> (Matrix<f32>, Matrix<f32>) {
    let mut rng = Rng::new(seed);
    (
        Matrix::random_symmetric(n, n, e, &mut rng),
        Matrix::random_symmetric(n, n, e, &mut rng),
    )
}

/// Ablation 1: three-term vs four-term accuracy + modeled cost.
pub fn run_low_low(n: usize, seeds: u64) -> Table {
    let mut t = Table::new(
        "Ablation: omitted low-low term (Sec 4.3)",
        &["e", "three-term err", "four-term err", "ratio", "extra cost"],
    );
    let chip = Chip::ascend_910a();
    let shape = GemmShape::new(5632, 4096, 5632);
    let t3 = simulate_sgemm_cube(&chip, shape, BlockConfig::paper_best(), Buffering::Double);
    // A fourth GEMM pass scales the dominant cost by 4/3.
    let cost = format!("{:.1}%", 100.0 / 3.0);
    for e in [-8i32, 0, 8] {
        let (mut e3, mut e4) = (0.0, 0.0);
        for s in 0..seeds {
            let (a, b) = pair(n, e, 3000 + s);
            let c_ref = dgemm_of_f32(&a, &b);
            let cfg = SplitConfig::default();
            e3 += relative_error(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise).to_f64());
            e4 += relative_error(&c_ref, &cube_gemm_four_term(&a, &b, cfg).to_f64());
        }
        t.row(vec![
            e.to_string(),
            sci(e3 / seeds as f64),
            sci(e4 / seeds as f64),
            fixed(e3 / e4, 2),
            cost.clone(),
        ]);
    }
    let _ = t3; // cost context: the 3-term double-buffer baseline
    t
}

/// Ablation 2+3: rounding modes (split RZ; accumulate RZ).
pub fn run_rounding(n: usize, seeds: u64) -> Table {
    let mut t = Table::new(
        "Ablation: rounding modes (split RN/RZ; HGEMM accumulate RN/RZ)",
        &["e", "cube RN-split", "cube RZ-split", "bits lost", "hgemm RN-acc", "hgemm RZ-acc"],
    );
    for e in [-4i32, 0, 4] {
        let (mut c_rn, mut c_rz, mut h_rn, mut h_rz) = (0.0, 0.0, 0.0, 0.0);
        for s in 0..seeds {
            let (a, b) = pair(n, e, 4000 + s);
            let c_ref = dgemm_of_f32(&a, &b);
            c_rn += relative_error(
                &c_ref,
                &cube_gemm(&a, &b, SplitConfig::default(), Accumulation::Termwise).to_f64(),
            );
            c_rz += relative_error(&c_ref, &cube_gemm_rz(&a, &b, 12).to_f64());
            // Accumulator-mode bias shows on cancellation-free sums with
            // deep k (every RZ add rounds the positive sum downward).
            let mut rng = Rng::new(4500 + s);
            let an = Matrix::random_nonneg(32, 8 * n, e, &mut rng);
            let bn = Matrix::random_nonneg(8 * n, 32, e, &mut rng);
            let cn_ref = dgemm_of_f32(&an, &bn);
            h_rn += relative_error(&cn_ref, &hgemm(&an, &bn, AccumulateMode::Fp32Rn).to_f64());
            h_rz += relative_error(&cn_ref, &hgemm(&an, &bn, AccumulateMode::Fp32Rz).to_f64());
        }
        t.row(vec![
            e.to_string(),
            sci(c_rn / seeds as f64),
            sci(c_rz / seeds as f64),
            fixed((c_rz / c_rn).log2(), 2),
            sci(h_rn / seeds as f64),
            sci(h_rz / seeds as f64),
        ]);
    }
    t
}

/// Ablation 4: the dynamic range policy (Eq. 6 window + low-side FP32
/// fallback) vs always forcing the cube path with fixed s_b = 12.
///
/// Finding recorded here (and encoded in the policy): growing s_b above
/// 12 for tiny inputs does NOT help — below e ≈ -14 the *high* component
/// is fp16-subnormal and the contiguous high+low mantissa is the binding
/// limit, so the policy routes to FP32 instead.
pub fn run_dynamic_scaling(n: usize, seeds: u64) -> Table {
    let mut t = Table::new(
        "Ablation: range policy (Eq. 6 + low-side fallback) vs forced cube s_b=12",
        &["e", "chosen path", "err forced-cube", "err policy", "gain"],
    );
    let policy = PrecisionPolicy::default();
    for e in [-22i32, -18, -14, 0] {
        let (mut ef, mut ed) = (0.0, 0.0);
        let mut path = String::new();
        for s in 0..seeds {
            let mut rng = Rng::new(5000 + s);
            let a = Matrix::from_fn(n, n, |_, _| rng.f32_with_exponent(e));
            let b = Matrix::from_fn(n, n, |_, _| rng.f32_with_exponent(e));
            let d = policy.decide(&a, &b);
            path = format!("{} sb={}", d.backend.name(), d.scale_exp);
            let c_ref = dgemm_of_f32(&a, &b);
            ef += relative_error(
                &c_ref,
                &cube_gemm(&a, &b, SplitConfig::with_scale(12), Accumulation::Termwise).to_f64(),
            );
            let exec = crate::gemm::backend::GemmBackend::new(d.backend)
                .with_scale(d.scale_exp)
                .exact();
            ed += relative_error(&c_ref, &exec.gemm(&a, &b).to_f64());
        }
        t.row(vec![
            e.to_string(),
            path,
            sci(ef / seeds as f64),
            sci(ed / seeds as f64),
            format!("{:.1}x", ef / ed),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_low_ratio_near_one() {
        let t = run_low_low(48, 2);
        for r in &t.rows {
            let ratio: f64 = r[3].parse().unwrap();
            // Four-term at most marginally better — the omission is safe.
            assert!((0.5..4.0).contains(&ratio), "ratio {ratio}");
        }
    }

    #[test]
    fn rz_split_loses_one_to_three_bits() {
        let t = run_rounding(48, 2);
        for r in &t.rows {
            let bits: f64 = r[3].parse().unwrap();
            assert!((0.5..3.5).contains(&bits), "bits {bits}");
        }
    }

    #[test]
    fn range_policy_wins_below_window() {
        let t = run_dynamic_scaling(32, 2);
        let row = t.rows.iter().find(|r| r[0] == "-18").unwrap();
        assert!(row[1].starts_with("fp32"), "chosen path {}", row[1]);
        let gain: f64 = row[4].trim_end_matches('x').parse().unwrap();
        assert!(gain > 10.0, "gain {gain}");
        // Inside the window the policy keeps the cube path at s_b = 12.
        let row0 = t.rows.iter().find(|r| r[0] == "0").unwrap();
        assert!(row0[1].starts_with("cube"), "chosen path {}", row0[1]);
    }
}
