//! Fig. 10: roofline analysis of single- vs double-buffered SGEMM-cube
//! on the 910A model — OI (Eq. 10), P_roof (Eq. 11), and the simulated
//! achieved throughput for a spread of block configurations.

use crate::experiments::report::{fixed, Table};
use crate::sim::blocking::{BlockConfig, GemmShape};
use crate::sim::chip::Chip;
use crate::sim::executor::simulate_sgemm_cube;
use crate::sim::pipeline::Buffering;
use crate::sim::roofline::knee_oi;

/// The block configurations Fig. 10 sweeps.
pub fn sweep_configs() -> Vec<BlockConfig> {
    vec![
        BlockConfig::new(48, 64, 48),
        BlockConfig::new(64, 64, 64),
        BlockConfig::new(96, 64, 96),
        BlockConfig::new(128, 64, 128),
        BlockConfig::new(160, 64, 160),
        BlockConfig::paper_best(),
        BlockConfig::new(96, 128, 96),
        BlockConfig::new(128, 32, 128),
    ]
}

/// Run the Fig. 10 roofline sweep for `shape`.
pub fn run(shape: GemmShape) -> Table {
    let chip = Chip::ascend_910a();
    let mut t = Table::new(
        &format!(
            "Fig 10: roofline, 910A (knee OI = {:.1} F/B, FP32-equiv peak = {:.1} TF/s)",
            knee_oi(&chip),
            chip.fp32_equiv_peak_tflops()
        ),
        &["bm", "bk", "bn", "OI (F/B)", "P_roof", "single TF/s", "double TF/s"],
    );
    for cfg in sweep_configs() {
        let s = simulate_sgemm_cube(&chip, shape, cfg, Buffering::Single);
        let d = simulate_sgemm_cube(&chip, shape, cfg, Buffering::Double);
        t.row(vec![
            cfg.bm.to_string(),
            cfg.bk.to_string(),
            cfg.bn.to_string(),
            fixed(d.oi, 1),
            fixed(d.roof, 1),
            fixed(s.tflops, 1),
            fixed(d.tflops, 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> GemmShape {
        GemmShape::new(5632, 4096, 5632)
    }

    #[test]
    fn all_configs_compute_bound() {
        // Paper: all measured OI values lie above the knee.
        let chip = Chip::ascend_910a();
        let t = run(shape());
        for r in &t.rows {
            let oi: f64 = r[3].parse().unwrap();
            assert!(oi > knee_oi(&chip), "OI {oi} below knee");
            let roof: f64 = r[4].parse().unwrap();
            assert_eq!(roof, 85.3, "roof should be the compute ceiling");
        }
    }

    #[test]
    fn double_buffering_improves_but_stays_below_roof() {
        let t = run(shape());
        for r in &t.rows {
            let s: f64 = r[5].parse().unwrap();
            let d: f64 = r[6].parse().unwrap();
            let roof: f64 = r[4].parse().unwrap();
            assert!(d >= s, "double {d} < single {s}");
            assert!(d < roof, "double {d} must stay below the roof {roof}");
        }
    }
}
