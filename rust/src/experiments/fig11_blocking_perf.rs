//! Fig. 11: throughput vs blocking configuration, single vs double
//! buffer. Paper anchors: single peaks at 41.7 TFLOP/s, double at
//! 65.3 TFLOP/s (77% of the 85.3 FP32-equivalent peak), best block
//! (176, 64, 176) with N_fused = 44.

use crate::experiments::report::{fixed, Table};
use crate::sim::blocking::{feasible_blocks, BlockConfig, GemmShape};
use crate::sim::chip::Chip;
use crate::sim::executor::simulate_sgemm_cube;
use crate::sim::pipeline::Buffering;

/// Full sweep over feasible square-ish blocks (plus the paper's best).
pub fn run(shape: GemmShape) -> Table {
    let chip = Chip::ascend_910a();
    let mut t = Table::new(
        "Fig 11: SGEMM-cube throughput vs blocking (910A, FP32-equivalent TF/s)",
        &["bm", "bk", "bn", "N_fused", "single", "double", "gain"],
    );
    let mut configs: Vec<BlockConfig> = feasible_blocks(&chip, 224)
        .into_iter()
        .filter(|c| c.bn == c.bm && (c.bk == 32 || c.bk == 64 || c.bk == 128))
        .collect();
    if !configs.contains(&BlockConfig::paper_best()) {
        configs.push(BlockConfig::paper_best());
    }
    configs.sort_by_key(|c| (c.bk, c.bm));
    for cfg in configs {
        if cfg.n_fused(&chip) == 0 {
            continue;
        }
        let s = simulate_sgemm_cube(&chip, shape, cfg, Buffering::Single);
        let d = simulate_sgemm_cube(&chip, shape, cfg, Buffering::Double);
        t.row(vec![
            cfg.bm.to_string(),
            cfg.bk.to_string(),
            cfg.bn.to_string(),
            cfg.n_fused(&chip).to_string(),
            fixed(s.tflops, 1),
            fixed(d.tflops, 1),
            format!("{:.0}%", (d.tflops / s.tflops - 1.0) * 100.0),
        ]);
    }
    t
}

/// The headline numbers (best block), for Table 2 and EXPERIMENTS.md.
pub fn headline(shape: GemmShape) -> (f64, f64, f64) {
    let chip = Chip::ascend_910a();
    let best = BlockConfig::paper_best();
    let s = simulate_sgemm_cube(&chip, shape, best, Buffering::Single);
    let d = simulate_sgemm_cube(&chip, shape, best, Buffering::Double);
    (s.tflops, d.tflops, d.tflops / chip.fp32_equiv_peak_tflops())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> GemmShape {
        GemmShape::new(5632, 4096, 5632)
    }

    #[test]
    fn headline_matches_paper_anchors() {
        let (single, double, frac) = headline(shape());
        assert!((single - 41.7).abs() < 3.0, "single {single}");
        assert!((double - 65.3).abs() < 3.5, "double {double}");
        assert!((frac - 0.77).abs() < 0.05, "fraction {frac}");
    }

    #[test]
    fn best_block_is_at_or_near_the_paper_config() {
        let t = run(shape());
        let best = t
            .rows
            .iter()
            .max_by(|a, b| {
                a[5].parse::<f64>().unwrap().total_cmp(&b[5].parse::<f64>().unwrap())
            })
            .unwrap();
        let bm: usize = best[0].parse().unwrap();
        // The paper's best is (176, 64, 176); the model's optimum must
        // land on a large-bm config (>= 160) of the same family.
        assert!(bm >= 160, "best bm {bm}");
    }

    #[test]
    fn small_blocks_are_low_points() {
        let t = run(shape());
        let small = t.rows.iter().find(|r| r[0] == "16" && r[1] == "32").unwrap();
        let d: f64 = small[5].parse().unwrap();
        assert!(d < 20.0, "16-blocks should be slow, got {d}");
    }
}
