//! Fig. 9: relative error vs matrix size at offset exponent 0.
//! (a) m = n sweep at fixed k — error flat (accumulation depth is k);
//! (b, c) k sweep — termwise beats elementwise and FP32 SGEMM.

use crate::experiments::report::{sci, Table};
use crate::gemm::cube::{cube_gemm, Accumulation};
use crate::gemm::dgemm::dgemm_of_f32;
use crate::gemm::error::relative_error;
use crate::gemm::sgemm::sgemm;
use crate::softfloat::split::SplitConfig;
use crate::util::mat::Matrix;
use crate::util::rng::Rng;

fn errors_at(m: usize, k: usize, n: usize, seeds: u64) -> (f64, f64, f64) {
    let (mut e_s, mut e_el, mut e_tw) = (0.0, 0.0, 0.0);
    for s in 0..seeds {
        let mut rng = Rng::new(2000 + s);
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let c_ref = dgemm_of_f32(&a, &b);
        let cfg = SplitConfig::default();
        e_s += relative_error(&c_ref, &sgemm(&a, &b).to_f64());
        e_el += relative_error(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Elementwise).to_f64());
        e_tw += relative_error(&c_ref, &cube_gemm(&a, &b, cfg, Accumulation::Termwise).to_f64());
    }
    (e_s / seeds as f64, e_el / seeds as f64, e_tw / seeds as f64)
}

/// Fig. 9(a): m = n sweep at fixed k.
pub fn run_mn_sweep(sizes: &[usize], k: usize, seeds: u64) -> Table {
    let mut t = Table::new(
        &format!("Fig 9(a): relative error vs m=n (k={k}, e=0)"),
        &["m=n", "sgemm-fp32", "cube-elementwise", "cube-termwise"],
    );
    for &mn in sizes {
        let (s, el, tw) = errors_at(mn, k, mn, seeds);
        t.row(vec![mn.to_string(), sci(s), sci(el), sci(tw)]);
    }
    t
}

/// Fig. 9(b,c): k sweep at fixed m = n.
pub fn run_k_sweep(mn: usize, ks: &[usize], seeds: u64) -> Table {
    let mut t = Table::new(
        &format!("Fig 9(b,c): relative error vs k (m=n={mn}, e=0)"),
        &["k", "sgemm-fp32", "cube-elementwise", "cube-termwise"],
    );
    for &k in ks {
        let (s, el, tw) = errors_at(mn, k, mn, seeds);
        t.row(vec![k.to_string(), sci(s), sci(el), sci(tw)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_flat_in_mn() {
        // Paper: varying m, n with fixed k leaves the error nearly
        // unchanged (within 2x across the sweep).
        let t = run_mn_sweep(&[16, 48, 96], 128, 2);
        let errs: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        let (min, max) = (
            errs.iter().cloned().fold(f64::MAX, f64::min),
            errs.iter().cloned().fold(0.0, f64::max),
        );
        assert!(max / min < 3.0, "termwise spread too wide: {errs:?}");
    }

    #[test]
    fn termwise_wins_as_k_grows() {
        let t = run_k_sweep(24, &[64, 512, 2048], 2);
        let last = t.rows.last().unwrap();
        let s: f64 = last[1].parse().unwrap();
        let el: f64 = last[2].parse().unwrap();
        let tw: f64 = last[3].parse().unwrap();
        assert!(tw <= el, "termwise {tw} vs elementwise {el}");
        assert!(tw <= s * 1.5, "termwise {tw} vs sgemm {s}");
    }

    #[test]
    fn error_grows_with_k_for_elementwise() {
        let t = run_k_sweep(16, &[64, 2048], 2);
        let first: f64 = t.rows[0][2].parse().unwrap();
        let last: f64 = t.rows[1][2].parse().unwrap();
        assert!(last > first * 0.5, "k growth should not shrink error an order: {first} -> {last}");
    }
}
