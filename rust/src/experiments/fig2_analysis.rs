//! Fig. 2: (a) residual underflow / gradual-underflow probability vs the
//! FP32 offset exponent; (b) retained precision bits vs exponent with and
//! without residual scaling. Analytic (Eq. 3–6) vs Monte-Carlo measured.

use crate::experiments::report::{fixed, Table};
use crate::softfloat::analysis::{
    measure_precision_bits, precision_bits_model, underflow_sweep,
};
use crate::softfloat::f16::SubnormalMode;
use crate::util::rng::Rng;

/// Fig. 2(a).
pub fn run_underflow(samples: usize, seed: u64) -> Table {
    let rows = underflow_sweep(-16, 6, samples, seed);
    let mut t = Table::new(
        "Fig 2(a): residual underflow probability vs FP32 offset exponent",
        &["E_offset", "P(u+gu) analytic", "P(u+gu) measured", "P(u) analytic", "P(u) measured"],
    );
    for r in rows {
        t.row(vec![
            r.e_offset.to_string(),
            fixed(r.analytic_gradual_or_under, 4),
            fixed(r.measured_gradual_or_under, 4),
            fixed(r.analytic_under, 4),
            fixed(r.measured_under, 4),
        ]);
    }
    t
}

/// Fig. 2(b).
pub fn run_precision_bits(samples: usize, seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let mut t = Table::new(
        "Fig 2(b): retained precision bits vs FP32 offset exponent",
        &["E_offset", "model s_b=0", "measured s_b=0", "model s_b=12", "measured s_b=12"],
    );
    for e in (-24..=15).step_by(2) {
        t.row(vec![
            e.to_string(),
            fixed(precision_bits_model(e, 0, SubnormalMode::Supported), 1),
            fixed(measure_precision_bits(e, 0, samples, &mut rng), 1),
            fixed(precision_bits_model(e, 12, SubnormalMode::Supported), 1),
            fixed(measure_precision_bits(e, 12, samples, &mut rng), 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn underflow_table_shape_and_anchors() {
        let t = run_underflow(2_000, 1);
        assert_eq!(t.rows.len(), 23);
        // Paper anchor: gradual-underflow > 10% at E_offset = 0.
        let row0 = t.rows.iter().find(|r| r[0] == "0").unwrap();
        assert!(row0[1].parse::<f64>().unwrap() > 0.10);
    }

    #[test]
    fn precision_table_scaling_expands_range() {
        let t = run_precision_bits(500, 2);
        // At E = -12: s_b=0 collapsed, s_b=12 full.
        let row = t.rows.iter().find(|r| r[0] == "-12").unwrap();
        let unscaled: f64 = row[1].parse().unwrap();
        let scaled: f64 = row[3].parse().unwrap();
        assert!(scaled >= 22.0 - 1e-9);
        assert!(unscaled <= 12.0);
    }
}
