//! Persistent executor subsystem: one worker pool for sweeps, prefetch
//! and serving.
//!
//! * [`pool`] — the lazily-initialized process-wide worker pool
//!   ([`pool::global`]): a scoped data-parallel primitive
//!   ([`pool::Pool::run_chunks`], the engine of
//!   [`crate::util::threads::parallel_chunks`]) plus detached jobs with
//!   cancellable handles ([`pool::Pool::submit`]). Replaces the
//!   per-sweep scoped spawns, the per-call prefetch threads and the
//!   per-service worker sets of earlier PRs with a single fixed thread
//!   population, so concurrent serving load no longer oversubscribes
//!   the host.
//! * [`pipeline`] — the depth-configurable prefetch ring over the
//!   blocked engine's `b_n → b_k` panel loop: overlapped-B (the paper's
//!   Fig. 7 double-buffered B stream) and overlapped-AB (B panel + A
//!   row-block stripe prefetched together), both bit-identical to the
//!   serial sweeps.
//! * [`faults`] — deterministic failpoints planted in the pool task
//!   path, the prefetch ring, the prepack cache and batch/shard
//!   execution; a single relaxed atomic load when disarmed, the chaos
//!   suite's lever when armed (`SGEMM_CUBE_FAILPOINTS` or the
//!   programmatic API).

pub mod faults;
pub mod pipeline;
pub mod pool;

pub use faults::{FailPolicy, InjectedFault};
pub use pipeline::{clamp_depth, PrefetchStats, DEFAULT_PIPELINE_DEPTH, MAX_PIPELINE_DEPTH};
pub use pool::{Pool, TaskHandle, TaskState};
