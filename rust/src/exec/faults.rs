//! Deterministic fault injection: named failpoints for the serving and
//! executor layers.
//!
//! A *failpoint* is a named site in production code — e.g.
//! `coordinator.batch.exec` — that is a no-op until a test (or the
//! `SGEMM_CUBE_FAILPOINTS` environment variable) arms it with a policy:
//!
//! * `panic` — panic at the site (exercises the `catch_unwind` /
//!   detached-panic containment paths),
//! * `error` — return [`InjectedFault`], which call sites map to a
//!   typed [`crate::gemm::error::GemmError::Injected`],
//! * `delay(ms)` — sleep at the site (exercises deadlines, overload
//!   shedding and pipeline stalls),
//! * `off` — remove the site's configuration.
//!
//! Trigger counting is deterministic: a site configured with
//! [`configure_nth`]`(site, policy, after, times)` fires on hits
//! `after, after+1, …` until it has fired `times` times, then goes
//! quiet. Hit and fire counters are observable ([`hits`], [`fired`])
//! so chaos tests can pin exact schedules.
//!
//! **Disabled cost.** When nothing is armed, [`check`] compiles to a
//! single relaxed atomic load and an equality test — no lock, no map
//! lookup, no allocation. The registry only gets involved while at
//! least one site holds a non-`off` policy.
//!
//! Environment syntax (applied once, on first use):
//!
//! ```text
//! SGEMM_CUBE_FAILPOINTS="site=policy[@after[:times]][;site2=...]"
//! SGEMM_CUBE_FAILPOINTS="coordinator.batch.exec=panic@3:1;exec.pipeline.prefetch=delay(5)"
//! ```
//!
//! Planted sites (all no-ops unless armed):
//!
//! | site | where | effect when armed |
//! |------|-------|-------------------|
//! | `exec.pool.task` | start of every detached pool task | detached-panic containment |
//! | `exec.pipeline.prefetch` | prefetch-ring pack step | ring poisoning → consumer panic |
//! | `gemm.cache.prepack` | prepack-cache miss path (outside the lock) | pack failure without lock poisoning |
//! | `coordinator.batch.exec` | per-request batch execution | typed request failure / retry |
//! | `coordinator.shard.exec` (+ `.N`) | per-slice shard execution | shard failure → health/failover |

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Duration;

/// What a triggered failpoint does at its site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailPolicy {
    /// Not armed (configuring a site `Off` removes it).
    Off,
    /// Panic at the site.
    Panic,
    /// Return [`InjectedFault`] from [`check`].
    Error,
    /// Sleep this many milliseconds at the site, then proceed normally.
    Delay(u64),
}

impl FailPolicy {
    /// Parse the env-spec form: `off`, `panic`, `error`, `delay(ms)`.
    pub fn parse(s: &str) -> Option<FailPolicy> {
        match s {
            "off" => Some(FailPolicy::Off),
            "panic" => Some(FailPolicy::Panic),
            "error" => Some(FailPolicy::Error),
            _ => {
                let ms = s.strip_prefix("delay(")?.strip_suffix(')')?;
                Some(FailPolicy::Delay(ms.trim().parse().ok()?))
            }
        }
    }
}

/// The typed result of an `error`-policy failpoint firing. Call sites
/// on the serving path convert it to
/// [`crate::gemm::error::GemmError::Injected`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InjectedFault {
    /// The site that fired.
    pub site: String,
    /// Which hit at the site this was (1-based).
    pub hit: u64,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "failpoint '{}' injected error (hit {})", self.site, self.hit)
    }
}

impl std::error::Error for InjectedFault {}

// Arming state: a three-valued relaxed atomic so the disabled fast path
// is one load. UNINIT forces the first check through the slow path,
// which applies SGEMM_CUBE_FAILPOINTS exactly once and then settles on
// DISARMED/ARMED.
const UNINIT: u8 = 0;
const DISARMED: u8 = 1;
const ARMED: u8 = 2;
static STATE: AtomicU8 = AtomicU8::new(UNINIT);

#[derive(Debug, Clone, Copy)]
struct Site {
    policy: FailPolicy,
    /// First hit (1-based) that triggers.
    after: u64,
    /// Maximum number of triggers before the site goes quiet.
    times: u64,
    hits: u64,
    fired: u64,
}

fn registry() -> &'static Mutex<HashMap<String, Site>> {
    static REG: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Apply `SGEMM_CUBE_FAILPOINTS` exactly once (idempotent, re-entrant
/// safe: inserts into the registry directly rather than recursing
/// through [`configure_nth`]).
fn ensure_init() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        if let Ok(spec) = std::env::var("SGEMM_CUBE_FAILPOINTS") {
            let mut reg = registry().lock().unwrap();
            for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
                match parse_entry(entry) {
                    Some((site, FailPolicy::Off, _, _)) => {
                        reg.remove(&site);
                    }
                    Some((site, policy, after, times)) => {
                        reg.insert(
                            site,
                            Site { policy, after, times, hits: 0, fired: 0 },
                        );
                    }
                    None => eprintln!(
                        "SGEMM_CUBE_FAILPOINTS: ignoring malformed entry '{entry}'"
                    ),
                }
            }
            rearm(&reg);
        }
        // No env (or env armed nothing): settle out of UNINIT so every
        // later check is the one-load fast path.
        let _ = STATE.compare_exchange(UNINIT, DISARMED, Ordering::SeqCst, Ordering::SeqCst);
    });
}

/// `site=policy[@after[:times]]` → `(site, policy, after, times)`.
fn parse_entry(entry: &str) -> Option<(String, FailPolicy, u64, u64)> {
    let (site, rhs) = entry.split_once('=')?;
    let site = site.trim();
    if site.is_empty() {
        return None;
    }
    let (policy_s, after, times) = match rhs.trim().split_once('@') {
        Some((p, trigger)) => {
            let (after, times) = match trigger.split_once(':') {
                Some((a, t)) => (a.trim().parse().ok()?, t.trim().parse().ok()?),
                None => (trigger.trim().parse().ok()?, u64::MAX),
            };
            (p.trim(), after, times)
        }
        None => (rhs.trim(), 1, u64::MAX),
    };
    let policy = FailPolicy::parse(policy_s)?;
    Some((site.to_string(), policy, after.max(1), times))
}

/// Recompute the arming flag from the registry contents (caller holds
/// the registry lock and passes the guarded map).
fn rearm(reg: &HashMap<String, Site>) {
    let armed = reg.values().any(|s| s.policy != FailPolicy::Off);
    STATE.store(if armed { ARMED } else { DISARMED }, Ordering::SeqCst);
}

/// Arm `site` with `policy`, triggering from the first hit with no
/// fire limit. `FailPolicy::Off` disarms the site.
pub fn configure(site: &str, policy: FailPolicy) {
    configure_nth(site, policy, 1, u64::MAX);
}

/// Arm `site` with `policy`, triggering on hits `after, after+1, …`
/// (1-based) for at most `times` fires. Resets the site's hit/fire
/// counters, so reconfiguring replays the same deterministic schedule.
pub fn configure_nth(site: &str, policy: FailPolicy, after: u64, times: u64) {
    ensure_init();
    let mut reg = registry().lock().unwrap();
    if policy == FailPolicy::Off {
        reg.remove(site);
    } else {
        reg.insert(
            site.to_string(),
            Site { policy, after: after.max(1), times, hits: 0, fired: 0 },
        );
    }
    rearm(&reg);
}

/// Disarm every site (test teardown).
pub fn reset() {
    ensure_init();
    let mut reg = registry().lock().unwrap();
    reg.clear();
    rearm(&reg);
}

/// Whether any site is currently armed.
pub fn armed() -> bool {
    STATE.load(Ordering::Relaxed) == ARMED
}

/// Total hits observed at `site` since it was (re)configured.
pub fn hits(site: &str) -> u64 {
    registry().lock().unwrap().get(site).map_or(0, |s| s.hits)
}

/// Times `site` actually triggered since it was (re)configured.
pub fn fired(site: &str) -> u64 {
    registry().lock().unwrap().get(site).map_or(0, |s| s.fired)
}

/// Evaluate the failpoint at `site`. Disabled cost: one relaxed atomic
/// load. When the site triggers: `Panic` panics here, `Delay` sleeps
/// here, `Error` returns the fault for the call site to surface as a
/// typed error.
#[inline]
pub fn check(site: &str) -> Result<(), InjectedFault> {
    if STATE.load(Ordering::Relaxed) == DISARMED {
        return Ok(());
    }
    check_slow(site)
}

/// Like [`check`] for sites that cannot propagate an error (detached
/// pool tasks, cache pack closures): an `error` policy panics too.
#[inline]
pub fn fire(site: &str) {
    if STATE.load(Ordering::Relaxed) == DISARMED {
        return;
    }
    if let Err(f) = check_slow(site) {
        panic!("{f}");
    }
}

/// Per-instance variant for replicated sites (shards): consults
/// `"{site}.{idx}"` first, then the bare `site`, so a test can target
/// one shard or all of them. Allocates the composed name only while
/// armed.
#[inline]
pub fn check_indexed(site: &str, idx: usize) -> Result<(), InjectedFault> {
    if STATE.load(Ordering::Relaxed) == DISARMED {
        return Ok(());
    }
    check_slow(&format!("{site}.{idx}"))?;
    check_slow(site)
}

#[cold]
fn check_slow(site: &str) -> Result<(), InjectedFault> {
    ensure_init();
    if STATE.load(Ordering::Relaxed) == DISARMED {
        return Ok(());
    }
    let (policy, hit) = {
        let mut reg = registry().lock().unwrap();
        let Some(s) = reg.get_mut(site) else { return Ok(()) };
        s.hits += 1;
        if s.policy == FailPolicy::Off || s.hits < s.after || s.fired >= s.times {
            return Ok(());
        }
        s.fired += 1;
        (s.policy, s.hits)
    };
    match policy {
        FailPolicy::Off => Ok(()),
        FailPolicy::Panic => panic!("failpoint '{site}' injected panic (hit {hit})"),
        FailPolicy::Delay(ms) => {
            // Sleep outside the registry lock so a delayed site never
            // stalls checks at other sites.
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        FailPolicy::Error => Err(InjectedFault { site: site.to_string(), hit }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests share the process-global registry with every other
    // test in the lib binary, so they only touch synthetic
    // `test.faults.*` sites and disarm exactly those sites when done
    // (never `reset()`, which would disarm concurrent tests).

    #[test]
    fn unconfigured_site_is_a_noop() {
        for _ in 0..10 {
            assert!(check("test.faults.never").is_ok());
        }
        assert_eq!(hits("test.faults.never"), 0, "disabled checks must not even count");
    }

    #[test]
    fn error_policy_fires_deterministically_from_nth_hit() {
        let site = "test.faults.nth";
        configure_nth(site, FailPolicy::Error, 3, 2);
        assert!(armed());
        let fires: Vec<u64> =
            (1..=8u64).filter(|_| check(site).is_err()).collect();
        // 1-based positions 3 and 4 fire; the `times` budget then quiets
        // the site for good.
        assert_eq!(hits(site), 8);
        assert_eq!(fired(site), 2);
        assert_eq!(fires.len(), 2);
        // Reconfiguring resets counters: the schedule replays exactly.
        configure_nth(site, FailPolicy::Error, 3, 2);
        let replay: Vec<usize> =
            (1..=8usize).filter(|_| check(site).is_err()).collect();
        assert_eq!(replay, vec![3, 4]);
        configure(site, FailPolicy::Off);
    }

    #[test]
    fn error_carries_site_and_hit() {
        let site = "test.faults.err";
        configure(site, FailPolicy::Error);
        let f = check(site).unwrap_err();
        assert_eq!(f.site, site);
        assert_eq!(f.hit, 1);
        assert!(format!("{f}").contains("test.faults.err"));
        configure(site, FailPolicy::Off);
        assert!(check(site).is_ok(), "off removes the site");
    }

    #[test]
    fn panic_policy_panics_with_site_name() {
        let site = "test.faults.boom";
        configure(site, FailPolicy::Panic);
        let r = std::panic::catch_unwind(|| {
            let _ = check(site);
        });
        configure(site, FailPolicy::Off);
        let payload = r.expect_err("panic policy must panic");
        let msg = payload.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("test.faults.boom"), "{msg}");
    }

    #[test]
    fn fire_panics_on_error_policy() {
        let site = "test.faults.fire";
        configure(site, FailPolicy::Error);
        let r = std::panic::catch_unwind(|| fire(site));
        configure(site, FailPolicy::Off);
        assert!(r.is_err(), "fire() must escalate error policies to panics");
    }

    #[test]
    fn delay_policy_sleeps_then_proceeds() {
        let site = "test.faults.delay";
        configure(site, FailPolicy::Delay(20));
        let t0 = std::time::Instant::now();
        assert!(check(site).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(15), "{:?}", t0.elapsed());
        configure(site, FailPolicy::Off);
    }

    #[test]
    fn indexed_sites_match_suffix_then_base() {
        let site = "test.faults.shardy";
        configure(&format!("{site}.1"), FailPolicy::Error);
        assert!(check_indexed(site, 0).is_ok());
        assert!(check_indexed(site, 1).is_err());
        configure(&format!("{site}.1"), FailPolicy::Off);
        configure(site, FailPolicy::Error);
        assert!(check_indexed(site, 0).is_err(), "base site catches every index");
        configure(site, FailPolicy::Off);
    }

    #[test]
    fn env_spec_parsing() {
        assert_eq!(
            parse_entry("a.b=panic"),
            Some(("a.b".to_string(), FailPolicy::Panic, 1, u64::MAX))
        );
        assert_eq!(
            parse_entry(" a.b = delay(5) @ 3 : 2 "),
            Some(("a.b".to_string(), FailPolicy::Delay(5), 3, 2))
        );
        assert_eq!(
            parse_entry("x=error@7"),
            Some(("x".to_string(), FailPolicy::Error, 7, u64::MAX))
        );
        assert_eq!(parse_entry("x=off"), Some(("x".to_string(), FailPolicy::Off, 1, u64::MAX)));
        // `after` is clamped to 1 (hit counting is 1-based).
        assert_eq!(parse_entry("x=error@0"), Some(("x".to_string(), FailPolicy::Error, 1, u64::MAX)));
        for bad in ["", "=panic", "x", "x=warp", "x=delay(", "x=delay(a)", "x=error@a"] {
            assert_eq!(parse_entry(bad), None, "{bad:?}");
        }
        assert_eq!(FailPolicy::parse("delay(250)"), Some(FailPolicy::Delay(250)));
        assert_eq!(FailPolicy::parse("panic "), None, "caller trims");
    }
}
